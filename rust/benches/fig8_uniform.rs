//! Fig 8 — Varying the number of parallel components uniformly: n × (1p 1w
//! 1k) with a fixed engine count per kernel. Throughput rises with the
//! added kernels; per-request execution time *also* rises because the
//! fuller board clocks lower (§4.3).

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::{simulate, SimConfig, Topology};

fn main() {
    let batches: Vec<usize> = (8..=17).map(|i| 1usize << i).collect();
    let configs = [
        Topology::new(1, 1, 1, 1),
        Topology::new(2, 2, 2, 1),
        Topology::new(4, 4, 4, 1),
        Topology::new(1, 1, 1, 2),
        Topology::new(2, 2, 2, 2),
    ];
    let mut thr_rows = Vec::new();
    let mut lat_rows = Vec::new();
    for &b in &batches {
        let mut thr = vec![b.to_string()];
        let mut lat = vec![b.to_string()];
        for t in &configs {
            let r = simulate(&SimConfig::v2_cloud(*t, b));
            thr.push(fmt_qps(r.throughput_qps));
            lat.push(fmt_us(r.exec_p90_us));
        }
        thr_rows.push(thr);
        lat_rows.push(lat);
    }
    let labels: Vec<String> = configs.iter().map(|t| t.label()).collect();
    let mut headers = vec!["batch/request".to_string()];
    headers.extend(labels);
    let h: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig 8a — global throughput (uniform scaling)", &h, &thr_rows);
    print_table("Fig 8b — p90 execution time of a single MCT request", &h, &lat_rows);
    println!("\npaper anchors: throughput scales with kernels; latency increases as the");
    println!("board fills (slower clock); throughput prioritised over single-request time.");
}
