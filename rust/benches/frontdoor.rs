//! Front-door bench: sessions-vs-p99 frontier and the thread-per-request
//! vs event-driven head-to-head at equal offered load.
//!
//! Two experiments, both on the accept clock (latency measured from when
//! a batch was *ready to send*, so queueing behind a parked session or a
//! full thread pool is charged to the door, not hidden — no coordinated
//! omission):
//!
//! 1. **Frontier (DES)** — sweep concurrent sessions S ∈ {M, 3M, 10M,
//!    30M} at a fixed offered load (≈0.1× fleet capacity; more sessions
//!    = a longer storm, not a heavier one). The event door accepts every
//!    session at every S; the thread-per-session door pegs at its M
//!    threads and sheds the rest at the socket.
//! 2. **Head-to-head (real)** — at S = 10·M the event reactor must
//!    sustain ≥ 10× the concurrent sessions of the thread-per-session
//!    door at a no-worse accept-clock p99. This is the PR's acceptance
//!    assertion, enforced here and recorded in the artifact.
//!
//! Emits machine-readable `BENCH_frontdoor.json` (override with
//! `BENCH_OUT`), uploaded by the CI bench-smoke step. `BENCH_SMOKE=1`
//! shrinks the thread cap and per-session depth for CI.

use erbium_search::backend::BackendFactory;
use erbium_search::benchkit::{print_table, write_json, Json};
use erbium_search::cluster::{
    AdmissionPolicy, Cluster, ClusterConfig, ClusterSimConfig, RoutePolicy, SimNodeSpec,
};
use erbium_search::controlplane::FaultPlan;
use erbium_search::coordinator::{AggregationPolicy, PipelineConfig, Topology};
use erbium_search::frontdoor::{
    run_frontdoor, sim_frontdoor, BackpressurePolicy, FrontdoorConfig, FrontdoorReport,
    FrontdoorSimConfig,
};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::rules::standard::StandardVersion;
use erbium_search::testing::fixture::compile_fixture;
use erbium_search::workload::{session_plans, PoissonSource, RateSchedule, SessionPlan};

const BATCH: usize = 16;
const WINDOW: usize = 4;
const NODES: usize = 2;
/// Offered load as a fraction of measured fleet capacity — well under
/// the knee, so the comparison is about multiplexing, not saturation.
const LOAD: f64 = 0.1;

fn node_cfg() -> PipelineConfig {
    PipelineConfig::new(Topology::new(2, 1, 1, 4))
        .with_aggregation(AggregationPolicy::DrainQueue)
}

/// Equal-offered-load session storm: the session arrival rate depends on
/// the per-node drain rate only, so sweeping `sessions` lengthens the
/// storm without changing the offered q/s.
fn storm(
    seed: u64,
    mu_rps: f64,
    sessions: usize,
    batches: usize,
    stations: usize,
) -> Vec<SessionPlan> {
    let rate = LOAD * NODES as f64 * mu_rps / batches as f64;
    session_plans(seed, &RateSchedule::constant(rate), sessions, batches, BATCH, 0.0, stations)
}

fn report_json(r: &FrontdoorReport) -> Json {
    Json::obj([
        ("mode", Json::Str(r.mode.clone())),
        ("backpressure", Json::Str(r.backpressure.clone())),
        ("sessions_offered", Json::Int(r.sessions_offered as i64)),
        ("sessions_accepted", Json::Int(r.sessions_accepted as i64)),
        ("sessions_shed", Json::Int(r.sessions_shed as i64)),
        ("offered_queries", Json::Int(r.offered_queries as i64)),
        ("completed_queries", Json::Int(r.completed_queries as i64)),
        ("shed_socket_queries", Json::Int(r.shed_socket_queries as i64)),
        ("shed_queue_queries", Json::Int(r.shed_queue_queries as i64)),
        ("lost_queries", Json::Int(r.lost_queries as i64)),
        ("goodput_qps", Json::Num(r.goodput_qps)),
        ("accept_p50_us", Json::Num(r.accept_p50_us)),
        ("accept_p99_us", Json::Num(r.accept_p99_us)),
        ("submit_p99_us", Json::Num(r.submit_p99_us)),
        ("omission_gap_us", Json::Num(r.omission_gap_us())),
    ])
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    // M = the thread-per-session door's thread cap; per-session depth.
    let (m_threads, batches) = if smoke { (4, 4) } else { (16, 8) };

    // ---- Frontier in the DES: sessions vs accept-clock p99 --------------
    let sim_cluster = ClusterSimConfig::v2_cloud(NODES, 2).with_route(RoutePolicy::RoundRobin);
    let spec = SimNodeSpec::v2_cloud(2);
    let mu_sim_rps = spec.capacity_qps(&sim_cluster.overheads, BATCH) / BATCH as f64;
    let sim_run = |frontdoor: FrontdoorConfig, plans: &[SessionPlan]| {
        sim_frontdoor(
            &FrontdoorSimConfig {
                cluster: sim_cluster.clone(),
                frontdoor,
                faults: FaultPlan::none(),
            },
            plans,
        )
    };

    let mut frontier_rows = Vec::new();
    let mut frontier_json = Vec::new();
    for mult in [1usize, 3, 10, 30] {
        let sessions = mult * m_threads;
        let plans = storm(0xF207 + mult as u64, mu_sim_rps, sessions, batches, 8);
        let event = sim_run(
            FrontdoorConfig::event(2, BackpressurePolicy::Window { window: WINDOW }),
            &plans,
        );
        let baseline = sim_run(FrontdoorConfig::thread_per_session(m_threads), &plans);
        assert!(event.conserves_queries() && baseline.conserves_queries());
        assert_eq!(event.sessions_accepted, sessions, "event door accepts every session");
        assert_eq!(
            baseline.sessions_accepted,
            m_threads.min(sessions),
            "thread door pegs at its thread cap"
        );
        assert!(
            event.accept_p99_us <= baseline.accept_p99_us,
            "S={sessions}: multiplexing must not cost tail: event {:.0} vs thread {:.0} µs",
            event.accept_p99_us,
            baseline.accept_p99_us
        );
        frontier_rows.push(vec![
            format!("{sessions}"),
            format!("{}", event.sessions_accepted),
            format!("{:.0}", event.accept_p99_us),
            format!("{}", baseline.sessions_accepted),
            format!("{:.0}", baseline.accept_p99_us),
        ]);
        frontier_json.push(Json::obj([
            ("sessions", Json::Int(sessions as i64)),
            ("event", report_json(&event)),
            ("thread_per_session", report_json(&baseline)),
        ]));
    }
    print_table(
        "sessions-vs-p99 frontier (DES, equal offered load)",
        &["sessions", "event accepted", "event p99 µs", "thread accepted", "thread p99 µs"],
        &frontier_rows,
    );

    // ---- Head-to-head in the real reactor at S = 10·M -------------------
    let f = compile_fixture(4117, 300, StandardVersion::V2, HardwareConfig::v2_aws(4));
    let factory: BackendFactory = f.native_factory();
    let world = f.world;
    let probe_cfg = ClusterConfig::new(1, node_cfg()).with_admission(AdmissionPolicy::Open);
    let probe = Cluster::new(probe_cfg, factory.clone());
    let mu_real_rps = (0..2u64)
        .map(|i| {
            let mut src = PoissonSource::new(&world, 0xD00 ^ (1 + i), 1e8, BATCH, 240);
            probe.run(&mut src).expect("probe run").achieved_qps / BATCH as f64
        })
        .fold(0.0, f64::max);

    let sessions = 10 * m_threads;
    let plans = storm(0xF207, mu_real_rps, sessions, batches, world.airports.len());
    let real_cluster = ClusterConfig::new(NODES, node_cfg()).with_route(RoutePolicy::RoundRobin);
    let real_run = |fd: &FrontdoorConfig| {
        run_frontdoor(
            real_cluster.clone(),
            factory.clone(),
            &world,
            0xF207,
            &plans,
            fd,
            &FaultPlan::none(),
        )
        .expect("frontdoor run")
    };
    let event = real_run(&FrontdoorConfig::event(2, BackpressurePolicy::Window { window: WINDOW }));
    let baseline = real_run(&FrontdoorConfig::thread_per_session(m_threads));
    println!("\nevent : {}", event.summary());
    println!("thread: {}", baseline.summary());

    assert!(event.conserves_queries() && baseline.conserves_queries());
    assert!(
        event.sessions_accepted >= 10 * baseline.sessions_accepted,
        "acceptance: event door must sustain ≥10× the concurrent sessions: {} vs {}",
        event.sessions_accepted,
        baseline.sessions_accepted
    );
    assert!(
        event.accept_p99_us <= baseline.accept_p99_us,
        "acceptance: at no worse accept-clock p99: event {:.0} vs thread {:.0} µs",
        event.accept_p99_us,
        baseline.accept_p99_us
    );
    println!(
        "\nevent door: {}× sessions ({} vs {}) at p99 {:.0} µs vs {:.0} µs",
        event.sessions_accepted / baseline.sessions_accepted.max(1),
        event.sessions_accepted,
        baseline.sessions_accepted,
        event.accept_p99_us,
        baseline.accept_p99_us
    );

    // ---- Artifact -------------------------------------------------------
    let json = Json::obj([
        ("bench", Json::Str("frontdoor".into())),
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Int(BATCH as i64)),
        ("batches_per_session", Json::Int(batches as i64)),
        ("window", Json::Int(WINDOW as i64)),
        ("thread_cap", Json::Int(m_threads as i64)),
        ("load_fraction", Json::Num(LOAD)),
        ("mu_sim_rps", Json::Num(mu_sim_rps)),
        ("mu_real_rps", Json::Num(mu_real_rps)),
        ("frontier", Json::Arr(frontier_json)),
        (
            "head_to_head",
            Json::obj([
                ("sessions", Json::Int(sessions as i64)),
                ("event", report_json(&event)),
                ("thread_per_session", report_json(&baseline)),
            ]),
        ),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_frontdoor.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");
}
