//! Fig 7 — Varying the number of engines per kernel (1p 1w 1k, e ∈ {1,2,4}):
//! (a) global throughput in MCT queries/s, (b) execution time of a single
//! MCT request. Deterministic closed-loop simulation of the integrated
//! system (DESIGN.md §Dual-clock).

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::{simulate, SimConfig, Topology};

fn main() {
    let batches: Vec<usize> = (8..=17).map(|i| 1usize << i).collect();
    let mut thr_rows = Vec::new();
    let mut lat_rows = Vec::new();
    for &b in &batches {
        let mut thr = vec![b.to_string()];
        let mut lat = vec![b.to_string()];
        for e in [1usize, 2, 4] {
            let r = simulate(&SimConfig::v2_cloud(Topology::new(1, 1, 1, e), b));
            thr.push(fmt_qps(r.throughput_qps));
            lat.push(fmt_us(r.exec_p90_us));
        }
        thr_rows.push(thr);
        lat_rows.push(lat);
    }
    print_table(
        "Fig 7a — global throughput (1p 1w 1k, varying engines)",
        &["batch/request", "1p1w1k1e", "1p1w1k2e", "1p1w1k4e"],
        &thr_rows,
    );
    print_table(
        "Fig 7b — p90 execution time of a single MCT request",
        &["batch/request", "1p1w1k1e", "1p1w1k2e", "1p1w1k4e"],
        &lat_rows,
    );
    println!("\npaper anchors: more engines → lower request time & higher throughput,");
    println!("sub-linear scaling (30 % clock penalty at 4 engines).");
}
