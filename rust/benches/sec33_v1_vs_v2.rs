//! §3.3 scalars — the MCT v1 → v2 adaptation cost, compiled from the same
//! synthetic world:
//!
//! * consolidated criteria (NFA depth): 22 vs 26;
//! * resource intensity: paper reports v2 **+56 %**;
//! * FPGA memory: paper reports v2 **−4 %** (more homogeneous per-level
//!   transition distribution despite more rules);
//! * operating frequency: v2 **−11 %**;
//! * §3.2.2 range splitting: "zero to a few hundred" extra rules.

use erbium_search::benchkit::print_table;
use erbium_search::nfa::constraint_gen::{estimate, HardwareConfig};
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};

fn main() {
    let gen_cfg = GeneratorConfig { n_rules: 40_000, ..GeneratorConfig::default() };
    let world = generate_world(&gen_cfg);
    let opts = CompileOptions::default();

    let mut per_version = Vec::new();
    for version in [StandardVersion::V1, StandardVersion::V2] {
        let schema = Schema::for_version(version);
        let rs = generate_rule_set(&gen_cfg, &world, version);
        let (nfa, stats) = compile_rule_set(&schema, &rs, &opts);
        let hw = match version {
            StandardVersion::V1 => HardwareConfig::v1_onprem(4),
            StandardVersion::V2 => HardwareConfig::v2_aws(4),
        };
        let est = estimate(&hw, &nfa);
        per_version.push((version, rs.rules.len(), stats, est));
    }
    let (_, n1, s1, e1) = &per_version[0];
    let (_, n2, s2, e2) = &per_version[1];

    let rows = vec![
        vec!["rules".into(), n1.to_string(), n2.to_string(),
             format!("{:+.1} %", (*n2 as f64 / *n1 as f64 - 1.0) * 100.0), "larger set".into()],
        vec!["consolidated criteria (depth)".into(), s1.depth.to_string(), s2.depth.to_string(),
             format!("{:+}", s2.depth as i64 - s1.depth as i64), "22 → 26".into()],
        vec!["resource units".into(), format!("{:.0}", e1.resource_units),
             format!("{:.0}", e2.resource_units),
             format!("{:+.1} %", (e2.resource_units / e1.resource_units - 1.0) * 100.0),
             "+56 %".into()],
        vec!["FPGA memory (bytes)".into(), e1.memory_bytes.to_string(), e2.memory_bytes.to_string(),
             format!("{:+.1} %", (e2.memory_bytes as f64 / e1.memory_bytes as f64 - 1.0) * 100.0),
             "−4 %".into()],
        vec!["frequency (MHz)".into(), format!("{:.1}", e1.frequency_mhz),
             format!("{:.1}", e2.frequency_mhz),
             format!("{:+.1} %", (e2.frequency_mhz / e1.frequency_mhz - 1.0) * 100.0),
             "−11 %".into()],
        vec!["rules added by §3.2.2 split".into(), s1.rules_added_by_split.to_string(),
             s2.rules_added_by_split.to_string(), "—".into(), "0 .. few hundred".into()],
        vec!["partitions (VMEM tiles)".into(), s1.partitions.to_string(),
             s2.partitions.to_string(), "—".into(), "(ours: TPU adaptation)".into()],
        vec!["total transitions".into(), s1.total_transitions.to_string(),
             s2.total_transitions.to_string(),
             format!("{:+.1} %", (s2.total_transitions as f64 / s1.total_transitions as f64 - 1.0) * 100.0),
             "—".into()],
    ];
    print_table(
        "§3.3 — MCT v1 vs v2 deployment characteristics",
        &["metric", "v1", "v2", "delta", "paper"],
        &rows,
    );
}
