//! Disaggregated FPGA pool vs the PCIe fleet — the $/Mquery head-to-head.
//!
//! The §6.1 imbalance (one weak CPU feeder starves a PCIe-attached
//! kernel at large batch) means a PCIe fleet buys one board per feeder
//! and leaves most of each board idle. A network-attached pool decouples
//! the ratio: M feeders share N kernels over a modelled 10GbE hop, and N
//! is sized to the *kernel* demand, not the feeder count. Under
//! rack-density pricing (64 modules amortising one chassis) the pooled
//! kernels are also far cheaper per unit than f1.2xlarge boards.
//!
//! Two sweeps over the pool DES at the §6.1 batch:
//!
//! 1. **Kernel sweep** (10 feeders, N = 1..=8, fifo and packing leases):
//!    goodput climbs with N until the feeder ceiling binds; the
//!    head-to-head finds the smallest N that matches an 8-node PCIe
//!    fleet's goodput.
//! 2. **Feeder sweep** (3 kernels, M = 4..16): the mirrored knee —
//!    goodput climbs with M until the 3-kernel ceiling binds.
//!
//! Acceptance (the PR's tentpole claim): some pool with *strictly fewer*
//! kernels than the PCIe fleet's 8 boards reaches ≥ its goodput at
//! *strictly lower* $/Mquery, with each pooled kernel serving ≥2× the
//! queries of a PCIe board. Emits `BENCH_fpga_pool.json` (override with
//! `BENCH_OUT`); `BENCH_SMOKE=1` shrinks the workload for CI.

use erbium_search::benchkit::{fmt_qps, print_table, write_json, Json};
use erbium_search::cluster::sim::measure_node_saturation_qps;
use erbium_search::costmodel::{
    dollars_per_mquery, pcie_topology_hourly_usd, pool_topology_hourly_usd,
};
use erbium_search::pool::sim::{measure_pool_saturation_qps, PoolSimConfig};
use erbium_search::pool::LeasePolicy;

/// The §6.1 weak-feeder point: one feeder's sched+encode (~2.4 ms) caps
/// a PCIe node at a fraction of the kernel rate.
const BATCH: usize = 16_384;
const PCIE_NODES: usize = 8;
const POOL_FEEDERS: usize = 10;
const KERNEL_SWEEP: std::ops::RangeInclusive<usize> = 1..=8;
/// Acceptance: per-kernel goodput of the winning pool vs per-board
/// goodput of the PCIe fleet.
const MIN_KERNEL_LEVERAGE: f64 = 2.0;

fn pack_at_knee() -> LeasePolicy {
    // Two §6.1 batches per transfer: still coalescing, without letting
    // the age cap dominate at saturation.
    LeasePolicy::SizeAware { pack_queries: 2 * BATCH, age_cap_us: 600.0 }
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let requests = if smoke { 150 } else { 400 };

    // ---- PCIe baseline: 8 single-feeder nodes, one board each ----------
    let pcie_node_qps = measure_node_saturation_qps(1, BATCH, requests);
    let pcie_qps = PCIE_NODES as f64 * pcie_node_qps;
    let pcie_hourly = pcie_topology_hourly_usd(PCIE_NODES);
    let pcie_usd_mq = dollars_per_mquery(pcie_hourly, pcie_qps);

    // ---- 1. Kernel sweep: 10 feeders over N pooled kernels -------------
    let pool_qps = |kernels: usize, lease: LeasePolicy| {
        let cfg = PoolSimConfig::v2_pool(POOL_FEEDERS, kernels).with_lease(lease);
        measure_pool_saturation_qps(&cfg, BATCH, requests)
    };
    let mut rows = Vec::new();
    let mut sweep_json = Vec::new();
    let mut k_min = None;
    for kernels in KERNEL_SWEEP {
        let fifo = pool_qps(kernels, LeasePolicy::Fifo);
        let pack = pool_qps(kernels, pack_at_knee());
        let hourly = pool_topology_hourly_usd(POOL_FEEDERS, kernels);
        let usd_mq = dollars_per_mquery(hourly, fifo);
        if k_min.is_none() && fifo >= pcie_qps {
            k_min = Some((kernels, fifo, usd_mq));
        }
        rows.push(vec![
            format!("{POOL_FEEDERS}:{kernels}"),
            fmt_qps(fifo),
            fmt_qps(pack),
            format!("{:.2} $/h", hourly),
            format!("{:.2} µ$/Mq", usd_mq * 1e6),
            format!("{:.0} %", fifo / pcie_qps * 100.0),
        ]);
        sweep_json.push(Json::obj([
            ("kernels", Json::Int(kernels as i64)),
            ("fifo_qps", Json::Num(fifo)),
            ("pack_qps", Json::Num(pack)),
            ("hourly_usd", Json::Num(hourly)),
            ("fifo_usd_per_mquery", Json::Num(usd_mq)),
        ]));
    }
    print_table(
        &format!(
            "pool kernel sweep ({POOL_FEEDERS} feeders, batch {BATCH}) vs \
             {PCIE_NODES}-node PCIe fleet at {}",
            fmt_qps(pcie_qps)
        ),
        &["M:N", "fifo", "pack", "pool cost", "fifo $/Mq", "of PCIe goodput"],
        &rows,
    );

    // ---- 2. Feeder sweep: the mirrored knee at 3 kernels ---------------
    let mut feeder_rows = Vec::new();
    for feeders in [4usize, 6, 8, 10, 12, 16] {
        let cfg = PoolSimConfig::v2_pool(feeders, 3);
        let qps = measure_pool_saturation_qps(&cfg, BATCH, requests);
        let ceiling = cfg.ceiling_qps(BATCH);
        feeder_rows.push(vec![
            format!("{feeders}:3"),
            fmt_qps(qps),
            fmt_qps(ceiling),
            format!("{:.0} %", qps / ceiling * 100.0),
        ]);
    }
    print_table(
        "pool feeder sweep (3 kernels): goodput climbs to the kernel ceiling",
        &["M:N", "goodput", "model ceiling", "of ceiling"],
        &feeder_rows,
    );

    // ---- Head-to-head acceptance ---------------------------------------
    let (k, pool_match_qps, pool_usd_mq) =
        k_min.expect("some pool in the sweep must reach PCIe goodput");
    let leverage = (pool_match_qps / k as f64) / pcie_node_qps;
    println!(
        "\nhead-to-head: pool {POOL_FEEDERS}:{k} at {} matches the PCIe fleet's {} \
         with {k} kernels instead of {PCIE_NODES} boards",
        fmt_qps(pool_match_qps),
        fmt_qps(pcie_qps),
    );
    println!(
        "$/Mquery: pool {:.2} µ$ vs PCIe {:.2} µ$ ({:.1}× cheaper); \
         per-kernel leverage {leverage:.1}×",
        pool_usd_mq * 1e6,
        pcie_usd_mq * 1e6,
        pcie_usd_mq / pool_usd_mq,
    );
    assert!(
        k < PCIE_NODES,
        "acceptance: the matching pool must use strictly fewer kernels ({k} vs {PCIE_NODES})"
    );
    assert!(pool_match_qps >= pcie_qps, "acceptance: pool goodput must reach the PCIe fleet");
    assert!(
        pool_usd_mq < pcie_usd_mq,
        "acceptance: pool $/Mquery {pool_usd_mq:.3e} must be strictly below PCIe {pcie_usd_mq:.3e}"
    );
    assert!(
        leverage >= MIN_KERNEL_LEVERAGE,
        "acceptance: each pooled kernel must serve ≥{MIN_KERNEL_LEVERAGE}× a PCIe board's \
         queries, got {leverage:.2}×"
    );

    // ---- Artifact ------------------------------------------------------
    let json = Json::obj([
        ("bench", Json::Str("fpga_pool".into())),
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Int(BATCH as i64)),
        ("requests", Json::Int(requests as i64)),
        ("pcie_nodes", Json::Int(PCIE_NODES as i64)),
        ("pcie_qps", Json::Num(pcie_qps)),
        ("pcie_hourly_usd", Json::Num(pcie_hourly)),
        ("pcie_usd_per_mquery", Json::Num(pcie_usd_mq)),
        ("pool_feeders", Json::Int(POOL_FEEDERS as i64)),
        ("kernel_sweep", Json::Arr(sweep_json)),
        ("match_kernels", Json::Int(k as i64)),
        ("match_qps", Json::Num(pool_match_qps)),
        ("match_usd_per_mquery", Json::Num(pool_usd_mq)),
        ("kernel_leverage", Json::Num(leverage)),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_fpga_pool.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");
}
