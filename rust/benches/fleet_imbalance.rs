//! §6.1 — the fleet imbalance, end to end: sweep the feeder:FPGA ratio of
//! one node over an open-loop overload, print the achieved-throughput and
//! $/Mqps curves (the "FPGA starves behind a weak CPU feeder" knee), then
//! derive the cloud fleet plan from the *measured* saturation and
//! cross-check it against the `costmodel` catalogue rows of Table 2.
//!
//! Paper anchors reproduced here:
//! * a single weak feeder leaves the accelerator at a small fraction of
//!   its nominal rate; adding feeders climbs to the (XRT-contended)
//!   kernel ceiling and flattens — provisioning more FPGAs without CPUs
//!   buys nothing;
//! * sizing an f1.2xlarge fleet for the freed 244-server Domain Explorer
//!   needs ≈6 instances per replaced server — CPU-bound, not
//!   FPGA-bound — which is the 3× (AWS) / 2.5× (Azure) cost blow-up.

use erbium_search::benchkit::{fmt_qps, print_table};
use erbium_search::cluster::sim::measure_node_saturation_qps;
use erbium_search::cluster::ClusterSimConfig;
use erbium_search::costmodel::{
    catalog, fleet_cost_usd, fleet_mct_demand_qps, freed_server_count, plan_fleet,
    FleetBottleneck, DEFAULT_UQ_PER_S, DE_SERVERS, DE_VCPUS, HOURS_PER_YEAR,
};

fn main() {
    let nominal = ClusterSimConfig::v2_cloud(1, 1).kernel_model().saturation_qps();
    let batch = 16_384;

    // ---- Feeder:FPGA sweep (one node, open-loop overload) --------------
    let mut rows = Vec::new();
    let mut measured_f1 = 0.0;
    for feeders in [1usize, 2, 3, 4, 6, 8, 12, 16] {
        let qps = measure_node_saturation_qps(feeders, batch, 400);
        if feeders == 8 {
            measured_f1 = qps; // f1.2xlarge-shaped node: 8 vCPUs of feeder
        }
        let dollars_per_mqps_year =
            catalog::AWS_F1_2XL.unit_cost * HOURS_PER_YEAR / (qps / 1e6);
        rows.push(vec![
            format!("{feeders}"),
            fmt_qps(qps),
            format!("{:.0} %", qps / nominal * 100.0),
            format!("{dollars_per_mqps_year:.0} $/Mqps·yr"),
        ]);
    }
    print_table(
        "§6.1 — achieved node throughput vs feeder count (open-loop overload, f1-priced)",
        &["feeders", "achieved", "of kernel nominal", "cost efficiency"],
        &rows,
    );
    println!("\nknee: 1 feeder starves the kernel; the ceiling flattens once the");
    println!("feeders outrun the (XRT-contended) kernel — extra CPUs stop paying.");

    // ---- Fleet plan from the measured saturation -----------------------
    let reduced = freed_server_count(DE_SERVERS);
    let target = fleet_mct_demand_qps(DEFAULT_UQ_PER_S);
    let mut plan_rows = Vec::new();
    for elem in [catalog::AWS_F1_2XL, catalog::AZURE_NP10S] {
        let plan = plan_fleet(elem, target, measured_f1, reduced * DE_VCPUS);
        assert_eq!(
            plan.bottleneck,
            FleetBottleneck::CpuCapacity,
            "the cloud imbalance must be CPU-bound"
        );
        plan_rows.push(vec![
            elem.name.to_string(),
            plan.units.to_string(),
            plan.units_for_throughput.to_string(),
            plan.units_for_cpu.to_string(),
            format!("{:.1}×", plan.multiplier_vs(reduced)),
            format!("{:.1} M/year", plan.total_usd / 1e6),
        ]);
    }
    print_table(
        "fleet plans from measured node saturation (target = §5.2 demand at 10 k uq/s)",
        &["instance", "units", "for qps", "for vCPUs", "per replaced server", "cost"],
        &plan_rows,
    );

    // ---- Cross-check against the catalogue (Table 2) -------------------
    let aws_plan = plan_fleet(catalog::AWS_F1_2XL, target, measured_f1, reduced * DE_VCPUS);
    let cpu_only = fleet_cost_usd(catalog::AWS_C5_12XL, DE_SERVERS);
    let ratio = aws_plan.total_usd / cpu_only;
    println!(
        "\ncross-check vs costmodel::catalog: {} × f1.2xlarge = {:.1} M/year vs \
         CPU-only {:.1} M/year → {ratio:.2}× (paper: ~3×)",
        aws_plan.units,
        aws_plan.total_usd / 1e6,
        cpu_only / 1e6,
    );
    assert_eq!(aws_plan.units, 1464, "must reproduce the Table 2 unit count");
    assert!((2.8..3.4).contains(&ratio), "must reproduce the §6.1 blow-up");
    println!(
        "accelerator overprovision: {:.0}× more FPGA instances than MCT throughput needs",
        aws_plan.accelerator_overprovision()
    );
}
