//! Fig 12 — Execution time comparison of CPU and FPGA processing MCT
//! queries over a production-trace replica, per user query, as a function
//! of the number of checked MCT queries; plus the number of FPGA calls
//! needed to complete each request.
//!
//! Both sides run behind the same [`MatchBackend`] surface. CPU side: the
//! optimised §5.2 baseline, *really executed* and wall-clock timed (its
//! modeled service time is reported alongside). FPGA side: answers really
//! computed by the native functional simulator, time from the
//! hardware-model clock (kernel + shell) plus the calibrated software
//! overheads — exactly the quantities the paper's deployment measured.
//! Batch sizing follows the §5.2 required-TS policy.
//!
//! The tail section replays the same trace through the **full threaded
//! pipeline** with each backend — the paper's §5 comparison end-to-end
//! through one code path, not just per-call loops.

use std::time::Instant;

use erbium_search::backend::{
    cpu_backend_factory, native_backend_factory, CpuBackend, MatchBackend,
};
use erbium_search::benchkit::print_table;
use erbium_search::coordinator::{
    AggregationPolicy, MctStrategy, Pipeline, PipelineConfig, Topology,
};
use erbium_search::coordinator::domain_explorer::DomainExplorer;
use erbium_search::coordinator::overheads::Overheads;
use erbium_search::erbium::{Backend, ErbiumEngine, FpgaModel};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::workload::{generate_trace, TraceConfig};

fn main() {
    // Scaled production snapshot: same §5.2 marginals, fewer user queries
    // (full scale is 6 301 uq / 4.8 M MCT queries; scale via FIG12_UQ).
    let n_uq: usize = std::env::var("FIG12_UQ").ok().and_then(|s| s.parse().ok()).unwrap_or(400);
    let gen_cfg = GeneratorConfig { n_rules: 20_000, ..GeneratorConfig::default() };
    let world = generate_world(&gen_cfg);
    let schema = Schema::for_version(StandardVersion::V2);
    let rs = generate_rule_set(&gen_cfg, &world, StandardVersion::V2);
    let trace = generate_trace(
        &TraceConfig { n_user_queries: n_uq, ..TraceConfig::default() },
        &world,
    );
    let stats = trace.stats();
    println!(
        "trace: {} uq, {} TS, {} MCT queries, {:.1} % direct, {:.2} MCT q/TS (paper: 6301 / 5.8M / 4.8M / 17 % / 1.24)",
        stats.user_queries,
        stats.travel_solutions,
        stats.mct_queries,
        stats.direct_fraction() * 100.0,
        stats.mean_mct_per_nondirect_ts()
    );

    // Both flows behind the one backend surface.
    let cpu = CpuBackend::new(schema.clone(), &rs);
    let (nfa, cstats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
    let model = FpgaModel::new(HardwareConfig::v2_aws(4), cstats.depth);
    let engine: Box<dyn MatchBackend> = Box::new(
        ErbiumEngine::new(nfa.clone(), model, Backend::Native, 28, 64).expect("engine"),
    );
    let o = Overheads::default();

    // Per-user-query measurements.
    struct Point {
        mct: usize,
        cpu_ms: f64,
        cpu_model_ms: f64,
        fpga_ms: f64,
        calls: usize,
    }
    let mut points = Vec::with_capacity(trace.queries.len());
    let de_cpu = DomainExplorer::new(MctStrategy::CpuPerTs);
    let de_fpga = DomainExplorer::new(MctStrategy::FpgaBatched);
    for uq in &trace.queries {
        // CPU flow: real wall-clock, modeled service time alongside.
        let mut cpu_model_us = 0.0;
        let t0 = Instant::now();
        let oc = de_cpu.process(uq, |qs| {
            let (ds, t) = cpu.evaluate_batch_timed(qs).expect("cpu backend");
            cpu_model_us += t.total_us;
            ds
        });
        let cpu_ms = t0.elapsed().as_secs_f64() * 1e3;
        // FPGA flow: answers real, time = hw model + software overheads.
        let mut fpga_us = 0.0;
        let of = de_fpga.process(uq, |qs| {
            let (ds, t) = engine.evaluate_batch_timed(qs).expect("engine");
            fpga_us += o.zmq.request_us(qs.len())
                + o.encode.us(qs.len())
                + o.xrt.submission_us(1)
                + t.total_us
                + o.sched.us(qs.len())
                + o.zmq.reply_us(qs.len());
            ds
        });
        // §5.1 trade-off, observable here: the CPU flow stops exactly at the
        // required-TS count, while the batched FPGA flow evaluates whole
        // batches and may overshoot — "minimise the number of TS's to be
        // evaluated ... but maximise the number of MCT queries packed".
        if oc.valid_ts < uq.required_ts && of.valid_ts < uq.required_ts {
            assert_eq!(oc.valid_ts, of.valid_ts, "flows must agree when the cap is not hit");
        } else {
            assert!(oc.valid_ts >= uq.required_ts.min(oc.examined_ts));
            assert!(of.valid_ts >= oc.valid_ts, "batched flow can only overshoot");
        }
        points.push(Point {
            mct: of.checked_mct_queries,
            cpu_ms,
            cpu_model_ms: cpu_model_us / 1e3,
            fpga_ms: fpga_us / 1e3,
            calls: of.engine_calls,
        });
    }

    // Bin by checked-MCT-query count (log bins, as the paper's x-axis).
    let bins = [
        (1usize, 50usize),
        (50, 100),
        (100, 200),
        (200, 400),
        (400, 800),
        (800, 1600),
        (1600, 3200),
        (3200, 10_000),
    ];
    let mut rows = Vec::new();
    for (lo, hi) in bins {
        let sel: Vec<&Point> = points.iter().filter(|p| p.mct >= lo && p.mct < hi).collect();
        if sel.is_empty() {
            continue;
        }
        let med = |f: &dyn Fn(&Point) -> f64| {
            let mut v: Vec<f64> = sel.iter().map(|p| f(p)).collect();
            v.sort_by(|a, b| a.partial_cmp(b).unwrap());
            v[v.len() / 2]
        };
        let cpu_ms = med(&|p| p.cpu_ms);
        let fpga_ms = med(&|p| p.fpga_ms);
        rows.push(vec![
            format!("[{lo}, {hi})"),
            sel.len().to_string(),
            format!("{cpu_ms:.3}"),
            format!("{:.3}", med(&|p| p.cpu_model_ms)),
            format!("{fpga_ms:.3}"),
            format!("{:.0}", med(&|p| p.calls as f64)),
            if cpu_ms < fpga_ms { "CPU".into() } else { "FPGA".into() },
        ]);
    }
    print_table(
        "Fig 12 — CPU vs FPGA execution time per user query",
        &[
            "#MCT queries",
            "uq count",
            "CPU ms (median)",
            "CPU model ms",
            "FPGA ms (median)",
            "FPGA calls",
            "winner",
        ],
        &rows,
    );

    // Crossover estimate.
    let mut crossover = None;
    for (lo, hi) in bins {
        let sel: Vec<&Point> = points.iter().filter(|p| p.mct >= lo && p.mct < hi).collect();
        if sel.len() < 3 {
            continue;
        }
        let cpu: f64 = sel.iter().map(|p| p.cpu_ms).sum::<f64>() / sel.len() as f64;
        let fpga: f64 = sel.iter().map(|p| p.fpga_ms).sum::<f64>() / sel.len() as f64;
        if fpga < cpu {
            crossover = Some(lo);
            break;
        }
    }
    match crossover {
        Some(c) => println!("\ncrossover: FPGA wins from ≈{c} MCT queries per user query (paper: ≈400)"),
        None => println!("\nno crossover observed in this trace (paper: ≈400)"),
    }
    let s = cpu.baseline().cache_stats();
    println!("CPU baseline airport-cache: {} hits / {} misses", s.hits, s.misses);

    // ---- End-to-end: both flows through the full threaded pipeline ------
    let topo = Topology::new(8, 2, 1, 4);
    let pipe_uq = n_uq.min(64); // the threaded replay is heavier per uq
    let pipe_trace = generate_trace(
        &TraceConfig { n_user_queries: pipe_uq, ..TraceConfig::default() },
        &world,
    );
    let mut rows = Vec::new();
    let runs: Vec<(&str, erbium_search::backend::BackendFactory, MctStrategy)> = vec![
        (
            "CPU baseline",
            cpu_backend_factory(schema.clone(), rs.clone()),
            MctStrategy::CpuPerTs,
        ),
        (
            "FPGA (native)",
            native_backend_factory(nfa.clone(), model, 28, 64),
            MctStrategy::FpgaBatched,
        ),
    ];
    for (name, factory, strategy) in runs {
        let cfg = PipelineConfig::new(topo)
            .with_strategy(strategy)
            .with_aggregation(AggregationPolicy::DrainQueue);
        let r = Pipeline::new(cfg, factory).run(&pipe_trace).expect("pipeline run");
        rows.push(vec![
            name.to_string(),
            r.backend.clone(),
            format!("{:.2}", r.modeled_kernel_us / 1e3),
            format!("{:.1}", r.uq_latency_p90_ms),
            format!("{:.2}", r.mean_aggregation),
            r.valid_travel_solutions.to_string(),
        ]);
    }
    print_table(
        "§5 end-to-end — same trace, same pipeline, backend swapped",
        &["flow", "backend", "model time ms", "uq p90 ms (wall)", "agg", "valid TS"],
        &rows,
    );
    println!("\nvalid-TS: the per-TS CPU flow stops exactly at the required count, the");
    println!("batched FPGA flow may overshoot (§5.1) — equal-or-higher is the invariant.");
    println!("model time compares the machines the stand-ins represent (DESIGN.md §Dual-clock).");
}
