//! Telemetry bench: the flight recorder's overhead gate and the trace
//! artifact.
//!
//! Three measurements:
//!
//! 1. **Overhead gate (DES)** — the same front-door simulation run with
//!    the zero-cost `NullRecorder` path and with a full `RingRecorder`,
//!    best-of-N wall clock each. Acceptance: traced throughput ≥
//!    [`MIN_THROUGHPUT_RATIO`] of untraced (≤ ~5 % overhead), and the
//!    traced run's *results* are bit-identical to the untraced run's —
//!    recording is a side effect, never a perturbation.
//! 2. **Ring micro-bench** — raw `RingRecorder::record` events/s, full
//!    and 1-in-64 sampled (the sampled path pays the hash but skips the
//!    ring).
//! 3. **Reconciliation** — the traced run's lane counts equal the
//!    report's exactly (the flight recorder is an audit, not an
//!    estimate).
//!
//! Emits `BENCH_telemetry.json` (override with `BENCH_OUT`) plus a
//! Perfetto-loadable `BENCH_telemetry.trace.json` (override with
//! `TRACE_OUT`), both uploaded by the CI bench-smoke step. `BENCH_SMOKE=1`
//! shrinks the workload for CI.

use std::time::Instant;

use erbium_search::benchkit::{print_table, write_json, Json};
use erbium_search::cluster::{AdmissionPolicy, ClusterSimConfig, RoutePolicy};
use erbium_search::controlplane::FaultPlan;
use erbium_search::frontdoor::{
    sim_frontdoor, BackpressurePolicy, FrontdoorConfig, FrontdoorReport, FrontdoorSimConfig,
};
use erbium_search::telemetry::{
    write_chrome_trace, Recorder, RingRecorder, StageEvent, TraceSpec,
};
use erbium_search::workload::{session_plans, RateSchedule, SessionPlan};

const BATCH: usize = 16;
const NODES: usize = 3;
/// Acceptance: traced DES throughput as a fraction of untraced.
const MIN_THROUGHPUT_RATIO: f64 = 0.95;

fn plans(sessions: usize, batches: usize) -> Vec<SessionPlan> {
    // Moderate load on the modelled fleet; the absolute rate only scales
    // virtual time, the wall-clock cost is per *event*.
    session_plans(0x7E1E, &RateSchedule::constant(4_000.0), sessions, batches, BATCH, 0.0, 8)
}

fn cfg(trace: Option<TraceSpec>) -> FrontdoorSimConfig {
    let mut fd = FrontdoorConfig::event(2, BackpressurePolicy::Window { window: 4 });
    if let Some(spec) = trace {
        fd = fd.with_trace(spec);
    }
    FrontdoorSimConfig {
        cluster: ClusterSimConfig::v2_cloud(NODES, 2)
            .with_route(RoutePolicy::RoundRobin)
            .with_admission(AdmissionPolicy::QueueCap(24)),
        frontdoor: fd,
        faults: FaultPlan::none(),
    }
}

/// Best-of-N wall clock of one DES run (min is the standard noise floor
/// estimator for a deterministic workload).
fn best_of(repeats: usize, cfg: &FrontdoorSimConfig, p: &[SessionPlan]) -> (f64, FrontdoorReport) {
    let mut best = f64::INFINITY;
    let mut report = None;
    for _ in 0..repeats {
        let t0 = Instant::now();
        let r = sim_frontdoor(cfg, p);
        best = best.min(t0.elapsed().as_secs_f64());
        report = Some(r);
    }
    (best, report.expect("at least one repeat"))
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (sessions, batches, repeats, micro_n) =
        if smoke { (48, 8, 5, 1_000_000u64) } else { (96, 16, 7, 4_000_000u64) };
    let p = plans(sessions, batches);

    // ---- 1. Overhead gate: NullRecorder vs RingRecorder -----------------
    let (t_null, r_null) = best_of(repeats, &cfg(None), &p);
    let (t_ring, r_ring) = best_of(repeats, &cfg(Some(TraceSpec::full())), &p);
    let ratio = t_null / t_ring.max(1e-12);
    println!(
        "DES {} requests: untraced {:.2} ms, traced {:.2} ms → traced throughput {:.1}% \
         ({} events recorded)",
        sessions * batches,
        t_null * 1e3,
        t_ring * 1e3,
        ratio * 100.0,
        r_ring.trace.len(),
    );
    assert!(
        ratio >= MIN_THROUGHPUT_RATIO,
        "acceptance: tracing must keep ≥{:.0}% of untraced throughput, got {:.1}%",
        MIN_THROUGHPUT_RATIO * 100.0,
        ratio * 100.0
    );
    // Recording is side-effect-only: identical results bit for bit.
    assert_eq!(r_null.completed_queries, r_ring.completed_queries);
    assert_eq!(r_null.lost_queries, r_ring.lost_queries);
    assert_eq!(r_null.accept_p99_us.to_bits(), r_ring.accept_p99_us.to_bits());
    assert!(!r_ring.trace.is_empty(), "traced run must actually record");

    // ---- 2. Ring micro-bench: events/s, full and sampled ----------------
    let micro = |spec: TraceSpec| {
        let mut rec = RingRecorder::new(spec);
        let t0 = Instant::now();
        for i in 0..micro_n {
            rec.record(i as f64, i, StageEvent::Admitted);
        }
        let dt = t0.elapsed().as_secs_f64();
        (micro_n as f64 / dt.max(1e-12), rec.into_trace())
    };
    let (full_eps, _) = micro(TraceSpec::full());
    let (sampled_eps, sampled_trace) = micro(TraceSpec::sampled(64));
    println!(
        "RingRecorder: {:.0} M events/s full, {:.0} M events/s 1-in-64 sampled \
         ({} kept of {micro_n})",
        full_eps / 1e6,
        sampled_eps / 1e6,
        sampled_trace.len() + sampled_trace.dropped as usize,
    );

    // ---- 3. Reconciliation: the trace is an audit of the report ---------
    assert!(r_ring.conserves_queries(), "{}", r_ring.summary());
    assert!(r_ring.trace.is_complete());
    let lanes = r_ring.trace.lane_counts();
    assert_eq!(lanes.completed_queries, r_ring.completed_queries);
    assert_eq!(lanes.shed_socket_queries, r_ring.shed_socket_queries);
    assert_eq!(lanes.shed_queue_queries, r_ring.shed_queue_queries);
    assert_eq!(lanes.shed_deadline_queries, r_ring.shed_deadline_queries);
    assert_eq!(lanes.lost_queries, r_ring.lost_queries);
    assert_eq!(lanes.terminal_queries(), r_ring.offered_queries);

    print_table(
        "flight-recorder overhead",
        &["run", "best ms", "throughput vs untraced"],
        &[
            vec!["untraced (NullRecorder)".into(), format!("{:.2}", t_null * 1e3), "—".into()],
            vec![
                "traced (RingRecorder)".into(),
                format!("{:.2}", t_ring * 1e3),
                format!("{:.1}%", ratio * 100.0),
            ],
        ],
    );

    // ---- Artifacts ------------------------------------------------------
    let trace_path = std::env::var("TRACE_OUT")
        .unwrap_or_else(|_| "BENCH_telemetry.trace.json".to_string());
    write_chrome_trace(&trace_path, &r_ring.trace).expect("write chrome trace");

    let json = Json::obj([
        ("bench", Json::Str("telemetry".into())),
        ("smoke", Json::Bool(smoke)),
        ("requests", Json::Int((sessions * batches) as i64)),
        ("repeats", Json::Int(repeats as i64)),
        ("untraced_best_s", Json::Num(t_null)),
        ("traced_best_s", Json::Num(t_ring)),
        ("throughput_ratio", Json::Num(ratio)),
        ("min_throughput_ratio", Json::Num(MIN_THROUGHPUT_RATIO)),
        ("trace_events", Json::Int(r_ring.trace.len() as i64)),
        ("ring_full_events_per_s", Json::Num(full_eps)),
        ("ring_sampled64_events_per_s", Json::Num(sampled_eps)),
        ("trace_artifact", Json::Str(trace_path)),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_telemetry.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");
}
