//! Table 3 — Cost estimates including the Route Scoring module (Fig 14
//! layout): the CPU-only fleets grow by 80 servers while the FPGA fleets
//! absorb Route Scoring on the same boards, improving the FPGA's relative
//! cost-effectiveness on-premises but not nearly enough in the cloud.

use erbium_search::benchkit::print_table;
use erbium_search::costmodel::table3;
use erbium_search::routescoring::RsHwModel;

fn main() {
    let rows: Vec<Vec<String>> = table3()
        .iter()
        .map(|r| {
            vec![
                r.deployment.clone(),
                r.element.name.to_string(),
                r.units.to_string(),
                format!("{}", r.element.unit_cost),
                r.total_label(),
            ]
        })
        .collect();
    print_table(
        "Table 3 — Domain Explorer + ERBIUM + Route Scoring deployment costs",
        &["deployment", "element", "units", "unit cost (USD|USD/h)", "total"],
        &rows,
    );
    // Feasibility of co-locating Route Scoring with MCT (Fig 14): board
    // occupancy of the scoring kernel at Domain-Explorer route volumes.
    let rs = RsHwModel::default();
    println!(
        "\nRoute-Scoring co-location: 50k routes/user-query at 1k uq/s ⇒ {:.1} % board occupancy, \
         {:.0} µs per user query",
        rs.occupancy(50_000, 1_000.0) * 100.0,
        rs.time_to_score_us(50_000)
    );
    println!("paper anchors: on-prem U50 clearly ahead (3.17 M vs 4.8 M); cloud still 2.1–2.6× more expensive.");
}
