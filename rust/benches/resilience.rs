//! Chaos harness: the gray-failure resilience ladder under seeded fault
//! injection, with the PR's two headline assertions enforced on the DES
//! and a wall-clock conservation smoke on the real reactor.
//!
//! 1. **Straggler + hedge (DES)** — 8 JSQ-routed nodes, one slowed 10×
//!    mid-run. JSQ starves the straggler down to a trickle (ties break to
//!    it, so it never fully drains out), which is exactly the gray regime:
//!    a few percent of requests land there and eat 10–30× latency. The
//!    storm runs light and with a wide session window — a hedge can only
//!    cut the *backend* component of accept latency, so queueing delay
//!    and batches parked behind their own session's predecessors put a
//!    floor under the hedged p99 that no trigger tuning removes.
//!    Acceptance: a tail-triggered hedge cuts accept-clock p99 **≥ 2×**
//!    at **≤ 1.05×** physical backend load.
//! 2. **Error replica + breaker (DES)** — 4 round-robin nodes, one
//!    failing 20% of calls. Acceptance: retry + circuit breaker keeps
//!    goodput (completed queries over the same offered set) within **10%
//!    of the fault-free run**, and strictly above the no-policy run.
//! 3. **Real-reactor chaos smoke** — slowdown + error-rate gray windows
//!    against live threads under the full mechanism stack: the extended
//!    conservation law holds on the wall clock and no completion is
//!    recorded past its deadline.
//!
//! Emits machine-readable `BENCH_resilience.json` (override with
//! `BENCH_OUT`), uploaded by the CI bench-smoke step. `BENCH_SMOKE=1`
//! shrinks the storms for CI.

use erbium_search::backend::BackendFactory;
use erbium_search::benchkit::{print_table, write_json, Json};
use erbium_search::cluster::{
    AdmissionPolicy, Cluster, ClusterConfig, ClusterSimConfig, RoutePolicy, SimNodeSpec,
};
use erbium_search::controlplane::FaultPlan;
use erbium_search::coordinator::{AggregationPolicy, PipelineConfig, Topology};
use erbium_search::frontdoor::{
    run_frontdoor, sim_frontdoor, BackpressurePolicy, FrontdoorConfig, FrontdoorReport,
    FrontdoorSimConfig,
};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::resilience::{BreakerConfig, HedgePolicy, ResiliencePolicy, RetryPolicy};
use erbium_search::rules::standard::StandardVersion;
use erbium_search::testing::fixture::compile_fixture;
use erbium_search::workload::{session_plans, PoissonSource, RateSchedule, SessionPlan};

const BATCH: usize = 16;
/// Per-session backpressure window. Wide enough that a session's batches
/// rarely park behind their own slow predecessors — park time is accept
/// latency hedging cannot cut.
const WINDOW: usize = 4;
/// Offered load as a fraction of the *healthy* fleet's capacity — well
/// under the knee, so the tail is the fault's signature, not queueing's:
/// baseline waits inflate the winner-latency EWMA the hedge trigger is
/// armed from, pushing the effective trigger far past its nominal factor.
const LOAD: f64 = 0.4;

fn node_cfg() -> PipelineConfig {
    PipelineConfig::new(Topology::new(2, 1, 1, 4))
        .with_aggregation(AggregationPolicy::DrainQueue)
}

fn storm(seed: u64, mu_rps: f64, nodes: usize, sessions: usize, batches: usize) -> Vec<SessionPlan> {
    let rate = LOAD * nodes as f64 * mu_rps / batches as f64;
    session_plans(seed, &RateSchedule::constant(rate), sessions, batches, BATCH, 0.0, 8)
}

fn report_json(r: &FrontdoorReport) -> Json {
    Json::obj([
        ("resilience", Json::Str(r.resilience.clone())),
        ("offered_queries", Json::Int(r.offered_queries as i64)),
        ("completed_queries", Json::Int(r.completed_queries as i64)),
        ("shed_queue_queries", Json::Int(r.shed_queue_queries as i64)),
        ("shed_deadline_queries", Json::Int(r.shed_deadline_queries as i64)),
        ("lost_queries", Json::Int(r.lost_queries as i64)),
        ("goodput_qps", Json::Num(r.goodput_qps)),
        ("accept_p50_us", Json::Num(r.accept_p50_us)),
        ("accept_p99_us", Json::Num(r.accept_p99_us)),
        ("backend_load_factor", Json::Num(r.backend_load_factor())),
        ("retries", Json::Int(r.res.retries as i64)),
        ("hedges_issued", Json::Int(r.res.hedges_issued as i64)),
        ("hedge_wins", Json::Int(r.res.hedge_wins as i64)),
        ("breaker_trips", Json::Int(r.res.breaker_trips as i64)),
        ("breaker_rejections", Json::Int(r.res.breaker_rejections as i64)),
        ("degraded_requests", Json::Int(r.res.degraded_requests as i64)),
        ("backend_requests", Json::Int(r.res.backend_requests as i64)),
    ])
}

fn sim_run(
    cluster: &ClusterSimConfig,
    faults: &FaultPlan,
    res: ResiliencePolicy,
    plans: &[SessionPlan],
) -> FrontdoorReport {
    sim_frontdoor(
        &FrontdoorSimConfig {
            cluster: cluster.clone(),
            frontdoor: FrontdoorConfig::event(2, BackpressurePolicy::Window { window: WINDOW })
                .with_resilience(res),
            faults: faults.clone(),
        },
        plans,
    )
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (sessions, batches) = if smoke { (32, 6) } else { (64, 8) };

    // ---- 1. Straggler + hedge (DES) -------------------------------------
    let n_straggle = 8;
    let straggle_cluster = ClusterSimConfig::v2_cloud(n_straggle, 2)
        .with_route(RoutePolicy::JoinShortestQueue)
        .with_admission(AdmissionPolicy::QueueCap(16));
    let spec = SimNodeSpec::v2_cloud(2);
    let svc = spec.request_service_us(&straggle_cluster.overheads, BATCH);
    let mu_sim_rps = spec.capacity_qps(&straggle_cluster.overheads, BATCH) / BATCH as f64;
    // The slowdown opens after a clean warm-up so the hedge expectation
    // (winner-latency EWMA) is trained on healthy traffic.
    let straggler = FaultPlan::none().and_slowdown(0, 20.0 * svc, 1e12, 10.0);
    let plans = storm(0x6E51, mu_sim_rps, n_straggle, sessions, batches);
    let plain = sim_run(&straggle_cluster, &straggler, ResiliencePolicy::none(), &plans);
    let hedged = sim_run(
        &straggle_cluster,
        &straggler,
        ResiliencePolicy::none().with_hedge(HedgePolicy::new(3.0)),
        &plans,
    );
    assert!(plain.conserves_queries() && hedged.conserves_queries());
    assert_eq!(hedged.completed_queries, hedged.offered_queries, "hedges lose nothing");
    assert!(hedged.res.hedges_issued > 0 && hedged.res.hedge_wins > 0, "{}", hedged.summary());
    assert!(
        plain.accept_p99_us >= 2.0 * hedged.accept_p99_us,
        "acceptance: hedging must cut accept-p99 ≥2× under a 10× straggler: \
         plain {:.0} vs hedged {:.0} µs",
        plain.accept_p99_us,
        hedged.accept_p99_us
    );
    assert!(
        hedged.backend_load_factor() <= 1.05,
        "acceptance: at ≤1.05× physical backend load: {:.3}",
        hedged.backend_load_factor()
    );
    print_table(
        "10× straggler, 8 nodes JSQ (DES)",
        &["policy", "p99 µs", "load ×", "hedges", "wins"],
        &[&plain, &hedged]
            .iter()
            .map(|r| {
                vec![
                    r.resilience.clone(),
                    format!("{:.0}", r.accept_p99_us),
                    format!("{:.3}", r.backend_load_factor()),
                    format!("{}", r.res.hedges_issued),
                    format!("{}", r.res.hedge_wins),
                ]
            })
            .collect::<Vec<_>>(),
    );

    // ---- 2. Error replica + breaker (DES) --------------------------------
    let n_err = 4;
    let err_cluster = ClusterSimConfig::v2_cloud(n_err, 2)
        .with_route(RoutePolicy::RoundRobin)
        .with_admission(AdmissionPolicy::QueueCap(16));
    let flaky = FaultPlan::none().and_error_rate(0, 20.0 * svc, 1e12, 0.2);
    let stack = ResiliencePolicy::none()
        .with_retry(RetryPolicy::new(3, 0.5 * svc, 8.0 * svc))
        .with_budget_ratio(0.5)
        .with_breaker(BreakerConfig { open_us: 40.0 * svc, ..Default::default() });
    let plans = storm(0x6E52, mu_sim_rps, n_err, sessions, batches);
    let clean = sim_run(&err_cluster, &FaultPlan::none(), ResiliencePolicy::none(), &plans);
    let unguarded = sim_run(&err_cluster, &flaky, ResiliencePolicy::none(), &plans);
    let guarded = sim_run(&err_cluster, &flaky, stack, &plans);
    for r in [&clean, &unguarded, &guarded] {
        assert!(r.conserves_queries(), "{}", r.summary());
    }
    assert!(unguarded.lost_queries > 0, "the fault must bite: {}", unguarded.summary());
    assert!(guarded.res.breaker_trips > 0, "{}", guarded.summary());
    assert!(
        guarded.completed_queries * 10 >= clean.completed_queries * 9,
        "acceptance: breakers keep goodput within 10% of fault-free: {} vs {}",
        guarded.completed_queries,
        clean.completed_queries
    );
    assert!(
        guarded.completed_queries > unguarded.completed_queries,
        "the stack must beat no policy: {} vs {}",
        guarded.completed_queries,
        unguarded.completed_queries
    );
    print_table(
        "20% error replica, 4 nodes RR (DES)",
        &["policy", "faults", "completed", "lost", "trips", "retries"],
        &[
            (&clean, "none"),
            (&unguarded, "err:0.2"),
            (&guarded, "err:0.2"),
        ]
        .iter()
        .map(|(r, f)| {
            vec![
                r.resilience.clone(),
                (*f).to_string(),
                format!("{}", r.completed_queries),
                format!("{}", r.lost_queries),
                format!("{}", r.res.breaker_trips),
                format!("{}", r.res.retries),
            ]
        })
        .collect::<Vec<_>>(),
    );

    // ---- 3. Real-reactor chaos smoke -------------------------------------
    let f = compile_fixture(4117, 300, StandardVersion::V2, HardwareConfig::v2_aws(4));
    let factory: BackendFactory = f.native_factory();
    let world = f.world;
    let probe_cfg = ClusterConfig::new(1, node_cfg()).with_admission(AdmissionPolicy::Open);
    let probe = Cluster::new(probe_cfg, factory.clone());
    let mu_real_rps = (0..2u64)
        .map(|i| {
            let mut src = PoissonSource::new(&world, 0xD05 ^ (1 + i), 1e8, BATCH, 240);
            probe.run(&mut src).expect("probe run").achieved_qps / BATCH as f64
        })
        .fold(0.0, f64::max);
    let (real_sessions, real_batches) = if smoke { (8, 4) } else { (16, 6) };
    let real_cluster = ClusterConfig::new(3, node_cfg())
        .with_route(RoutePolicy::RoundRobin)
        .with_admission(AdmissionPolicy::QueueCap(16));
    let chaos = FaultPlan::none()
        .and_slowdown(0, 10_000.0, 1e9, 6.0)
        .and_error_rate(1, 10_000.0, 1e9, 0.3);
    let deadline = 150_000.0;
    let full = ResiliencePolicy::none()
        .with_deadline(deadline)
        .with_retry(RetryPolicy::new(3, 1_000.0, 8_000.0))
        .with_budget_ratio(0.5)
        .with_hedge(HedgePolicy::new(3.0))
        .with_breaker(BreakerConfig { open_us: 80_000.0, ..Default::default() });
    let real_plans = storm(0x6E53, mu_real_rps, 3, real_sessions, real_batches);
    let fd = FrontdoorConfig::event(2, BackpressurePolicy::Window { window: WINDOW })
        .with_resilience(full);
    let real = run_frontdoor(
        real_cluster,
        factory,
        &world,
        0x6E53,
        &real_plans,
        &fd,
        &chaos,
    )
    .expect("real chaos run");
    println!("\nreal chaos: {}", real.summary());
    assert!(real.conserves_queries(), "{}", real.summary());
    assert_eq!(real.res.gray_fault_windows, 2);
    assert!(real.res.backend_requests >= real.completed_requests, "{}", real.summary());
    assert!(
        // Slack: the expiry check and the latency record read the wall
        // clock a few µs apart.
        real.accept_p99_us <= deadline + 5_000.0,
        "no completion recorded past its deadline: p99 {:.0} vs {deadline}",
        real.accept_p99_us
    );

    // ---- Artifact -------------------------------------------------------
    let json = Json::obj([
        ("bench", Json::Str("resilience".into())),
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Int(BATCH as i64)),
        ("load_fraction", Json::Num(LOAD)),
        ("mu_sim_rps", Json::Num(mu_sim_rps)),
        ("mu_real_rps", Json::Num(mu_real_rps)),
        (
            "straggler_hedge",
            Json::obj([
                ("nodes", Json::Int(n_straggle as i64)),
                ("slow_factor", Json::Num(10.0)),
                ("plain", report_json(&plain)),
                ("hedged", report_json(&hedged)),
                ("p99_cut", Json::Num(plain.accept_p99_us / hedged.accept_p99_us.max(1.0))),
            ]),
        ),
        (
            "error_breaker",
            Json::obj([
                ("nodes", Json::Int(n_err as i64)),
                ("error_p", Json::Num(0.2)),
                ("clean", report_json(&clean)),
                ("unguarded", report_json(&unguarded)),
                ("guarded", report_json(&guarded)),
            ]),
        ),
        ("real_chaos", report_json(&real)),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_resilience.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");
}
