//! §Perf — hot-path microbenchmarks feeding EXPERIMENTS.md §Perf:
//!
//! * L3: native NFA evaluation rate (the bulk-sweep engine), the real
//!   encoder, and the CPU baseline;
//! * L1/L2 via PJRT: XLA artifact execution per batch (requires
//!   `artifacts/`; skipped otherwise).

use erbium_search::backend::{CpuBackend, MatchBackend};
use erbium_search::benchkit::{fmt_qps, measure, print_table};
use erbium_search::encoder::QueryEncoder;
use erbium_search::erbium::{Backend, ErbiumEngine, FpgaModel};
use erbium_search::cpu_baseline::CpuBaseline;
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::nfa::memory::NfaImage;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::prng::Rng;
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::runtime::Runtime;
use erbium_search::workload::random_query;

fn main() {
    let gen_cfg = GeneratorConfig { n_rules: 20_000, ..GeneratorConfig::default() };
    let world = generate_world(&gen_cfg);
    let schema = Schema::for_version(StandardVersion::V2);
    let rs = generate_rule_set(&gen_cfg, &world, StandardVersion::V2);
    let (nfa, cstats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
    let model = FpgaModel::new(HardwareConfig::v2_aws(4), cstats.depth);
    let engine =
        ErbiumEngine::new(nfa.clone(), model, Backend::Native, 28, 64).expect("engine");
    let cpu = CpuBaseline::new(schema.clone(), &rs);
    let enc = QueryEncoder::new(&nfa.plan, 28);

    let mut rng = Rng::new(0xBEEF);
    let queries: Vec<_> = (0..8192)
        .map(|_| {
            let st = rng.index(gen_cfg.n_airports) as u32;
            random_query(&mut rng, &world, st)
        })
        .collect();

    let mut rows = Vec::new();

    // Encoder.
    let mut buf = Vec::new();
    let st = measure(200.0, || {
        enc.encode_batch(&queries, 8192, &mut buf);
        std::hint::black_box(&buf);
    });
    rows.push(vec![
        "L3 encoder (encode_batch)".into(),
        format!("{:.1} ns/query", st.p50_ns / 8192.0),
        fmt_qps(8192.0 / (st.p50_ns * 1e-9)),
    ]);

    // Native NFA evaluation (bulk sweep engine).
    let st = measure(400.0, || {
        std::hint::black_box(engine.evaluate_batch(&queries).unwrap());
    });
    rows.push(vec![
        "native NFA evaluate_batch (8k)".into(),
        format!("{:.0} ns/query", st.p50_ns / 8192.0),
        fmt_qps(8192.0 / (st.p50_ns * 1e-9)),
    ]);

    // CPU baseline.
    let st = measure(400.0, || {
        std::hint::black_box(cpu.evaluate_batch(&queries));
    });
    rows.push(vec![
        "CPU baseline evaluate_batch (8k)".into(),
        format!("{:.0} ns/query", st.p50_ns / 8192.0),
        fmt_qps(8192.0 / (st.p50_ns * 1e-9)),
    ]);

    // The MatchBackend surface the pipeline actually calls through: same
    // work as above plus dynamic dispatch and the service-time model —
    // the cost of the abstraction must stay in the noise.
    let backends: Vec<(&str, Box<dyn MatchBackend>)> = vec![
        (
            "dyn MatchBackend / fpga-native (8k)",
            Box::new(
                ErbiumEngine::new(nfa.clone(), model, Backend::Native, 28, 64)
                    .expect("engine"),
            ),
        ),
        (
            "dyn MatchBackend / cpu (8k)",
            Box::new(CpuBackend::new(schema.clone(), &rs)),
        ),
    ];
    for (name, b) in &backends {
        let st = measure(400.0, || {
            std::hint::black_box(b.evaluate_batch_timed(&queries).unwrap());
        });
        rows.push(vec![
            (*name).into(),
            format!("{:.0} ns/query", st.p50_ns / 8192.0),
            fmt_qps(8192.0 / (st.p50_ns * 1e-9)),
        ]);
    }

    // XLA path, if artifacts exist.
    if Runtime::artifacts_available() {
        let rt = std::sync::Arc::new(Runtime::cpu(Runtime::default_dir()).unwrap());
        // Raw kernel invocation on one uploaded partition (B=1024).
        let exe = rt.load("nfa_b1024_s64_l28").unwrap();
        let pi = (0..nfa.partitions.len())
            .max_by_key(|&i| nfa.partitions[i].accepts.len())
            .unwrap();
        let img = NfaImage::from_compiled(&nfa.partitions[pi], 28, 64).unwrap();
        let dev = exe.upload(&img).unwrap();
        let station = nfa.partitions[pi].station.unwrap();
        let qs: Vec<_> = (0..1024).map(|_| random_query(&mut rng, &world, station)).collect();
        let mut ebuf = Vec::new();
        enc.encode_batch(&qs, 1024, &mut ebuf);
        let st = measure(1_500.0, || {
            std::hint::black_box(exe.execute(&ebuf, &dev).unwrap());
        });
        rows.push(vec![
            "XLA kernel execute (B=1024, 1 partition)".into(),
            format!("{:.2} ms/batch", st.p50_ns / 1e6),
            fmt_qps(1024.0 / (st.p50_ns * 1e-9)),
        ]);

        // Full engine path through partition routing.
        let xeng = ErbiumEngine::new(
            nfa.clone(),
            model,
            Backend::Xla { runtime: rt, batch_hint: 1024 },
            28,
            64,
        )
        .unwrap();
        let sample: Vec<_> = queries.iter().take(2048).copied().collect();
        let st = measure(2_000.0, || {
            std::hint::black_box(xeng.evaluate_batch(&sample).unwrap());
        });
        rows.push(vec![
            "XLA engine evaluate_batch (2k mixed)".into(),
            format!("{:.2} ms", st.p50_ns / 1e6),
            fmt_qps(2048.0 / (st.p50_ns * 1e-9)),
        ]);
    } else {
        println!("artifacts missing — XLA rows skipped (run `make artifacts`)");
    }

    print_table("§Perf — hot-path microbenchmarks", &["path", "unit cost", "rate"], &rows);
}
