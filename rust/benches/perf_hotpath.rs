//! §Perf — the match-hot-path throughput harness.
//!
//! Measures the CPU *feeder* (encoder + sparse NFA walk) five ways on the
//! Fig 12 replay workload — scalar (per-query, allocating), batch
//! (CSR arena + reused scratch), sharded (multi-core batch split),
//! lockstep (transposed 64-query-per-word walk) and sharded lockstep —
//! plus the CPU baseline and the `MatchBackend` dispatch surface, and
//! re-derives the §6.1 feeder-saturation point from the measured numbers:
//! how many feeder cores it takes to saturate the modeled FPGA node under
//! each feeder implementation. Lane-occupancy statistics (mean live lanes
//! per lockstep group, scalar-fallback share) are reported alongside, so a
//! station skew that defeats the bucketing is visible rather than silent.
//!
//! Emits machine-readable `BENCH_hotpath.json` (override the path with
//! `BENCH_OUT`) — the repo's perf-trajectory baseline, uploaded as a CI
//! artifact by the bench-smoke step; `schema_version` 2 adds the
//! `trajectory` section (per-feeder q/s + feeders-to-saturate knee).
//! `BENCH_SMOKE=1` shrinks the rule set and budgets for CI.
//!
//! The harness *asserts* the batch feeder is no slower than the scalar
//! one, and the lockstep feeder no slower than the batch one (both on
//! minimum iteration times): each step strictly removes per-query work —
//! allocations first, then per-query instruction counts — so a regression
//! here means the hot path picked up a real cost.

use erbium_search::backend::{CpuBackend, MatchBackend};
use erbium_search::benchkit::{fmt_qps, measure, print_table, write_json, Json};
use erbium_search::cpu_baseline::CpuBaseline;
use erbium_search::encoder::{EncodedBatch, QueryEncoder};
use erbium_search::erbium::{Backend, ErbiumEngine, FpgaModel, NativeEvaluator};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::nfa::memory::NfaImage;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::prng::Rng;
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::runtime::Runtime;
use erbium_search::workload::QueryFactory;

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (n_rules, n_queries, budget_scale) =
        if smoke { (2_000, 2_048, 0.1) } else { (20_000, 8_192, 1.0) };
    let budget = |ms: f64| ms * budget_scale;

    let gen_cfg = GeneratorConfig { n_rules, ..GeneratorConfig::default() };
    let world = generate_world(&gen_cfg);
    let schema = Schema::for_version(StandardVersion::V2);
    let rs = generate_rule_set(&gen_cfg, &world, StandardVersion::V2);
    let (nfa, cstats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
    let model = FpgaModel::new(HardwareConfig::v2_aws(4), cstats.depth);
    let native = NativeEvaluator::new(nfa.clone());
    let cpu = CpuBaseline::new(schema.clone(), &rs);
    let enc = QueryEncoder::new(&nfa.plan, 28);
    let shards = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(2).clamp(2, 8);

    // Fig 12 replay workload: schedule-drawn queries under zipf station
    // skew — hot connections recur, exactly what the production trace
    // replays against the two flows.
    let factory = QueryFactory::new(&world, 5, 40);
    let mut rng = Rng::new(0xBEEF);
    let queries: Vec<_> = (0..n_queries)
        .map(|_| {
            let st = rng.zipf(world.airports.len(), 1.1) as u32;
            factory.query(&mut rng, &world, st)
        })
        .collect();
    let nq = n_queries as f64;
    let qps = |p50_ns: f64| nq / (p50_ns * 1e-9);

    let mut rows = Vec::new();
    let mut row = |name: &str, st_p50_ns: f64| {
        let r = qps(st_p50_ns);
        rows.push(vec![
            name.into(),
            format!("{:.0} ns/query", st_p50_ns / nq),
            fmt_qps(r),
        ]);
        r
    };

    // Encoder alone: the struct-of-arrays in-place batch fill.
    let mut ebatch = EncodedBatch::default();
    let st = measure(budget(200.0), || {
        enc.encode_batch_into(&queries, &mut ebatch);
        std::hint::black_box(&ebatch);
    });
    let encoder_qps = row("encoder encode_batch_into", st.p50_ns);

    // Scalar feeder: per-query encode (fresh Vec) + per-query walk (fresh
    // bit-sets) — the pre-optimisation hot path, kept as the baseline the
    // speedup is measured against.
    let st = measure(budget(400.0), || {
        for q in &queries {
            let v = enc.encode(q);
            std::hint::black_box(native.evaluate_encoded(q.station, &v));
        }
    });
    let scalar_qps = row("native scalar (alloc per query)", st.p50_ns);
    let scalar_min_ns = st.min_ns;

    // Batch feeder: one in-place encode + one walk with reused scratch.
    let mut scratch = native.scratch();
    let mut out = Vec::new();
    let st = measure(budget(400.0), || {
        enc.encode_batch_into(&queries, &mut ebatch);
        native.evaluate_batch(&ebatch, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let batch_qps = row("native evaluate_batch (reused scratch)", st.p50_ns);
    let batch_min_ns = st.min_ns;

    // Sharded feeder: same batch split across cores.
    let st = measure(budget(400.0), || {
        enc.encode_batch_into(&queries, &mut ebatch);
        native.evaluate_batch_sharded(&ebatch, shards, &mut scratch, &mut out);
        std::hint::black_box(&out);
    });
    let sharded_qps = row(&format!("native evaluate_batch_sharded (×{shards})"), st.p50_ns);

    // Lockstep feeder: station-bucketed lane groups, 64 queries per word.
    let mut lanes = native.lane_scratch();
    let st = measure(budget(400.0), || {
        enc.encode_batch_into(&queries, &mut ebatch);
        native.evaluate_batch_lockstep(&ebatch, &mut lanes, &mut out);
        std::hint::black_box(&out);
    });
    let lockstep_qps = row("native evaluate_batch_lockstep (64-wide)", st.p50_ns);
    let lockstep_min_ns = st.min_ns;
    let lane_stats = native.evaluate_batch_lockstep(&ebatch, &mut lanes, &mut out);

    // Sharded lockstep: shards split over whole lane groups.
    let st = measure(budget(400.0), || {
        enc.encode_batch_into(&queries, &mut ebatch);
        native.evaluate_batch_lockstep_sharded(&ebatch, shards, &mut out);
        std::hint::black_box(&out);
    });
    let lockstep_sharded_qps =
        row(&format!("native lockstep_sharded (×{shards})"), st.p50_ns);

    // CPU baseline (§5.2), batch-into path with sharded airport caches.
    let st = measure(budget(400.0), || {
        cpu.evaluate_batch_into(&queries, &mut out);
        std::hint::black_box(&out);
    });
    let cpu_qps = row("CPU baseline evaluate_batch_into", st.p50_ns);

    // The MatchBackend surface the pipeline actually calls through: same
    // work plus dynamic dispatch and the service-time model — the cost of
    // the abstraction must stay in the noise.
    let engine =
        ErbiumEngine::new(nfa.clone(), model, Backend::Native, 28, 64).expect("engine");
    let backends: Vec<(&str, Box<dyn MatchBackend>)> = vec![
        ("dyn MatchBackend / fpga-native", Box::new(engine)),
        ("dyn MatchBackend / cpu", Box::new(CpuBackend::new(schema.clone(), &rs))),
    ];
    let mut dyn_qps = Vec::new();
    for (name, b) in &backends {
        let st = measure(budget(400.0), || {
            b.evaluate_batch_timed_into(&queries, &mut out).unwrap();
            std::hint::black_box(&out);
        });
        dyn_qps.push((*name, row(*name, st.p50_ns)));
    }

    // XLA path, if artifacts exist.
    if Runtime::artifacts_available() {
        let rt = std::sync::Arc::new(Runtime::cpu(Runtime::default_dir()).unwrap());
        // Raw kernel invocation on one uploaded partition (B=1024).
        let exe = rt.load("nfa_b1024_s64_l28").unwrap();
        let pi = (0..nfa.partitions.len())
            .max_by_key(|&i| nfa.partitions[i].accepts.len())
            .unwrap();
        let img = NfaImage::from_compiled(&nfa.partitions[pi], 28, 64).unwrap();
        let dev = exe.upload(&img).unwrap();
        let station = nfa.partitions[pi].station.unwrap();
        let qs: Vec<_> = (0..1024).map(|_| factory.query(&mut rng, &world, station)).collect();
        let mut ebuf = Vec::new();
        enc.encode_batch(&qs, 1024, &mut ebuf);
        let st = measure(budget(1_500.0), || {
            std::hint::black_box(exe.execute(&ebuf, &dev).unwrap());
        });
        rows.push(vec![
            "XLA kernel execute (B=1024, 1 partition)".into(),
            format!("{:.2} ms/batch", st.p50_ns / 1e6),
            fmt_qps(1024.0 / (st.p50_ns * 1e-9)),
        ]);
    } else {
        println!("artifacts missing — XLA rows skipped (run `make artifacts`)");
    }

    print_table(
        "§Perf — match hot path (Fig 12 replay workload)",
        &["path", "unit cost", "rate"],
        &rows,
    );

    // ---- §6.1 feeder-saturation knee, re-derived from measurements -----
    // The modeled v2 cloud node saturates at `node_sat` q/s; a feeder core
    // supplying `f` q/s starves it unless ceil(node_sat / f) cores feed it.
    // This is the paper's observation that the accelerator's gains hinge on
    // the software side submitting requests optimally.
    let node_sat = model.saturation_qps();
    let feeders = |f: f64| (node_sat / f).ceil() as i64;
    println!("\n§6.1 feeder saturation (modeled node saturates at {}):", fmt_qps(node_sat));
    println!(
        "  scalar feeder: {} q/s → {} cores to saturate",
        fmt_qps(scalar_qps),
        feeders(scalar_qps)
    );
    println!(
        "  batch feeder:  {} q/s → {} cores to saturate ({:.2}× speedup)",
        fmt_qps(batch_qps),
        feeders(batch_qps),
        batch_qps / scalar_qps
    );
    println!(
        "  sharded ×{shards}:    {} q/s → {} feeder units to saturate",
        fmt_qps(sharded_qps),
        feeders(sharded_qps)
    );
    println!(
        "  lockstep:      {} q/s → {} cores to saturate ({:.2}× over batch)",
        fmt_qps(lockstep_qps),
        feeders(lockstep_qps),
        lockstep_qps / batch_qps
    );
    println!(
        "  lockstep ×{shards}:   {} q/s → {} feeder units to saturate",
        fmt_qps(lockstep_sharded_qps),
        feeders(lockstep_sharded_qps)
    );
    println!(
        "  lane occupancy: {:.1} live lanes/group mean over {} groups, \
         {} stations, {:.1} % scalar fallback",
        lane_stats.mean_occupancy(),
        lane_stats.groups,
        lane_stats.stations,
        lane_stats.fallback_fraction() * 100.0
    );

    // One trajectory entry per feeder implementation: the measured rate
    // and the derived §6.1 knee (feeder units needed to saturate the
    // modeled node). Downstream tooling plots these to watch the knee move
    // across PRs.
    let leg = |q: f64| {
        Json::obj([("qps", Json::Num(q)), ("feeders_to_saturate", Json::Int(feeders(q)))])
    };
    let json = Json::obj([
        ("bench", Json::Str("hotpath".into())),
        ("schema_version", Json::Int(2)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("n_rules", Json::Int(n_rules as i64)),
        ("n_queries", Json::Int(n_queries as i64)),
        ("shards", Json::Int(shards as i64)),
        ("encoder_qps", Json::Num(encoder_qps)),
        ("scalar_qps", Json::Num(scalar_qps)),
        ("batch_qps", Json::Num(batch_qps)),
        ("sharded_qps", Json::Num(sharded_qps)),
        ("lockstep_qps", Json::Num(lockstep_qps)),
        ("lockstep_sharded_qps", Json::Num(lockstep_sharded_qps)),
        ("batch_speedup", Json::Num(batch_qps / scalar_qps)),
        ("sharded_speedup", Json::Num(sharded_qps / scalar_qps)),
        ("lockstep_speedup", Json::Num(lockstep_qps / scalar_qps)),
        ("cpu_baseline_qps", Json::Num(cpu_qps)),
        (
            "dyn_backend_qps",
            Json::Obj(
                dyn_qps.iter().map(|(n, q)| (n.to_string(), Json::Num(*q))).collect(),
            ),
        ),
        ("modeled_node_saturation_qps", Json::Num(node_sat)),
        ("feeder_cores_to_saturate_scalar", Json::Int(feeders(scalar_qps))),
        ("feeder_cores_to_saturate_batch", Json::Int(feeders(batch_qps))),
        ("feeder_units_to_saturate_sharded", Json::Int(feeders(sharded_qps))),
        ("feeder_cores_to_saturate_lockstep", Json::Int(feeders(lockstep_qps))),
        (
            "trajectory",
            Json::obj([
                ("scalar", leg(scalar_qps)),
                ("batch", leg(batch_qps)),
                ("sharded", leg(sharded_qps)),
                ("lockstep", leg(lockstep_qps)),
                ("lockstep_sharded", leg(lockstep_sharded_qps)),
            ]),
        ),
        (
            "lane_occupancy",
            Json::obj([
                ("mean_lanes_per_group", Json::Num(lane_stats.mean_occupancy())),
                ("fallback_fraction", Json::Num(lane_stats.fallback_fraction())),
                ("groups", Json::Int(lane_stats.groups as i64)),
                ("stations", Json::Int(lane_stats.stations as i64)),
            ]),
        ),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_hotpath.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");

    // Sanity bounds, not tuned thresholds: batching strictly removes
    // per-query work over scalar (two bit-set allocations and one encode
    // `Vec` per query), and lockstep strictly removes per-query
    // instructions over batch on this ≥64-row zipf workload (one table
    // probe advances a whole lane group). The asserts compare *minimum*
    // iteration times — noise (frequency scaling, neighbors on a shared
    // runner) only ever adds time, so mins are the stable comparator; the
    // p50-based q/s stay in the report and JSON.
    assert!(
        batch_min_ns <= scalar_min_ns,
        "batch path slower than scalar even at best-case timing: \
         {batch_min_ns:.0} ns > {scalar_min_ns:.0} ns per pass — hot-path regression"
    );
    assert!(
        lockstep_min_ns <= batch_min_ns,
        "lockstep path slower than scalar batch even at best-case timing: \
         {lockstep_min_ns:.0} ns > {batch_min_ns:.0} ns per pass \
         (occupancy {:.1} lanes/group) — hot-path regression",
        lane_stats.mean_occupancy()
    );
}
