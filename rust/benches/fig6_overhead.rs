//! Fig 6 — Execution time of an MCT query decomposed into the processing
//! steps of the integrated architecture (basic 1p 1w 1k 1e configuration),
//! as a function of the batch size.
//!
//! Steps, in flow order (Fig 5): ZeroMQ request → Encoder → PCIe transfer
//! in → FPGA kernel → PCIe transfer out → result partition → ZeroMQ reply.
//! Software steps use the calibrated overhead models; the *real* Rust
//! encoder is also measured and printed alongside for calibration evidence.

use erbium_search::benchkit::{fmt_us, measure, print_table};
use erbium_search::coordinator::overheads::Overheads;
use erbium_search::encoder::QueryEncoder;
use erbium_search::erbium::FpgaModel;
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::prng::Rng;
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::workload::random_query;

fn main() {
    let o = Overheads::default();
    let model = FpgaModel::new(HardwareConfig::v2_aws(1), 26);

    // Real encoder measurement (our QueryEncoder on a real compiled plan).
    let gen_cfg = GeneratorConfig::small(0xF16, 2_000);
    let world = generate_world(&gen_cfg);
    let schema = Schema::for_version(StandardVersion::V2);
    let rs = generate_rule_set(&gen_cfg, &world, StandardVersion::V2);
    let (nfa, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
    let enc = QueryEncoder::new(&nfa.plan, 28);
    let mut rng = Rng::new(1);
    let queries: Vec<_> = (0..4096).map(|_| random_query(&mut rng, &world, 1)).collect();
    let mut buf = Vec::new();
    let st = measure(60.0, || {
        enc.encode_batch(&queries, 4096, &mut buf);
        std::hint::black_box(&buf);
    });
    let real_ns_per_q = st.p50_ns / 4096.0;
    println!(
        "real QueryEncoder: {:.1} ns/query (calibrated production-encoder model: {:.0} ns/query)",
        real_ns_per_q, o.encode.ns_per_query
    );

    let batches: Vec<usize> = (4..=18).step_by(2).map(|i| 1usize << i).collect();
    let mut rows = Vec::new();
    for &b in &batches {
        let t = model.batch_timing(b);
        let zmq_req = o.zmq.request_us(b);
        let encode = o.encode.us(b);
        let xrt = o.xrt.submission_us(1);
        let partition = o.sched.us(b);
        let zmq_rep = o.zmq.reply_us(b);
        let total =
            zmq_req + encode + xrt + t.transfer_in_us + t.compute_us + t.transfer_out_us
                + partition + zmq_rep + t.setup_us;
        let zmq_share = (zmq_req + zmq_rep) / total * 100.0;
        rows.push(vec![
            b.to_string(),
            fmt_us(zmq_req),
            fmt_us(encode),
            fmt_us(t.setup_us + t.transfer_in_us),
            fmt_us(t.compute_us),
            fmt_us(t.transfer_out_us),
            fmt_us(partition),
            fmt_us(zmq_rep),
            fmt_us(total),
            format!("{zmq_share:.0} %"),
        ]);
    }
    print_table(
        "Fig 6 — per-step execution time decomposition (1p 1w 1k 1e, MCT v2/XDMA)",
        &[
            "batch", "zmq req", "encode", "shell+PCIe in", "kernel", "PCIe out", "partition",
            "zmq reply", "total", "zmq share",
        ],
        &rows,
    );
    println!("\npaper anchors: ZeroMQ 60 %→30 % of total; data movement dominates ≤4 096;");
    println!("encoder linear and above kernel time at large batches.");
}
