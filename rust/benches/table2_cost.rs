//! Table 2 — Cost estimates for Domain Explorer + MCT deployments
//! (Fig 13 layout): on-premises (Alveo U200 / U50), AWS (c5.12xlarge vs
//! f1.2xlarge) and Azure (F48s v2 vs NP10s).

use erbium_search::benchkit::print_table;
use erbium_search::costmodel::{queries_per_dollar, table2, catalog};

fn main() {
    let rows: Vec<Vec<String>> = table2()
        .iter()
        .map(|r| {
            vec![
                r.deployment.clone(),
                r.element.name.to_string(),
                r.element.vcpus.to_string(),
                r.units.to_string(),
                format!("{}", r.element.unit_cost),
                r.total_label(),
            ]
        })
        .collect();
    print_table(
        "Table 2 — Domain Explorer + ERBIUM deployment costs",
        &["deployment", "element", "vCPUs", "units", "unit cost (USD|USD/h)", "total"],
        &rows,
    );
    println!(
        "\ncloud efficiency headline ([15]-style): v2 engine at 32 M q/s on f1.2xlarge ⇒ {:.0} G queries/USD",
        queries_per_dollar(32e6, catalog::AWS_F1_2XL.unit_cost) / 1e9
    );
    println!("paper anchors: on-prem only U50 beats CPU-only; cloud 3× (AWS) / 2.5× (Azure) MORE expensive.");
}
