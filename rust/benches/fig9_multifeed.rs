//! Fig 9 — Multiple Process-Worker couples feeding a single 4-engine
//! kernel: global throughput is maximised (paper: up to ~40 M q/s) while
//! the XRT scheduler imposes a latency linear in the number of feeding
//! threads and constant in the batch size.

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::overheads::XrtModel;
use erbium_search::coordinator::{simulate, SimConfig, Topology};

fn main() {
    let batches: Vec<usize> = (10..=17).map(|i| 1usize << i).collect();
    let couples = [1usize, 2, 4, 8];
    let mut thr_rows = Vec::new();
    let mut lat_rows = Vec::new();
    for &b in &batches {
        let mut thr = vec![b.to_string()];
        let mut lat = vec![b.to_string()];
        for &n in &couples {
            let r = simulate(&SimConfig::v2_cloud(Topology::new(n, n, 1, 4), b));
            thr.push(fmt_qps(r.throughput_qps));
            lat.push(fmt_us(r.exec_p90_us));
        }
        thr_rows.push(thr);
        lat_rows.push(lat);
    }
    let headers: Vec<String> = std::iter::once("batch/request".to_string())
        .chain(couples.iter().map(|n| format!("{n}p {n}w 1k 4e")))
        .collect();
    let h: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig 9a — global throughput (multi-feed, one kernel)", &h, &thr_rows);
    print_table("Fig 9b — p90 execution time of a single MCT request", &h, &lat_rows);

    // The XRT overhead model itself (linear in feeders, constant in batch).
    let x = XrtModel::default();
    let rows: Vec<Vec<String>> = couples
        .iter()
        .map(|&n| vec![n.to_string(), format!("{:.0} µs", x.submission_us(n))])
        .collect();
    print_table("XRT submission overhead model", &["feeders", "overhead"], &rows);
    println!("\npaper anchors: throughput maximised (≈40 M q/s reported for the integrated");
    println!("system; our v2 kernel model ceilings at ≈32 M q/s — see EXPERIMENTS.md);");
    println!("XRT sync linear in feeders, constant in batch size.");
}
