//! Fig 10 — Multiple processes per worker (np 1w 1k 4e): the wrapper
//! batches the queued requests of several processes into a single ERBIUM
//! call. A single process cannot saturate a worker; gains grow to ~8
//! processes and flatten towards 16 (worker saturation). Worker-level
//! scheduling latency resembles XRT's but depends on the batch size.
//!
//! Since the `MatchBackend` refactor the same regime runs for real: the
//! second half cross-validates the simulator against the threaded pipeline
//! (native backend, `AggregationPolicy::DrainQueue`) on the same
//! topologies — the paper's §4.3 worker aggregation, reproduced in the
//! real system rather than only modeled.

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::{cross_validate, simulate, SimConfig, Topology};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::rules::standard::StandardVersion;
use erbium_search::testing::fixture::compile_fixture;
use erbium_search::workload::{generate_trace, TraceConfig};

fn main() {
    let batches: Vec<usize> = (8..=15).map(|i| 1usize << i).collect();
    let procs = [1usize, 2, 4, 8, 16];
    let mut thr_rows = Vec::new();
    let mut lat_rows = Vec::new();
    let mut agg_rows = Vec::new();
    for &b in &batches {
        let mut thr = vec![b.to_string()];
        let mut lat = vec![b.to_string()];
        let mut agg = vec![b.to_string()];
        for &n in &procs {
            let r = simulate(&SimConfig::v2_cloud(Topology::new(n, 1, 1, 4), b));
            thr.push(fmt_qps(r.throughput_qps));
            lat.push(fmt_us(r.exec_p90_us));
            agg.push(format!("{:.2}", r.mean_aggregation));
        }
        thr_rows.push(thr);
        lat_rows.push(lat);
        agg_rows.push(agg);
    }
    let headers: Vec<String> = std::iter::once("batch/request".to_string())
        .chain(procs.iter().map(|n| format!("{n}p 1w 1k 4e")))
        .collect();
    let h: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig 10a — global throughput (processes per worker)", &h, &thr_rows);
    print_table("Fig 10b — p90 execution time of a single MCT request", &h, &lat_rows);
    print_table("wrapper aggregation (requests per ERBIUM call)", &h, &agg_rows);
    println!("\npaper anchors: single process does not saturate the worker; gains up to");
    println!("~8 processes, reduced towards 16; worker scheduling latency batch-dependent.");

    // ---- Cross-validation: simulator vs real pipeline -------------------
    let f = compile_fixture(0xF1610, 600, StandardVersion::V2, HardwareConfig::v2_aws(4));
    let trace = generate_trace(&TraceConfig::scaled(0xF16, 64, 40.0), &f.world);

    let mut rows = Vec::new();
    for n in [1usize, 4, 16] {
        let cv = cross_validate(Topology::new(n, 1, 1, 4), 4_096, f.native_factory(), &trace)
            .expect("cross-validation run");
        rows.push(vec![
            format!("{n}p 1w 1k 4e"),
            format!("{:.2}", cv.sim.mean_aggregation),
            format!("{:.2}", cv.real.mean_aggregation),
            format!("{:.0}/{:.0}", cv.real.mct_req_p50_us, cv.real.mct_req_p90_us),
            if cv.same_aggregation_regime() { "yes".into() } else { "NO".into() },
        ]);
    }
    print_table(
        "Fig 10 cross-validation — sim vs real pipeline (native backend, drain policy)",
        &["topology", "sim agg", "real agg", "real req p50/p90 µs", "same regime"],
        &rows,
    );
    println!("\n§4.3 reproduced end-to-end: many processes per worker force real");
    println!("worker-side aggregation (mean requests per engine call > 1).");
}
