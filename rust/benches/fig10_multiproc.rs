//! Fig 10 — Multiple processes per worker (np 1w 1k 4e): the wrapper
//! batches the queued requests of several processes into a single ERBIUM
//! call. A single process cannot saturate a worker; gains grow to ~8
//! processes and flatten towards 16 (worker saturation). Worker-level
//! scheduling latency resembles XRT's but depends on the batch size.

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::{simulate, SimConfig, Topology};

fn main() {
    let batches: Vec<usize> = (8..=15).map(|i| 1usize << i).collect();
    let procs = [1usize, 2, 4, 8, 16];
    let mut thr_rows = Vec::new();
    let mut lat_rows = Vec::new();
    let mut agg_rows = Vec::new();
    for &b in &batches {
        let mut thr = vec![b.to_string()];
        let mut lat = vec![b.to_string()];
        let mut agg = vec![b.to_string()];
        for &n in &procs {
            let r = simulate(&SimConfig::v2_cloud(Topology::new(n, 1, 1, 4), b));
            thr.push(fmt_qps(r.throughput_qps));
            lat.push(fmt_us(r.exec_p90_us));
            agg.push(format!("{:.2}", r.mean_aggregation));
        }
        thr_rows.push(thr);
        lat_rows.push(lat);
        agg_rows.push(agg);
    }
    let headers: Vec<String> = std::iter::once("batch/request".to_string())
        .chain(procs.iter().map(|n| format!("{n}p 1w 1k 4e")))
        .collect();
    let h: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table("Fig 10a — global throughput (processes per worker)", &h, &thr_rows);
    print_table("Fig 10b — p90 execution time of a single MCT request", &h, &lat_rows);
    print_table("wrapper aggregation (requests per ERBIUM call)", &h, &agg_rows);
    println!("\npaper anchors: single process does not saturate the worker; gains up to");
    println!("~8 processes, reduced towards 16; worker scheduling latency batch-dependent.");
}
