//! §6.1 dynamically — fleet economics of a *managed* deployment.
//!
//! Three experiments over the control-plane DES, all seeded and
//! deterministic:
//!
//! 1. **Autoscaled-heterogeneous vs static-homogeneous** under one
//!    diurnal period: the static fleet is peak-provisioned (the Table 2/3
//!    sizing discipline); the autoscaled fleet starts at one FPGA node and
//!    lets the cost-aware policy breathe over a CPU/FPGA class catalogue.
//!    The harness *asserts* the autoscaled fleet meets the same p90 SLA
//!    attainment at **strictly lower modeled $/Mquery**.
//! 2. **Fault drill** — a node dies mid-run and revives; with a live peer
//!    the drain/reroute policy must lose zero admitted requests.
//! 3. **The §6.1 knee, re-derived from dynamic runs** — sweep the feeder
//!    count of the FPGA node class and let each fleet autoscale against
//!    the same absolute demand: $/Mquery falls steeply while feeders
//!    relieve the starved kernel, then flattens at the kernel ceiling —
//!    the "strong FPGA behind a weak CPU feeder" curve, measured from
//!    managed fleets rather than a static sweep.
//!
//! Emits machine-readable `BENCH_fleet_dynamics.json` (override with
//! `BENCH_OUT`), uploaded next to `BENCH_hotpath.json` by the CI
//! bench-smoke step. `BENCH_SMOKE=1` shrinks request counts for CI.

use erbium_search::benchkit::{print_table, write_json, Json};
use erbium_search::cluster::sim::measure_spec_saturation_qps;
use erbium_search::cluster::{scheduled_sim_arrivals, NodeClass, SimNodeSpec};
use erbium_search::controlplane::{
    simulate_fleet, CostAware, FaultPlan, FleetSimConfig, ReactiveUtilisation, SimClass,
    StaticFleet,
};
use erbium_search::workload::RateSchedule;

/// Large batches put the node in the encoder-bound regime of §4.2/§6.1 —
/// the regime where the feeder count is the binding knob (the knee).
const BATCH: usize = 16_384;
const SLA_US: f64 = 120_000.0;
const SLA_TARGET: f64 = 0.90;

/// Measured-capacity class over a spec (the DES analogue of probing a
/// node before enrolling it in the fleet).
fn calibrated(class: NodeClass, spec: SimNodeSpec, probe_requests: usize) -> SimClass {
    let mut class = class;
    class.capacity_qps = measure_spec_saturation_qps(spec, BATCH, probe_requests);
    SimClass::new(class, spec)
}

/// One diurnal period spanning `n` requests around `base_rps`, plus a
/// control tick resolving it into ~30 windows.
fn diurnal(base_rps: f64, n: usize) -> (RateSchedule, f64) {
    let period_s = n as f64 / base_rps;
    (RateSchedule::diurnal(base_rps, 0.8 * base_rps, period_s), period_s * 1e6 / 30.0)
}

fn usage_json(r: &erbium_search::controlplane::FleetDynamicsReport) -> Json {
    Json::Obj(
        r.usage
            .iter()
            .map(|u| {
                (
                    u.class.clone(),
                    Json::obj([
                        ("node_hours", Json::Num(u.node_hours)),
                        ("cost_usd", Json::Num(u.cost_usd)),
                        ("peak_nodes", Json::Int(u.peak_nodes as i64)),
                    ]),
                )
            })
            .collect(),
    )
}

fn report_json(r: &erbium_search::controlplane::FleetDynamicsReport) -> Json {
    Json::obj([
        ("policy", Json::Str(r.policy.clone())),
        ("cost_usd", Json::Num(r.cost_usd)),
        ("node_hours", Json::Num(r.node_hours)),
        ("dollars_per_mquery", Json::Num(r.dollars_per_mquery())),
        ("sla_attainment", Json::Num(r.sla_attainment)),
        ("peak_nodes", Json::Int(r.peak_nodes as i64)),
        ("scale_events", Json::Int(r.events.len() as i64)),
        ("completed_queries", Json::Int(r.cluster.completed_queries as i64)),
        ("usage", usage_json(r)),
    ])
}

fn main() {
    let smoke = std::env::var("BENCH_SMOKE").is_ok();
    let (n_requests, probe_requests) = if smoke { (600, 150) } else { (2_500, 400) };

    // ---- Class catalogue, capacities measured ---------------------------
    let fpga = calibrated(NodeClass::fpga_f1(0.0), SimNodeSpec::v2_cloud(8), probe_requests);
    let cpu = calibrated(NodeClass::cpu_c5(0.0), SimNodeSpec::cpu(4, 2.0), probe_requests);
    println!(
        "classes: {} {:.1} M q/s @ {:.4} $/h | {} {:.1} M q/s @ {:.4} $/h",
        fpga.class.name,
        fpga.class.capacity_qps / 1e6,
        fpga.class.hourly_usd(),
        cpu.class.name,
        cpu.class.capacity_qps / 1e6,
        cpu.class.hourly_usd()
    );

    // ---- 1. static-homogeneous vs autoscaled-heterogeneous -------------
    let base_rps = fpga.class.capacity_qps / BATCH as f64;
    let (schedule, tick_us) = diurnal(base_rps, n_requests);
    let arrivals = scheduled_sim_arrivals(0xD1A, &schedule, BATCH, n_requests, 16, 0.9, 0);
    // Peak-provisioned static fleet: peak demand over the standard 70 %
    // utilisation target — the Table 2/3 sizing discipline.
    let peak_qps = schedule.peak_rps() * BATCH as f64;
    let n_static =
        ((peak_qps / 0.7 / fpga.class.capacity_qps).ceil() as usize).max(1);
    let static_cfg =
        FleetSimConfig::new(vec![fpga.clone()], vec![0; n_static])
            .with_control(tick_us, tick_us / 2.0)
            .with_sla(SLA_US)
            .with_bounds(1, n_static.max(1))
            .with_profile_label(schedule.label());
    let mut static_scaler = StaticFleet;
    let static_run = simulate_fleet(&static_cfg, &mut static_scaler, &arrivals);

    // The autoscaled fleet starts *mixed* (one FPGA node + one CPU node
    // behind the same router); the cost-aware policy is free to shed the
    // expensive-per-capacity class at the trough and add the cheap one at
    // the peak — the §6.1 balance decision made live.
    let auto_cfg = FleetSimConfig::new(vec![fpga.clone(), cpu.clone()], vec![0, 1])
        .with_control(tick_us, tick_us / 2.0)
        .with_sla(SLA_US)
        .with_bounds(1, n_static + 2)
        .with_profile_label(schedule.label());
    let mut cost_scaler = CostAware::with_target(0.60);
    let auto_run = simulate_fleet(&auto_cfg, &mut cost_scaler, &arrivals);

    println!("\nstatic    : {}", static_run.summary());
    println!("autoscaled: {}", auto_run.summary());
    print!("{}", auto_run.timeline());

    assert!(static_run.cluster.conserves_requests());
    assert!(auto_run.cluster.conserves_requests());
    assert!(
        static_run.meets_sla(SLA_TARGET) && auto_run.meets_sla(SLA_TARGET),
        "both fleets must hold the p90 SLA: static {:.3}, auto {:.3}",
        static_run.sla_attainment,
        auto_run.sla_attainment
    );
    assert!(
        auto_run.dollars_per_mquery() < static_run.dollars_per_mquery(),
        "autoscaling must beat peak provisioning on $/Mquery: {:.4} !< {:.4}",
        auto_run.dollars_per_mquery(),
        static_run.dollars_per_mquery()
    );
    println!(
        "\n$/Mquery: static {:.4} vs autoscaled {:.4} ({:.0} % saved at equal SLA)",
        static_run.dollars_per_mquery(),
        auto_run.dollars_per_mquery(),
        (1.0 - auto_run.dollars_per_mquery() / static_run.dollars_per_mquery()) * 100.0
    );

    // ---- 2. fault drill -------------------------------------------------
    let mid_us = arrivals[arrivals.len() / 2].at_us;
    let span_us = arrivals.last().unwrap().at_us;
    let drill_cfg = FleetSimConfig::new(vec![fpga.clone()], vec![0, 0])
        .with_control(tick_us, tick_us / 2.0)
        .with_sla(SLA_US)
        .with_bounds(1, 2)
        .with_faults(FaultPlan::kill(0, mid_us, 0.15 * span_us))
        .with_profile_label(schedule.label());
    let mut drill_scaler = StaticFleet;
    let drill = simulate_fleet(&drill_cfg, &mut drill_scaler, &arrivals);
    println!("\nfault drill: {}", drill.summary());
    assert!(drill.cluster.conserves_requests());
    assert_eq!(
        drill.cluster.lost, 0,
        "drain/reroute with a live peer must lose zero admitted requests"
    );
    assert!(drill.rerouted > 0, "the kill must actually displace in-flight work");

    // ---- 3. the §6.1 knee from managed fleets ---------------------------
    // Same absolute demand for every feeder count; each fleet autoscales
    // (reactive) with enough headroom to serve the peak.
    let mut knee_rows = Vec::new();
    let mut knee_json = Vec::new();
    let mut per_feeders = Vec::new();
    for feeders in [1usize, 2, 4, 8] {
        let class = calibrated(
            NodeClass::fpga_f1(0.0),
            SimNodeSpec::v2_cloud(feeders),
            probe_requests,
        );
        let max_nodes =
            ((peak_qps / 0.7 / class.class.capacity_qps).ceil() as usize + 1).max(2);
        let cfg = FleetSimConfig::new(vec![class.clone()], vec![0])
            .with_control(tick_us, tick_us / 2.0)
            .with_sla(SLA_US)
            .with_bounds(1, max_nodes)
            .with_profile_label(schedule.label());
        let mut scaler = ReactiveUtilisation::with_band(0, 0.7, 0.3);
        let r = simulate_fleet(&cfg, &mut scaler, &arrivals);
        assert!(r.cluster.conserves_requests());
        knee_rows.push(vec![
            format!("{feeders}"),
            format!("{:.1} M q/s", class.class.capacity_qps / 1e6),
            format!("{}", r.peak_nodes),
            format!("{:.4}", r.dollars_per_mquery()),
        ]);
        knee_json.push(Json::obj([
            ("feeders", Json::Int(feeders as i64)),
            ("capacity_qps", Json::Num(class.class.capacity_qps)),
            ("peak_nodes", Json::Int(r.peak_nodes as i64)),
            ("dollars_per_mquery", Json::Num(r.dollars_per_mquery())),
        ]));
        per_feeders.push(r.dollars_per_mquery());
    }
    print_table(
        "§6.1 knee, dynamic: $/Mquery of an autoscaled fleet vs feeder count",
        &["feeders", "node capacity", "peak nodes", "$/Mquery"],
        &knee_rows,
    );
    assert!(
        per_feeders[0] > 1.8 * per_feeders[2],
        "a starved feeder must cost ≈2× per query vs the balanced node: {:.4} !> 1.8×{:.4}",
        per_feeders[0],
        per_feeders[2]
    );
    // Past the knee the kernel (XRT-contended) binds: doubling 4 → 8
    // feeders buys nothing — $/Mquery flattens (and can even tick up, the
    // §6.1 "extra CPUs stop paying" point).
    assert!(
        per_feeders[3] > 0.7 * per_feeders[2],
        "the curve must flatten at the kernel ceiling: {:.4} vs {:.4}",
        per_feeders[3],
        per_feeders[2]
    );

    // ---- Artifact -------------------------------------------------------
    let json = Json::obj([
        ("bench", Json::Str("fleet_dynamics".into())),
        ("smoke", Json::Bool(smoke)),
        ("batch", Json::Int(BATCH as i64)),
        ("requests", Json::Int(n_requests as i64)),
        ("profile", Json::Str(schedule.label())),
        ("sla_us", Json::Num(SLA_US)),
        ("static", report_json(&static_run)),
        ("autoscaled", report_json(&auto_run)),
        (
            "fault_drill",
            Json::obj([
                ("lost", Json::Int(drill.cluster.lost as i64)),
                ("rerouted", Json::Int(drill.rerouted as i64)),
                ("completed", Json::Int(drill.cluster.completed as i64)),
            ]),
        ),
        ("knee", Json::Arr(knee_json)),
    ]);
    let out_path =
        std::env::var("BENCH_OUT").unwrap_or_else(|_| "BENCH_fleet_dynamics.json".to_string());
    write_json(&out_path, &json).expect("write bench artifact");
}
