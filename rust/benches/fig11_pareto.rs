//! Fig 11 — Pareto comparison: p90 execution time as a function of the
//! global throughput for selected configurations. The paper's two worked
//! examples: above a 20 M q/s throughput floor, `4p 4w 1k 4e` has the
//! lowest execution time; under a 500 µs execution-time cap, `2p 2w 1k 4e`
//! yields the best throughput.

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::coordinator::{simulate, SimConfig, Topology};

fn main() {
    let configs = [
        Topology::new(1, 1, 1, 1),
        Topology::new(1, 1, 1, 2),
        Topology::new(1, 1, 1, 4),
        Topology::new(2, 2, 1, 4),
        Topology::new(4, 4, 1, 4),
        Topology::new(8, 8, 1, 4),
        Topology::new(2, 2, 2, 2),
        Topology::new(4, 4, 2, 2),
        Topology::new(4, 4, 4, 1),
        Topology::new(8, 4, 1, 4),
        Topology::new(16, 4, 1, 4),
        Topology::new(8, 2, 1, 4),
    ];
    let batch = 16_384;
    let mut points: Vec<(String, f64, f64)> = configs
        .iter()
        .map(|t| {
            let r = simulate(&SimConfig::v2_cloud(*t, batch));
            (t.label(), r.throughput_qps, r.exec_p90_us)
        })
        .collect();
    points.sort_by(|a, b| a.1.partial_cmp(&b.1).unwrap());

    // Pareto front: increasing throughput, minimal exec time.
    let mut front: Vec<bool> = vec![true; points.len()];
    for (i, p) in points.iter().enumerate() {
        front[i] = !points.iter().any(|q| q.1 >= p.1 && q.2 < p.2);
    }
    let rows: Vec<Vec<String>> = points
        .iter()
        .zip(&front)
        .map(|((label, thr, lat), on)| {
            vec![
                label.clone(),
                fmt_qps(*thr),
                fmt_us(*lat),
                if *on { "pareto".into() } else { "".into() },
            ]
        })
        .collect();
    print_table(
        &format!("Fig 11 — exec time vs throughput (batch/request = {batch})"),
        &["config", "throughput", "p90 exec", "front"],
        &rows,
    );

    // The paper's two selection queries.
    let floor = 20e6;
    let best_above = points
        .iter()
        .filter(|p| p.1 >= floor)
        .min_by(|a, b| a.2.partial_cmp(&b.2).unwrap());
    match best_above {
        Some(p) => println!(
            "\nbest config above 20 M q/s floor: {} ({} @ {}) — paper: 4p 4w 1k 4e",
            p.0,
            fmt_qps(p.1),
            fmt_us(p.2)
        ),
        None => println!("\nno config clears the 20 M q/s floor at this batch size"),
    }
    // Pick the paper's latency cap relative to our clock: the paper says
    // 500 µs; our per-request batch differs, so also report a scaled cap.
    for cap in [500.0, 2_000.0, 5_000.0] {
        if let Some(p) = points
            .iter()
            .filter(|p| p.2 <= cap)
            .max_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
        {
            println!(
                "best throughput under {} exec-time cap: {} ({} @ {}) — paper(500µs): 2p 2w 1k 4e",
                fmt_us(cap),
                p.0,
                fmt_qps(p.1),
                fmt_us(p.2)
            );
        }
    }
}
