//! Fig 4 — Stand-alone hardware engine: execution time (µs) and throughput
//! (MCT queries/s) as a function of the batch size.
//!
//! Series (as in the paper): MCT v1 with 4 NFA Evaluation Engines on the
//! on-prem QDMA shell, and MCT v2 with 1, 2 and 4 engines on AWS F1's XDMA
//! shell. Per batch size, the paper computes one thousand travel solutions
//! and reports the 90th percentile; the hardware-model clock here is
//! deterministic, so percentile == value.
//!
//! Functional sanity: for a subset of batch sizes we actually *evaluate*
//! the batches on the native functional simulator so the reported rows come
//! from real answered queries, not shapes alone.

use erbium_search::benchkit::{fmt_qps, fmt_us, print_table};
use erbium_search::erbium::{Backend, ErbiumEngine, FpgaModel};
use erbium_search::nfa::constraint_gen::HardwareConfig;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::prng::Rng;
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::workload::random_query;

fn main() {
    let gen_cfg = GeneratorConfig { n_rules: 20_000, ..GeneratorConfig::default() };
    let world = generate_world(&gen_cfg);

    // Compile both standards once.
    let mut engines = Vec::new();
    for (version, label_hw) in
        [(StandardVersion::V1, "QDMA on-prem"), (StandardVersion::V2, "XDMA AWS F1")]
    {
        let schema = Schema::for_version(version);
        let rs = generate_rule_set(&gen_cfg, &world, version);
        let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let depth = stats.depth;
        let configs: Vec<usize> =
            if version == StandardVersion::V1 { vec![4] } else { vec![1, 2, 4] };
        for e in configs {
            let hw = match version {
                StandardVersion::V1 => HardwareConfig::v1_onprem(e),
                StandardVersion::V2 => HardwareConfig::v2_aws(e),
            };
            let model = FpgaModel::new(hw, depth);
            let engine = ErbiumEngine::new(nfa.clone(), model, Backend::Native, 28, 64)
                .expect("engine");
            engines.push((format!("{} {e}e ({label_hw})", version.name()), engine));
        }
    }

    // Functional spot-check: answer real batches on every engine.
    let mut rng = Rng::new(0xF164);
    let spot: Vec<_> = (0..4096)
        .map(|_| {
            let st = rng.index(gen_cfg.n_airports) as u32;
            random_query(&mut rng, &world, st)
        })
        .collect();
    for (label, engine) in &engines {
        let out = engine.evaluate_batch(&spot).expect("evaluate");
        let matched = out.iter().filter(|d| d.matched()).count();
        println!("functional check [{label}]: {matched}/{} queries matched", spot.len());
        assert!(matched > 0);
    }

    let batches: Vec<usize> = (0..=20).map(|i| 1usize << i).collect(); // 1 .. 1,048,576

    let mut rows = Vec::new();
    for &b in &batches {
        let mut row = vec![format!("{b}")];
        for (_, engine) in &engines {
            let t = engine.model().batch_timing(b);
            row.push(fmt_us(t.total_us));
            row.push(fmt_qps(engine.model().sustained_qps(b)));
        }
        rows.push(row);
    }
    let mut headers: Vec<String> = vec!["batch".into()];
    for (label, _) in &engines {
        headers.push(format!("{label} exec"));
        headers.push(format!("{label} thr"));
    }
    let headers_ref: Vec<&str> = headers.iter().map(String::as_str).collect();
    print_table(
        "Fig 4 — stand-alone execution time & throughput vs batch size",
        &headers_ref,
        &rows,
    );

    // Paper anchors.
    println!("\npaper anchors: v1 saturates ≈40 M q/s, v2 ≈32 M q/s above ~100k batch;");
    for (label, engine) in &engines {
        println!(
            "  {label}: saturation {} (bound: {})",
            fmt_qps(engine.model().saturation_qps()),
            if engine.model().compute_qps() < engine.model().pcie_qps() {
                "frequency/compute"
            } else {
                "PCIe bandwidth"
            }
        );
    }
}
