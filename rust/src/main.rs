//! `erbium-search` — leader entrypoint / CLI for the reproduction.
//!
//! Subcommands (hand-rolled parsing; clap is unavailable offline):
//!
//! ```text
//! erbium-search gen-rules   [--rules N] [--seed S] [--version v1|v2] [--out FILE]
//! erbium-search compile     [--rules N] [--seed S] [--version v1|v2] [--order declared|optimised]
//! erbium-search query       [--rules N] [--seed S] [--station ID] [--n N] [--backend native|xla]
//! erbium-search replay      [--uq N] [--rules N] [--p P] [--w W] [--k K] [--e E]
//!                           [--backend cpu|native|xla] [--agg forward|drain|max:N]
//!                           [--strategy cpu|fpga] [--fail fast|degrade]
//!                           [--open RATE_RPS] [--requests N] [--batch B] [--cache CAP]
//!                           [--shards N]  (native backend: split large batches over N cores)
//!                           [--no-lockstep]  (native backend: disable the query-parallel walk)
//! erbium-search fleet       [--nodes N] [--route rr|jsq|jsq2|jsqd:N|shard] [--rate RPS]
//!                           [--requests N] [--batch B] [--cache CAP] [--cap Q | --sla US]
//!                           [--rules N] [--seed S] [--p P] [--w W] [--k K] [--e E]
//!                           [--autoscale static|reactive|sla|cost]   (control-plane DES)
//!                           [--profile diurnal:BASE:AMP:PERIOD_S | const:RPS]
//!                           [--faults FAULTS] [--hetero] [--tick-us T] [--max N] [--feeders F]
//!                           [--retry] [--hedge] [--breaker] [--deadline-us D]
//!                           (resilience flags run the fleet behind the event front door)
//! erbium-search frontdoor   [--sessions N] [--batches B] [--batch Q] [--rate SESSIONS_PER_S]
//!                           [--backpressure none|window|socket] [--window W] [--pending P]
//!                           [--threads T] [--nodes N] [--cap Q] [--faults FAULTS] [--seed S]
//!                           [--retry] [--hedge] [--breaker] [--deadline-us D]
//!                           [--baseline]  (thread-per-session door, T threads)
//!                           [--des]       (run the DES twin instead of the real reactor)
//!
//! FAULTS is either `N` (N seeded kills, back-compat) or a gray spec:
//! `gray:slow:F` | `gray:err:P` | `gray:hang:P:STALL_US` | `gray:mix:N`.
//! erbium-search costs       [--uqps UQ_PER_S] [--node-qps QPS]
//! ```
//!
//! `--trace FILE [--trace-sample N]` attaches the flight recorder
//! ([`erbium_search::telemetry`]) and exports a Chrome-trace-event JSON
//! to FILE — load it in Perfetto (ui.perfetto.dev) or `chrome://tracing`.
//! Supported by `replay --open`, `frontdoor`, and front-door `fleet` runs
//! (a resilience flag set); `--trace-sample N` keeps 1 in N requests
//! (deterministic in the request id; default 1 = everything).

use std::sync::Arc;

use erbium_search::backend::{
    cpu_backend_factory, native_backend_factory, native_backend_factory_tuned,
    xla_backend_factory, BackendFactory,
};
use erbium_search::cluster::{
    scheduled_sim_arrivals, simulate_cluster, AdmissionPolicy, Cluster, ClusterConfig,
    ClusterSimConfig, NodeClass, RoutePolicy, SimNodeSpec,
};
use erbium_search::controlplane::{
    simulate_fleet, Autoscaler, CostAware, FaultPlan, FleetSimConfig, ReactiveUtilisation,
    SimClass, SlaLatency, StaticFleet,
};
use erbium_search::coordinator::{
    AggregationPolicy, FailurePolicy, MctStrategy, Overheads, Pipeline, PipelineConfig,
    Topology,
};
use erbium_search::erbium::{Backend, ErbiumEngine, FpgaModel};
use erbium_search::frontdoor::{
    run_frontdoor, sim_frontdoor, BackpressurePolicy, FrontdoorConfig, FrontdoorSimConfig,
};
use erbium_search::nfa::constraint_gen::{estimate, HardwareConfig};
use erbium_search::nfa::optimiser::OrderStrategy;
use erbium_search::nfa::parser::{compile_rule_set, CompileOptions};
use erbium_search::prng::Rng;
use erbium_search::resilience::{BreakerConfig, HedgePolicy, ResiliencePolicy, RetryPolicy};
use erbium_search::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
use erbium_search::rules::standard::{Schema, StandardVersion};
use erbium_search::rules::serde_text;
use erbium_search::runtime::Runtime;
use erbium_search::telemetry::{write_chrome_trace, Recorder, RingRecorder, Trace, TraceSpec};
use erbium_search::workload::{
    generate_trace, random_query, session_plans, PoissonSource, RateSchedule, TraceConfig,
};

struct Args(Vec<String>);

impl Args {
    fn get(&self, key: &str) -> Option<&str> {
        self.0.iter().position(|a| a == key).and_then(|i| self.0.get(i + 1)).map(|s| s.as_str())
    }
    fn usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn flag(&self, key: &str) -> bool {
        self.0.iter().any(|a| a == key)
    }
    fn f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
    }
    fn version(&self) -> StandardVersion {
        match self.get("--version") {
            Some("v1") => StandardVersion::V1,
            _ => StandardVersion::V2,
        }
    }
}

fn setup(args: &Args) -> (GeneratorConfig, erbium_search::rules::types::World, Schema, erbium_search::rules::types::RuleSet) {
    let cfg = GeneratorConfig {
        n_rules: args.usize("--rules", 20_000),
        seed: args.u64("--seed", 0xE2B1_00),
        ..GeneratorConfig::default()
    };
    let world = generate_world(&cfg);
    let version = args.version();
    let schema = Schema::for_version(version);
    let rs = generate_rule_set(&cfg, &world, version);
    (cfg, world, schema, rs)
}

/// The `--retry`/`--hedge`/`--breaker`/`--deadline-us` flags shared by
/// the `fleet` and `frontdoor` subcommands. Retry backoffs and breaker
/// thresholds use library defaults at µs scale; the hedge trigger is
/// scale-free (a multiple of the learned winner latency).
fn resilience_from_args(args: &Args) -> ResiliencePolicy {
    let mut res = ResiliencePolicy::none();
    if let Some(d) = args.get("--deadline-us").and_then(|v| v.parse().ok()) {
        res = res.with_deadline(d);
    }
    if args.flag("--retry") {
        res = res.with_retry(RetryPolicy::new(3, 500.0, 8_000.0)).with_budget_ratio(0.5);
    }
    if args.flag("--hedge") {
        res = res.with_hedge(HedgePolicy::new(3.0));
    }
    if args.flag("--breaker") {
        res = res.with_breaker(BreakerConfig::default());
    }
    res
}

/// The `--trace FILE [--trace-sample N]` pair: where to export the
/// flight-recorder trace, and how it samples.
fn trace_from_args(args: &Args) -> Option<(String, TraceSpec)> {
    let path = args.get("--trace")?.to_string();
    let sample = args.usize("--trace-sample", 1).max(1) as u32;
    Some((path, TraceSpec::sampled(sample)))
}

/// Export a drained trace as Chrome trace events and say where it went.
fn export_trace(path: &str, trace: &Trace) -> anyhow::Result<()> {
    write_chrome_trace(path, trace)?;
    println!(
        "trace: {} events (1-in-{} sampled, {} dropped) → {path} — load in Perfetto",
        trace.len(),
        trace.sample.max(1),
        trace.dropped
    );
    Ok(())
}

/// Parse `--faults` (kills or a gray spec) against the run's span.
fn faults_from_args(
    args: &Args,
    seed: u64,
    nodes: usize,
    span_us: f64,
    service_scale_us: f64,
) -> anyhow::Result<FaultPlan> {
    match args.get("--faults") {
        None => Ok(FaultPlan::none()),
        Some(spec) => FaultPlan::parse_cli(spec, seed, nodes, span_us, service_scale_us)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "bad --faults {spec:?} (N | gray:slow:F | gray:err:P | \
                     gray:hang:P:STALL_US | gray:mix:N)"
                )
            }),
    }
}

fn backend(args: &Args) -> anyhow::Result<Backend> {
    Ok(match args.get("--backend") {
        Some("xla") => Backend::Xla {
            runtime: Arc::new(Runtime::cpu(Runtime::default_dir())?),
            batch_hint: 1024,
        },
        _ => Backend::Native,
    })
}

fn main() -> anyhow::Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().cloned().unwrap_or_default();
    let args = Args(argv);
    match cmd.as_str() {
        "gen-rules" => {
            let (_, _, schema, rs) = setup(&args);
            let out = args.get("--out").unwrap_or("rules.mct").to_string();
            serde_text::write_rule_set(&rs, &out)?;
            println!("wrote {} {} rules to {out}", rs.rules.len(), schema.version.name());
        }
        "compile" => {
            let (_, _, schema, rs) = setup(&args);
            let strategy = match args.get("--order") {
                Some("declared") => OrderStrategy::Declared,
                _ => OrderStrategy::Optimised,
            };
            let (nfa, stats) =
                compile_rule_set(&schema, &rs, &CompileOptions { strategy, ..Default::default() });
            let hw = HardwareConfig::v2_aws(4);
            let est = estimate(&hw, &nfa);
            println!(
                "{} rules → depth {}, {} partitions (max width {}), {} transitions (+{} split)",
                stats.rules_in, stats.depth, stats.partitions, stats.max_width,
                stats.total_transitions, stats.rules_added_by_split
            );
            println!(
                "synthesis model: {:.0} resource units, {:.1} MiB, {:.1} MHz; artifact {}",
                est.resource_units,
                est.memory_bytes as f64 / (1 << 20) as f64,
                est.frequency_mhz,
                hw.artifact_name(1024)
            );
        }
        "query" => {
            let (cfg, world, schema, rs) = setup(&args);
            let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
            let engine = ErbiumEngine::new(nfa, model, backend(&args)?, 28, 64)?;
            let n = args.usize("--n", 8);
            let mut rng = Rng::new(args.u64("--seed", 1));
            let qs: Vec<_> = (0..n)
                .map(|_| {
                    let st = args
                        .get("--station")
                        .and_then(|v| v.parse().ok())
                        .unwrap_or_else(|| rng.index(cfg.n_airports) as u32);
                    random_query(&mut rng, &world, st)
                })
                .collect();
            let (out, t) = engine.evaluate_batch_timed(&qs)?;
            for (q, d) in qs.iter().zip(&out) {
                println!("station {:>3} → {d}", q.station);
            }
            println!("hw-model time for the batch: {:.1} µs", t.total_us);
        }
        "replay" => {
            let (_, world, schema, rs) = setup(&args);
            let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
            let topo = Topology::new(
                args.usize("--p", 4),
                args.usize("--w", 2),
                args.usize("--k", 1),
                args.usize("--e", 4),
            );
            let trace = generate_trace(
                &TraceConfig {
                    n_user_queries: args.usize("--uq", 16),
                    mean_ts_per_query: 150.0,
                    ..TraceConfig::default()
                },
                &world,
            );
            // The whole point of the MatchBackend layer: CPU and FPGA flows
            // replay end-to-end through the same threaded pipeline.
            let factory: BackendFactory = match args.get("--backend") {
                Some("cpu") => cpu_backend_factory(schema.clone(), rs.clone()),
                Some("xla") => {
                    anyhow::ensure!(
                        Runtime::artifacts_available(),
                        "--backend xla needs the AOT artifacts; run `make artifacts` first"
                    );
                    xla_backend_factory(nfa.clone(), model, 1024, 28, 64)
                }
                _ => native_backend_factory_tuned(
                    nfa.clone(),
                    model,
                    28,
                    64,
                    args.usize("--shards", 1),
                    !args.flag("--no-lockstep"),
                ),
            };
            let strategy = match args.get("--strategy") {
                Some("cpu") => MctStrategy::CpuPerTs,
                _ => MctStrategy::FpgaBatched,
            };
            let agg = args
                .get("--agg")
                .map(|s| {
                    AggregationPolicy::parse(s)
                        .ok_or_else(|| anyhow::anyhow!("bad --agg {s:?} (forward|drain|max:N)"))
                })
                .transpose()?
                .unwrap_or(AggregationPolicy::Forward);
            let failure = match args.get("--fail") {
                Some("degrade") => FailurePolicy::Degrade,
                _ => FailurePolicy::FailFast,
            };
            let mut cfg = PipelineConfig::new(topo)
                .with_strategy(strategy)
                .with_aggregation(agg)
                .with_failure(failure);
            if let Some(cap) = args.get("--cache").and_then(|v| v.parse().ok()) {
                cfg = cfg.with_cache(cap);
            }
            // --open RATE: bypass the closed-loop trace replay and drive the
            // node from a Poisson arrival stream at RATE requests/s.
            let flight = trace_from_args(&args);
            let r = match args.get("--open").and_then(|v| v.parse::<f64>().ok()) {
                Some(rate) => {
                    let mut src = PoissonSource::new(
                        &world,
                        args.u64("--seed", 1),
                        rate,
                        args.usize("--batch", 256),
                        args.usize("--requests", 512),
                    );
                    match &flight {
                        Some((path, spec)) => {
                            let mut rec = RingRecorder::new(*spec);
                            let r =
                                Pipeline::new(cfg, factory).run_open_traced(&mut src, &mut rec)?;
                            export_trace(path, &rec.into_trace())?;
                            r
                        }
                        None => Pipeline::new(cfg, factory).run_open(&mut src)?,
                    }
                }
                None => {
                    anyhow::ensure!(
                        flight.is_none(),
                        "--trace on replay needs --open (the recorder hooks the open-loop driver)"
                    );
                    Pipeline::new(cfg, factory).run(&trace)?
                }
            };
            println!(
                "{} | backend {} | agg {} | {} uq, {} MCT q, {} requests, {} calls ({} failed)",
                r.topology_label,
                r.backend,
                r.aggregation,
                r.user_queries,
                r.mct_queries,
                r.mct_requests,
                r.engine_calls,
                r.failed_calls,
            );
            println!(
                "wall {:.2} s ({:.1} k q/s) | model kernel {:.2} ms | p90 uq latency {:.1} ms",
                r.wall_ms / 1e3,
                r.wall_qps / 1e3,
                r.modeled_kernel_us / 1e3,
                r.uq_latency_p90_ms
            );
            println!(
                "aggregation {:.2} req/call | mct request p50/p90 {:.0}/{:.0} µs | router queue mean {:.2} max {} | busy worker {:.0} % kernel {:.0} %",
                r.mean_aggregation,
                r.mct_req_p50_us,
                r.mct_req_p90_us,
                r.mean_router_queue,
                r.max_router_queue,
                r.worker_busy_frac * 100.0,
                r.kernel_busy_frac * 100.0,
            );
            if r.offered_qps > 0.0 {
                println!(
                    "open loop: offered {:.1} k q/s vs achieved {:.1} k q/s",
                    r.offered_qps / 1e3,
                    r.wall_qps / 1e3
                );
            }
            if r.cache_lookups > 0 {
                println!(
                    "hot-connection cache: {}/{} hits ({:.1} %)",
                    r.cache_hits,
                    r.cache_lookups,
                    r.cache_hit_rate() * 100.0
                );
            }
        }
        "fleet" if args.get("--autoscale").is_some() => {
            // Control-plane DES: heterogeneous classes, diurnal load,
            // autoscaling, optional fault injection. Synthetic arrivals —
            // no world compilation needed.
            let policy = args.get("--autoscale").unwrap().to_string();
            let seed = args.u64("--seed", 1);
            let batch = args.usize("--batch", 2_048);
            let requests = args.usize("--requests", 1_500);
            let o = Overheads::default();
            let fpga = SimClass::calibrated(
                NodeClass::fpga_f1(0.0),
                SimNodeSpec::v2_cloud(args.usize("--feeders", 2)),
                &o,
                batch,
            );
            let cpu =
                SimClass::calibrated(NodeClass::cpu_c5(0.0), SimNodeSpec::cpu(2, 2.0), &o, batch);
            let classes =
                if args.flag("--hetero") { vec![fpga.clone(), cpu] } else { vec![fpga.clone()] };
            let cap_rps = fpga.class.capacity_qps / batch as f64;
            let default_period = requests as f64 / cap_rps;
            let schedule = match args.get("--profile") {
                None => RateSchedule::diurnal(cap_rps, 0.8 * cap_rps, default_period),
                Some(p) => {
                    let parts: Vec<&str> = p.split(':').collect();
                    match parts.as_slice() {
                        ["const", r] => RateSchedule::constant(r.parse()?),
                        ["diurnal", b, a, per] => {
                            RateSchedule::diurnal(b.parse()?, a.parse()?, per.parse()?)
                        }
                        _ => anyhow::bail!(
                            "bad --profile {p:?} (diurnal:BASE:AMP:PERIOD_S | const:RPS)"
                        ),
                    }
                }
            };
            let arrivals = scheduled_sim_arrivals(seed, &schedule, batch, requests, 16, 0.9, 0);
            let span_us = arrivals.last().map(|a| a.at_us).unwrap_or(1.0);
            let tick_us = args.f64("--tick-us", span_us / 25.0);
            let initial = args.usize("--nodes", 1);
            let max_nodes = args.usize("--max", 6);
            anyhow::ensure!(
                initial >= 1 && initial <= max_nodes,
                "--nodes {initial} must be between 1 and --max {max_nodes}"
            );
            let mut cfg = FleetSimConfig::new(classes, vec![0; initial])
                .with_control(tick_us, tick_us / 2.0)
                .with_sla(args.f64("--sla", 20_000.0))
                .with_bounds(1, max_nodes)
                .with_profile_label(schedule.label());
            let faults = faults_from_args(&args, seed, initial, span_us, 1_000.0)?;
            if !faults.is_empty() {
                cfg = cfg.with_faults(faults);
            }
            let mut scaler: Box<dyn Autoscaler> = match policy.as_str() {
                "static" => Box::new(StaticFleet),
                "reactive" => Box::new(ReactiveUtilisation::new(0)),
                "sla" => Box::new(SlaLatency::new(0)),
                "cost" => Box::new(CostAware::new()),
                p => anyhow::bail!("bad --autoscale {p:?} (static|reactive|sla|cost)"),
            };
            let r = simulate_fleet(&cfg, scaler.as_mut(), &arrivals);
            println!("{}", r.summary());
            print!("{}", r.timeline());
            for u in &r.usage {
                println!(
                    "  class {:<8} {:.2} node-h × {:.4} $/h = {:.4} $ (peak {} nodes)",
                    u.class, u.node_hours, u.hourly_usd, u.cost_usd, u.peak_nodes
                );
            }
        }
        "fleet" => {
            let (_, world, schema, rs) = setup(&args);
            let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
            let factory: BackendFactory = match args.get("--backend") {
                Some("cpu") => cpu_backend_factory(schema.clone(), rs.clone()),
                _ => native_backend_factory(nfa.clone(), model, 28, 64),
            };
            let mut node = PipelineConfig::new(Topology::new(
                args.usize("--p", 2),
                args.usize("--w", 1),
                args.usize("--k", 1),
                args.usize("--e", 4),
            ))
            .with_aggregation(AggregationPolicy::DrainQueue);
            if let Some(cap) = args.get("--cache").and_then(|v| v.parse().ok()) {
                node = node.with_cache(cap);
            }
            let route = args
                .get("--route")
                .map(|s| {
                    RoutePolicy::parse(s)
                        .ok_or_else(|| {
                            anyhow::anyhow!("bad --route {s:?} (rr|jsq|jsq2|jsqd:N|shard)")
                        })
                })
                .transpose()?
                .unwrap_or(RoutePolicy::RoundRobin);
            let admission = if let Some(cap) = args.get("--cap").and_then(|v| v.parse().ok()) {
                AdmissionPolicy::QueueCap(cap)
            } else if let Some(sla) = args.get("--sla").and_then(|v| v.parse().ok()) {
                AdmissionPolicy::SlaP90 { sla_us: sla }
            } else {
                AdmissionPolicy::Open
            };
            let nodes = args.usize("--nodes", 4);
            let feeders = node.topology.workers.max(1);
            let cluster_cfg = ClusterConfig::new(nodes, node)
                .with_route(route)
                .with_admission(admission);
            let seed = args.u64("--seed", 1);
            let rate = args.f64("--rate", 50_000.0);
            let batch = args.usize("--batch", 256);
            let requests = args.usize("--requests", 1_000);
            let span_us = requests as f64 / rate * 1e6;
            let faults = faults_from_args(&args, seed, nodes, span_us, 2_000.0)?;
            let res = resilience_from_args(&args);
            if !res.is_none() {
                // Client-side resilience lives in the front door: run the
                // same fleet behind the event reactor, one batch per
                // session at the same request rate. The door executes the
                // fault plan (kills and gray windows) itself, so the
                // cluster configs stay fault-free here — setting both
                // would apply gray degradation twice.
                let schedule = RateSchedule::constant(rate);
                let plans = session_plans(
                    seed,
                    &schedule,
                    requests,
                    1,
                    batch,
                    0.0,
                    world.airports.len(),
                );
                let mut fd = FrontdoorConfig::event(2, BackpressurePolicy::Window { window: 2 })
                    .with_resilience(res);
                let flight = trace_from_args(&args);
                if let Some((_, spec)) = &flight {
                    fd = fd.with_trace(*spec);
                }
                let real =
                    run_frontdoor(cluster_cfg, factory, &world, seed, &plans, &fd, &faults)?;
                println!("real: {}", real.summary());
                if let Some((path, _)) = &flight {
                    export_trace(path, &real.trace)?;
                }
                let sim_cfg = ClusterSimConfig::v2_cloud(nodes, feeders)
                    .with_route(route)
                    .with_admission(admission);
                let sim = sim_frontdoor(
                    &FrontdoorSimConfig { cluster: sim_cfg, frontdoor: fd, faults },
                    &plans,
                );
                println!("sim : {}", sim.summary());
                return Ok(());
            }
            anyhow::ensure!(
                faults.kills().is_empty(),
                "kill faults in plain `fleet` need --autoscale (the control-plane DES owns \
                 liveness) or a resilience flag (front-door run); gray specs apply in place"
            );
            anyhow::ensure!(
                trace_from_args(&args).is_none(),
                "--trace in `fleet` needs a resilience flag (the flight recorder hooks the \
                 front-door run) — add e.g. --retry, or use `frontdoor`"
            );
            // The same seeded stream through both realisations; gray
            // windows degrade the cluster layers in place.
            let mut src = PoissonSource::new(&world, seed, rate, batch, requests);
            let real =
                Cluster::new(cluster_cfg.with_faults(faults.clone()), factory).run(&mut src)?;
            println!("real: {}", real.summary());
            let sim_cfg = ClusterSimConfig::v2_cloud(nodes, feeders)
                .with_route(route)
                .with_admission(admission)
                .with_faults(faults);
            let mut src = PoissonSource::new(&world, seed, rate, batch, requests);
            let arrivals = erbium_search::cluster::sim::sim_arrivals(&mut src, false);
            let sim = simulate_cluster(&sim_cfg, &arrivals);
            println!("sim : {}", sim.summary());
            for (i, nr) in real.per_node.iter().enumerate() {
                println!(
                    "  node {i} [{}/{}]: {} req, p90 {:.0} µs, agg {:.2}, cache {:.1} %",
                    nr.class,
                    nr.backend,
                    nr.completed_requests,
                    nr.req_p90_us,
                    nr.mean_aggregation,
                    nr.cache_hit_rate * 100.0
                );
            }
        }
        "frontdoor" => {
            // The event-driven session door in front of the cluster —
            // real poll-loop reactor by default, DES twin with --des,
            // thread-per-session baseline with --baseline.
            let sessions = args.usize("--sessions", 64);
            let batches = args.usize("--batches", 8);
            let batch = args.usize("--batch", 16);
            let window = args.usize("--window", 4);
            let pending = args.usize("--pending", 2 * window);
            let policy = match args.get("--backpressure") {
                None | Some("window") => BackpressurePolicy::Window { window },
                Some("none") => BackpressurePolicy::None,
                Some("socket") => BackpressurePolicy::SocketShed { window, pending_cap: pending },
                Some(p) => anyhow::bail!("bad --backpressure {p:?} (none|window|socket)"),
            };
            let mut fd = if args.flag("--baseline") {
                FrontdoorConfig::thread_per_session(args.usize("--threads", 16))
            } else {
                FrontdoorConfig::event(args.usize("--threads", 2), policy)
            }
            .with_resilience(resilience_from_args(&args));
            let flight = trace_from_args(&args);
            if let Some((_, spec)) = &flight {
                fd = fd.with_trace(*spec);
            }
            let seed = args.u64("--seed", 1);
            let rate = args.f64("--rate", 2_000.0);
            let nodes = args.usize("--nodes", 2);
            let admission = match args.get("--cap").and_then(|v| v.parse().ok()) {
                Some(cap) => AdmissionPolicy::QueueCap(cap),
                None => AdmissionPolicy::Open,
            };
            let span_us = sessions as f64 / rate * 1e6;
            let faults = faults_from_args(&args, seed, nodes, span_us, 2_000.0)?;
            let schedule = RateSchedule::constant(rate);
            let r = if args.flag("--des") {
                // Synthetic stations — the DES never materialises queries.
                let plans = session_plans(seed, &schedule, sessions, batches, batch, 0.0, 16);
                let cfg = FrontdoorSimConfig {
                    cluster: ClusterSimConfig::v2_cloud(nodes, 2)
                        .with_route(RoutePolicy::RoundRobin)
                        .with_admission(admission),
                    frontdoor: fd,
                    faults,
                };
                sim_frontdoor(&cfg, &plans)
            } else {
                let (_, world, schema, rs) = setup(&args);
                let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
                let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
                let factory: BackendFactory = native_backend_factory(nfa, model, 28, 64);
                let node = PipelineConfig::new(Topology::new(2, 1, 1, 4))
                    .with_aggregation(AggregationPolicy::DrainQueue);
                let cluster = ClusterConfig::new(nodes, node)
                    .with_route(RoutePolicy::RoundRobin)
                    .with_admission(admission);
                let plans = session_plans(
                    seed,
                    &schedule,
                    sessions,
                    batches,
                    batch,
                    0.0,
                    world.airports.len(),
                );
                run_frontdoor(cluster, factory, &world, seed, &plans, &fd, &faults)?
            };
            println!("{}", r.summary());
            for e in &r.fault_events {
                println!("{}", e.line());
            }
            if let Some((path, _)) = &flight {
                export_trace(path, &r.trace)?;
            }
        }
        "pool" => {
            // The disaggregated network-attached kernel pool (DES): M
            // feeders lease N kernels over a modelled link. Knobs:
            // --feeders M --kernels N --link-us L --link-gbps B
            // --lease fifo|pack[:<queries>[:<age_us>]] --dispatch-us D
            // --batch --rate --requests --seed.
            use erbium_search::costmodel::{dollars_per_mquery, pool_topology_hourly_usd};
            use erbium_search::pool::sim::{simulate_pool, PoolSimConfig};
            use erbium_search::pool::{LeasePolicy, LinkModel};
            let feeders = args.usize("--feeders", 10);
            let kernels = args.usize("--kernels", 3);
            let lease = match args.get("--lease") {
                Some(s) => LeasePolicy::parse(s).ok_or_else(|| {
                    anyhow::anyhow!("bad --lease {s:?} (fifo|pack|pack:<q>|pack:<q>:<age_us>)")
                })?,
                None => LeasePolicy::Fifo,
            };
            let default_link = LinkModel::tor_10g();
            let link = LinkModel {
                hop_us: args.f64("--link-us", default_link.hop_us),
                gbps: args.f64("--link-gbps", default_link.gbps),
                switch_gbps: default_link.switch_gbps,
            };
            let batch = args.usize("--batch", 16_384);
            let requests = args.usize("--requests", 400);
            let seed = args.u64("--seed", 0xB007);
            let cfg = PoolSimConfig::v2_pool(feeders, kernels)
                .with_lease(lease)
                .with_link(link)
                .with_seed(seed)
                .with_dispatch_us(args.f64("--dispatch-us", 0.0));
            let ceiling = cfg.ceiling_qps(batch);
            // Default drive: 2× the model ceiling, i.e. saturation —
            // goodput then reads as the topology's capacity.
            let rate = args.f64("--rate", 2.0 * ceiling / batch as f64);
            let arrivals = erbium_search::cluster::sim::poisson_sim_arrivals(
                seed ^ 0xFEED,
                rate,
                batch,
                requests,
                1,
                0.0,
                0,
            );
            let r = simulate_pool(&cfg, &arrivals);
            println!("{}", r.summary());
            let hourly = pool_topology_hourly_usd(feeders, kernels);
            println!(
                "model ceiling {:.2} M q/s | rack-density fleet {hourly:.3} $/h → \
                 {:.2} µ$/Mquery at measured goodput",
                ceiling / 1e6,
                dollars_per_mquery(hourly, r.goodput_qps) * 1e6
            );
        }
        "costs" => {
            use erbium_search::costmodel as cm;
            for (title, rows) in [("Table 2", cm::table2()), ("Table 3", cm::table3())] {
                println!("\n{title}");
                for r in rows {
                    println!(
                        "  {:<55} {:<18} ×{:<5} {}",
                        r.deployment,
                        r.element.name,
                        r.units,
                        r.total_label()
                    );
                }
            }
            // Fleet provisioning, derived from (measured or modeled) node
            // saturation rather than transcribed §6.1 constants.
            // Prefer the measured hot-path trajectory (BENCH_hotpath.json)
            // over the analytic datapath model when an artifact is around.
            let node_qps = args.f64("--node-qps", cm::default_node_qps());
            let target = cm::fleet_mct_demand_qps(args.f64("--uqps", cm::DEFAULT_UQ_PER_S));
            let reduced = cm::freed_server_count(cm::DE_SERVERS);
            println!(
                "\nfleet plans (target {:.1} M q/s, node {:.1} M q/s, {} freed servers):",
                target / 1e6,
                node_qps / 1e6,
                reduced
            );
            for elem in [cm::catalog::AWS_F1_2XL, cm::catalog::AZURE_NP10S] {
                let plan =
                    cm::plan_fleet(elem, target, node_qps, reduced * cm::DE_VCPUS);
                println!(
                    "  {:<12} ×{:<5} ({:?}-bound; {} for qps, {} for vCPUs; {:.1}×/server, {:.0} $/Mqps·yr)",
                    plan.element.name,
                    plan.units,
                    plan.bottleneck,
                    plan.units_for_throughput,
                    plan.units_for_cpu,
                    plan.multiplier_vs(reduced),
                    plan.dollars_per_mqps()
                );
            }
        }
        _ => {
            println!("erbium-search — see module docs; subcommands:");
            println!("  gen-rules | compile | query | replay | fleet | frontdoor | pool | costs");
            println!("run `cargo bench` for the paper's figures/tables,");
            println!("`cargo run --release --example e2e_search` for the end-to-end driver.");
        }
    }
    Ok(())
}
