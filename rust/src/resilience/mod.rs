//! Gray-failure resilience: deadlines, budgeted retries, hedged
//! requests, circuit breakers, and brown-out health scores.
//!
//! Everything in this module is *pure policy state* driven by an
//! explicit clock (`t_us`) and a seeded [`Rng`], so the real threaded
//! realisation (wall clock) and the DES (virtual clock) execute exactly
//! the same decision logic — only the clock and the scheduler differ.
//! The mechanisms compose as a ladder:
//!
//! * **Deadlines** live on the *accept clock*: a request that became
//!   ready at `ready_us` must complete by `ready_us + deadline_us` or it
//!   is counted `shed_deadline` — cancelled work is never `completed`.
//! * **[`RetryPolicy`]** re-issues failed calls with capped exponential
//!   backoff and decorrelated jitter, gated by a token-bucket
//!   [`RetryBudget`] so a brown-out cannot be amplified into a retry
//!   storm (retries are paid for by fresh first-attempt traffic).
//! * **[`HedgePolicy`]** duplicates a still-outstanding request to a
//!   second replica once it has been in flight longer than a tail
//!   trigger; the first copy to finish wins and is counted once.
//! * **[`CircuitBreaker`]** is per-replica: EWMA error-rate and
//!   latency-inflation signals drive closed → open → half-open, with
//!   seeded probe admission in half-open.
//! * **[`HealthScore`]** folds failed calls, deadline misses and
//!   service-time inflation into a per-replica brown-out weight in
//!   `(0, 1]` that routing composes with queue depths, plus a
//!   graceful-degradation ladder that fails a browning FPGA node's
//!   traffic over to a CPU-class replica before shedding it.

use crate::prng::Rng;

/// Capped exponential backoff with decorrelated jitter
/// (`sleep = min(cap, uniform(base, 3·prev))`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RetryPolicy {
    /// Total attempts including the first submission (≥ 1).
    pub max_attempts: u32,
    /// Lower bound of the first backoff interval, µs.
    pub base_us: f64,
    /// Backoff ceiling, µs.
    pub cap_us: f64,
}

impl RetryPolicy {
    pub fn new(max_attempts: u32, base_us: f64, cap_us: f64) -> Self {
        assert!(max_attempts >= 1 && base_us > 0.0 && cap_us >= base_us);
        Self { max_attempts, base_us, cap_us }
    }

    /// Next backoff given the previous one (pass `0.0` for the first
    /// retry). Decorrelated jitter keeps concurrent retriers spread out.
    pub fn backoff_us(&self, prev_us: f64, rng: &mut Rng) -> f64 {
        let hi = (prev_us.max(self.base_us) * 3.0).min(self.cap_us);
        self.base_us + rng.f64() * (hi - self.base_us).max(0.0)
    }
}

/// Token-bucket retry budget: each *first-attempt* request deposits
/// `ratio` tokens, each retry spends one. When the bucket is dry the
/// retry is refused — the request fails instead of joining a storm.
#[derive(Clone, Debug)]
pub struct RetryBudget {
    balance: f64,
    cap: f64,
    ratio: f64,
}

impl RetryBudget {
    pub fn new(ratio: f64, cap: f64) -> Self {
        assert!(ratio >= 0.0 && cap >= 1.0);
        // Start full so a fault in the first few requests can still retry.
        Self { balance: cap, cap, ratio }
    }

    /// Account one first-attempt request.
    pub fn deposit(&mut self) {
        self.balance = (self.balance + self.ratio).min(self.cap);
    }

    /// Try to pay for one retry.
    pub fn try_spend(&mut self) -> bool {
        if self.balance >= 1.0 {
            self.balance -= 1.0;
            true
        } else {
            false
        }
    }

    pub fn balance(&self) -> f64 {
        self.balance
    }
}

/// Tail-latency hedging: duplicate an outstanding request to a second
/// replica once it has been in flight for `trigger_factor ×` its
/// expected latency (a p9x proxy). Both realisations feed the trigger a
/// *fleet-wide* EWMA of winner latencies — deliberately not the routed
/// node's own estimate, which would learn a straggler's slowness as
/// normal and stop hedging exactly the replica that needs it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HedgePolicy {
    pub trigger_factor: f64,
}

impl HedgePolicy {
    pub fn new(trigger_factor: f64) -> Self {
        assert!(trigger_factor >= 1.0);
        Self { trigger_factor }
    }

    /// Hedge fire time relative to submission. `expected_latency_us` of
    /// zero means the caller has no estimate yet — never hedge blind.
    pub fn trigger_us(&self, expected_latency_us: f64) -> Option<f64> {
        if expected_latency_us > 0.0 {
            Some(self.trigger_factor * expected_latency_us)
        } else {
            None
        }
    }
}

/// Circuit-breaker thresholds. Latency trips compare the EWMA of
/// *depth-normalized* per-request latency against `latency_factor ×`
/// the smallest normalized latency ever observed on the replica (its
/// fault-free floor), so queueing under load does not false-trip.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakerConfig {
    /// Trip when the EWMA error rate exceeds this.
    pub error_threshold: f64,
    /// Trip when EWMA normalized latency exceeds `factor × floor`.
    pub latency_factor: f64,
    /// Cool-down in the open state before probing resumes, µs.
    pub open_us: f64,
    /// EWMA smoothing for both signals.
    pub alpha: f64,
    /// Probe admission probability while half-open.
    pub probe_p: f64,
    /// Minimum outcomes observed before the breaker may trip.
    pub min_observations: u32,
}

impl Default for BreakerConfig {
    fn default() -> Self {
        Self {
            error_threshold: 0.1,
            latency_factor: 8.0,
            open_us: 20_000.0,
            alpha: 0.15,
            probe_p: 0.2,
            min_observations: 8,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum BreakerState {
    Closed,
    Open,
    HalfOpen,
}

/// Bound on the per-breaker transition log: a breaker flapping open/
/// half-open/open every cool-down for an entire run stays well under
/// this; beyond it the oldest transitions are dropped (and counted),
/// keeping memory constant — the telemetry plane's bounding rule.
pub const TRANSITION_LOG_CAP: usize = 256;

/// One timestamped breaker state change, in the order it happened —
/// the open → half-open → close record end-state reporting loses.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BreakerTransition {
    pub t_us: f64,
    pub from: BreakerState,
    pub to: BreakerState,
}

/// A health score crossing the brown-out degrade threshold (in either
/// direction), timestamped on the caller's clock.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct HealthTransition {
    pub t_us: f64,
    /// `true` = crossed below [`BROWNOUT_DEGRADE_THRESHOLD`] (degraded),
    /// `false` = recovered above it.
    pub degraded: bool,
}

/// Per-replica breaker: closed → open on EWMA error/latency signals,
/// open → half-open after `open_us`, half-open admits seeded probes and
/// closes on the first probe success (re-opens on probe failure).
#[derive(Clone, Debug)]
pub struct CircuitBreaker {
    cfg: BreakerConfig,
    state: BreakerState,
    open_until_us: f64,
    err_ewma: f64,
    lat_ewma_us: f64,
    floor_us: f64,
    seen: u32,
    trips: usize,
    transitions: Vec<BreakerTransition>,
    transitions_dropped: usize,
}

impl CircuitBreaker {
    pub fn new(cfg: BreakerConfig) -> Self {
        Self {
            cfg,
            state: BreakerState::Closed,
            open_until_us: 0.0,
            err_ewma: 0.0,
            lat_ewma_us: 0.0,
            floor_us: f64::INFINITY,
            seen: 0,
            trips: 0,
            transitions: Vec::new(),
            transitions_dropped: 0,
        }
    }

    pub fn state(&self) -> BreakerState {
        self.state
    }

    pub fn trips(&self) -> usize {
        self.trips
    }

    /// The timestamped state-change log so far (bounded; see
    /// [`TRANSITION_LOG_CAP`]).
    pub fn transitions(&self) -> &[BreakerTransition] {
        &self.transitions
    }

    /// Transitions evicted from the bounded log (0 = the log is
    /// complete).
    pub fn transitions_dropped(&self) -> usize {
        self.transitions_dropped
    }

    /// Drain the transition log (telemetry pulls this at end of run so
    /// per-thread breakers feed the per-thread recorder without locks).
    pub fn take_transitions(&mut self) -> Vec<BreakerTransition> {
        std::mem::take(&mut self.transitions)
    }

    fn move_to(&mut self, t_us: f64, to: BreakerState) {
        if self.transitions.len() >= TRANSITION_LOG_CAP {
            self.transitions.remove(0);
            self.transitions_dropped += 1;
        }
        self.transitions.push(BreakerTransition { t_us, from: self.state, to });
        self.state = to;
    }

    /// Routing gate: may this replica receive a request at `t_us`?
    /// Open transitions to half-open once the cool-down has elapsed;
    /// half-open admits a seeded Bernoulli(probe_p) trickle.
    pub fn allows(&mut self, t_us: f64, rng: &mut Rng) -> bool {
        if self.state == BreakerState::Open {
            if t_us < self.open_until_us {
                return false;
            }
            self.move_to(t_us, BreakerState::HalfOpen);
        }
        match self.state {
            BreakerState::Closed => true,
            BreakerState::HalfOpen => rng.chance(self.cfg.probe_p),
            BreakerState::Open => unreachable!(),
        }
    }

    fn trip(&mut self, t_us: f64) {
        self.move_to(t_us, BreakerState::Open);
        self.open_until_us = t_us + self.cfg.open_us;
        self.trips += 1;
    }

    /// Feed one call outcome. `norm_latency_us` should be the
    /// per-request latency normalized by the replica's queue depth at
    /// completion (the same normalization the service estimator uses).
    pub fn on_outcome(&mut self, t_us: f64, ok: bool, norm_latency_us: f64) {
        self.seen += 1;
        let a = self.cfg.alpha;
        self.err_ewma += a * ((if ok { 0.0 } else { 1.0 }) - self.err_ewma);
        if norm_latency_us > 0.0 {
            if self.lat_ewma_us == 0.0 {
                self.lat_ewma_us = norm_latency_us;
            } else {
                self.lat_ewma_us += a * (norm_latency_us - self.lat_ewma_us);
            }
            if ok {
                self.floor_us = self.floor_us.min(norm_latency_us);
            }
        }
        match self.state {
            BreakerState::HalfOpen => {
                if ok {
                    // Probe succeeded: close and forget the bad spell so
                    // the error EWMA restarts from clean.
                    self.move_to(t_us, BreakerState::Closed);
                    self.err_ewma = 0.0;
                    self.lat_ewma_us = self.floor_us.min(self.lat_ewma_us);
                } else {
                    self.trip(t_us);
                }
            }
            BreakerState::Closed => {
                if self.seen >= self.cfg.min_observations && self.signals_bad() {
                    self.trip(t_us);
                }
            }
            BreakerState::Open => {}
        }
    }

    fn signals_bad(&self) -> bool {
        self.err_ewma > self.cfg.error_threshold
            || (self.floor_us.is_finite()
                && self.lat_ewma_us > self.cfg.latency_factor * self.floor_us)
    }
}

/// EWMA smoothing for [`HealthScore`].
pub const HEALTH_ALPHA: f64 = 0.15;
/// Brown-out weights never reach zero — a floored weight keeps the
/// replica routable (at heavy de-preference) so recovery is observable.
pub const HEALTH_FLOOR: f64 = 0.05;
/// An FPGA node whose health weight drops below this fails its traffic
/// over to a CPU-class replica (the graceful-degradation ladder).
pub const BROWNOUT_DEGRADE_THRESHOLD: f64 = 0.5;

/// Per-replica brown-out health: an EWMA over instantaneous outcome
/// scores — 0 for a failed call, 0.25 for a deadline miss, and
/// `floor/normalized-latency` for service-time inflation — yielding a
/// routing weight in `(0, 1]`.
#[derive(Clone, Copy, Debug)]
pub struct HealthScore {
    score: f64,
    floor_us: f64,
}

impl Default for HealthScore {
    fn default() -> Self {
        Self::new()
    }
}

impl HealthScore {
    pub fn new() -> Self {
        Self { score: 1.0, floor_us: f64::INFINITY }
    }

    /// Preset the fault-free latency floor (the DES knows it from the
    /// node spec; the real realisation tracks a running minimum).
    pub fn with_nominal(nominal_us: f64) -> Self {
        Self { score: 1.0, floor_us: nominal_us.max(1e-9) }
    }

    pub fn observe(&mut self, ok: bool, deadline_miss: bool, norm_latency_us: f64) {
        self.observe_at(f64::NAN, ok, deadline_miss, norm_latency_us);
    }

    /// Like [`HealthScore::observe`], but timestamped: returns the
    /// brown-out threshold crossing this observation caused, if any, so
    /// the caller can feed it to the flight recorder. `HealthScore`
    /// stays `Copy` (it lives by value behind the cluster's per-replica
    /// locks) — the log belongs to the caller, not the score.
    pub fn observe_at(
        &mut self,
        t_us: f64,
        ok: bool,
        deadline_miss: bool,
        norm_latency_us: f64,
    ) -> Option<HealthTransition> {
        let instant = if !ok {
            0.0
        } else if deadline_miss {
            0.25
        } else if norm_latency_us > 0.0 {
            self.floor_us = self.floor_us.min(norm_latency_us);
            (self.floor_us / norm_latency_us).clamp(0.0, 1.0)
        } else {
            1.0
        };
        let was_degraded = self.score < BROWNOUT_DEGRADE_THRESHOLD;
        self.score += HEALTH_ALPHA * (instant - self.score);
        let is_degraded = self.score < BROWNOUT_DEGRADE_THRESHOLD;
        if is_degraded != was_degraded && t_us.is_finite() {
            Some(HealthTransition { t_us, degraded: is_degraded })
        } else {
            None
        }
    }

    pub fn score(&self) -> f64 {
        self.score
    }

    /// Routing weight: health floored away from zero.
    pub fn weight(&self) -> f64 {
        self.score.max(HEALTH_FLOOR)
    }
}

/// The composed per-request resilience policy a front door runs with.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ResiliencePolicy {
    /// Accept-clock deadline per request (ready → complete), µs.
    pub deadline_us: Option<f64>,
    pub retry: Option<RetryPolicy>,
    /// Tokens deposited into the retry budget per first-attempt request.
    pub retry_budget_ratio: f64,
    pub hedge: Option<HedgePolicy>,
    pub breaker: Option<BreakerConfig>,
    /// Health-weighted routing plus the FPGA→CPU degradation ladder.
    pub brownout: bool,
}

impl Default for ResiliencePolicy {
    fn default() -> Self {
        Self::none()
    }
}

impl ResiliencePolicy {
    pub fn none() -> Self {
        Self {
            deadline_us: None,
            retry: None,
            retry_budget_ratio: 0.1,
            hedge: None,
            breaker: None,
            brownout: false,
        }
    }

    pub fn with_deadline(mut self, deadline_us: f64) -> Self {
        assert!(deadline_us > 0.0);
        self.deadline_us = Some(deadline_us);
        self
    }

    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = Some(retry);
        self
    }

    pub fn with_budget_ratio(mut self, ratio: f64) -> Self {
        self.retry_budget_ratio = ratio;
        self
    }

    pub fn with_hedge(mut self, hedge: HedgePolicy) -> Self {
        self.hedge = Some(hedge);
        self
    }

    pub fn with_breaker(mut self, breaker: BreakerConfig) -> Self {
        self.breaker = Some(breaker);
        self
    }

    pub fn with_brownout(mut self) -> Self {
        self.brownout = true;
        self
    }

    /// No mechanism active at all (deadline included).
    pub fn is_none(&self) -> bool {
        self.deadline_us.is_none()
            && self.retry.is_none()
            && self.hedge.is_none()
            && self.breaker.is_none()
            && !self.brownout
    }

    /// Has a request whose ready time is `ready_us` expired at `t_us`?
    pub fn expired(&self, ready_us: f64, t_us: f64) -> bool {
        match self.deadline_us {
            Some(d) => t_us > ready_us + d,
            None => false,
        }
    }

    pub fn budget(&self) -> RetryBudget {
        RetryBudget::new(self.retry_budget_ratio, 8.0)
    }

    /// The four-rung ladder `cross_validate_resilience_policies` ranks,
    /// scaled to a nominal per-request service time.
    pub fn ladder(service_us: f64) -> Vec<ResiliencePolicy> {
        let retry = RetryPolicy::new(3, 0.5 * service_us, 8.0 * service_us);
        let hedge = HedgePolicy::new(3.0);
        let breaker = BreakerConfig {
            open_us: 40.0 * service_us,
            ..BreakerConfig::default()
        };
        vec![
            Self::none(),
            Self::none().with_retry(retry).with_budget_ratio(0.5),
            Self::none().with_retry(retry).with_budget_ratio(0.5).with_hedge(hedge),
            Self::none()
                .with_retry(retry)
                .with_budget_ratio(0.5)
                .with_hedge(hedge)
                .with_breaker(breaker),
        ]
    }

    /// Mechanism label: `no-retry`, `retry`, `retry+hedge`,
    /// `retry+hedge+breaker`, … (deadline does not change the label).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.retry.is_some() {
            parts.push("retry");
        }
        if self.hedge.is_some() {
            parts.push("hedge");
        }
        if self.breaker.is_some() {
            parts.push("breaker");
        }
        if self.brownout {
            parts.push("brownout");
        }
        if parts.is_empty() {
            "no-retry".to_string()
        } else {
            parts.join("+")
        }
    }
}

/// Resilience counters shared by both realisations, embedded in
/// [`crate::frontdoor::FrontdoorCounters`]. `backend_requests` counts
/// *physical* submissions (first attempts + retries + hedges) so the
/// hedge amplification factor is measurable against logical load.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ResilienceCounters {
    pub retries: usize,
    pub retry_budget_exhausted: usize,
    pub hedges_issued: usize,
    pub hedge_wins: usize,
    pub breaker_rejections: usize,
    pub breaker_trips: usize,
    pub degraded_requests: usize,
    pub backend_requests: usize,
    pub gray_fault_windows: usize,
}

impl ResilienceCounters {
    pub fn merge(&mut self, other: &ResilienceCounters) {
        self.retries += other.retries;
        self.retry_budget_exhausted += other.retry_budget_exhausted;
        self.hedges_issued += other.hedges_issued;
        self.hedge_wins += other.hedge_wins;
        self.breaker_rejections += other.breaker_rejections;
        self.breaker_trips += other.breaker_trips;
        self.degraded_requests += other.degraded_requests;
        self.backend_requests += other.backend_requests;
        self.gray_fault_windows += other.gray_fault_windows;
    }

    pub fn any(&self) -> bool {
        *self != ResilienceCounters::default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backoff_is_capped_jittered_and_seeded() {
        let p = RetryPolicy::new(4, 100.0, 1_000.0);
        let mut rng = Rng::new(7);
        let mut prev = 0.0;
        for _ in 0..50 {
            let b = p.backoff_us(prev, &mut rng);
            assert!(b >= p.base_us && b <= p.cap_us, "backoff {b} out of [base, cap]");
            prev = b;
        }
        // Deterministic per seed.
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        assert_eq!(p.backoff_us(0.0, &mut a), p.backoff_us(0.0, &mut b));
    }

    #[test]
    fn retry_budget_refuses_when_dry_and_refills_from_traffic() {
        let mut budget = RetryBudget::new(0.5, 2.0);
        assert!(budget.try_spend());
        assert!(budget.try_spend());
        assert!(!budget.try_spend(), "bucket starts at cap=2, third spend must fail");
        budget.deposit();
        assert!(!budget.try_spend(), "0.5 tokens is not a whole retry");
        budget.deposit();
        assert!(budget.try_spend(), "two deposits buy one retry");
    }

    #[test]
    fn breaker_closed_to_open_on_error_ewma() {
        let cfg = BreakerConfig { min_observations: 4, ..BreakerConfig::default() };
        let mut br = CircuitBreaker::new(cfg);
        let mut rng = Rng::new(1);
        assert_eq!(br.state(), BreakerState::Closed);
        for i in 0..10 {
            assert!(br.allows(i as f64, &mut rng), "closed breaker admits everything");
            br.on_outcome(i as f64, i % 2 == 0, 100.0);
        }
        assert_eq!(br.state(), BreakerState::Open, "50% errors must trip a 10% threshold");
        assert_eq!(br.trips(), 1);
        assert!(!br.allows(11.0, &mut rng), "open breaker rejects before cool-down");
    }

    #[test]
    fn breaker_latency_inflation_trips_without_errors() {
        let cfg = BreakerConfig {
            min_observations: 4,
            latency_factor: 5.0,
            ..BreakerConfig::default()
        };
        let mut br = CircuitBreaker::new(cfg);
        // Establish a healthy floor, then a 10× straggler phase.
        for i in 0..6 {
            br.on_outcome(i as f64, true, 100.0);
        }
        assert_eq!(br.state(), BreakerState::Closed);
        for i in 6..40 {
            br.on_outcome(i as f64, true, 1_000.0);
        }
        assert_eq!(br.state(), BreakerState::Open, "sustained 10× inflation must trip 5×");
    }

    #[test]
    fn breaker_half_open_probe_and_close_cycle() {
        let cfg = BreakerConfig {
            min_observations: 2,
            open_us: 1_000.0,
            probe_p: 0.5,
            ..BreakerConfig::default()
        };
        let mut br = CircuitBreaker::new(cfg);
        for i in 0..6 {
            br.on_outcome(i as f64, false, 100.0);
        }
        assert_eq!(br.state(), BreakerState::Open);
        let mut rng = Rng::new(9);
        assert!(!br.allows(500.0, &mut rng), "still cooling down");
        // After cool-down: seeded probe admission — some draws pass,
        // some don't, but the state is now half-open either way.
        let admitted = (0..20).filter(|_| br.allows(2_000.0, &mut rng)).count();
        assert_eq!(br.state(), BreakerState::HalfOpen);
        assert!(admitted > 0 && admitted < 20, "probe_p=0.5 admits a strict subset: {admitted}");
        // Failed probe re-opens …
        br.on_outcome(2_100.0, false, 100.0);
        assert_eq!(br.state(), BreakerState::Open);
        assert_eq!(br.trips(), 2);
        // … and a successful probe after the next cool-down closes.
        assert!(!br.allows(2_500.0, &mut rng));
        while !br.allows(4_000.0, &mut rng) {}
        br.on_outcome(4_001.0, true, 100.0);
        assert_eq!(br.state(), BreakerState::Closed);
        assert!(br.allows(4_002.0, &mut rng), "closed again after probe success");
    }

    #[test]
    fn breaker_probe_admission_is_deterministic_per_seed() {
        let run = |seed: u64| {
            let cfg = BreakerConfig { min_observations: 1, ..BreakerConfig::default() };
            let mut br = CircuitBreaker::new(cfg);
            for i in 0..4 {
                br.on_outcome(i as f64, false, 50.0);
            }
            let mut rng = Rng::new(seed);
            (0..32).map(|_| br.allows(1e9, &mut rng)).collect::<Vec<_>>()
        };
        assert_eq!(run(77), run(77));
        assert_ne!(run(77), run(78), "different seeds draw different probe patterns");
    }

    #[test]
    fn health_score_sinks_on_faults_and_recovers() {
        let mut h = HealthScore::with_nominal(100.0);
        assert!((h.weight() - 1.0).abs() < 1e-12);
        for _ in 0..40 {
            h.observe(false, false, 100.0);
        }
        assert!(h.weight() < 0.1, "sustained failures brown the replica out: {}", h.score());
        assert!(h.weight() >= HEALTH_FLOOR, "weight never reaches zero");
        for _ in 0..60 {
            h.observe(true, false, 100.0);
        }
        assert!(h.score() > 0.9, "healthy traffic restores the score: {}", h.score());
    }

    #[test]
    fn health_score_sees_service_inflation() {
        let mut h = HealthScore::with_nominal(100.0);
        for _ in 0..60 {
            h.observe(true, false, 1_000.0);
        }
        assert!(
            h.score() < 0.2,
            "a 10× straggler must brown out on latency alone: {}",
            h.score()
        );
    }

    #[test]
    fn breaker_logs_timestamped_transitions() {
        let cfg = BreakerConfig {
            min_observations: 2,
            open_us: 1_000.0,
            probe_p: 1.0, // every half-open draw admits, for determinism
            ..BreakerConfig::default()
        };
        let mut br = CircuitBreaker::new(cfg);
        // Errors at t=0..4 trip the breaker; cool-down; probe fails at
        // t=2100 (re-open); cool-down; probe succeeds at t=4000 (close).
        for i in 0..4 {
            br.on_outcome(i as f64, false, 100.0);
        }
        let mut rng = Rng::new(3);
        assert!(br.allows(2_000.0, &mut rng));
        br.on_outcome(2_100.0, false, 100.0);
        assert!(br.allows(3_500.0, &mut rng));
        br.on_outcome(4_000.0, true, 100.0);
        assert_eq!(br.state(), BreakerState::Closed);

        let log = br.transitions();
        let states: Vec<(BreakerState, BreakerState)> =
            log.iter().map(|t| (t.from, t.to)).collect();
        assert_eq!(
            states,
            vec![
                (BreakerState::Closed, BreakerState::Open),
                (BreakerState::Open, BreakerState::HalfOpen),
                (BreakerState::HalfOpen, BreakerState::Open),
                (BreakerState::Open, BreakerState::HalfOpen),
                (BreakerState::HalfOpen, BreakerState::Closed),
            ],
            "full open → half-open → close cycle, in order: {log:?}"
        );
        assert!(
            log.windows(2).all(|w| w[0].t_us <= w[1].t_us),
            "timestamps are monotone: {log:?}"
        );
        assert_eq!(log[4].t_us, 4_000.0, "close stamped at the probe outcome");
        assert_eq!(br.transitions_dropped(), 0);
        // Draining empties the log without touching the state machine.
        let drained = br.take_transitions();
        assert_eq!(drained.len(), 5);
        assert!(br.transitions().is_empty());
        assert_eq!(br.state(), BreakerState::Closed);
    }

    #[test]
    fn breaker_transition_log_is_bounded() {
        let cfg = BreakerConfig {
            min_observations: 1,
            open_us: 10.0,
            probe_p: 1.0,
            ..BreakerConfig::default()
        };
        let mut br = CircuitBreaker::new(cfg);
        let mut rng = Rng::new(5);
        // Flap forever: each iteration is open → half-open → open.
        let mut t = 0.0;
        for _ in 0..TRANSITION_LOG_CAP {
            br.on_outcome(t, false, 50.0);
            t += 20.0;
            let _ = br.allows(t, &mut rng);
        }
        assert_eq!(br.transitions().len(), TRANSITION_LOG_CAP, "log capped");
        assert!(br.transitions_dropped() > 0, "overflow counted, not silent");
        // The log keeps the *newest* transitions.
        let last = br.transitions().last().unwrap();
        assert!(last.t_us >= t - 20.0);
    }

    #[test]
    fn health_score_reports_brownout_crossings() {
        let mut h = HealthScore::with_nominal(100.0);
        let mut crossings = Vec::new();
        let mut t = 0.0;
        // Sustained failures: exactly one degraded crossing on the way
        // down, one recovery on the way back up.
        for _ in 0..40 {
            t += 10.0;
            if let Some(c) = h.observe_at(t, false, false, 100.0) {
                crossings.push(c);
            }
        }
        for _ in 0..60 {
            t += 10.0;
            if let Some(c) = h.observe_at(t, true, false, 100.0) {
                crossings.push(c);
            }
        }
        assert_eq!(crossings.len(), 2, "one degrade + one recover: {crossings:?}");
        assert!(crossings[0].degraded && !crossings[1].degraded);
        assert!(crossings[0].t_us < crossings[1].t_us);
        // The untimestamped path never reports (NaN clock).
        let mut h2 = HealthScore::with_nominal(100.0);
        for _ in 0..40 {
            h2.observe(false, false, 100.0);
        }
        assert!(h2.score() < BROWNOUT_DEGRADE_THRESHOLD, "state still moves");
    }

    #[test]
    fn ladder_labels_and_deadline_expiry() {
        let rungs = ResiliencePolicy::ladder(250.0);
        let labels: Vec<String> = rungs.iter().map(|p| p.label()).collect();
        assert_eq!(labels, vec!["no-retry", "retry", "retry+hedge", "retry+hedge+breaker"]);
        let p = ResiliencePolicy::none().with_deadline(1_000.0);
        assert!(!p.expired(500.0, 1_400.0));
        assert!(p.expired(500.0, 1_500.1));
        assert!(!ResiliencePolicy::none().expired(0.0, f64::MAX));
        assert!(ResiliencePolicy::none().is_none() && !p.is_none());
    }
}
