//! # erbium-search
//!
//! Reproduction of *"From Research to Proof-of-Concept: Analysis of a
//! Deployment of FPGAs on a Commercial Search Engine"* (Maschi, Alonso,
//! Hock-Koon, Bondoux, Roy, Boudia, Casalino — ETH Zurich / Amadeus, 2021)
//! as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L1/L2 (build-time Python)** — the ERBIUM NFA evaluation engine as a
//!   Pallas kernel inside a JAX model, AOT-lowered to HLO text
//!   (`python/compile/`, artifacts in `artifacts/`).
//! * **L3 (this crate)** — everything around the accelerator: the rule
//!   standards and generator, the offline NFA compiler toolchain, the PJRT
//!   runtime, the [`backend`] match-backend layer (one evaluation surface
//!   over the ERBIUM engine and the optimised CPU baseline), the
//!   flight-search coordinator (injector → domain explorer → router → MCT
//!   wrapper → XRT model), the FPGA datapath cost model, Route Scoring, and
//!   the deployment cost model.
//!
//! See `DESIGN.md` (repo root) for the system inventory, the
//! backend/aggregation architecture and the dual-clock convention.

pub mod backend;
pub mod benchkit;
pub mod bits;
pub mod cluster;
pub mod controlplane;
pub mod coordinator;
pub mod costmodel;
pub mod cpu_baseline;
pub mod encoder;
pub mod erbium;
pub mod frontdoor;
pub mod nfa;
pub mod pool;
pub mod prng;
pub mod resilience;
pub mod routescoring;
pub mod rules;
pub mod runtime;
pub mod telemetry;
pub mod testing;
pub mod workload;
