//! PJRT runtime: load the AOT artifacts (`artifacts/*.hlo.txt`), compile
//! them on the PJRT CPU client, and execute batches from the L3 hot path.
//!
//! Python never runs here — the HLO text produced once by
//! `python/compile/aot.py` is the entire interface (see that module and
//! `/opt/xla-example/README.md` for why text, not serialized protos).
//!
//! The runtime plays the role of the paper's vendor runtime (XRT) at the
//! *functional* level: move a batch in, run the kernel, move results out.
//! Scheduling behaviour (§4.1 "XRT") is modelled separately in
//! [`crate::coordinator::overheads::XrtModel`].

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use anyhow::{anyhow, bail, Context, Result};

use crate::nfa::memory::NfaImage;

/// One artifact variant as listed in `artifacts/manifest.txt`.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArtifactSpec {
    pub name: String,
    pub batch: usize,
    pub s: usize,
    pub l: usize,
    pub file: String,
}

/// Parse `artifacts/manifest.txt` (lines: `name B S L file`).
pub fn read_manifest(dir: &Path) -> Result<Vec<ArtifactSpec>> {
    let path = dir.join("manifest.txt");
    let text = std::fs::read_to_string(&path)
        .with_context(|| format!("reading {path:?}; run `make artifacts` first"))?;
    let mut specs = Vec::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let f: Vec<&str> = line.split_whitespace().collect();
        if f.len() != 5 {
            bail!("malformed manifest line: {line:?}");
        }
        specs.push(ArtifactSpec {
            name: f[0].to_string(),
            batch: f[1].parse()?,
            s: f[2].parse()?,
            l: f[3].parse()?,
            file: f[4].to_string(),
        });
    }
    Ok(specs)
}

/// Results of one kernel execution over a batch.
#[derive(Debug, Clone)]
pub struct BatchOutput {
    /// Winning accept-state index per query (meaningful where matched).
    pub best: Vec<i32>,
    /// Winning precision weight (0 where unmatched).
    pub weight: Vec<f32>,
    /// Winning decision in minutes (0 where unmatched).
    pub decision: Vec<f32>,
    /// 1.0 where any accept state is active.
    pub matched: Vec<f32>,
}

/// An NFA image uploaded to the device once and reused across batches —
/// the analogue of ERBIUM's "loading the NFA data into the FPGA internal
/// memory" (§3.1 Host Executor).
pub struct DeviceImage {
    kinds: xla::PjRtBuffer,
    lo: xla::PjRtBuffer,
    hi: xla::PjRtBuffer,
    weights: xla::PjRtBuffer,
    decisions: xla::PjRtBuffer,
    /// Host-side accept metadata for winner resolution.
    pub rule_ids: Vec<u32>,
    pub station: Option<u32>,
    pub l: usize,
    pub s: usize,
}

fn upload_to(client: &xla::PjRtClient, img: &NfaImage) -> Result<DeviceImage> {
    let (l, s) = (img.l, img.s);
    let cube = [l, s, s];
    Ok(DeviceImage {
        kinds: client.buffer_from_host_buffer(&img.kinds, &cube, None)?,
        lo: client.buffer_from_host_buffer(&img.lo, &cube, None)?,
        hi: client.buffer_from_host_buffer(&img.hi, &cube, None)?,
        weights: client.buffer_from_host_buffer(&img.weights, &[s], None)?,
        decisions: client.buffer_from_host_buffer(&img.decisions, &[s], None)?,
        rule_ids: img.rule_ids.clone(),
        station: img.station,
        l,
        s,
    })
}

/// A compiled artifact variant ready to execute.
pub struct NfaExecutable {
    exe: xla::PjRtLoadedExecutable,
    pub spec: ArtifactSpec,
    client: xla::PjRtClient,
}

impl NfaExecutable {
    /// Upload an NFA image to the device (the image is client-scoped: it
    /// can be executed by any artifact variant of the same runtime).
    pub fn upload(&self, img: &NfaImage) -> Result<DeviceImage> {
        upload_to(&self.client, img)
    }

    /// Execute one batch of encoded queries (`queries.len() == B × L`,
    /// row-major) against an uploaded image.
    pub fn execute(&self, queries: &[i32], image: &DeviceImage) -> Result<BatchOutput> {
        let (b, l) = (self.spec.batch, self.spec.l);
        if queries.len() != b * l {
            bail!("query buffer {} != B×L = {}", queries.len(), b * l);
        }
        if image.l != l || image.s != self.spec.s {
            bail!("image ({}, {}) does not fit artifact {}", image.l, image.s, self.spec.name);
        }
        let qbuf = self.client.buffer_from_host_buffer(queries, &[b, l], None)?;
        let outs = self.exe.execute_b(&[
            &qbuf,
            &image.kinds,
            &image.lo,
            &image.hi,
            &image.weights,
            &image.decisions,
        ])?;
        let result = outs
            .first()
            .and_then(|d| d.first())
            .ok_or_else(|| anyhow!("no output buffer"))?
            .to_literal_sync()?;
        // aot.py lowers with return_tuple=True → a 4-tuple literal.
        let (best, weight, decision, matched) = result.to_tuple4()?;
        Ok(BatchOutput {
            best: best.to_vec::<i32>()?,
            weight: weight.to_vec::<f32>()?,
            decision: decision.to_vec::<f32>()?,
            matched: matched.to_vec::<f32>()?,
        })
    }
}

/// The PJRT runtime: one client, a cache of compiled artifact variants.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    specs: Vec<ArtifactSpec>,
    compiled: std::sync::Mutex<HashMap<String, Arc<NfaExecutable>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn cpu(artifact_dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = artifact_dir.as_ref().to_path_buf();
        let specs = read_manifest(&dir)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e}"))?;
        Ok(Runtime { client, dir, specs, compiled: std::sync::Mutex::new(HashMap::new()) })
    }

    /// Default artifact directory relative to the crate root.
    pub fn default_dir() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    /// Whether the AOT XLA artifacts are present. The single gate every
    /// XLA-dependent test, bench and example checks before touching PJRT,
    /// so `cargo test -q` stays green on a fresh checkout (no `artifacts/`).
    pub fn artifacts_available() -> bool {
        Runtime::default_dir().join("manifest.txt").exists()
    }

    /// [`Self::artifacts_available`], with the canonical skip message on
    /// stderr when artifacts are missing. Use as the guard in tests:
    /// `if !Runtime::require_artifacts("test_name") { return; }`.
    pub fn require_artifacts(what: &str) -> bool {
        if Runtime::artifacts_available() {
            return true;
        }
        eprintln!("SKIP {what}: XLA artifacts missing; run `make artifacts` to enable");
        false
    }

    pub fn specs(&self) -> &[ArtifactSpec] {
        &self.specs
    }

    /// Pick the smallest variant whose batch ≥ `batch_hint` (or the largest
    /// available), with matching `(s, l)`.
    pub fn pick_variant(&self, batch_hint: usize, s: usize, l: usize) -> Option<&ArtifactSpec> {
        let mut fitting: Vec<&ArtifactSpec> =
            self.specs.iter().filter(|v| v.s == s && v.l == l).collect();
        fitting.sort_by_key(|v| v.batch);
        fitting
            .iter()
            .find(|v| v.batch >= batch_hint)
            .copied()
            .or_else(|| fitting.last().copied())
    }

    /// Upload an NFA image once; reusable across all variants.
    pub fn upload_image(&self, img: &NfaImage) -> Result<DeviceImage> {
        upload_to(&self.client, img)
    }

    /// Load + compile an artifact by name (cached).
    pub fn load(&self, name: &str) -> Result<Arc<NfaExecutable>> {
        if let Some(e) = self.compiled.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let spec = self
            .specs
            .iter()
            .find(|s| s.name == name)
            .ok_or_else(|| anyhow!("artifact {name} not in manifest"))?
            .clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(|e| anyhow!("compiling {name}: {e}"))?;
        let wrapped = Arc::new(NfaExecutable { exe, spec, client: self.client.clone() });
        self.compiled.lock().unwrap().insert(name.to_string(), wrapped.clone());
        Ok(wrapped)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn have_artifacts() -> bool {
        Runtime::require_artifacts("runtime test")
    }

    #[test]
    fn manifest_parses() {
        if !have_artifacts() {
            return;
        }
        let specs = read_manifest(&Runtime::default_dir()).unwrap();
        assert!(!specs.is_empty());
        assert!(specs.iter().any(|s| s.batch == 256 && s.s == 64 && s.l == 28));
    }

    #[test]
    fn pick_variant_prefers_smallest_fitting() {
        if !have_artifacts() {
            return;
        }
        let rt = Runtime::cpu(Runtime::default_dir()).unwrap();
        let v = rt.pick_variant(100, 64, 28).unwrap();
        assert_eq!(v.batch, 256);
        let v = rt.pick_variant(300, 64, 28).unwrap();
        assert_eq!(v.batch, 1024);
        // Over the largest: take the largest (the engine chunks).
        let v = rt.pick_variant(1_000_000, 64, 28).unwrap();
        assert_eq!(v.batch, 1024);
    }
}
