//! The **flight recorder**: a dependency-free per-request stage-tracing
//! plane that both realisations feed identically. The paper's core
//! contribution is the *end-to-end decomposition* — knowing where each
//! millisecond goes between the CPU feeder, the queues, and the FPGA so
//! the §6.1 imbalance and the §4.3 aggregation effects become visible.
//! Endpoint aggregates ([`FrontdoorReport`](crate::frontdoor::FrontdoorReport))
//! tell you *that* goodput fell; the trace tells you *which stage* ate it.
//!
//! Design rules, in order of importance:
//!
//! 1. **Zero cost when off.** The hot paths are generic over [`Recorder`];
//!    the default [`NullRecorder`] monomorphises every `record` call to
//!    nothing. The determinism tests (bit-identical sim reports) hold
//!    because recording is side-effect-only: no RNG draws, no counter
//!    writes, no event reordering.
//! 2. **No hot-path locks.** Each event thread owns a [`RingRecorder`];
//!    rings are drained into one [`Trace`] at thread join, mirroring how
//!    per-thread [`FrontdoorCounters`](crate::frontdoor) merge.
//! 3. **Deterministic sampling keyed on request id.** 1-in-N sampling
//!    hashes the *request id* (not a counter, not a clock), so the sim
//!    and the real run sample the *same* requests and their stage
//!    decompositions are comparable request-for-request.
//! 4. **Explicit clocks.** Events are stamped on the clock each
//!    realisation already owns: the reactor's wall clock (µs since run
//!    start) or the DES virtual clock. The recorder never reads a clock
//!    itself.
//!
//! The lifecycle stream per request:
//! `Accepted → Admitted → AttemptStart → Routed → [NetSend] → Enqueued →
//! ExecStart → ExecEnd → [NetRecv] → (Completed | Shed | Lost)`, with
//! extra `AttemptStart{Retry|Hedge}`/`Routed`/`Enqueued`/`Exec*` groups
//! per resilience attempt; the bracketed network hops appear only on the
//! disaggregated pool topology ([`crate::pool`]).
//! Control events ([`StageEvent::Breaker`], [`StageEvent::Health`]) carry
//! the sentinel id [`CONTROL_ID`] and bypass sampling — state transitions
//! are rare and always worth keeping.
//!
//! On top of the raw stream: [`breakdown::StageBreakdown`] (time-in-stage
//! shares and the automatic bottleneck localiser) and [`chrome`] (a
//! Chrome-trace-event exporter; the output loads directly in Perfetto).

pub mod breakdown;
pub mod chrome;

pub use breakdown::{Bottleneck, ReplicaStats, StageBreakdown};
pub use chrome::{chrome_trace_json, write_chrome_trace};

use std::collections::VecDeque;

/// Sentinel id for control-plane events (breaker/health transitions,
/// which belong to a replica, not a request). Control events bypass
/// sampling: they are rare and always recorded.
pub const CONTROL_ID: u64 = u64::MAX;

/// Default per-thread ring capacity: enough for ~8k requests' full
/// lifecycles per thread, bounded regardless of run length.
pub const DEFAULT_RING_CAPACITY: usize = 65_536;

/// Which attempt a submission belongs to, in resilience-ladder terms.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttemptKind {
    Primary,
    Retry,
    Hedge,
}

/// Which shed lane a request died in — mirrors the conservation law's
/// three shed terms (`shed_socket`/`shed_queue`/`shed_deadline`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShedLane {
    Socket,
    Queue,
    Deadline,
}

/// Circuit-breaker phase, recorder vocabulary. The resilience layer owns
/// the real state machine; transitions are mapped into this mirror enum
/// when drained so telemetry stays foundational (no internal deps).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BreakerPhase {
    Closed,
    Open,
    HalfOpen,
}

impl BreakerPhase {
    pub fn label(&self) -> &'static str {
        match self {
            BreakerPhase::Closed => "closed",
            BreakerPhase::Open => "open",
            BreakerPhase::HalfOpen => "half-open",
        }
    }
}

impl From<crate::resilience::BreakerState> for BreakerPhase {
    fn from(s: crate::resilience::BreakerState) -> BreakerPhase {
        match s {
            crate::resilience::BreakerState::Closed => BreakerPhase::Closed,
            crate::resilience::BreakerState::Open => BreakerPhase::Open,
            crate::resilience::BreakerState::HalfOpen => BreakerPhase::HalfOpen,
        }
    }
}

/// One point in a request's lifecycle (or a control-plane transition).
///
/// Terminal events (`Completed`/`Shed`/`Lost`) and `Accepted` carry the
/// request's query count so lane totals — the conservation law — can be
/// re-derived exactly from an unsampled trace.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum StageEvent {
    /// The client had the work: session accepted, batch ready (the accept
    /// clock's zero for this request).
    Accepted { n_queries: usize },
    /// Passed the front-door ladder (window + pending cap) and is being
    /// handed to the cluster.
    Admitted,
    /// An attempt begins (primary submission, a retry, or a hedge copy).
    AttemptStart { kind: AttemptKind },
    /// The router picked a replica for this attempt.
    Routed { replica: usize },
    /// The encoded batch left the feeder onto the pool's network hop
    /// (`bytes` = encoded payload size). Only the disaggregated pool
    /// topology emits this; PCIe-attached paths go straight to `Enqueued`.
    NetSend { bytes: usize },
    /// The attempt entered the replica's queue.
    Enqueued { replica: usize },
    /// The replica started executing this attempt.
    ExecStart { replica: usize },
    /// The replica finished executing: `kernel_us` is the slice of the
    /// exec span spent in the accelerator kernel itself (0 for CPU
    /// backends), `ok` whether the backend call succeeded.
    ExecEnd { replica: usize, kernel_us: f64, ok: bool },
    /// The result batch arrived back over the pool's network hop
    /// (`bytes` = result payload size). Pool topology only, as `NetSend`.
    NetRecv { bytes: usize },
    /// Terminal: completed within deadline.
    Completed { n_queries: usize },
    /// Terminal: shed in `lane`.
    Shed { lane: ShedLane, n_queries: usize },
    /// Terminal: lost to a fault (failed with retries exhausted/disabled).
    Lost { n_queries: usize },
    /// Control: a circuit breaker changed state (id = [`CONTROL_ID`]).
    Breaker { replica: usize, from: BreakerPhase, to: BreakerPhase },
    /// Control: a replica's health score crossed the brown-out degrade
    /// threshold (id = [`CONTROL_ID`]).
    Health { replica: usize, degraded: bool },
}

impl StageEvent {
    /// Is this one of the three terminal lanes?
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            StageEvent::Completed { .. } | StageEvent::Shed { .. } | StageEvent::Lost { .. }
        )
    }

    /// Is this a control-plane event (replica-scoped, not request-scoped)?
    pub fn is_control(&self) -> bool {
        matches!(self, StageEvent::Breaker { .. } | StageEvent::Health { .. })
    }

    pub fn label(&self) -> &'static str {
        match self {
            StageEvent::Accepted { .. } => "accepted",
            StageEvent::Admitted => "admitted",
            StageEvent::AttemptStart { kind: AttemptKind::Primary } => "attempt:primary",
            StageEvent::AttemptStart { kind: AttemptKind::Retry } => "attempt:retry",
            StageEvent::AttemptStart { kind: AttemptKind::Hedge } => "attempt:hedge",
            StageEvent::Routed { .. } => "routed",
            StageEvent::NetSend { .. } => "net-send",
            StageEvent::Enqueued { .. } => "enqueued",
            StageEvent::ExecStart { .. } => "exec-start",
            StageEvent::ExecEnd { .. } => "exec-end",
            StageEvent::NetRecv { .. } => "net-recv",
            StageEvent::Completed { .. } => "completed",
            StageEvent::Shed { lane: ShedLane::Socket, .. } => "shed:socket",
            StageEvent::Shed { lane: ShedLane::Queue, .. } => "shed:queue",
            StageEvent::Shed { lane: ShedLane::Deadline, .. } => "shed:deadline",
            StageEvent::Lost { .. } => "lost",
            StageEvent::Breaker { .. } => "breaker",
            StageEvent::Health { .. } => "health",
        }
    }
}

/// One recorded event: realisation clock, request id, lifecycle point.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceEvent {
    pub t_us: f64,
    pub id: u64,
    pub ev: StageEvent,
}

/// Trace configuration, identical across realisations (Copy so it rides
/// inside the Copy `FrontdoorConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TraceSpec {
    /// Record 1 in `sample` requests (1 = every request). Sampling is
    /// keyed on a hash of the request id, so both realisations keep the
    /// same subset.
    pub sample: u32,
    /// Per-recorder ring capacity; the oldest events are overwritten
    /// (and counted in [`Trace::dropped`]) beyond it.
    pub capacity: usize,
}

impl TraceSpec {
    /// Record everything (sample 1, default capacity).
    pub fn full() -> TraceSpec {
        TraceSpec { sample: 1, capacity: DEFAULT_RING_CAPACITY }
    }

    /// Record 1 in `n` requests.
    pub fn sampled(n: u32) -> TraceSpec {
        TraceSpec { sample: n.max(1), capacity: DEFAULT_RING_CAPACITY }
    }

    pub fn with_capacity(mut self, capacity: usize) -> TraceSpec {
        self.capacity = capacity.max(1);
        self
    }

    /// Does this spec keep request `id`? Deterministic in `id` alone.
    #[inline]
    pub fn keeps(&self, id: u64) -> bool {
        self.sample <= 1 || id == CONTROL_ID || sample_hash(id) % self.sample as u64 == 0
    }
}

/// splitmix64 finalizer — a cheap, well-mixed hash so sampling is
/// insensitive to request-id structure (sequential batch indices,
/// session<<32 packing). The one definition lives in [`crate::prng`];
/// the pool's lease scheduler shares it for tie-breaking.
#[inline]
pub fn sample_hash(x: u64) -> u64 {
    crate::prng::mix64(x)
}

/// The recording surface both realisations call. Implementations must be
/// side-effect-only with respect to the caller: no clock reads, no RNG,
/// no shared state — so a recorded run is bit-identical to an unrecorded
/// one in everything but the trace.
pub trait Recorder {
    fn record(&mut self, t_us: f64, id: u64, ev: StageEvent);

    /// Drain this recorder into a trace (called at thread join / end of
    /// run). The default is the empty trace — what `NullRecorder` yields.
    fn into_trace(self) -> Trace
    where
        Self: Sized,
    {
        Trace::default()
    }
}

/// The zero-cost default: every `record` call monomorphises to nothing.
#[derive(Debug, Clone, Copy, Default)]
pub struct NullRecorder;

impl Recorder for NullRecorder {
    #[inline(always)]
    fn record(&mut self, _t_us: f64, _id: u64, _ev: StageEvent) {}
}

/// A per-thread fixed-capacity ring recorder: push is O(1), no locks, no
/// allocation after warm-up; when full, the oldest event is overwritten
/// and counted. Sampling filters whole requests (all-or-nothing per id),
/// so every kept request has its complete lifecycle in the ring.
#[derive(Debug, Clone)]
pub struct RingRecorder {
    spec: TraceSpec,
    ring: VecDeque<TraceEvent>,
    dropped: u64,
}

impl RingRecorder {
    pub fn new(spec: TraceSpec) -> RingRecorder {
        RingRecorder {
            spec,
            // Cap the eager allocation; the ring still grows to spec
            // capacity on demand.
            ring: VecDeque::with_capacity(spec.capacity.min(4096)),
            dropped: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.ring.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring.is_empty()
    }
}

impl Recorder for RingRecorder {
    #[inline]
    fn record(&mut self, t_us: f64, id: u64, ev: StageEvent) {
        if !self.spec.keeps(id) {
            return;
        }
        if self.ring.len() >= self.spec.capacity {
            self.ring.pop_front();
            self.dropped += 1;
        }
        self.ring.push_back(TraceEvent { t_us, id, ev });
    }

    fn into_trace(self) -> Trace {
        Trace { events: self.ring.into(), dropped: self.dropped, sample: self.spec.sample }
    }
}

/// Query totals per terminal lane, re-derived from a trace — the
/// conservation law's terms as the event stream saw them.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LaneCounts {
    pub accepted_queries: usize,
    pub completed_queries: usize,
    pub completed_requests: usize,
    pub shed_socket_queries: usize,
    pub shed_queue_queries: usize,
    pub shed_deadline_queries: usize,
    pub lost_queries: usize,
}

impl LaneCounts {
    /// Total queries across all terminal lanes — equals offered queries
    /// when the trace is unsampled and nothing was ring-dropped.
    pub fn terminal_queries(&self) -> usize {
        self.completed_queries
            + self.shed_socket_queries
            + self.shed_queue_queries
            + self.shed_deadline_queries
            + self.lost_queries
    }
}

/// A drained, merged event stream (plus how it was sampled/bounded).
#[derive(Debug, Clone, Default)]
pub struct Trace {
    pub events: Vec<TraceEvent>,
    /// Events overwritten by ring wrap-around (0 = the trace is complete
    /// with respect to its sampling).
    pub dropped: u64,
    /// The 1-in-N sampling this trace was recorded under (0 = no trace
    /// was requested; treat as empty).
    pub sample: u32,
}

impl Trace {
    pub fn len(&self) -> usize {
        self.events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }

    /// Is this trace a complete record (every request, nothing dropped)?
    /// Only then does lane reconciliation against a report hold exactly.
    pub fn is_complete(&self) -> bool {
        self.sample == 1 && self.dropped == 0
    }

    /// Fold another recorder's drained trace in (thread-join merge).
    pub fn merge(&mut self, other: Trace) {
        self.events.extend(other.events);
        self.dropped += other.dropped;
        self.sample = self.sample.max(other.sample);
    }

    /// Sort events by time (then id, then lifecycle order) — merged
    /// per-thread rings interleave arbitrarily until this runs.
    pub fn sort(&mut self) {
        self.events.sort_by(|a, b| {
            a.t_us
                .total_cmp(&b.t_us)
                .then_with(|| a.id.cmp(&b.id))
                .then_with(|| event_order(&a.ev).cmp(&event_order(&b.ev)))
        });
    }

    /// Re-derive the conservation-law lane totals from terminal events.
    pub fn lane_counts(&self) -> LaneCounts {
        let mut lanes = LaneCounts::default();
        for e in &self.events {
            match e.ev {
                StageEvent::Accepted { n_queries } => lanes.accepted_queries += n_queries,
                StageEvent::Completed { n_queries } => {
                    lanes.completed_queries += n_queries;
                    lanes.completed_requests += 1;
                }
                StageEvent::Shed { lane: ShedLane::Socket, n_queries } => {
                    lanes.shed_socket_queries += n_queries
                }
                StageEvent::Shed { lane: ShedLane::Queue, n_queries } => {
                    lanes.shed_queue_queries += n_queries
                }
                StageEvent::Shed { lane: ShedLane::Deadline, n_queries } => {
                    lanes.shed_deadline_queries += n_queries
                }
                StageEvent::Lost { n_queries } => lanes.lost_queries += n_queries,
                _ => {}
            }
        }
        lanes
    }

    /// Per-request terminal-event counts, for the exactly-one-terminal
    /// invariant: every request that appears in the trace must terminate
    /// exactly once. Returns `(id, terminals)` sorted by id.
    pub fn terminals_per_request(&self) -> Vec<(u64, usize)> {
        let mut ids: Vec<u64> = self
            .events
            .iter()
            .filter(|e| e.id != CONTROL_ID)
            .map(|e| e.id)
            .collect();
        ids.sort_unstable();
        ids.dedup();
        let mut counts: Vec<(u64, usize)> = ids.into_iter().map(|id| (id, 0)).collect();
        for e in &self.events {
            if e.id == CONTROL_ID || !e.ev.is_terminal() {
                continue;
            }
            if let Ok(i) = counts.binary_search_by_key(&e.id, |&(id, _)| id) {
                counts[i].1 += 1;
            }
        }
        counts
    }
}

/// Lifecycle ordering for same-timestamp same-request ties (the DES
/// stamps several lifecycle points at one virtual instant).
fn event_order(ev: &StageEvent) -> u8 {
    match ev {
        StageEvent::Accepted { .. } => 0,
        StageEvent::Admitted => 1,
        StageEvent::AttemptStart { .. } => 2,
        StageEvent::Routed { .. } => 3,
        StageEvent::NetSend { .. } => 4,
        StageEvent::Enqueued { .. } => 5,
        StageEvent::ExecStart { .. } => 6,
        StageEvent::ExecEnd { .. } => 7,
        StageEvent::NetRecv { .. } => 8,
        StageEvent::Completed { .. } | StageEvent::Shed { .. } | StageEvent::Lost { .. } => 9,
        StageEvent::Breaker { .. } | StageEvent::Health { .. } => 10,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lifecycle(rec: &mut impl Recorder, id: u64, t0: f64, n: usize) {
        rec.record(t0, id, StageEvent::Accepted { n_queries: n });
        rec.record(t0 + 1.0, id, StageEvent::Admitted);
        rec.record(t0 + 1.0, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(t0 + 1.0, id, StageEvent::Routed { replica: 0 });
        rec.record(t0 + 1.0, id, StageEvent::Enqueued { replica: 0 });
        rec.record(t0 + 5.0, id, StageEvent::ExecStart { replica: 0 });
        rec.record(t0 + 15.0, id, StageEvent::ExecEnd { replica: 0, kernel_us: 6.0, ok: true });
        rec.record(t0 + 15.0, id, StageEvent::Completed { n_queries: n });
    }

    #[test]
    fn null_recorder_yields_the_empty_trace() {
        let mut rec = NullRecorder;
        lifecycle(&mut rec, 7, 0.0, 16);
        let t = rec.into_trace();
        assert!(t.is_empty());
        assert_eq!(t.sample, 0, "no trace was requested");
    }

    #[test]
    fn ring_records_full_lifecycles_and_reconciles_lanes() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        for id in 0..10u64 {
            lifecycle(&mut rec, id, id as f64 * 100.0, 16);
        }
        rec.record(1e4, 99, StageEvent::Shed { lane: ShedLane::Queue, n_queries: 16 });
        rec.record(1e4, 100, StageEvent::Lost { n_queries: 16 });
        let t = rec.into_trace();
        assert!(t.is_complete());
        let lanes = t.lane_counts();
        assert_eq!(lanes.completed_queries, 160);
        assert_eq!(lanes.completed_requests, 10);
        assert_eq!(lanes.shed_queue_queries, 16);
        assert_eq!(lanes.lost_queries, 16);
        assert_eq!(lanes.terminal_queries(), 192);
        for (id, terms) in t.terminals_per_request() {
            assert_eq!(terms, 1, "request {id} must terminate exactly once");
        }
    }

    #[test]
    fn ring_overwrites_oldest_beyond_capacity() {
        let mut rec = RingRecorder::new(TraceSpec::full().with_capacity(8));
        for id in 0..4u64 {
            lifecycle(&mut rec, id, id as f64, 1); // 8 events each
        }
        let t = rec.into_trace();
        assert_eq!(t.len(), 8, "ring holds exactly its capacity");
        assert_eq!(t.dropped, 24, "three full lifecycles were overwritten");
        assert!(!t.is_complete());
        assert!(t.events.iter().all(|e| e.id == 3), "only the newest request survives");
    }

    #[test]
    fn sampling_is_deterministic_and_keyed_on_id() {
        let spec = TraceSpec::sampled(4);
        // The kept subset is a pure function of the id — two recorders
        // (sim and real) keep exactly the same requests.
        let kept_a: Vec<u64> = (0..1000).filter(|&id| spec.keeps(id)).collect();
        let kept_b: Vec<u64> = (0..1000).filter(|&id| spec.keeps(id)).collect();
        assert_eq!(kept_a, kept_b);
        // Roughly 1-in-4 (hash-spread, not exact).
        assert!(
            kept_a.len() > 150 && kept_a.len() < 350,
            "1-in-4 of 1000 ≈ 250, got {}",
            kept_a.len()
        );
        // Sampling is all-or-nothing per request: a sampled-out id leaves
        // zero events, a sampled-in id leaves its full lifecycle.
        let mut rec = RingRecorder::new(spec);
        for id in 0..1000u64 {
            lifecycle(&mut rec, id, id as f64, 1);
        }
        let t = rec.into_trace();
        assert_eq!(t.len(), kept_a.len() * 8);
        // Control events bypass sampling.
        let mut rec = RingRecorder::new(TraceSpec::sampled(1_000_000));
        rec.record(
            1.0,
            CONTROL_ID,
            StageEvent::Breaker {
                replica: 0,
                from: BreakerPhase::Closed,
                to: BreakerPhase::Open,
            },
        );
        assert_eq!(rec.into_trace().len(), 1);
    }

    #[test]
    fn merge_and_sort_interleave_thread_rings() {
        let mut a = RingRecorder::new(TraceSpec::full());
        let mut b = RingRecorder::new(TraceSpec::full());
        lifecycle(&mut a, 1, 50.0, 4);
        lifecycle(&mut b, 2, 0.0, 4);
        let mut t = a.into_trace();
        t.merge(b.into_trace());
        t.sort();
        assert_eq!(t.len(), 16);
        assert!(t.events.windows(2).all(|w| w[0].t_us <= w[1].t_us), "time-ordered");
        assert_eq!(t.events[0].id, 2, "thread b's request came first");
        // Same-instant lifecycle points keep their logical order.
        let id2: Vec<&'static str> =
            t.events.iter().filter(|e| e.id == 2).map(|e| e.ev.label()).collect();
        assert_eq!(
            id2,
            vec![
                "accepted",
                "admitted",
                "attempt:primary",
                "routed",
                "enqueued",
                "exec-start",
                "exec-end",
                "completed"
            ]
        );
    }
}
