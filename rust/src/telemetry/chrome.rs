//! **Chrome-trace-event exporter**: turn a drained [`Trace`] into the
//! Trace Event Format JSON that Perfetto (ui.perfetto.dev) and
//! `chrome://tracing` load directly — no serde, via [`benchkit::Json`].
//!
//! Layout:
//!
//! * **pid 0 ("requests")** — one complete (`ph:"X"`) span per request
//!   from `Accepted` to its terminal, named by its terminal lane
//!   (`completed`/`shed:*`/`lost`), one track (`tid`) per request.
//! * **pid 1+r ("replica r")** — one `X` span per exec
//!   (`ExecStart→ExecEnd`), carrying the kernel slice and backend
//!   outcome in `args`; same per-request `tid` so a request's exec spans
//!   line up under its lifecycle span.
//! * **pid [`NETWORK_PID`] ("network")** — the pool topology's hop lane:
//!   one `X` span per forward hop (`NetSend→Enqueued`) named `net:send`
//!   and per reply hop (`ExecEnd→NetRecv`) named `net:recv`, with the
//!   payload bytes in `args`; same per-request `tid`. Emitted (with its
//!   metadata row) only when the trace contains `Net*` events, so
//!   PCIe-attached exports are unchanged.
//! * **instants (`ph:"i"`)** — terminals without an accept (socket sheds
//!   refused before acceptance) on pid 0, and breaker/health transitions
//!   on their replica's pid.
//!
//! Timestamps pass through unscaled: both realisations already stamp
//! events in µs, the format's native unit.

use super::{StageEvent, Trace, CONTROL_ID};
use crate::benchkit::Json;

/// The network hop's process row — far above any plausible `1 + replica`
/// pid so the lanes can never collide.
pub const NETWORK_PID: i64 = 9_999;

/// Build the Trace Event Format document for a trace.
pub fn chrome_trace_json(trace: &Trace) -> Json {
    let mut sorted = trace.clone();
    sorted.sort();

    let mut out: Vec<Json> = Vec::new();
    // Process-name metadata rows.
    let mut replicas: Vec<usize> = sorted
        .events
        .iter()
        .filter_map(|e| match e.ev {
            StageEvent::Routed { replica }
            | StageEvent::Enqueued { replica }
            | StageEvent::ExecStart { replica }
            | StageEvent::ExecEnd { replica, .. }
            | StageEvent::Breaker { replica, .. }
            | StageEvent::Health { replica, .. } => Some(replica),
            _ => None,
        })
        .collect();
    replicas.sort_unstable();
    replicas.dedup();
    out.push(meta_process(0, "requests"));
    for &r in &replicas {
        out.push(meta_process(1 + r as i64, &format!("replica {r}")));
    }
    let has_net = sorted
        .events
        .iter()
        .any(|e| matches!(e.ev, StageEvent::NetSend { .. } | StageEvent::NetRecv { .. }));
    if has_net {
        out.push(meta_process(NETWORK_PID, "network"));
    }

    // Compact per-request track ids, in first-appearance order.
    let mut tids: Vec<u64> = Vec::new();
    let mut tid_of = |id: u64, tids: &mut Vec<u64>| -> i64 {
        match tids.iter().position(|&x| x == id) {
            Some(i) => i as i64,
            None => {
                tids.push(id);
                (tids.len() - 1) as i64
            }
        }
    };

    // Walk per request: accept time, open exec starts, open hops, terminal.
    let mut accept_at: Vec<(u64, f64, usize)> = Vec::new(); // (id, t, n)
    let mut open_exec: Vec<(u64, usize, f64)> = Vec::new(); // (id, replica, t_start)
    let mut open_send: Vec<(u64, f64, usize)> = Vec::new(); // (id, t, bytes)
    let mut last_exec_end: Vec<(u64, f64)> = Vec::new(); // (id, t) — reply hop start
    for e in &sorted.events {
        if e.id == CONTROL_ID {
            if let StageEvent::Breaker { replica, from, to } = e.ev {
                out.push(instant(
                    e.t_us,
                    1 + replica as i64,
                    0,
                    &format!("breaker {}→{}", from.label(), to.label()),
                ));
            } else if let StageEvent::Health { replica, degraded } = e.ev {
                let name = if degraded { "health: degraded" } else { "health: recovered" };
                out.push(instant(e.t_us, 1 + replica as i64, 0, name));
            }
            continue;
        }
        match e.ev {
            StageEvent::Accepted { n_queries } => accept_at.push((e.id, e.t_us, n_queries)),
            StageEvent::NetSend { bytes } => open_send.push((e.id, e.t_us, bytes)),
            StageEvent::Enqueued { .. } => {
                // Close the forward hop, if this request rode the pool.
                if let Some(i) = open_send.iter().position(|&(id, _, _)| id == e.id) {
                    let (_, t_send, bytes) = open_send.remove(i);
                    let tid = tid_of(e.id, &mut tids);
                    out.push(net_span("net:send", t_send, e.t_us, tid, e.id, bytes));
                }
            }
            StageEvent::NetRecv { bytes } => {
                // Pair with the latest exec end (the winning attempt).
                if let Some(i) = last_exec_end.iter().rposition(|&(id, _)| id == e.id) {
                    let (_, t_end) = last_exec_end.remove(i);
                    let tid = tid_of(e.id, &mut tids);
                    out.push(net_span("net:recv", t_end, e.t_us, tid, e.id, bytes));
                }
            }
            StageEvent::ExecStart { replica } => open_exec.push((e.id, replica, e.t_us)),
            StageEvent::ExecEnd { replica, kernel_us, ok } => {
                last_exec_end.push((e.id, e.t_us));
                if let Some(i) =
                    open_exec.iter().position(|&(id, r, _)| id == e.id && r == replica)
                {
                    let (_, _, t_start) = open_exec.remove(i);
                    let tid = tid_of(e.id, &mut tids);
                    out.push(Json::obj([
                        ("name", Json::Str("exec".to_string())),
                        ("ph", Json::Str("X".to_string())),
                        ("ts", Json::Num(t_start)),
                        ("dur", Json::Num((e.t_us - t_start).max(0.0))),
                        ("pid", Json::Int(1 + replica as i64)),
                        ("tid", Json::Int(tid)),
                        (
                            "args",
                            Json::obj([
                                ("id", Json::Int(e.id as i64)),
                                ("kernel_us", Json::Num(kernel_us)),
                                ("ok", Json::Bool(ok)),
                            ]),
                        ),
                    ]));
                }
            }
            ev if ev.is_terminal() => {
                let tid = tid_of(e.id, &mut tids);
                match accept_at.iter().position(|&(id, _, _)| id == e.id) {
                    Some(i) => {
                        let (_, t_accept, n) = accept_at.remove(i);
                        out.push(Json::obj([
                            ("name", Json::Str(ev.label().to_string())),
                            ("ph", Json::Str("X".to_string())),
                            ("ts", Json::Num(t_accept)),
                            ("dur", Json::Num((e.t_us - t_accept).max(0.0))),
                            ("pid", Json::Int(0)),
                            ("tid", Json::Int(tid)),
                            (
                                "args",
                                Json::obj([
                                    ("id", Json::Int(e.id as i64)),
                                    ("n_queries", Json::Int(n as i64)),
                                ]),
                            ),
                        ]));
                    }
                    // Refused before acceptance (socket shed): an instant.
                    None => out.push(instant(e.t_us, 0, tid, ev.label())),
                }
            }
            _ => {}
        }
    }

    Json::obj([
        ("traceEvents", Json::Arr(out)),
        ("displayTimeUnit", Json::Str("ms".to_string())),
        (
            "otherData",
            Json::obj([
                ("sample", Json::Int(trace.sample as i64)),
                ("dropped", Json::Int(trace.dropped as i64)),
                ("events", Json::Int(trace.events.len() as i64)),
            ]),
        ),
    ])
}

/// Write `trace` to `path` in Trace Event Format (open in Perfetto).
pub fn write_chrome_trace(path: &str, trace: &Trace) -> std::io::Result<()> {
    crate::benchkit::write_json(path, &chrome_trace_json(trace))
}

fn meta_process(pid: i64, name: &str) -> Json {
    Json::obj([
        ("name", Json::Str("process_name".to_string())),
        ("ph", Json::Str("M".to_string())),
        ("pid", Json::Int(pid)),
        ("tid", Json::Int(0)),
        ("args", Json::obj([("name", Json::Str(name.to_string()))])),
    ])
}

fn net_span(name: &str, t_start: f64, t_end: f64, tid: i64, id: u64, bytes: usize) -> Json {
    Json::obj([
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("X".to_string())),
        ("ts", Json::Num(t_start)),
        ("dur", Json::Num((t_end - t_start).max(0.0))),
        ("pid", Json::Int(NETWORK_PID)),
        ("tid", Json::Int(tid)),
        (
            "args",
            Json::obj([("id", Json::Int(id as i64)), ("bytes", Json::Int(bytes as i64))]),
        ),
    ])
}

fn instant(t_us: f64, pid: i64, tid: i64, name: &str) -> Json {
    Json::obj([
        ("name", Json::Str(name.to_string())),
        ("ph", Json::Str("i".to_string())),
        ("ts", Json::Num(t_us)),
        ("pid", Json::Int(pid)),
        ("tid", Json::Int(tid)),
        ("s", Json::Str("p".to_string())),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{
        AttemptKind, BreakerPhase, Recorder, RingRecorder, ShedLane, TraceSpec,
    };

    #[test]
    fn export_produces_loadable_trace_event_json() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        let id = 42u64;
        rec.record(0.0, id, StageEvent::Accepted { n_queries: 8 });
        rec.record(1.0, id, StageEvent::Admitted);
        rec.record(1.0, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(1.0, id, StageEvent::Routed { replica: 1 });
        rec.record(1.0, id, StageEvent::Enqueued { replica: 1 });
        rec.record(4.0, id, StageEvent::ExecStart { replica: 1 });
        rec.record(9.0, id, StageEvent::ExecEnd { replica: 1, kernel_us: 3.0, ok: true });
        rec.record(9.0, id, StageEvent::Completed { n_queries: 8 });
        rec.record(2.0, 7, StageEvent::Shed { lane: ShedLane::Socket, n_queries: 8 });
        rec.record(
            5.0,
            CONTROL_ID,
            StageEvent::Breaker { replica: 1, from: BreakerPhase::Closed, to: BreakerPhase::Open },
        );
        let doc = chrome_trace_json(&rec.into_trace());

        // Round-trips through the benchkit parser (valid JSON).
        let text = doc.render();
        let back = Json::parse(&text).expect("exporter emits valid JSON");
        let events = match back.get("traceEvents") {
            Some(Json::Arr(xs)) => xs.clone(),
            other => panic!("traceEvents must be an array, got {other:?}"),
        };
        // 2 process metadata + request span + exec span + shed instant +
        // breaker instant.
        assert_eq!(events.len(), 6, "{text}");

        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("missing event {name} in {text}"))
        };
        let req = find("completed");
        assert_eq!(req.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(req.get("ts").and_then(Json::as_f64), Some(0.0));
        assert_eq!(req.get("dur").and_then(Json::as_f64), Some(9.0));
        assert_eq!(req.get("pid").and_then(Json::as_i64), Some(0));
        let exec = find("exec");
        assert_eq!(exec.get("pid").and_then(Json::as_i64), Some(2), "replica 1 → pid 2");
        assert_eq!(exec.get("dur").and_then(Json::as_f64), Some(5.0));
        assert_eq!(exec.path(&["args", "kernel_us"]).and_then(Json::as_f64), Some(3.0));
        assert_eq!(
            exec.get("tid").and_then(Json::as_i64),
            req.get("tid").and_then(Json::as_i64),
            "exec spans share the request's track"
        );
        let shed = find("shed:socket");
        assert_eq!(shed.get("ph").and_then(Json::as_str), Some("i"), "no accept → instant");
        let brk = find("breaker closed→open");
        assert_eq!(brk.get("pid").and_then(Json::as_i64), Some(2));
        // No Net events → no network lane.
        assert!(
            !events.iter().any(|e| e.get("pid").and_then(Json::as_i64) == Some(NETWORK_PID)),
            "PCIe-attached traces must not grow a network lane"
        );
    }

    #[test]
    fn pool_hops_get_their_own_network_lane() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        let id = 7u64;
        rec.record(0.0, id, StageEvent::Accepted { n_queries: 8 });
        rec.record(1.0, id, StageEvent::Admitted);
        rec.record(1.0, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(1.0, id, StageEvent::Routed { replica: 0 });
        rec.record(3.0, id, StageEvent::NetSend { bytes: 416 });
        rec.record(10.0, id, StageEvent::Enqueued { replica: 0 });
        rec.record(12.0, id, StageEvent::ExecStart { replica: 0 });
        rec.record(20.0, id, StageEvent::ExecEnd { replica: 0, kernel_us: 8.0, ok: true });
        rec.record(26.0, id, StageEvent::NetRecv { bytes: 64 });
        rec.record(26.0, id, StageEvent::Completed { n_queries: 8 });
        let doc = chrome_trace_json(&rec.into_trace());
        let text = doc.render();
        let back = Json::parse(&text).expect("exporter emits valid JSON");
        let events = match back.get("traceEvents") {
            Some(Json::Arr(xs)) => xs.clone(),
            other => panic!("traceEvents must be an array, got {other:?}"),
        };
        let find = |name: &str| {
            events
                .iter()
                .find(|e| e.get("name").and_then(Json::as_str) == Some(name))
                .unwrap_or_else(|| panic!("missing event {name} in {text}"))
        };
        // The lane announces itself and both hops are complete spans on it.
        let meta = events
            .iter()
            .find(|e| e.path(&["args", "name"]).and_then(Json::as_str) == Some("network"))
            .expect("network process metadata row");
        assert_eq!(meta.get("pid").and_then(Json::as_i64), Some(NETWORK_PID));
        let send = find("net:send");
        assert_eq!(send.get("ph").and_then(Json::as_str), Some("X"));
        assert_eq!(send.get("ts").and_then(Json::as_f64), Some(3.0));
        assert_eq!(send.get("dur").and_then(Json::as_f64), Some(7.0));
        assert_eq!(send.get("pid").and_then(Json::as_i64), Some(NETWORK_PID));
        assert_eq!(send.path(&["args", "bytes"]).and_then(Json::as_i64), Some(416));
        let recv = find("net:recv");
        assert_eq!(recv.get("ts").and_then(Json::as_f64), Some(20.0));
        assert_eq!(recv.get("dur").and_then(Json::as_f64), Some(6.0));
        assert_eq!(recv.path(&["args", "bytes"]).and_then(Json::as_i64), Some(64));
        // Hops ride the request's track so the lanes line up in Perfetto.
        let req = find("completed");
        assert_eq!(
            send.get("tid").and_then(Json::as_i64),
            req.get("tid").and_then(Json::as_i64)
        );
    }
}
