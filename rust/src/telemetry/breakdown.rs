//! **Stage breakdown + automatic bottleneck localisation** over a drained
//! [`Trace`]: the paper's manual where-does-the-millisecond-go analysis
//! (§6.1 feeder/kernel imbalance, §4.3 aggregation effects) turned into a
//! checked decomposition.
//!
//! Per completed request the lifecycle stream decomposes into four
//! additive stages on the accept clock:
//!
//! * **park** — `Accepted → first AttemptStart`: time waiting at the
//!   front door (session window, pending buffer, admission re-tries).
//! * **queue** — `Enqueued → ExecStart` of the *winning* attempt: time
//!   in the replica's queue (plus channel/router transit in the real
//!   realisation, which stamps ExecStart retroactively).
//! * **exec** — the winning attempt's `ExecStart → ExecEnd` span, with
//!   `kernel_us` inside it attributing the accelerator-kernel slice.
//! * **network** — pool-topology hops around the winning attempt:
//!   `NetSend → Enqueued` (forward: link latency + serialisation + any
//!   switch wait + dispatcher packing delay) plus `ExecEnd → NetRecv`
//!   (the result's way back). Zero on PCIe-attached traces, which emit
//!   no `Net*` events.
//! * **overhead** — the residual: failed attempts, retry backoff, hedge
//!   arming — everything the resilience ladder spent beyond the winner.
//!
//! Shares are time-weighted (`Σ stage / Σ total`), so a handful of
//! pathological requests can't be voted down by a crowd of fast ones.
//!
//! The localiser walks a fixed decision tree over the breakdown —
//! replica skew first (a gray straggler distorts every downstream
//! share), then upstream-vs-exec, then feeder-vs-kernel via wall-clock
//! kernel occupancy:
//!
//! 1. A replica whose mean exec span is ≥ [`STRAGGLER_FACTOR`]× the
//!    median of its peers (with enough samples) → [`Bottleneck::Replica`].
//! 2. The network share alone reaches [`NETWORK_DOMINANT`] → the pool's
//!    hop (link, switch, or dispatcher packing) is eating the latency:
//!    [`Bottleneck::Network`].
//! 3. Upstream shares (park + queue) dominate (≥ [`UPSTREAM_DOMINANT`]):
//!    replicas mostly idle → [`Bottleneck::Frontdoor`] (work is stuck at
//!    the door, not the backend); replicas busy but kernels idle
//!    (occupancy < [`KERNEL_IDLE`]) → [`Bottleneck::Feeder`] — the §6.1
//!    signature: queue grows upstream while the FPGA starves; otherwise
//!    → [`Bottleneck::Kernel`].
//! 4. Nothing dominates → [`Bottleneck::Balanced`].

use super::{AttemptKind, ShedLane, StageEvent, Trace, TraceEvent, CONTROL_ID};
use crate::coordinator::LogHistogram;

/// A replica is a straggler when its mean exec span is this many times
/// its peers' median (PR 7's gray slowdown factors are 8–10×; 3× keeps
/// margin on both sides).
pub const STRAGGLER_FACTOR: f64 = 3.0;
/// Minimum exec spans on a replica before its mean is trusted.
pub const MIN_REPLICA_SPANS: usize = 8;
/// Park + queue share at/above which the bottleneck is upstream of exec.
pub const UPSTREAM_DOMINANT: f64 = 0.5;
/// Mean replica busy fraction below which the backend counts as idle
/// (the door, not the replicas, is the constraint).
pub const NODE_IDLE: f64 = 0.35;
/// Kernel occupancy below which a busy replica is feeder-bound: the
/// CPU side is saturated while the accelerator waits for work.
pub const KERNEL_IDLE: f64 = 0.4;
/// Network share at/above which the pool hop itself is the verdict —
/// checked before the upstream split, since a slow link backs work up
/// into park/queue too.
pub const NETWORK_DOMINANT: f64 = 0.4;
/// Cap on stored queue-depth timeline points per replica (decimated
/// beyond this — the trace itself is already ring-bounded).
const DEPTH_TIMELINE_CAP: usize = 2048;

/// Where the pipeline's constraint sits, as localised from a trace.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Bottleneck {
    /// One replica is limping (gray straggler): its exec spans dwarf its
    /// peers'.
    Replica(usize),
    /// Work is stuck at the front door / admission while replicas idle.
    Frontdoor,
    /// The §6.1 weak-feeder regime: replicas busy, queues full upstream,
    /// but the accelerator kernels are starved by the CPU feed stage.
    Feeder,
    /// The pool's network hop (link latency, serialisation, switch wait,
    /// dispatcher packing delay) dominates request time.
    Network,
    /// The accelerator itself is the constraint: kernels saturated.
    Kernel,
    /// No single stage dominates.
    Balanced,
}

impl Bottleneck {
    pub fn label(&self) -> String {
        match self {
            Bottleneck::Replica(i) => format!("replica:{i}"),
            Bottleneck::Frontdoor => "frontdoor".to_string(),
            Bottleneck::Feeder => "feeder".to_string(),
            Bottleneck::Network => "network".to_string(),
            Bottleneck::Kernel => "kernel".to_string(),
            Bottleneck::Balanced => "balanced".to_string(),
        }
    }
}

/// The dominant request-level stage (argmax of the four shares) — the
/// coarse regime signature crossval compares across realisations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DominantStage {
    Park,
    Queue,
    Exec,
    Network,
    Overhead,
}

impl DominantStage {
    pub fn label(&self) -> &'static str {
        match self {
            DominantStage::Park => "park",
            DominantStage::Queue => "queue",
            DominantStage::Exec => "exec",
            DominantStage::Network => "network",
            DominantStage::Overhead => "overhead",
        }
    }
}

/// Per-replica utilisation and queue view, from the replica-scoped
/// events (`Enqueued`/`ExecStart`/`ExecEnd`).
#[derive(Debug, Clone)]
pub struct ReplicaStats {
    pub replica: usize,
    /// Completed exec spans observed (winners and losers alike).
    pub exec_spans: usize,
    /// Σ exec-span durations — can exceed the trace span on replicas
    /// with parallel engines.
    pub busy_us: f64,
    /// Σ kernel slices inside those spans.
    pub kernel_busy_us: f64,
    pub mean_exec_us: f64,
    /// `busy_us / span_us` — per-replica busy fraction (>1 with engine
    /// parallelism).
    pub util: f64,
    /// `kernel_busy_us / (span_us × kernels)` — wall-clock kernel
    /// occupancy, the §6.1 starvation signal.
    pub kernel_util: f64,
    pub max_queue_depth: usize,
    /// `(t_us, depth)` after each enqueue/exec-start, decimated to at
    /// most [`DEPTH_TIMELINE_CAP`] points.
    pub depth_timeline: Vec<(f64, u32)>,
}

/// Time-in-stage decomposition of a trace plus the per-replica view and
/// the control-plane transition log.
#[derive(Debug, Clone)]
pub struct StageBreakdown {
    /// Completed requests that decomposed fully (had accept, attempt,
    /// enqueue, exec and terminal events in the trace).
    pub requests: usize,
    /// Observed trace span (first to last event), µs.
    pub span_us: f64,
    pub park_share: f64,
    pub queue_share: f64,
    pub exec_share: f64,
    /// Pool-hop share (forward + reply network spans of the winning
    /// attempt); exactly 0 on PCIe-attached traces.
    pub network_share: f64,
    pub overhead_share: f64,
    /// Σ kernel slice / Σ winning exec span — how much of exec was the
    /// accelerator itself.
    pub kernel_exec_share: f64,
    pub park: LogHistogram,
    pub queue: LogHistogram,
    pub exec: LogHistogram,
    pub network: LogHistogram,
    pub overhead: LogHistogram,
    pub total: LogHistogram,
    pub replicas: Vec<ReplicaStats>,
    /// Breaker/health transitions, time-ordered ([`CONTROL_ID`] events).
    pub transitions: Vec<TraceEvent>,
    /// How many kernels each replica drives (localiser occupancy basis).
    pub kernels_per_replica: usize,
}

/// Accumulator for one request's lifecycle while scanning its events.
#[derive(Debug, Clone, Default)]
struct RequestLane {
    t_accept: Option<f64>,
    t_first_attempt: Option<f64>,
    attempts: usize,
    net_sends: Vec<f64>,
    net_recvs: Vec<f64>,
    enqueues: Vec<(f64, usize)>,
    exec_starts: Vec<(f64, usize)>,
    exec_spans: Vec<(f64, f64, usize, f64)>, // (start, end, replica, kernel_us)
    t_terminal: Option<f64>,
    completed: bool,
}

impl StageBreakdown {
    /// Decompose a drained trace. `n_replicas` sizes the per-replica
    /// table (replicas beyond any seen in the trace report zeros);
    /// `kernels_per_replica` is the number of kernel servers behind each
    /// replica — the denominator of kernel occupancy (1 for the sim's
    /// single modelled kernel pipeline, `topology.kernels` engine-server
    /// threads for the real node).
    pub fn analyze(trace: &Trace, n_replicas: usize, kernels_per_replica: usize) -> StageBreakdown {
        let kpr = kernels_per_replica.max(1);
        let mut events = trace.events.clone();
        events.sort_by(|a, b| a.t_us.total_cmp(&b.t_us).then_with(|| a.id.cmp(&b.id)));

        let span_us = match (events.first(), events.last()) {
            (Some(f), Some(l)) => (l.t_us - f.t_us).max(1e-9),
            _ => 1e-9,
        };

        // Group request-scoped events by id; keep control events aside.
        let mut transitions: Vec<TraceEvent> = Vec::new();
        let mut lanes: Vec<(u64, RequestLane)> = Vec::new();
        for e in &events {
            if e.id == CONTROL_ID {
                if e.ev.is_control() {
                    transitions.push(*e);
                }
                continue;
            }
            let lane = match lanes.binary_search_by_key(&e.id, |&(id, _)| id) {
                Ok(i) => &mut lanes[i].1,
                Err(i) => {
                    lanes.insert(i, (e.id, RequestLane::default()));
                    &mut lanes[i].1
                }
            };
            match e.ev {
                StageEvent::Accepted { .. } => lane.t_accept = lane.t_accept.or(Some(e.t_us)),
                StageEvent::AttemptStart { .. } => {
                    lane.t_first_attempt = lane.t_first_attempt.or(Some(e.t_us));
                    lane.attempts += 1;
                }
                StageEvent::NetSend { .. } => lane.net_sends.push(e.t_us),
                StageEvent::NetRecv { .. } => lane.net_recvs.push(e.t_us),
                StageEvent::Enqueued { replica } => lane.enqueues.push((e.t_us, replica)),
                StageEvent::ExecStart { replica } => lane.exec_starts.push((e.t_us, replica)),
                StageEvent::ExecEnd { replica, kernel_us, .. } => {
                    // Pair with the earliest unmatched start on the same
                    // replica (FIFO per replica — each replica executes a
                    // given request's attempt once at a time).
                    let start = lane
                        .exec_starts
                        .iter()
                        .position(|&(_, r)| r == replica)
                        .map(|i| lane.exec_starts.remove(i).0)
                        .unwrap_or(e.t_us);
                    lane.exec_spans.push((start, e.t_us, replica, kernel_us));
                }
                StageEvent::Completed { .. } => {
                    lane.t_terminal = lane.t_terminal.or(Some(e.t_us));
                    lane.completed = true;
                }
                StageEvent::Shed { .. } | StageEvent::Lost { .. } => {
                    lane.t_terminal = lane.t_terminal.or(Some(e.t_us));
                }
                _ => {}
            }
        }

        // Per-replica stats from all exec spans + queue-depth timelines.
        let max_seen_replica = lanes
            .iter()
            .flat_map(|(_, l)| {
                l.exec_spans.iter().map(|&(_, _, r, _)| r).chain(l.enqueues.iter().map(|&(_, r)| r))
            })
            .max()
            .map(|r| r + 1)
            .unwrap_or(0);
        let nr = n_replicas.max(max_seen_replica);
        let mut replicas: Vec<ReplicaStats> = (0..nr)
            .map(|replica| ReplicaStats {
                replica,
                exec_spans: 0,
                busy_us: 0.0,
                kernel_busy_us: 0.0,
                mean_exec_us: 0.0,
                util: 0.0,
                kernel_util: 0.0,
                max_queue_depth: 0,
                depth_timeline: Vec::new(),
            })
            .collect();
        let mut depth_deltas: Vec<Vec<(f64, i32)>> = vec![Vec::new(); nr];
        for (_, lane) in &lanes {
            for &(start, end, r, kernel_us) in &lane.exec_spans {
                let s = &mut replicas[r];
                s.exec_spans += 1;
                s.busy_us += (end - start).max(0.0);
                s.kernel_busy_us += kernel_us.max(0.0);
            }
            for &(t, r) in &lane.enqueues {
                depth_deltas[r].push((t, 1));
            }
            for &(start, _, r, _) in &lane.exec_spans {
                depth_deltas[r].push((start, -1));
            }
        }
        for (r, deltas) in depth_deltas.iter_mut().enumerate() {
            deltas.sort_by(|a, b| a.0.total_cmp(&b.0).then_with(|| b.1.cmp(&a.1)));
            let mut depth: i64 = 0;
            let mut timeline = Vec::with_capacity(deltas.len());
            for &(t, d) in deltas.iter() {
                depth = (depth + d as i64).max(0);
                timeline.push((t, depth as u32));
            }
            let s = &mut replicas[r];
            s.max_queue_depth = timeline.iter().map(|&(_, d)| d as usize).max().unwrap_or(0);
            // Decimate long timelines to the cap, always keeping the last
            // point so the end state survives.
            if timeline.len() > DEPTH_TIMELINE_CAP {
                let step = timeline.len().div_ceil(DEPTH_TIMELINE_CAP);
                let last = *timeline.last().unwrap();
                let mut kept: Vec<(f64, u32)> = timeline.into_iter().step_by(step).collect();
                if kept.last() != Some(&last) {
                    kept.push(last);
                }
                timeline = kept;
            }
            s.depth_timeline = timeline;
            s.mean_exec_us = s.busy_us / (s.exec_spans as f64).max(1.0);
            s.util = s.busy_us / span_us;
            s.kernel_util = s.kernel_busy_us / (span_us * kpr as f64);
        }

        // Stage decomposition over completed, fully-observed requests.
        let (mut park, mut queue, mut exec, mut network, mut overhead, mut total) = (
            LogHistogram::new(),
            LogHistogram::new(),
            LogHistogram::new(),
            LogHistogram::new(),
            LogHistogram::new(),
            LogHistogram::new(),
        );
        let (mut sum_park, mut sum_queue, mut sum_exec, mut sum_net, mut sum_over, mut sum_total) =
            (0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64, 0.0f64);
        let mut sum_kernel = 0.0f64;
        let mut requests = 0usize;
        for (_, lane) in &lanes {
            let (Some(t_accept), Some(t_attempt), Some(t_term)) =
                (lane.t_accept, lane.t_first_attempt, lane.t_terminal)
            else {
                continue;
            };
            if !lane.completed {
                continue;
            }
            // Winner = the exec span ending at (or latest before) the
            // terminal; hedge losers end after it.
            let Some(&(w_start, w_end, _, w_kernel)) = lane
                .exec_spans
                .iter()
                .filter(|&&(_, end, _, _)| end <= t_term + 1e-6)
                .max_by(|a, b| a.1.total_cmp(&b.1))
            else {
                continue;
            };
            // The winning attempt's enqueue: the latest at/before its
            // exec start (earlier enqueues belong to failed attempts).
            let t_enq = lane
                .enqueues
                .iter()
                .filter(|&&(t, _)| t <= w_start + 1e-6)
                .map(|&(t, _)| t)
                .fold(f64::NEG_INFINITY, f64::max);
            if !t_enq.is_finite() {
                continue;
            }
            let r_total = (t_term - t_accept).max(0.0);
            let r_park = (t_attempt - t_accept).max(0.0);
            let r_exec = (w_end - w_start).max(0.0);
            let r_queue = (w_start - t_enq).max(0.0);
            // Pool hops around the winner, otherwise part of the residual:
            // forward = the latest NetSend at/before the winning enqueue →
            // that enqueue; reply = winning ExecEnd → the earliest NetRecv
            // at/after it. PCIe traces have no Net events → both zero.
            let r_net_fwd = lane
                .net_sends
                .iter()
                .filter(|&&t| t <= t_enq + 1e-6)
                .fold(f64::NEG_INFINITY, |a, &t| a.max(t));
            let r_net_fwd = if r_net_fwd.is_finite() { (t_enq - r_net_fwd).max(0.0) } else { 0.0 };
            let r_net_reply = lane
                .net_recvs
                .iter()
                .filter(|&&t| t >= w_end - 1e-6)
                .fold(f64::INFINITY, |a, &t| a.min(t));
            let r_net_reply =
                if r_net_reply.is_finite() { (r_net_reply - w_end).max(0.0) } else { 0.0 };
            let r_net = r_net_fwd + r_net_reply;
            let r_over = (r_total - r_park - r_queue - r_exec - r_net).max(0.0);
            park.record(r_park);
            queue.record(r_queue);
            exec.record(r_exec);
            network.record(r_net);
            overhead.record(r_over);
            total.record(r_total);
            sum_park += r_park;
            sum_queue += r_queue;
            sum_exec += r_exec;
            sum_net += r_net;
            sum_over += r_over;
            sum_total += r_total;
            sum_kernel += w_kernel.max(0.0);
            requests += 1;
        }

        let denom = sum_total.max(1e-9);
        StageBreakdown {
            requests,
            span_us,
            park_share: sum_park / denom,
            queue_share: sum_queue / denom,
            exec_share: sum_exec / denom,
            network_share: sum_net / denom,
            overhead_share: sum_over / denom,
            kernel_exec_share: sum_kernel / sum_exec.max(1e-9),
            park,
            queue,
            exec,
            network,
            overhead,
            total,
            replicas,
            transitions,
            kernels_per_replica: kpr,
        }
    }

    /// Argmax of the four stage shares.
    pub fn dominant_stage(&self) -> DominantStage {
        let shares = [
            (self.park_share, DominantStage::Park),
            (self.queue_share, DominantStage::Queue),
            (self.exec_share, DominantStage::Exec),
            (self.network_share, DominantStage::Network),
            (self.overhead_share, DominantStage::Overhead),
        ];
        shares.iter().max_by(|a, b| a.0.total_cmp(&b.0)).map(|&(_, s)| s).unwrap()
    }

    /// Mean busy fraction across replicas that saw any exec work.
    pub fn mean_util(&self) -> f64 {
        let active: Vec<&ReplicaStats> =
            self.replicas.iter().filter(|r| r.exec_spans > 0).collect();
        if active.is_empty() {
            return 0.0;
        }
        active.iter().map(|r| r.util).sum::<f64>() / active.len() as f64
    }

    /// Mean wall-clock kernel occupancy across active replicas.
    pub fn mean_kernel_util(&self) -> f64 {
        let active: Vec<&ReplicaStats> =
            self.replicas.iter().filter(|r| r.exec_spans > 0).collect();
        if active.is_empty() {
            return 0.0;
        }
        active.iter().map(|r| r.kernel_util).sum::<f64>() / active.len() as f64
    }

    /// The automatic bottleneck localiser (decision tree in the module
    /// docs). Deterministic: same trace, same verdict.
    pub fn localise(&self) -> Bottleneck {
        if self.requests == 0 {
            return Bottleneck::Balanced;
        }
        // 1. Replica skew first: a straggler distorts everything below.
        let trusted: Vec<(usize, f64)> = self
            .replicas
            .iter()
            .filter(|r| r.exec_spans >= MIN_REPLICA_SPANS)
            .map(|r| (r.replica, r.mean_exec_us))
            .collect();
        if trusted.len() >= 2 {
            let mut worst: Option<(usize, f64)> = None;
            for &(i, mean) in &trusted {
                let mut peers: Vec<f64> =
                    trusted.iter().filter(|&&(j, _)| j != i).map(|&(_, m)| m).collect();
                peers.sort_by(f64::total_cmp);
                let median = peers[peers.len() / 2];
                let ratio = mean / median.max(1e-9);
                if ratio >= STRAGGLER_FACTOR && worst.map(|(_, w)| ratio > w).unwrap_or(true) {
                    worst = Some((i, ratio));
                }
            }
            if let Some((i, _)) = worst {
                return Bottleneck::Replica(i);
            }
        }
        // 2. The pool hop itself: checked before the upstream split
        // because a saturated link also backs work up into park/queue.
        if self.network_share >= NETWORK_DOMINANT {
            return Bottleneck::Network;
        }
        // 3. Upstream-dominant: the door or the feed, not the kernel.
        if self.park_share + self.queue_share >= UPSTREAM_DOMINANT {
            if self.mean_util() < NODE_IDLE {
                return Bottleneck::Frontdoor;
            }
            if self.mean_kernel_util() < KERNEL_IDLE {
                return Bottleneck::Feeder;
            }
            return Bottleneck::Kernel;
        }
        Bottleneck::Balanced
    }

    pub fn summary(&self) -> String {
        format!(
            "{} reqs over {:.1} ms | shares park/queue/exec/net/overhead \
             {:.2}/{:.2}/{:.2}/{:.2}/{:.2} (kernel {:.2} of exec) | util {:.2} kernel-util {:.2} \
             | dominant {} → {} | {} transitions",
            self.requests,
            self.span_us / 1e3,
            self.park_share,
            self.queue_share,
            self.exec_share,
            self.network_share,
            self.overhead_share,
            self.kernel_exec_share,
            self.mean_util(),
            self.mean_kernel_util(),
            self.dominant_stage().label(),
            self.localise().label(),
            self.transitions.len(),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::telemetry::{BreakerPhase, Recorder, RingRecorder, TraceSpec};

    /// Drive one synthetic request through a recorder with explicit stage
    /// durations, returning its completion time.
    #[allow(clippy::too_many_arguments)]
    fn request(
        rec: &mut RingRecorder,
        id: u64,
        t0: f64,
        replica: usize,
        park: f64,
        queue: f64,
        exec: f64,
        kernel: f64,
    ) -> f64 {
        let n = 16;
        rec.record(t0, id, StageEvent::Accepted { n_queries: n });
        let t1 = t0 + park;
        rec.record(t1, id, StageEvent::Admitted);
        rec.record(t1, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(t1, id, StageEvent::Routed { replica });
        rec.record(t1, id, StageEvent::Enqueued { replica });
        let t2 = t1 + queue;
        rec.record(t2, id, StageEvent::ExecStart { replica });
        let t3 = t2 + exec;
        rec.record(t3, id, StageEvent::ExecEnd { replica, kernel_us: kernel, ok: true });
        rec.record(t3, id, StageEvent::Completed { n_queries: n });
        t3
    }

    #[test]
    fn shares_recover_known_stage_durations() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..40u64 {
            // park 10, queue 30, exec 60 → shares 0.1/0.3/0.6 exactly.
            request(&mut rec, i, i as f64 * 120.0, (i % 2) as usize, 10.0, 30.0, 60.0, 40.0);
        }
        let trace = rec.into_trace();
        let b = StageBreakdown::analyze(&trace, 2, 1);
        assert_eq!(b.requests, 40);
        assert!((b.park_share - 0.1).abs() < 1e-6, "{}", b.summary());
        assert!((b.queue_share - 0.3).abs() < 1e-6, "{}", b.summary());
        assert!((b.exec_share - 0.6).abs() < 1e-6, "{}", b.summary());
        assert!(b.overhead_share.abs() < 1e-6);
        assert!((b.kernel_exec_share - 40.0 / 60.0).abs() < 1e-6);
        assert_eq!(b.dominant_stage(), DominantStage::Exec);
        assert_eq!(b.replicas.len(), 2);
        assert_eq!(b.replicas[0].exec_spans + b.replicas[1].exec_spans, 40);
        assert!((b.exec.mean() - 60.0).abs() < 1.0, "exec histogram centred on 60 µs");
    }

    #[test]
    fn retry_overhead_lands_in_the_residual() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        let id = 1u64;
        let n = 16;
        // Accepted at 0; failed primary (exec 0→50 on replica 0, not ok);
        // retry at 100 (backoff), enqueued, exec 110→140 on replica 1.
        rec.record(0.0, id, StageEvent::Accepted { n_queries: n });
        rec.record(5.0, id, StageEvent::Admitted);
        rec.record(5.0, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(5.0, id, StageEvent::Enqueued { replica: 0 });
        rec.record(10.0, id, StageEvent::ExecStart { replica: 0 });
        rec.record(50.0, id, StageEvent::ExecEnd { replica: 0, kernel_us: 0.0, ok: false });
        rec.record(100.0, id, StageEvent::AttemptStart { kind: AttemptKind::Retry });
        rec.record(100.0, id, StageEvent::Enqueued { replica: 1 });
        rec.record(110.0, id, StageEvent::ExecStart { replica: 1 });
        rec.record(140.0, id, StageEvent::ExecEnd { replica: 1, kernel_us: 20.0, ok: true });
        rec.record(140.0, id, StageEvent::Completed { n_queries: n });
        let b = StageBreakdown::analyze(&rec.into_trace(), 2, 1);
        assert_eq!(b.requests, 1);
        // total 140: park 5, queue 10 (winner's enqueue 100 → start 110),
        // exec 30, overhead 95 (failed attempt + backoff).
        assert!((b.park_share - 5.0 / 140.0).abs() < 1e-6, "{}", b.summary());
        assert!((b.queue_share - 10.0 / 140.0).abs() < 1e-6, "{}", b.summary());
        assert!((b.exec_share - 30.0 / 140.0).abs() < 1e-6, "{}", b.summary());
        assert!((b.overhead_share - 95.0 / 140.0).abs() < 1e-6, "{}", b.summary());
        assert_eq!(b.dominant_stage(), DominantStage::Overhead);
    }

    /// One pooled request: feeder hands off at `t0+park`, the batch rides
    /// the network for `fwd`, queues `queue`, executes `exec`, and the
    /// result rides back for `reply`.
    #[allow(clippy::too_many_arguments)]
    fn pooled_request(
        rec: &mut RingRecorder,
        id: u64,
        t0: f64,
        park: f64,
        fwd: f64,
        queue: f64,
        exec: f64,
        reply: f64,
    ) -> f64 {
        let n = 16;
        rec.record(t0, id, StageEvent::Accepted { n_queries: n });
        let t1 = t0 + park;
        rec.record(t1, id, StageEvent::Admitted);
        rec.record(t1, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
        rec.record(t1, id, StageEvent::Routed { replica: 0 });
        rec.record(t1, id, StageEvent::NetSend { bytes: 832 });
        let t2 = t1 + fwd;
        rec.record(t2, id, StageEvent::Enqueued { replica: 0 });
        let t3 = t2 + queue;
        rec.record(t3, id, StageEvent::ExecStart { replica: 0 });
        let t4 = t3 + exec;
        rec.record(t4, id, StageEvent::ExecEnd { replica: 0, kernel_us: exec, ok: true });
        let t5 = t4 + reply;
        rec.record(t5, id, StageEvent::NetRecv { bytes: 128 });
        rec.record(t5, id, StageEvent::Completed { n_queries: n });
        t5
    }

    #[test]
    fn network_hops_carve_out_of_the_residual() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..30u64 {
            // park 5, fwd 25, queue 10, exec 40, reply 20 → total 100,
            // network share (25+20)/100 exactly; overhead exactly 0.
            pooled_request(&mut rec, i, i as f64 * 150.0, 5.0, 25.0, 10.0, 40.0, 20.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert_eq!(b.requests, 30);
        assert!((b.park_share - 0.05).abs() < 1e-6, "{}", b.summary());
        assert!((b.network_share - 0.45).abs() < 1e-6, "{}", b.summary());
        assert!((b.queue_share - 0.10).abs() < 1e-6, "{}", b.summary());
        assert!((b.exec_share - 0.40).abs() < 1e-6, "{}", b.summary());
        assert!(b.overhead_share.abs() < 1e-6, "{}", b.summary());
        assert_eq!(b.dominant_stage(), DominantStage::Network);
        assert!((b.network.mean() - 45.0).abs() < 1.0, "network histogram centred on 45 µs");
        // 0.45 ≥ NETWORK_DOMINANT: the localiser names the hop.
        assert_eq!(b.localise(), Bottleneck::Network, "{}", b.summary());

        // A fast link stays out of the verdict: same shape, tiny hops.
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..30u64 {
            pooled_request(&mut rec, i, i as f64 * 150.0, 5.0, 2.0, 10.0, 80.0, 1.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert!(b.network_share < 0.05, "{}", b.summary());
        assert_eq!(b.localise(), Bottleneck::Balanced, "{}", b.summary());
    }

    #[test]
    fn localiser_pins_a_straggler_replica() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        let mut t = 0.0;
        for i in 0..60u64 {
            let replica = (i % 3) as usize;
            // Replica 1 limps at 8× the exec span of its peers.
            let exec = if replica == 1 { 400.0 } else { 50.0 };
            t = request(&mut rec, i, t, replica, 2.0, 5.0, exec, exec * 0.8);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 3, 1);
        assert_eq!(b.localise(), Bottleneck::Replica(1), "{}", b.summary());
    }

    #[test]
    fn localiser_separates_feeder_from_kernel_saturation() {
        // Feeder-bound: queue dominates, replicas busy, kernel slice tiny
        // (the CPU feed stage is the wall; the FPGA idles — §6.1).
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..50u64 {
            // back-to-back spans: replica busy the whole trace
            request(&mut rec, i, i as f64 * 100.0, 0, 2.0, 200.0, 98.0, 10.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert!(b.park_share + b.queue_share >= UPSTREAM_DOMINANT, "{}", b.summary());
        assert!(b.mean_util() >= NODE_IDLE, "{}", b.summary());
        assert_eq!(b.localise(), Bottleneck::Feeder, "{}", b.summary());

        // Kernel-bound: same queueing but the kernel slice fills the span.
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..50u64 {
            request(&mut rec, i, i as f64 * 100.0, 0, 2.0, 200.0, 98.0, 95.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert_eq!(b.localise(), Bottleneck::Kernel, "{}", b.summary());

        // Door-bound: park dominates and the replica is mostly idle.
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..50u64 {
            request(&mut rec, i, i as f64 * 1000.0, 0, 900.0, 2.0, 50.0, 40.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert!(b.mean_util() < NODE_IDLE, "{}", b.summary());
        assert_eq!(b.localise(), Bottleneck::Frontdoor, "{}", b.summary());

        // Balanced: exec dominates, nothing upstream.
        let mut rec = RingRecorder::new(TraceSpec::full());
        for i in 0..50u64 {
            request(&mut rec, i, i as f64 * 100.0, 0, 2.0, 5.0, 90.0, 80.0);
        }
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert_eq!(b.localise(), Bottleneck::Balanced, "{}", b.summary());
    }

    #[test]
    fn queue_depth_timeline_and_transitions() {
        let mut rec = RingRecorder::new(TraceSpec::full());
        // Three enqueues before any exec start: depth peaks at 3.
        for id in 0..3u64 {
            rec.record(id as f64, id, StageEvent::Accepted { n_queries: 1 });
            rec.record(id as f64, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
            rec.record(id as f64, id, StageEvent::Enqueued { replica: 0 });
        }
        for id in 0..3u64 {
            let t = 10.0 + id as f64 * 20.0;
            rec.record(t, id, StageEvent::ExecStart { replica: 0 });
            rec.record(t + 15.0, id, StageEvent::ExecEnd { replica: 0, kernel_us: 5.0, ok: true });
            rec.record(t + 15.0, id, StageEvent::Completed { n_queries: 1 });
        }
        rec.record(
            30.0,
            CONTROL_ID,
            StageEvent::Breaker { replica: 0, from: BreakerPhase::Closed, to: BreakerPhase::Open },
        );
        rec.record(60.0, CONTROL_ID, StageEvent::Health { replica: 0, degraded: true });
        let b = StageBreakdown::analyze(&rec.into_trace(), 1, 1);
        assert_eq!(b.replicas[0].max_queue_depth, 3);
        let last = *b.replicas[0].depth_timeline.last().unwrap();
        assert_eq!(last.1, 0, "queue drains by the end");
        assert_eq!(b.transitions.len(), 2);
        assert!(matches!(b.transitions[0].ev, StageEvent::Breaker { .. }));
        assert!(matches!(b.transitions[1].ev, StageEvent::Health { degraded: true, .. }));
    }

    #[test]
    fn empty_trace_is_balanced_and_quiet() {
        let b = StageBreakdown::analyze(&Trace::default(), 2, 4);
        assert_eq!(b.requests, 0);
        assert_eq!(b.localise(), Bottleneck::Balanced);
        assert_eq!(b.replicas.len(), 2);
        assert!(b.summary().contains("0 reqs"));
    }
}
