//! Real (threaded) realisation of the disaggregated kernel pool.
//!
//! The injector paces arrivals on the wall clock and parks each batch
//! on the least-loaded **feeder lane** (a bounded in-flight counter —
//! the feeder-side admission valve). Accepted jobs cross a channel hop
//! into a single **pool dispatcher** thread: the network model is the
//! dispatcher pacing itself `transfer_us` per transfer (hop latency +
//! serialisation of one encoded batch), so the hop's capacity — and
//! the amortisation a packing lease buys — is physical, not assumed.
//! The dispatcher leases each transfer to the least-loaded eligible
//! kernel node ([`pick_kernel`] over live queue depths) and submits it
//! through the cluster's tagged-completion surface
//! ([`ClusterHandle::try_submit_to`]); a collector thread maps tagged
//! completions back to pack members, feeds per-kernel circuit
//! breakers, and folds per-member latency.
//!
//! Lease revocation follows the real realisation's drain semantics: a
//! revoked kernel (forced window or breaker trip) stops receiving new
//! leases but finishes what it holds — so `lost` is structurally zero
//! here, exactly like [`Cluster::run`](crate::cluster::real::Cluster),
//! and the conservation law closes through `completed + shed`.

use std::collections::HashMap;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::{gray_fault_factory, BackendFactory};
use crate::cluster::real::ClusterHandle;
use crate::cluster::ClusterConfig;
use crate::coordinator::pipeline::{pace_until, Completion};
use crate::coordinator::Percentiles;
use crate::prng::Rng;
use crate::resilience::{BreakerConfig, CircuitBreaker};
use crate::rules::types::MctQuery;
use crate::workload::ArrivalSource;

use super::{pick_kernel, LeasePolicy, PoolReport};

/// Pool-side knobs of the real realisation (the kernel fleet itself is
/// a plain [`ClusterConfig`]).
#[derive(Debug, Clone)]
pub struct PoolRealConfig {
    /// Feeder lanes the injector spreads over (M of M:N).
    pub feeders: usize,
    /// Per-lane in-flight cap — the feeder-side admission valve.
    pub feeder_cap: usize,
    /// Dispatcher occupancy per transfer, µs: the modelled hop latency
    /// plus serialisation of one encoded batch onto the pool's link.
    pub transfer_us: f64,
    pub lease: LeasePolicy,
    pub breaker: BreakerConfig,
    /// Forced lease-revocation windows `(t_down_us, t_up_us, kernel)`:
    /// the kernel takes no new leases inside the window (drain
    /// semantics — in-flight work completes).
    pub revoke_windows: Vec<(f64, f64, usize)>,
    /// Dispatcher outage windows `(t_down_us, t_up_us)`: the channel
    /// buffers jobs until revival.
    pub dispatcher_down: Vec<(f64, f64)>,
    pub seed: u64,
}

impl PoolRealConfig {
    pub fn new(feeders: usize) -> PoolRealConfig {
        PoolRealConfig {
            feeders,
            feeder_cap: 64,
            transfer_us: 0.0,
            lease: LeasePolicy::Fifo,
            breaker: BreakerConfig::default(),
            revoke_windows: Vec::new(),
            dispatcher_down: Vec::new(),
            seed: 0xB007,
        }
    }

    pub fn with_lease(mut self, lease: LeasePolicy) -> Self {
        self.lease = lease;
        self
    }

    pub fn with_transfer_us(mut self, transfer_us: f64) -> Self {
        self.transfer_us = transfer_us;
        self
    }

    pub fn with_feeder_cap(mut self, feeder_cap: usize) -> Self {
        self.feeder_cap = feeder_cap;
        self
    }

    pub fn with_revoke_windows(mut self, w: Vec<(f64, f64, usize)>) -> Self {
        self.revoke_windows = w;
        self
    }

    pub fn with_dispatcher_down(mut self, w: Vec<(f64, f64)>) -> Self {
        self.dispatcher_down = w;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }
}

/// One feeder batch crossing the feeder→dispatcher hop.
struct PoolJob {
    queries: Vec<MctQuery>,
    n: usize,
    /// Injector clock when the lane accepted the job, µs.
    accept_us: f64,
    /// Injector clock when the job left the feeder for the hop, µs.
    sent_us: f64,
    feeder: usize,
}

/// One request inside a (possibly packed) transfer, as the collector
/// needs it back.
struct Member {
    n: usize,
    accept_us: f64,
    feeder: usize,
}

/// Aggregates the dispatcher thread hands back at join.
#[derive(Default)]
struct DispatchStats {
    transfers: usize,
    transfer_queries: usize,
    net_forward_sum: f64,
    net_forward_n: usize,
}

fn now_us(t0: &Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// A runnable pool: M feeder lanes → dispatcher hop → N kernel nodes.
pub struct PoolCluster {
    pub cluster: ClusterConfig,
    pub pool: PoolRealConfig,
    factories: Vec<BackendFactory>,
}

impl PoolCluster {
    /// Homogeneous kernel fleet from one factory.
    pub fn new(cluster: ClusterConfig, pool: PoolRealConfig, factory: BackendFactory) -> Self {
        let factories = vec![factory; cluster.nodes()];
        for &(_, _, k) in &pool.revoke_windows {
            assert!(k < cluster.nodes(), "revocation names kernel {k}");
        }
        PoolCluster { cluster, pool, factories }
    }

    /// Serve the arrival stream through the pool and report.
    pub fn run(&self, source: &mut dyn ArrivalSource) -> Result<PoolReport> {
        let n_kernels = self.cluster.nodes();
        let cfg = &self.pool;
        assert!(cfg.feeders > 0 && n_kernels > 0);
        let t0 = Instant::now();
        let factories: Vec<BackendFactory> = self
            .factories
            .iter()
            .enumerate()
            .map(|(i, f)| {
                gray_fault_factory(
                    f.clone(),
                    self.cluster.faults.clone(),
                    i,
                    t0,
                    self.cluster.route_seed,
                )
            })
            .collect();
        let handle = ClusterHandle::spawn(&self.cluster, &factories);
        let (jtx, jrx) = mpsc::channel::<PoolJob>();
        let (ctx, crx) = mpsc::channel::<Completion>();
        let members: Mutex<HashMap<u64, Vec<Member>>> = Mutex::new(HashMap::new());
        let breakers: Mutex<Vec<CircuitBreaker>> =
            Mutex::new((0..n_kernels).map(|_| CircuitBreaker::new(cfg.breaker)).collect());
        let pending: Vec<AtomicUsize> = (0..cfg.feeders).map(|_| AtomicUsize::new(0)).collect();

        let mut requests = 0usize;
        let mut shed = 0usize;
        let mut shed_queries = 0usize;

        let (lat_completed, dstats) = std::thread::scope(|scope| {
            let h = &handle;
            let members_ref = &members;
            let breakers_ref = &breakers;
            let pending_ref = &pending;

            // ---- Pool dispatcher -----------------------------------
            let dispatcher = scope.spawn(move || {
                let mut rng = Rng::new(cfg.seed ^ 0xB007_CAFE);
                let mut stats = DispatchStats::default();
                let mut next_free_us = 0.0f64;
                let mut xfer_id = 0u64;
                let mut buf: Vec<PoolJob> = Vec::new();
                let mut buf_q = 0usize;
                let mut closed = false;

                let mut submit = |jobs: Vec<PoolJob>, stats: &mut DispatchStats,
                                  next_free_us: &mut f64,
                                  xfer_id: &mut u64| {
                    // Outage windows: the dispatcher is simply gone;
                    // the channel (and pack buffer) hold the backlog.
                    loop {
                        let now = now_us(&t0);
                        match cfg
                            .dispatcher_down
                            .iter()
                            .find(|&&(d, u)| now >= d && now < u)
                        {
                            Some(&(_, up)) => pace_until(t0, up),
                            None => break,
                        }
                    }
                    // The hop is a single-server resource: one transfer
                    // serialises at a time, whatever its size — this is
                    // what packing amortises.
                    let now = now_us(&t0);
                    *next_free_us = now.max(*next_free_us) + cfg.transfer_us;
                    pace_until(t0, *next_free_us);
                    // Lease: least-loaded eligible kernel, by live depth.
                    let k = loop {
                        let now = now_us(&t0);
                        let depths = h.depths();
                        let eligible: Vec<bool> = (0..n_kernels)
                            .map(|k| {
                                !cfg.revoke_windows
                                    .iter()
                                    .any(|&(d, u, rk)| rk == k && now >= d && now < u)
                                    && breakers_ref.lock().unwrap()[k].allows(now, &mut rng)
                            })
                            .collect();
                        match pick_kernel(&depths, &eligible, cfg.seed, *xfer_id) {
                            Some(k) => break k,
                            // Every lease revoked: wait out the storm.
                            None => std::thread::sleep(Duration::from_micros(200)),
                        }
                    };
                    let now = now_us(&t0);
                    let mut queries = Vec::new();
                    let mut mem = Vec::new();
                    for j in jobs {
                        stats.net_forward_sum += now - j.sent_us;
                        stats.net_forward_n += 1;
                        queries.extend(j.queries);
                        mem.push(Member { n: j.n, accept_us: j.accept_us, feeder: j.feeder });
                    }
                    stats.transfers += 1;
                    stats.transfer_queries += queries.len();
                    members_ref.lock().unwrap().insert(*xfer_id, mem);
                    h.try_submit_to(k, queries, *xfer_id, &ctx);
                    *xfer_id += 1;
                };

                while !closed || !buf.is_empty() {
                    match cfg.lease {
                        LeasePolicy::Fifo => match jrx.recv() {
                            Ok(j) => submit(vec![j], &mut stats, &mut next_free_us, &mut xfer_id),
                            Err(_) => closed = true,
                        },
                        LeasePolicy::SizeAware { pack_queries, age_cap_us } => {
                            if closed {
                                let jobs = std::mem::take(&mut buf);
                                buf_q = 0;
                                submit(jobs, &mut stats, &mut next_free_us, &mut xfer_id);
                                continue;
                            }
                            if buf.is_empty() {
                                match jrx.recv() {
                                    Ok(j) => {
                                        buf_q += j.n;
                                        buf.push(j);
                                    }
                                    Err(_) => closed = true,
                                }
                                continue;
                            }
                            let now = now_us(&t0);
                            let deadline = buf[0].sent_us + age_cap_us;
                            if buf_q >= pack_queries || now >= deadline {
                                let jobs = std::mem::take(&mut buf);
                                buf_q = 0;
                                submit(jobs, &mut stats, &mut next_free_us, &mut xfer_id);
                                continue;
                            }
                            let wait = Duration::from_micros((deadline - now) as u64 + 1);
                            match jrx.recv_timeout(wait) {
                                Ok(j) => {
                                    buf_q += j.n;
                                    buf.push(j);
                                }
                                Err(mpsc::RecvTimeoutError::Timeout) => {
                                    let jobs = std::mem::take(&mut buf);
                                    buf_q = 0;
                                    submit(jobs, &mut stats, &mut next_free_us, &mut xfer_id);
                                }
                                Err(mpsc::RecvTimeoutError::Disconnected) => closed = true,
                            }
                        }
                    }
                }
                stats
            });

            // ---- Collector -----------------------------------------
            let collector = scope.spawn(move || {
                let mut lat = Percentiles::new();
                let mut completed = 0usize;
                let mut completed_q = 0usize;
                let mut failed = 0usize;
                while let Ok(c) = crx.recv() {
                    let now = now_us(&t0);
                    h.note_completion(&c);
                    breakers_ref.lock().unwrap()[c.node].on_outcome(
                        now,
                        c.ok,
                        c.latency_us * 1024.0 / c.n_queries.max(1) as f64,
                    );
                    let mem = members_ref
                        .lock()
                        .unwrap()
                        .remove(&c.id)
                        .expect("every tagged completion has a member map entry");
                    for m in mem {
                        pending_ref[m.feeder].fetch_sub(1, Ordering::Relaxed);
                        lat.record(now - m.accept_us);
                        completed += 1;
                        completed_q += m.n;
                        if !c.ok {
                            failed += 1;
                        }
                    }
                }
                (lat, completed, completed_q, failed)
            });

            // ---- Injector (this thread) ----------------------------
            let mut idx = 0u64;
            while let Some(a) = source.next_arrival() {
                requests += 1;
                pace_until(t0, a.at_us);
                let n = a.queries.len();
                let loads: Vec<usize> =
                    pending.iter().map(|p| p.load(Ordering::Relaxed)).collect();
                let all = vec![true; cfg.feeders];
                let f = pick_kernel(&loads, &all, cfg.seed ^ 0xFEED_F00D, idx)
                    .expect("at least one feeder lane");
                idx += 1;
                if loads[f] >= cfg.feeder_cap {
                    shed += 1;
                    shed_queries += n;
                    continue;
                }
                pending[f].fetch_add(1, Ordering::Relaxed);
                let now = now_us(&t0);
                jtx.send(PoolJob {
                    queries: a.queries,
                    n,
                    accept_us: now,
                    sent_us: now,
                    feeder: f,
                })
                .expect("dispatcher outlives the injector");
            }
            drop(jtx);
            let dstats = dispatcher.join().expect("dispatcher panicked");
            let lat_completed = collector.join().expect("collector panicked");
            (lat_completed, dstats)
        });

        let (mut lat, completed, completed_queries, failed) = lat_completed;
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let stranded: usize = members.lock().unwrap().values().map(Vec::len).sum();
        let trips: usize = breakers.lock().unwrap().iter().map(CircuitBreaker::trips).sum();
        handle.shutdown();

        anyhow::ensure!(
            completed + shed + stranded == requests,
            "pool lost requests: {requests} in, {completed} completed + {shed} shed + \
             {stranded} stranded"
        );

        Ok(PoolReport {
            label: format!("pool/{}", cfg.lease.label()),
            feeders: cfg.feeders,
            kernels: n_kernels,
            requests,
            accepted: requests - shed,
            completed,
            shed_queue: shed,
            lost: stranded,
            completed_queries,
            shed_queries,
            failed,
            offered_qps: source.offered_qps(),
            goodput_qps: completed_queries as f64 / wall_s,
            p50_us: lat.p50(),
            p90_us: lat.p90(),
            p99_us: lat.p99(),
            transfers: dstats.transfers,
            mean_transfer_queries: dstats.transfer_queries as f64
                / dstats.transfers.max(1) as f64,
            net_forward_mean_us: dstats.net_forward_sum / dstats.net_forward_n.max(1) as f64,
            revocations: self.pool.revoke_windows.len() + trips,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{AggregationPolicy, PipelineConfig, Topology};
    use crate::nfa::constraint_gen::HardwareConfig;
    use crate::rules::standard::StandardVersion;
    use crate::testing::fixture::compile_fixture;
    use crate::workload::PoissonSource;

    fn fixture() -> (BackendFactory, crate::rules::types::World) {
        let f = compile_fixture(909, 300, StandardVersion::V2, HardwareConfig::v2_aws(4));
        (f.native_factory(), f.world)
    }

    fn kernel_node() -> PipelineConfig {
        PipelineConfig::new(Topology::new(2, 1, 1, 4))
            .with_aggregation(AggregationPolicy::DrainQueue)
    }

    #[test]
    fn pool_serves_and_conserves_fifo() {
        let (factory, world) = fixture();
        let cluster = ClusterConfig::new(2, kernel_node());
        let pool = PoolRealConfig::new(4).with_transfer_us(50.0);
        let mut src = PoissonSource::new(&world, 11, 3e5, 16, 200);
        let r = PoolCluster::new(cluster, pool, factory).run(&mut src).unwrap();
        assert!(r.conserves());
        assert_eq!(r.requests, 200);
        assert_eq!(r.lost, 0, "real pool drains; nothing is lost");
        assert_eq!(r.transfers, r.accepted, "fifo: one transfer per accepted batch");
        assert!(r.completed > 0);
    }

    #[test]
    fn pool_packing_coalesces_in_the_real_hop() {
        let (factory, world) = fixture();
        let cluster = ClusterConfig::new(2, kernel_node());
        let pool = PoolRealConfig::new(4)
            .with_transfer_us(50.0)
            .with_lease(LeasePolicy::SizeAware { pack_queries: 64, age_cap_us: 2_000.0 });
        let mut src = PoissonSource::new(&world, 12, 4e5, 16, 240);
        let r = PoolCluster::new(cluster, pool, factory).run(&mut src).unwrap();
        assert!(r.conserves());
        assert!(
            r.transfers < r.accepted,
            "packing must coalesce: {} transfers for {} accepted",
            r.transfers,
            r.accepted
        );
        assert!(r.mean_transfer_queries > 16.0);
    }

    #[test]
    fn revocation_window_drains_onto_surviving_kernels() {
        let (factory, world) = fixture();
        let cluster = ClusterConfig::new(2, kernel_node());
        // Kernel 0's lease is revoked for the whole run.
        let pool = PoolRealConfig::new(4)
            .with_transfer_us(20.0)
            .with_revoke_windows(vec![(0.0, 60e6, 0)]);
        let mut src = PoissonSource::new(&world, 13, 3e5, 16, 150);
        let r = PoolCluster::new(cluster, pool, factory).run(&mut src).unwrap();
        assert!(r.conserves());
        assert_eq!(r.lost, 0);
        assert!(r.revocations >= 1);
        assert_eq!(r.completed, r.accepted, "kernel 1 must carry everything");
    }
}
