//! Deterministic DES realisation of the disaggregated kernel pool.
//!
//! M feeder stations encode locally (single-server `sched + encode`
//! per batch), then the encoded batch crosses an explicit link model —
//! per-port serialisation, a shared-switch FIFO at the sled's
//! bisection rate, and a fixed per-hop latency — into the pool
//! dispatcher. The dispatcher packs batches per [`LeasePolicy`] and
//! leases each transfer to the least-loaded eligible kernel
//! ([`pick_kernel`]); kernel occupancy follows
//! [`LinkModel::kernel_invocation_us`]. Per-kernel circuit breakers
//! revoke a lease on trip; forced faults ([`PoolFaults`]) revoke
//! kernels mid-flight and kill/revive the dispatcher so the
//! conservation law can be exercised under the ugliest interleavings.
//!
//! Everything is seeded and heap-ordered by `(ns, seq)`, so a given
//! `(config, arrivals)` pair replays to the bit.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::cluster::sim::SimArrival;
use crate::cluster::AdmissionPolicy;
use crate::controlplane::FaultPlan;
use crate::coordinator::metrics::Percentiles;
use crate::coordinator::overheads::Overheads;
use crate::erbium::FpgaModel;
use crate::nfa::HardwareConfig;
use crate::prng::Rng;
use crate::resilience::{BreakerConfig, BreakerState, CircuitBreaker};
use crate::telemetry::{AttemptKind, NullRecorder, Recorder, ShedLane, StageEvent};

use super::{
    encoded_bytes, pick_kernel, result_bytes, LeasePolicy, LinkModel, PoolReport,
};

/// Forced fault schedule for the pool, beyond the gray-fault plan.
#[derive(Debug, Clone, Default)]
pub struct PoolFaults {
    /// `(t_us, kernel, down_for_us)` — revoke the kernel's lease at
    /// `t_us`: its in-flight transfer is lost, queued transfers are
    /// re-leased elsewhere, and the kernel rejoins after `down_for_us`.
    pub revoke: Vec<(f64, usize, f64)>,
    /// `(t_down_us, t_up_us)` — dispatcher outage windows: batches
    /// arriving while down are held and drained at revival.
    pub dispatcher_down: Vec<(f64, f64)>,
    /// Gray faults on kernels (slowdown / error / hang), drawn at
    /// service start exactly like the cluster DES.
    pub gray: FaultPlan,
}

impl PoolFaults {
    pub fn none() -> PoolFaults {
        PoolFaults::default()
    }
}

/// One pool run's configuration — the three independent knobs (feeder
/// count, kernel count, network budget) plus policy.
#[derive(Debug, Clone)]
pub struct PoolSimConfig {
    pub feeders: usize,
    pub kernels: usize,
    pub hw: HardwareConfig,
    pub depth: usize,
    pub link: LinkModel,
    pub lease: LeasePolicy,
    /// Feeder-side admission valve (outstanding = that feeder's queue).
    pub admission: AdmissionPolicy,
    /// Dispatcher occupancy per transfer, µs — the single-server hop
    /// resource (serialisation of one transfer onto the pool's uplink,
    /// whatever its size). 0 = ideal dispatcher. Mirrors the real
    /// realisation's `transfer_us`, which is what lets the crossval
    /// calibrate the same hop budget into both realisations.
    pub dispatch_us: f64,
    pub overheads: Overheads,
    pub breaker: BreakerConfig,
    pub seed: u64,
    pub faults: PoolFaults,
}

impl PoolSimConfig {
    /// The paper's v2 cloud kernel behind a ToR 10GbE hop, FIFO leases.
    pub fn v2_pool(feeders: usize, kernels: usize) -> PoolSimConfig {
        PoolSimConfig {
            feeders,
            kernels,
            hw: HardwareConfig::v2_aws(4),
            depth: 26,
            link: LinkModel::tor_10g(),
            lease: LeasePolicy::Fifo,
            admission: AdmissionPolicy::QueueCap(64),
            dispatch_us: 0.0,
            overheads: Overheads::default(),
            breaker: BreakerConfig::default(),
            seed: 0xB007,
            faults: PoolFaults::none(),
        }
    }

    pub fn with_lease(mut self, lease: LeasePolicy) -> Self {
        self.lease = lease;
        self
    }

    pub fn with_link(mut self, link: LinkModel) -> Self {
        self.link = link;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> Self {
        self.admission = admission;
        self
    }

    pub fn with_dispatch_us(mut self, dispatch_us: f64) -> Self {
        self.dispatch_us = dispatch_us;
        self
    }

    pub fn with_seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    pub fn with_faults(mut self, faults: PoolFaults) -> Self {
        self.faults = faults;
        self
    }

    pub fn kernel_model(&self) -> FpgaModel {
        FpgaModel::new(self.hw, self.depth)
    }

    /// One feeder's encode-side service time for a batch, µs.
    pub fn feeder_service_us(&self, n: usize) -> f64 {
        self.overheads.sched.us(n) + self.overheads.encode.us(n)
    }

    /// Analytic ceiling of the configuration at `batch`, queries/s —
    /// min(feeder side, kernel side).
    pub fn ceiling_qps(&self, batch: usize) -> f64 {
        let feeder = batch as f64 / self.feeder_service_us(batch) * 1e6;
        let kernel = self.link.kernel_qps(&self.kernel_model(), batch);
        (self.feeders as f64 * feeder).min(self.kernels as f64 * kernel)
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// Request hits a feeder (client zmq cost already paid).
    Arrive { req: usize },
    /// Feeder finished sched+encode for the request.
    FeederDone { feeder: usize, req: usize },
    /// Encoded batch arrived at the pool dispatcher.
    AtDispatcher { req: usize },
    /// Age cap on the oldest buffered batch expired (stale if `seq`
    /// lags the current pack generation).
    FlushTimer { seq: u64 },
    /// Kernel invocation finished (stale if `gen` lags — the lease was
    /// revoked mid-service).
    KernelDone { kernel: usize, gen: u64, xfer: usize },
    /// Transfer cleared the dispatcher's per-transfer hop occupancy and
    /// is ready to be leased to a kernel.
    Lease { xfer: usize },
    /// Forced lease revocation / restoration.
    Revoke { kernel: usize },
    Restore { kernel: usize },
    DispatcherDown,
    DispatcherUp,
    /// Re-attempt held transfers (armed when no kernel was eligible).
    RetryHeld,
}

type EventHeap = BinaryHeap<Reverse<(u64, u64, Event)>>;

fn push_event(heap: &mut EventHeap, seq: &mut u64, t_us: f64, ev: Event) {
    let t_ns = (t_us * 1000.0).round() as u64;
    heap.push(Reverse((t_ns, *seq, ev)));
    *seq += 1;
}

#[derive(Debug, Clone, Copy)]
struct Req {
    at_us: f64,
    n: usize,
    netsend_us: f64,
    done: bool,
}

#[derive(Debug, Default)]
struct Feeder {
    q: VecDeque<usize>,
    busy: bool,
    pending: usize,
}

#[derive(Debug, Default)]
struct Kernel {
    q: VecDeque<usize>,
    busy: Option<usize>,
    /// Bumped on forced revocation: in-flight `KernelDone`s go stale.
    gen: u64,
    forced_down: bool,
    /// Outstanding queries (queued + running) — the lease load metric.
    load_q: usize,
}

#[derive(Debug)]
struct Transfer {
    members: Vec<usize>,
    n: usize,
    service_us: f64,
    ok: bool,
}

/// Run the pool DES and return its report (untraced).
pub fn simulate_pool(cfg: &PoolSimConfig, arrivals: &[SimArrival]) -> PoolReport {
    simulate_pool_traced(cfg, arrivals, &mut NullRecorder)
}

/// Run the pool DES, recording the full request lifecycle (including
/// the `NetSend`/`NetRecv` hops) into `rec`.
pub fn simulate_pool_traced<R: Recorder>(
    cfg: &PoolSimConfig,
    arrivals: &[SimArrival],
    rec: &mut R,
) -> PoolReport {
    assert!(cfg.feeders > 0 && cfg.kernels > 0);
    let hw = cfg.kernel_model();
    let o = &cfg.overheads;
    let link = cfg.link;

    let mut reqs: Vec<Req> = arrivals
        .iter()
        .map(|a| Req { at_us: a.at_us, n: a.n_queries, netsend_us: 0.0, done: false })
        .collect();
    let mut feeders: Vec<Feeder> = (0..cfg.feeders).map(|_| Feeder::default()).collect();
    let mut kernels: Vec<Kernel> = (0..cfg.kernels).map(|_| Kernel::default()).collect();
    let mut breakers: Vec<CircuitBreaker> =
        (0..cfg.kernels).map(|_| CircuitBreaker::new(cfg.breaker)).collect();
    let mut transfers: Vec<Transfer> = Vec::new();

    let mut heap: EventHeap = BinaryHeap::new();
    let mut seq = 0u64;
    for (i, a) in arrivals.iter().enumerate() {
        push_event(&mut heap, &mut seq, a.at_us + o.zmq.request_us(a.n_queries), Event::Arrive {
            req: i,
        });
    }
    for &(t, k, down_for) in &cfg.faults.revoke {
        assert!(k < cfg.kernels, "revocation names kernel {k} of {}", cfg.kernels);
        push_event(&mut heap, &mut seq, t, Event::Revoke { kernel: k });
        push_event(&mut heap, &mut seq, t + down_for, Event::Restore { kernel: k });
    }
    for &(t_down, t_up) in &cfg.faults.dispatcher_down {
        assert!(t_down < t_up);
        push_event(&mut heap, &mut seq, t_down, Event::DispatcherDown);
        push_event(&mut heap, &mut seq, t_up, Event::DispatcherUp);
    }

    let mut gray_rng = Rng::new(cfg.seed ^ 0x62AF_17);
    let mut probe_rng = Rng::new(cfg.seed ^ 0xB007_CAFE);

    // Dispatcher state.
    let mut down = false;
    let mut held_reqs: Vec<usize> = Vec::new();
    let mut held_xfers: Vec<usize> = Vec::new();
    let mut buffer: Vec<usize> = Vec::new();
    let mut buffered_q = 0usize;
    let mut pack_seq = 0u64;
    let mut retry_armed = false;
    let mut switch_free_us = 0.0f64;
    let mut dispatcher_free_us = 0.0f64;

    // Tallies.
    let mut shed_queue = 0usize;
    let mut shed_queries = 0usize;
    let mut completed = 0usize;
    let mut completed_queries = 0usize;
    let mut lost = 0usize;
    let mut failed = 0usize;
    let mut revocations = 0usize;
    let mut net_forward_sum = 0.0f64;
    let mut net_forward_n = 0usize;
    let mut lat = Percentiles::new();
    let mut t_end = 0.0f64;

    macro_rules! try_start_feeder {
        ($f:expr, $now:expr) => {{
            let f = $f;
            if !feeders[f].busy {
                if let Some(r) = feeders[f].q.pop_front() {
                    feeders[f].busy = true;
                    let svc = cfg.feeder_service_us(reqs[r].n);
                    push_event(&mut heap, &mut seq, $now + svc, Event::FeederDone {
                        feeder: f,
                        req: r,
                    });
                }
            }
        }};
    }

    macro_rules! try_start_kernel {
        ($k:expr, $now:expr) => {{
            let k = $k;
            if kernels[k].busy.is_none() && !kernels[k].forced_down {
                if let Some(x) = kernels[k].q.pop_front() {
                    kernels[k].busy = Some(x);
                    let eff = cfg.faults.gray.gray_at(k, $now);
                    let mut svc = link.kernel_invocation_us(&hw, transfers[x].n) * eff.slow_factor;
                    let mut ok = true;
                    if eff.error_p > 0.0 && gray_rng.chance(eff.error_p) {
                        ok = false;
                    }
                    if eff.hang_p > 0.0 && gray_rng.chance(eff.hang_p) {
                        svc += eff.stall_us;
                    }
                    transfers[x].service_us = svc;
                    transfers[x].ok = ok;
                    for &m in &transfers[x].members {
                        rec.record($now, m as u64, StageEvent::ExecStart { replica: k });
                    }
                    push_event(&mut heap, &mut seq, $now + svc, Event::KernelDone {
                        kernel: k,
                        gen: kernels[k].gen,
                        xfer: x,
                    });
                }
            }
        }};
    }

    // Lease a transfer to the least-loaded eligible kernel; hold it if
    // every lease is revoked (breaker open or forced down).
    macro_rules! lease_transfer {
        ($x:expr, $now:expr) => {{
            let x = $x;
            let loads: Vec<usize> = kernels.iter().map(|k| k.load_q).collect();
            let eligible: Vec<bool> = (0..cfg.kernels)
                .map(|k| !kernels[k].forced_down && breakers[k].allows($now, &mut probe_rng))
                .collect();
            match pick_kernel(&loads, &eligible, cfg.seed, x as u64) {
                Some(k) => {
                    for &m in &transfers[x].members {
                        rec.record($now, m as u64, StageEvent::Enqueued { replica: k });
                        net_forward_sum += $now - reqs[m].netsend_us;
                        net_forward_n += 1;
                    }
                    kernels[k].load_q += transfers[x].n;
                    kernels[k].q.push_back(x);
                    try_start_kernel!(k, $now);
                }
                None => {
                    held_xfers.push(x);
                    if !retry_armed {
                        retry_armed = true;
                        push_event(
                            &mut heap,
                            &mut seq,
                            $now + cfg.breaker.open_us + 1.0,
                            Event::RetryHeld,
                        );
                    }
                }
            }
        }};
    }

    // Push a transfer through the dispatcher's single-server hop: it
    // occupies the uplink for `dispatch_us` regardless of size (which is
    // exactly what size-aware packing amortises), then gets leased.
    macro_rules! dispatch_transfer {
        ($x:expr, $now:expr) => {{
            let x = $x;
            if cfg.dispatch_us > 0.0 {
                let start = dispatcher_free_us.max($now);
                dispatcher_free_us = start + cfg.dispatch_us;
                push_event(&mut heap, &mut seq, start + cfg.dispatch_us, Event::Lease {
                    xfer: x,
                });
            } else {
                lease_transfer!(x, $now);
            }
        }};
    }

    macro_rules! flush_pack {
        ($now:expr) => {{
            pack_seq += 1;
            let members = std::mem::take(&mut buffer);
            buffered_q = 0;
            let n: usize = members.iter().map(|&m| reqs[m].n).sum();
            transfers.push(Transfer { members, n, service_us: 0.0, ok: true });
            let x = transfers.len() - 1;
            dispatch_transfer!(x, $now);
        }};
    }

    // Route a dispatcher-side batch per the lease policy.
    macro_rules! dispatch_path {
        ($r:expr, $now:expr) => {{
            let r = $r;
            match cfg.lease {
                LeasePolicy::Fifo => {
                    transfers.push(Transfer {
                        members: vec![r],
                        n: reqs[r].n,
                        service_us: 0.0,
                        ok: true,
                    });
                    let x = transfers.len() - 1;
                    dispatch_transfer!(x, $now);
                }
                LeasePolicy::SizeAware { pack_queries, age_cap_us } => {
                    buffer.push(r);
                    buffered_q += reqs[r].n;
                    if buffered_q >= pack_queries {
                        flush_pack!($now);
                    } else if buffer.len() == 1 {
                        push_event(&mut heap, &mut seq, $now + age_cap_us, Event::FlushTimer {
                            seq: pack_seq,
                        });
                    }
                }
            }
        }};
    }

    // Held and requeued transfers already paid the hop — they re-lease
    // from the dispatcher without a second occupancy charge.
    macro_rules! drain_held_xfers {
        ($now:expr) => {{
            let held = std::mem::take(&mut held_xfers);
            for x in held {
                lease_transfer!(x, $now);
            }
        }};
    }

    while let Some(Reverse((t_ns, _, ev))) = heap.pop() {
        let now = t_ns as f64 / 1000.0;
        t_end = t_end.max(now);
        match ev {
            Event::Arrive { req } => {
                let n = reqs[req].n;
                rec.record(reqs[req].at_us, req as u64, StageEvent::Accepted { n_queries: n });
                let loads: Vec<usize> = feeders.iter().map(|f| f.pending).collect();
                let all: Vec<bool> = vec![true; cfg.feeders];
                let f = pick_kernel(&loads, &all, cfg.seed ^ 0xFEED_F00D, req as u64)
                    .expect("at least one feeder");
                if !cfg.admission.admits(feeders[f].pending, cfg.feeder_service_us(n)) {
                    rec.record(now, req as u64, StageEvent::Shed {
                        lane: ShedLane::Queue,
                        n_queries: n,
                    });
                    reqs[req].done = true;
                    shed_queue += 1;
                    shed_queries += n;
                    continue;
                }
                rec.record(now, req as u64, StageEvent::Admitted);
                rec.record(now, req as u64, StageEvent::AttemptStart {
                    kind: AttemptKind::Primary,
                });
                rec.record(now, req as u64, StageEvent::Routed { replica: f });
                feeders[f].pending += 1;
                feeders[f].q.push_back(req);
                try_start_feeder!(f, now);
            }
            Event::FeederDone { feeder, req } => {
                feeders[feeder].busy = false;
                feeders[feeder].pending -= 1;
                let bytes = encoded_bytes(reqs[req].n, &hw);
                rec.record(now, req as u64, StageEvent::NetSend { bytes });
                reqs[req].netsend_us = now;
                // Port serialisation, then the shared-switch FIFO, then
                // the fixed hop into the pool.
                let depart = now.max(switch_free_us);
                let sw = link.switch_serialization_us(bytes);
                switch_free_us = depart + sw;
                let arrive = depart + sw + link.serialization_us(bytes) + link.hop_us;
                push_event(&mut heap, &mut seq, arrive, Event::AtDispatcher { req });
                try_start_feeder!(feeder, now);
            }
            Event::AtDispatcher { req } => {
                if down {
                    held_reqs.push(req);
                } else {
                    dispatch_path!(req, now);
                }
            }
            Event::FlushTimer { seq: s } => {
                if s == pack_seq && !buffer.is_empty() && !down {
                    flush_pack!(now);
                }
            }
            Event::Lease { xfer } => {
                lease_transfer!(xfer, now);
            }
            Event::KernelDone { kernel, gen, xfer } => {
                if gen != kernels[kernel].gen {
                    continue; // lease revoked mid-service; members already lost
                }
                kernels[kernel].busy = None;
                kernels[kernel].load_q -= transfers[xfer].n;
                let (svc, ok) = (transfers[xfer].service_us, transfers[xfer].ok);
                for &m in &transfers[xfer].members {
                    rec.record(now, m as u64, StageEvent::ExecEnd {
                        replica: kernel,
                        kernel_us: svc,
                        ok,
                    });
                }
                if !ok {
                    failed += transfers[xfer].members.len();
                }
                let was_open = breakers[kernel].state() == BreakerState::Open;
                let norm = svc * 1024.0 / transfers[xfer].n.max(1) as f64;
                breakers[kernel].on_outcome(now, ok, norm);
                if breakers[kernel].state() == BreakerState::Open && !was_open {
                    // Breaker trip = lease revocation: queued transfers
                    // go back to the dispatcher for other kernels.
                    revocations += 1;
                    let queued: Vec<usize> = kernels[kernel].q.drain(..).collect();
                    for x in &queued {
                        kernels[kernel].load_q -= transfers[*x].n;
                    }
                    for x in queued {
                        lease_transfer!(x, now);
                    }
                }
                // Reply path: results stream back over the same link.
                let ser_out = link.serialization_us(result_bytes(transfers[xfer].n));
                let back = now + ser_out + link.hop_us;
                for i in 0..transfers[xfer].members.len() {
                    let m = transfers[xfer].members[i];
                    let n = reqs[m].n;
                    rec.record(back, m as u64, StageEvent::NetRecv {
                        bytes: result_bytes(n),
                    });
                    let done_at = back + o.zmq.reply_us(n);
                    rec.record(done_at, m as u64, StageEvent::Completed { n_queries: n });
                    reqs[m].done = true;
                    completed += 1;
                    completed_queries += n;
                    lat.record(done_at - reqs[m].at_us);
                    t_end = t_end.max(done_at);
                }
                try_start_kernel!(kernel, now);
                drain_held_xfers!(now);
            }
            Event::Revoke { kernel } => {
                revocations += 1;
                kernels[kernel].forced_down = true;
                kernels[kernel].gen += 1;
                if let Some(x) = kernels[kernel].busy.take() {
                    kernels[kernel].load_q -= transfers[x].n;
                    for &m in &transfers[x].members {
                        rec.record(now, m as u64, StageEvent::Lost { n_queries: reqs[m].n });
                        reqs[m].done = true;
                        lost += 1;
                    }
                }
                let queued: Vec<usize> = kernels[kernel].q.drain(..).collect();
                for x in &queued {
                    kernels[kernel].load_q -= transfers[*x].n;
                }
                for x in queued {
                    lease_transfer!(x, now);
                }
            }
            Event::Restore { kernel } => {
                kernels[kernel].forced_down = false;
                drain_held_xfers!(now);
                try_start_kernel!(kernel, now);
            }
            Event::DispatcherDown => down = true,
            Event::DispatcherUp => {
                down = false;
                if !buffer.is_empty() {
                    flush_pack!(now);
                }
                let held = std::mem::take(&mut held_reqs);
                for r in held {
                    dispatch_path!(r, now);
                }
            }
            Event::RetryHeld => {
                retry_armed = false;
                drain_held_xfers!(now);
            }
        }
    }

    // Whatever never terminated (held at a dead dispatcher, leases
    // revoked to the end) is lost — the conservation law still holds.
    for (i, r) in reqs.iter_mut().enumerate() {
        if !r.done {
            rec.record(t_end, i as u64, StageEvent::Lost { n_queries: r.n });
            r.done = true;
            lost += 1;
        }
    }

    let first_at = arrivals.iter().map(|a| a.at_us).fold(f64::INFINITY, f64::min);
    let last_at = arrivals.iter().map(|a| a.at_us).fold(0.0f64, f64::max);
    let total_q: usize = arrivals.iter().map(|a| a.n_queries).sum();
    let span = (last_at - first_at).max(1.0);
    let wall = (t_end - first_at).max(1.0);
    let dispatched = transfers.len() - held_xfers.len();
    let dispatched_q: usize =
        transfers.iter().map(|x| x.n).sum::<usize>() - held_xfers.iter().map(|&x| transfers[x].n).sum::<usize>();

    let report = PoolReport {
        label: format!("pool/{}", cfg.lease.label()),
        feeders: cfg.feeders,
        kernels: cfg.kernels,
        requests: arrivals.len(),
        accepted: arrivals.len() - shed_queue,
        completed,
        shed_queue,
        lost,
        completed_queries,
        shed_queries,
        failed,
        offered_qps: total_q as f64 * 1e6 / span,
        goodput_qps: completed_queries as f64 * 1e6 / wall,
        p50_us: lat.p50(),
        p90_us: lat.p90(),
        p99_us: lat.p99(),
        transfers: dispatched,
        mean_transfer_queries: dispatched_q as f64 / dispatched.max(1) as f64,
        net_forward_mean_us: net_forward_sum / net_forward_n.max(1) as f64,
        revocations,
    };
    assert!(
        report.conserves(),
        "pool conservation violated: {} != {} + {} + {}",
        report.requests,
        report.completed,
        report.shed_queue,
        report.lost
    );
    report
}

/// Saturation goodput of a pool configuration at `batch`: offer 2× the
/// analytic ceiling through Poisson arrivals and measure what completes.
pub fn measure_pool_saturation_qps(cfg: &PoolSimConfig, batch: usize, requests: usize) -> f64 {
    let rate_rps = 2.0 * cfg.ceiling_qps(batch) / batch as f64;
    let arrivals = crate::cluster::sim::poisson_sim_arrivals(
        0xFEED ^ cfg.seed,
        rate_rps,
        batch,
        requests,
        1,
        0.0,
        0,
    );
    simulate_pool(cfg, &arrivals).goodput_qps
}

#[cfg(test)]
mod tests {
    use super::*;

    fn light_arrivals(n_requests: usize, batch: usize, gap_us: f64) -> Vec<SimArrival> {
        (0..n_requests)
            .map(|i| SimArrival {
                at_us: i as f64 * gap_us,
                station: 0,
                n_queries: batch,
                keys: Vec::new(),
            })
            .collect()
    }

    #[test]
    fn light_load_completes_everything_fifo() {
        let cfg = PoolSimConfig::v2_pool(4, 2);
        let arrivals = light_arrivals(200, 1024, 500.0);
        let r = simulate_pool(&cfg, &arrivals);
        assert!(r.conserves());
        assert_eq!(r.completed, 200);
        assert_eq!((r.shed_queue, r.lost, r.revocations), (0, 0, 0));
        assert_eq!(r.transfers, 200, "fifo forwards every batch as its own transfer");
        // Forward span ≥ hop + port serialisation of one batch.
        let hw = cfg.kernel_model();
        let floor = cfg.link.hop_us + cfg.link.serialization_us(encoded_bytes(1024, &hw));
        assert!(r.net_forward_mean_us >= floor - 1e-6);
    }

    #[test]
    fn packing_coalesces_small_batches() {
        let cfg = PoolSimConfig::v2_pool(4, 2)
            .with_lease(LeasePolicy::SizeAware { pack_queries: 4096, age_cap_us: 400.0 });
        let arrivals = light_arrivals(256, 512, 40.0);
        let r = simulate_pool(&cfg, &arrivals);
        assert!(r.conserves());
        assert_eq!(r.completed, 256);
        assert!(
            r.transfers < 256 / 3,
            "size-aware leases must coalesce: {} transfers for 256 batches",
            r.transfers
        );
        assert!(r.mean_transfer_queries >= 1536.0);
    }

    #[test]
    fn pack_age_cap_flushes_a_lone_batch() {
        let cfg = PoolSimConfig::v2_pool(2, 1)
            .with_lease(LeasePolicy::SizeAware { pack_queries: 1 << 20, age_cap_us: 150.0 });
        let arrivals = light_arrivals(3, 256, 5_000.0);
        let r = simulate_pool(&cfg, &arrivals);
        assert_eq!(r.completed, 3, "age cap must flush packs that never fill");
        assert_eq!(r.transfers, 3);
        // Each lone batch waited out its age cap before the lease.
        assert!(r.net_forward_mean_us >= 150.0);
    }

    #[test]
    fn forced_revocation_loses_in_flight_but_conserves() {
        let mut faults = PoolFaults::none();
        // Both kernels yanked mid-run; kernel 0 comes back quickly.
        faults.revoke = vec![(8_000.0, 0, 3_000.0), (12_000.0, 1, 50_000.0)];
        let cfg = PoolSimConfig::v2_pool(4, 2).with_faults(faults);
        let arrivals = light_arrivals(120, 2048, 120.0);
        let r = simulate_pool(&cfg, &arrivals);
        assert!(r.conserves());
        assert!(r.revocations >= 2);
        assert!(r.completed + r.lost + r.shed_queue == 120);
        assert!(r.completed > 80, "pool must keep serving on surviving kernels");
    }

    #[test]
    fn dispatcher_outage_holds_and_drains() {
        let mut faults = PoolFaults::none();
        faults.dispatcher_down = vec![(2_000.0, 9_000.0)];
        let cfg = PoolSimConfig::v2_pool(4, 2).with_faults(faults);
        let arrivals = light_arrivals(80, 1024, 100.0);
        let r = simulate_pool(&cfg, &arrivals);
        assert!(r.conserves());
        assert_eq!(r.completed, 80, "held batches must drain at revival");
        assert!(r.p99_us > 6_000.0, "outage must show up as latency");
    }

    #[test]
    fn replays_are_bit_identical() {
        let mut faults = PoolFaults::none();
        faults.revoke = vec![(5_000.0, 1, 2_000.0)];
        faults.dispatcher_down = vec![(9_000.0, 11_000.0)];
        let cfg = PoolSimConfig::v2_pool(3, 2)
            .with_lease(LeasePolicy::SizeAware { pack_queries: 2048, age_cap_us: 200.0 })
            .with_faults(faults);
        let arrivals = crate::cluster::sim::poisson_sim_arrivals(7, 4_000.0, 512, 300, 1, 0.0, 0);
        let a = simulate_pool(&cfg, &arrivals);
        let b = simulate_pool(&cfg, &arrivals);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.transfers, b.transfers);
        assert_eq!(a.p99_us, b.p99_us);
        assert_eq!(a.goodput_qps, b.goodput_qps);
    }

    #[test]
    fn saturated_pool_tracks_the_kernel_ceiling() {
        // 10 feeders on 3 kernels at the §6.1 batch: kernel-bound.
        let cfg = PoolSimConfig::v2_pool(10, 3);
        let batch = 16_384;
        let kernel_ceiling = 3.0 * cfg.link.kernel_qps(&cfg.kernel_model(), batch);
        let goodput = measure_pool_saturation_qps(&cfg, batch, 400);
        assert!(
            goodput > 0.85 * kernel_ceiling,
            "pool goodput {goodput:.0} must approach the kernel ceiling {kernel_ceiling:.0}"
        );
        assert!(goodput < 1.02 * kernel_ceiling);
    }

    #[test]
    fn narrow_dispatch_hop_binds_fifo_and_packing_amortises_it() {
        // A 400µs-per-transfer hop caps fifo at batch/400µs; size-aware
        // packing ships 8 batches per occupancy slot and sails past it.
        let batch = 2048;
        let dispatch_us = 400.0;
        let fifo = PoolSimConfig::v2_pool(8, 3).with_dispatch_us(dispatch_us);
        let pack = fifo.clone().with_lease(LeasePolicy::SizeAware {
            pack_queries: 8 * batch,
            age_cap_us: 3_000.0,
        });
        let hop_qps = batch as f64 / dispatch_us * 1e6;
        let g_fifo = measure_pool_saturation_qps(&fifo, batch, 300);
        let g_pack = measure_pool_saturation_qps(&pack, batch, 300);
        assert!(
            g_fifo < 1.05 * hop_qps,
            "fifo goodput {g_fifo:.0} must be pinned near the hop ceiling {hop_qps:.0}"
        );
        assert!(
            g_pack > 1.5 * g_fifo,
            "packing ({g_pack:.0}) must amortise the hop past fifo ({g_fifo:.0})"
        );
    }
}
