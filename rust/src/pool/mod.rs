//! Disaggregated network-attached FPGA pool.
//!
//! The PCIe topology couples feeders and kernels 1:1 inside a node: a
//! weak feeder (§6.1) strands kernel capacity, and the only remedy is
//! buying whole nodes. This module decouples them — M feeders encode
//! locally and submit batches over a modelled network hop to a shared
//! pool of N kernels, so feeder count, kernel count, and network budget
//! become three independent knobs (the cloudFPGA-style disaggregation
//! of Snippet 1's 64-FPGA chassis).
//!
//! Both realisations share this module's vocabulary:
//!
//! - [`LinkModel`] — per-hop latency + bandwidth-proportional
//!   serialisation per encoded batch + optional shared-switch ceiling.
//! - [`LeasePolicy`] — how the pool dispatcher packs feeder batches
//!   into kernel leases ([`LeasePolicy::Fifo`] forwards each batch as
//!   its own transfer; [`LeasePolicy::SizeAware`] coalesces small
//!   batches to amortise the hop, bounded by a deadline-aware age cap).
//! - [`pick_kernel`] — least-loaded eligible kernel, ties broken by the
//!   shared splitmix64 finalizer so both realisations agree.
//! - [`PoolReport`] — the conservation-law-carrying result surface.
//!
//! [`sim`] is the deterministic DES realisation; [`real`] drives real
//! threads through a pool-dispatcher hop over the cluster's tagged
//! completion plumbing.

pub mod real;
pub mod sim;

use crate::erbium::hw_model::{FpgaModel, RESULT_BYTES};
use crate::prng::mix64;

/// Per-invocation kernel setup over the network shell (lease tag
/// validation + descriptor exchange), µs. Replaces the PCIe shell's
/// DMA setup; cloudFPGA-style TCP/UDP offload keeps it flat.
pub const POOL_SETUP_US: f64 = 10.0;

/// Streaming overlap residue: the shell overlaps deserialisation,
/// compute, and result serialisation; the non-dominant phases cost this
/// fraction beyond the dominant one (same residue the QDMA streaming
/// shell model uses for PCIe).
pub const POOL_OVERLAP_RESIDUE: f64 = 0.08;

/// Encoded payload of a batch on the wire, bytes (2 bytes per level of
/// the v2 mapping tree per query — identical to the PCIe encoding).
pub fn encoded_bytes(n_queries: usize, hw: &FpgaModel) -> usize {
    (n_queries as f64 * hw.query_bytes()) as usize
}

/// Result payload of a batch on the wire, bytes.
pub fn result_bytes(n_queries: usize) -> usize {
    (n_queries as f64 * RESULT_BYTES) as usize
}

/// The modelled network hop between a feeder and the kernel pool.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkModel {
    /// One-way propagation + switching latency per hop, µs.
    pub hop_us: f64,
    /// Per-port line rate, Gbit/s (serialisation cost of a batch is
    /// proportional to its encoded bytes at this rate).
    pub gbps: f64,
    /// Shared-switch bisection ceiling, Gbit/s. `Some` models transfers
    /// from all feeders contending for one uplink fabric (a FIFO at
    /// this rate in the DES); `None` models an ideal non-blocking
    /// fabric.
    pub switch_gbps: Option<f64>,
}

impl LinkModel {
    /// A top-of-rack 10GbE port into a cloudFPGA-style sled: 5 µs hop,
    /// 10 Gb/s per port, 640 Gb/s shared sled switch (64 ports).
    pub fn tor_10g() -> LinkModel {
        LinkModel { hop_us: 5.0, gbps: 10.0, switch_gbps: Some(640.0) }
    }

    /// Serialisation time of `bytes` at the per-port line rate, µs.
    pub fn serialization_us(&self, bytes: usize) -> f64 {
        bytes as f64 * 8.0 / (self.gbps * 1e3)
    }

    /// Serialisation time of `bytes` through the shared switch fabric,
    /// µs (equals the per-port cost when no switch ceiling is set).
    pub fn switch_serialization_us(&self, bytes: usize) -> f64 {
        match self.switch_gbps {
            Some(g) => bytes as f64 * 8.0 / (g * 1e3),
            None => 0.0,
        }
    }

    /// One network-attached kernel invocation over `batch` queries, µs.
    ///
    /// Same streaming composition as the QDMA PCIe shell — setup plus
    /// the dominant of {deserialise-in, compute, serialise-out} with an
    /// [`POOL_OVERLAP_RESIDUE`] tax on the overlapped phases — but with
    /// PCIe transfer replaced by network serialisation at the port
    /// rate. The hop latency itself is *not* included: it is pipelined
    /// across back-to-back invocations and belongs to the request's
    /// network span, not the kernel's occupancy.
    pub fn kernel_invocation_us(&self, hw: &FpgaModel, batch: usize) -> f64 {
        let ser_in = self.serialization_us(encoded_bytes(batch, hw));
        let ser_out = self.serialization_us(result_bytes(batch));
        let compute = hw.batch_timing(batch).compute_us;
        let max = ser_in.max(compute).max(ser_out);
        let sum = ser_in + compute + ser_out;
        POOL_SETUP_US + max + POOL_OVERLAP_RESIDUE * (sum - max)
    }

    /// Steady-state per-kernel ceiling at `batch`, queries/s.
    pub fn kernel_qps(&self, hw: &FpgaModel, batch: usize) -> f64 {
        batch as f64 / self.kernel_invocation_us(hw, batch) * 1e6
    }
}

/// How the pool dispatcher turns feeder batches into kernel leases.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum LeasePolicy {
    /// Every feeder batch becomes its own transfer and lease, in
    /// arrival order. Simple, but each small batch pays the full hop.
    Fifo,
    /// Coalesce queued batches into one transfer until `pack_queries`
    /// queries are buffered, bounded by a deadline-aware age cap: the
    /// pack flushes early once its oldest member has waited
    /// `age_cap_us`, so coalescing never costs more latency than the
    /// hop it amortises.
    SizeAware { pack_queries: usize, age_cap_us: f64 },
}

/// Default coalescing target, queries per transfer.
pub const DEFAULT_PACK_QUERIES: usize = 8_192;
/// Default age cap on the oldest buffered batch, µs.
pub const DEFAULT_PACK_AGE_US: f64 = 200.0;

impl LeasePolicy {
    /// The size-aware policy at its defaults.
    pub fn packing() -> LeasePolicy {
        LeasePolicy::SizeAware {
            pack_queries: DEFAULT_PACK_QUERIES,
            age_cap_us: DEFAULT_PACK_AGE_US,
        }
    }

    pub fn label(&self) -> String {
        match *self {
            LeasePolicy::Fifo => "fifo".to_string(),
            LeasePolicy::SizeAware { pack_queries, age_cap_us } => {
                format!("pack:{pack_queries}:{age_cap_us:.0}")
            }
        }
    }

    /// Parse a CLI spec: `fifo`, `pack`, `pack:<queries>`, or
    /// `pack:<queries>:<age_us>`.
    pub fn parse(s: &str) -> Option<LeasePolicy> {
        let mut parts = s.split(':');
        match parts.next()? {
            "fifo" => parts.next().is_none().then_some(LeasePolicy::Fifo),
            "pack" => {
                let pack_queries = match parts.next() {
                    Some(q) => q.parse().ok()?,
                    None => DEFAULT_PACK_QUERIES,
                };
                let age_cap_us = match parts.next() {
                    Some(a) => a.parse().ok()?,
                    None => DEFAULT_PACK_AGE_US,
                };
                parts.next().is_none().then_some(LeasePolicy::SizeAware {
                    pack_queries,
                    age_cap_us,
                })
            }
            _ => None,
        }
    }
}

/// Least-loaded eligible kernel, deterministic across realisations:
/// ties are broken by hashing `(seed, transfer id, kernel)` through the
/// shared splitmix64 finalizer, so neither realisation's iteration
/// order leaks into placement. Returns `None` when no kernel is
/// eligible (all leases revoked / breakers open).
pub fn pick_kernel(loads: &[usize], eligible: &[bool], seed: u64, transfer_id: u64) -> Option<usize> {
    debug_assert_eq!(loads.len(), eligible.len());
    let mut best: Option<(usize, u64, usize)> = None;
    for k in 0..loads.len() {
        if !eligible[k] {
            continue;
        }
        let tie = mix64(seed ^ transfer_id.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ k as u64);
        let cand = (loads[k], tie, k);
        if best.map_or(true, |b| (cand.0, cand.1) < (b.0, b.1)) {
            best = Some(cand);
        }
    }
    best.map(|(_, _, k)| k)
}

/// Result surface of one pool run — identical fields in both
/// realisations so the cross-validation harness compares them 1:1.
#[derive(Debug, Clone)]
pub struct PoolReport {
    /// `"pool/<lease label>"` for dashboards and bench JSON.
    pub label: String,
    pub feeders: usize,
    pub kernels: usize,
    /// Requests offered (arrivals).
    pub requests: usize,
    /// Requests past feeder admission.
    pub accepted: usize,
    pub completed: usize,
    /// Requests shed by feeder-side admission (queue cap).
    pub shed_queue: usize,
    /// Requests that failed with no path to completion (lease revoked
    /// mid-flight with the backend erroring, dispatcher dead at drain).
    pub lost: usize,
    pub completed_queries: usize,
    pub shed_queries: usize,
    /// Backend invocations that returned not-ok (feeds the breakers;
    /// the requests themselves still terminate).
    pub failed: usize,
    pub offered_qps: f64,
    pub goodput_qps: f64,
    pub p50_us: f64,
    pub p90_us: f64,
    pub p99_us: f64,
    /// Network transfers the dispatcher issued (= kernel leases).
    pub transfers: usize,
    /// Mean queries per transfer — the packing amortisation knob.
    pub mean_transfer_queries: f64,
    /// Mean feeder→kernel network span (hop + serialisation + pack
    /// wait), µs.
    pub net_forward_mean_us: f64,
    /// Kernel leases revoked by breaker trips or forced faults.
    pub revocations: usize,
}

impl PoolReport {
    /// The conservation law: every offered request terminates in
    /// exactly one lane.
    pub fn conserves(&self) -> bool {
        self.requests == self.completed + self.shed_queue + self.lost
    }

    pub fn summary(&self) -> String {
        format!(
            "{:<14} {:>2}f:{:<2}k  goodput {:>9.0} q/s  p50 {:>7.0}µs  p99 {:>8.0}µs  \
             xfers {:>6} ({:>6.0} q/xfer)  net {:>6.1}µs  shed {:>5}  lost {:>3}  revoked {}",
            self.label,
            self.feeders,
            self.kernels,
            self.goodput_qps,
            self.p50_us,
            self.p99_us,
            self.transfers,
            self.mean_transfer_queries,
            self.net_forward_mean_us,
            self.shed_queue,
            self.lost,
            self.revocations,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::HardwareConfig;

    fn hw() -> FpgaModel {
        FpgaModel::new(HardwareConfig::v2_aws(4), 26)
    }

    #[test]
    fn serialization_follows_the_line_rate() {
        let link = LinkModel::tor_10g();
        // 1250 bytes = 10_000 bits at 10 Gb/s = 1 µs.
        assert!((link.serialization_us(1250) - 1.0).abs() < 1e-12);
        // The shared 640 Gb/s fabric moves the same payload 64× faster.
        assert!((link.switch_serialization_us(1250) - 1.0 / 64.0).abs() < 1e-12);
        let ideal = LinkModel { switch_gbps: None, ..link };
        assert_eq!(ideal.switch_serialization_us(1 << 20), 0.0);
    }

    #[test]
    fn kernel_invocation_is_the_streaming_composition() {
        let link = LinkModel::tor_10g();
        let hw = hw();
        let batch = 16_384;
        let ser_in = link.serialization_us(encoded_bytes(batch, &hw));
        let ser_out = link.serialization_us(result_bytes(batch));
        let compute = hw.batch_timing(batch).compute_us;
        let max = ser_in.max(compute).max(ser_out);
        let want =
            POOL_SETUP_US + max + POOL_OVERLAP_RESIDUE * (ser_in + compute + ser_out - max);
        assert!((link.kernel_invocation_us(&hw, batch) - want).abs() < 1e-9);
        // At v2 depth 26 on 10GbE the network-attached kernel still
        // clears the §6.1 weak feeder's ~6.8M q/s by a wide margin.
        assert!(link.kernel_qps(&hw, batch) > 1.5e7);
        // More bandwidth can only help.
        let fat = LinkModel { gbps: 100.0, ..link };
        assert!(fat.kernel_invocation_us(&hw, batch) <= link.kernel_invocation_us(&hw, batch));
    }

    #[test]
    fn lease_policy_parse_round_trips() {
        assert_eq!(LeasePolicy::parse("fifo"), Some(LeasePolicy::Fifo));
        assert_eq!(LeasePolicy::parse("pack"), Some(LeasePolicy::packing()));
        assert_eq!(
            LeasePolicy::parse("pack:1024:500"),
            Some(LeasePolicy::SizeAware { pack_queries: 1024, age_cap_us: 500.0 })
        );
        assert_eq!(
            LeasePolicy::parse("pack:1024"),
            Some(LeasePolicy::SizeAware { pack_queries: 1024, age_cap_us: DEFAULT_PACK_AGE_US })
        );
        assert_eq!(LeasePolicy::parse("lru"), None);
        assert_eq!(LeasePolicy::parse("fifo:3"), None);
        for p in [LeasePolicy::Fifo, LeasePolicy::packing()] {
            assert_eq!(LeasePolicy::parse(&p.label()), Some(p));
        }
    }

    #[test]
    fn pick_kernel_is_least_loaded_and_deterministic() {
        let loads = [3, 1, 1, 5];
        let all = [true; 4];
        // Least-loaded wins outright.
        assert!(matches!(pick_kernel(&[2, 0, 1, 1], &all, 7, 0), Some(1)));
        // Ties resolve by hash — stable across calls, spread across ids.
        let a = pick_kernel(&loads, &all, 42, 9).unwrap();
        assert_eq!(pick_kernel(&loads, &all, 42, 9), Some(a));
        assert!(a == 1 || a == 2);
        let spread: std::collections::HashSet<usize> =
            (0..64).map(|id| pick_kernel(&loads, &all, 42, id).unwrap()).collect();
        assert_eq!(spread, [1, 2].into_iter().collect());
        // Eligibility masks revoked leases; no kernel ⇒ None.
        assert_eq!(pick_kernel(&loads, &[false, false, false, true], 42, 9), Some(3));
        assert_eq!(pick_kernel(&loads, &[false; 4], 42, 9), None);
    }

    #[test]
    fn conservation_checks_all_three_lanes() {
        let mut r = PoolReport {
            label: "pool/fifo".to_string(),
            feeders: 4,
            kernels: 2,
            requests: 100,
            accepted: 93,
            completed: 90,
            shed_queue: 7,
            lost: 3,
            completed_queries: 90 * 128,
            shed_queries: 7 * 128,
            failed: 1,
            offered_qps: 1e6,
            goodput_qps: 9e5,
            p50_us: 300.0,
            p90_us: 500.0,
            p99_us: 900.0,
            transfers: 20,
            mean_transfer_queries: 576.0,
            net_forward_mean_us: 40.0,
            revocations: 1,
        };
        assert!(r.conserves());
        assert!(r.summary().contains("pool/fifo"));
        r.lost = 2;
        assert!(!r.conserves());
    }
}
