//! Minimal benchmarking toolkit (criterion is not available offline): warm
//! timing loops, robust statistics, paper-style table printing, and a tiny
//! JSON emitter (serde is likewise unavailable) shared by every
//! `rust/benches/*` target — machine-readable `BENCH_*.json` files are how
//! the CI tracks the perf trajectory across PRs.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn p50_us(&self) -> f64 {
        self.p50_ns / 1e3
    }
}

/// Time `f` with warmup; auto-scales iterations to roughly `budget_ms`.
pub fn measure<F: FnMut()>(budget_ms: f64, mut f: F) -> Stats {
    // Warmup + calibration run.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms * 1e6 / once_ns) as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    Stats {
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        p50_ns: samples[iters / 2],
        min_ns: samples[0],
        iters,
    }
}

/// Human formatting for µs quantities spanning µs → s.
pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1} µs")
    } else if us < 1e6 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{:.3} s", us / 1e6)
    }
}

/// Human formatting for queries/second.
pub fn fmt_qps(qps: f64) -> String {
    if qps >= 1e6 {
        format!("{:.1} M q/s", qps / 1e6)
    } else if qps >= 1e3 {
        format!("{:.1} k q/s", qps / 1e3)
    } else {
        format!("{qps:.0} q/s")
    }
}

/// Print a markdown-ish aligned table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
        }
        s
    };
    println!("{}", fmt_row(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Minimal JSON value for `BENCH_*.json` emission (no serde offline).
#[derive(Debug, Clone)]
pub enum Json {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffable artifacts).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience constructor for object literals.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn render_into(&self, out: &mut String) {
        match self {
            // JSON has no NaN/inf; emit null rather than an invalid token.
            Json::Num(x) if !x.is_finite() => out.push_str("null"),
            Json::Num(x) => out.push_str(&format!("{x}")),
            Json::Int(x) => out.push_str(&format!("{x}")),
            Json::Str(s) => Self::escape(s, out),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }
}

/// Write a JSON value to `path` (with a trailing newline) and echo the
/// path, so bench logs say where the artifact landed.
pub fn write_json(path: &str, value: &Json) -> std::io::Result<()> {
    std::fs::write(path, value.render() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_sane_stats() {
        let s = measure(5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.mean_ns * 3.0);
        assert!(s.iters >= 3);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_us(12.34), "12.3 µs");
        assert_eq!(fmt_us(12_340.0), "12.34 ms");
        assert_eq!(fmt_qps(32e6), "32.0 M q/s");
    }

    #[test]
    fn json_renders_stably() {
        let j = Json::obj([
            ("bench", Json::Str("hotpath".into())),
            ("qps", Json::Num(1234.5)),
            ("n", Json::Int(8192)),
            ("ok", Json::Bool(true)),
            ("bad", Json::Num(f64::NAN)),
            ("rows", Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"bench":"hotpath","qps":1234.5,"n":8192,"ok":true,"bad":null,"rows":[1,2]}"#
        );
    }

    #[test]
    fn json_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }
}
