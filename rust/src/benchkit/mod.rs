//! Minimal benchmarking toolkit (criterion is not available offline): warm
//! timing loops, robust statistics, paper-style table printing, and a tiny
//! JSON emitter (serde is likewise unavailable) shared by every
//! `rust/benches/*` target — machine-readable `BENCH_*.json` files are how
//! the CI tracks the perf trajectory across PRs.

use std::time::Instant;

/// Timing statistics over repeated runs.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub mean_ns: f64,
    pub p50_ns: f64,
    pub min_ns: f64,
    pub iters: usize,
}

impl Stats {
    pub fn mean_us(&self) -> f64 {
        self.mean_ns / 1e3
    }
    pub fn p50_us(&self) -> f64 {
        self.p50_ns / 1e3
    }
}

/// Time `f` with warmup; auto-scales iterations to roughly `budget_ms`.
pub fn measure<F: FnMut()>(budget_ms: f64, mut f: F) -> Stats {
    // Warmup + calibration run.
    let t0 = Instant::now();
    f();
    let once_ns = t0.elapsed().as_nanos().max(1) as f64;
    let iters = ((budget_ms * 1e6 / once_ns) as usize).clamp(3, 10_000);
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as f64);
    }
    samples.sort_by(f64::total_cmp);
    Stats {
        mean_ns: samples.iter().sum::<f64>() / iters as f64,
        p50_ns: samples[iters / 2],
        min_ns: samples[0],
        iters,
    }
}

/// Human formatting for µs quantities spanning µs → s.
pub fn fmt_us(us: f64) -> String {
    if us < 1e3 {
        format!("{us:.1} µs")
    } else if us < 1e6 {
        format!("{:.2} ms", us / 1e3)
    } else {
        format!("{:.3} s", us / 1e6)
    }
}

/// Human formatting for queries/second.
pub fn fmt_qps(qps: f64) -> String {
    if qps >= 1e6 {
        format!("{:.1} M q/s", qps / 1e6)
    } else if qps >= 1e3 {
        format!("{:.1} k q/s", qps / 1e3)
    } else {
        format!("{qps:.0} q/s")
    }
}

/// Print a markdown-ish aligned table.
pub fn print_table(title: &str, headers: &[&str], rows: &[Vec<String>]) {
    println!("\n### {title}\n");
    let mut widths: Vec<usize> = headers.iter().map(|h| h.len()).collect();
    for row in rows {
        for (i, cell) in row.iter().enumerate() {
            if i < widths.len() {
                widths[i] = widths[i].max(cell.len());
            }
        }
    }
    let fmt_row = |cells: &[String]| {
        let mut s = String::from("|");
        for (i, c) in cells.iter().enumerate() {
            s.push_str(&format!(" {:<w$} |", c, w = widths.get(i).copied().unwrap_or(4)));
        }
        s
    };
    println!("{}", fmt_row(&headers.iter().map(|h| h.to_string()).collect::<Vec<_>>()));
    println!(
        "|{}|",
        widths.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("|")
    );
    for row in rows {
        println!("{}", fmt_row(row));
    }
}

/// Minimal JSON value for `BENCH_*.json` emission (no serde offline).
#[derive(Debug, Clone)]
pub enum Json {
    Num(f64),
    Int(i64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    /// Insertion-ordered object (stable output for diffable artifacts).
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Convenience constructor for object literals.
    pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    fn escape(s: &str, out: &mut String) {
        out.push('"');
        for c in s.chars() {
            match c {
                '"' => out.push_str("\\\""),
                '\\' => out.push_str("\\\\"),
                '\n' => out.push_str("\\n"),
                '\t' => out.push_str("\\t"),
                '\r' => out.push_str("\\r"),
                c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                c => out.push(c),
            }
        }
        out.push('"');
    }

    fn render_into(&self, out: &mut String) {
        match self {
            // JSON has no NaN/inf; emit null rather than an invalid token.
            Json::Num(x) if !x.is_finite() => out.push_str("null"),
            Json::Num(x) => out.push_str(&format!("{x}")),
            Json::Int(x) => out.push_str(&format!("{x}")),
            Json::Str(s) => Self::escape(s, out),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(kvs) => {
                out.push('{');
                for (i, (k, v)) in kvs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Self::escape(k, out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    pub fn render(&self) -> String {
        let mut s = String::new();
        self.render_into(&mut s);
        s
    }

    /// Parse a JSON document (the inverse of [`Json::render`], plus
    /// whitespace and `null` → `Num(NAN)` round-tripping). Benches *emit*
    /// artifacts; the library also *reads* them back — e.g. the cost model
    /// pulls measured node throughput out of `BENCH_hotpath.json` — and
    /// serde is not available offline, so this is a small recursive-descent
    /// parser over the subset `render` produces (which is all of JSON minus
    /// exotic escapes).
    pub fn parse(text: &str) -> Option<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos == p.bytes.len() {
            Some(v)
        } else {
            None // trailing garbage
        }
    }

    /// Object field lookup (None for non-objects and missing keys).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(kvs) => kvs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Walk a path of object keys.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        keys.iter().try_fold(self, |j, k| j.get(k))
    }

    /// Numeric view: `Num` or `Int` (ints are exact up to 2^53 as f64,
    /// far beyond any bench counter we emit).
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            Json::Int(x) => Some(*x as f64),
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Int(x) => Some(*x),
            Json::Num(x) if x.fract() == 0.0 => Some(*x as i64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
}

/// Recursive-descent state for [`Json::parse`].
struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl Parser<'_> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, b: u8) -> Option<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Some(())
        } else {
            None
        }
    }

    fn lit(&mut self, word: &str, value: Json) -> Option<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Some(value)
        } else {
            None
        }
    }

    fn value(&mut self) -> Option<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => self.string().map(Json::Str),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            // render() emits null for non-finite numbers; round-trip it as
            // a NaN Num so readers can see "a number was here, but bad".
            b'n' => self.lit("null", Json::Num(f64::NAN)),
            _ => self.number(),
        }
    }

    fn object(&mut self) -> Option<Json> {
        self.eat(b'{')?;
        let mut kvs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Some(Json::Obj(kvs));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            kvs.push((k, v));
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Some(Json::Obj(kvs));
                }
                _ => return None,
            }
        }
    }

    fn array(&mut self) -> Option<Json> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Some(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Some(Json::Arr(xs));
                }
                _ => return None,
            }
        }
    }

    fn string(&mut self) -> Option<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek()? {
                b'"' => {
                    self.pos += 1;
                    return Some(s);
                }
                b'\\' => {
                    self.pos += 1;
                    match self.peek()? {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'n' => s.push('\n'),
                        b't' => s.push('\t'),
                        b'r' => s.push('\r'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'u' => {
                            let hex = self.bytes.get(self.pos + 1..self.pos + 5)?;
                            let code =
                                u32::from_str_radix(std::str::from_utf8(hex).ok()?, 16).ok()?;
                            s.push(char::from_u32(code)?);
                            self.pos += 4;
                        }
                        _ => return None,
                    }
                    self.pos += 1;
                }
                // Multi-byte UTF-8: copy the whole scalar, not byte by byte.
                _ => {
                    let rest = std::str::from_utf8(&self.bytes[self.pos..]).ok()?;
                    let c = rest.chars().next()?;
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Option<Json> {
        let start = self.pos;
        while matches!(
            self.peek(),
            Some(b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        ) {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).ok()?;
        if text.is_empty() {
            return None;
        }
        // Integers stay Int (counters survive a round trip); the rest Num.
        if !text.contains(['.', 'e', 'E']) {
            if let Ok(i) = text.parse::<i64>() {
                return Some(Json::Int(i));
            }
        }
        text.parse::<f64>().ok().map(Json::Num)
    }
}

/// Write a JSON value to `path` (with a trailing newline) and echo the
/// path, so bench logs say where the artifact landed.
pub fn write_json(path: &str, value: &Json) -> std::io::Result<()> {
    std::fs::write(path, value.render() + "\n")?;
    println!("wrote {path}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_produces_sane_stats() {
        let s = measure(5.0, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(s.min_ns <= s.p50_ns);
        assert!(s.p50_ns <= s.mean_ns * 3.0);
        assert!(s.iters >= 3);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_us(12.34), "12.3 µs");
        assert_eq!(fmt_us(12_340.0), "12.34 ms");
        assert_eq!(fmt_qps(32e6), "32.0 M q/s");
    }

    #[test]
    fn json_renders_stably() {
        let j = Json::obj([
            ("bench", Json::Str("hotpath".into())),
            ("qps", Json::Num(1234.5)),
            ("n", Json::Int(8192)),
            ("ok", Json::Bool(true)),
            ("bad", Json::Num(f64::NAN)),
            ("rows", Json::Arr(vec![Json::Int(1), Json::Int(2)])),
        ]);
        assert_eq!(
            j.render(),
            r#"{"bench":"hotpath","qps":1234.5,"n":8192,"ok":true,"bad":null,"rows":[1,2]}"#
        );
    }

    #[test]
    fn json_escapes_strings() {
        let j = Json::Str("a\"b\\c\nd".into());
        assert_eq!(j.render(), r#""a\"b\\c\nd""#);
    }

    #[test]
    fn json_parse_roundtrips_render() {
        let j = Json::obj([
            ("bench", Json::Str("hotpath".into())),
            ("qps", Json::Num(1234.5)),
            ("n", Json::Int(8192)),
            ("ok", Json::Bool(true)),
            ("rows", Json::Arr(vec![Json::Int(1), Json::Int(-2), Json::Num(3.25)])),
            ("nested", Json::obj([("s", Json::Str("a\"b\\c\nd".into()))])),
            ("empty_obj", Json::Obj(vec![])),
            ("empty_arr", Json::Arr(vec![])),
        ]);
        let parsed = Json::parse(&j.render()).expect("parse back what we render");
        assert_eq!(parsed.render(), j.render());
    }

    #[test]
    fn json_parse_accessors_walk_bench_artifacts() {
        let text = r#"{
            "schema_version": 2,
            "trajectory": {
                "lockstep_sharded": { "qps": 1.25e7, "feeders_to_saturate": 3 }
            },
            "smoke": false,
            "label": "hotpath"
        }"#;
        let j = Json::parse(text).unwrap();
        assert_eq!(j.get("schema_version").and_then(Json::as_i64), Some(2));
        assert_eq!(
            j.path(&["trajectory", "lockstep_sharded", "qps"]).and_then(Json::as_f64),
            Some(1.25e7)
        );
        assert_eq!(
            j.path(&["trajectory", "lockstep_sharded", "feeders_to_saturate"])
                .and_then(Json::as_i64),
            Some(3)
        );
        assert_eq!(j.get("smoke").and_then(Json::as_bool), Some(false));
        assert_eq!(j.get("label").and_then(Json::as_str), Some("hotpath"));
        assert!(j.get("missing").is_none());
        assert!(j.path(&["trajectory", "missing", "qps"]).is_none());
    }

    #[test]
    fn json_parse_rejects_malformed() {
        for bad in [
            "",
            "{",
            "[1,]",
            "{\"a\":}",
            "{\"a\" 1}",
            "{\"a\":1} extra",
            "\"unterminated",
            "nul",
            "--3",
        ] {
            assert!(Json::parse(bad).is_none(), "should reject {bad:?}");
        }
        // null round-trips as a NaN number (render emits null for those).
        match Json::parse("null") {
            Some(Json::Num(x)) => assert!(x.is_nan()),
            other => panic!("null parsed as {other:?}"),
        }
    }
}
