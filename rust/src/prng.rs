//! Deterministic pseudo-random number generation.
//!
//! The offline registry (crates-io replacement) ships no `rand`; every
//! stochastic component in the reproduction (rule generator, workload
//! traces, property tests) needs *seeded, stable* streams anyway so that
//! experiments regenerate identically. We implement splitmix64 (seeding)
//! and xoshiro256** (bulk generation), the standard public-domain pair.

/// The splitmix64 increment (the 64-bit golden-ratio constant).
const SPLITMIX64_GOLDEN: u64 = 0x9E37_79B9_7F4A_7C15;

/// The splitmix64 xor-multiply avalanche over an already-incremented state.
#[inline]
fn splitmix64_avalanche(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless splitmix64 finalizer: a cheap, well-mixed `u64 → u64` hash
/// (golden-ratio increment + xor-multiply avalanche). `mix64(x)` equals
/// what [`splitmix64`] would emit from state `x` without advancing any
/// state — the one splitmix definition shared by trace sampling
/// ([`crate::telemetry::TraceSpec::keeps`]) and the kernel-pool lease
/// scheduler's deterministic tie-breaking.
#[inline]
pub fn mix64(x: u64) -> u64 {
    splitmix64_avalanche(x.wrapping_add(SPLITMIX64_GOLDEN))
}

/// splitmix64 step: used to expand a single `u64` seed into a full
/// xoshiro256** state and as a cheap standalone mixer.
#[inline]
pub fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(SPLITMIX64_GOLDEN);
    splitmix64_avalanche(*state)
}

/// xoshiro256** deterministic PRNG.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Construct from a 64-bit seed (expanded via splitmix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (for per-entity determinism).
    pub fn fork(&mut self, tag: u64) -> Rng {
        let mut sm = self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15);
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. `n` must be > 0.
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free-enough reduction; bias is
        // negligible for our n << 2^64 and determinism is what matters.
        ((self.next_u64() as u128 * n as u128) >> 64) as u64
    }

    /// Uniform usize in `[0, n)`.
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        self.below(n as u64) as usize
    }

    /// Uniform in `[lo, hi]` inclusive.
    #[inline]
    pub fn range_u32(&mut self, lo: u32, hi: u32) -> u32 {
        debug_assert!(lo <= hi);
        lo + self.below((hi - lo) as u64 + 1) as u32
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli trial.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a reference from a non-empty slice.
    #[inline]
    pub fn pick<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.index(xs.len())]
    }

    /// Sample an index from a discrete weight vector (weights ≥ 0, sum > 0).
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut x = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if x < *w {
                return i;
            }
            x -= w;
        }
        weights.len() - 1
    }

    /// Zipf-like skewed index in `[0, n)` with exponent `s` (s=0 ⇒ uniform).
    ///
    /// Used for airport/carrier popularity: real MCT traffic is highly
    /// skewed towards hub airports (§5.2's cache-for-selected-airports
    /// optimisation only pays off under skew).
    pub fn zipf(&mut self, n: usize, s: f64) -> usize {
        debug_assert!(n > 0);
        if s == 0.0 {
            return self.index(n);
        }
        // Inverse-CDF on the continuous approximation; deterministic and
        // cheap, accuracy is irrelevant beyond "plausibly skewed".
        let u = self.f64();
        let one_minus_s = 1.0 - s;
        let nf = n as f64;
        let x = if (one_minus_s).abs() < 1e-9 {
            nf.powf(u)
        } else {
            ((nf.powf(one_minus_s) - 1.0) * u + 1.0).powf(1.0 / one_minus_s)
        };
        // x ∈ [1, n] → 0-based index.
        (x.floor() as usize).saturating_sub(1).min(n - 1)
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mix64_pins_the_splitmix64_constants() {
        // The canonical splitmix64 reference vector (seed 0): any change
        // to the golden-ratio increment, the multiply constants, or the
        // shift amounts breaks these exact outputs — and with them the
        // cross-realisation trace-sampling agreement and the lease
        // scheduler's tie-break determinism.
        let mut s = 0u64;
        assert_eq!(splitmix64(&mut s), 0xE220_A839_7B1D_CDAF);
        assert_eq!(splitmix64(&mut s), 0x6E78_9E6A_A1B9_65F4);
        assert_eq!(splitmix64(&mut s), 0x06C4_5D18_8009_454F);
        // The stateless finalizer is the same function of the incremented
        // state: mix64(x) == splitmix64 stepped once from state x.
        assert_eq!(mix64(0), 0xE220_A839_7B1D_CDAF);
        assert_eq!(mix64(0x9E37_79B9_7F4A_7C15), 0x6E78_9E6A_A1B9_65F4);
        // And it is the telemetry sampling hash, re-exported.
        assert_eq!(crate::telemetry::sample_hash(12345), mix64(12345));
    }

    #[test]
    fn deterministic_across_constructions() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_in_range() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            assert!(r.below(13) < 13);
        }
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(9);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn f64_roughly_uniform_mean() {
        let mut r = Rng::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn zipf_skews_low_indices() {
        let mut r = Rng::new(13);
        let n = 1000;
        let hits_low = (0..10_000).filter(|_| r.zipf(n, 1.1) < 10).count();
        // Under uniform this would be ≈100; zipf(1.1) concentrates mass.
        assert!(hits_low > 1000, "hits_low={hits_low}");
    }

    #[test]
    fn zipf_zero_exponent_is_uniform_range() {
        let mut r = Rng::new(17);
        for _ in 0..1000 {
            assert!(r.zipf(5, 0.0) < 5);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(19);
        let mut xs: Vec<u32> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_zero_weight() {
        let mut r = Rng::new(23);
        for _ in 0..1000 {
            let i = r.weighted(&[0.0, 1.0, 0.0]);
            assert_eq!(i, 1);
        }
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(29);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let same = (0..100).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
