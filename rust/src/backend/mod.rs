//! The match-backend abstraction: **one evaluation surface for every MCT
//! implementation**, so the integrated pipeline, the benches and the tests
//! replay CPU-vs-FPGA end-to-end through a single code path.
//!
//! The paper's §5 comparison puts two very different engines behind the same
//! Domain-Explorer traffic: the FPGA flow (ERBIUM kernels behind the MCT
//! Wrapper) and the optimised CPU flow (no batching, per-TS calls). Before
//! this module the real threaded pipeline was hardcoded to
//! [`ErbiumEngine`]; the CPU baseline could only be driven by ad-hoc bench
//! loops. [`MatchBackend`] closes that gap:
//!
//! * [`ErbiumEngine`] implements it directly (Native and Xla backends) —
//!   answers computed for real, time from the FPGA datapath model;
//! * [`CpuBackend`] wraps [`CpuBaseline`] with a calibrated **CPU
//!   service-time model**, so the same dual-clock reporting (wall-clock of
//!   the stand-in, modeled clock of the modeled machine) holds for the §5.2
//!   CPU flow too.
//!
//! A backend also exposes a small capability surface ([`BackendKind`],
//! [`MatchBackend::benefits_from_batching`], [`MatchBackend::max_batch`])
//! that the coordinator uses to pick sensible strategies: §5.1 "the notion
//! of batch processing is not required" on the CPU, while the accelerator
//! lives or dies by aggregation (§4.3, Fig 10).
//!
//! Backends are built *inside* each engine-server thread via a
//! [`BackendFactory`]: PJRT handles are `Rc`-based and not `Send`, exactly
//! like an FPGA board handle is pinned to its XRT process.

pub mod cache;

pub use cache::{
    cached_factory, canonical_key, canonicalise, query_key, CacheCounters, CachedBackend,
    LruCache,
};

use std::cell::RefCell;
use std::sync::Arc;
use std::time::Instant;

use anyhow::Result;

use crate::controlplane::{FaultPlan, GrayEffect};
use crate::cpu_baseline::CpuBaseline;
use crate::erbium::{Backend, BatchTiming, ErbiumEngine, FpgaModel};
use crate::nfa::model::PartitionedNfa;
use crate::prng::Rng;
use crate::rules::standard::Schema;
use crate::rules::types::{MctDecision, MctQuery, RuleSet};
use crate::runtime::Runtime;

/// What kind of machine answers the queries — the label surface the
/// reports and the CLI expose.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BackendKind {
    /// The optimised §5.2 CPU baseline.
    Cpu,
    /// ERBIUM engine, native sparse functional simulator.
    FpgaNative,
    /// ERBIUM engine, AOT XLA artifact via PJRT.
    FpgaXla,
}

impl BackendKind {
    pub fn name(&self) -> &'static str {
        match self {
            BackendKind::Cpu => "cpu",
            BackendKind::FpgaNative => "fpga-native",
            BackendKind::FpgaXla => "fpga-xla",
        }
    }

    /// True for the accelerator flows (per-call overhead amortised by
    /// batching; the CPU flow's per-query cost is flat, §5.1).
    pub fn is_accelerator(&self) -> bool {
        !matches!(self, BackendKind::Cpu)
    }
}

impl std::fmt::Display for BackendKind {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// One MCT evaluation machine: answers a batch functionally and attaches
/// the modeled service time of the machine it stands in for.
pub trait MatchBackend {
    /// Evaluate a batch, returning one decision per query (same order) and
    /// the modeled timing of the invocation.
    fn evaluate_batch_timed(&self, queries: &[MctQuery])
        -> Result<(Vec<MctDecision>, BatchTiming)>;

    /// Batch-first entry point: evaluate into a caller-owned buffer
    /// (cleared first) and return only the timing. Engine servers call this
    /// so whole aggregated batches flow through without re-encoding or
    /// per-query allocation; backends with an allocation-free internal
    /// path override it (the default delegates to
    /// [`Self::evaluate_batch_timed`]).
    ///
    /// Error contract: on `Err` the buffer is left **empty** — callers
    /// reusing one buffer across calls must never read stale (or partial)
    /// decisions after a failure.
    fn evaluate_batch_timed_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<BatchTiming> {
        out.clear();
        let (ds, timing) = self.evaluate_batch_timed(queries)?;
        out.extend_from_slice(&ds);
        Ok(timing)
    }

    /// Capability/label surface.
    fn kind(&self) -> BackendKind;

    /// Human-readable label for reports (defaults to the kind name).
    fn label(&self) -> String {
        self.kind().name().to_string()
    }

    /// Whether worker-side aggregation pays off on this backend.
    fn benefits_from_batching(&self) -> bool {
        self.kind().is_accelerator()
    }

    /// Largest batch one call should carry (`usize::MAX` = unbounded).
    fn max_batch(&self) -> usize {
        usize::MAX
    }

    /// Functional-only convenience wrapper.
    fn evaluate_batch(&self, queries: &[MctQuery]) -> Result<Vec<MctDecision>> {
        self.evaluate_batch_timed(queries).map(|(ds, _)| ds)
    }
}

impl MatchBackend for ErbiumEngine {
    fn evaluate_batch_timed(
        &self,
        queries: &[MctQuery],
    ) -> Result<(Vec<MctDecision>, BatchTiming)> {
        ErbiumEngine::evaluate_batch_timed(self, queries)
    }

    fn evaluate_batch_timed_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<BatchTiming> {
        self.evaluate_batch_into(queries, out)?;
        Ok(self.model().batch_timing(queries.len()))
    }

    fn kind(&self) -> BackendKind {
        if self.is_xla() {
            BackendKind::FpgaXla
        } else {
            BackendKind::FpgaNative
        }
    }

    fn max_batch(&self) -> usize {
        self.kernel_batch()
    }
}

/// Calibrated CPU service-time model for the §5.2 baseline — the CPU-side
/// analogue of [`FpgaModel`]. Fig 12's CPU curve is per-query linear with
/// no per-call amortisation: a fixed dispatch cost, a cheap hit path for
/// the airport caches and a trie walk for everything else.
#[derive(Debug, Clone, Copy)]
pub struct CpuServiceModel {
    /// Per-call dispatch overhead, ns (function call, no ZeroMQ/XRT here).
    pub dispatch_ns: f64,
    /// Airport-cache hit, ns (one hash + one slot probe).
    pub hit_ns: f64,
    /// Shared-prefix trie walk, ns (the [15] CPU path; dominated by the
    /// ~26-level sparse walk over the station partition).
    pub walk_ns: f64,
}

impl Default for CpuServiceModel {
    fn default() -> Self {
        // Calibrated against the §Perf hot-path microbenchmarks of the CPU
        // baseline on the reference host (~0.5 µs/query uncached, tens of
        // ns on a cache hit).
        CpuServiceModel { dispatch_ns: 120.0, hit_ns: 45.0, walk_ns: 520.0 }
    }
}

impl CpuServiceModel {
    /// Modeled service time of one call over `hits` cache hits and
    /// `walks` trie walks.
    pub fn call_us(&self, hits: u64, walks: u64) -> f64 {
        (self.dispatch_ns + hits as f64 * self.hit_ns + walks as f64 * self.walk_ns) / 1e3
    }
}

/// The §5.2 CPU baseline behind the [`MatchBackend`] surface: functional
/// answers from [`CpuBaseline`], modeled time from [`CpuServiceModel`].
pub struct CpuBackend {
    baseline: CpuBaseline,
    model: CpuServiceModel,
}

impl CpuBackend {
    pub fn new(schema: Schema, rs: &RuleSet) -> CpuBackend {
        CpuBackend::with_model(schema, rs, CpuServiceModel::default())
    }

    pub fn with_model(schema: Schema, rs: &RuleSet, model: CpuServiceModel) -> CpuBackend {
        CpuBackend { baseline: CpuBaseline::new(schema, rs), model }
    }

    pub fn baseline(&self) -> &CpuBaseline {
        &self.baseline
    }

    pub fn service_model(&self) -> &CpuServiceModel {
        &self.model
    }
}

impl MatchBackend for CpuBackend {
    fn evaluate_batch_timed(
        &self,
        queries: &[MctQuery],
    ) -> Result<(Vec<MctDecision>, BatchTiming)> {
        let mut out = Vec::with_capacity(queries.len());
        let timing = self.evaluate_batch_timed_into(queries, &mut out)?;
        Ok((out, timing))
    }

    fn evaluate_batch_timed_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<BatchTiming> {
        let before = self.baseline.total_cache_hits();
        self.baseline.evaluate_batch_into(queries, out);
        let hits = self.baseline.total_cache_hits() - before;
        let walks = (queries.len() as u64).saturating_sub(hits);
        let compute_us = self.model.call_us(hits, walks);
        // No shell, no PCIe: the CPU answers in place.
        Ok(BatchTiming {
            setup_us: 0.0,
            transfer_in_us: 0.0,
            compute_us,
            transfer_out_us: 0.0,
            total_us: compute_us,
        })
    }

    fn kind(&self) -> BackendKind {
        BackendKind::Cpu
    }
}

/// Builds one backend instance inside an engine-server thread. Called once
/// per kernel (`k` times per pipeline run).
pub type BackendFactory = Arc<dyn Fn() -> Result<Box<dyn MatchBackend>> + Send + Sync>;

/// Factory for the native ERBIUM engine (the bulk-sweep accelerator
/// stand-in).
pub fn native_backend_factory(
    nfa: PartitionedNfa,
    model: FpgaModel,
    l_pad: usize,
    s_pad: usize,
) -> BackendFactory {
    native_backend_factory_sharded(nfa, model, l_pad, s_pad, 1)
}

/// Like [`native_backend_factory`], but each built engine splits large
/// batches across `shards` cores — the feeder-side parallelism knob of the
/// §6.1 analysis (`replay --shards`). Lockstep stays on (the default).
pub fn native_backend_factory_sharded(
    nfa: PartitionedNfa,
    model: FpgaModel,
    l_pad: usize,
    s_pad: usize,
    shards: usize,
) -> BackendFactory {
    native_backend_factory_tuned(nfa, model, l_pad, s_pad, shards, true)
}

/// Fully-tuned native factory: multi-core split *and* the lockstep toggle
/// (`replay --no-lockstep` builds its engines through this with
/// `lockstep = false`, the A/B lever for the feeder-saturation analysis).
pub fn native_backend_factory_tuned(
    nfa: PartitionedNfa,
    model: FpgaModel,
    l_pad: usize,
    s_pad: usize,
    shards: usize,
    lockstep: bool,
) -> BackendFactory {
    Arc::new(move || {
        let engine = ErbiumEngine::new(nfa.clone(), model, Backend::Native, l_pad, s_pad)?
            .with_shards(shards)
            .with_lockstep(lockstep);
        Ok(Box::new(engine) as Box<dyn MatchBackend>)
    })
}

/// Factory for the XLA-artifact ERBIUM engine. The PJRT runtime is built
/// *inside* the engine-server thread (handles are not `Send`).
pub fn xla_backend_factory(
    nfa: PartitionedNfa,
    model: FpgaModel,
    batch_hint: usize,
    l_pad: usize,
    s_pad: usize,
) -> BackendFactory {
    Arc::new(move || {
        let runtime = Arc::new(Runtime::cpu(Runtime::default_dir())?);
        let engine = ErbiumEngine::new(
            nfa.clone(),
            model,
            Backend::Xla { runtime, batch_hint },
            l_pad,
            s_pad,
        )?;
        Ok(Box::new(engine) as Box<dyn MatchBackend>)
    })
}

/// Factory for the §5.2 optimised CPU baseline.
pub fn cpu_backend_factory(schema: Schema, rs: RuleSet) -> BackendFactory {
    let rs = Arc::new(rs);
    Arc::new(move || Ok(Box::new(CpuBackend::new(schema.clone(), &rs)) as Box<dyn MatchBackend>))
}

/// Gray-fault injecting decorator: the real-realisation twin of the DES's
/// service-start sampling. Wraps any [`MatchBackend`] and consults the
/// shared [`FaultPlan`] at *call time* on the run's wall clock (`t0` is
/// the instant the cluster started accepting — the same origin the accept
/// clock uses), so a scripted brown-out window degrades both realisations
/// over the same stretch of the run:
///
/// * `Slowdown{factor}` — the call runs, then sleeps `(factor−1)×` its
///   own elapsed time, and the modeled [`BatchTiming`] is scaled too, so
///   wall and modeled clocks brown out together;
/// * `ErrorRate{p}` — seeded Bernoulli draw fails the call with an `Err`
///   before any work; the node still emits a (failed) completion;
/// * `Hang{p, stall_us}` — seeded Bernoulli draw sleeps `stall_us`
///   before serving (the intermittent-stall shape of a gray fault).
///
/// Draws come from a per-node seeded [`Rng`] — deterministic in *count*
/// per node, not in thread interleaving (the real realisation is
/// statistical by construction; the DES is the bit-exact one).
pub struct GrayFaultBackend {
    inner: Box<dyn MatchBackend>,
    plan: FaultPlan,
    node: usize,
    t0: Instant,
    rng: RefCell<Rng>,
}

impl GrayFaultBackend {
    pub fn new(
        inner: Box<dyn MatchBackend>,
        plan: FaultPlan,
        node: usize,
        t0: Instant,
        seed: u64,
    ) -> GrayFaultBackend {
        let rng = RefCell::new(Rng::new(seed ^ 0x62AF_17 ^ ((node as u64) << 40)));
        GrayFaultBackend { inner, plan, node, t0, rng }
    }

    fn effect(&self) -> GrayEffect {
        self.plan.gray_at(self.node, self.t0.elapsed().as_secs_f64() * 1e6)
    }

    /// Pre-call injection: `Err` on an error draw, stall on a hang draw.
    fn inject_before(&self, eff: &GrayEffect) -> Result<()> {
        let (fail, hang) = {
            let mut rng = self.rng.borrow_mut();
            (
                eff.error_p > 0.0 && rng.chance(eff.error_p),
                eff.hang_p > 0.0 && rng.chance(eff.hang_p),
            )
        };
        if fail {
            anyhow::bail!("gray fault: injected error on node {}", self.node);
        }
        if hang {
            std::thread::sleep(std::time::Duration::from_secs_f64(eff.stall_us / 1e6));
        }
        Ok(())
    }

    /// Post-call injection: stretch wall and modeled time by the slowdown.
    fn inject_after(&self, eff: &GrayEffect, started: Instant, timing: &mut BatchTiming) {
        if eff.slow_factor > 1.0 {
            std::thread::sleep(started.elapsed().mul_f64(eff.slow_factor - 1.0));
            timing.setup_us *= eff.slow_factor;
            timing.transfer_in_us *= eff.slow_factor;
            timing.compute_us *= eff.slow_factor;
            timing.transfer_out_us *= eff.slow_factor;
            timing.total_us *= eff.slow_factor;
        }
    }
}

impl MatchBackend for GrayFaultBackend {
    fn evaluate_batch_timed(
        &self,
        queries: &[MctQuery],
    ) -> Result<(Vec<MctDecision>, BatchTiming)> {
        let eff = self.effect();
        if eff.is_clean() {
            return self.inner.evaluate_batch_timed(queries);
        }
        self.inject_before(&eff)?;
        let started = Instant::now();
        let (ds, mut timing) = self.inner.evaluate_batch_timed(queries)?;
        self.inject_after(&eff, started, &mut timing);
        Ok((ds, timing))
    }

    fn evaluate_batch_timed_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<BatchTiming> {
        let eff = self.effect();
        if eff.is_clean() {
            return self.inner.evaluate_batch_timed_into(queries, out);
        }
        if let Err(e) = self.inject_before(&eff) {
            out.clear(); // uphold the empty-buffer error contract
            return Err(e);
        }
        let started = Instant::now();
        let mut timing = self.inner.evaluate_batch_timed_into(queries, out)?;
        self.inject_after(&eff, started, &mut timing);
        Ok(timing)
    }

    fn kind(&self) -> BackendKind {
        self.inner.kind()
    }

    fn label(&self) -> String {
        self.inner.label()
    }

    fn benefits_from_batching(&self) -> bool {
        self.inner.benefits_from_batching()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }
}

/// Wrap `inner` so every backend it builds injects `plan`'s gray windows
/// for `node`. Kill faults are untouched — they stay with the up/down
/// machinery; this decorator is only the *gray* (still-answering) path.
pub fn gray_fault_factory(
    inner: BackendFactory,
    plan: FaultPlan,
    node: usize,
    t0: Instant,
    seed: u64,
) -> BackendFactory {
    if !plan.has_gray() {
        return inner;
    }
    Arc::new(move || {
        let b = inner()?;
        Ok(Box::new(GrayFaultBackend::new(b, plan.clone(), node, t0, seed))
            as Box<dyn MatchBackend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::constraint_gen::HardwareConfig;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::StandardVersion;
    use crate::workload::random_query;

    fn world_and_rules(
        seed: u64,
        n: usize,
    ) -> (GeneratorConfig, crate::rules::types::World, Schema, RuleSet) {
        let cfg = GeneratorConfig::small(seed, n);
        let world = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &world, StandardVersion::V2);
        (cfg, world, schema, rs)
    }

    #[test]
    fn cpu_and_native_backends_agree_query_for_query() {
        let (cfg, world, schema, rs) = world_and_rules(41, 400);
        let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let native: Box<dyn MatchBackend> =
            Box::new(ErbiumEngine::new(nfa, model, Backend::Native, 28, 64).unwrap());
        let cpu: Box<dyn MatchBackend> = Box::new(CpuBackend::new(schema, &rs));
        let mut rng = Rng::new(5);
        let queries: Vec<_> = (0..250)
            .map(|_| {
                let st = rng.index(cfg.n_airports) as u32;
                random_query(&mut rng, &world, st)
            })
            .collect();
        let a = native.evaluate_batch(&queries).unwrap();
        let b = cpu.evaluate_batch(&queries).unwrap();
        for ((q, x), y) in queries.iter().zip(&a).zip(&b) {
            assert_eq!(x.rule_id, y.rule_id, "{q:?}");
            assert_eq!(x.minutes, y.minutes, "{q:?}");
        }
    }

    #[test]
    fn kinds_and_capabilities() {
        let (_, _, schema, rs) = world_and_rules(43, 120);
        let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let native = ErbiumEngine::new(nfa, model, Backend::Native, 28, 64).unwrap();
        assert_eq!(MatchBackend::kind(&native), BackendKind::FpgaNative);
        assert!(MatchBackend::benefits_from_batching(&native));
        let cpu = CpuBackend::new(schema, &rs);
        assert_eq!(cpu.kind(), BackendKind::Cpu);
        assert!(!cpu.benefits_from_batching());
        assert_eq!(cpu.label(), "cpu");
    }

    #[test]
    fn cpu_service_model_charges_hits_less_than_walks() {
        let (cfg, world, schema, rs) = world_and_rules(47, 200);
        let cpu = CpuBackend::new(schema, &rs);
        // Hot station 0 is cached: the second pass over identical queries
        // must be modeled cheaper than the first (cache hits).
        let q = crate::workload::query_for_station(&world, 0, 9);
        let qs = vec![q; 64];
        let (_, cold) = cpu.evaluate_batch_timed(&qs).unwrap();
        let (_, warm) = cpu.evaluate_batch_timed(&qs).unwrap();
        assert!(
            warm.total_us < cold.total_us,
            "warm {} !< cold {}",
            warm.total_us,
            cold.total_us
        );
        let _ = cfg;
    }

    #[test]
    fn gray_decorator_errors_slows_and_delegates() {
        let (cfg, world, schema, rs) = world_and_rules(61, 150);
        let t0 = Instant::now();
        let mut rng = Rng::new(3);
        let st = rng.index(cfg.n_airports) as u32;
        let q = random_query(&mut rng, &world, st);

        // ErrorRate{1.0} over a huge window: every call must fail, with
        // the into-buffer left empty per the error contract.
        let plan = FaultPlan::none().and_error_rate(0, 0.0, 1e12, 1.0);
        let erring = GrayFaultBackend::new(
            Box::new(CpuBackend::new(schema.clone(), &rs)),
            plan,
            0,
            t0,
            7,
        );
        assert_eq!(erring.kind(), BackendKind::Cpu, "capability surface delegates");
        assert_eq!(erring.label(), "cpu");
        assert!(!erring.benefits_from_batching());
        let mut out = vec![MctDecision { minutes: 0, weight: 0.0, rule_id: u32::MAX }];
        assert!(erring.evaluate_batch_timed_into(&[q], &mut out).is_err());
        assert!(out.is_empty(), "failed call must leave the buffer empty");

        // Slowdown{4×} inflates the modeled timing; answers are untouched.
        let slow = GrayFaultBackend::new(
            Box::new(CpuBackend::new(schema.clone(), &rs)),
            FaultPlan::none().and_slowdown(0, 0.0, 1e12, 4.0),
            0,
            t0,
            7,
        );
        let clean = CpuBackend::new(schema.clone(), &rs);
        let (ds_slow, t_slow) = slow.evaluate_batch_timed(&[q]).unwrap();
        let (ds_clean, t_clean) = clean.evaluate_batch_timed(&[q]).unwrap();
        assert_eq!(ds_slow[0].rule_id, ds_clean[0].rule_id);
        assert!(
            t_slow.total_us > 3.9 * t_clean.total_us,
            "modeled time must stretch: {} !> 3.9×{}",
            t_slow.total_us,
            t_clean.total_us
        );

        // A window that never opens is a pass-through, and a plan with no
        // gray faults never even wraps.
        let dormant = GrayFaultBackend::new(
            Box::new(CpuBackend::new(schema.clone(), &rs)),
            FaultPlan::none().and_error_rate(0, 1e12, 1.0, 1.0),
            0,
            t0,
            7,
        );
        assert!(dormant.evaluate_batch_timed(&[q]).is_ok());
        let kills_only = FaultPlan::none().and_kill(0, 0.0, 1e6);
        let f = gray_fault_factory(cpu_backend_factory(schema, rs), kills_only, 0, t0, 7);
        assert!(f().unwrap().evaluate_batch_timed(&[q]).is_ok());
    }

    #[test]
    fn factories_build_working_backends() {
        let (cfg, world, schema, rs) = world_and_rules(53, 150);
        let (nfa, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let fs: Vec<BackendFactory> = vec![
            native_backend_factory(nfa, model, 28, 64),
            cpu_backend_factory(schema, rs),
        ];
        let mut rng = Rng::new(1);
        let st = rng.index(cfg.n_airports) as u32;
        let q = random_query(&mut rng, &world, st);
        for f in fs {
            let b = f().unwrap();
            let (ds, t) = b.evaluate_batch_timed(&[q]).unwrap();
            assert_eq!(ds.len(), 1);
            assert!(t.total_us > 0.0);
        }
    }
}
