//! Hot-connection result cache — the §5.2 "cache mechanisms for selected
//! airports", generalised to an LRU in front of any [`MatchBackend`].
//!
//! Production MCT queries are built from a *finite* published flight
//! schedule, so hot connections repeat exactly ([`crate::workload`] module
//! docs); the optimised CPU flow exploits that with per-airport caches.
//! [`CachedBackend`] gives the same lever to every backend: queries are
//! canonicalised (code-share-redundant fields collapsed), keyed, and
//! answered from a bounded LRU when the identical connection was decided
//! before. Only misses reach the wrapped backend, so on the accelerator
//! flows a hit also saves the modeled shell/PCIe round trip.
//!
//! The cache is per backend instance — one per engine-server thread, the
//! software analogue of a board-local cache — while hit counters aggregate
//! per node through a shared [`CacheCounters`]. The cluster router's
//! station-sharded policy exists to make these caches effective: pinning a
//! station to a replica keeps its hot connections in that replica's LRU
//! (measured by the routing-policy tests and the `fleet_imbalance` bench).

use std::collections::hash_map::DefaultHasher;
use std::collections::HashMap;
use std::hash::{Hash, Hasher};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::Result;

use crate::erbium::BatchTiming;
use crate::rules::types::{MctDecision, MctQuery};

use super::{BackendFactory, BackendKind, MatchBackend};

/// Canonical form of a query for caching: on non-code-share legs the
/// operating carrier/flight duplicate the marketing values by construction
/// (§3.2.3), so the canonical form collapses them — two spellings of the
/// same physical connection share one cache slot.
pub fn canonicalise(q: &MctQuery) -> MctQuery {
    let mut c = *q;
    if !c.arr_codeshare {
        c.arr_carrier_op = c.arr_carrier_mkt;
        c.arr_flight_op = c.arr_flight_mkt;
    }
    if !c.dep_codeshare {
        c.dep_carrier_op = c.dep_carrier_mkt;
        c.dep_flight_op = c.dep_flight_mkt;
    }
    c
}

/// Canonicalise **and** hash in one pass — the `(canonical form, key)`
/// pair every cache lookup needs, computed once and reused verbatim on
/// both the probe and the insert path (the canonical form doubles as the
/// 64-bit-collision guard stored next to the decision).
pub fn canonical_key(q: &MctQuery) -> (MctQuery, u64) {
    let canon = canonicalise(q);
    let key = key_of_canonical(&canon);
    (canon, key)
}

/// Stable 64-bit key of the canonicalised query. `DefaultHasher::new()`
/// is fixed-key SipHash, so keys are deterministic across runs — the
/// cluster simulator relies on that to replay identical cache behaviour.
pub fn query_key(q: &MctQuery) -> u64 {
    canonical_key(q).1
}

/// Key of an already-canonicalised query (avoids re-canonicalising on the
/// hot engine-server path).
fn key_of_canonical(canon: &MctQuery) -> u64 {
    let mut h = DefaultHasher::new();
    canon.hash(&mut h);
    h.finish()
}

const NIL: usize = usize::MAX;

struct LruEntry<V> {
    key: u64,
    value: V,
    prev: usize,
    next: usize,
}

/// Exact LRU keyed by `u64`, backed by an index-linked list over a slab —
/// O(1) get/insert/evict, no allocation after the slab fills. Shared by
/// the real [`CachedBackend`] (values = cached decisions) and the cluster
/// simulator (values = `()`, only hit/miss behaviour matters).
pub struct LruCache<V> {
    map: HashMap<u64, usize>,
    entries: Vec<LruEntry<V>>,
    head: usize,
    tail: usize,
    capacity: usize,
}

impl<V> LruCache<V> {
    pub fn new(capacity: usize) -> LruCache<V> {
        let capacity = capacity.max(1);
        LruCache {
            map: HashMap::with_capacity(capacity),
            entries: Vec::with_capacity(capacity),
            head: NIL,
            tail: NIL,
            capacity,
        }
    }

    pub fn len(&self) -> usize {
        self.map.len()
    }

    pub fn is_empty(&self) -> bool {
        self.map.is_empty()
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Unlink `idx` from the recency list (entry stays in the slab).
    fn unlink(&mut self, idx: usize) {
        let (prev, next) = (self.entries[idx].prev, self.entries[idx].next);
        match prev {
            NIL => self.head = next,
            p => self.entries[p].next = next,
        }
        match next {
            NIL => self.tail = prev,
            n => self.entries[n].prev = prev,
        }
    }

    /// Link `idx` at the head (most recently used).
    fn push_front(&mut self, idx: usize) {
        self.entries[idx].prev = NIL;
        self.entries[idx].next = self.head;
        match self.head {
            NIL => self.tail = idx,
            h => self.entries[h].prev = idx,
        }
        self.head = idx;
    }

    /// Look up `key`, refreshing its recency on a hit.
    pub fn get(&mut self, key: u64) -> Option<&V> {
        let idx = *self.map.get(&key)?;
        if self.head != idx {
            self.unlink(idx);
            self.push_front(idx);
        }
        Some(&self.entries[idx].value)
    }

    /// Insert or refresh `key`, evicting the least recently used entry
    /// when at capacity.
    pub fn insert(&mut self, key: u64, value: V) {
        if let Some(&idx) = self.map.get(&key) {
            self.entries[idx].value = value;
            if self.head != idx {
                self.unlink(idx);
                self.push_front(idx);
            }
            return;
        }
        let idx = if self.entries.len() < self.capacity {
            self.entries.push(LruEntry { key, value, prev: NIL, next: NIL });
            self.entries.len() - 1
        } else {
            // Reuse the LRU slot.
            let idx = self.tail;
            self.unlink(idx);
            self.map.remove(&self.entries[idx].key);
            self.entries[idx].key = key;
            self.entries[idx].value = value;
            idx
        };
        self.map.insert(key, idx);
        self.push_front(idx);
    }
}

/// Lookup/hit counters, shared across the engine-server threads of one
/// node so the per-node hit rate can be reported.
#[derive(Debug, Default)]
pub struct CacheCounters {
    pub lookups: AtomicU64,
    pub hits: AtomicU64,
}

impl CacheCounters {
    pub fn hit_rate(&self) -> f64 {
        let lookups = self.lookups.load(Ordering::Relaxed);
        if lookups == 0 {
            0.0
        } else {
            self.hits.load(Ordering::Relaxed) as f64 / lookups as f64
        }
    }

    pub fn snapshot(&self) -> (u64, u64) {
        (self.lookups.load(Ordering::Relaxed), self.hits.load(Ordering::Relaxed))
    }
}

/// Modeled cost of a cache hit, ns (hash + probe; same order as the CPU
/// baseline's airport-cache hit in [`super::CpuServiceModel`]).
pub const CACHE_HIT_NS: f64 = 45.0;

/// An LRU result cache in front of any [`MatchBackend`]: hits answer from
/// the cache, misses pass through as one (smaller) batch.
pub struct CachedBackend {
    inner: Box<dyn MatchBackend>,
    cache: Mutex<LruCache<(MctQuery, MctDecision)>>,
    counters: Arc<CacheCounters>,
}

impl CachedBackend {
    pub fn new(
        inner: Box<dyn MatchBackend>,
        capacity: usize,
        counters: Arc<CacheCounters>,
    ) -> CachedBackend {
        CachedBackend { inner, cache: Mutex::new(LruCache::new(capacity)), counters }
    }
}

impl MatchBackend for CachedBackend {
    fn evaluate_batch_timed(
        &self,
        queries: &[MctQuery],
    ) -> Result<(Vec<MctDecision>, BatchTiming)> {
        let mut out = Vec::with_capacity(queries.len());
        let timing = self.evaluate_batch_timed_into(queries, &mut out)?;
        Ok((out, timing))
    }

    fn evaluate_batch_timed_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<BatchTiming> {
        let mut cache = self.cache.lock().unwrap();
        self.counters.lookups.fetch_add(queries.len() as u64, Ordering::Relaxed);
        // Every row starts as a placeholder; hits overwrite now, misses are
        // overwritten from the inner batch below — so no `Option` lane.
        out.clear();
        out.resize(queries.len(), MctDecision::no_match());
        // Misses keep their (index, key, canonical form) so the fill loop
        // never re-canonicalises or re-hashes.
        let mut misses: Vec<(usize, u64, MctQuery)> = Vec::new();
        for (i, q) in queries.iter().enumerate() {
            let (canon, key) = canonical_key(q);
            // Guard against 64-bit key collisions: a slot only answers for
            // the exact canonical query it stores.
            match cache.get(key) {
                Some((stored, d)) if *stored == canon => out[i] = *d,
                _ => misses.push((i, key, canon)),
            }
        }
        let hits = (queries.len() - misses.len()) as u64;
        self.counters.hits.fetch_add(hits, Ordering::Relaxed);
        let hit_us = hits as f64 * CACHE_HIT_NS / 1e3;
        let mut timing = BatchTiming {
            setup_us: 0.0,
            transfer_in_us: 0.0,
            compute_us: hit_us,
            transfer_out_us: 0.0,
            total_us: hit_us,
        };
        if !misses.is_empty() {
            // Evaluate the *original* spellings (decisions are identical
            // either way; it keeps the inner backend's view untouched).
            let miss_queries: Vec<MctQuery> =
                misses.iter().map(|&(i, _, _)| queries[i]).collect();
            // Trait error contract: a failed call leaves `out` empty, never
            // part-hit part-placeholder.
            let inner = self.inner.evaluate_batch_timed(&miss_queries);
            let (ds, inner_t) = match inner {
                Ok(r) if r.0.len() == misses.len() => r,
                Ok(r) => {
                    out.clear();
                    anyhow::bail!(
                        "inner backend returned {} decisions for {} misses",
                        r.0.len(),
                        misses.len()
                    );
                }
                Err(e) => {
                    out.clear();
                    return Err(e);
                }
            };
            for (&(i, key, canon), d) in misses.iter().zip(&ds) {
                cache.insert(key, (canon, *d));
                out[i] = *d;
            }
            timing = BatchTiming {
                setup_us: inner_t.setup_us,
                transfer_in_us: inner_t.transfer_in_us,
                compute_us: inner_t.compute_us + hit_us,
                transfer_out_us: inner_t.transfer_out_us,
                total_us: inner_t.total_us + hit_us,
            };
        }
        Ok(timing)
    }

    fn kind(&self) -> BackendKind {
        self.inner.kind()
    }

    fn label(&self) -> String {
        format!("{}+cache", self.inner.label())
    }

    fn benefits_from_batching(&self) -> bool {
        self.inner.benefits_from_batching()
    }

    fn max_batch(&self) -> usize {
        self.inner.max_batch()
    }

    fn evaluate_batch(&self, queries: &[MctQuery]) -> Result<Vec<MctDecision>> {
        self.evaluate_batch_timed(queries).map(|(ds, _)| ds)
    }
}

/// Wrap a factory so every backend it builds sits behind its own LRU
/// (per engine-server thread), all reporting into the shared `counters`.
pub fn cached_factory(
    inner: BackendFactory,
    capacity: usize,
    counters: Arc<CacheCounters>,
) -> BackendFactory {
    Arc::new(move || {
        let backend = inner()?;
        Ok(Box::new(CachedBackend::new(backend, capacity, counters.clone()))
            as Box<dyn MatchBackend>)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::backend::CpuBackend;
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{Schema, StandardVersion};
    use crate::workload::QueryFactory;

    #[test]
    fn lru_evicts_least_recently_used() {
        let mut c: LruCache<u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.get(1), Some(&10)); // 1 refreshed; 2 is now LRU
        c.insert(3, 30);
        assert_eq!(c.get(2), None, "2 must be evicted");
        assert_eq!(c.get(1), Some(&10));
        assert_eq!(c.get(3), Some(&30));
        assert_eq!(c.len(), 2);
    }

    #[test]
    fn lru_reinsert_refreshes_and_updates() {
        let mut c: LruCache<u32> = LruCache::new(2);
        c.insert(1, 10);
        c.insert(2, 20);
        c.insert(1, 11); // refresh: 2 becomes LRU
        c.insert(3, 30);
        assert_eq!(c.get(1), Some(&11));
        assert_eq!(c.get(2), None);
    }

    #[test]
    fn lru_capacity_floor_is_one() {
        let mut c: LruCache<u32> = LruCache::new(0);
        c.insert(1, 10);
        c.insert(2, 20);
        assert_eq!(c.len(), 1);
        assert_eq!(c.get(2), Some(&20));
    }

    #[test]
    fn canonicalisation_collapses_non_codeshare_spellings() {
        let cfg = GeneratorConfig::small(3, 20);
        let world = generate_world(&cfg);
        let mut q = crate::workload::query_for_station(&world, 2, 7);
        q.arr_codeshare = false;
        q.dep_codeshare = false;
        let mut alias = q;
        alias.arr_carrier_op = q.arr_carrier_mkt + 1; // redundant field differs
        alias.dep_flight_op = q.dep_flight_mkt + 1;
        assert_eq!(canonicalise(&q), canonicalise(&alias));
        assert_eq!(query_key(&q), query_key(&alias));
        // ...but code-share operating values are load-bearing.
        let mut cs = q;
        cs.arr_codeshare = true;
        cs.arr_carrier_op = q.arr_carrier_mkt + 1;
        assert_ne!(query_key(&q), query_key(&cs));
    }

    #[test]
    fn cached_backend_is_functionally_transparent_and_hits() {
        let cfg = GeneratorConfig::small(11, 300);
        let world = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &world, StandardVersion::V2);
        let plain = CpuBackend::new(schema.clone(), &rs);
        let counters = Arc::new(CacheCounters::default());
        let cached = CachedBackend::new(
            Box::new(CpuBackend::new(schema, &rs)),
            4096,
            counters.clone(),
        );

        // Schedule-drawn queries repeat (hot connections); decisions must be
        // identical with and without the cache, and the warm pass must hit.
        let factory = QueryFactory::new(&world, 5, 40);
        let mut rng = Rng::new(9);
        let queries: Vec<_> = (0..400)
            .map(|_| {
                let st = rng.zipf(world.airports.len(), 1.1) as u32;
                factory.query(&mut rng, &world, st)
            })
            .collect();
        let want = plain.evaluate_batch(&queries).unwrap();
        let cold = cached.evaluate_batch(&queries).unwrap();
        let warm = cached.evaluate_batch(&queries).unwrap();
        for ((a, b), c) in want.iter().zip(&cold).zip(&warm) {
            assert_eq!(a.minutes, b.minutes);
            assert_eq!(a.rule_id, b.rule_id);
            assert_eq!(a.minutes, c.minutes);
        }
        let (lookups, hits) = counters.snapshot();
        assert_eq!(lookups, 800);
        // The warm pass alone hits on everything that stayed resident.
        assert!(hits >= 400, "expected the warm pass to hit, got {hits}");
        assert!(counters.hit_rate() >= 0.5);
        assert_eq!(cached.label(), "cpu+cache");
    }

    #[test]
    fn failed_inner_call_leaves_output_empty() {
        // The `_into` error contract: callers reusing one decisions buffer
        // must never observe stale or placeholder rows after an Err.
        struct Broken;
        impl MatchBackend for Broken {
            fn evaluate_batch_timed(
                &self,
                _queries: &[MctQuery],
            ) -> Result<(Vec<MctDecision>, BatchTiming)> {
                anyhow::bail!("board fell off the bus")
            }
            fn kind(&self) -> BackendKind {
                BackendKind::FpgaNative
            }
        }
        let cached =
            CachedBackend::new(Box::new(Broken), 16, Arc::new(CacheCounters::default()));
        let world = generate_world(&GeneratorConfig::small(5, 20));
        let q = crate::workload::query_for_station(&world, 1, 2);
        let mut out = vec![MctDecision::no_match(); 7];
        assert!(cached.evaluate_batch_timed_into(&[q], &mut out).is_err());
        assert!(out.is_empty(), "error contract: buffer left empty");
    }

    #[test]
    fn cache_hit_skips_the_modeled_backend_time() {
        let cfg = GeneratorConfig::small(13, 150);
        let world = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &world, StandardVersion::V2);
        let counters = Arc::new(CacheCounters::default());
        let cached =
            CachedBackend::new(Box::new(CpuBackend::new(schema, &rs)), 1024, counters);
        let q = crate::workload::query_for_station(&world, 0, 3);
        let qs = vec![q; 32];
        let (_, cold) = cached.evaluate_batch_timed(&qs).unwrap();
        let (_, warm) = cached.evaluate_batch_timed(&qs).unwrap();
        assert!(warm.total_us < cold.total_us, "warm {} !< cold {}", warm.total_us, cold.total_us);
    }
}
