//! The ERBIUM Encoder (§4.1): adapts the software data representation to
//! the format the accelerator consumes.
//!
//! Two halves:
//!
//! * [`Dictionary`] / [`WorldDicts`] — dictionary encoding of symbolic
//!   values (airport/carrier/… codes → dense ids), "to reduce both the
//!   storage requirement and the online data movement";
//! * [`QueryEncoder`] — the hot-path flattening of an [`MctQuery`] into the
//!   `[i32; L]` level-ordered vector the NFA kernel expects. This runs once
//!   per query inside the MCT Wrapper workers, pipelined with the previous
//!   batch's kernel execution (§4.1), and is deliberately allocation-free in
//!   its batch form — Fig 6 shows the encoder is a dominant cost at large
//!   batch sizes, so it is also a §Perf optimisation target.

use std::collections::HashMap;

use crate::nfa::model::LevelPlan;
use crate::rules::standard::{query_exact, query_range_value, Consolidated};
use crate::rules::types::{MctQuery, World};

/// One symbol table (string ⇄ dense id).
#[derive(Debug, Clone, Default)]
pub struct Dictionary {
    map: HashMap<String, u32>,
    rev: Vec<String>,
}

impl Dictionary {
    pub fn from_values(values: &[String]) -> Dictionary {
        let mut d = Dictionary::default();
        for v in values {
            d.intern(v);
        }
        d
    }

    /// Insert (or find) a symbol, returning its id.
    pub fn intern(&mut self, v: &str) -> u32 {
        if let Some(&id) = self.map.get(v) {
            return id;
        }
        let id = self.rev.len() as u32;
        self.map.insert(v.to_string(), id);
        self.rev.push(v.to_string());
        id
    }

    pub fn id(&self, v: &str) -> Option<u32> {
        self.map.get(v).copied()
    }

    pub fn symbol(&self, id: u32) -> Option<&str> {
        self.rev.get(id as usize).map(String::as_str)
    }

    pub fn len(&self) -> usize {
        self.rev.len()
    }
    pub fn is_empty(&self) -> bool {
        self.rev.is_empty()
    }
}

/// All symbol tables of a [`World`] (the reference data the production
/// encoder keeps warm per worker).
#[derive(Debug, Clone)]
pub struct WorldDicts {
    pub airports: Dictionary,
    pub carriers: Dictionary,
    pub terminals: Dictionary,
    pub regions: Dictionary,
    pub aircraft: Dictionary,
    pub services: Dictionary,
    pub conn_types: Dictionary,
    pub seasons: Dictionary,
}

impl WorldDicts {
    pub fn from_world(w: &World) -> WorldDicts {
        WorldDicts {
            airports: Dictionary::from_values(&w.airports),
            carriers: Dictionary::from_values(&w.carriers),
            terminals: Dictionary::from_values(&w.terminals),
            regions: Dictionary::from_values(&w.regions),
            aircraft: Dictionary::from_values(&w.aircraft),
            services: Dictionary::from_values(&w.services),
            conn_types: Dictionary::from_values(&w.conn_types),
            seasons: Dictionary::from_values(&w.seasons),
        }
    }
}

/// A batch of encoded queries in struct-of-arrays form: one contiguous
/// row-major `i32` value buffer (`n × L`) plus a parallel station array —
/// no per-query `Vec`, no pointer chasing.
///
/// Ownership contract (DESIGN.md §Hot path): the **caller** owns the
/// buffer and reuses it across batches; [`QueryEncoder::encode_batch_into`]
/// fills it in place, growing capacity only on the first batches. The
/// evaluator ([`crate::erbium::NativeEvaluator::evaluate_batch`]) borrows
/// it read-only, so one buffer can feed several sharded walkers at once.
#[derive(Debug, Clone, Default)]
pub struct EncodedBatch {
    /// Row-major encoded values, `len = n × depth`.
    values: Vec<i32>,
    /// Routing station of each row, `len = n`.
    stations: Vec<u32>,
    /// Padded level count `L` of the rows.
    depth: usize,
}

impl EncodedBatch {
    /// Number of encoded queries.
    pub fn len(&self) -> usize {
        self.stations.len()
    }

    pub fn is_empty(&self) -> bool {
        self.stations.is_empty()
    }

    /// Padded level count of each row.
    pub fn depth(&self) -> usize {
        self.depth
    }

    /// Encoded values of row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[i32] {
        &self.values[i * self.depth..(i + 1) * self.depth]
    }

    /// Routing station of row `i`.
    #[inline]
    pub fn station(&self, i: usize) -> u32 {
        self.stations[i]
    }

    /// The whole station lane (parallel to the rows). The lockstep
    /// evaluator sorts row indices by this slice to bucket a batch into
    /// same-station lane groups without touching the value buffer.
    #[inline]
    pub fn stations(&self) -> &[u32] {
        &self.stations
    }

    /// The whole row-major value buffer (e.g. for handing to a dense
    /// kernel).
    pub fn values(&self) -> &[i32] {
        &self.values
    }

    /// Drop all rows, keeping capacity.
    pub fn clear(&mut self) {
        self.values.clear();
        self.stations.clear();
    }
}

/// Hot-path query encoder for a fixed level plan.
#[derive(Debug, Clone)]
pub struct QueryEncoder {
    /// Per padded level: how to extract the value (None = padding level).
    extractors: Vec<Option<Consolidated>>,
}

impl QueryEncoder {
    /// Build an encoder for a compiled plan, padded to artifact depth `l`.
    pub fn new(plan: &[LevelPlan], l: usize) -> QueryEncoder {
        assert!(plan.len() <= l, "plan deeper than artifact");
        let mut extractors: Vec<Option<Consolidated>> =
            plan.iter().map(|p| Some(p.criterion)).collect();
        extractors.resize(l, None);
        QueryEncoder { extractors }
    }

    /// Padded depth `L`.
    pub fn depth(&self) -> usize {
        self.extractors.len()
    }

    /// Encode one query into `out[..L]` (must be sized `L`).
    #[inline]
    pub fn encode_into(&self, q: &MctQuery, out: &mut [i32]) {
        debug_assert_eq!(out.len(), self.extractors.len());
        for (o, ex) in out.iter_mut().zip(&self.extractors) {
            *o = match ex {
                None => 0,
                Some(Consolidated::Exact(slot)) => query_exact(*slot, q) as i32,
                Some(
                    Consolidated::Range(slot)
                    | Consolidated::RangeMin(slot)
                    | Consolidated::RangeMax(slot),
                ) => query_range_value(*slot, q) as i32,
            };
        }
    }

    /// Encode one query, allocating.
    pub fn encode(&self, q: &MctQuery) -> Vec<i32> {
        let mut out = vec![0i32; self.depth()];
        self.encode_into(q, &mut out);
        out
    }

    /// Encode a batch into a reusable [`EncodedBatch`], in place: no
    /// per-query allocation, and once the buffers' capacity is warm no
    /// allocation at all. This is the feeder hot path the MCT-Wrapper
    /// workers run per aggregated engine call (DESIGN.md §Hot path).
    pub fn encode_batch_into(&self, queries: &[MctQuery], batch: &mut EncodedBatch) {
        let l = self.depth();
        batch.depth = l;
        batch.stations.clear();
        batch.stations.extend(queries.iter().map(|q| q.station));
        batch.values.resize(queries.len() * l, 0);
        for (q, row) in queries.iter().zip(batch.values.chunks_mut(l.max(1))) {
            self.encode_into(q, row);
        }
    }

    /// Encode a batch row-major into `out` (resized to `n × L`), padding the
    /// tail with repeats of the last query (the kernel batch is fixed-size;
    /// repeats are cheap and results beyond `queries.len()` are discarded).
    pub fn encode_batch(&self, queries: &[MctQuery], batch: usize, out: &mut Vec<i32>) {
        assert!(!queries.is_empty() && queries.len() <= batch);
        let l = self.depth();
        out.resize(batch * l, 0);
        for (i, q) in queries.iter().enumerate() {
            self.encode_into(q, &mut out[i * l..(i + 1) * l]);
        }
        // Pad with the last row.
        let last = (queries.len() - 1) * l;
        let (head, tail) = out.split_at_mut(queries.len() * l);
        let src = &head[last..last + l];
        for row in tail.chunks_mut(l) {
            row.copy_from_slice(src);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::optimiser::OrderStrategy;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{Schema, StandardVersion};
    use crate::workload::query_for_station;

    #[test]
    fn dictionary_roundtrip() {
        let mut d = Dictionary::default();
        let zrh = d.intern("ZRH");
        let cdg = d.intern("CDG");
        assert_ne!(zrh, cdg);
        assert_eq!(d.intern("ZRH"), zrh);
        assert_eq!(d.id("CDG"), Some(cdg));
        assert_eq!(d.symbol(zrh), Some("ZRH"));
        assert_eq!(d.len(), 2);
    }

    #[test]
    fn world_dicts_are_bijective() {
        let w = generate_world(&GeneratorConfig::small(61, 10));
        let d = WorldDicts::from_world(&w);
        for (i, code) in w.airports.iter().enumerate() {
            assert_eq!(d.airports.id(code), Some(i as u32));
            assert_eq!(d.airports.symbol(i as u32), Some(code.as_str()));
        }
    }

    #[test]
    fn encode_respects_plan_order() {
        let cfg = GeneratorConfig::small(63, 200);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(
            &schema,
            &rs,
            &CompileOptions { strategy: OrderStrategy::Optimised, ..Default::default() },
        );
        let enc = QueryEncoder::new(&p.plan, 28);
        let q = query_for_station(&w, 5, 7);
        let v = enc.encode(&q);
        assert_eq!(v.len(), 28);
        // Level 0 is always Station.
        assert_eq!(v[0], 5);
        // Padding levels are zero.
        assert_eq!(v[26], 0);
        assert_eq!(v[27], 0);
    }

    #[test]
    fn encode_batch_into_matches_scalar_and_reuses_buffers() {
        let cfg = GeneratorConfig::small(67, 150);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let qs: Vec<_> = (0..5).map(|i| query_for_station(&w, i, 100 + i as u64)).collect();
        let mut batch = EncodedBatch::default();
        enc.encode_batch_into(&qs, &mut batch);
        assert_eq!(batch.len(), 5);
        assert_eq!(batch.depth(), enc.depth());
        assert_eq!(batch.values().len(), 5 * enc.depth());
        for (i, q) in qs.iter().enumerate() {
            assert_eq!(batch.row(i), enc.encode(q).as_slice(), "row {i}");
            assert_eq!(batch.station(i), q.station);
        }
        let stations: Vec<u32> = qs.iter().map(|q| q.station).collect();
        assert_eq!(batch.stations(), stations.as_slice());
        // Refill with a smaller batch: rows shrink, stale content is gone.
        enc.encode_batch_into(&qs[..2], &mut batch);
        assert_eq!(batch.len(), 2);
        assert_eq!(batch.row(1), enc.encode(&qs[1]).as_slice());
        // Empty batch is legal.
        enc.encode_batch_into(&[], &mut batch);
        assert!(batch.is_empty());
        assert_eq!(batch.values().len(), 0);
    }

    #[test]
    fn encode_batch_pads_with_last_row() {
        let cfg = GeneratorConfig::small(65, 100);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, 28);
        let qs: Vec<_> = (0..3).map(|i| query_for_station(&w, i, i as u64)).collect();
        let mut out = Vec::new();
        enc.encode_batch(&qs, 8, &mut out);
        assert_eq!(out.len(), 8 * 28);
        let row = |i: usize| &out[i * 28..(i + 1) * 28];
        assert_eq!(row(3), row(2));
        assert_eq!(row(7), row(2));
        assert_ne!(row(0), row(2));
    }
}
