//! Workload generation: flight domain, Travel Solutions, user queries and
//! the production-trace replica.
//!
//! §5.2 gives the production-snapshot marginals this generator reproduces:
//! 6 301 user queries → 5.8 M potential Travel Solutions → 4.8 M MCT
//! queries; ~17 % of TS's are direct flights (no MCT call); non-direct TS's
//! spawn **1.24** MCT queries on average; at most five connecting airports
//! per TS (§2.2); the engine explores up to **1 500** TS's per user query.

mod arrivals;
mod trace;

pub use arrivals::{
    session_plans, Arrival, ArrivalSource, PoissonSource, RateProfile, RateSchedule,
    ScheduledSource, SessionBatch, SessionPlan, TraceSource,
};
pub use trace::{
    generate_trace, ProductionTrace, TraceConfig, TraceStats, TravelSolution, UserQuery,
};

use crate::prng::Rng;
use crate::rules::types::{MctQuery, World};

/// Build one plausible MCT query targeting `station` (used by tests and
/// micro-benchmarks that need station-routed load).
pub fn query_for_station(world: &World, station: u32, seed: u64) -> MctQuery {
    let mut rng = Rng::new(seed);
    random_query(&mut rng, world, station)
}

/// One scheduled flight leg at a station — the unit real MCT queries are
/// built from. Production queries draw from the *finite* published
/// schedule, which is what makes the §5.2 "cache mechanisms for selected
/// airports" pay off: hot connections repeat.
#[derive(Debug, Clone, Copy)]
pub struct FlightLeg {
    pub carrier_mkt: u32,
    pub carrier_op: u32,
    pub codeshare: bool,
    pub flight_mkt: u32,
    pub flight_op: u32,
    pub terminal: u32,
    pub region: u32,
    pub aircraft: u32,
    pub service: u32,
    pub time: u32,
    pub other_station: u32,
}

/// Per-station schedules: queries are (arriving leg, departing leg) pairs
/// drawn zipf-skewed, so popular connections recur.
#[derive(Debug, Clone)]
pub struct QueryFactory {
    /// `legs[station]` — scheduled legs at that station.
    legs: Vec<Vec<FlightLeg>>,
}

impl QueryFactory {
    /// Build schedules: leg count per station follows the traffic skew.
    pub fn new(world: &World, seed: u64, mean_legs_per_station: usize) -> QueryFactory {
        let mut rng = Rng::new(seed ^ 0x1E65);
        let n_air = world.airports.len();
        let n_car = world.carriers.len();
        let legs = (0..n_air)
            .map(|st| {
                // Hubs get many legs; tail airports get a handful.
                let weight = 1.0 / (1.0 + st as f64).powf(0.7);
                let n = ((mean_legs_per_station as f64 * weight * 3.0) as usize).max(4);
                (0..n)
                    .map(|_| {
                        let carrier_mkt = rng.zipf(n_car, 0.9) as u32;
                        let codeshare = rng.chance(0.08);
                        let flight_mkt = rng.range_u32(1, World::FLIGHT_NO_MAX - 1);
                        FlightLeg {
                            carrier_mkt,
                            carrier_op: if codeshare {
                                rng.zipf(n_car, 0.9) as u32
                            } else {
                                carrier_mkt
                            },
                            codeshare,
                            flight_mkt,
                            flight_op: if codeshare {
                                rng.range_u32(1, World::FLIGHT_NO_MAX - 1)
                            } else {
                                flight_mkt
                            },
                            terminal: rng.index(world.terminals.len()) as u32,
                            region: rng.index(world.regions.len()) as u32,
                            aircraft: rng.index(world.aircraft.len()) as u32,
                            service: rng.index(world.services.len()) as u32,
                            time: rng.range_u32(0, World::TIME_MAX - 1),
                            other_station: rng.zipf(n_air, 0.9) as u32,
                        }
                    })
                    .collect()
            })
            .collect();
        QueryFactory { legs }
    }

    /// Draw one MCT query at `station`: a zipf-skewed (arrival, departure)
    /// leg pair from the station's schedule plus a near-term date.
    pub fn query(&self, rng: &mut Rng, world: &World, station: u32) -> MctQuery {
        let Some(legs) = self.legs.get(station as usize).filter(|l| !l.is_empty()) else {
            return random_query(rng, world, station);
        };
        let arr = legs[rng.zipf(legs.len(), 1.05)];
        let dep = legs[rng.zipf(legs.len(), 1.05)];
        // Searches concentrate on a near-term date window.
        let date = 100 + rng.zipf(10, 1.0) as u32;
        MctQuery {
            station,
            arr_terminal: arr.terminal,
            dep_terminal: dep.terminal,
            arr_region: arr.region,
            dep_region: dep.region,
            day_of_week: date % World::DOW_MAX,
            season: ((date / 182) as usize % world.seasons.len()) as u32,
            arr_aircraft: arr.aircraft,
            dep_aircraft: dep.aircraft,
            conn_type: ((arr.region.min(1)) * 2 + dep.region.min(1)) % 4,
            prev_station: arr.other_station,
            next_station: dep.other_station,
            arr_service: arr.service,
            dep_service: dep.service,
            arr_carrier_mkt: arr.carrier_mkt,
            arr_carrier_op: arr.carrier_op,
            arr_codeshare: arr.codeshare,
            dep_carrier_mkt: dep.carrier_mkt,
            dep_carrier_op: dep.carrier_op,
            dep_codeshare: dep.codeshare,
            arr_flight_mkt: arr.flight_mkt,
            arr_flight_op: arr.flight_op,
            dep_flight_mkt: dep.flight_mkt,
            dep_flight_op: dep.flight_op,
            date,
            arr_time: arr.time,
            // The departing leg's own scheduled time: the query is fully
            // determined by (arr leg, dep leg, date), so hot connections
            // produce *identical* queries — the cache-friendly structure
            // real schedules have.
            dep_time: dep.time,
            capacity: 40 + (arr.aircraft * 27) % (World::CAPACITY_MAX - 40),
        }
    }
}

/// Draw a random MCT query at a given connection station.
pub fn random_query(rng: &mut Rng, world: &World, station: u32) -> MctQuery {
    let n_air = world.airports.len();
    let n_car = world.carriers.len();
    let arr_carrier_mkt = rng.zipf(n_car, 0.9) as u32;
    let dep_carrier_mkt = rng.zipf(n_car, 0.9) as u32;
    // ~8 % of legs are code-share operated (industry-plausible; exercises
    // the §3.2.3–4 cross-matching paths).
    let arr_codeshare = rng.chance(0.08);
    let dep_codeshare = rng.chance(0.08);
    let arr_carrier_op =
        if arr_codeshare { rng.zipf(n_car, 0.9) as u32 } else { arr_carrier_mkt };
    let dep_carrier_op =
        if dep_codeshare { rng.zipf(n_car, 0.9) as u32 } else { dep_carrier_mkt };
    let arr_flight_mkt = rng.range_u32(1, World::FLIGHT_NO_MAX - 1);
    let dep_flight_mkt = rng.range_u32(1, World::FLIGHT_NO_MAX - 1);
    let arr_flight_op =
        if arr_codeshare { rng.range_u32(1, World::FLIGHT_NO_MAX - 1) } else { arr_flight_mkt };
    let dep_flight_op =
        if dep_codeshare { rng.range_u32(1, World::FLIGHT_NO_MAX - 1) } else { dep_flight_mkt };
    let arr_time = rng.range_u32(0, World::TIME_MAX - 1);
    MctQuery {
        station,
        arr_terminal: rng.index(world.terminals.len()) as u32,
        dep_terminal: rng.index(world.terminals.len()) as u32,
        arr_region: rng.index(world.regions.len()) as u32,
        dep_region: rng.index(world.regions.len()) as u32,
        day_of_week: rng.range_u32(0, World::DOW_MAX - 1),
        season: rng.index(world.seasons.len()) as u32,
        arr_aircraft: rng.index(world.aircraft.len()) as u32,
        dep_aircraft: rng.index(world.aircraft.len()) as u32,
        conn_type: rng.index(world.conn_types.len()) as u32,
        prev_station: rng.zipf(n_air, 0.9) as u32,
        next_station: rng.zipf(n_air, 0.9) as u32,
        arr_service: rng.index(world.services.len()) as u32,
        dep_service: rng.index(world.services.len()) as u32,
        arr_carrier_mkt,
        arr_carrier_op,
        arr_codeshare,
        dep_carrier_mkt,
        dep_carrier_op,
        dep_codeshare,
        arr_flight_mkt,
        arr_flight_op,
        dep_flight_mkt,
        dep_flight_op,
        date: rng.range_u32(0, World::DATE_MAX - 1),
        arr_time,
        // Departures cluster after arrivals (it's a connection).
        dep_time: (arr_time + rng.range_u32(30, 360)) % World::TIME_MAX,
        capacity: rng.range_u32(40, World::CAPACITY_MAX - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_world, GeneratorConfig};

    #[test]
    fn random_query_respects_station() {
        let w = generate_world(&GeneratorConfig::small(1, 10));
        let q = query_for_station(&w, 7, 99);
        assert_eq!(q.station, 7);
    }

    #[test]
    fn non_codeshare_queries_have_equal_carriers() {
        let w = generate_world(&GeneratorConfig::small(1, 10));
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let q = random_query(&mut rng, &w, 0);
            if !q.arr_codeshare {
                assert_eq!(q.arr_carrier_mkt, q.arr_carrier_op);
                assert_eq!(q.arr_flight_mkt, q.arr_flight_op);
            }
            if !q.dep_codeshare {
                assert_eq!(q.dep_carrier_mkt, q.dep_carrier_op);
            }
        }
    }

    #[test]
    fn query_values_in_domain() {
        let w = generate_world(&GeneratorConfig::small(2, 10));
        let mut rng = Rng::new(6);
        for _ in 0..500 {
            let q = random_query(&mut rng, &w, 3);
            assert!(q.arr_flight_mkt < World::FLIGHT_NO_MAX);
            assert!(q.date < World::DATE_MAX);
            assert!(q.arr_time < World::TIME_MAX);
            assert!(q.day_of_week < World::DOW_MAX);
            assert!((q.arr_terminal as usize) < w.terminals.len());
        }
    }
}
