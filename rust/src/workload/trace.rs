//! Production-trace replica: user queries → Travel Solutions → MCT queries.
//!
//! Reproduces the §5.2 snapshot marginals (see module docs in
//! [`super`]). A [`UserQuery`] carries the list of Travel Solutions the
//! Domain Explorer's connection builder would emit for it, each TS being
//! either a direct flight (no MCT calls) or a chain of 1–4 connections
//! (= MCT queries). The `required_ts` field models the "number of required
//! qualified TS's provided by the user query" that §5.2 uses to choose the
//! FPGA batch size.

use crate::prng::Rng;
use crate::rules::types::{MctQuery, World};

/// One Travel Solution: a combination of routes/carriers/flights (§2.2).
#[derive(Debug, Clone)]
pub struct TravelSolution {
    /// MCT queries spawned by this TS — empty ⇔ direct flight.
    pub mct_queries: Vec<MctQuery>,
}

impl TravelSolution {
    pub fn is_direct(&self) -> bool {
        self.mct_queries.is_empty()
    }
}

/// One user query (origin/destination/date search) with its pre-computed
/// potential Travel Solutions.
#[derive(Debug, Clone)]
pub struct UserQuery {
    pub id: u32,
    /// "Required qualified TS's" — how many valid TS's the engine must
    /// return for this query (caps at the engine-wide 1 500, §2.2).
    pub required_ts: usize,
    pub solutions: Vec<TravelSolution>,
}

impl UserQuery {
    /// Total MCT queries across all TS's.
    pub fn mct_query_count(&self) -> usize {
        self.solutions.iter().map(|ts| ts.mct_queries.len()).sum()
    }
}

/// A replayable workload trace.
#[derive(Debug, Clone)]
pub struct ProductionTrace {
    pub queries: Vec<UserQuery>,
}

/// Generation knobs. Defaults reproduce §5.2 at 1:1 scale; `scale` shrinks
/// the trace proportionally for cheap CI runs.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub seed: u64,
    /// Number of user queries (paper snapshot: 6 301).
    pub n_user_queries: usize,
    /// Mean potential TS's per user query (paper: 5.8 M / 6 301 ≈ 920).
    pub mean_ts_per_query: f64,
    /// Fraction of TS's that are direct flights (paper: ~17 %).
    pub direct_fraction: f64,
    /// Target mean MCT queries per non-direct TS (paper: 1.24).
    pub mean_mct_per_ts: f64,
    /// Engine-wide TS cap per user query (§2.2: 1 500).
    pub ts_cap: usize,
    /// Zipf exponent for connection-airport popularity.
    pub airport_skew: f64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            seed: 0x72ACE,
            n_user_queries: 6_301,
            mean_ts_per_query: 920.0,
            direct_fraction: 0.17,
            mean_mct_per_ts: 1.24,
            ts_cap: 1_500,
            airport_skew: 1.05,
        }
    }
}

impl TraceConfig {
    /// Scaled-down trace (same shape, fewer user queries / TS's).
    pub fn scaled(seed: u64, n_user_queries: usize, mean_ts: f64) -> Self {
        TraceConfig {
            seed,
            n_user_queries,
            mean_ts_per_query: mean_ts,
            ..TraceConfig::default()
        }
    }
}

/// Aggregate statistics of a trace (the §5.2 headline numbers).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TraceStats {
    pub user_queries: usize,
    pub travel_solutions: usize,
    pub mct_queries: usize,
    pub direct_ts: usize,
}

impl TraceStats {
    pub fn direct_fraction(&self) -> f64 {
        self.direct_ts as f64 / self.travel_solutions.max(1) as f64
    }
    pub fn mean_mct_per_nondirect_ts(&self) -> f64 {
        self.mct_queries as f64 / (self.travel_solutions - self.direct_ts).max(1) as f64
    }
}

/// Number of connections (= MCT queries) for one non-direct TS, matching the
/// paper's constraints: 1..=4 connections (≤5 airports, §2.2) with mean
/// ≈ `mean_mct_per_ts`.
fn connections_for_ts(rng: &mut Rng, mean: f64) -> usize {
    // Geometric-ish mixture over {1,2,3,4}: p(k+1 | ≥k+1 possible) = r,
    // solved so that E[k] ≈ mean. For mean 1.24, r ≈ 0.205.
    let r = ((mean - 1.0) / (mean * 0.94)).clamp(0.01, 0.9);
    let mut k = 1;
    while k < 4 && rng.chance(r) {
        k += 1;
    }
    k
}

/// Generate a production-trace replica. Queries are drawn from a finite
/// flight schedule ([`super::QueryFactory`]) so hot connections recur — the
/// property the §5.2 airport caches exploit.
pub fn generate_trace(cfg: &TraceConfig, world: &World) -> ProductionTrace {
    let factory = super::QueryFactory::new(world, cfg.seed, 160);
    let mut rng = Rng::new(cfg.seed);
    let n_air = world.airports.len();
    let mut queries = Vec::with_capacity(cfg.n_user_queries);
    for id in 0..cfg.n_user_queries {
        let mut qrng = rng.fork(id as u64);
        // Per-query TS volume: log-normal-ish spread around the mean —
        // real queries range from a handful of TS's (rare city pair) to the
        // cap (flexible-dates hub pair). Mixture keeps it simple + seeded.
        let burst = qrng.f64();
        let n_ts = if burst < 0.10 {
            1 + qrng.index(30) // thin queries: almost no alternatives
        } else if burst < 0.85 {
            let base = cfg.mean_ts_per_query * (0.4 + 1.1 * qrng.f64());
            base as usize
        } else {
            cfg.ts_cap + qrng.index(cfg.ts_cap) // overflowing queries, capped
        };
        let n_ts = n_ts.clamp(1, cfg.ts_cap * 2);
        let required_ts = cfg.ts_cap.min(n_ts.max(1));
        let mut solutions = Vec::with_capacity(n_ts);
        for _ in 0..n_ts {
            if qrng.chance(cfg.direct_fraction) {
                solutions.push(TravelSolution { mct_queries: Vec::new() });
            } else {
                let k = connections_for_ts(&mut qrng, cfg.mean_mct_per_ts);
                let mct_queries = (0..k)
                    .map(|_| {
                        let station = qrng.zipf(n_air, cfg.airport_skew) as u32;
                        factory.query(&mut qrng, world, station)
                    })
                    .collect();
                solutions.push(TravelSolution { mct_queries });
            }
        }
        queries.push(UserQuery { id: id as u32, required_ts, solutions });
    }
    ProductionTrace { queries }
}

impl ProductionTrace {
    pub fn stats(&self) -> TraceStats {
        let mut ts = 0;
        let mut mct = 0;
        let mut direct = 0;
        for uq in &self.queries {
            ts += uq.solutions.len();
            for s in &uq.solutions {
                if s.is_direct() {
                    direct += 1;
                } else {
                    mct += s.mct_queries.len();
                }
            }
        }
        TraceStats {
            user_queries: self.queries.len(),
            travel_solutions: ts,
            mct_queries: mct,
            direct_ts: direct,
        }
    }

    /// Flatten all MCT queries (for stand-alone engine benchmarks).
    pub fn all_mct_queries(&self) -> Vec<MctQuery> {
        self.queries
            .iter()
            .flat_map(|uq| uq.solutions.iter().flat_map(|s| s.mct_queries.iter().copied()))
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_world, GeneratorConfig};

    fn small_world() -> World {
        generate_world(&GeneratorConfig::small(3, 10))
    }

    #[test]
    fn trace_is_deterministic() {
        let w = small_world();
        let cfg = TraceConfig::scaled(9, 20, 50.0);
        let a = generate_trace(&cfg, &w);
        let b = generate_trace(&cfg, &w);
        assert_eq!(a.stats(), b.stats());
        assert_eq!(
            a.queries[5].solutions.len(),
            b.queries[5].solutions.len()
        );
    }

    #[test]
    fn marginals_match_paper_shape() {
        // Scaled-down trace must still reproduce the §5.2 ratios.
        let w = small_world();
        let cfg = TraceConfig::scaled(1, 300, 920.0);
        let t = generate_trace(&cfg, &w);
        let s = t.stats();
        assert_eq!(s.user_queries, 300);
        let direct = s.direct_fraction();
        assert!((0.14..0.20).contains(&direct), "direct fraction {direct}");
        let mean_mct = s.mean_mct_per_nondirect_ts();
        assert!((1.15..1.35).contains(&mean_mct), "mean mct/ts {mean_mct}");
        // ≈920 TS per user query on average (wide tolerance: mixture tails)
        let ts_per_uq = s.travel_solutions as f64 / s.user_queries as f64;
        assert!((600.0..1300.0).contains(&ts_per_uq), "ts/uq {ts_per_uq}");
    }

    #[test]
    fn connections_respect_cap() {
        let w = small_world();
        let t = generate_trace(&TraceConfig::scaled(2, 50, 100.0), &w);
        for uq in &t.queries {
            assert!(uq.required_ts <= 1_500);
            for s in &uq.solutions {
                assert!(s.mct_queries.len() <= 4, "≤5 airports ⇒ ≤4 connections");
            }
        }
    }

    #[test]
    fn mean_connections_close_to_target() {
        let mut rng = Rng::new(4);
        let n = 100_000;
        let total: usize = (0..n).map(|_| connections_for_ts(&mut rng, 1.24)).sum();
        let mean = total as f64 / n as f64;
        assert!((1.15..1.33).contains(&mean), "mean={mean}");
    }

    #[test]
    fn all_mct_queries_flattens_consistently() {
        let w = small_world();
        let t = generate_trace(&TraceConfig::scaled(5, 30, 40.0), &w);
        assert_eq!(t.all_mct_queries().len(), t.stats().mct_queries);
    }
}
