//! Open-loop arrival generation — how load *enters* the system.
//!
//! The closed-loop drivers (each Domain Explorer process keeps exactly one
//! request outstanding) measure saturation ceilings, but a deployment is
//! sized against *offered* load: users do not wait for the fleet to drain
//! before searching. An [`ArrivalSource`] decouples the request stream
//! from the serving system: requests carry their own arrival timestamps,
//! and the coordinator/cluster layers report **offered vs achieved**
//! throughput — the gap (plus SLA drops) is what provisioning must close
//! (§6.1's imbalance discussion; the provisioning-for-throughput framing
//! of Jiang et al.).
//!
//! Two deterministic sources:
//!
//! * [`PoissonSource`] — a seeded Poisson process of MCT requests, each a
//!   single-station batch drawn from the finite flight schedule
//!   ([`QueryFactory`]), station popularity zipf-skewed. The workhorse for
//!   saturation sweeps and router-policy experiments.
//! * [`TraceSource`] — replay of a [`ProductionTrace`]: user queries
//!   arrive as a Poisson stream, each expanding into its §5.2
//!   required-TS-sized MCT requests separated by a per-user-query think
//!   time (the Domain Explorer digesting the previous reply).

use crate::prng::Rng;
use crate::rules::types::{MctQuery, World};

use super::{ProductionTrace, QueryFactory};

/// Shape of a time-varying offered-load profile, requests/second as a
/// function of seconds since stream start.
#[derive(Debug, Clone)]
pub enum RateProfile {
    /// Flat rate (what [`PoissonSource`] models natively).
    Constant(f64),
    /// `base − amplitude·cos(2π t / period)`: the diurnal curve — the
    /// stream starts at the overnight trough, peaks at `period/2` and
    /// returns. `amplitude` is clamped to `base` so the rate never goes
    /// negative.
    Diurnal { base_rps: f64, amplitude_rps: f64, period_s: f64 },
    /// Step profile: `(from_s, rps)` knots in ascending time order; the
    /// rate holds each step until the next knot.
    Piecewise(Vec<(f64, f64)>),
}

/// A deterministic rate profile the open-loop sources (and the
/// control-plane autoscalers) evaluate: *offered* requests/s at any
/// instant of the run.
#[derive(Debug, Clone)]
pub struct RateSchedule {
    pub profile: RateProfile,
}

impl RateSchedule {
    pub fn constant(rps: f64) -> RateSchedule {
        assert!(rps > 0.0);
        RateSchedule { profile: RateProfile::Constant(rps) }
    }

    /// Diurnal sinusoid from trough to peak and back over `period_s`.
    pub fn diurnal(base_rps: f64, amplitude_rps: f64, period_s: f64) -> RateSchedule {
        assert!(base_rps > 0.0 && period_s > 0.0 && amplitude_rps >= 0.0);
        RateSchedule {
            profile: RateProfile::Diurnal {
                base_rps,
                amplitude_rps: amplitude_rps.min(base_rps),
                period_s,
            },
        }
    }

    /// Step profile from `(from_s, rps)` knots (first knot at 0 s).
    pub fn piecewise(steps: Vec<(f64, f64)>) -> RateSchedule {
        assert!(!steps.is_empty() && steps[0].0 <= 0.0, "first knot must start at 0 s");
        assert!(steps.windows(2).all(|w| w[0].0 < w[1].0), "knots must ascend");
        assert!(steps.iter().all(|&(_, r)| r > 0.0));
        RateSchedule { profile: RateProfile::Piecewise(steps) }
    }

    /// Offered request rate at `t_s` seconds into the run.
    pub fn rate_rps(&self, t_s: f64) -> f64 {
        match &self.profile {
            RateProfile::Constant(r) => *r,
            RateProfile::Diurnal { base_rps, amplitude_rps, period_s } => {
                base_rps - amplitude_rps * (2.0 * std::f64::consts::PI * t_s / period_s).cos()
            }
            RateProfile::Piecewise(steps) => steps
                .iter()
                .rev()
                .find(|&&(from, _)| t_s >= from)
                .map(|&(_, r)| r)
                .unwrap_or(steps[0].1),
        }
    }

    /// Largest rate the profile reaches — what a static fleet must be
    /// provisioned for.
    pub fn peak_rps(&self) -> f64 {
        match &self.profile {
            RateProfile::Constant(r) => *r,
            RateProfile::Diurnal { base_rps, amplitude_rps, .. } => base_rps + amplitude_rps,
            RateProfile::Piecewise(steps) => {
                steps.iter().map(|&(_, r)| r).fold(0.0, f64::max)
            }
        }
    }

    /// Inter-arrival gap (µs) of the inhomogeneous Poisson clock at
    /// `clock_us`, driven by one uniform draw `u` ∈ [0, 1). The single
    /// definition of the re-timing step, shared by [`ScheduledSource`]
    /// and the DES's payload-free
    /// [`scheduled_sim_arrivals`](crate::cluster::scheduled_sim_arrivals),
    /// so the two arrival generators can never drift apart.
    pub fn poisson_gap_us(&self, clock_us: f64, u: f64) -> f64 {
        let rate = self.rate_rps(clock_us * 1e-6).max(1e-6);
        -(1.0 - u).ln() / rate * 1e6
    }

    /// Smallest rate the profile reaches (the overnight trough).
    pub fn trough_rps(&self) -> f64 {
        match &self.profile {
            RateProfile::Constant(r) => *r,
            RateProfile::Diurnal { base_rps, amplitude_rps, .. } => base_rps - amplitude_rps,
            RateProfile::Piecewise(steps) => {
                steps.iter().map(|&(_, r)| r).fold(f64::INFINITY, f64::min)
            }
        }
    }

    pub fn label(&self) -> String {
        match &self.profile {
            RateProfile::Constant(r) => format!("const {r:.0}/s"),
            RateProfile::Diurnal { base_rps, amplitude_rps, period_s } => {
                format!("diurnal {base_rps:.0}±{amplitude_rps:.0}/s over {period_s:.0}s")
            }
            RateProfile::Piecewise(steps) => format!("piecewise ×{}", steps.len()),
        }
    }
}

/// Re-times another source's request stream onto a [`RateSchedule`]: the
/// payloads (and their order) come from the inner source, the arrival
/// clock is a seeded inhomogeneous-Poisson draw against the profile —
/// diurnal load without touching the payload generator. Think-time
/// structure of a [`TraceSource`] is deliberately overridden: the wrapper
/// owns the clock.
pub struct ScheduledSource {
    arrivals: std::vec::IntoIter<Arrival>,
    total: usize,
    offered_qps: f64,
    label: String,
}

impl ScheduledSource {
    pub fn new(
        mut inner: Box<dyn ArrivalSource>,
        seed: u64,
        schedule: &RateSchedule,
    ) -> ScheduledSource {
        let mut rng = Rng::new(seed ^ 0xD1_42A1);
        let mut clock_us = 0.0f64;
        let mut total_queries = 0usize;
        let inner_label = inner.label();
        let mut arrivals: Vec<Arrival> = Vec::with_capacity(inner.total_requests());
        while let Some(mut a) = inner.next_arrival() {
            clock_us += schedule.poisson_gap_us(clock_us, rng.f64());
            a.at_us = clock_us;
            total_queries += a.queries.len();
            arrivals.push(a);
        }
        let window_s = (arrivals.last().map(|a| a.at_us).unwrap_or(0.0) / 1e6).max(1e-9);
        let total = arrivals.len();
        ScheduledSource {
            arrivals: arrivals.into_iter(),
            total,
            offered_qps: total_queries as f64 / window_s,
            label: format!("{} @ {}", inner_label, schedule.label()),
        }
    }
}

impl ArrivalSource for ScheduledSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        self.arrivals.next()
    }

    fn offered_qps(&self) -> f64 {
        self.offered_qps
    }

    fn total_requests(&self) -> usize {
        self.total
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

/// One MCT request entering the system at `at_us` (µs since stream start).
#[derive(Debug, Clone)]
pub struct Arrival {
    pub at_us: f64,
    /// Originating user query (0 for synthetic sources without one).
    pub user_query: u32,
    pub queries: Vec<MctQuery>,
}

impl Arrival {
    /// Routing key for station-sharded policies: the first query's
    /// station. [`PoissonSource`] requests are single-station by
    /// construction, so the key is exact there; [`TraceSource`] batches
    /// can span the stations of several travel solutions, for which this
    /// is the lead-connection approximation (cache affinity degrades
    /// gracefully toward round-robin as batches get more mixed).
    pub fn station(&self) -> u32 {
        self.queries.first().map(|q| q.station).unwrap_or(0)
    }
}

/// A finite, deterministic, time-stamped stream of MCT requests.
pub trait ArrivalSource: Send {
    /// Next arrival in non-decreasing `at_us` order; `None` when drained.
    fn next_arrival(&mut self) -> Option<Arrival>;

    /// Offered load over the arrival window, MCT queries / second.
    fn offered_qps(&self) -> f64;

    /// Total requests this source emits over its lifetime.
    fn total_requests(&self) -> usize;

    fn label(&self) -> String;

    /// Drain into a service-time schedule `(arrival µs, batch size)` for
    /// the discrete-event simulator, which needs timings and sizes but no
    /// payloads.
    fn schedule(&mut self) -> Vec<(f64, usize)> {
        let mut out = Vec::with_capacity(self.total_requests());
        while let Some(a) = self.next_arrival() {
            out.push((a.at_us, a.queries.len()));
        }
        out
    }
}

/// Seeded open-loop Poisson request stream over the flight schedule.
pub struct PoissonSource {
    rng: Rng,
    factory: QueryFactory,
    world: World,
    seed: u64,
    rate_rps: f64,
    batch_per_request: usize,
    airport_skew: f64,
    total: usize,
    emitted: usize,
    clock_us: f64,
}

impl PoissonSource {
    /// `rate_rps` requests/second, each carrying `batch_per_request`
    /// queries at one zipf-chosen station, `n_requests` total.
    pub fn new(
        world: &World,
        seed: u64,
        rate_rps: f64,
        batch_per_request: usize,
        n_requests: usize,
    ) -> PoissonSource {
        assert!(rate_rps > 0.0, "arrival rate must be positive");
        PoissonSource {
            rng: Rng::new(seed ^ 0x0A55_0A55),
            factory: QueryFactory::new(world, seed, 160),
            world: world.clone(),
            seed,
            rate_rps,
            batch_per_request: batch_per_request.max(1),
            airport_skew: 1.05,
            total: n_requests,
            emitted: 0,
            clock_us: 0.0,
        }
    }

    /// Override the station-popularity skew (higher ⇒ hotter hubs; the
    /// router-policy experiments use this to stress sharded routing).
    pub fn with_airport_skew(mut self, skew: f64) -> PoissonSource {
        self.airport_skew = skew;
        self
    }

    /// Rebuild the flight schedule with `mean` legs per station. Fewer
    /// legs ⇒ a denser repeat structure (the same connections recur far
    /// more often) — the knob the cache-affinity experiments turn.
    pub fn with_mean_legs(mut self, mean: usize) -> PoissonSource {
        self.factory = QueryFactory::new(&self.world, self.seed, mean);
        self
    }
}

impl ArrivalSource for PoissonSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        if self.emitted >= self.total {
            return None;
        }
        // Inverse-CDF exponential inter-arrival, seeded ⇒ reproducible.
        let u = self.rng.f64();
        self.clock_us += -(1.0 - u).ln() / self.rate_rps * 1e6;
        let station = self.rng.zipf(self.world.airports.len(), self.airport_skew) as u32;
        let queries = (0..self.batch_per_request)
            .map(|_| self.factory.query(&mut self.rng, &self.world, station))
            .collect();
        let id = self.emitted as u32;
        self.emitted += 1;
        Some(Arrival { at_us: self.clock_us, user_query: id, queries })
    }

    fn offered_qps(&self) -> f64 {
        self.rate_rps * self.batch_per_request as f64
    }

    fn total_requests(&self) -> usize {
        self.total
    }

    fn label(&self) -> String {
        format!(
            "poisson λ={:.0}/s ×{}q ({} req)",
            self.rate_rps, self.batch_per_request, self.total
        )
    }
}

/// Replay of a production trace: user queries arrive Poisson at
/// `uq_per_s`; within one user query, consecutive MCT requests (the §5.2
/// required-TS-sized batches) are separated by `think_us` of Domain
/// Explorer work.
pub struct TraceSource {
    arrivals: std::vec::IntoIter<Arrival>,
    total: usize,
    offered_qps: f64,
    label: String,
}

impl TraceSource {
    pub fn new(trace: &ProductionTrace, seed: u64, uq_per_s: f64, think_us: f64) -> TraceSource {
        assert!(uq_per_s > 0.0, "user-query rate must be positive");
        let mut rng = Rng::new(seed ^ 0x7_2ACE);
        let mut arrivals: Vec<Arrival> = Vec::new();
        let mut clock_us = 0.0f64;
        let mut total_queries = 0usize;
        for uq in &trace.queries {
            let u = rng.f64();
            clock_us += -(1.0 - u).ln() / uq_per_s * 1e6;
            // §5.2 batching: one request per `required_ts` travel
            // solutions; direct TS's consume quota but add no queries.
            // Open-loop replay offers every batch (validity is not known
            // until the replies return).
            let mut offset = 0usize;
            let mut batch: Vec<MctQuery> = Vec::new();
            let mut ts_in_batch = 0usize;
            let mut flush =
                |batch: &mut Vec<MctQuery>, offset: &mut usize, arrivals: &mut Vec<Arrival>| {
                    if batch.is_empty() {
                        return;
                    }
                    total_queries += batch.len();
                    arrivals.push(Arrival {
                        at_us: clock_us + *offset as f64 * think_us,
                        user_query: uq.id,
                        queries: std::mem::take(batch),
                    });
                    *offset += 1;
                };
            for ts in &uq.solutions {
                batch.extend_from_slice(&ts.mct_queries);
                ts_in_batch += 1;
                if ts_in_batch >= uq.required_ts {
                    flush(&mut batch, &mut offset, &mut arrivals);
                    ts_in_batch = 0;
                }
            }
            flush(&mut batch, &mut offset, &mut arrivals);
        }
        // Think-time offsets can leapfrog later user queries: restore
        // global time order (stable tie-break on the original position).
        arrivals.sort_by(|a, b| a.at_us.partial_cmp(&b.at_us).unwrap());
        let window_s = (arrivals.last().map(|a| a.at_us).unwrap_or(0.0) / 1e6).max(1e-9);
        let total = arrivals.len();
        TraceSource {
            arrivals: arrivals.into_iter(),
            total,
            offered_qps: total_queries as f64 / window_s,
            label: format!("trace λ={uq_per_s:.0} uq/s think={think_us:.0}µs ({total} req)"),
        }
    }
}

impl ArrivalSource for TraceSource {
    fn next_arrival(&mut self) -> Option<Arrival> {
        self.arrivals.next()
    }

    fn offered_qps(&self) -> f64 {
        self.offered_qps
    }

    fn total_requests(&self) -> usize {
        self.total
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

/// One query batch within a session's stream: ready `offset_us` after the
/// session is accepted, carrying `n_queries` single-station queries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SessionBatch {
    pub offset_us: f64,
    pub n_queries: usize,
}

/// One front-door client session: accepted at `accept_us`, then a stream
/// of query batches at fixed offsets from the accept. This is the unit
/// the front door multiplexes — and the unit the **accept clock** starts
/// from: a batch's honest latency is measured from `accept_us +
/// offset_us` (when the client *had* it), not from when the serving stack
/// deigned to read it.
#[derive(Debug, Clone, PartialEq)]
pub struct SessionPlan {
    pub accept_us: f64,
    pub station: u32,
    pub batches: Vec<SessionBatch>,
}

impl SessionPlan {
    /// Queries this session offers over its lifetime.
    pub fn total_queries(&self) -> usize {
        self.batches.iter().map(|b| b.n_queries).sum()
    }

    /// Client-clock instant batch `i` becomes ready.
    pub fn ready_us(&self, batch: usize) -> f64 {
        self.accept_us + self.batches[batch].offset_us
    }
}

/// Seeded session arrival process on top of a [`RateSchedule`]: session
/// accepts are an inhomogeneous Poisson stream (the same
/// [`RateSchedule::poisson_gap_us`] re-timing step [`ScheduledSource`]
/// uses), stations zipf-skewed as in [`PoissonSource`], and each session
/// carries `batches_per_session` batches of `batch_queries` queries
/// spaced `batch_gap_us` apart. A gap of 0 is the bursty client whose
/// whole stream is ready at accept — the workload that makes the
/// backpressure policies distinguishable.
pub fn session_plans(
    seed: u64,
    schedule: &RateSchedule,
    n_sessions: usize,
    batches_per_session: usize,
    batch_queries: usize,
    batch_gap_us: f64,
    n_stations: usize,
) -> Vec<SessionPlan> {
    assert!(batch_gap_us >= 0.0);
    let mut rng = Rng::new(seed ^ 0x5E55_10);
    let mut clock_us = 0.0f64;
    (0..n_sessions)
        .map(|_| {
            clock_us += schedule.poisson_gap_us(clock_us, rng.f64());
            let station = rng.zipf(n_stations.max(1), 1.05) as u32;
            let batches = (0..batches_per_session.max(1))
                .map(|i| SessionBatch {
                    offset_us: i as f64 * batch_gap_us,
                    n_queries: batch_queries.max(1),
                })
                .collect();
            SessionPlan { accept_us: clock_us, station, batches }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_world, GeneratorConfig};
    use crate::workload::{generate_trace, TraceConfig};

    fn world() -> World {
        generate_world(&GeneratorConfig::small(3, 10))
    }

    #[test]
    fn poisson_is_seeded_deterministic() {
        let w = world();
        let mut a = PoissonSource::new(&w, 42, 10_000.0, 16, 200);
        let mut b = PoissonSource::new(&w, 42, 10_000.0, 16, 200);
        loop {
            match (a.next_arrival(), b.next_arrival()) {
                (None, None) => break,
                (Some(x), Some(y)) => {
                    assert_eq!(x.at_us, y.at_us);
                    assert_eq!(x.queries, y.queries);
                }
                _ => panic!("streams diverged in length"),
            }
        }
    }

    #[test]
    fn poisson_rate_and_ordering() {
        let w = world();
        let mut s = PoissonSource::new(&w, 7, 1_000.0, 4, 2_000);
        let mut last = 0.0;
        let mut last_at = 0.0;
        let mut n = 0;
        while let Some(a) = s.next_arrival() {
            assert!(a.at_us >= last, "arrivals must be time-ordered");
            assert_eq!(a.queries.len(), 4);
            assert!(a.queries.iter().all(|q| q.station == a.station()));
            last = a.at_us;
            last_at = a.at_us;
            n += 1;
        }
        assert_eq!(n, 2_000);
        // Mean inter-arrival ≈ 1/λ = 1 000 µs (loose statistical bound).
        let mean_gap = last_at / 2_000.0;
        assert!((800.0..1200.0).contains(&mean_gap), "mean gap {mean_gap}");
        assert_eq!(s.offered_qps(), 4_000.0);
    }

    #[test]
    fn schedule_matches_stream() {
        let w = world();
        let sched = PoissonSource::new(&w, 9, 5_000.0, 8, 100).schedule();
        assert_eq!(sched.len(), 100);
        assert!(sched.iter().all(|&(_, n)| n == 8));
        assert!(sched.windows(2).all(|pair| pair[0].0 <= pair[1].0));
    }

    #[test]
    fn trace_source_offers_every_mct_query() {
        let w = world();
        let trace = generate_trace(&TraceConfig::scaled(5, 40, 60.0), &w);
        let mut s = TraceSource::new(&trace, 11, 500.0, 50.0);
        let total_req = s.total_requests();
        let mut queries = 0;
        let mut reqs = 0;
        let mut last = 0.0;
        while let Some(a) = s.next_arrival() {
            assert!(a.at_us >= last);
            last = a.at_us;
            queries += a.queries.len();
            reqs += 1;
        }
        assert_eq!(reqs, total_req);
        // Open-loop replay offers the full trace, nothing lost or invented.
        assert_eq!(queries, trace.stats().mct_queries);
        assert!(s.offered_qps() > 0.0);
    }

    #[test]
    fn rate_schedule_shapes() {
        let d = RateSchedule::diurnal(1_000.0, 800.0, 86_400.0);
        assert!((d.rate_rps(0.0) - 200.0).abs() < 1e-9, "starts at the trough");
        assert!((d.rate_rps(43_200.0) - 1_800.0).abs() < 1e-9, "peaks at midday");
        assert_eq!(d.peak_rps(), 1_800.0);
        assert_eq!(d.trough_rps(), 200.0);
        // Amplitude clamps to base: the rate never goes negative.
        let clamped = RateSchedule::diurnal(100.0, 5_000.0, 60.0);
        assert!(clamped.rate_rps(0.0) >= 0.0);

        let p = RateSchedule::piecewise(vec![(0.0, 100.0), (10.0, 900.0), (20.0, 300.0)]);
        assert_eq!(p.rate_rps(5.0), 100.0);
        assert_eq!(p.rate_rps(10.0), 900.0);
        assert_eq!(p.rate_rps(99.0), 300.0);
        assert_eq!(p.peak_rps(), 900.0);
        assert_eq!(p.trough_rps(), 100.0);
    }

    #[test]
    fn scheduled_source_retimes_but_preserves_payloads() {
        let w = world();
        let payloads = |src: &mut dyn ArrivalSource| {
            let mut out = Vec::new();
            while let Some(a) = src.next_arrival() {
                out.push(a.queries);
            }
            out
        };
        let schedule = RateSchedule::diurnal(1_000.0, 900.0, 2.0);
        let mut plain = PoissonSource::new(&w, 42, 10_000.0, 8, 300);
        let mut wrapped = ScheduledSource::new(
            Box::new(PoissonSource::new(&w, 42, 10_000.0, 8, 300)),
            7,
            &schedule,
        );
        assert_eq!(wrapped.total_requests(), 300);
        assert!(wrapped.offered_qps() > 0.0);
        let a = payloads(&mut plain);
        let mut at = Vec::new();
        let mut b = Vec::new();
        while let Some(x) = wrapped.next_arrival() {
            at.push(x.at_us);
            b.push(x.queries);
        }
        assert_eq!(a, b, "re-timing must not touch payloads");
        assert!(at.windows(2).all(|w| w[0] <= w[1]), "time-ordered");
        // Deterministic: same seeds ⇒ same clock.
        let mut again = ScheduledSource::new(
            Box::new(PoissonSource::new(&w, 42, 10_000.0, 8, 300)),
            7,
            &schedule,
        );
        let first = again.next_arrival().unwrap();
        assert_eq!(first.at_us, at[0]);
    }

    #[test]
    fn diurnal_clock_breathes_with_the_profile() {
        // Mean inter-arrival gap in the trough third vs the peak third of
        // a one-period diurnal stream: the trough must be visibly sparser.
        let w = world();
        let schedule = RateSchedule::diurnal(1_000.0, 800.0, 4.0);
        let mut src = ScheduledSource::new(
            Box::new(PoissonSource::new(&w, 9, 1.0, 4, 2_000)),
            21,
            &schedule,
        );
        let mut ts = Vec::new();
        while let Some(a) = src.next_arrival() {
            ts.push(a.at_us);
        }
        let in_band = |lo_s: f64, hi_s: f64| {
            ts.iter().filter(|&&t| t >= lo_s * 1e6 && t < hi_s * 1e6).count()
        };
        let trough = in_band(0.0, 0.8);
        let peak = in_band(1.2, 2.0);
        assert!(
            peak as f64 > 2.0 * trough as f64,
            "midday band must be ≥2× denser: peak {peak} vs trough {trough}"
        );
    }

    #[test]
    fn trace_source_is_deterministic() {
        let w = world();
        let trace = generate_trace(&TraceConfig::scaled(6, 20, 40.0), &w);
        let a = TraceSource::new(&trace, 3, 800.0, 25.0).schedule();
        let b = TraceSource::new(&trace, 3, 800.0, 25.0).schedule();
        assert_eq!(a, b);
    }

    #[test]
    fn piecewise_rate_at_step_boundaries() {
        // Exactly *at* a knot the new rate applies (t >= from), and the
        // final step holds forever.
        let p = RateSchedule::piecewise(vec![(0.0, 50.0), (5.0, 500.0), (12.0, 80.0)]);
        assert_eq!(p.rate_rps(0.0), 50.0, "first knot applies at t=0");
        assert_eq!(p.rate_rps(4.999_999), 50.0);
        assert_eq!(p.rate_rps(5.0), 500.0, "boundary belongs to the new step");
        assert_eq!(p.rate_rps(11.999_999), 500.0);
        assert_eq!(p.rate_rps(12.0), 80.0);
        assert_eq!(p.rate_rps(1e9), 80.0, "last step holds forever");
    }

    #[test]
    fn peak_and_trough_on_degenerate_schedules() {
        // Single-step piecewise: peak == trough == the only rate.
        let single = RateSchedule::piecewise(vec![(0.0, 750.0)]);
        assert_eq!(single.peak_rps(), 750.0);
        assert_eq!(single.trough_rps(), 750.0);
        assert_eq!(single.rate_rps(0.0), 750.0);
        assert_eq!(single.rate_rps(1e6), 750.0);

        // Constant: likewise degenerate.
        let c = RateSchedule::constant(123.0);
        assert_eq!(c.peak_rps(), 123.0);
        assert_eq!(c.trough_rps(), 123.0);

        // Zero-amplitude diurnal: a flat line dressed as a sinusoid.
        let flat = RateSchedule::diurnal(400.0, 0.0, 60.0);
        assert_eq!(flat.peak_rps(), 400.0);
        assert_eq!(flat.trough_rps(), 400.0);
        assert_eq!(flat.rate_rps(17.0), 400.0);

        // Full-amplitude diurnal troughs at exactly zero offered load.
        let full = RateSchedule::diurnal(300.0, 300.0, 60.0);
        assert_eq!(full.trough_rps(), 0.0);
        assert!(full.rate_rps(0.0).abs() < 1e-9);
    }

    #[test]
    fn poisson_gap_is_monotone_in_u() {
        // The inverse-CDF draw must be strictly increasing in u (and start
        // at a zero gap for u=0): larger uniforms ⇒ rarer, longer gaps.
        for schedule in [
            RateSchedule::constant(1_000.0),
            RateSchedule::diurnal(1_000.0, 900.0, 10.0),
            RateSchedule::piecewise(vec![(0.0, 10.0), (1.0, 10_000.0)]),
        ] {
            for clock_us in [0.0, 5e5, 3e6] {
                let mut last = -1.0;
                for i in 0..100 {
                    let u = i as f64 / 100.0;
                    let gap = schedule.poisson_gap_us(clock_us, u);
                    assert!(
                        gap > last,
                        "gap must grow with u: u={u} gap={gap} last={last} ({})",
                        schedule.label()
                    );
                    last = gap;
                }
                assert_eq!(schedule.poisson_gap_us(clock_us, 0.0), 0.0);
            }
        }
    }

    #[test]
    fn session_plans_are_seeded_poisson_streams() {
        let schedule = RateSchedule::constant(2_000.0);
        let plans = session_plans(77, &schedule, 300, 4, 16, 500.0, 40);
        assert_eq!(plans.len(), 300);
        assert_eq!(plans, session_plans(77, &schedule, 300, 4, 16, 500.0, 40), "deterministic");
        assert_ne!(
            plans[0].accept_us,
            session_plans(78, &schedule, 1, 4, 16, 500.0, 40)[0].accept_us,
            "seed moves the clock"
        );
        let mut last = 0.0;
        for p in &plans {
            assert!(p.accept_us >= last, "accepts are time-ordered");
            last = p.accept_us;
            assert!((p.station as usize) < 40);
            assert_eq!(p.batches.len(), 4);
            assert_eq!(p.total_queries(), 64);
            // Fixed spacing, and ready_us composes accept + offset.
            for (i, b) in p.batches.iter().enumerate() {
                assert_eq!(b.offset_us, i as f64 * 500.0);
                assert_eq!(p.ready_us(i), p.accept_us + b.offset_us);
            }
        }
        // Mean accept gap ≈ 1/λ = 500 µs (loose statistical bound).
        let mean_gap = plans.last().unwrap().accept_us / 300.0;
        assert!((350.0..650.0).contains(&mean_gap), "mean accept gap {mean_gap}");

        // Bursty shape: gap 0 ⇒ every batch ready at accept.
        let burst = session_plans(5, &schedule, 10, 8, 4, 0.0, 4);
        assert!(burst.iter().all(|p| p.batches.iter().all(|b| b.offset_us == 0.0)));
    }
}
