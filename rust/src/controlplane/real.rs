//! The real managed fleet: threaded [`NodeCore`] replicas spawned,
//! drained and joined **live**, under the same [`Autoscaler`] policies and
//! [`FaultPlan`] scripts as the DES ([`super::sim`]).
//!
//! The injector (this thread) owns the control loop: between arrivals it
//! executes every control event whose arrival-clock time has come — fault
//! kills, revivals, autoscaler ticks — then routes the arrival over the
//! live slots. Billing, observations and the event timeline all run on
//! the **arrival clock** (`at_us`), so a calibrated real run and a DES
//! run of the same scenario make comparable (and for the clock-free
//! utilisation policies, identical) scaling decisions.
//!
//! Failure semantics differ from the DES in one honest way: a real node
//! cannot be vaporised mid-batch, so a kill *drains* — the node stops
//! being routable instantly, its in-flight work completes on the dying
//! threads ([`NodeCore::shutdown`] joins them), and those requests are
//! counted `rerouted` (moved off the routable fleet). Either way the
//! guarantee under test is the same: **no admitted request is lost while
//! the fleet has a live replica** — `lost` can only tick when every slot
//! is down. Scale-ups spawn instantly (thread creation stands in for
//! cloud provisioning; the DES models the boot delay explicitly).
//!
//! Two further bounded asymmetries vs the DES: control events timed
//! *after* the last arrival are not executed (nothing can be observed of
//! them — no new work arrives, and a drain-based kill completes the
//! backlog either way), and a retiring node's drain tail is not billed
//! (it happens in wall time, off the arrival clock the billing runs on).

use std::sync::mpsc;
use std::time::Instant;

use anyhow::Result;

use crate::backend::BackendFactory;
use crate::cluster::{
    merged_quantiles, update_service_estimate, AdmissionPolicy, ClusterReport, NodeClass,
    NodeReport, RoutePolicy, Router,
};
use crate::coordinator::pipeline::{pace_until, Completion, NodeCore};
use crate::coordinator::{Percentiles, PipelineConfig};
use crate::workload::ArrivalSource;

use super::autoscaler::{Autoscaler, FleetObservation, ScalingAction};
use super::faults::FaultPlan;
use super::report::{ClassUsage, FleetDynamicsReport, ScalingEvent, ScalingEventKind};

/// One provisionable node class of the real fleet: economic identity,
/// replica topology, and the backend factory its engine threads build
/// from.
#[derive(Clone)]
pub struct RealClass {
    pub class: NodeClass,
    pub node: PipelineConfig,
    pub factory: BackendFactory,
}

/// Configuration of one managed real-fleet run.
#[derive(Clone)]
pub struct ManagedClusterConfig {
    pub classes: Vec<RealClass>,
    /// Class index of each initial node.
    pub initial: Vec<usize>,
    pub route: RoutePolicy,
    pub admission: AdmissionPolicy,
    pub route_seed: u64,
    /// Control-loop period on the arrival clock, µs.
    pub tick_us: f64,
    pub sla_us: f64,
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub faults: FaultPlan,
    pub profile_label: String,
}

impl ManagedClusterConfig {
    pub fn new(classes: Vec<RealClass>, initial: Vec<usize>) -> ManagedClusterConfig {
        assert!(!classes.is_empty() && !initial.is_empty());
        assert!(initial.iter().all(|&c| c < classes.len()));
        ManagedClusterConfig {
            classes,
            initial,
            route: RoutePolicy::JoinShortestQueue,
            admission: AdmissionPolicy::Open,
            route_seed: 0,
            tick_us: 100_000.0,
            sla_us: 20_000.0,
            min_nodes: 1,
            max_nodes: 8,
            faults: FaultPlan::none(),
            profile_label: "unlabelled".into(),
        }
    }

    pub fn with_route(mut self, route: RoutePolicy) -> ManagedClusterConfig {
        self.route = route;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> ManagedClusterConfig {
        self.admission = admission;
        self
    }

    pub fn with_control(mut self, tick_us: f64) -> ManagedClusterConfig {
        assert!(tick_us > 0.0);
        self.tick_us = tick_us;
        self
    }

    pub fn with_sla(mut self, sla_us: f64) -> ManagedClusterConfig {
        self.sla_us = sla_us;
        self
    }

    pub fn with_bounds(mut self, min_nodes: usize, max_nodes: usize) -> ManagedClusterConfig {
        assert!(min_nodes >= 1 && max_nodes >= min_nodes);
        self.min_nodes = min_nodes;
        self.max_nodes = max_nodes;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> ManagedClusterConfig {
        self.faults = faults;
        self
    }

    pub fn with_profile_label(mut self, label: impl Into<String>) -> ManagedClusterConfig {
        self.profile_label = label.into();
        self
    }

    fn label(&self) -> String {
        let init: Vec<String> =
            self.initial.iter().map(|&c| self.classes[c].class.name.to_string()).collect();
        format!(
            "managed [{}] route={} adm={} {}",
            init.join("+"),
            self.route.label(),
            self.admission.label(),
            self.faults.label()
        )
    }
}

/// One fleet slot: a class binding plus (while up) a live [`NodeCore`].
struct Slot {
    class_idx: usize,
    core: Option<NodeCore>,
    up: bool,
    billed_since_us: f64,
    billed_us: f64,
    backend: String,
    cache_lookups: u64,
    cache_hits: u64,
    agg_calls: usize,
    agg_requests: usize,
}

impl Slot {
    fn spawn(class_idx: usize, classes: &[RealClass], now_us: f64) -> Slot {
        let c = &classes[class_idx];
        Slot {
            class_idx,
            core: Some(NodeCore::spawn(&c.node, &c.factory)),
            up: true,
            billed_since_us: now_us,
            billed_us: 0.0,
            backend: String::new(),
            cache_lookups: 0,
            cache_hits: 0,
            agg_calls: 0,
            agg_requests: 0,
        }
    }

    fn outstanding(&self) -> usize {
        self.core.as_ref().map(|c| c.outstanding()).unwrap_or(0)
    }

    /// Stop routing, drain to completion, join the threads, and fold the
    /// node's counters into the slot. Returns the in-flight count drained.
    fn take_down(&mut self, now_us: f64) -> usize {
        debug_assert!(self.up);
        self.up = false;
        self.billed_us += now_us - self.billed_since_us;
        let core = self.core.take().expect("up slot has a core");
        let in_flight = core.outstanding();
        let stats = core.shutdown();
        if self.backend.is_empty() {
            self.backend = stats.backend.clone();
        }
        self.cache_lookups += stats.cache_lookups;
        self.cache_hits += stats.cache_hits;
        self.agg_calls += stats.agg_calls;
        self.agg_requests += stats.agg_requests;
        in_flight
    }
}

/// A managed, elastic, failure-injected real fleet.
pub struct ManagedCluster {
    pub config: ManagedClusterConfig,
}

impl ManagedCluster {
    pub fn new(config: ManagedClusterConfig) -> ManagedCluster {
        ManagedCluster { config }
    }

    /// Serve the arrival stream under `scaler` and report fleet dynamics.
    pub fn run(
        &self,
        scaler: &mut dyn Autoscaler,
        source: &mut dyn ArrivalSource,
    ) -> Result<FleetDynamicsReport> {
        let cfg = &self.config;
        let n_classes = cfg.classes.len();
        let class_list: Vec<NodeClass> =
            cfg.classes.iter().map(|c| c.class.clone()).collect();

        let mut slots: Vec<Slot> =
            cfg.initial.iter().map(|&c| Slot::spawn(c, &cfg.classes, 0.0)).collect();
        let mut router = Router::new(cfg.route).with_seed(cfg.route_seed).with_weights(
            slots.iter().map(|s| cfg.classes[s.class_idx].class.capacity_qps).collect(),
        );
        let (ctx, crx) = mpsc::channel::<Completion>();
        let t0 = Instant::now();

        // Per-slot completion stats (the injector is also the collector:
        // it drains the completion channel opportunistically, so a single
        // thread owns every counter and the run needs no locks).
        let mut lat: Vec<Percentiles> = slots.iter().map(|_| Percentiles::new()).collect();
        let mut completed: Vec<usize> = vec![0; slots.len()];
        let mut completed_q: Vec<usize> = vec![0; slots.len()];
        let mut est_service: Vec<f64> = vec![0.0; slots.len()];
        let mut failed = 0usize;
        let mut within_sla = 0usize;
        let mut win_queries = 0usize;
        let mut win_lat = Percentiles::new();
        let mut last_tick_us = 0.0f64;
        let mut next_tick_us = cfg.tick_us;
        let mut requests = 0usize;
        let mut dropped = 0usize;
        let mut dropped_q = 0usize;
        let mut lost = 0usize;
        let mut lost_q = 0usize;
        let mut rerouted = 0usize;
        let mut submitted = 0u64;
        let mut end_us = 0.0f64;
        let mut events: Vec<ScalingEvent> = Vec::new();
        let mut billable_by_class = vec![0usize; n_classes];
        for s in &slots {
            billable_by_class[s.class_idx] += 1;
        }
        let mut peak_by_class = billable_by_class.clone();
        let mut peak_total = slots.len();
        let faults = cfg.faults.kills();
        let mut next_fault = 0usize;
        // (revive time µs, slot) — kept sorted by construction order of
        // faults, merged into the control-event stream below.
        let mut revives: Vec<(f64, usize)> = Vec::new();

        macro_rules! record_completion {
            ($c:expr) => {{
                let c: Completion = $c;
                lat[c.node].record(c.latency_us);
                completed[c.node] += 1;
                completed_q[c.node] += c.n_queries;
                if !c.ok {
                    failed += 1;
                }
                if c.latency_us <= cfg.sla_us {
                    within_sla += 1;
                }
                win_lat.record(c.latency_us);
                est_service[c.node] = update_service_estimate(
                    est_service[c.node],
                    c.latency_us,
                    slots[c.node].outstanding(),
                );
            }};
        }
        macro_rules! drain_completions {
            () => {
                while let Ok(c) = crx.try_recv() {
                    record_completion!(c);
                }
            };
        }
        macro_rules! up_count {
            () => {
                slots.iter().filter(|s| s.up).count()
            };
        }

        // ---- Injector + control loop (this thread) ---------------------
        while let Some(a) = source.next_arrival() {
            requests += 1;
            end_us = end_us.max(a.at_us);

            // Execute every control event due before this arrival, in
            // arrival-clock order: fault kills, revivals, scaling ticks.
            loop {
                let fault_at =
                    faults.get(next_fault).map(|f| f.at_us).unwrap_or(f64::INFINITY);
                let revive_at = revives
                    .iter()
                    .map(|&(t, _)| t)
                    .fold(f64::INFINITY, f64::min);
                let tick_at = next_tick_us;
                let soonest = fault_at.min(revive_at).min(tick_at);
                if soonest > a.at_us {
                    break;
                }
                pace_until(t0, soonest);
                drain_completions!();
                if soonest == fault_at {
                    let f = faults[next_fault];
                    next_fault += 1;
                    if f.node < slots.len() && slots[f.node].up {
                        rerouted += slots[f.node].take_down(f.at_us);
                        billable_by_class[slots[f.node].class_idx] -= 1;
                        revives.push((f.at_us + f.down_us, f.node));
                        events.push(ScalingEvent {
                            t_us: f.at_us,
                            kind: ScalingEventKind::Fail,
                            class: cfg.classes[slots[f.node].class_idx]
                                .class
                                .name
                                .into(),
                            node: f.node,
                            up_after: up_count!(),
                        });
                    }
                } else if soonest == revive_at {
                    let pos = revives
                        .iter()
                        .position(|&(t, _)| t == revive_at)
                        .expect("revive entry");
                    let (at, slot_idx) = revives.swap_remove(pos);
                    let ci = slots[slot_idx].class_idx;
                    slots[slot_idx].core =
                        Some(NodeCore::spawn(&cfg.classes[ci].node, &cfg.classes[ci].factory));
                    slots[slot_idx].up = true;
                    slots[slot_idx].billed_since_us = at;
                    // Cold revive: the dead incarnation's (backlog-inflated)
                    // service estimate must not pre-bias SlaP90 admission —
                    // mirrors the DES reset.
                    est_service[slot_idx] = 0.0;
                    billable_by_class[ci] += 1;
                    peak_by_class[ci] = peak_by_class[ci].max(billable_by_class[ci]);
                    peak_total = peak_total.max(billable_by_class.iter().sum::<usize>());
                    events.push(ScalingEvent {
                        t_us: at,
                        kind: ScalingEventKind::Recover,
                        class: cfg.classes[ci].class.name.into(),
                        node: slot_idx,
                        up_after: up_count!(),
                    });
                } else {
                    // Scaling tick.
                    let now = tick_at;
                    next_tick_us += cfg.tick_us;
                    let window_s = ((now - last_tick_us) * 1e-6).max(1e-9);
                    let capacity_qps: f64 = slots
                        .iter()
                        .filter(|s| s.up)
                        .map(|s| cfg.classes[s.class_idx].class.capacity_qps)
                        .sum();
                    let offered_qps = win_queries as f64 / window_s;
                    let mut up_by_class = vec![0usize; n_classes];
                    for s in &slots {
                        if s.up {
                            up_by_class[s.class_idx] += 1;
                        }
                    }
                    let obs = FleetObservation {
                        t_us: now,
                        offered_qps,
                        capacity_qps,
                        utilisation: if capacity_qps > 0.0 {
                            offered_qps / capacity_qps
                        } else {
                            f64::INFINITY
                        },
                        outstanding: slots.iter().map(Slot::outstanding).sum(),
                        window_p90_us: if win_lat.is_empty() { 0.0 } else { win_lat.p90() },
                        sla_us: cfg.sla_us,
                        nodes_up: up_by_class.iter().sum(),
                        up_by_class,
                    };
                    match scaler.decide(&obs, &class_list) {
                        ScalingAction::Hold => {}
                        ScalingAction::Add(ci) if ci < n_classes => {
                            let billable: usize = billable_by_class.iter().sum();
                            if billable < cfg.max_nodes {
                                let idx = slots.len();
                                slots.push(Slot::spawn(ci, &cfg.classes, now));
                                lat.push(Percentiles::new());
                                completed.push(0);
                                completed_q.push(0);
                                est_service.push(0.0);
                                billable_by_class[ci] += 1;
                                peak_by_class[ci] =
                                    peak_by_class[ci].max(billable_by_class[ci]);
                                peak_total = peak_total
                                    .max(billable_by_class.iter().sum::<usize>());
                                router.set_weights(
                                    slots
                                        .iter()
                                        .map(|s| {
                                            cfg.classes[s.class_idx].class.capacity_qps
                                        })
                                        .collect(),
                                );
                                events.push(ScalingEvent {
                                    t_us: now,
                                    kind: ScalingEventKind::Add,
                                    class: cfg.classes[ci].class.name.into(),
                                    node: idx,
                                    up_after: up_count!(),
                                });
                            }
                        }
                        ScalingAction::Remove(ci) if ci < n_classes => {
                            if up_count!() > cfg.min_nodes {
                                let pick = slots
                                    .iter()
                                    .enumerate()
                                    .filter(|(_, s)| s.up && s.class_idx == ci)
                                    .min_by_key(|(i, s)| (s.outstanding(), *i))
                                    .map(|(i, _)| i);
                                if let Some(i) = pick {
                                    // Draining retirement: in-flight work
                                    // completes on the retiring threads.
                                    slots[i].take_down(now);
                                    billable_by_class[ci] -= 1;
                                    events.push(ScalingEvent {
                                        t_us: now,
                                        kind: ScalingEventKind::Drain,
                                        class: cfg.classes[ci].class.name.into(),
                                        node: i,
                                        up_after: up_count!(),
                                    });
                                }
                            }
                        }
                        _ => {}
                    }
                    win_queries = 0;
                    win_lat = Percentiles::new();
                    last_tick_us = now;
                }
            }

            pace_until(t0, a.at_us);
            drain_completions!();
            win_queries += a.queries.len();
            let depths: Vec<usize> = slots.iter().map(Slot::outstanding).collect();
            let up: Vec<bool> = slots.iter().map(|s| s.up).collect();
            match router.route_up(a.station(), &depths, Some(&up)) {
                None => {
                    lost += 1;
                    lost_q += a.queries.len();
                }
                Some(target) => {
                    if !cfg.admission.admits(depths[target], est_service[target]) {
                        dropped += 1;
                        dropped_q += a.queries.len();
                        continue;
                    }
                    slots[target].core.as_ref().expect("routable slot").submit_tagged(
                        a.queries,
                        submitted,
                        target,
                        &ctx,
                    );
                    submitted += 1;
                }
            }
        }

        // ---- Drain: every submitted request completes ------------------
        drop(ctx);
        while let Ok(c) = crx.recv() {
            record_completion!(c);
        }
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        for s in slots.iter_mut() {
            if s.up {
                s.take_down(end_us);
            }
        }

        let completed_total: usize = completed.iter().sum();
        let completed_queries: usize = completed_q.iter().sum();
        anyhow::ensure!(
            completed_total == submitted as usize,
            "managed cluster lost requests: {submitted} submitted, {completed_total} completed"
        );
        anyhow::ensure!(
            requests == completed_total + dropped + lost,
            "conservation: {requests} != {completed_total} + {dropped} + {lost}"
        );

        let (p50, p90, p99) = merged_quantiles(&lat);
        let mut lat = lat;
        let per_node: Vec<NodeReport> = slots
            .iter()
            .enumerate()
            .map(|(i, s)| NodeReport {
                class: cfg.classes[s.class_idx].class.name.to_string(),
                backend: s.backend.clone(),
                completed_requests: completed[i],
                completed_queries: completed_q[i],
                failed_requests: 0,
                req_p90_us: if lat[i].is_empty() { 0.0 } else { lat[i].p90() },
                cache_hit_rate: if s.cache_lookups == 0 {
                    0.0
                } else {
                    s.cache_hits as f64 / s.cache_lookups as f64
                },
                mean_aggregation: s.agg_requests as f64 / s.agg_calls.max(1) as f64,
                health: 1.0,
            })
            .collect();
        let (lookups, hits) = slots
            .iter()
            .fold((0u64, 0u64), |(l, h), s| (l + s.cache_lookups, h + s.cache_hits));

        let cluster = ClusterReport {
            label: cfg.label(),
            route: cfg.route.label(),
            offered_qps: source.offered_qps(),
            achieved_qps: completed_queries as f64 / wall_s,
            requests,
            completed: completed_total,
            dropped,
            lost,
            completed_queries,
            dropped_queries: dropped_q,
            lost_queries: lost_q,
            failed,
            failed_queries: 0,
            req_p50_us: p50,
            req_p90_us: p90,
            req_p99_us: p99,
            cache_hit_rate: if lookups == 0 { 0.0 } else { hits as f64 / lookups as f64 },
            per_node,
        };

        let mut usage: Vec<ClassUsage> = cfg
            .classes
            .iter()
            .enumerate()
            .map(|(ci, c)| ClassUsage {
                class: c.class.name.into(),
                node_hours: 0.0,
                hourly_usd: c.class.hourly_usd(),
                cost_usd: 0.0,
                peak_nodes: peak_by_class[ci],
            })
            .collect();
        for s in &slots {
            usage[s.class_idx].node_hours += s.billed_us / 3.6e9;
        }
        for u in usage.iter_mut() {
            u.cost_usd = u.node_hours * u.hourly_usd;
        }
        let node_hours: f64 = usage.iter().map(|u| u.node_hours).sum();
        let cost_usd: f64 = usage.iter().map(|u| u.cost_usd).sum();

        Ok(FleetDynamicsReport {
            policy: scaler.name().into(),
            profile: cfg.profile_label.clone(),
            cluster,
            events,
            usage,
            node_hours,
            cost_usd,
            sla_us: cfg.sla_us,
            sla_attainment: if requests == 0 {
                1.0
            } else {
                within_sla as f64 / requests as f64
            },
            rerouted,
            peak_nodes: peak_total,
            gray_fault_windows: cfg.faults.grays().len(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::controlplane::autoscaler::{ReactiveUtilisation, StaticFleet};
    use crate::coordinator::{AggregationPolicy, Topology};
    use crate::nfa::constraint_gen::HardwareConfig;
    use crate::rules::standard::StandardVersion;
    use crate::testing::fixture::compile_fixture;
    use crate::workload::{PoissonSource, RateSchedule, ScheduledSource};

    fn fixture() -> crate::testing::fixture::MctFixture {
        compile_fixture(4411, 300, StandardVersion::V2, HardwareConfig::v2_aws(4))
    }

    fn node_cfg() -> PipelineConfig {
        PipelineConfig::new(Topology::new(2, 1, 1, 4))
            .with_aggregation(AggregationPolicy::DrainQueue)
    }

    /// Probe one real node's drain rate so the scenario's rates are set
    /// relative to measured capacity (the crossval calibration step).
    fn probe_rps(f: &crate::testing::fixture::MctFixture, batch: usize) -> f64 {
        let cfg = crate::cluster::ClusterConfig::new(1, node_cfg());
        let mut burst = PoissonSource::new(&f.world, 3, 1e8, batch, 120);
        let r = crate::cluster::Cluster::new(cfg, f.native_factory())
            .run(&mut burst)
            .unwrap();
        r.achieved_qps / batch as f64
    }

    #[test]
    fn managed_real_fleet_scales_up_and_down_with_the_wave() {
        let f = fixture();
        let batch = 16;
        let mu_rps = probe_rps(&f, batch);
        let classes = vec![RealClass {
            class: NodeClass::fpga_f1(mu_rps * batch as f64),
            node: node_cfg(),
            factory: f.native_factory(),
        }];
        // One diurnal period spanning 400 requests around the measured
        // single-node rate: trough 0.2×, peak 1.8×.
        let n = 400usize;
        let period_s = n as f64 / mu_rps;
        let schedule = RateSchedule::diurnal(mu_rps, 0.8 * mu_rps, period_s);
        let mut src = ScheduledSource::new(
            Box::new(PoissonSource::new(&f.world, 7, 1e3, batch, n)),
            11,
            &schedule,
        );
        let cfg = ManagedClusterConfig::new(classes, vec![0])
            .with_control(period_s * 1e6 / 25.0)
            .with_sla(1e9) // latency not under test here
            .with_bounds(1, 3)
            .with_profile_label(schedule.label());
        let mut scaler = ReactiveUtilisation::new(0);
        let r = ManagedCluster::new(cfg).run(&mut scaler, &mut src).unwrap();
        assert!(r.cluster.conserves_requests());
        assert_eq!(r.cluster.lost, 0);
        assert!(r.peak_nodes > 1, "peak must trigger a real scale-up: {}", r.summary());
        assert!(r.events.iter().any(|e| e.kind == ScalingEventKind::Add));
        assert!(r.node_hours > 0.0 && r.cost_usd > 0.0);
    }

    #[test]
    fn real_kill_mid_run_drains_without_losing_admitted_work() {
        let f = fixture();
        let batch = 16;
        let n = 300usize;
        let rate = 1.5 * probe_rps(&f, batch); // mild overload keeps queues non-empty
        let classes = vec![RealClass {
            class: NodeClass::fpga_f1(rate * batch as f64),
            node: node_cfg(),
            factory: f.native_factory(),
        }];
        let span_us = n as f64 / rate * 1e6;
        let cfg = ManagedClusterConfig::new(classes, vec![0, 0])
            .with_control(span_us / 10.0)
            .with_sla(1e9)
            .with_bounds(1, 2)
            .with_faults(FaultPlan::kill(0, span_us * 0.4, span_us * 0.3));
        let mut src = PoissonSource::new(&f.world, 13, rate, batch, n);
        let mut stat = StaticFleet;
        let r = ManagedCluster::new(cfg).run(&mut stat, &mut src).unwrap();
        assert!(r.cluster.conserves_requests());
        assert_eq!(r.cluster.lost, 0, "a live peer means zero loss: {}", r.summary());
        assert_eq!(r.cluster.dropped, 0);
        assert_eq!(r.cluster.completed, n);
        assert!(r.events.iter().any(|e| e.kind == ScalingEventKind::Fail));
        assert!(
            r.events.iter().any(|e| e.kind == ScalingEventKind::Recover),
            "the node must revive: {}",
            r.timeline()
        );
    }
}
