//! Seeded fault injection: which node fails when, how, and for how long.
//!
//! A [`FaultPlan`] is pure data — both fleet realisations execute the same
//! plan, so a DES run and a real threaded run see the *same* failures at
//! the same points of the arrival clock. Two fault families share the
//! plan:
//!
//! * **Fail-stop** ([`FaultMode::Kill`]): the node stops being routable
//!   immediately; its in-flight work is drained or rerouted (never
//!   silently discarded — the report's conservation invariant separates
//!   `rerouted` from `lost`, and `lost` stays zero while at least one
//!   replica is live); after `down_us` the node revives cold (fresh
//!   cache, fresh queues).
//! * **Gray** ([`FaultMode::Slowdown`], [`FaultMode::ErrorRate`],
//!   [`FaultMode::Hang`]): the node stays up and routable but degrades —
//!   a straggler multiplies its service time, an intermittent fault
//!   fails calls with probability `p`, a stalling kernel adds `stall_us`
//!   with probability `p`. Gray windows are *invisible* to the fleet's
//!   up/down machinery by design (that is what makes them gray); the
//!   resilience layer (`rust/src/resilience/`) has to detect them from
//!   outcomes. Executors sample [`FaultPlan::gray_at`] at service start
//!   (DES) or call time (the real `MatchBackend` decorator) with a
//!   seeded RNG, so both realisations draw from the same distributions.

use crate::prng::Rng;

/// How a fault manifests.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultMode {
    /// Fail-stop: node down, revives after `down_us`.
    Kill,
    /// Straggler: service time multiplied by `factor` while active.
    Slowdown { factor: f64 },
    /// Intermittent per-call failures with probability `p`.
    ErrorRate { p: f64 },
    /// Kernel stalls: with probability `p` a call takes `stall_us` extra.
    Hang { p: f64, stall_us: f64 },
}

/// One injected failure: `node` degrades in `mode` at `at_us` for
/// `down_us` (for `Kill`, the time until revival; for gray modes, the
/// length of the degradation window).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    pub node: usize,
    pub at_us: f64,
    pub down_us: f64,
    pub mode: FaultMode,
}

impl Fault {
    pub fn active_at(&self, t_us: f64) -> bool {
        t_us >= self.at_us && t_us < self.at_us + self.down_us
    }
}

/// The combined gray effect on one node at one instant: all active
/// windows folded together (slowdown factors multiply, error/hang
/// probabilities saturate-add).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GrayEffect {
    pub slow_factor: f64,
    pub error_p: f64,
    pub hang_p: f64,
    pub stall_us: f64,
}

impl GrayEffect {
    pub fn clean() -> GrayEffect {
        GrayEffect { slow_factor: 1.0, error_p: 0.0, hang_p: 0.0, stall_us: 0.0 }
    }

    pub fn is_clean(&self) -> bool {
        *self == GrayEffect::clean()
    }
}

/// The run's failure script, time-ordered.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// No failures (the default).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A single scripted kill.
    pub fn kill(node: usize, at_us: f64, down_us: f64) -> FaultPlan {
        FaultPlan::none().and_kill(node, at_us, down_us)
    }

    /// Append another scripted kill (kept time-ordered).
    pub fn and_kill(self, node: usize, at_us: f64, down_us: f64) -> FaultPlan {
        self.and_fault(node, at_us, down_us, FaultMode::Kill)
    }

    /// Append a straggler window: `node` serves `factor ×` slower.
    pub fn and_slowdown(self, node: usize, at_us: f64, down_us: f64, factor: f64) -> FaultPlan {
        assert!(factor >= 1.0);
        self.and_fault(node, at_us, down_us, FaultMode::Slowdown { factor })
    }

    /// Append an intermittent-error window: calls fail w.p. `p`.
    pub fn and_error_rate(self, node: usize, at_us: f64, down_us: f64, p: f64) -> FaultPlan {
        assert!((0.0..=1.0).contains(&p));
        self.and_fault(node, at_us, down_us, FaultMode::ErrorRate { p })
    }

    /// Append a kernel-stall window: calls take `stall_us` extra w.p. `p`.
    pub fn and_hang(
        self,
        node: usize,
        at_us: f64,
        down_us: f64,
        p: f64,
        stall_us: f64,
    ) -> FaultPlan {
        assert!((0.0..=1.0).contains(&p) && stall_us > 0.0);
        self.and_fault(node, at_us, down_us, FaultMode::Hang { p, stall_us })
    }

    fn and_fault(mut self, node: usize, at_us: f64, down_us: f64, mode: FaultMode) -> FaultPlan {
        assert!(at_us >= 0.0 && down_us > 0.0);
        self.faults.push(Fault { node, at_us, down_us, mode });
        self.faults.sort_by(|a, b| a.at_us.total_cmp(&b.at_us));
        self
    }

    /// `n_faults` seeded kills over the initial `n_nodes`, uniformly
    /// placed across `window_us`, each down for an exponential draw around
    /// `mean_down_us`. Deterministic for a given seed.
    pub fn seeded(
        seed: u64,
        n_nodes: usize,
        window_us: f64,
        n_faults: usize,
        mean_down_us: f64,
    ) -> FaultPlan {
        assert!(n_nodes >= 1 && window_us > 0.0 && mean_down_us > 0.0);
        let mut rng = Rng::new(seed ^ 0xFA_17);
        let mut plan = FaultPlan::none();
        for _ in 0..n_faults {
            let node = rng.index(n_nodes);
            let at_us = rng.f64() * window_us;
            let down_us = -(1.0 - rng.f64()).ln() * mean_down_us;
            plan = plan.and_kill(node, at_us, down_us.max(1.0));
        }
        plan
    }

    /// A seeded gray-fault matrix: `n_faults` degradation windows over
    /// the initial `n_nodes`, uniformly placed across the middle 80% of
    /// `window_us`, each lasting 20–60% of the window. Modes rotate
    /// through straggler (4–16×), error rate (10–40%), and hangs
    /// (2–10% at 20–120 × `service_scale_us`). Deterministic per seed.
    pub fn seeded_gray(
        seed: u64,
        n_nodes: usize,
        window_us: f64,
        n_faults: usize,
        service_scale_us: f64,
    ) -> FaultPlan {
        assert!(n_nodes >= 1 && window_us > 0.0 && service_scale_us > 0.0);
        let mut rng = Rng::new(seed ^ 0x62A9);
        let mut plan = FaultPlan::none();
        for _ in 0..n_faults {
            let node = rng.index(n_nodes);
            let at_us = (0.1 + 0.8 * rng.f64()) * window_us;
            let down_us = (0.2 + 0.4 * rng.f64()) * window_us;
            plan = match rng.index(3) {
                0 => plan.and_slowdown(node, at_us, down_us, 4.0 + 12.0 * rng.f64()),
                1 => plan.and_error_rate(node, at_us, down_us, 0.1 + 0.3 * rng.f64()),
                _ => plan.and_hang(
                    node,
                    at_us,
                    down_us,
                    0.02 + 0.08 * rng.f64(),
                    (20.0 + 100.0 * rng.f64()) * service_scale_us,
                ),
            };
        }
        plan
    }

    /// Parse a CLI fault spec. Accepted forms:
    /// `N` (N seeded kills — back-compat), `gray:slow:F` (one straggler
    /// window at `F ×`), `gray:err:P`, `gray:hang:P:STALL_US`, and
    /// `gray:mix:N` (a seeded `N`-window gray matrix). Scripted gray
    /// windows span the middle 80% of `window_us` on a seeded node.
    pub fn parse_cli(
        spec: &str,
        seed: u64,
        n_nodes: usize,
        window_us: f64,
        service_scale_us: f64,
    ) -> Option<FaultPlan> {
        if let Ok(n) = spec.parse::<usize>() {
            return Some(if n == 0 {
                FaultPlan::none()
            } else {
                FaultPlan::seeded(seed, n_nodes, window_us, n, window_us / 10.0)
            });
        }
        let parts: Vec<&str> = spec.split(':').collect();
        if parts.first() != Some(&"gray") {
            return None;
        }
        let node = Rng::new(seed ^ 0x62A9).index(n_nodes);
        let (at, dur) = (0.1 * window_us, 0.8 * window_us);
        match (parts.get(1), parts.get(2), parts.get(3)) {
            (Some(&"slow"), Some(f), None) => {
                Some(FaultPlan::none().and_slowdown(node, at, dur, f.parse().ok()?))
            }
            (Some(&"err"), Some(p), None) => {
                Some(FaultPlan::none().and_error_rate(node, at, dur, p.parse().ok()?))
            }
            (Some(&"hang"), Some(p), Some(s)) => Some(FaultPlan::none().and_hang(
                node,
                at,
                dur,
                p.parse().ok()?,
                s.parse().ok()?,
            )),
            (Some(&"mix"), Some(n), None) => Some(FaultPlan::seeded_gray(
                seed,
                n_nodes,
                window_us,
                n.parse().ok()?,
                service_scale_us,
            )),
            _ => None,
        }
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    /// The fail-stop subset — what the fleets' up/down machinery executes.
    pub fn kills(&self) -> Vec<Fault> {
        self.faults.iter().filter(|f| f.mode == FaultMode::Kill).copied().collect()
    }

    /// The gray subset — what the service-time/error injectors execute.
    pub fn grays(&self) -> Vec<Fault> {
        self.faults.iter().filter(|f| f.mode != FaultMode::Kill).copied().collect()
    }

    pub fn has_gray(&self) -> bool {
        self.faults.iter().any(|f| f.mode != FaultMode::Kill)
    }

    /// Fold every gray window active on `node` at `t_us` into one
    /// effect: slowdown factors multiply, error and hang probabilities
    /// saturate-add (capped at 1), stall times add.
    pub fn gray_at(&self, node: usize, t_us: f64) -> GrayEffect {
        let mut eff = GrayEffect::clean();
        for f in &self.faults {
            if f.node != node || !f.active_at(t_us) {
                continue;
            }
            match f.mode {
                FaultMode::Kill => {}
                FaultMode::Slowdown { factor } => eff.slow_factor *= factor,
                FaultMode::ErrorRate { p } => eff.error_p = (eff.error_p + p).min(1.0),
                FaultMode::Hang { p, stall_us } => {
                    eff.hang_p = (eff.hang_p + p).min(1.0);
                    eff.stall_us += stall_us;
                }
            }
        }
        eff
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn label(&self) -> String {
        if self.is_empty() {
            "no-faults".into()
        } else {
            let grays = self.grays().len();
            match (self.faults.len() - grays, grays) {
                (k, 0) => format!("{k} faults"),
                (0, g) => format!("{g} gray faults"),
                (k, g) => format!("{k} faults + {g} gray"),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_deterministic_ordered_and_in_window() {
        let a = FaultPlan::seeded(7, 4, 1e6, 6, 50_000.0);
        let b = FaultPlan::seeded(7, 4, 1e6, 6, 50_000.0);
        assert_eq!(a.faults(), b.faults());
        assert_eq!(a.len(), 6);
        assert!(a.faults().windows(2).all(|w| w[0].at_us <= w[1].at_us));
        assert!(a.faults().iter().all(|f| f.node < 4 && f.at_us <= 1e6 && f.down_us > 0.0));
        assert!(a.faults().iter().all(|f| f.mode == FaultMode::Kill));
        let c = FaultPlan::seeded(8, 4, 1e6, 6, 50_000.0);
        assert_ne!(a.faults(), c.faults(), "different seeds script different failures");
    }

    #[test]
    fn scripted_kills_sort_by_time() {
        let plan = FaultPlan::kill(1, 500.0, 10.0).and_kill(0, 100.0, 10.0);
        assert_eq!(plan.faults()[0].node, 0);
        assert_eq!(plan.faults()[1].node, 1);
        assert_eq!(plan.label(), "2 faults");
        assert_eq!(FaultPlan::none().label(), "no-faults");
    }

    #[test]
    fn gray_windows_fold_and_stay_invisible_to_kills() {
        let plan = FaultPlan::kill(0, 0.0, 100.0)
            .and_slowdown(1, 100.0, 400.0, 8.0)
            .and_slowdown(1, 200.0, 100.0, 2.0)
            .and_error_rate(1, 100.0, 400.0, 0.3)
            .and_hang(2, 0.0, 1_000.0, 0.05, 500.0);
        assert_eq!(plan.kills().len(), 1);
        assert_eq!(plan.grays().len(), 4);
        assert!(plan.has_gray());
        assert_eq!(plan.label(), "1 faults + 4 gray");

        // Outside any window: clean.
        assert!(plan.gray_at(1, 50.0).is_clean());
        // One straggler window + errors.
        let e = plan.gray_at(1, 150.0);
        assert_eq!(e.slow_factor, 8.0);
        assert_eq!(e.error_p, 0.3);
        // Overlapping straggler windows multiply.
        assert_eq!(plan.gray_at(1, 250.0).slow_factor, 16.0);
        // Hang node carries stall probability and stall time.
        let h = plan.gray_at(2, 500.0);
        assert_eq!((h.hang_p, h.stall_us), (0.05, 500.0));
        // Kills do not contribute gray effects.
        assert!(plan.gray_at(0, 50.0).is_clean());
        // Window end is exclusive.
        assert!(plan.gray_at(1, 500.0).is_clean());
    }

    #[test]
    fn seeded_gray_matrix_is_deterministic_and_gray_only() {
        let a = FaultPlan::seeded_gray(11, 4, 1e6, 5, 300.0);
        let b = FaultPlan::seeded_gray(11, 4, 1e6, 5, 300.0);
        assert_eq!(a.faults(), b.faults());
        assert_eq!(a.grays().len(), 5);
        assert!(a.kills().is_empty());
        assert_ne!(a.faults(), FaultPlan::seeded_gray(12, 4, 1e6, 5, 300.0).faults());
    }

    #[test]
    fn cli_specs_parse_back_compat_and_gray() {
        let kills = FaultPlan::parse_cli("3", 7, 4, 1e6, 300.0).unwrap();
        assert_eq!(kills.kills().len(), 3);
        assert!(FaultPlan::parse_cli("0", 7, 4, 1e6, 300.0).unwrap().is_empty());
        let slow = FaultPlan::parse_cli("gray:slow:10", 7, 4, 1e6, 300.0).unwrap();
        assert_eq!(slow.grays().len(), 1);
        assert!(matches!(slow.faults()[0].mode, FaultMode::Slowdown { factor } if factor == 10.0));
        let err = FaultPlan::parse_cli("gray:err:0.2", 7, 4, 1e6, 300.0).unwrap();
        assert!(matches!(err.faults()[0].mode, FaultMode::ErrorRate { p } if p == 0.2));
        let hang = FaultPlan::parse_cli("gray:hang:0.05:800", 7, 4, 1e6, 300.0).unwrap();
        assert!(
            matches!(hang.faults()[0].mode, FaultMode::Hang { p, stall_us } if p == 0.05 && stall_us == 800.0)
        );
        assert_eq!(FaultPlan::parse_cli("gray:mix:4", 7, 4, 1e6, 300.0).unwrap().len(), 4);
        assert!(FaultPlan::parse_cli("bogus", 7, 4, 1e6, 300.0).is_none());
        assert!(FaultPlan::parse_cli("gray:slow", 7, 4, 1e6, 300.0).is_none());
    }
}
