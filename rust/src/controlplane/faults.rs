//! Seeded fault injection: which node dies when, and for how long.
//!
//! A [`FaultPlan`] is pure data — both fleet realisations execute the same
//! plan, so a DES run and a real threaded run see the *same* failures at
//! the same points of the arrival clock. Semantics at the fleet layer
//! (`controlplane::{sim, real}`): a faulted node stops being routable
//! immediately; its in-flight work is drained or rerouted (never silently
//! discarded — the report's conservation invariant separates `rerouted`
//! from `lost`, and `lost` stays zero while at least one replica is live);
//! after `down_us` the node revives cold (fresh cache, fresh queues).

use crate::prng::Rng;

/// One injected failure: `node` dies at `at_us` and revives `down_us`
/// later.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Fault {
    pub node: usize,
    pub at_us: f64,
    pub down_us: f64,
}

/// The run's failure script, time-ordered.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<Fault>,
}

impl FaultPlan {
    /// No failures (the default).
    pub fn none() -> FaultPlan {
        FaultPlan::default()
    }

    /// A single scripted kill.
    pub fn kill(node: usize, at_us: f64, down_us: f64) -> FaultPlan {
        FaultPlan::none().and_kill(node, at_us, down_us)
    }

    /// Append another scripted kill (kept time-ordered).
    pub fn and_kill(mut self, node: usize, at_us: f64, down_us: f64) -> FaultPlan {
        assert!(at_us >= 0.0 && down_us > 0.0);
        self.faults.push(Fault { node, at_us, down_us });
        self.faults.sort_by(|a, b| a.at_us.partial_cmp(&b.at_us).unwrap());
        self
    }

    /// `n_faults` seeded kills over the initial `n_nodes`, uniformly
    /// placed across `window_us`, each down for an exponential draw around
    /// `mean_down_us`. Deterministic for a given seed.
    pub fn seeded(
        seed: u64,
        n_nodes: usize,
        window_us: f64,
        n_faults: usize,
        mean_down_us: f64,
    ) -> FaultPlan {
        assert!(n_nodes >= 1 && window_us > 0.0 && mean_down_us > 0.0);
        let mut rng = Rng::new(seed ^ 0xFA_17);
        let mut plan = FaultPlan::none();
        for _ in 0..n_faults {
            let node = rng.index(n_nodes);
            let at_us = rng.f64() * window_us;
            let down_us = -(1.0 - rng.f64()).ln() * mean_down_us;
            plan = plan.and_kill(node, at_us, down_us.max(1.0));
        }
        plan
    }

    pub fn faults(&self) -> &[Fault] {
        &self.faults
    }

    pub fn is_empty(&self) -> bool {
        self.faults.is_empty()
    }

    pub fn len(&self) -> usize {
        self.faults.len()
    }

    pub fn label(&self) -> String {
        if self.is_empty() {
            "no-faults".into()
        } else {
            format!("{} faults", self.faults.len())
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn seeded_plan_is_deterministic_ordered_and_in_window() {
        let a = FaultPlan::seeded(7, 4, 1e6, 6, 50_000.0);
        let b = FaultPlan::seeded(7, 4, 1e6, 6, 50_000.0);
        assert_eq!(a.faults(), b.faults());
        assert_eq!(a.len(), 6);
        assert!(a.faults().windows(2).all(|w| w[0].at_us <= w[1].at_us));
        assert!(a.faults().iter().all(|f| f.node < 4 && f.at_us <= 1e6 && f.down_us > 0.0));
        let c = FaultPlan::seeded(8, 4, 1e6, 6, 50_000.0);
        assert_ne!(a.faults(), c.faults(), "different seeds script different failures");
    }

    #[test]
    fn scripted_kills_sort_by_time() {
        let plan = FaultPlan::kill(1, 500.0, 10.0).and_kill(0, 100.0, 10.0);
        assert_eq!(plan.faults()[0].node, 0);
        assert_eq!(plan.faults()[1].node, 1);
        assert_eq!(plan.label(), "2 faults");
        assert_eq!(FaultPlan::none().label(), "no-faults");
    }
}
