//! Autoscaling policies: one decision per control tick, shared verbatim by
//! the fleet DES and the real threaded fleet (the policy code never knows
//! which realisation is driving it).
//!
//! The [`FleetObservation`] deliberately leads with **offered load vs
//! provisioned capacity** — both are defined on the arrival clock, so the
//! utilisation-driven policies make *identical* decisions in the simulator
//! and the real cluster once each realisation's node capacity is
//! calibrated. Latency (window p90 vs SLA) is realisation-coloured and
//! drives the [`SlaLatency`] policy. [`CostAware`] is the §6.1 lesson as a
//! controller: it sizes the needed capacity with
//! [`costmodel::plan_fleet`](crate::costmodel::plan_fleet) against every
//! class in the catalogue and adds the class with the cheapest marginal
//! $/query·s — or removes the most expensive node the fleet can spare.

use crate::cluster::NodeClass;
use crate::costmodel::plan_fleet;

/// What the control loop sees at one tick. Rates are MCT queries/s over
/// the elapsed control window; `utilisation` is offered/capacity (large
/// when no capacity is live).
#[derive(Debug, Clone)]
pub struct FleetObservation {
    /// Arrival-clock time of the tick, µs.
    pub t_us: f64,
    /// Offered load over the last window, queries/s.
    pub offered_qps: f64,
    /// Σ capacity of live (routable) nodes, queries/s.
    pub capacity_qps: f64,
    /// offered / capacity.
    pub utilisation: f64,
    /// Requests admitted and not yet completed, fleet-wide.
    pub outstanding: usize,
    /// p90 of request latencies completed during the window, µs (0 when
    /// the window saw no completion).
    pub window_p90_us: f64,
    /// The run's latency objective, µs.
    pub sla_us: f64,
    /// Live (routable) nodes.
    pub nodes_up: usize,
    /// Live nodes per class index (parallel to the `classes` slice handed
    /// to [`Autoscaler::decide`]).
    pub up_by_class: Vec<usize>,
}

/// One scaling decision; class values index the `classes` slice.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingAction {
    Hold,
    /// Provision one node of this class.
    Add(usize),
    /// Drain and retire one node of this class.
    Remove(usize),
}

/// A scaling policy: one [`ScalingAction`] per control tick. The driver
/// is the authority on fleet-level bounds (it enforces `min_nodes`/
/// `max_nodes` whatever the policy says); the built-in policies
/// additionally decline to *propose* removing the last live node, purely
/// so their intent stream stays sensible in isolation.
pub trait Autoscaler {
    fn name(&self) -> &'static str;
    fn decide(&mut self, obs: &FleetObservation, classes: &[NodeClass]) -> ScalingAction;
}

/// The Table 2/3 baseline: a fixed fleet, whatever happens.
#[derive(Debug, Clone, Copy, Default)]
pub struct StaticFleet;

impl Autoscaler for StaticFleet {
    fn name(&self) -> &'static str {
        "static"
    }

    fn decide(&mut self, _obs: &FleetObservation, _classes: &[NodeClass]) -> ScalingAction {
        ScalingAction::Hold
    }
}

/// Cooldown bookkeeping shared by the reactive policies: after any scaling
/// action, hold for `cooldown` ticks so the fleet settles before the next
/// decision (provisioned capacity needs a window to show up in the
/// utilisation signal).
#[derive(Debug, Clone, Copy)]
struct Cooldown {
    ticks: usize,
    remaining: usize,
}

impl Cooldown {
    fn new(ticks: usize) -> Cooldown {
        Cooldown { ticks, remaining: 0 }
    }

    /// True when a decision is allowed this tick (counts the tick down
    /// otherwise).
    fn ready(&mut self) -> bool {
        if self.remaining > 0 {
            self.remaining -= 1;
            false
        } else {
            true
        }
    }

    fn fire(&mut self) {
        self.remaining = self.ticks;
    }
}

/// Queue-depth/utilisation-driven scaling of one class: add when offered
/// load exceeds `scale_up_above` of capacity, remove when it falls under
/// `scale_down_below`. The workhorse reactive policy.
#[derive(Debug, Clone, Copy)]
pub struct ReactiveUtilisation {
    /// Class this policy scales.
    pub class: usize,
    pub scale_up_above: f64,
    pub scale_down_below: f64,
    cool: Cooldown,
}

impl ReactiveUtilisation {
    pub fn new(class: usize) -> ReactiveUtilisation {
        ReactiveUtilisation::with_band(class, 0.85, 0.30)
    }

    pub fn with_band(class: usize, up: f64, down: f64) -> ReactiveUtilisation {
        assert!(0.0 < down && down < up);
        ReactiveUtilisation {
            class,
            scale_up_above: up,
            scale_down_below: down,
            cool: Cooldown::new(1),
        }
    }
}

impl Autoscaler for ReactiveUtilisation {
    fn name(&self) -> &'static str {
        "reactive"
    }

    fn decide(&mut self, obs: &FleetObservation, _classes: &[NodeClass]) -> ScalingAction {
        if !self.cool.ready() {
            return ScalingAction::Hold;
        }
        if obs.utilisation > self.scale_up_above {
            self.cool.fire();
            ScalingAction::Add(self.class)
        } else if obs.utilisation < self.scale_down_below
            && obs.up_by_class.get(self.class).copied().unwrap_or(0) > 0
            && obs.nodes_up > 1
        {
            self.cool.fire();
            ScalingAction::Remove(self.class)
        } else {
            ScalingAction::Hold
        }
    }
}

/// SLA-attainment-driven scaling of one class: add capacity while the
/// window p90 crowds the SLA, shed it when latency is comfortably inside
/// *and* the fleet is lightly loaded (so a quiet window alone never
/// triggers a flap).
#[derive(Debug, Clone, Copy)]
pub struct SlaLatency {
    pub class: usize,
    /// Add when window p90 > this fraction of the SLA.
    pub upscale_frac: f64,
    /// Remove when window p90 < this fraction and utilisation < 0.5.
    pub downscale_frac: f64,
    cool: Cooldown,
}

impl SlaLatency {
    pub fn new(class: usize) -> SlaLatency {
        SlaLatency { class, upscale_frac: 0.9, downscale_frac: 0.3, cool: Cooldown::new(1) }
    }
}

impl Autoscaler for SlaLatency {
    fn name(&self) -> &'static str {
        "sla-p90"
    }

    fn decide(&mut self, obs: &FleetObservation, _classes: &[NodeClass]) -> ScalingAction {
        if !self.cool.ready() || obs.window_p90_us <= 0.0 {
            return ScalingAction::Hold;
        }
        if obs.window_p90_us > self.upscale_frac * obs.sla_us {
            self.cool.fire();
            ScalingAction::Add(self.class)
        } else if obs.window_p90_us < self.downscale_frac * obs.sla_us
            && obs.utilisation < 0.5
            && obs.up_by_class.get(self.class).copied().unwrap_or(0) > 0
            && obs.nodes_up > 1
        {
            self.cool.fire();
            ScalingAction::Remove(self.class)
        } else {
            ScalingAction::Hold
        }
    }
}

/// Cost-aware scaling over the whole class catalogue: size the fleet for
/// `offered / target_utilisation` queries/s with
/// [`costmodel::plan_fleet`](crate::costmodel::plan_fleet) per class, add
/// the class whose plan is cheapest per hour when capacity is short, and
/// retire the most expensive live node when the fleet can spare it — the
/// §6.1 "balance the deployment" lesson as a feedback controller.
#[derive(Debug, Clone, Copy)]
pub struct CostAware {
    /// Capacity headroom target: provision for offered/target.
    pub target_utilisation: f64,
    cool: Cooldown,
}

impl CostAware {
    pub fn new() -> CostAware {
        CostAware::with_target(0.70)
    }

    pub fn with_target(target_utilisation: f64) -> CostAware {
        assert!(0.0 < target_utilisation && target_utilisation < 1.0);
        CostAware { target_utilisation, cool: Cooldown::new(1) }
    }

    /// The class whose [`plan_fleet`] sizing for `needed_qps` costs the
    /// least per hour.
    pub fn cheapest_class(classes: &[NodeClass], needed_qps: f64) -> usize {
        let mut best = 0usize;
        let mut best_usd = f64::INFINITY;
        for (i, c) in classes.iter().enumerate() {
            let plan = plan_fleet(c.element, needed_qps, c.capacity_qps.max(1.0), 0);
            let usd_per_hour = plan.units as f64 * c.hourly_usd();
            if usd_per_hour < best_usd {
                best_usd = usd_per_hour;
                best = i;
            }
        }
        best
    }
}

impl Default for CostAware {
    fn default() -> Self {
        CostAware::new()
    }
}

impl Autoscaler for CostAware {
    fn name(&self) -> &'static str {
        "cost-aware"
    }

    fn decide(&mut self, obs: &FleetObservation, classes: &[NodeClass]) -> ScalingAction {
        if !self.cool.ready() || classes.is_empty() {
            return ScalingAction::Hold;
        }
        let needed_qps = obs.offered_qps / self.target_utilisation;
        if obs.capacity_qps < needed_qps {
            self.cool.fire();
            return ScalingAction::Add(Self::cheapest_class(classes, needed_qps));
        }
        // Can the fleet retire its priciest live node and still hold the
        // headroom target?
        let costliest = obs
            .up_by_class
            .iter()
            .enumerate()
            .filter(|&(_, &n)| n > 0)
            .max_by(|&(a, _), &(b, _)| {
                classes[a]
                    .cost_per_qps()
                    .partial_cmp(&classes[b].cost_per_qps())
                    .unwrap()
            })
            .map(|(i, _)| i);
        if let Some(i) = costliest {
            if obs.nodes_up > 1 && obs.capacity_qps - classes[i].capacity_qps >= needed_qps {
                self.cool.fire();
                return ScalingAction::Remove(i);
            }
        }
        ScalingAction::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn classes() -> Vec<NodeClass> {
        vec![NodeClass::fpga_f1(20e6), NodeClass::cpu_c5(2e6)]
    }

    fn obs(offered: f64, capacity: f64, p90: f64, up: Vec<usize>) -> FleetObservation {
        FleetObservation {
            t_us: 0.0,
            offered_qps: offered,
            capacity_qps: capacity,
            utilisation: if capacity > 0.0 { offered / capacity } else { f64::INFINITY },
            outstanding: 0,
            window_p90_us: p90,
            sla_us: 10_000.0,
            nodes_up: up.iter().sum(),
            up_by_class: up,
        }
    }

    #[test]
    fn static_policy_never_scales() {
        let mut s = StaticFleet;
        assert_eq!(s.decide(&obs(1e9, 1.0, 1e9, vec![1, 0]), &classes()), ScalingAction::Hold);
    }

    #[test]
    fn reactive_scales_on_utilisation_band_with_cooldown() {
        let mut r = ReactiveUtilisation::new(0);
        let hot = obs(18e6, 20e6, 0.0, vec![1, 0]);
        assert_eq!(r.decide(&hot, &classes()), ScalingAction::Add(0));
        // Cooldown: the immediate next tick holds even under overload.
        assert_eq!(r.decide(&hot, &classes()), ScalingAction::Hold);
        let cold = obs(2e6, 40e6, 0.0, vec![2, 0]);
        assert_eq!(r.decide(&cold, &classes()), ScalingAction::Remove(0));
        // Never removes the last live node.
        let mut r2 = ReactiveUtilisation::new(0);
        assert_eq!(r2.decide(&obs(1e5, 20e6, 0.0, vec![1, 0]), &classes()), ScalingAction::Hold);
    }

    #[test]
    fn sla_policy_follows_the_latency_signal() {
        let mut s = SlaLatency::new(0);
        // p90 crowding the 10 ms SLA ⇒ add.
        assert_eq!(
            s.decide(&obs(5e6, 20e6, 9_500.0, vec![1, 0]), &classes()),
            ScalingAction::Add(0)
        );
        let mut s2 = SlaLatency::new(0);
        // Comfortable p90 at light load ⇒ remove.
        assert_eq!(
            s2.decide(&obs(2e6, 40e6, 1_000.0, vec![2, 0]), &classes()),
            ScalingAction::Remove(0)
        );
        // No completions this window ⇒ no blind decision.
        let mut s3 = SlaLatency::new(0);
        assert_eq!(
            s3.decide(&obs(5e6, 20e6, 0.0, vec![2, 0]), &classes()),
            ScalingAction::Hold
        );
    }

    #[test]
    fn cost_aware_adds_the_cheapest_class_per_marginal_qps() {
        // fpga-f1: 20 M q/s at $1.2266/h ⇒ ~0.06 $/Mqps·h.
        // cpu-c5: 2 M q/s at $1.452/h ⇒ ~0.73 $/Mqps·h. FPGA is cheaper.
        let cs = classes();
        assert_eq!(CostAware::cheapest_class(&cs, 30e6), 0);
        let mut c = CostAware::new();
        assert_eq!(c.decide(&obs(18e6, 20e6, 0.0, vec![1, 0]), &cs), ScalingAction::Add(0));
        // Flip the economics: a CPU class with great capacity per dollar.
        let flipped = vec![NodeClass::fpga_f1(2e6), NodeClass::cpu_c5(20e6)];
        assert_eq!(CostAware::cheapest_class(&flipped, 30e6), 1);
    }

    #[test]
    fn cost_aware_retires_the_priciest_spare_node() {
        let cs = classes();
        let mut c = CostAware::new();
        // Capacity 42 M vs needed 10/0.7 ≈ 14.3 M: even dropping the
        // costly-per-qps CPU node leaves plenty ⇒ remove class 1.
        let o = obs(10e6, 42e6, 0.0, vec![2, 1]);
        assert_eq!(c.decide(&o, &cs), ScalingAction::Remove(1));
        // Tight capacity ⇒ hold.
        let mut c2 = CostAware::new();
        assert_eq!(c2.decide(&obs(14e6, 21e6, 0.0, vec![1, 1]), &cs), ScalingAction::Hold);
    }
}
