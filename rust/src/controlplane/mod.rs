//! The control plane: what turns the static [`cluster`](crate::cluster)
//! into a **managed, heterogeneous, elastic fleet** — the deployment layer
//! the paper's §6.1 economics implicitly assume and never build.
//!
//! Table 2/3 price a *statically sized* fleet against peak demand, and the
//! §6.1 discussion shows how badly that goes in the cloud (a big FPGA
//! starved behind a small CPU, 2.5–3× the cost). The control plane attacks
//! both halves of that conclusion dynamically:
//!
//! * **Heterogeneity** — CPU-only and FPGA-backed node classes
//!   ([`NodeClass`], carrying [`costmodel::Element`](crate::costmodel::Element)
//!   price/capacity metadata) serve behind one capacity-weighted router,
//!   so the fleet mix is a *policy decision*, not a deployment constant.
//! * **Elasticity** — an [`Autoscaler`] watches offered load (diurnal
//!   [`RateSchedule`](crate::workload::RateSchedule) profiles), queue
//!   state and SLA attainment, and adds/removes nodes mid-run; the
//!   cost-aware policy sizes with
//!   [`costmodel::plan_fleet`](crate::costmodel::plan_fleet) and picks the
//!   cheapest class per marginal query/s.
//! * **Failure** — a seeded [`FaultPlan`] kills and revives nodes mid-run;
//!   the fleet drains/reroutes in-flight work and the report separates
//!   *rerouted* from *lost* requests (lost only when no replica is live).
//!
//! Like every layer of this reproduction, the control plane has **two
//! realisations** over the same policy code: a deterministic dynamic DES
//! ([`sim::simulate_fleet`]) and a real threaded fleet of
//! [`NodeCore`](crate::coordinator) replicas ([`real::ManagedCluster`])
//! that spawns, drains and joins nodes live.
//! [`crate::coordinator::crossval`] checks both rank scaling policies
//! identically by fleet cost.
//!
//! [`report::FleetDynamicsReport`] closes the loop back to §6.1: a
//! scaling-event timeline, per-class node-hours, and modeled **$/Mquery**
//! under the diurnal profile — the number `benches/fleet_dynamics.rs`
//! shows dropping when an autoscaled heterogeneous fleet replaces a
//! static peak-provisioned one at the same SLA attainment.

pub mod autoscaler;
pub mod faults;
pub mod real;
pub mod report;
pub mod sim;

pub use autoscaler::{
    Autoscaler, CostAware, FleetObservation, ReactiveUtilisation, ScalingAction, SlaLatency,
    StaticFleet,
};
pub use faults::{Fault, FaultMode, FaultPlan, GrayEffect};
pub use real::{ManagedCluster, ManagedClusterConfig, RealClass};
pub use report::{ClassUsage, FleetDynamicsReport, ScalingEvent, ScalingEventKind};
pub use sim::{simulate_fleet, FleetSimConfig, SimClass};

// Re-exported so control-plane users get the class vocabulary from one
// place.
pub use crate::cluster::NodeClass;
