//! Control-plane run reports: the scaling-event timeline, per-class
//! node-hours, and the §6.1 headline re-derived dynamically — modeled
//! **$/Mquery** of the fleet that actually ran, not of a statically sized
//! one.

use crate::cluster::ClusterReport;

/// What happened to the fleet at one point of the timeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalingEventKind {
    /// Autoscaler provisioned a node (serving after the provision delay).
    Add,
    /// Autoscaler started draining a node for retirement.
    Drain,
    /// Fault plan killed a node.
    Fail,
    /// A killed node revived.
    Recover,
}

impl ScalingEventKind {
    pub fn label(&self) -> &'static str {
        match self {
            ScalingEventKind::Add => "add",
            ScalingEventKind::Drain => "drain",
            ScalingEventKind::Fail => "fail",
            ScalingEventKind::Recover => "recover",
        }
    }
}

/// One timeline entry.
#[derive(Debug, Clone)]
pub struct ScalingEvent {
    pub t_us: f64,
    pub kind: ScalingEventKind,
    pub class: String,
    pub node: usize,
    /// Live (routable) nodes after the event took effect.
    pub up_after: usize,
}

impl ScalingEvent {
    /// Fault-plan kill at `t_us` — the event shape shared by the control
    /// plane's drills and the front door's fault timeline.
    pub fn fail(t_us: f64, class: &str, node: usize, up_after: usize) -> ScalingEvent {
        let class = class.to_string();
        ScalingEvent { t_us, kind: ScalingEventKind::Fail, class, node, up_after }
    }

    /// Revival of a previously killed node.
    pub fn recover(t_us: f64, class: &str, node: usize, up_after: usize) -> ScalingEvent {
        ScalingEvent {
            t_us,
            kind: ScalingEventKind::Recover,
            class: class.to_string(),
            node,
            up_after,
        }
    }

    /// One formatted timeline line — every consumer (fleet timeline,
    /// front-door fault log, CLIs) prints events identically.
    pub fn line(&self) -> String {
        format!(
            "  t={:>10.0} µs  {:<7}  {:<8} node {:>2}  ({} up)",
            self.t_us,
            self.kind.label(),
            self.class,
            self.node,
            self.up_after
        )
    }
}

/// Billed usage of one node class over the run.
#[derive(Debug, Clone)]
pub struct ClassUsage {
    pub class: String,
    /// Σ billed node time, hours, on the arrival clock — so identical
    /// scaling decisions bill comparably across realisations. One known
    /// asymmetry: the DES bills a retiring/failed node's drain tail
    /// (sim time is observable), while the real fleet stops billing at
    /// the decision — its drain happens in wall time, which has no
    /// arrival-clock coordinate.
    pub node_hours: f64,
    /// Effective hourly price of the class's element.
    pub hourly_usd: f64,
    /// `node_hours × hourly_usd`.
    pub cost_usd: f64,
    /// Most nodes of this class simultaneously billed.
    pub peak_nodes: usize,
}

/// Outcome of one managed-fleet run (DES or real).
#[derive(Debug, Clone)]
pub struct FleetDynamicsReport {
    /// Autoscaler name (`static`, `reactive`, `sla-p90`, `cost-aware`).
    pub policy: String,
    /// Offered-load profile label.
    pub profile: String,
    /// The serving outcome, cluster vocabulary (offered vs achieved,
    /// completed/dropped/lost, quantiles, per-node + per-class slices).
    pub cluster: ClusterReport,
    pub events: Vec<ScalingEvent>,
    pub usage: Vec<ClassUsage>,
    /// Σ usage node-hours.
    pub node_hours: f64,
    /// Σ usage cost.
    pub cost_usd: f64,
    pub sla_us: f64,
    /// Completions within the SLA / offered requests — drops and losses
    /// count against attainment, so shedding cannot fake compliance.
    pub sla_attainment: f64,
    /// In-flight requests moved off a failed node (drained or re-queued;
    /// all of them completed elsewhere or later).
    pub rerouted: usize,
    /// Most nodes simultaneously billed.
    pub peak_nodes: usize,
    /// Gray degradation windows scripted by the fault plan (stragglers,
    /// error bursts, hangs). Gray faults never touch the up/down
    /// machinery — they surface as `cluster.failed` calls and inflated
    /// latency, which is exactly what makes them gray.
    pub gray_fault_windows: usize,
}

impl FleetDynamicsReport {
    /// Modeled dollars per million completed queries — the cost axis the
    /// `fleet_dynamics` bench compares static vs autoscaled fleets on.
    pub fn dollars_per_mquery(&self) -> f64 {
        let mq = self.cluster.completed_queries as f64 / 1e6;
        self.cost_usd / mq.max(1e-12)
    }

    /// SLA attainment at or above `target` (e.g. 0.90 for "p90 within
    /// SLA").
    pub fn meets_sla(&self, target: f64) -> bool {
        self.sla_attainment >= target
    }

    /// One-line summary for benches and the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{} @ {} | {} | {:.1} node-h = {:.2} $ → {:.3} $/Mq | SLA({:.0} µs) {:.1} % | \
             peak {} nodes, {} scale events, {} rerouted",
            self.policy,
            self.profile,
            self.cluster.summary(),
            self.node_hours,
            self.cost_usd,
            self.dollars_per_mquery(),
            self.sla_us,
            self.sla_attainment * 100.0,
            self.peak_nodes,
            self.events.len(),
            self.rerouted,
        )
    }

    /// Multi-line scaling-event timeline.
    pub fn timeline(&self) -> String {
        let mut out = String::new();
        for e in &self.events {
            out.push_str(&e.line());
            out.push('\n');
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cluster_stub(completed_queries: usize) -> ClusterReport {
        ClusterReport {
            label: "t".into(),
            route: "rr".into(),
            offered_qps: 0.0,
            achieved_qps: 0.0,
            requests: 10,
            completed: 10,
            dropped: 0,
            lost: 0,
            completed_queries,
            dropped_queries: 0,
            lost_queries: 0,
            failed: 0,
            failed_queries: 0,
            req_p50_us: 0.0,
            req_p90_us: 0.0,
            req_p99_us: 0.0,
            cache_hit_rate: 0.0,
            per_node: Vec::new(),
        }
    }

    #[test]
    fn dollars_per_mquery_and_sla_gate() {
        let r = FleetDynamicsReport {
            policy: "static".into(),
            profile: "const".into(),
            cluster: cluster_stub(2_000_000),
            events: vec![ScalingEvent {
                t_us: 5.0,
                kind: ScalingEventKind::Add,
                class: "fpga-f1".into(),
                node: 1,
                up_after: 2,
            }],
            usage: Vec::new(),
            node_hours: 2.0,
            cost_usd: 3.0,
            sla_us: 10_000.0,
            sla_attainment: 0.93,
            rerouted: 0,
            peak_nodes: 2,
            gray_fault_windows: 0,
        };
        assert!((r.dollars_per_mquery() - 1.5).abs() < 1e-12);
        assert!(r.meets_sla(0.9));
        assert!(!r.meets_sla(0.95));
        assert!(r.summary().contains("$/Mq"));
        assert!(r.timeline().contains("add"));
    }
}
