//! The dynamic fleet DES: the static cluster simulator of
//! [`crate::cluster::sim`] grown a node lifecycle — nodes provision,
//! serve, drain, die and revive mid-run, driven by an [`Autoscaler`] tick
//! loop and a [`FaultPlan`], all deterministic for a given config +
//! arrival stream.
//!
//! Per-node service semantics (feeder stage, optional kernel datapath,
//! per-node LRU) are identical to the static simulator; what this module
//! adds is *time-varying fleet membership*:
//!
//! * **provisioning** — an `Add` decision creates a node that starts
//!   serving `provision_us` later (cloud boot time), billed from the
//!   decision;
//! * **draining** — a `Remove` decision stops routing to the node; it
//!   finishes its outstanding work, then retires (billing stops);
//! * **failure** — a fault kills a node abruptly: its queued and
//!   in-service requests are *rerouted* through the router to live nodes
//!   (counted, never silently discarded; they re-enter the feeder on the
//!   new node). Only when **no** node is live does work count as `lost` —
//!   the drain/reroute guarantee the acceptance tests pin.
//!
//! Stale-event hygiene: every feeder/kernel event carries the node's
//! epoch at scheduling time; a kill bumps the epoch, so in-flight events
//! of the dead incarnation are ignored when they fire.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::backend::LruCache;
use crate::cluster::{
    merged_quantiles, update_service_estimate, AdmissionPolicy, ClusterReport, NodeClass,
    NodeReport, RoutePolicy, Router, SimArrival, SimEngine, SimNodeSpec,
};
use crate::coordinator::{Overheads, Percentiles};
use crate::erbium::FpgaModel;

use super::autoscaler::{Autoscaler, FleetObservation, ScalingAction};
use super::faults::FaultPlan;
use super::report::{
    ClassUsage, FleetDynamicsReport, ScalingEvent, ScalingEventKind,
};

/// One provisionable node class: the economic identity
/// ([`NodeClass`]) plus its DES realisation ([`SimNodeSpec`]).
#[derive(Debug, Clone)]
pub struct SimClass {
    pub class: NodeClass,
    pub spec: SimNodeSpec,
}

impl SimClass {
    pub fn new(class: NodeClass, spec: SimNodeSpec) -> SimClass {
        SimClass { class, spec }
    }

    /// Build with `class.capacity_qps` calibrated from the spec's
    /// closed-form estimate at `batch`-sized requests, so router weights
    /// and autoscaler capacity planning agree with the simulated node.
    pub fn calibrated(
        mut class: NodeClass,
        spec: SimNodeSpec,
        o: &Overheads,
        batch: usize,
    ) -> SimClass {
        class.capacity_qps = spec.capacity_qps(o, batch);
        SimClass { class, spec }
    }
}

/// Configuration of one managed-fleet simulation.
#[derive(Debug, Clone)]
pub struct FleetSimConfig {
    /// Class catalogue the autoscaler provisions from.
    pub classes: Vec<SimClass>,
    /// Class index of each initial node.
    pub initial: Vec<usize>,
    pub route: RoutePolicy,
    pub admission: AdmissionPolicy,
    pub cache_capacity: Option<usize>,
    pub overheads: Overheads,
    pub route_seed: u64,
    /// Control-loop period, µs.
    pub tick_us: f64,
    /// Add-decision → serving delay, µs (cloud instance boot).
    pub provision_us: f64,
    /// Latency objective, µs (drives [`FleetObservation::sla_us`] and the
    /// report's attainment).
    pub sla_us: f64,
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub faults: FaultPlan,
    /// Offered-load profile label for the report.
    pub profile_label: String,
}

impl FleetSimConfig {
    pub fn new(classes: Vec<SimClass>, initial: Vec<usize>) -> FleetSimConfig {
        assert!(!classes.is_empty() && !initial.is_empty());
        assert!(initial.iter().all(|&c| c < classes.len()));
        FleetSimConfig {
            classes,
            initial,
            route: RoutePolicy::JoinShortestQueue,
            admission: AdmissionPolicy::Open,
            cache_capacity: None,
            overheads: Overheads::default(),
            route_seed: 0,
            tick_us: 100_000.0,
            provision_us: 50_000.0,
            sla_us: 20_000.0,
            min_nodes: 1,
            max_nodes: 8,
            faults: FaultPlan::none(),
            profile_label: "unlabelled".into(),
        }
    }

    pub fn with_route(mut self, route: RoutePolicy) -> FleetSimConfig {
        self.route = route;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> FleetSimConfig {
        self.admission = admission;
        self
    }

    pub fn with_cache(mut self, capacity: usize) -> FleetSimConfig {
        self.cache_capacity = Some(capacity);
        self
    }

    pub fn with_control(mut self, tick_us: f64, provision_us: f64) -> FleetSimConfig {
        assert!(tick_us > 0.0 && provision_us >= 0.0);
        self.tick_us = tick_us;
        self.provision_us = provision_us;
        self
    }

    pub fn with_sla(mut self, sla_us: f64) -> FleetSimConfig {
        self.sla_us = sla_us;
        self
    }

    pub fn with_bounds(mut self, min_nodes: usize, max_nodes: usize) -> FleetSimConfig {
        assert!(min_nodes >= 1 && max_nodes >= min_nodes);
        self.min_nodes = min_nodes;
        self.max_nodes = max_nodes;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> FleetSimConfig {
        self.faults = faults;
        self
    }

    pub fn with_profile_label(mut self, label: impl Into<String>) -> FleetSimConfig {
        self.profile_label = label.into();
        self
    }

    fn label(&self) -> String {
        let init: Vec<String> =
            self.initial.iter().map(|&c| self.classes[c].class.name.to_string()).collect();
        format!(
            "fleet [{}] route={} adm={} {}",
            init.join("+"),
            self.route.label(),
            self.admission.label(),
            self.faults.label()
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum NodeState {
    /// Billed, not yet serving (boot).
    Provisioning,
    /// Billed and routable.
    Up,
    /// Billed, no longer routable; retires when its queue empties.
    Draining,
    /// Killed by a fault; not billed, revives later.
    Down,
    /// Gone for good (scale-down completed).
    Retired,
}

struct DReq {
    node: usize,
    at_us: f64,
    n: usize,
    misses: usize,
}

struct DNode {
    class_idx: usize,
    spec: SimNodeSpec,
    model: Option<FpgaModel>,
    state: NodeState,
    epoch: u32,
    queue: VecDeque<usize>,
    /// Requests currently in feeder service (needed for fault reroute).
    feeding: Vec<usize>,
    kernel_queue: VecDeque<usize>,
    in_kernel: Option<usize>,
    free_feeders: usize,
    cache: Option<LruCache<()>>,
    outstanding: usize,
    est_service_us: f64,
    completed: usize,
    completed_q: usize,
    lookups: u64,
    hits: u64,
    lat: Percentiles,
    billed_since_us: f64,
    billed_us: f64,
}

impl DNode {
    fn of(class_idx: usize, cfg: &FleetSimConfig, state: NodeState, now_us: f64) -> DNode {
        let spec = cfg.classes[class_idx].spec;
        DNode {
            class_idx,
            spec,
            model: spec.kernel_model(),
            state,
            epoch: 0,
            queue: VecDeque::new(),
            feeding: Vec::new(),
            kernel_queue: VecDeque::new(),
            in_kernel: None,
            free_feeders: spec.feeders,
            cache: cfg.cache_capacity.map(LruCache::new),
            outstanding: 0,
            est_service_us: 0.0,
            completed: 0,
            completed_q: 0,
            lookups: 0,
            hits: 0,
            lat: Percentiles::new(),
            billed_since_us: now_us,
            billed_us: 0.0,
        }
    }

    fn billed(&self) -> bool {
        matches!(self.state, NodeState::Provisioning | NodeState::Up | NodeState::Draining)
    }

    fn bill_stop(&mut self, now_us: f64) {
        self.billed_us += now_us - self.billed_since_us;
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Ev {
    Arrive { req: usize },
    FeederDone { node: usize, epoch: u32, req: usize },
    KernelDone { node: usize, epoch: u32, req: usize },
    FaultDown { fault: usize },
    NodeUp { node: usize, epoch: u32 },
    /// Control tick. Note: ties on the ns-rounded timestamp break by
    /// insertion order (`seq`), not by variant — a tick scheduled a full
    /// period ahead fires *before* same-instant completions, which then
    /// count toward the next window.
    Tick,
}

type EvHeap = BinaryHeap<Reverse<(u64, u64, Ev)>>;

fn push_ev(heap: &mut EvHeap, seq: &mut u64, t_us: f64, ev: Ev) {
    let key = (t_us.max(0.0) * 1000.0).round() as u64; // ns resolution
    heap.push(Reverse((key, *seq, ev)));
    *seq += 1;
}

fn router_weights(nodes: &[DNode], classes: &[SimClass]) -> Vec<f64> {
    nodes.iter().map(|n| classes[n.class_idx].class.capacity_qps).collect()
}

#[allow(clippy::too_many_arguments)]
fn try_start_feeder(
    node_idx: usize,
    nodes: &mut [DNode],
    reqs: &mut [DReq],
    arrivals: &[SimArrival],
    o: &Overheads,
    now: f64,
    heap: &mut EvHeap,
    seq: &mut u64,
) {
    while nodes[node_idx].free_feeders > 0 {
        let Some(rid) = nodes[node_idx].queue.pop_front() else { break };
        let node = &mut nodes[node_idx];
        let keys = &arrivals[rid].keys;
        let mut misses = reqs[rid].n;
        if let Some(cache) = node.cache.as_mut() {
            if !keys.is_empty() {
                node.lookups += keys.len() as u64;
                let mut hit = 0usize;
                for &k in keys {
                    if cache.get(k).is_some() {
                        hit += 1;
                    } else {
                        cache.insert(k, ());
                    }
                }
                node.hits += hit as u64;
                misses = reqs[rid].n - hit;
            }
        }
        reqs[rid].misses = misses;
        node.free_feeders -= 1;
        node.feeding.push(rid);
        let service = match node.spec.engine {
            SimEngine::Fpga { .. } => o.sched.us(reqs[rid].n) + o.encode.us(misses),
            SimEngine::Cpu { per_query_us } => {
                o.sched.us(reqs[rid].n) + misses as f64 * per_query_us
            }
        };
        push_ev(
            heap,
            seq,
            now + service,
            Ev::FeederDone { node: node_idx, epoch: node.epoch, req: rid },
        );
    }
}

fn try_start_kernel(
    node_idx: usize,
    nodes: &mut [DNode],
    reqs: &[DReq],
    o: &Overheads,
    now: f64,
    heap: &mut EvHeap,
    seq: &mut u64,
) {
    let node = &mut nodes[node_idx];
    if node.in_kernel.is_some() {
        return;
    }
    let Some(rid) = node.kernel_queue.pop_front() else { return };
    let model = node.model.as_ref().expect("kernel queue on a CPU node");
    node.in_kernel = Some(rid);
    let service =
        o.xrt.submission_us(node.spec.feeders) + model.batch_timing(reqs[rid].misses).total_us;
    push_ev(
        heap,
        seq,
        now + service,
        Ev::KernelDone { node: node_idx, epoch: node.epoch, req: rid },
    );
}

/// Run the managed-fleet simulation under `scaler`; deterministic for a
/// given config + arrivals.
pub fn simulate_fleet(
    cfg: &FleetSimConfig,
    scaler: &mut dyn Autoscaler,
    arrivals: &[SimArrival],
) -> FleetDynamicsReport {
    assert!(!arrivals.is_empty(), "a fleet run needs arrivals");
    assert!(cfg.initial.len() <= cfg.max_nodes);
    let o = &cfg.overheads;
    let class_list: Vec<NodeClass> = cfg.classes.iter().map(|c| c.class.clone()).collect();
    let n_classes = cfg.classes.len();

    let mut nodes: Vec<DNode> =
        cfg.initial.iter().map(|&c| DNode::of(c, cfg, NodeState::Up, 0.0)).collect();
    let mut router = Router::new(cfg.route)
        .with_seed(cfg.route_seed)
        .with_weights(router_weights(&nodes, &cfg.classes));

    let mut reqs: Vec<DReq> = Vec::with_capacity(arrivals.len());
    let mut heap: EvHeap = BinaryHeap::new();
    let mut seq = 0u64;
    let mut offered_q = 0usize;
    let mut end_us = 0.0f64;
    for a in arrivals {
        offered_q += a.n_queries;
        end_us = end_us.max(a.at_us);
        let rid = reqs.len();
        reqs.push(DReq { node: usize::MAX, at_us: a.at_us, n: a.n_queries, misses: a.n_queries });
        push_ev(&mut heap, &mut seq, a.at_us + o.zmq.request_us(a.n_queries), Ev::Arrive {
            req: rid,
        });
    }
    // The fleet's up/down machinery executes the fail-stop subset only;
    // gray windows degrade service inside the cluster layer instead.
    let kills = cfg.faults.kills();
    for (i, f) in kills.iter().enumerate() {
        push_ev(&mut heap, &mut seq, f.at_us, Ev::FaultDown { fault: i });
    }
    if cfg.tick_us <= end_us {
        push_ev(&mut heap, &mut seq, cfg.tick_us, Ev::Tick);
    }

    // ---- Run counters --------------------------------------------------
    let mut dropped = 0usize;
    let mut dropped_q = 0usize;
    let mut lost = 0usize;
    let mut lost_q = 0usize;
    let mut rerouted = 0usize;
    let mut within_sla = 0usize;
    let mut makespan = 0.0f64;
    let mut events: Vec<ScalingEvent> = Vec::new();
    // Billing/peak tracking.
    let mut billable_by_class = vec![0usize; n_classes];
    for n in &nodes {
        billable_by_class[n.class_idx] += 1;
    }
    let mut peak_by_class = billable_by_class.clone();
    let mut peak_total = nodes.len();
    // Control window accumulators.
    let mut win_queries = 0usize;
    let mut win_lat = Percentiles::new();
    let mut last_tick_us = 0.0f64;

    macro_rules! up_count {
        () => {
            nodes.iter().filter(|n| n.state == NodeState::Up).count()
        };
    }

    while let Some(Reverse((key, _, ev))) = heap.pop() {
        let now = key as f64 / 1000.0;
        match ev {
            Ev::Arrive { req } => {
                win_queries += reqs[req].n;
                let depths: Vec<usize> = nodes.iter().map(|n| n.outstanding).collect();
                let up: Vec<bool> =
                    nodes.iter().map(|n| n.state == NodeState::Up).collect();
                match router.route_up(arrivals[req].station, &depths, Some(&up)) {
                    None => {
                        // No live replica: lost to failure, visibly.
                        lost += 1;
                        lost_q += reqs[req].n;
                    }
                    Some(target) => {
                        if !cfg
                            .admission
                            .admits(depths[target], nodes[target].est_service_us)
                        {
                            dropped += 1;
                            dropped_q += reqs[req].n;
                            continue;
                        }
                        reqs[req].node = target;
                        nodes[target].outstanding += 1;
                        nodes[target].queue.push_back(req);
                        try_start_feeder(
                            target, &mut nodes, &mut reqs, arrivals, o, now, &mut heap,
                            &mut seq,
                        );
                    }
                }
            }
            Ev::FeederDone { node, epoch, req } => {
                if nodes[node].epoch != epoch {
                    continue; // stale: the node died and rerouted this work
                }
                nodes[node].free_feeders += 1;
                if let Some(pos) = nodes[node].feeding.iter().position(|&r| r == req) {
                    nodes[node].feeding.swap_remove(pos);
                }
                let cpu_node = matches!(nodes[node].spec.engine, SimEngine::Cpu { .. });
                if cpu_node || reqs[req].misses == 0 {
                    let done = now + o.zmq.reply_us(reqs[req].n);
                    let latency = done - reqs[req].at_us;
                    complete_on(&mut nodes[node], req, &reqs, latency);
                    if latency <= cfg.sla_us {
                        within_sla += 1;
                    }
                    win_lat.record(latency);
                    makespan = makespan.max(done);
                    maybe_retire(&mut nodes[node], now, &mut billable_by_class);
                } else {
                    nodes[node].kernel_queue.push_back(req);
                    try_start_kernel(node, &mut nodes, &reqs, o, now, &mut heap, &mut seq);
                }
                try_start_feeder(
                    node, &mut nodes, &mut reqs, arrivals, o, now, &mut heap, &mut seq,
                );
            }
            Ev::KernelDone { node, epoch, req } => {
                if nodes[node].epoch != epoch {
                    continue;
                }
                nodes[node].in_kernel = None;
                let done = now + o.zmq.reply_us(reqs[req].n);
                let latency = done - reqs[req].at_us;
                complete_on(&mut nodes[node], req, &reqs, latency);
                if latency <= cfg.sla_us {
                    within_sla += 1;
                }
                win_lat.record(latency);
                makespan = makespan.max(done);
                maybe_retire(&mut nodes[node], now, &mut billable_by_class);
                try_start_kernel(node, &mut nodes, &reqs, o, now, &mut heap, &mut seq);
            }
            Ev::FaultDown { fault } => {
                let f = kills[fault];
                if f.node >= nodes.len()
                    || matches!(nodes[f.node].state, NodeState::Down | NodeState::Retired)
                {
                    continue; // nothing (left) to kill
                }
                let node = f.node;
                if nodes[node].billed() {
                    nodes[node].bill_stop(now);
                    billable_by_class[nodes[node].class_idx] -= 1;
                }
                // Gather every admitted request the dead node still holds.
                let mut victims: Vec<usize> = nodes[node].queue.drain(..).collect();
                victims.extend(nodes[node].feeding.drain(..));
                victims.extend(nodes[node].kernel_queue.drain(..));
                victims.extend(nodes[node].in_kernel.take());
                nodes[node].outstanding = 0;
                nodes[node].free_feeders = nodes[node].spec.feeders;
                nodes[node].est_service_us = 0.0;
                nodes[node].cache = cfg.cache_capacity.map(LruCache::new); // cold revive
                nodes[node].epoch += 1;
                nodes[node].state = NodeState::Down;
                push_ev(&mut heap, &mut seq, now + f.down_us, Ev::NodeUp {
                    node,
                    epoch: nodes[node].epoch,
                });
                events.push(ScalingEvent {
                    t_us: now,
                    kind: ScalingEventKind::Fail,
                    class: cfg.classes[nodes[node].class_idx].class.name.into(),
                    node,
                    up_after: up_count!(),
                });
                // Drain/reroute: every victim re-enters the router; only a
                // fully dead fleet loses work.
                for rid in victims {
                    let depths: Vec<usize> =
                        nodes.iter().map(|n| n.outstanding).collect();
                    let up: Vec<bool> =
                        nodes.iter().map(|n| n.state == NodeState::Up).collect();
                    match router.route_up(arrivals[rid].station, &depths, Some(&up)) {
                        None => {
                            lost += 1;
                            lost_q += reqs[rid].n;
                        }
                        Some(target) => {
                            rerouted += 1;
                            reqs[rid].node = target;
                            reqs[rid].misses = reqs[rid].n;
                            nodes[target].outstanding += 1;
                            nodes[target].queue.push_back(rid);
                            try_start_feeder(
                                target, &mut nodes, &mut reqs, arrivals, o, now,
                                &mut heap, &mut seq,
                            );
                        }
                    }
                }
            }
            Ev::NodeUp { node, epoch } => {
                if nodes[node].epoch != epoch {
                    continue;
                }
                match nodes[node].state {
                    NodeState::Down => {
                        nodes[node].state = NodeState::Up;
                        nodes[node].billed_since_us = now;
                        billable_by_class[nodes[node].class_idx] += 1;
                        peak_by_class[nodes[node].class_idx] = peak_by_class
                            [nodes[node].class_idx]
                            .max(billable_by_class[nodes[node].class_idx]);
                        peak_total =
                            peak_total.max(billable_by_class.iter().sum::<usize>());
                        events.push(ScalingEvent {
                            t_us: now,
                            kind: ScalingEventKind::Recover,
                            class: cfg.classes[nodes[node].class_idx].class.name.into(),
                            node,
                            up_after: up_count!(),
                        });
                    }
                    NodeState::Provisioning => {
                        nodes[node].state = NodeState::Up;
                    }
                    _ => {}
                }
            }
            Ev::Tick => {
                let window_s = ((now - last_tick_us) * 1e-6).max(1e-9);
                let capacity_qps: f64 = nodes
                    .iter()
                    .filter(|n| n.state == NodeState::Up)
                    .map(|n| cfg.classes[n.class_idx].class.capacity_qps)
                    .sum();
                let offered_qps = win_queries as f64 / window_s;
                let mut up_by_class = vec![0usize; n_classes];
                for n in &nodes {
                    if n.state == NodeState::Up {
                        up_by_class[n.class_idx] += 1;
                    }
                }
                let obs = FleetObservation {
                    t_us: now,
                    offered_qps,
                    capacity_qps,
                    utilisation: if capacity_qps > 0.0 {
                        offered_qps / capacity_qps
                    } else {
                        f64::INFINITY
                    },
                    outstanding: nodes.iter().map(|n| n.outstanding).sum(),
                    window_p90_us: if win_lat.is_empty() { 0.0 } else { win_lat.p90() },
                    sla_us: cfg.sla_us,
                    nodes_up: up_by_class.iter().sum(),
                    up_by_class,
                };
                match scaler.decide(&obs, &class_list) {
                    ScalingAction::Hold => {}
                    ScalingAction::Add(ci) if ci < n_classes => {
                        let billable_total: usize = billable_by_class.iter().sum();
                        if billable_total < cfg.max_nodes {
                            let idx = nodes.len();
                            nodes.push(DNode::of(ci, cfg, NodeState::Provisioning, now));
                            billable_by_class[ci] += 1;
                            peak_by_class[ci] = peak_by_class[ci].max(billable_by_class[ci]);
                            peak_total =
                                peak_total.max(billable_by_class.iter().sum::<usize>());
                            router.set_weights(router_weights(&nodes, &cfg.classes));
                            push_ev(&mut heap, &mut seq, now + cfg.provision_us, Ev::NodeUp {
                                node: idx,
                                epoch: 0,
                            });
                            events.push(ScalingEvent {
                                t_us: now,
                                kind: ScalingEventKind::Add,
                                class: cfg.classes[ci].class.name.into(),
                                node: idx,
                                up_after: up_count!(),
                            });
                        }
                    }
                    ScalingAction::Remove(ci) if ci < n_classes => {
                        let up_total = up_count!();
                        if up_total > cfg.min_nodes {
                            // The emptiest Up node of the class drains.
                            let pick = nodes
                                .iter()
                                .enumerate()
                                .filter(|(_, n)| {
                                    n.state == NodeState::Up && n.class_idx == ci
                                })
                                .min_by_key(|(i, n)| (n.outstanding, *i))
                                .map(|(i, _)| i);
                            if let Some(i) = pick {
                                nodes[i].state = NodeState::Draining;
                                events.push(ScalingEvent {
                                    t_us: now,
                                    kind: ScalingEventKind::Drain,
                                    class: cfg.classes[ci].class.name.into(),
                                    node: i,
                                    up_after: up_count!(),
                                });
                                maybe_retire(&mut nodes[i], now, &mut billable_by_class);
                            }
                        }
                    }
                    _ => {}
                }
                win_queries = 0;
                win_lat = Percentiles::new();
                last_tick_us = now;
                let next = now + cfg.tick_us;
                if next <= end_us {
                    push_ev(&mut heap, &mut seq, next, Ev::Tick);
                }
            }
        }
    }

    // ---- Final billing and report --------------------------------------
    let run_end_us = makespan.max(end_us);
    for n in nodes.iter_mut() {
        // A fault revive can fire *after* the run window (its NodeUp event
        // still drains from the heap); clamp so such a node bills zero
        // tail time instead of a negative interval.
        if n.billed() && n.billed_since_us < run_end_us {
            n.bill_stop(run_end_us);
        }
    }

    let completed: usize = nodes.iter().map(|n| n.completed).sum();
    let completed_queries: usize = nodes.iter().map(|n| n.completed_q).sum();
    assert_eq!(
        completed + dropped + lost,
        arrivals.len(),
        "managed fleet must conserve requests"
    );

    let lats: Vec<Percentiles> = nodes.iter().map(|n| n.lat.clone()).collect();
    let (p50, p90, p99) = merged_quantiles(&lats);
    let (lookups, hits) =
        nodes.iter().fold((0u64, 0u64), |(l, h), n| (l + n.lookups, h + n.hits));
    let per_node: Vec<NodeReport> = nodes
        .iter_mut()
        .map(|n| NodeReport {
            class: n.spec.class_name.to_string(),
            backend: n.spec.class_name.to_string(),
            completed_requests: n.completed,
            completed_queries: n.completed_q,
            failed_requests: 0,
            req_p90_us: if n.lat.is_empty() { 0.0 } else { n.lat.p90() },
            cache_hit_rate: if n.lookups == 0 { 0.0 } else { n.hits as f64 / n.lookups as f64 },
            mean_aggregation: 1.0,
            health: 1.0,
        })
        .collect();

    let cluster = ClusterReport {
        label: cfg.label(),
        route: cfg.route.label(),
        offered_qps: offered_q as f64 / (end_us.max(1.0) * 1e-6),
        achieved_qps: completed_queries as f64 / (makespan.max(1e-9) * 1e-6),
        requests: arrivals.len(),
        completed,
        dropped,
        lost,
        completed_queries,
        dropped_queries: dropped_q,
        lost_queries: lost_q,
        failed: 0,
        failed_queries: 0,
        req_p50_us: p50,
        req_p90_us: p90,
        req_p99_us: p99,
        cache_hit_rate: if lookups == 0 { 0.0 } else { hits as f64 / lookups as f64 },
        per_node,
    };

    // Per-class usage rollup.
    let mut usage: Vec<ClassUsage> = cfg
        .classes
        .iter()
        .enumerate()
        .map(|(ci, c)| ClassUsage {
            class: c.class.name.into(),
            node_hours: 0.0,
            hourly_usd: c.class.hourly_usd(),
            cost_usd: 0.0,
            peak_nodes: peak_by_class[ci],
        })
        .collect();
    for n in &nodes {
        usage[n.class_idx].node_hours += n.billed_us / 3.6e9;
    }
    for u in usage.iter_mut() {
        u.cost_usd = u.node_hours * u.hourly_usd;
    }
    let node_hours: f64 = usage.iter().map(|u| u.node_hours).sum();
    let cost_usd: f64 = usage.iter().map(|u| u.cost_usd).sum();

    FleetDynamicsReport {
        policy: scaler.name().into(),
        profile: cfg.profile_label.clone(),
        cluster,
        events,
        usage,
        node_hours,
        cost_usd,
        sla_us: cfg.sla_us,
        sla_attainment: within_sla as f64 / arrivals.len() as f64,
        rerouted,
        peak_nodes: peak_total,
        gray_fault_windows: cfg.faults.grays().len(),
    }
}

fn complete_on(node: &mut DNode, rid: usize, reqs: &[DReq], latency: f64) {
    node.lat.record(latency);
    node.outstanding -= 1;
    node.completed += 1;
    node.completed_q += reqs[rid].n;
    node.est_service_us = update_service_estimate(node.est_service_us, latency, node.outstanding);
}

fn maybe_retire(node: &mut DNode, now: f64, billable_by_class: &mut [usize]) {
    if node.state == NodeState::Draining && node.outstanding == 0 {
        node.bill_stop(now);
        node.state = NodeState::Retired;
        billable_by_class[node.class_idx] -= 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::scheduled_sim_arrivals;
    use crate::controlplane::autoscaler::{ReactiveUtilisation, StaticFleet};
    use crate::workload::RateSchedule;

    const BATCH: usize = 2_048;

    fn fpga_class() -> SimClass {
        SimClass::calibrated(
            NodeClass::fpga_f1(0.0),
            SimNodeSpec::v2_cloud(2),
            &Overheads::default(),
            BATCH,
        )
    }

    /// One full diurnal period scaled to the single-node capacity (trough
    /// well under one node, peak well over it), plus a config whose
    /// control tick resolves that period into ~25 windows.
    fn scenario(seed: u64, n: usize, initial: usize) -> (FleetSimConfig, Vec<SimArrival>) {
        let cap_rps = fpga_class().class.capacity_qps / BATCH as f64;
        // Mean of the sinusoid over one period is its base, so n requests
        // at base rate span ≈ one period.
        let period_s = n as f64 / cap_rps;
        let schedule = RateSchedule::diurnal(cap_rps, 0.8 * cap_rps, period_s);
        let arrivals = scheduled_sim_arrivals(seed, &schedule, BATCH, n, 16, 0.9, 0);
        let tick_us = period_s * 1e6 / 25.0;
        let cfg = FleetSimConfig::new(vec![fpga_class()], vec![0; initial])
            .with_control(tick_us, tick_us / 2.0)
            .with_sla(60_000.0)
            .with_bounds(1, 4)
            .with_profile_label(schedule.label());
        (cfg, arrivals)
    }

    #[test]
    fn managed_fleet_is_deterministic_and_conserves() {
        let (cfg, arrivals) = scenario(11, 600, 1);
        let run = || {
            let mut scaler = ReactiveUtilisation::new(0);
            simulate_fleet(&cfg, &mut scaler, &arrivals)
        };
        let a = run();
        let b = run();
        assert!(a.cluster.conserves_requests());
        assert_eq!(a.cluster.completed, b.cluster.completed);
        assert_eq!(a.cost_usd, b.cost_usd);
        assert_eq!(a.events.len(), b.events.len());
        assert_eq!(a.sla_attainment, b.sla_attainment);
    }

    #[test]
    fn reactive_scaler_follows_the_diurnal_wave() {
        let (cfg, arrivals) = scenario(13, 800, 1);
        let mut scaler = ReactiveUtilisation::new(0);
        let r = simulate_fleet(&cfg, &mut scaler, &arrivals);
        assert!(r.cluster.conserves_requests());
        assert!(r.peak_nodes > 1, "the midday peak must force a scale-up");
        assert!(
            r.events.iter().any(|e| e.kind == ScalingEventKind::Add),
            "timeline must record the adds: {}",
            r.timeline()
        );
        assert!(r.node_hours > 0.0);
        assert!(r.cost_usd > 0.0);
        assert!(r.dollars_per_mquery() > 0.0);
    }

    #[test]
    fn static_peak_fleet_costs_more_than_autoscaled() {
        let (auto_cfg, arrivals) = scenario(17, 800, 1);
        // Static: peak-provisioned (3 nodes) for the whole window.
        let (static_cfg, _) = scenario(17, 800, 3);
        let mut stat = StaticFleet;
        let static_run = simulate_fleet(&static_cfg, &mut stat, &arrivals);
        // Autoscaled: start at 1, breathe with the wave.
        let mut scaler = ReactiveUtilisation::new(0);
        let auto_run = simulate_fleet(&auto_cfg, &mut scaler, &arrivals);
        assert!(static_run.cluster.conserves_requests());
        assert!(auto_run.cluster.conserves_requests());
        assert!(
            auto_run.cost_usd < static_run.cost_usd,
            "autoscaling must bill fewer node-hours: {} !< {}",
            auto_run.cost_usd,
            static_run.cost_usd
        );
    }

    #[test]
    fn killing_a_replica_loses_nothing_while_a_peer_lives() {
        // Sustained 1.15× fleet overload on 2 nodes: the backlog grows
        // monotonically, so the killed node certainly holds in-flight
        // work and the reroute path is exercised.
        let (cfg, _) = scenario(19, 500, 2);
        let cap_rps = fpga_class().class.capacity_qps / BATCH as f64;
        let schedule = RateSchedule::constant(2.3 * cap_rps);
        let arrivals = scheduled_sim_arrivals(19, &schedule, BATCH, 500, 16, 0.9, 0);
        let mid = arrivals[arrivals.len() / 2].at_us;
        let span = arrivals.last().unwrap().at_us;
        let cfg = cfg.with_faults(FaultPlan::kill(0, mid, 0.2 * span));
        let mut stat = StaticFleet;
        let r = simulate_fleet(&cfg, &mut stat, &arrivals);
        assert!(r.cluster.conserves_requests());
        assert_eq!(r.cluster.lost, 0, "drain/reroute must preserve admitted work");
        assert!(r.rerouted > 0, "the dead node's in-flight work must move");
        assert!(r.events.iter().any(|e| e.kind == ScalingEventKind::Fail));
        assert!(r.events.iter().any(|e| e.kind == ScalingEventKind::Recover));
        assert_eq!(r.cluster.completed, r.cluster.requests - r.cluster.dropped);
    }

    #[test]
    fn killing_the_only_replica_counts_losses_visibly() {
        let (cfg, arrivals) = scenario(23, 400, 1);
        let mid = arrivals[arrivals.len() / 2].at_us;
        let span = arrivals.last().unwrap().at_us;
        let cfg = cfg.with_faults(FaultPlan::kill(0, mid, 0.3 * span));
        let mut stat = StaticFleet;
        let r = simulate_fleet(&cfg, &mut stat, &arrivals);
        assert!(r.cluster.conserves_requests());
        assert!(r.cluster.lost > 0, "a dead fleet must lose visibly, not silently");
        assert_eq!(r.cluster.lost_queries, r.cluster.lost * BATCH);
    }
}
