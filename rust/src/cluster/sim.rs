//! Deterministic discrete-event simulation of the fleet: N replica nodes,
//! each a *feeder stage* (CPU-side scheduling + encoding, per-node
//! parallel servers) in front of the node's engine — either one
//! accelerator kernel (the [`FpgaModel`] datapath: the §6.1 shape where "a
//! powerful FPGA [starves] behind a weak CPU feeder") or, since the
//! control-plane refactor, a CPU-only match path whose feeders answer in
//! place ([`SimEngine::Cpu`]), so heterogeneous CPU/FPGA fleets simulate
//! behind one router.
//!
//! The feeder:FPGA ratio is the experiment variable: with one feeder the
//! encode rate caps achieved throughput at a small fraction of the kernel
//! ceiling; adding feeders climbs to the kernel (XRT-contended) ceiling —
//! the knee the `fleet_imbalance` bench sweeps, and the measured
//! `node_qps` that [`crate::costmodel::provision_for_throughput`] turns
//! into fleet sizes.
//!
//! Routing/admission mirror the real cluster ([`super::real`]): the same
//! [`Router`] and [`AdmissionPolicy`] code runs inside the event loop
//! (capacity weights included), and per-node LRU caches (same [`LruCache`]
//! as the real [`CachedBackend`](crate::backend::CachedBackend), over the
//! same canonical keys) model the §5.2 hot-connection hit rates — cache
//! hits skip both the encode share and the kernel pass.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

use crate::backend::{query_key, LruCache};
use crate::controlplane::FaultPlan;
use crate::coordinator::{Overheads, Percentiles};
use crate::erbium::FpgaModel;
use crate::nfa::constraint_gen::HardwareConfig;
use crate::prng::Rng;
use crate::resilience::HealthScore;
use crate::workload::{Arrival, ArrivalSource, RateSchedule};

use super::{
    merged_quantiles, update_service_estimate, AdmissionPolicy, ClusterReport, NodeReport,
    RoutePolicy, Router,
};

/// Reference batch size for relative capacity weights (router bias on
/// heterogeneous fleets; only ratios matter).
pub const ROUTER_WEIGHT_BATCH: usize = 1024;

/// Payload-free arrival for the simulator: timings, the routing station,
/// and (when cache behaviour matters) the canonical query keys.
#[derive(Debug, Clone)]
pub struct SimArrival {
    pub at_us: f64,
    pub station: u32,
    pub n_queries: usize,
    /// Canonical query keys; empty ⇒ model every query as a cache miss
    /// (cheap mode for cache-less sweeps).
    pub keys: Vec<u64>,
}

impl SimArrival {
    /// Project a real [`Arrival`] down to its simulator shape.
    pub fn of(a: &Arrival, with_keys: bool) -> SimArrival {
        SimArrival {
            at_us: a.at_us,
            station: a.station(),
            n_queries: a.queries.len(),
            keys: if with_keys { a.queries.iter().map(query_key).collect() } else { Vec::new() },
        }
    }
}

/// Drain an [`ArrivalSource`] into simulator arrivals.
pub fn sim_arrivals(source: &mut dyn ArrivalSource, with_keys: bool) -> Vec<SimArrival> {
    let mut out = Vec::with_capacity(source.total_requests());
    while let Some(a) = source.next_arrival() {
        out.push(SimArrival::of(&a, with_keys));
    }
    out
}

fn synth_arrival(
    rng: &mut Rng,
    clock_us: f64,
    batch_per_request: usize,
    n_stations: usize,
    station_skew: f64,
    keys_per_station: usize,
) -> SimArrival {
    let station = rng.zipf(n_stations, station_skew) as u32;
    let keys = if keys_per_station > 0 {
        (0..batch_per_request)
            .map(|_| ((station as u64) << 32) | rng.zipf(keys_per_station, 1.05) as u64)
            .collect()
    } else {
        Vec::new()
    };
    SimArrival { at_us: clock_us, station, n_queries: batch_per_request, keys }
}

/// Synthetic Poisson arrivals without a `World`: zipf-skewed stations and
/// (optionally) zipf-repeating synthetic keys per station, so cache and
/// routing behaviour can be swept cheaply at any scale.
#[allow(clippy::too_many_arguments)]
pub fn poisson_sim_arrivals(
    seed: u64,
    rate_rps: f64,
    batch_per_request: usize,
    n_requests: usize,
    n_stations: usize,
    station_skew: f64,
    keys_per_station: usize,
) -> Vec<SimArrival> {
    assert!(rate_rps > 0.0 && n_stations > 0);
    let mut rng = Rng::new(seed ^ 0x51A7);
    let mut clock_us = 0.0;
    (0..n_requests)
        .map(|_| {
            clock_us += -(1.0 - rng.f64()).ln() / rate_rps * 1e6;
            synth_arrival(
                &mut rng,
                clock_us,
                batch_per_request,
                n_stations,
                station_skew,
                keys_per_station,
            )
        })
        .collect()
}

/// Like [`poisson_sim_arrivals`], but the request rate follows a
/// [`RateSchedule`] (diurnal sinusoid or piecewise steps): the
/// inter-arrival gap is drawn against the instantaneous rate, so offered
/// load breathes over the run — the input the autoscaling experiments
/// drive their fleets with.
#[allow(clippy::too_many_arguments)]
pub fn scheduled_sim_arrivals(
    seed: u64,
    schedule: &RateSchedule,
    batch_per_request: usize,
    n_requests: usize,
    n_stations: usize,
    station_skew: f64,
    keys_per_station: usize,
) -> Vec<SimArrival> {
    assert!(n_stations > 0);
    let mut rng = Rng::new(seed ^ 0xD1_42A1);
    let mut clock_us = 0.0;
    (0..n_requests)
        .map(|_| {
            clock_us += schedule.poisson_gap_us(clock_us, rng.f64());
            synth_arrival(
                &mut rng,
                clock_us,
                batch_per_request,
                n_stations,
                station_skew,
                keys_per_station,
            )
        })
        .collect()
}

/// What answers the queries on one simulated node.
#[derive(Debug, Clone, Copy)]
pub enum SimEngine {
    /// Feeders encode, one accelerator kernel evaluates the batch.
    Fpga { hw: HardwareConfig, depth: usize },
    /// CPU-only node: each feeder answers its request in place at
    /// `per_query_us` per (uncached) query — no kernel stage, the §5.2
    /// baseline as a fleet citizen.
    Cpu { per_query_us: f64 },
}

/// One simulated replica: its class label, feeder parallelism and engine.
#[derive(Debug, Clone, Copy)]
pub struct SimNodeSpec {
    /// Class label matching the control plane's
    /// [`NodeClass`](super::NodeClass) name.
    pub class_name: &'static str,
    /// Parallel feeder servers (the vCPU-shaped knob: each runs the
    /// per-request scheduling + encoding serially).
    pub feeders: usize,
    pub engine: SimEngine,
}

impl SimNodeSpec {
    /// The paper's cloud FPGA node (MCT v2 on AWS F1, 4 engines, XDMA).
    pub fn v2_cloud(feeders: usize) -> SimNodeSpec {
        assert!(feeders >= 1);
        SimNodeSpec {
            class_name: "fpga-f1",
            feeders,
            engine: SimEngine::Fpga { hw: HardwareConfig::v2_aws(4), depth: 26 },
        }
    }

    /// A CPU-only node with `feeders` cores of the §5.2 baseline.
    pub fn cpu(feeders: usize, per_query_us: f64) -> SimNodeSpec {
        assert!(feeders >= 1 && per_query_us > 0.0);
        SimNodeSpec { class_name: "cpu-c5", feeders, engine: SimEngine::Cpu { per_query_us } }
    }

    pub fn with_class(mut self, name: &'static str) -> SimNodeSpec {
        self.class_name = name;
        self
    }

    /// The datapath model of this node's kernel (FPGA nodes only).
    pub fn kernel_model(&self) -> Option<FpgaModel> {
        match self.engine {
            SimEngine::Fpga { hw, depth } => Some(FpgaModel::new(hw, depth)),
            SimEngine::Cpu { .. } => None,
        }
    }

    /// Nominal sustained capacity at `batch`-sized requests, queries/s:
    /// the min of what the feeders encode and what the engine evaluates.
    /// Feeds router weights and the autoscaler's utilisation estimate.
    pub fn capacity_qps(&self, o: &Overheads, batch: usize) -> f64 {
        let b = batch.max(1) as f64;
        match self.engine {
            SimEngine::Fpga { hw, depth } => {
                let model = FpgaModel::new(hw, depth);
                let feeder_us = o.sched.us(batch) + o.encode.us(batch);
                let feeder_qps = self.feeders as f64 * b / feeder_us.max(1e-9) * 1e6;
                let kernel_us =
                    o.xrt.submission_us(self.feeders) + model.batch_timing(batch).total_us;
                let kernel_qps = b / kernel_us.max(1e-9) * 1e6;
                feeder_qps.min(kernel_qps)
            }
            SimEngine::Cpu { per_query_us } => {
                let svc_us = o.sched.us(batch) + b * per_query_us;
                self.feeders as f64 * b / svc_us.max(1e-9) * 1e6
            }
        }
    }

    /// Closed-form service time of one `n_queries`-sized request on this
    /// node, µs — the single-FIFO server model the front-door DES queues
    /// behind. Derived from [`SimNodeSpec::capacity_qps`] at that batch
    /// size, so sustained throughput under saturation matches the capacity
    /// the router weights and autoscaler already believe in.
    pub fn request_service_us(&self, o: &Overheads, n_queries: usize) -> f64 {
        let b = n_queries.max(1);
        b as f64 / self.capacity_qps(o, b).max(1e-9) * 1e6
    }

    /// Fraction of a request's service time that is the accelerator
    /// kernel itself (as opposed to the CPU feed stage), in [0, 1] — the
    /// telemetry plane's `kernel_us` attribution for simulated exec
    /// spans. Exactly `capacity / kernel-capacity` from the same
    /// decomposition [`SimNodeSpec::capacity_qps`] min's over: 1.0 when
    /// the kernel is the binding stage, small when a weak feeder starves
    /// it (§6.1 — the kernel idles while the node is saturated). CPU
    /// nodes have no kernel stage: 0.
    pub fn kernel_share(&self, o: &Overheads, n_queries: usize) -> f64 {
        let batch = n_queries.max(1);
        let b = batch as f64;
        match self.engine {
            SimEngine::Fpga { hw, depth } => {
                let model = FpgaModel::new(hw, depth);
                let kernel_us =
                    o.xrt.submission_us(self.feeders) + model.batch_timing(batch).total_us;
                let kernel_qps = b / kernel_us.max(1e-9) * 1e6;
                (self.capacity_qps(o, batch) / kernel_qps.max(1e-9)).clamp(0.0, 1.0)
            }
            SimEngine::Cpu { .. } => 0.0,
        }
    }

    fn label(&self) -> String {
        match self.engine {
            SimEngine::Fpga { hw, .. } => {
                format!("{}[{}f 1k {}e]", self.class_name, self.feeders, hw.engines)
            }
            SimEngine::Cpu { .. } => format!("{}[{}f]", self.class_name, self.feeders),
        }
    }
}

/// Fleet-simulation parameters.
#[derive(Debug, Clone)]
pub struct ClusterSimConfig {
    /// Per-replica spec — heterogeneous fleets mix entries.
    pub specs: Vec<SimNodeSpec>,
    pub route: RoutePolicy,
    pub admission: AdmissionPolicy,
    /// Per-node hot-connection LRU capacity (needs keyed arrivals).
    pub cache_capacity: Option<usize>,
    pub overheads: Overheads,
    /// Seed of the router's JSQ(d) sampling stream.
    pub route_seed: u64,
    /// Gray degradation windows (stragglers, error bursts, kernel
    /// stalls) sampled at service start. Kill entries are ignored here —
    /// the plain cluster DES has no up/down machinery; the front door
    /// and control plane execute those.
    pub faults: FaultPlan,
}

impl ClusterSimConfig {
    /// `nodes` identical copies of the paper's cloud node
    /// ([`SimNodeSpec::v2_cloud`]).
    pub fn v2_cloud(nodes: usize, feeders_per_node: usize) -> ClusterSimConfig {
        assert!(nodes >= 1);
        ClusterSimConfig::heterogeneous(vec![SimNodeSpec::v2_cloud(feeders_per_node); nodes])
    }

    /// Mixed fleet from explicit per-node specs.
    pub fn heterogeneous(specs: Vec<SimNodeSpec>) -> ClusterSimConfig {
        assert!(!specs.is_empty());
        ClusterSimConfig {
            specs,
            route: RoutePolicy::RoundRobin,
            admission: AdmissionPolicy::Open,
            cache_capacity: None,
            overheads: Overheads::default(),
            route_seed: 0,
            faults: FaultPlan::none(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.specs.len()
    }

    pub fn with_route(mut self, route: RoutePolicy) -> ClusterSimConfig {
        self.route = route;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> ClusterSimConfig {
        self.admission = admission;
        self
    }

    pub fn with_cache(mut self, capacity: usize) -> ClusterSimConfig {
        self.cache_capacity = Some(capacity);
        self
    }

    pub fn with_route_seed(mut self, seed: u64) -> ClusterSimConfig {
        self.route_seed = seed;
        self
    }

    pub fn with_faults(mut self, faults: FaultPlan) -> ClusterSimConfig {
        self.faults = faults;
        self
    }

    /// The datapath model of the first FPGA node's kernel (the nominal
    /// ceiling the §6.1 sweeps compare against); the v2 cloud default when
    /// the fleet is CPU-only.
    pub fn kernel_model(&self) -> FpgaModel {
        self.specs
            .iter()
            .find_map(SimNodeSpec::kernel_model)
            .unwrap_or_else(|| FpgaModel::new(HardwareConfig::v2_aws(4), 26))
    }

    /// The run's router: policy + capacity weights from the specs.
    pub fn router(&self) -> Router {
        Router::new(self.route).with_seed(self.route_seed).with_weights(
            self.specs
                .iter()
                .map(|s| s.capacity_qps(&self.overheads, ROUTER_WEIGHT_BATCH))
                .collect(),
        )
    }

    pub fn label(&self) -> String {
        let body = super::group_label(
            &self.specs,
            |a, b| a.class_name == b.class_name && a.feeders == b.feeders,
            SimNodeSpec::label,
        );
        format!(
            "sim {} route={} adm={}",
            body,
            self.route.label(),
            self.admission.label()
        )
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    /// Request reaches the router (post transport).
    Arrive { req: usize },
    /// A feeder finished scheduling + encoding the request's misses (CPU
    /// nodes: finished answering them outright).
    FeederDone { req: usize },
    /// The node's kernel finished the request's misses.
    KernelDone { node: usize, req: usize },
}

type EventHeap = BinaryHeap<Reverse<(u64, u64, Event)>>;

fn push_event(heap: &mut EventHeap, seq: &mut u64, t_us: f64, ev: Event) {
    let key = (t_us * 1000.0).round() as u64; // ns resolution
    heap.push(Reverse((key, *seq, ev)));
    *seq += 1;
}

struct ReqSim {
    node: usize,
    at_us: f64,
    n: usize,
    /// Queries that must pass through encode + kernel (set at feed time;
    /// `n` until the cache has spoken).
    misses: usize,
    /// Cleared by a gray error draw at service start: the request still
    /// completes (conservation counts it once) but as a failed call.
    ok: bool,
}

struct NodeSim {
    spec: SimNodeSpec,
    model: Option<FpgaModel>,
    queue: VecDeque<usize>,
    free_feeders: usize,
    kernel_busy: bool,
    kernel_queue: VecDeque<usize>,
    cache: Option<LruCache<()>>,
    outstanding: usize,
    est_service_us: f64,
    completed: usize,
    completed_q: usize,
    failed: usize,
    failed_q: usize,
    health: HealthScore,
    lookups: u64,
    hits: u64,
    lat: Percentiles,
}

impl NodeSim {
    fn of(spec: SimNodeSpec, cache_capacity: Option<usize>) -> NodeSim {
        NodeSim {
            spec,
            model: spec.kernel_model(),
            queue: VecDeque::new(),
            free_feeders: spec.feeders,
            kernel_busy: false,
            kernel_queue: VecDeque::new(),
            cache: cache_capacity.map(LruCache::new),
            outstanding: 0,
            // 0 until the first completion: like the real cluster, the
            // SLA controller never drops blind.
            est_service_us: 0.0,
            completed: 0,
            completed_q: 0,
            failed: 0,
            failed_q: 0,
            health: HealthScore::new(),
            lookups: 0,
            hits: 0,
            lat: Percentiles::new(),
        }
    }
}

/// Run the fleet simulation; deterministic for a given config + arrivals.
pub fn simulate_cluster(cfg: &ClusterSimConfig, arrivals: &[SimArrival]) -> ClusterReport {
    let o = &cfg.overheads;
    let mut router = cfg.router();
    let mut nodes: Vec<NodeSim> =
        cfg.specs.iter().map(|s| NodeSim::of(*s, cfg.cache_capacity)).collect();

    let mut reqs: Vec<ReqSim> = Vec::with_capacity(arrivals.len());
    let mut heap: EventHeap = BinaryHeap::new();
    let mut seq = 0u64;
    let mut offered_q = 0usize;
    let mut window_us = 0.0f64;
    for a in arrivals {
        offered_q += a.n_queries;
        window_us = window_us.max(a.at_us);
        let rid = reqs.len();
        reqs.push(ReqSim {
            node: usize::MAX,
            at_us: a.at_us,
            n: a.n_queries,
            misses: a.n_queries,
            ok: true,
        });
        push_event(
            &mut heap,
            &mut seq,
            a.at_us + o.zmq.request_us(a.n_queries),
            Event::Arrive { req: rid },
        );
    }

    let mut dropped = 0usize;
    let mut dropped_q = 0usize;
    let mut makespan = 0.0f64;
    // Gray-fault sampling stream: effects are drawn at service start, so
    // the draw order is fixed by the (deterministic) event order.
    let mut gray_rng = Rng::new(cfg.route_seed ^ 0x62AF_17);

    // Start the next queued request on a free feeder: the cache speaks at
    // feed time (hits skip encode and the kernel), then the feeder spends
    // the scheduling + service share — encode for FPGA nodes, the whole
    // match for CPU nodes.
    #[allow(clippy::too_many_arguments)]
    fn try_start_feeder(
        node_idx: usize,
        nodes: &mut [NodeSim],
        reqs: &mut [ReqSim],
        arrivals: &[SimArrival],
        o: &Overheads,
        now: f64,
        heap: &mut EventHeap,
        seq: &mut u64,
        faults: &FaultPlan,
        gray_rng: &mut Rng,
    ) {
        while nodes[node_idx].free_feeders > 0 {
            let Some(rid) = nodes[node_idx].queue.pop_front() else { break };
            let node = &mut nodes[node_idx];
            let keys = &arrivals[rid].keys;
            let mut misses = reqs[rid].n;
            if let Some(cache) = node.cache.as_mut() {
                if !keys.is_empty() {
                    node.lookups += keys.len() as u64;
                    let mut hit = 0usize;
                    for &k in keys {
                        if cache.get(k).is_some() {
                            hit += 1;
                        } else {
                            cache.insert(k, ());
                        }
                    }
                    node.hits += hit as u64;
                    misses = reqs[rid].n - hit;
                }
            }
            reqs[rid].misses = misses;
            node.free_feeders -= 1;
            let mut service = match node.spec.engine {
                SimEngine::Fpga { .. } => o.sched.us(reqs[rid].n) + o.encode.us(misses),
                SimEngine::Cpu { per_query_us } => {
                    o.sched.us(reqs[rid].n) + misses as f64 * per_query_us
                }
            };
            // Gray effects, sampled once at feeder-service start: the
            // straggler factor inflates this stage, the error draw marks
            // the whole request failed; stalls hit CPU nodes here (FPGA
            // stalls model kernel hangs and are drawn at kernel start).
            let eff = faults.gray_at(node_idx, now);
            if !eff.is_clean() {
                service *= eff.slow_factor;
                if eff.error_p > 0.0 && gray_rng.chance(eff.error_p) {
                    reqs[rid].ok = false;
                }
                if matches!(node.spec.engine, SimEngine::Cpu { .. })
                    && eff.hang_p > 0.0
                    && gray_rng.chance(eff.hang_p)
                {
                    service += eff.stall_us;
                }
            }
            push_event(heap, seq, now + service, Event::FeederDone { req: rid });
        }
    }

    #[allow(clippy::too_many_arguments)]
    fn try_start_kernel(
        node_idx: usize,
        nodes: &mut [NodeSim],
        reqs: &[ReqSim],
        o: &Overheads,
        now: f64,
        heap: &mut EventHeap,
        seq: &mut u64,
        faults: &FaultPlan,
        gray_rng: &mut Rng,
    ) {
        let node = &mut nodes[node_idx];
        if node.kernel_busy {
            return;
        }
        let Some(rid) = node.kernel_queue.pop_front() else { return };
        let model = node.model.as_ref().expect("kernel queue on a CPU node");
        node.kernel_busy = true;
        let mut service = o.xrt.submission_us(node.spec.feeders)
            + model.batch_timing(reqs[rid].misses).total_us;
        let eff = faults.gray_at(node_idx, now);
        if !eff.is_clean() {
            service *= eff.slow_factor;
            if eff.hang_p > 0.0 && gray_rng.chance(eff.hang_p) {
                service += eff.stall_us;
            }
        }
        push_event(heap, seq, now + service, Event::KernelDone { node: node_idx, req: rid });
    }

    let complete = |node: &mut NodeSim, rid: usize, reqs: &[ReqSim], now: f64| -> f64 {
        let done = now + o.zmq.reply_us(reqs[rid].n);
        let latency = done - reqs[rid].at_us;
        node.lat.record(latency);
        node.outstanding -= 1;
        node.completed += 1;
        node.completed_q += reqs[rid].n;
        if !reqs[rid].ok {
            node.failed += 1;
            node.failed_q += reqs[rid].n;
        }
        node.est_service_us =
            update_service_estimate(node.est_service_us, latency, node.outstanding);
        node.health.observe(reqs[rid].ok, false, latency / (node.outstanding as f64 + 1.0));
        done
    };

    while let Some(Reverse((key, _, ev))) = heap.pop() {
        let now = key as f64 / 1000.0;
        match ev {
            Event::Arrive { req } => {
                let depths: Vec<usize> = nodes.iter().map(|n| n.outstanding).collect();
                let target = router.route(arrivals[req].station, &depths);
                if !cfg.admission.admits(depths[target], nodes[target].est_service_us) {
                    dropped += 1;
                    dropped_q += reqs[req].n;
                    continue;
                }
                reqs[req].node = target;
                nodes[target].outstanding += 1;
                nodes[target].queue.push_back(req);
                try_start_feeder(
                    target, &mut nodes, &mut reqs, arrivals, o, now, &mut heap, &mut seq,
                    &cfg.faults, &mut gray_rng,
                );
            }
            Event::FeederDone { req } => {
                let node_idx = reqs[req].node;
                nodes[node_idx].free_feeders += 1;
                let cpu_node = matches!(nodes[node_idx].spec.engine, SimEngine::Cpu { .. });
                if cpu_node || reqs[req].misses == 0 {
                    // CPU nodes answer in the feeder; pure cache hits need
                    // no kernel pass on any node.
                    let done = complete(&mut nodes[node_idx], req, &reqs, now);
                    makespan = makespan.max(done);
                } else {
                    nodes[node_idx].kernel_queue.push_back(req);
                    try_start_kernel(
                        node_idx, &mut nodes, &reqs, o, now, &mut heap, &mut seq,
                        &cfg.faults, &mut gray_rng,
                    );
                }
                try_start_feeder(
                    node_idx, &mut nodes, &mut reqs, arrivals, o, now, &mut heap, &mut seq,
                    &cfg.faults, &mut gray_rng,
                );
            }
            Event::KernelDone { node, req } => {
                nodes[node].kernel_busy = false;
                let done = complete(&mut nodes[node], req, &reqs, now);
                makespan = makespan.max(done);
                try_start_kernel(
                    node, &mut nodes, &reqs, o, now, &mut heap, &mut seq, &cfg.faults,
                    &mut gray_rng,
                );
            }
        }
    }

    let completed: usize = nodes.iter().map(|n| n.completed).sum();
    let completed_queries: usize = nodes.iter().map(|n| n.completed_q).sum();
    let failed: usize = nodes.iter().map(|n| n.failed).sum();
    let failed_queries: usize = nodes.iter().map(|n| n.failed_q).sum();
    assert_eq!(
        completed + dropped,
        arrivals.len(),
        "cluster sim must conserve requests"
    );

    let lats: Vec<Percentiles> = nodes.iter().map(|n| n.lat.clone()).collect();
    let (p50, p90, p99) = merged_quantiles(&lats);
    let (lookups, hits) =
        nodes.iter().fold((0u64, 0u64), |(l, h), n| (l + n.lookups, h + n.hits));
    let per_node: Vec<NodeReport> = nodes
        .iter_mut()
        .map(|n| NodeReport {
            class: n.spec.class_name.to_string(),
            backend: n.spec.class_name.to_string(),
            completed_requests: n.completed,
            completed_queries: n.completed_q,
            failed_requests: n.failed,
            req_p90_us: if n.lat.is_empty() { 0.0 } else { n.lat.p90() },
            cache_hit_rate: if n.lookups == 0 { 0.0 } else { n.hits as f64 / n.lookups as f64 },
            mean_aggregation: 1.0,
            health: n.health.weight(),
        })
        .collect();

    ClusterReport {
        label: cfg.label(),
        route: cfg.route.label(),
        offered_qps: offered_q as f64 / (window_us.max(1.0) * 1e-6),
        achieved_qps: completed_queries as f64 / (makespan.max(1e-9) * 1e-6),
        requests: arrivals.len(),
        completed,
        dropped,
        lost: 0,
        completed_queries,
        dropped_queries: dropped_q,
        lost_queries: 0,
        failed,
        failed_queries,
        req_p50_us: p50,
        req_p90_us: p90,
        req_p99_us: p99,
        cache_hit_rate: if lookups == 0 { 0.0 } else { hits as f64 / lookups as f64 },
        per_node,
    }
}

/// Measured saturation throughput of one node with `feeders` feeder
/// servers: offer far more load than any configuration can serve and read
/// the achieved rate (the cluster-level analogue of
/// [`FpgaModel::sustained_qps`], now including the CPU feeder path).
pub fn measure_node_saturation_qps(feeders: usize, batch: usize, requests: usize) -> f64 {
    let arrivals = poisson_sim_arrivals(0xFEED, 1e7, batch, requests, 16, 0.8, 0);
    let cfg = ClusterSimConfig::v2_cloud(1, feeders);
    simulate_cluster(&cfg, &arrivals).achieved_qps
}

/// Measured saturation of one node of an arbitrary spec (the heterogeneous
/// analogue of [`measure_node_saturation_qps`], used to calibrate
/// [`NodeClass::capacity_qps`](super::NodeClass) before a control-plane
/// run).
pub fn measure_spec_saturation_qps(spec: SimNodeSpec, batch: usize, requests: usize) -> f64 {
    let arrivals = poisson_sim_arrivals(0xFEED, 1e7, batch, requests, 16, 0.8, 0);
    let cfg = ClusterSimConfig::heterogeneous(vec![spec]);
    simulate_cluster(&cfg, &arrivals).achieved_qps
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sim_is_deterministic_and_conserves() {
        let arrivals = poisson_sim_arrivals(9, 50_000.0, 1024, 400, 16, 1.1, 256);
        for route in [
            RoutePolicy::RoundRobin,
            RoutePolicy::JoinShortestQueue,
            RoutePolicy::JsqD(2),
            RoutePolicy::StationSharded,
        ] {
            let cfg = ClusterSimConfig::v2_cloud(4, 2)
                .with_route(route)
                .with_admission(AdmissionPolicy::QueueCap(32))
                .with_cache(512);
            let a = simulate_cluster(&cfg, &arrivals);
            let b = simulate_cluster(&cfg, &arrivals);
            assert!(a.conserves_requests(), "{route:?}");
            assert_eq!(a.completed, b.completed);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.achieved_qps, b.achieved_qps);
            assert_eq!(a.req_p90_us, b.req_p90_us);
            assert_eq!(a.cache_hit_rate, b.cache_hit_rate);
        }
    }

    #[test]
    fn gray_faults_inflate_latency_and_fail_calls_without_breaking_conservation() {
        let arrivals = poisson_sim_arrivals(11, 40_000.0, 1024, 500, 16, 1.1, 0);
        let span = arrivals.last().map(|a| a.at_us).unwrap_or(0.0) + 1.0;
        let clean_cfg = ClusterSimConfig::v2_cloud(4, 2);
        let clean = simulate_cluster(&clean_cfg, &arrivals);

        // Gray windows open after a clean warm-up so the health floor is
        // learned from fault-free service (the shape of a real brown-out).
        let gray_cfg = ClusterSimConfig::v2_cloud(4, 2).with_faults(
            FaultPlan::none()
                .and_slowdown(0, 0.3 * span, 20.0 * span, 10.0)
                .and_error_rate(1, 0.3 * span, 20.0 * span, 0.5),
        );
        let a = simulate_cluster(&gray_cfg, &arrivals);
        let b = simulate_cluster(&gray_cfg, &arrivals);

        // Gray faults are drawn from the seeded stream: byte-identical reruns.
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.failed, b.failed);
        assert_eq!(a.req_p90_us, b.req_p90_us);

        // A failed call still completes — conservation is untouched.
        assert!(a.conserves_requests());
        assert!(a.failed > 0, "0.5 error rate must fail calls");
        assert_eq!(
            a.completed, clean.completed,
            "gray errors must not change what completes"
        );

        // The ×10 straggler shows up in its own tail and its health score;
        // the clean nodes keep theirs.
        let straggler = &a.per_node[0];
        let clean_node = &clean.per_node[0];
        assert!(
            straggler.req_p90_us > 3.0 * clean_node.req_p90_us,
            "slowdown must inflate the straggler's p90: {} !> 3×{}",
            straggler.req_p90_us,
            clean_node.req_p90_us
        );
        assert!(
            straggler.health < 0.5,
            "straggler health must sink: {}",
            straggler.health
        );
        assert!(
            a.per_node[1].health < 0.9,
            "erroring node health must sink: {}",
            a.per_node[1].health
        );
        assert!(
            a.per_node[2].health > 0.8,
            "clean node health must hold: {}",
            a.per_node[2].health
        );
        assert_eq!(a.per_node[1].failed_requests + a.per_node[0].failed_requests, a.failed);
    }

    #[test]
    fn weak_feeder_starves_the_kernel() {
        // §6.1: one weak feeder in front of an FPGA-class backend leaves
        // the accelerator mostly idle — achieved is a small fraction of
        // the kernel's nominal saturation.
        let sat = ClusterSimConfig::v2_cloud(1, 1).kernel_model().saturation_qps();
        let one = measure_node_saturation_qps(1, 16_384, 300);
        assert!(
            one < 0.35 * sat,
            "1 feeder must starve the kernel: {:.1} M vs {:.1} M q/s",
            one / 1e6,
            sat / 1e6
        );
        // Adding feeders climbs towards the kernel ceiling, then flattens
        // (the knee): the last doubling buys almost nothing.
        let four = measure_node_saturation_qps(4, 16_384, 300);
        let eight = measure_node_saturation_qps(8, 16_384, 300);
        assert!(four > 1.5 * one, "feeders must relieve the bottleneck");
        assert!(eight < 1.3 * four, "kernel ceiling must flatten the curve");
        assert!(eight < sat, "nothing exceeds the nominal kernel rate");
    }

    #[test]
    fn kernel_share_tracks_the_binding_stage() {
        let o = Overheads::default();
        // One weak feeder at a large batch: the feeder is the wall, the
        // kernel mostly idles — share below the telemetry localiser's
        // kernel-idle threshold (0.4), which is what makes the §6.1
        // weak-feeder crossval regime legible. (Past one XDMA chunk the
        // kernel's per-query steady state is ~31 ns vs the feeder's
        // ~145 ns, so the share keeps falling with batch size.)
        let weak = SimNodeSpec::v2_cloud(1);
        let share_weak = weak.kernel_share(&o, 32_768);
        assert!(
            share_weak < 0.4,
            "1 feeder at batch 32k must starve the kernel: share {share_weak:.2}"
        );
        // Plenty of feeders: the kernel becomes the binding stage.
        let strong = SimNodeSpec::v2_cloud(16);
        let share_strong = strong.kernel_share(&o, 32_768);
        assert!(
            share_strong > 0.99,
            "16 feeders must saturate the kernel: share {share_strong:.2}"
        );
        // The share is exactly capacity/kernel-capacity: when the kernel
        // binds, service time × share equals the kernel's closed-form time.
        assert!(share_weak > 0.0 && share_weak <= 1.0);
        // CPU nodes have no kernel stage at all.
        assert_eq!(SimNodeSpec::cpu(4, 2.0).kernel_share(&o, 1_024), 0.0);
    }

    #[test]
    fn sla_admission_protects_latency_at_the_cost_of_drops() {
        // Sustained ~2× overload (not an instantaneous burst): the SLA
        // controller never drops blind, so completions must interleave
        // with arrivals for its service estimate to engage. Fleet
        // capacity here is kernel-bound at ≈5.5 k req/s; offer 12 k.
        let arrivals = poisson_sim_arrivals(3, 12_000.0, 4_096, 600, 16, 0.8, 0);
        let open = simulate_cluster(&ClusterSimConfig::v2_cloud(2, 2), &arrivals);
        let sla_us = 20_000.0;
        let shed = simulate_cluster(
            &ClusterSimConfig::v2_cloud(2, 2)
                .with_admission(AdmissionPolicy::SlaP90 { sla_us }),
            &arrivals,
        );
        assert!(open.conserves_requests() && shed.conserves_requests());
        assert_eq!(open.dropped, 0);
        assert!(shed.dropped > 0, "overload must shed under an SLA");
        assert!(
            shed.req_p90_us < open.req_p90_us,
            "shedding must protect p90: {} !< {}",
            shed.req_p90_us,
            open.req_p90_us
        );
    }

    #[test]
    fn sharded_routing_wins_cache_hits_loses_balance() {
        let arrivals = poisson_sim_arrivals(21, 100_000.0, 512, 800, 32, 1.2, 128);
        let run = |route| {
            simulate_cluster(
                &ClusterSimConfig::v2_cloud(4, 2).with_route(route).with_cache(1024),
                &arrivals,
            )
        };
        let rr = run(RoutePolicy::RoundRobin);
        let sh = run(RoutePolicy::StationSharded);
        assert!(
            sh.cache_hit_rate > rr.cache_hit_rate,
            "sharded affinity must raise hit rate: {} !> {}",
            sh.cache_hit_rate,
            rr.cache_hit_rate
        );
        assert!(sh.max_node_share() > rr.max_node_share(), "affinity skews load");
    }

    #[test]
    fn cpu_nodes_serve_without_a_kernel_stage() {
        // A CPU-only fleet completes everything (no kernel events at all)
        // and a same-size FPGA fleet with generous feeders beats it on
        // achieved throughput at a large batch — the §5 comparison as a
        // fleet property.
        let arrivals = poisson_sim_arrivals(5, 2_000.0, 4_096, 200, 16, 0.8, 0);
        let cpu = simulate_cluster(
            &ClusterSimConfig::heterogeneous(vec![SimNodeSpec::cpu(2, 2.0); 2]),
            &arrivals,
        );
        assert!(cpu.conserves_requests());
        assert_eq!(cpu.completed, 200);
        assert_eq!(cpu.per_node[0].class, "cpu-c5");
        let fpga = simulate_cluster(&ClusterSimConfig::v2_cloud(2, 8), &arrivals);
        assert!(
            fpga.achieved_qps > cpu.achieved_qps,
            "accelerated nodes must outserve the CPU baseline: {} !> {}",
            fpga.achieved_qps,
            cpu.achieved_qps
        );
    }

    #[test]
    fn heterogeneous_fleet_mixes_classes_in_one_report() {
        let arrivals = poisson_sim_arrivals(13, 30_000.0, 1_024, 400, 16, 0.9, 0);
        let cfg = ClusterSimConfig::heterogeneous(vec![
            SimNodeSpec::v2_cloud(4),
            SimNodeSpec::v2_cloud(4),
            SimNodeSpec::cpu(2, 2.0),
        ])
        .with_route(RoutePolicy::JoinShortestQueue);
        let r = simulate_cluster(&cfg, &arrivals);
        assert!(r.conserves_requests());
        let classes = r.per_class();
        assert_eq!(classes.len(), 2, "{:?}", classes);
        assert_eq!(classes[0].nodes + classes[1].nodes, 3);
        // Capacity-weighted JSQ keeps the weak CPU node from hoarding: the
        // two FPGA nodes absorb the clear majority of the load.
        let fpga_req =
            classes.iter().find(|c| c.class == "fpga-f1").unwrap().completed_requests;
        assert!(
            fpga_req * 2 > r.completed,
            "FPGA class must carry most of the load: {fpga_req}/{}",
            r.completed
        );
        assert!(r.summary().contains("by class"));
    }

    #[test]
    fn capacity_estimate_tracks_measured_saturation() {
        // The closed-form capacity estimate used for router weights must
        // agree with the measured DES saturation within a factor of two —
        // it is a weight, not a promise.
        let o = Overheads::default();
        for spec in [SimNodeSpec::v2_cloud(2), SimNodeSpec::cpu(4, 0.5)] {
            let est = spec.capacity_qps(&o, 16_384);
            let measured = measure_spec_saturation_qps(spec, 16_384, 200);
            let ratio = est / measured;
            assert!(
                (0.5..2.0).contains(&ratio),
                "{}: estimate {est:.0} vs measured {measured:.0} ({ratio:.2})",
                spec.label()
            );
        }
    }
}
