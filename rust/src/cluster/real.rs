//! The real sharded cluster: N threaded serving replicas
//! ([`NodeCore`]) behind an admission-controlled router, driven open-loop
//! from an [`ArrivalSource`].
//!
//! The injector paces arrivals on the wall clock (best effort — once the
//! fleet lags the schedule, the backlog itself is the measurement), routes
//! per [`RoutePolicy`](super::RoutePolicy) using live per-replica
//! outstanding counts (capacity-weighted on heterogeneous fleets), and
//! applies [`AdmissionPolicy`](super::AdmissionPolicy) with a running
//! per-replica mean-service estimate fed back from completions. A
//! collector thread folds tagged completions into per-node latency
//! collectors, merged into fleet quantiles at the end
//! ([`Percentiles::merge`]).
//!
//! Heterogeneity: each replica is built from its own
//! [`NodeSpec`](super::NodeSpec)'s factory ([`Cluster::heterogeneous`]),
//! so CPU-baseline and FPGA-engine replicas serve side by side and the
//! report's per-class aggregates show who carried what.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Mutex};
use std::time::Instant;

use anyhow::Result;

use crate::backend::{gray_fault_factory, BackendFactory};
use crate::coordinator::pipeline::{Completion, NodeCore, NodeStats};
use crate::coordinator::Percentiles;
use crate::resilience::{HealthScore, BROWNOUT_DEGRADE_THRESHOLD};
use crate::workload::ArrivalSource;

use super::{
    merged_quantiles, update_service_estimate, AdmissionPolicy, ClusterConfig, ClusterReport,
    NodeReport, Router,
};

/// Outcome of a non-blocking submission through [`ClusterHandle`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub(crate) enum Submit {
    /// Accepted and in flight on `node`; exactly one tagged [`Completion`]
    /// will arrive for it. `degraded` marks the brown-out ladder failing
    /// an FPGA replica's traffic over to a CPU replica.
    Submitted { node: usize, degraded: bool },
    /// Refused — admission control said no, or no live node could take it.
    Shed,
}

/// Optional routing extras for [`ClusterHandle::try_submit_ext`] — the
/// resilience layer's knobs, all off in [`Default`] (which makes
/// `try_submit_ext` behave exactly like [`ClusterHandle::try_submit`]).
#[derive(Debug, Default, Clone, Copy)]
pub(crate) struct SubmitOpts<'a> {
    /// Replica that must not take this copy (a hedge goes to a *different*
    /// node). Ignored when it is the only live choice.
    pub exclude: Option<usize>,
    /// Per-replica deny mask (open circuit breakers).
    pub deny: Option<&'a [bool]>,
    /// Compose the per-replica brown-out weights into the router's
    /// capacity scaling.
    pub brownout: bool,
    /// Graceful-degradation ladder: fail a browning FPGA replica's
    /// traffic over to the least-loaded live CPU replica before shedding.
    pub degrade: bool,
}

/// The cluster's **tagged-completion surface**: live replicas behind the
/// shared router/admission policies, submissions returning immediately
/// and completions flowing back over whatever channel the caller tags
/// them with. [`Cluster::run`] drives it with one blocking injector; the
/// front door drives it from event threads multiplexing thousands of
/// sessions — same routing, same admission, same service-estimate
/// feedback, so the two entry points can never disagree about policy.
pub(crate) struct ClusterHandle {
    nodes: Vec<NodeCore>,
    router: Mutex<Router>,
    admission: AdmissionPolicy,
    /// Per-replica mean-service estimate, f64 bits in atomics so
    /// submitters read what completion observers write.
    est_service: Vec<AtomicU64>,
    /// Liveness mask for fault drills: a downed node stops receiving but
    /// drains what it holds (the real realisation's drain semantics).
    up: Vec<AtomicBool>,
    /// Per-replica brown-out health, fed by every observed completion.
    health: Vec<Mutex<HealthScore>>,
    /// CPU-class replicas (by class name) — the degradation ladder's
    /// fail-over targets.
    is_cpu: Vec<bool>,
}

impl ClusterHandle {
    /// Spawn every replica from its spec + factory.
    pub(crate) fn spawn(config: &ClusterConfig, factories: &[BackendFactory]) -> ClusterHandle {
        assert_eq!(factories.len(), config.nodes(), "one backend factory per node spec");
        let nodes: Vec<NodeCore> = config
            .specs
            .iter()
            .zip(factories)
            .map(|(spec, factory)| NodeCore::spawn(&spec.node, factory))
            .collect();
        let n = nodes.len();
        ClusterHandle {
            nodes,
            router: Mutex::new(config.router()),
            admission: config.admission,
            est_service: (0..n).map(|_| AtomicU64::new(0)).collect(),
            up: (0..n).map(|_| AtomicBool::new(true)).collect(),
            health: (0..n).map(|_| Mutex::new(HealthScore::new())).collect(),
            is_cpu: config.specs.iter().map(|s| s.class.name.starts_with("cpu")).collect(),
        }
    }

    pub(crate) fn n_nodes(&self) -> usize {
        self.nodes.len()
    }

    pub(crate) fn outstanding(&self, node: usize) -> usize {
        self.nodes[node].outstanding()
    }

    pub(crate) fn depths(&self) -> Vec<usize> {
        self.nodes.iter().map(|nd| nd.outstanding()).collect()
    }

    pub(crate) fn est_service_us(&self, node: usize) -> f64 {
        f64::from_bits(self.est_service[node].load(Ordering::Relaxed))
    }

    /// Kill/revive a replica for fault drills. Downed replicas stop
    /// receiving new work but finish what they hold.
    pub(crate) fn set_up(&self, node: usize, up: bool) {
        self.up[node].store(up, Ordering::Relaxed);
    }

    /// Route + admission-check + submit, without blocking. `Shed` means
    /// the cluster refused the request *now* — the caller owns the
    /// backpressure decision (drop it, park it, or push back on the
    /// client).
    pub(crate) fn try_submit(
        &self,
        station: u32,
        queries: Vec<crate::rules::types::MctQuery>,
        id: u64,
        tx: &mpsc::Sender<Completion>,
    ) -> Submit {
        self.try_submit_ext(station, queries, id, tx, SubmitOpts::default())
    }

    /// [`Self::try_submit`] with the resilience layer's routing extras:
    /// breaker deny masks, hedge exclusion, brown-out weights and the
    /// FPGA→CPU degradation ladder.
    pub(crate) fn try_submit_ext(
        &self,
        station: u32,
        queries: Vec<crate::rules::types::MctQuery>,
        id: u64,
        tx: &mpsc::Sender<Completion>,
        opts: SubmitOpts<'_>,
    ) -> Submit {
        let depths = self.depths();
        let mut live: Vec<bool> = self.up.iter().map(|u| u.load(Ordering::Relaxed)).collect();
        if let Some(deny) = opts.deny {
            for (l, d) in live.iter_mut().zip(deny) {
                *l = *l && !*d;
            }
        }
        if let Some(x) = opts.exclude {
            // Hedge to a different replica — unless it is the only one left.
            if x < live.len() && live.iter().enumerate().any(|(i, l)| *l && i != x) {
                live[x] = false;
            }
        }
        let health = (opts.brownout || opts.degrade).then(|| self.health_weights());
        let target = {
            let mut router = self.router.lock().unwrap();
            router.set_health(if opts.brownout {
                health.clone().unwrap_or_default()
            } else {
                Vec::new()
            });
            router.route_up(station, &depths, Some(&live))
        };
        let Some(mut target) = target else {
            return Submit::Shed;
        };
        let mut degraded = false;
        if opts.degrade && !self.is_cpu[target] {
            let browning = health
                .as_ref()
                .and_then(|h| h.get(target))
                .is_some_and(|h| *h < BROWNOUT_DEGRADE_THRESHOLD);
            if browning {
                let cpu = (0..live.len())
                    .filter(|&i| live[i] && self.is_cpu[i])
                    .min_by_key(|&i| depths[i]);
                if let Some(cpu) = cpu {
                    target = cpu;
                    degraded = true;
                }
            }
        }
        if !self.admission.admits(depths[target], self.est_service_us(target)) {
            return Submit::Shed;
        }
        self.nodes[target].submit_tagged(queries, id, target, tx);
        Submit::Submitted { node: target, degraded }
    }

    /// Submit directly to `node`, bypassing the router and admission —
    /// for callers that own both decisions themselves, like the pool
    /// dispatcher's lease scheduler picking the least-loaded leased
    /// kernel. The node must be live and the caller must collect exactly
    /// one tagged [`Completion`] for it.
    pub(crate) fn try_submit_to(
        &self,
        node: usize,
        queries: Vec<crate::rules::types::MctQuery>,
        id: u64,
        tx: &mpsc::Sender<Completion>,
    ) {
        self.nodes[node].submit_tagged(queries, id, node, tx);
    }

    /// Feed a completion back into the per-replica service estimate (the
    /// signal [`AdmissionPolicy::SlaP90`] sheds on).
    pub(crate) fn note_completion(&self, c: &Completion) {
        self.note_outcome(c, false);
    }

    /// [`Self::note_completion`] plus the brown-out health observation —
    /// callers that track deadlines report misses here.
    pub(crate) fn note_outcome(&self, c: &Completion, deadline_miss: bool) {
        self.note_outcome_at(c, deadline_miss, f64::NAN);
    }

    /// [`Self::note_outcome`] with a caller clock: returns the brown-out
    /// threshold crossing this outcome caused, if any, so the reactor —
    /// which owns the flight recorder — can log the health transition.
    /// (NaN clock: observe without transition reporting.)
    pub(crate) fn note_outcome_at(
        &self,
        c: &Completion,
        deadline_miss: bool,
        t_us: f64,
    ) -> Option<crate::resilience::HealthTransition> {
        let outstanding = self.nodes[c.node].outstanding();
        let prev = f64::from_bits(self.est_service[c.node].load(Ordering::Relaxed));
        let next = update_service_estimate(prev, c.latency_us, outstanding);
        self.est_service[c.node].store(next.to_bits(), Ordering::Relaxed);
        let norm = c.latency_us / (outstanding as f64 + 1.0);
        self.health[c.node].lock().unwrap().observe_at(t_us, c.ok, deadline_miss, norm)
    }

    /// Per-replica brown-out routing weights, `(0, 1]`.
    pub(crate) fn health_weights(&self) -> Vec<f64> {
        self.health.iter().map(|h| h.lock().unwrap().weight()).collect()
    }

    /// Is this replica a CPU-class fail-over target?
    pub(crate) fn is_cpu(&self, node: usize) -> bool {
        self.is_cpu[node]
    }

    /// Join every replica and collect its stats. All submitted work must
    /// have completed (drain before calling).
    pub(crate) fn shutdown(self) -> Vec<NodeStats> {
        self.nodes.into_iter().map(NodeCore::shutdown).collect()
    }
}

/// A runnable cluster: every replica is built from its spec's factory (the
/// backends themselves are constructed inside each replica's engine
/// threads).
pub struct Cluster {
    pub config: ClusterConfig,
    factories: Vec<BackendFactory>,
}

impl Cluster {
    /// Homogeneous cluster: every replica built from the same factory.
    pub fn new(config: ClusterConfig, factory: BackendFactory) -> Cluster {
        let factories = vec![factory; config.nodes()];
        Cluster { config, factories }
    }

    /// Heterogeneous cluster: one factory per [`NodeSpec`](super::NodeSpec)
    /// in `config.specs`, in order.
    pub fn heterogeneous(config: ClusterConfig, factories: Vec<BackendFactory>) -> Cluster {
        assert_eq!(
            factories.len(),
            config.nodes(),
            "one backend factory per node spec"
        );
        Cluster { config, factories }
    }

    /// Serve the arrival stream and report. Conservation is structural:
    /// every arrival is either dropped at admission or submitted, and
    /// every submission produces exactly one completion.
    pub fn run(&self, source: &mut dyn ArrivalSource) -> Result<ClusterReport> {
        let n = self.config.nodes();
        // t0 before spawn: the gray-fault decorators and the pacing loop
        // must share one clock origin, so a scripted brown-out window sits
        // on the same stretch of arrivals in both realisations.
        let t0 = Instant::now();
        let factories: Vec<BackendFactory> = self
            .factories
            .iter()
            .enumerate()
            .map(|(i, f)| {
                gray_fault_factory(
                    f.clone(),
                    self.config.faults.clone(),
                    i,
                    t0,
                    self.config.route_seed,
                )
            })
            .collect();
        let handle = ClusterHandle::spawn(&self.config, &factories);
        let (ctx, crx) = mpsc::channel::<Completion>();
        let mut requests = 0usize;
        let mut dropped = 0usize;
        let mut dropped_queries = 0usize;
        let mut submitted = 0u64;

        let collected = std::thread::scope(|scope| {
            let h = &handle;
            let collector = scope.spawn(move || {
                let mut lat: Vec<Percentiles> = (0..n).map(|_| Percentiles::new()).collect();
                let mut completed = vec![0usize; n];
                let mut completed_q = vec![0usize; n];
                let mut failed = vec![0usize; n];
                let mut failed_q = vec![0usize; n];
                while let Ok(c) = crx.recv() {
                    lat[c.node].record(c.latency_us);
                    completed[c.node] += 1;
                    completed_q[c.node] += c.n_queries;
                    if !c.ok {
                        failed[c.node] += 1;
                        failed_q[c.node] += c.n_queries;
                    }
                    h.note_completion(&c);
                }
                (lat, completed, completed_q, failed, failed_q)
            });

            // ---- Injector (this thread) --------------------------------
            while let Some(a) = source.next_arrival() {
                requests += 1;
                crate::coordinator::pipeline::pace_until(t0, a.at_us);
                let n_queries = a.queries.len();
                match handle.try_submit(a.station(), a.queries, submitted, &ctx) {
                    Submit::Submitted { .. } => submitted += 1,
                    Submit::Shed => {
                        dropped += 1;
                        dropped_queries += n_queries;
                    }
                }
            }
            drop(ctx);
            collector.join().expect("collector panicked")
        });
        let (lat, completed, completed_q, failed, failed_q) = collected;
        let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
        let health = handle.health_weights();
        let stats: Vec<_> = handle.shutdown();

        let completed_total: usize = completed.iter().sum();
        let completed_queries: usize = completed_q.iter().sum();
        anyhow::ensure!(
            completed_total == submitted as usize,
            "cluster lost requests: {submitted} submitted, {completed_total} completed"
        );

        let (p50, p90, p99) = merged_quantiles(&lat);
        let mut lat = lat;
        let per_node: Vec<NodeReport> = (0..n)
            .map(|i| NodeReport {
                class: self.config.specs[i].class.name.to_string(),
                backend: stats[i].backend.clone(),
                completed_requests: completed[i],
                completed_queries: completed_q[i],
                failed_requests: failed[i],
                req_p90_us: if lat[i].is_empty() { 0.0 } else { lat[i].p90() },
                cache_hit_rate: stats[i].cache_hit_rate(),
                mean_aggregation: stats[i].mean_aggregation(),
                health: health[i],
            })
            .collect();
        let (lookups, hits) = stats
            .iter()
            .fold((0u64, 0u64), |(l, h), s| (l + s.cache_lookups, h + s.cache_hits));

        Ok(ClusterReport {
            label: self.config.label(),
            route: self.config.route.label(),
            offered_qps: source.offered_qps(),
            achieved_qps: completed_queries as f64 / wall_s,
            requests,
            completed: completed_total,
            dropped,
            // The real cluster's failure story is drain-based (see
            // `controlplane::real`): a submitted request always completes,
            // so nothing is ever lost here.
            lost: 0,
            completed_queries,
            dropped_queries,
            lost_queries: 0,
            failed: failed.iter().sum(),
            failed_queries: failed_q.iter().sum(),
            req_p50_us: p50,
            req_p90_us: p90,
            req_p99_us: p99,
            cache_hit_rate: if lookups == 0 { 0.0 } else { hits as f64 / lookups as f64 },
            per_node,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{AdmissionPolicy, NodeClass, NodeSpec, RoutePolicy};
    use crate::coordinator::{AggregationPolicy, PipelineConfig, Topology};
    use crate::nfa::constraint_gen::HardwareConfig;
    use crate::rules::standard::StandardVersion;
    use crate::testing::fixture::compile_fixture;
    use crate::workload::PoissonSource;

    fn fixture() -> (BackendFactory, crate::rules::types::World) {
        let f = compile_fixture(909, 300, StandardVersion::V2, HardwareConfig::v2_aws(4));
        (f.native_factory(), f.world)
    }

    fn node_cfg() -> PipelineConfig {
        PipelineConfig::new(Topology::new(2, 1, 1, 4))
            .with_aggregation(AggregationPolicy::DrainQueue)
    }

    #[test]
    fn cluster_serves_everything_when_open() {
        let (factory, world) = fixture();
        let cfg = ClusterConfig::new(3, node_cfg()).with_route(RoutePolicy::RoundRobin);
        let mut src = PoissonSource::new(&world, 4, 1e6, 16, 150);
        let r = Cluster::new(cfg, factory).run(&mut src).unwrap();
        assert!(r.conserves_requests());
        assert_eq!(r.requests, 150);
        assert_eq!(r.completed, 150);
        assert_eq!(r.dropped, 0);
        assert_eq!(r.lost, 0);
        assert_eq!(r.completed_queries, 150 * 16);
        assert_eq!(r.failed, 0);
        assert!(r.req_p90_us >= r.req_p50_us);
        assert!(r.achieved_qps > 0.0);
        // Round-robin spreads a burst evenly.
        assert!(r.max_node_share() < 0.5, "share {}", r.max_node_share());
    }

    #[test]
    fn jsq_conserves_and_balances() {
        let (factory, world) = fixture();
        let cfg = ClusterConfig::new(3, node_cfg()).with_route(RoutePolicy::JoinShortestQueue);
        let mut src = PoissonSource::new(&world, 8, 1e6, 16, 120);
        let r = Cluster::new(cfg, factory).run(&mut src).unwrap();
        assert!(r.conserves_requests());
        assert_eq!(r.dropped, 0);
        assert_eq!(r.completed, 120);
    }

    #[test]
    fn queue_cap_drops_are_accounted_not_lost() {
        let (factory, world) = fixture();
        // A burst (effectively simultaneous arrivals) against a tiny queue
        // cap must shed load — and account for every shed request.
        let cfg = ClusterConfig::new(2, node_cfg())
            .with_route(RoutePolicy::RoundRobin)
            .with_admission(AdmissionPolicy::QueueCap(8));
        let mut src = PoissonSource::new(&world, 12, 1e8, 16, 400);
        let r = Cluster::new(cfg, factory).run(&mut src).unwrap();
        assert!(
            r.conserves_requests(),
            "in {} = done {} + drop {}",
            r.requests,
            r.completed,
            r.dropped
        );
        assert!(r.dropped > 0, "burst over cap 8 must drop");
        assert!(r.saturated());
        assert_eq!(r.completed_queries + r.dropped_queries, 400 * 16);
    }

    #[test]
    fn station_sharding_raises_cache_hit_rate_over_round_robin() {
        // §5.2 cache affinity: pinning stations to replicas keeps each
        // station's hot connections in one LRU. Same seed ⇒ identical
        // arrival stream, so the comparison is deterministic.
        let (factory, world) = fixture();
        let node = node_cfg().with_cache(512);
        let run = |route| {
            let cfg = ClusterConfig::new(4, node).with_route(route);
            // A thin schedule (6 mean legs/station) makes hot connections
            // recur densely, so the cache has something to win.
            let mut src = PoissonSource::new(&world, 77, 1e6, 32, 300)
                .with_airport_skew(1.2)
                .with_mean_legs(6);
            Cluster::new(cfg, factory.clone()).run(&mut src).unwrap()
        };
        let rr = run(RoutePolicy::RoundRobin);
        let sh = run(RoutePolicy::StationSharded);
        assert!(rr.conserves_requests() && sh.conserves_requests());
        assert!(sh.cache_hit_rate > 0.0);
        assert!(
            sh.cache_hit_rate > rr.cache_hit_rate,
            "sharded affinity must beat round-robin: {} !> {}",
            sh.cache_hit_rate,
            rr.cache_hit_rate
        );
        // The price of affinity: zipf skew concentrates load.
        assert!(sh.max_node_share() > rr.max_node_share());
    }

    #[test]
    fn heterogeneous_cluster_serves_with_mixed_backends() {
        // A real mixed fleet: two native-FPGA replicas plus one CPU-baseline
        // replica behind one weighted-JSQ router. Everything completes, and
        // the per-class rollup shows both classes serving.
        let f = compile_fixture(911, 250, StandardVersion::V2, HardwareConfig::v2_aws(4));
        let fpga_spec = NodeSpec { class: NodeClass::fpga_f1(20e6), node: node_cfg() };
        let cpu_spec = NodeSpec { class: NodeClass::cpu_c5(2e6), node: node_cfg() };
        let cfg = ClusterConfig::heterogeneous(vec![
            fpga_spec.clone(),
            fpga_spec,
            cpu_spec,
        ])
        .with_route(RoutePolicy::JoinShortestQueue);
        let factories = vec![f.native_factory(), f.native_factory(), f.cpu_factory()];
        let mut src = PoissonSource::new(&f.world, 5, 1e6, 16, 180);
        let r = Cluster::heterogeneous(cfg, factories).run(&mut src).unwrap();
        assert!(r.conserves_requests());
        assert_eq!(r.completed, 180);
        assert_eq!(r.failed, 0);
        let classes = r.per_class();
        assert_eq!(classes.len(), 2, "{classes:?}");
        // The CPU replica's report row is labelled with its real backend.
        let cpu_row = r.per_node.iter().find(|n| n.class == "cpu-c5").unwrap();
        assert_eq!(cpu_row.backend, "cpu");
        assert!(r.summary().contains("by class"), "{}", r.summary());
    }

    #[test]
    fn gray_error_rate_fails_calls_but_conserves() {
        use crate::controlplane::FaultPlan;
        let (factory, world) = fixture();
        // Every call on node 0 fails for the whole run: its requests still
        // complete (as failed), conservation holds, and its health sinks
        // while the clean node's holds.
        let cfg = ClusterConfig::new(2, node_cfg())
            .with_route(RoutePolicy::RoundRobin)
            .with_faults(FaultPlan::none().and_error_rate(0, 0.0, 1e12, 1.0));
        let mut src = PoissonSource::new(&world, 21, 1e6, 16, 120);
        let r = Cluster::new(cfg, factory).run(&mut src).unwrap();
        assert!(r.conserves_requests());
        assert_eq!(r.completed, 120);
        assert!(r.failed >= 50, "RR sends ~half the calls into the fault: {}", r.failed);
        assert_eq!(r.failed_queries, r.failed * 16);
        assert_eq!(r.per_node[0].failed_requests, r.failed);
        assert_eq!(r.per_node[1].failed_requests, 0);
        assert!(
            r.per_node[0].health < 0.2,
            "all-errors node must brown out: {}",
            r.per_node[0].health
        );
        assert!(
            r.per_node[1].health > 0.5,
            "clean node health must hold: {}",
            r.per_node[1].health
        );
    }

    #[test]
    fn jsq2_conserves_on_the_real_cluster() {
        let (factory, world) = fixture();
        let cfg = ClusterConfig::new(3, node_cfg())
            .with_route(RoutePolicy::JsqD(2))
            .with_route_seed(99);
        let mut src = PoissonSource::new(&world, 17, 1e6, 16, 120);
        let r = Cluster::new(cfg, factory).run(&mut src).unwrap();
        assert!(r.conserves_requests());
        assert_eq!(r.completed, 120);
        assert_eq!(r.route, "jsq2");
    }
}
