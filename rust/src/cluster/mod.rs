//! The fleet layer: a sharded cluster of feeder-node replicas behind a
//! router — the deployment the paper's §6 costs out, built over the
//! single-node machinery of [`crate::coordinator`].
//!
//! One *node* is a full Fig-5 serving replica (router queue → MCT-Wrapper
//! workers → engine servers → [`crate::backend::MatchBackend`], optional
//! hot-connection LRU). The cluster front-end takes an open-loop
//! [`ArrivalSource`](crate::workload::ArrivalSource), applies
//! [`AdmissionPolicy`] (drop rather than bust the p90 SLA — §3.3 "the 90th
//! percentile … matches the SLA of the search engine"), and routes every
//! admitted request to a replica per [`RoutePolicy`].
//!
//! Two realisations, cross-validated like the single-node pair:
//!
//! * [`real::Cluster`] — N threaded [`NodeCore`](crate::coordinator)
//!   replicas serving queries for real, wall-clock;
//! * [`sim::simulate_cluster`] — a deterministic discrete-event model of
//!   the same fleet (feeder service + kernel datapath + per-node LRU),
//!   which is what the `fleet_imbalance` bench sweeps to reproduce the
//!   §6.1 "FPGA starves behind a weak feeder" knee.
//!
//! Reports carry **offered vs achieved** load, SLA drops, per-node and
//! fleet-merged latency quantiles ([`Percentiles::merge`]) and cache hit
//! rates — the measured inputs that
//! [`crate::costmodel::provision_for_throughput`] turns into fleet plans.

pub mod real;
pub mod sim;

pub use real::Cluster;
pub use sim::{poisson_sim_arrivals, simulate_cluster, ClusterSimConfig, SimArrival};

use crate::coordinator::{Percentiles, PipelineConfig};

/// How the front-end router picks a replica for an admitted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas regardless of state (the ZeroMQ dealer
    /// default).
    RoundRobin,
    /// Send to the replica with the fewest outstanding requests.
    JoinShortestQueue,
    /// Pin each connection station to one replica (`station mod n`), so a
    /// station's hot connections stay in that replica's LRU — cache
    /// affinity at the price of zipf-skewed load.
    StationSharded,
}

impl RoutePolicy {
    pub fn label(&self) -> &'static str {
        match self {
            RoutePolicy::RoundRobin => "rr",
            RoutePolicy::JoinShortestQueue => "jsq",
            RoutePolicy::StationSharded => "shard",
        }
    }

    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "jsq" => Some(RoutePolicy::JoinShortestQueue),
            "shard" | "station" => Some(RoutePolicy::StationSharded),
            _ => None,
        }
    }
}

/// Stateful router: one instance per cluster run.
#[derive(Debug, Clone)]
pub struct Router {
    pub policy: RoutePolicy,
    rr_next: usize,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { policy, rr_next: 0 }
    }

    /// Pick the target replica for a request at `station`, given each
    /// replica's outstanding-request depth.
    pub fn route(&mut self, station: u32, depths: &[usize]) -> usize {
        let n = depths.len();
        debug_assert!(n > 0);
        match self.policy {
            RoutePolicy::RoundRobin => {
                let i = self.rr_next % n;
                self.rr_next = self.rr_next.wrapping_add(1);
                i
            }
            RoutePolicy::JoinShortestQueue => depths
                .iter()
                .enumerate()
                .min_by_key(|&(i, d)| (*d, i))
                .map(|(i, _)| i)
                .unwrap_or(0),
            RoutePolicy::StationSharded => station as usize % n,
        }
    }
}

/// When the router refuses an arrival instead of queueing it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Queue everything (offered load is absorbed as latency).
    Open,
    /// Drop when the target replica already has this many requests
    /// outstanding (a fixed back-pressure valve).
    QueueCap(usize),
    /// Drop when the target replica's estimated wait — outstanding
    /// requests × its running mean service time — would exceed the SLA:
    /// the request would land beyond the p90 objective, so shedding it
    /// protects the percentile (§3.3).
    SlaP90 { sla_us: f64 },
}

impl AdmissionPolicy {
    /// Admit into a replica with `outstanding` requests whose running
    /// mean service estimate is `est_service_us` (0 until first
    /// completion — the controller never drops blind).
    pub fn admits(&self, outstanding: usize, est_service_us: f64) -> bool {
        match *self {
            AdmissionPolicy::Open => true,
            AdmissionPolicy::QueueCap(cap) => outstanding < cap.max(1),
            AdmissionPolicy::SlaP90 { sla_us } => {
                est_service_us <= 0.0 || (outstanding as f64 + 1.0) * est_service_us <= sla_us
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            AdmissionPolicy::Open => "open".into(),
            AdmissionPolicy::QueueCap(cap) => format!("cap:{cap}"),
            AdmissionPolicy::SlaP90 { sla_us } => format!("sla:{sla_us:.0}us"),
        }
    }
}

/// One cluster deployment: N identical replicas behind a router.
#[derive(Debug, Clone, Copy)]
pub struct ClusterConfig {
    pub nodes: usize,
    /// Per-replica topology and policies (including the result cache).
    pub node: PipelineConfig,
    pub route: RoutePolicy,
    pub admission: AdmissionPolicy,
}

impl ClusterConfig {
    pub fn new(nodes: usize, node: PipelineConfig) -> ClusterConfig {
        assert!(nodes >= 1);
        ClusterConfig {
            nodes,
            node,
            route: RoutePolicy::RoundRobin,
            admission: AdmissionPolicy::Open,
        }
    }

    pub fn with_route(mut self, route: RoutePolicy) -> ClusterConfig {
        self.route = route;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> ClusterConfig {
        self.admission = admission;
        self
    }

    pub fn label(&self) -> String {
        format!(
            "{}×[{}] route={} adm={}",
            self.nodes,
            self.node.topology.label(),
            self.route.label(),
            self.admission.label()
        )
    }
}

/// Per-replica slice of a cluster run.
#[derive(Debug, Clone, Default)]
pub struct NodeReport {
    pub completed_requests: usize,
    pub completed_queries: usize,
    pub req_p90_us: f64,
    pub cache_hit_rate: f64,
    pub mean_aggregation: f64,
}

/// Outcome of one cluster run (real or simulated).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub label: String,
    pub route: String,
    /// Offered load of the arrival stream, queries/s.
    pub offered_qps: f64,
    /// Completed queries over the run span, queries/s.
    pub achieved_qps: f64,
    /// Requests offered / completed / dropped at admission.
    pub requests: usize,
    pub completed: usize,
    pub dropped: usize,
    pub completed_queries: usize,
    pub dropped_queries: usize,
    /// Requests whose engine path failed (degraded replies).
    pub failed: usize,
    /// Fleet-level request latency (per-node samples merged).
    pub req_p50_us: f64,
    pub req_p90_us: f64,
    pub req_p99_us: f64,
    /// Fleet-aggregate hot-connection cache hit rate (0 without a cache).
    pub cache_hit_rate: f64,
    pub per_node: Vec<NodeReport>,
}

impl ClusterReport {
    /// The router-policy conservation invariant: every offered request is
    /// either completed or visibly dropped — the fleet loses nothing.
    pub fn conserves_requests(&self) -> bool {
        self.requests == self.completed + self.dropped
    }

    /// A run "saturates" when it sheds load or visibly falls behind the
    /// offered clock.
    pub fn saturated(&self) -> bool {
        self.dropped > 0 || self.achieved_qps < 0.95 * self.offered_qps
    }

    /// Largest per-node completion share (1/n = perfectly balanced).
    pub fn max_node_share(&self) -> f64 {
        let total: usize = self.per_node.iter().map(|n| n.completed_requests).sum();
        if total == 0 {
            return 0.0;
        }
        self.per_node
            .iter()
            .map(|n| n.completed_requests as f64 / total as f64)
            .fold(0.0, f64::max)
    }

    /// One-line summary for benches and the CLI.
    pub fn summary(&self) -> String {
        format!(
            "{} | offered {:.2} Mq/s → achieved {:.2} Mq/s | {}/{} completed, {} dropped | \
             p90 {:.0} µs | cache {:.0} %",
            self.label,
            self.offered_qps / 1e6,
            self.achieved_qps / 1e6,
            self.completed,
            self.requests,
            self.dropped,
            self.req_p90_us,
            self.cache_hit_rate * 100.0,
        )
    }
}

/// EWMA weight of the per-replica service estimate behind
/// [`AdmissionPolicy::SlaP90`].
pub(crate) const SERVICE_EWMA_ALPHA: f64 = 0.2;

/// Update a replica's running per-request *service* estimate from an
/// observed completion. The observed latency includes the wait behind the
/// requests still outstanding at completion time, so it is normalised by
/// the queue depth before entering the EWMA — `outstanding × estimate`
/// must predict the wait, not double-count it. Shared by the real cluster
/// and the simulator so both realisations run the identical controller.
pub(crate) fn update_service_estimate(
    prev_us: f64,
    latency_us: f64,
    outstanding_after: usize,
) -> f64 {
    let observed = latency_us / (outstanding_after as f64 + 1.0);
    if prev_us <= 0.0 {
        observed
    } else {
        prev_us + SERVICE_EWMA_ALPHA * (observed - prev_us)
    }
}

/// Merge per-node latency collectors into fleet-level percentiles.
pub(crate) fn merged_quantiles(per_node: &[Percentiles]) -> (f64, f64, f64) {
    let mut fleet = Percentiles::new();
    for p in per_node {
        fleet.merge(p);
    }
    if fleet.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (fleet.p50(), fleet.p90(), fleet.p99())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Topology;

    #[test]
    fn router_round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let depths = [0usize; 3];
        let picks: Vec<usize> = (0..6).map(|_| r.route(9, &depths)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn router_jsq_picks_shortest_with_stable_ties() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(0, &[3, 1, 2]), 1);
        assert_eq!(r.route(0, &[2, 2, 2]), 0, "ties break to the lowest index");
        assert_eq!(r.route(0, &[5, 4, 0]), 2);
    }

    #[test]
    fn router_station_sharded_is_stable_per_station() {
        let mut r = Router::new(RoutePolicy::StationSharded);
        let depths = [100usize, 0, 0, 0]; // ignores load entirely
        assert_eq!(r.route(8, &depths), 0);
        assert_eq!(r.route(8, &depths), 0);
        assert_eq!(r.route(9, &depths), 1);
        assert_eq!(r.route(11, &depths), 3);
    }

    #[test]
    fn admission_policies() {
        assert!(AdmissionPolicy::Open.admits(10_000, 1e9));
        let cap = AdmissionPolicy::QueueCap(4);
        assert!(cap.admits(3, 0.0));
        assert!(!cap.admits(4, 0.0));
        let sla = AdmissionPolicy::SlaP90 { sla_us: 1_000.0 };
        assert!(sla.admits(100, 0.0), "no service estimate yet ⇒ never drop blind");
        assert!(sla.admits(4, 200.0), "5 × 200 µs = SLA boundary");
        assert!(!sla.admits(5, 200.0), "6 × 200 µs busts the SLA");
    }

    #[test]
    fn route_policy_parse_roundtrip() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::JoinShortestQueue,
            RoutePolicy::StationSharded,
        ] {
            assert_eq!(RoutePolicy::parse(p.label()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn service_estimate_normalises_queueing() {
        // A completion that waited behind 9 still-outstanding requests
        // contributes latency/10 — otherwise `outstanding × estimate`
        // would double-count the queue.
        let first = update_service_estimate(0.0, 1_000.0, 9);
        assert_eq!(first, 100.0);
        assert_eq!(
            update_service_estimate(first, 100.0, 0),
            100.0,
            "stationary on consistent observations"
        );
        let drift = update_service_estimate(100.0, 200.0, 0);
        assert!((drift - 120.0).abs() < 1e-9, "EWMA drifts at α=0.2: {drift}");
    }

    #[test]
    fn cluster_config_labels() {
        let cfg = ClusterConfig::new(4, PipelineConfig::new(Topology::new(2, 1, 1, 4)))
            .with_route(RoutePolicy::StationSharded)
            .with_admission(AdmissionPolicy::QueueCap(16));
        assert_eq!(cfg.label(), "4×[2p 1w 1k 4e] route=shard adm=cap:16");
    }
}
