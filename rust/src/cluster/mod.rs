//! The fleet layer: a sharded cluster of feeder-node replicas behind a
//! router — the deployment the paper's §6 costs out, built over the
//! single-node machinery of [`crate::coordinator`].
//!
//! One *node* is a full Fig-5 serving replica (router queue → MCT-Wrapper
//! workers → engine servers → [`crate::backend::MatchBackend`], optional
//! hot-connection LRU). The cluster front-end takes an open-loop
//! [`ArrivalSource`](crate::workload::ArrivalSource), applies
//! [`AdmissionPolicy`] (drop rather than bust the p90 SLA — §3.3 "the 90th
//! percentile … matches the SLA of the search engine"), and routes every
//! admitted request to a replica per [`RoutePolicy`].
//!
//! Since the control-plane refactor the fleet is **heterogeneous**: every
//! replica carries a [`NodeSpec`] whose [`NodeClass`] ties it to a
//! [`costmodel::Element`](crate::costmodel::Element) (what the node costs)
//! and a capacity estimate (what it serves) — CPU-only and FPGA-backed
//! nodes mix behind one router, and the JSQ-family policies normalise
//! queue depth by capacity so a strong node is offered proportionally more
//! load. [`crate::controlplane`] builds on this to autoscale the fleet and
//! inject failures.
//!
//! Two realisations, cross-validated like the single-node pair:
//!
//! * [`real::Cluster`] — N threaded [`NodeCore`](crate::coordinator)
//!   replicas serving queries for real, wall-clock;
//! * [`sim::simulate_cluster`] — a deterministic discrete-event model of
//!   the same fleet (feeder service + kernel datapath + per-node LRU),
//!   which is what the `fleet_imbalance` bench sweeps to reproduce the
//!   §6.1 "FPGA starves behind a weak feeder" knee.
//!
//! Reports carry **offered vs achieved** load, SLA drops, requests lost to
//! node failures, per-node and fleet-merged latency quantiles
//! ([`Percentiles::merge`]), per-class aggregates and cache hit rates —
//! the measured inputs that
//! [`crate::costmodel::provision_for_throughput`] turns into fleet plans.

pub mod real;
pub mod sim;

pub use real::Cluster;
pub use sim::{
    poisson_sim_arrivals, scheduled_sim_arrivals, simulate_cluster, ClusterSimConfig,
    SimArrival, SimEngine, SimNodeSpec,
};

use crate::coordinator::{Percentiles, PipelineConfig};
use crate::costmodel::{catalog, Element};
use crate::prng::Rng;

/// How the front-end router picks a replica for an admitted request.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    /// Cycle through replicas regardless of state (the ZeroMQ dealer
    /// default).
    RoundRobin,
    /// Send to the replica with the fewest outstanding requests,
    /// normalised by capacity on heterogeneous fleets.
    JoinShortestQueue,
    /// Power-of-d-choices: sample `d` distinct replicas and join the
    /// shortest (capacity-normalised) of those — JSQ's balance at O(d)
    /// state probes instead of O(n). `JsqD(2)` is the classic
    /// two-choices router.
    JsqD(usize),
    /// Pin each connection station to one replica (`station mod n`), so a
    /// station's hot connections stay in that replica's LRU — cache
    /// affinity at the price of zipf-skewed load.
    StationSharded,
}

impl RoutePolicy {
    pub fn label(&self) -> String {
        match *self {
            RoutePolicy::RoundRobin => "rr".into(),
            RoutePolicy::JoinShortestQueue => "jsq".into(),
            RoutePolicy::JsqD(2) => "jsq2".into(),
            RoutePolicy::JsqD(d) => format!("jsqd:{d}"),
            RoutePolicy::StationSharded => "shard".into(),
        }
    }

    pub fn parse(s: &str) -> Option<RoutePolicy> {
        match s {
            "rr" | "round-robin" => Some(RoutePolicy::RoundRobin),
            "jsq" => Some(RoutePolicy::JoinShortestQueue),
            "jsq2" => Some(RoutePolicy::JsqD(2)),
            "shard" | "station" => Some(RoutePolicy::StationSharded),
            _ => s
                .strip_prefix("jsqd:")
                .and_then(|d| d.parse().ok())
                .filter(|&d| d >= 1)
                .map(RoutePolicy::JsqD),
        }
    }
}

/// Stateful router: one instance per cluster run. On heterogeneous fleets
/// the JSQ-family policies compare *relative* queue depth
/// (`outstanding / capacity weight`), so a node with twice the capacity is
/// considered half as loaded at equal depth.
#[derive(Debug, Clone)]
pub struct Router {
    pub policy: RoutePolicy,
    rr_next: usize,
    /// Sampling stream for [`RoutePolicy::JsqD`]; seeded ⇒ reproducible.
    rng: Rng,
    /// Per-node capacity weights; empty ⇒ every node weighs 1.
    weights: Vec<f64>,
    /// Per-node brown-out health weights in `(0, 1]`; empty ⇒ healthy.
    /// Multiplied into the capacity weight, so the JSQ family sees a
    /// browning replica as proportionally smaller — it keeps receiving
    /// *some* traffic (health is floored), which is how recovery is
    /// observed.
    health: Vec<f64>,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router {
            policy,
            rr_next: 0,
            rng: Rng::new(0x2070_D2),
            weights: Vec::new(),
            health: Vec::new(),
        }
    }

    /// Reseed the JSQ(d) sampling stream.
    pub fn with_seed(mut self, seed: u64) -> Router {
        self.rng = Rng::new(seed ^ 0x2070_D2);
        self
    }

    /// Attach per-node capacity weights (queries/s or any consistent
    /// relative unit).
    pub fn with_weights(mut self, weights: Vec<f64>) -> Router {
        self.weights = weights;
        self
    }

    /// Replace the capacity weights mid-run (the control plane calls this
    /// when it grows the node set; routing state is otherwise preserved).
    pub fn set_weights(&mut self, weights: Vec<f64>) {
        self.weights = weights;
    }

    /// Replace the brown-out health weights (the resilience layer calls
    /// this as per-replica [`crate::resilience::HealthScore`]s move).
    pub fn set_health(&mut self, health: Vec<f64>) {
        self.health = health;
    }

    fn weight(&self, i: usize) -> f64 {
        let cap = self.weights.get(i).copied().filter(|w| *w > 0.0).unwrap_or(1.0);
        let h = self.health.get(i).copied().filter(|h| *h > 0.0).unwrap_or(1.0);
        cap * h
    }

    /// Capacity- and health-normalised depth the JSQ-family policies
    /// minimise.
    fn rel_depth(&self, i: usize, depth: usize) -> f64 {
        depth as f64 / self.weight(i)
    }

    fn argmin_rel(&self, depths: &[usize], up: Option<&[bool]>) -> usize {
        let mut best = usize::MAX;
        let mut best_d = f64::INFINITY;
        for (i, &d) in depths.iter().enumerate() {
            if let Some(u) = up {
                if !u[i] {
                    continue;
                }
            }
            let rd = self.rel_depth(i, d);
            if rd < best_d {
                best_d = rd;
                best = i;
            }
        }
        best
    }

    /// Pick the target replica for a request at `station`, given each
    /// replica's outstanding-request depth. Every replica is assumed live.
    pub fn route(&mut self, station: u32, depths: &[usize]) -> usize {
        self.route_up(station, depths, None).expect("route() needs ≥1 replica")
    }

    /// Liveness-aware routing: `up[i] == false` replicas are never picked
    /// (down, draining, or still provisioning). Returns `None` when no
    /// replica is live.
    pub fn route_up(
        &mut self,
        station: u32,
        depths: &[usize],
        up: Option<&[bool]>,
    ) -> Option<usize> {
        let n = depths.len();
        if n == 0 {
            return None;
        }
        let is_up = |i: usize| up.map(|u| u[i]).unwrap_or(true);
        if !(0..n).any(is_up) {
            return None;
        }
        Some(match self.policy {
            RoutePolicy::RoundRobin => {
                let mut i = self.rr_next % n;
                while !is_up(i) {
                    i = (i + 1) % n;
                }
                self.rr_next = i + 1;
                i
            }
            RoutePolicy::JoinShortestQueue => self.argmin_rel(depths, up),
            RoutePolicy::JsqD(d) => {
                let d = d.max(1);
                let live: Vec<usize> = (0..n).filter(|&i| is_up(i)).collect();
                if live.len() <= d {
                    self.argmin_rel(depths, up)
                } else {
                    // Partial Fisher–Yates: the first d entries are a
                    // uniform distinct sample of the live replicas.
                    let mut pool = live;
                    let mut best = usize::MAX;
                    let mut best_d = f64::INFINITY;
                    for k in 0..d {
                        let j = k + self.rng.index(pool.len() - k);
                        pool.swap(k, j);
                        let cand = pool[k];
                        let rd = self.rel_depth(cand, depths[cand]);
                        if rd < best_d {
                            best_d = rd;
                            best = cand;
                        }
                    }
                    best
                }
            }
            RoutePolicy::StationSharded => {
                let mut i = station as usize % n;
                while !is_up(i) {
                    i = (i + 1) % n;
                }
                i
            }
        })
    }
}

/// When the router refuses an arrival instead of queueing it.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum AdmissionPolicy {
    /// Queue everything (offered load is absorbed as latency).
    Open,
    /// Drop when the target replica already has this many requests
    /// outstanding (a fixed back-pressure valve).
    QueueCap(usize),
    /// Drop when the target replica's estimated wait — outstanding
    /// requests × its running mean service time — would exceed the SLA:
    /// the request would land beyond the p90 objective, so shedding it
    /// protects the percentile (§3.3).
    SlaP90 { sla_us: f64 },
}

impl AdmissionPolicy {
    /// Admit into a replica with `outstanding` requests whose running
    /// mean service estimate is `est_service_us` (0 until first
    /// completion — the controller never drops blind).
    pub fn admits(&self, outstanding: usize, est_service_us: f64) -> bool {
        match *self {
            AdmissionPolicy::Open => true,
            AdmissionPolicy::QueueCap(cap) => outstanding < cap.max(1),
            AdmissionPolicy::SlaP90 { sla_us } => {
                est_service_us <= 0.0 || (outstanding as f64 + 1.0) * est_service_us <= sla_us
            }
        }
    }

    pub fn label(&self) -> String {
        match *self {
            AdmissionPolicy::Open => "open".into(),
            AdmissionPolicy::QueueCap(cap) => format!("cap:{cap}"),
            AdmissionPolicy::SlaP90 { sla_us } => format!("sla:{sla_us:.0}us"),
        }
    }
}

/// What a replica *is*, economically: the purchasable element behind it
/// and the throughput it is provisioned to sustain. This is the metadata
/// path from [`crate::costmodel`] into the router (capacity weights) and
/// the control plane (cost-aware scaling, per-class node-hours).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeClass {
    /// Report/CLI label, e.g. `fpga-f1`, `cpu-c5`.
    pub name: &'static str,
    /// The catalogue element this node is billed as.
    pub element: Element,
    /// Modeled or measured single-node MCT saturation, queries/s — the
    /// router weight and the autoscaler's capacity-planning input.
    pub capacity_qps: f64,
}

impl NodeClass {
    /// An f1.2xlarge-shaped FPGA node.
    pub fn fpga_f1(capacity_qps: f64) -> NodeClass {
        NodeClass { name: "fpga-f1", element: catalog::AWS_F1_2XL, capacity_qps }
    }

    /// A c5.12xlarge-shaped CPU-only node.
    pub fn cpu_c5(capacity_qps: f64) -> NodeClass {
        NodeClass { name: "cpu-c5", element: catalog::AWS_C5_12XL, capacity_qps }
    }

    /// Effective hourly price (purchases amortised; see
    /// [`Element::hourly_usd`]).
    pub fn hourly_usd(&self) -> f64 {
        self.element.hourly_usd()
    }

    /// Marginal cost of capacity, $/h per query/s — what the cost-aware
    /// autoscaler minimises when it picks a class to add.
    pub fn cost_per_qps(&self) -> f64 {
        self.hourly_usd() / self.capacity_qps.max(1e-9)
    }
}

/// One replica of the (possibly heterogeneous) fleet: its economic class
/// plus the Fig-5 topology and policies it runs.
#[derive(Debug, Clone)]
pub struct NodeSpec {
    pub class: NodeClass,
    pub node: PipelineConfig,
}

/// One cluster deployment: N replicas behind a router. Homogeneous
/// clusters come from [`ClusterConfig::new`]; mixed CPU/FPGA fleets from
/// [`ClusterConfig::heterogeneous`].
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    /// Per-replica class + topology.
    pub specs: Vec<NodeSpec>,
    pub route: RoutePolicy,
    pub admission: AdmissionPolicy,
    /// Seed of the router's JSQ(d) sampling stream.
    pub route_seed: u64,
    /// Gray-degradation windows executed by the serving path itself
    /// (kill faults are the control plane's job and are ignored here).
    pub faults: crate::controlplane::FaultPlan,
}

impl ClusterConfig {
    /// N identical replicas of the default FPGA class, capacity-rated at
    /// the measured lockstep knee when a `BENCH_hotpath.json` is on disk
    /// (else the modeled v2 saturation) — so `CostAware` and
    /// `plan_fleet` size fleets of these nodes from measurement.
    pub fn new(nodes: usize, node: PipelineConfig) -> ClusterConfig {
        assert!(nodes >= 1);
        let class = NodeClass::fpga_f1(crate::costmodel::default_node_qps());
        ClusterConfig::heterogeneous(
            (0..nodes).map(|_| NodeSpec { class: class.clone(), node }).collect(),
        )
    }

    /// Mixed fleet from explicit per-node specs.
    pub fn heterogeneous(specs: Vec<NodeSpec>) -> ClusterConfig {
        assert!(!specs.is_empty());
        ClusterConfig {
            specs,
            route: RoutePolicy::RoundRobin,
            admission: AdmissionPolicy::Open,
            route_seed: 0,
            faults: crate::controlplane::FaultPlan::none(),
        }
    }

    pub fn nodes(&self) -> usize {
        self.specs.len()
    }

    pub fn with_route(mut self, route: RoutePolicy) -> ClusterConfig {
        self.route = route;
        self
    }

    pub fn with_admission(mut self, admission: AdmissionPolicy) -> ClusterConfig {
        self.admission = admission;
        self
    }

    pub fn with_route_seed(mut self, seed: u64) -> ClusterConfig {
        self.route_seed = seed;
        self
    }

    pub fn with_faults(mut self, faults: crate::controlplane::FaultPlan) -> ClusterConfig {
        self.faults = faults;
        self
    }

    /// The run's router: policy + capacity weights from the node classes.
    pub fn router(&self) -> Router {
        Router::new(self.route)
            .with_seed(self.route_seed)
            .with_weights(self.specs.iter().map(|s| s.class.capacity_qps).collect())
    }

    /// True when every replica shares one class and topology (what
    /// [`Cluster::new`] builds; the calibration-based cross-validations
    /// require it).
    pub fn is_homogeneous(&self) -> bool {
        self.specs
            .windows(2)
            .all(|w| w[0].class.name == w[1].class.name && w[0].node == w[1].node)
    }

    pub fn label(&self) -> String {
        let body = if self.is_homogeneous() {
            format!("{}×[{}]", self.specs.len(), self.specs[0].node.topology.label())
        } else {
            group_label(
                &self.specs,
                |a, b| a.class.name == b.class.name && a.node == b.node,
                |s| format!("{}[{}]", s.class.name, s.node.topology.label()),
            )
        };
        format!("{} route={} adm={}", body, self.route.label(), self.admission.label())
    }
}

/// Per-replica slice of a cluster run.
#[derive(Debug, Clone, Default)]
pub struct NodeReport {
    /// The replica's [`NodeClass`] name (`fpga-f1`, `cpu-c5`, …).
    pub class: String,
    /// Backend label the replica actually served with (real runs; the DES
    /// copies the class name).
    pub backend: String,
    pub completed_requests: usize,
    pub completed_queries: usize,
    /// Requests whose engine path failed on this replica (gray errors).
    pub failed_requests: usize,
    pub req_p90_us: f64,
    pub cache_hit_rate: f64,
    pub mean_aggregation: f64,
    /// Final brown-out health weight in `(0, 1]` (1 = never degraded).
    pub health: f64,
}

/// Per-class rollup of a heterogeneous run — what makes a mixed fleet's
/// report legible (which class served what share of the load).
#[derive(Debug, Clone)]
pub struct ClassAggregate {
    pub class: String,
    pub nodes: usize,
    pub completed_requests: usize,
    pub completed_queries: usize,
    /// Worst per-node p90 inside the class (the class's SLA exposure).
    pub max_p90_us: f64,
}

/// Outcome of one cluster run (real or simulated).
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub label: String,
    pub route: String,
    /// Offered load of the arrival stream, queries/s.
    pub offered_qps: f64,
    /// Completed queries over the run span, queries/s.
    pub achieved_qps: f64,
    /// Requests offered / completed / dropped at admission / lost to node
    /// failure.
    pub requests: usize,
    pub completed: usize,
    pub dropped: usize,
    /// Admitted requests that died with a failed node (only non-zero when
    /// a failure leaves no live replica to reroute to; the drain/reroute
    /// policy otherwise preserves every admitted request).
    pub lost: usize,
    pub completed_queries: usize,
    pub dropped_queries: usize,
    pub lost_queries: usize,
    /// Requests whose engine path failed (degraded replies). A failed
    /// request still *completes* — conservation counts it once — but a
    /// gray error burst surfaces here and in `failed_queries`.
    pub failed: usize,
    pub failed_queries: usize,
    /// Fleet-level request latency (per-node samples merged).
    pub req_p50_us: f64,
    pub req_p90_us: f64,
    pub req_p99_us: f64,
    /// Fleet-aggregate hot-connection cache hit rate (0 without a cache).
    pub cache_hit_rate: f64,
    pub per_node: Vec<NodeReport>,
}

impl ClusterReport {
    /// The router-policy conservation invariant: every offered request is
    /// exactly one of completed, visibly dropped at admission, or visibly
    /// lost to a node failure — the fleet loses nothing silently.
    pub fn conserves_requests(&self) -> bool {
        self.requests == self.completed + self.dropped + self.lost
    }

    /// A run "saturates" when it sheds load or visibly falls behind the
    /// offered clock.
    pub fn saturated(&self) -> bool {
        self.dropped > 0 || self.achieved_qps < 0.95 * self.offered_qps
    }

    /// Largest per-node completion share (1/n = perfectly balanced).
    pub fn max_node_share(&self) -> f64 {
        let total: usize = self.per_node.iter().map(|n| n.completed_requests).sum();
        if total == 0 {
            return 0.0;
        }
        self.per_node
            .iter()
            .map(|n| n.completed_requests as f64 / total as f64)
            .fold(0.0, f64::max)
    }

    /// Roll the per-node slices up by class, in first-seen order.
    pub fn per_class(&self) -> Vec<ClassAggregate> {
        let mut out: Vec<ClassAggregate> = Vec::new();
        for n in &self.per_node {
            let agg = match out.iter_mut().find(|a| a.class == n.class) {
                Some(a) => a,
                None => {
                    out.push(ClassAggregate {
                        class: n.class.clone(),
                        nodes: 0,
                        completed_requests: 0,
                        completed_queries: 0,
                        max_p90_us: 0.0,
                    });
                    out.last_mut().unwrap()
                }
            };
            agg.nodes += 1;
            agg.completed_requests += n.completed_requests;
            agg.completed_queries += n.completed_queries;
            agg.max_p90_us = agg.max_p90_us.max(n.req_p90_us);
        }
        out
    }

    /// One-line summary for benches and the CLI; heterogeneous runs append
    /// the per-class completion split.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} | offered {:.2} Mq/s → achieved {:.2} Mq/s | {}/{} completed, {} dropped, \
             {} lost | p90 {:.0} µs | cache {:.0} %",
            self.label,
            self.offered_qps / 1e6,
            self.achieved_qps / 1e6,
            self.completed,
            self.requests,
            self.dropped,
            self.lost,
            self.req_p90_us,
            self.cache_hit_rate * 100.0,
        );
        let classes = self.per_class();
        if classes.len() > 1 {
            let split: Vec<String> = classes
                .iter()
                .map(|c| format!("{}×{} {} req", c.nodes, c.class, c.completed_requests))
                .collect();
            s.push_str(&format!(" | by class: {}", split.join(", ")));
        }
        s
    }
}

/// EWMA weight of the per-replica service estimate behind
/// [`AdmissionPolicy::SlaP90`].
pub(crate) const SERVICE_EWMA_ALPHA: f64 = 0.2;

/// Update a replica's running per-request *service* estimate from an
/// observed completion. The observed latency includes the wait behind the
/// requests still outstanding at completion time, so it is normalised by
/// the queue depth before entering the EWMA — `outstanding × estimate`
/// must predict the wait, not double-count it. Shared by the real cluster
/// and the simulator so both realisations run the identical controller.
pub(crate) fn update_service_estimate(
    prev_us: f64,
    latency_us: f64,
    outstanding_after: usize,
) -> f64 {
    let observed = latency_us / (outstanding_after as f64 + 1.0);
    if prev_us <= 0.0 {
        observed
    } else {
        prev_us + SERVICE_EWMA_ALPHA * (observed - prev_us)
    }
}

/// Group consecutive equal items into `N×label` parts joined by `+` —
/// the shared grammar of the heterogeneous fleet labels (real and sim).
pub(crate) fn group_label<T>(
    items: &[T],
    eq: impl Fn(&T, &T) -> bool,
    fmt: impl Fn(&T) -> String,
) -> String {
    let mut parts: Vec<String> = Vec::new();
    let mut i = 0;
    while i < items.len() {
        let mut j = i + 1;
        while j < items.len() && eq(&items[i], &items[j]) {
            j += 1;
        }
        parts.push(format!("{}×{}", j - i, fmt(&items[i])));
        i = j;
    }
    parts.join("+")
}

/// Merge per-node latency collectors into fleet-level percentiles.
pub(crate) fn merged_quantiles(per_node: &[Percentiles]) -> (f64, f64, f64) {
    let mut fleet = Percentiles::new();
    for p in per_node {
        fleet.merge(p);
    }
    if fleet.is_empty() {
        (0.0, 0.0, 0.0)
    } else {
        (fleet.p50(), fleet.p90(), fleet.p99())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Topology;

    #[test]
    fn router_round_robin_cycles() {
        let mut r = Router::new(RoutePolicy::RoundRobin);
        let depths = [0usize; 3];
        let picks: Vec<usize> = (0..6).map(|_| r.route(9, &depths)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
    }

    #[test]
    fn router_jsq_picks_shortest_with_stable_ties() {
        let mut r = Router::new(RoutePolicy::JoinShortestQueue);
        assert_eq!(r.route(0, &[3, 1, 2]), 1);
        assert_eq!(r.route(0, &[2, 2, 2]), 0, "ties break to the lowest index");
        assert_eq!(r.route(0, &[5, 4, 0]), 2);
    }

    #[test]
    fn router_jsq_normalises_by_capacity_weights() {
        // Node 1 has 4× the capacity: at depths 3 vs 8 its *relative* load
        // (8/4 = 2) is still lighter than node 0's (3/1 = 3).
        let mut r = Router::new(RoutePolicy::JoinShortestQueue)
            .with_weights(vec![1.0, 4.0]);
        assert_eq!(r.route(0, &[3, 8]), 1);
        assert_eq!(r.route(0, &[1, 8]), 0, "past 4×, the big node is busier");
    }

    #[test]
    fn router_health_weights_compose_with_capacity() {
        // Equal capacity, equal depth — but node 0 is browning out at
        // health 0.1: its relative depth is 10× heavier, so JSQ shifts
        // traffic away without taking the node out of rotation.
        let mut r = Router::new(RoutePolicy::JoinShortestQueue)
            .with_weights(vec![1.0, 1.0]);
        r.set_health(vec![0.1, 1.0]);
        assert_eq!(r.route(0, &[2, 8]), 1, "2/0.1 = 20 beats 8/1");
        assert_eq!(r.route(0, &[0, 8]), 0, "an idle browning node still serves");
        // Clearing health restores pure capacity routing.
        r.set_health(Vec::new());
        assert_eq!(r.route(0, &[2, 8]), 0);
    }

    #[test]
    fn router_jsqd_samples_d_and_never_picks_the_worst() {
        // With d = 2 of 4 and one empty queue, JSQ(2) must always pick a
        // queue no deeper than the second-shortest of its sample — in
        // particular never the unique deepest one.
        let mut r = Router::new(RoutePolicy::JsqD(2)).with_seed(7);
        let depths = [9usize, 3, 0, 4];
        for _ in 0..64 {
            let pick = r.route_up(0, &depths, None).unwrap();
            assert_ne!(pick, 0, "two distinct samples always beat the deepest queue");
        }
        // d ≥ n degrades to exact JSQ.
        let mut full = Router::new(RoutePolicy::JsqD(8)).with_seed(7);
        assert_eq!(full.route(0, &depths), 2);
    }

    #[test]
    fn router_jsqd_is_seeded_deterministic() {
        let depths = [5usize, 1, 3, 2, 4];
        let run = |seed| {
            let mut r = Router::new(RoutePolicy::JsqD(2)).with_seed(seed);
            (0..32).map(|_| r.route(0, &depths)).collect::<Vec<_>>()
        };
        assert_eq!(run(11), run(11));
        assert_ne!(run(11), run(12), "different seeds sample differently");
    }

    #[test]
    fn router_station_sharded_is_stable_per_station() {
        let mut r = Router::new(RoutePolicy::StationSharded);
        let depths = [100usize, 0, 0, 0]; // ignores load entirely
        assert_eq!(r.route(8, &depths), 0);
        assert_eq!(r.route(8, &depths), 0);
        assert_eq!(r.route(9, &depths), 1);
        assert_eq!(r.route(11, &depths), 3);
    }

    #[test]
    fn router_skips_down_nodes_and_reports_dead_fleet() {
        let depths = [0usize, 0, 0];
        let up = [false, true, false];
        for policy in [
            RoutePolicy::RoundRobin,
            RoutePolicy::JoinShortestQueue,
            RoutePolicy::JsqD(2),
            RoutePolicy::StationSharded,
        ] {
            let mut r = Router::new(policy);
            for station in 0..6u32 {
                assert_eq!(
                    r.route_up(station, &depths, Some(&up)),
                    Some(1),
                    "{policy:?} must land on the only live node"
                );
            }
            assert_eq!(r.route_up(0, &depths, Some(&[false; 3])), None);
        }
    }

    #[test]
    fn admission_policies() {
        assert!(AdmissionPolicy::Open.admits(10_000, 1e9));
        let cap = AdmissionPolicy::QueueCap(4);
        assert!(cap.admits(3, 0.0));
        assert!(!cap.admits(4, 0.0));
        let sla = AdmissionPolicy::SlaP90 { sla_us: 1_000.0 };
        assert!(sla.admits(100, 0.0), "no service estimate yet ⇒ never drop blind");
        assert!(sla.admits(4, 200.0), "5 × 200 µs = SLA boundary");
        assert!(!sla.admits(5, 200.0), "6 × 200 µs busts the SLA");
    }

    #[test]
    fn route_policy_parse_roundtrip() {
        for p in [
            RoutePolicy::RoundRobin,
            RoutePolicy::JoinShortestQueue,
            RoutePolicy::JsqD(2),
            RoutePolicy::JsqD(3),
            RoutePolicy::StationSharded,
        ] {
            assert_eq!(RoutePolicy::parse(&p.label()), Some(p));
        }
        assert_eq!(RoutePolicy::parse("jsqd:0"), None, "d must be ≥ 1");
        assert_eq!(RoutePolicy::parse("nope"), None);
    }

    #[test]
    fn service_estimate_normalises_queueing() {
        // A completion that waited behind 9 still-outstanding requests
        // contributes latency/10 — otherwise `outstanding × estimate`
        // would double-count the queue.
        let first = update_service_estimate(0.0, 1_000.0, 9);
        assert_eq!(first, 100.0);
        assert_eq!(
            update_service_estimate(first, 100.0, 0),
            100.0,
            "stationary on consistent observations"
        );
        let drift = update_service_estimate(100.0, 200.0, 0);
        assert!((drift - 120.0).abs() < 1e-9, "EWMA drifts at α=0.2: {drift}");
    }

    #[test]
    fn cluster_config_labels() {
        let cfg = ClusterConfig::new(4, PipelineConfig::new(Topology::new(2, 1, 1, 4)))
            .with_route(RoutePolicy::StationSharded)
            .with_admission(AdmissionPolicy::QueueCap(16));
        assert_eq!(cfg.label(), "4×[2p 1w 1k 4e] route=shard adm=cap:16");
    }

    #[test]
    fn heterogeneous_config_labels_and_weights() {
        let fpga = NodeSpec {
            class: NodeClass::fpga_f1(30e6),
            node: PipelineConfig::new(Topology::new(2, 1, 1, 4)),
        };
        let cpu = NodeSpec {
            class: NodeClass::cpu_c5(2e6),
            node: PipelineConfig::new(Topology::new(2, 1, 1, 1)),
        };
        let cfg = ClusterConfig::heterogeneous(vec![fpga.clone(), fpga, cpu])
            .with_route(RoutePolicy::JsqD(2));
        assert_eq!(cfg.nodes(), 3);
        assert_eq!(
            cfg.label(),
            "2×fpga-f1[2p 1w 1k 4e]+1×cpu-c5[2p 1w 1k 1e] route=jsq2 adm=open"
        );
        // The router inherits the classes' capacities as weights: at equal
        // depth, relative load on the FPGA node is 15× lighter.
        let mut router = cfg.router();
        assert_eq!(router.route(0, &[4, 4, 1]), 0);
    }

    #[test]
    fn node_class_cost_metadata_flows_from_costmodel() {
        let f1 = NodeClass::fpga_f1(30e6);
        assert_eq!(f1.element.name, "f1.2xlarge");
        assert!(f1.hourly_usd() > 0.0);
        let cheap = NodeClass::cpu_c5(30e6);
        // Same capacity, different price ⇒ cost_per_qps orders the classes.
        assert!(cheap.cost_per_qps() != f1.cost_per_qps());
    }

    #[test]
    fn per_class_aggregates_roll_up_mixed_fleets() {
        let node = |class: &str, req: usize, p90: f64| NodeReport {
            class: class.into(),
            backend: class.into(),
            completed_requests: req,
            completed_queries: req * 10,
            failed_requests: 0,
            req_p90_us: p90,
            cache_hit_rate: 0.0,
            mean_aggregation: 1.0,
            health: 1.0,
        };
        let r = ClusterReport {
            label: "t".into(),
            route: "rr".into(),
            offered_qps: 0.0,
            achieved_qps: 0.0,
            requests: 70,
            completed: 60,
            dropped: 6,
            lost: 4,
            completed_queries: 600,
            dropped_queries: 60,
            lost_queries: 40,
            failed: 0,
            failed_queries: 0,
            req_p50_us: 0.0,
            req_p90_us: 0.0,
            req_p99_us: 0.0,
            cache_hit_rate: 0.0,
            per_node: vec![node("fpga-f1", 25, 900.0), node("cpu-c5", 10, 1500.0), node("fpga-f1", 25, 700.0)],
        };
        assert!(r.conserves_requests(), "completed + dropped + lost");
        let by_class = r.per_class();
        assert_eq!(by_class.len(), 2);
        assert_eq!(by_class[0].class, "fpga-f1");
        assert_eq!(by_class[0].nodes, 2);
        assert_eq!(by_class[0].completed_requests, 50);
        assert_eq!(by_class[0].max_p90_us, 900.0);
        assert_eq!(by_class[1].nodes, 1);
        assert!(r.summary().contains("by class"), "{}", r.summary());
    }
}
