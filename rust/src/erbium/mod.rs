//! The ERBIUM online engine (§3.1 second group): the Host Executor, the
//! hardware kernel backends, and the FPGA datapath cost model.
//!
//! * [`native`] — sparse functional simulator of the NFA kernel (bit-set
//!   active-state propagation, plus the transposed query-parallel lockstep
//!   walk). Bit-exact with the XLA path; used for bulk sweeps and as the
//!   cross-check oracle.
//! * [`engine`] — the Host Executor facade: owns the compiled images, routes
//!   queries to partitions, batches, and dispatches to a backend
//!   (XLA artifact via PJRT, or native).
//! * [`hw_model`] — the calibrated FPGA datapath cost model (shell latency,
//!   PCIe bandwidth, pipeline fill, clock) producing the *hardware-model
//!   clock* of DESIGN.md §Dual-clock.

pub mod engine;
pub mod hw_model;
pub mod native;

pub use engine::{Backend, ErbiumEngine};
pub use hw_model::{BatchTiming, FpgaModel};
pub use native::{
    EvalScratch, LaneScratch, LockstepStats, NativeEvaluator, LANE_MIN_OCCUPANCY, LANE_WIDTH,
    LOCKSTEP_MIN_ROWS,
};
