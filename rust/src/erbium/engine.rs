//! The Host Executor (§3.1): owns the compiled NFA partitions, loads them
//! into the accelerator, routes and batches MCT queries, and merges
//! per-partition results into final decisions.
//!
//! Two interchangeable backends evaluate the same compiled images:
//!
//! * [`Backend::Xla`] — the real accelerator path: AOT artifact executed via
//!   PJRT, partition images uploaded once and cached (the paper's "loading
//!   the NFA into the FPGA internal memory").
//! * [`Backend::Native`] — the sparse functional simulator, bit-exact with
//!   the XLA path and much faster on CPU; used for bulk figure sweeps.
//!
//! Hardware-model timing ([`FpgaModel`]) is attached per *logical* batch —
//! the modeled board holds the entire NFA (as the real FPGA does), so the
//! partition-at-a-time execution strategy of the CPU stand-in does not leak
//! into modeled time.

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::encoder::{EncodedBatch, QueryEncoder};
use crate::nfa::memory::NfaImage;
use crate::nfa::model::PartitionedNfa;
use crate::runtime::{DeviceImage, NfaExecutable, Runtime};
use crate::rules::types::{MctDecision, MctQuery};

use super::hw_model::{BatchTiming, FpgaModel};
use super::native::{EvalScratch, LaneScratch, NativeEvaluator, LOCKSTEP_MIN_ROWS};

/// Which implementation computes the answers.
#[derive(Clone)]
pub enum Backend {
    /// Sparse functional simulator.
    Native,
    /// AOT XLA artifact through the PJRT runtime.
    Xla { runtime: Arc<Runtime>, batch_hint: usize },
}

struct XlaState {
    runtime: Arc<Runtime>,
    /// Largest-batch variant (used for chunking bounds).
    exe: Arc<NfaExecutable>,
    /// partition index → uploaded device image.
    images: Mutex<HashMap<usize, Arc<DeviceImage>>>,
}

/// Reusable native-path buffers: the encoded batch, the scalar walker
/// scratch and the lockstep lane scratch, kept across calls so a
/// steady-state engine call allocates nothing (DESIGN.md §Hot path). One
/// lock per *batch*, not per query — the engine stays `Sync` without
/// contending the hot loop.
struct NativeScratch {
    batch: EncodedBatch,
    scratch: EvalScratch,
    lanes: LaneScratch,
}

/// The ERBIUM engine: compiled rule set + backend + datapath model.
pub struct ErbiumEngine {
    nfa: Arc<PartitionedNfa>,
    encoder: QueryEncoder,
    native: NativeEvaluator,
    xla: Option<XlaState>,
    model: FpgaModel,
    /// Artifact depth (padded L).
    l_pad: usize,
    s_pad: usize,
    /// Multi-core split of large native batches (1 = single core).
    shards: usize,
    /// Query-parallel lockstep walk for native batches of
    /// [`LOCKSTEP_MIN_ROWS`]+ rows (on by default; `--no-lockstep` and
    /// A/B tests turn it off).
    lockstep: bool,
    scratch: Mutex<NativeScratch>,
}

impl ErbiumEngine {
    /// Build an engine over a compiled rule set.
    ///
    /// `model` supplies the hardware-model clock; `(l_pad, s_pad)` must
    /// match the artifact variant when the XLA backend is used.
    pub fn new(
        nfa: PartitionedNfa,
        model: FpgaModel,
        backend: Backend,
        l_pad: usize,
        s_pad: usize,
    ) -> Result<ErbiumEngine> {
        let nfa = Arc::new(nfa);
        let encoder = QueryEncoder::new(&nfa.plan, l_pad);
        let native = NativeEvaluator::new((*nfa).clone());
        let xla = match backend {
            Backend::Native => None,
            Backend::Xla { runtime, batch_hint } => {
                let spec = runtime
                    .pick_variant(batch_hint, s_pad, l_pad)
                    .ok_or_else(|| anyhow!("no artifact variant for s={s_pad} l={l_pad}"))?
                    .clone();
                let exe = runtime.load(&spec.name)?;
                Some(XlaState { runtime, exe, images: Mutex::new(HashMap::new()) })
            }
        };
        let scratch = Mutex::new(NativeScratch {
            batch: EncodedBatch::default(),
            scratch: native.scratch(),
            lanes: native.lane_scratch(),
        });
        Ok(ErbiumEngine {
            nfa,
            encoder,
            native,
            xla,
            model,
            l_pad,
            s_pad,
            shards: 1,
            lockstep: true,
            scratch,
        })
    }

    /// Split native batches of [`crate::erbium::native::SHARD_MIN_ROWS`]+
    /// rows across `shards` cores. No effect on the XLA path. Composes
    /// with lockstep: shards then split over whole lane groups.
    pub fn with_shards(mut self, shards: usize) -> ErbiumEngine {
        self.shards = shards.max(1);
        self
    }

    /// Configured multi-core split of the native path.
    pub fn shards(&self) -> usize {
        self.shards
    }

    /// Enable or disable the query-parallel lockstep walk on the native
    /// path (on by default). With it off, large batches take the scalar
    /// batch/sharded walk — the PR 3 baseline, kept for A/B measurement.
    pub fn with_lockstep(mut self, lockstep: bool) -> ErbiumEngine {
        self.lockstep = lockstep;
        self
    }

    /// Whether the native path may use the lockstep walk.
    pub fn lockstep(&self) -> bool {
        self.lockstep
    }

    pub fn nfa(&self) -> &PartitionedNfa {
        &self.nfa
    }
    pub fn model(&self) -> &FpgaModel {
        &self.model
    }
    pub fn encoder(&self) -> &QueryEncoder {
        &self.encoder
    }
    pub fn is_xla(&self) -> bool {
        self.xla.is_some()
    }
    /// Kernel batch capacity of the XLA backend (native: unbounded, returns
    /// a conventional 1 Mi).
    pub fn kernel_batch(&self) -> usize {
        self.xla.as_ref().map(|x| x.exe.spec.batch).unwrap_or(1 << 20)
    }

    fn device_image(&self, xla: &XlaState, pi: usize) -> Result<Arc<DeviceImage>> {
        if let Some(img) = xla.images.lock().unwrap().get(&pi) {
            return Ok(img.clone());
        }
        let img = NfaImage::from_compiled(&self.nfa.partitions[pi], self.l_pad, self.s_pad)?;
        let dev = Arc::new(xla.runtime.upload_image(&img)?);
        xla.images.lock().unwrap().insert(pi, dev.clone());
        Ok(dev)
    }

    /// Evaluate a batch of MCT queries, returning one decision per query
    /// (same order). This is the *functional* call — wall-clock time here is
    /// CPU stand-in time, not FPGA time; see [`Self::evaluate_batch_timed`].
    pub fn evaluate_batch(&self, queries: &[MctQuery]) -> Result<Vec<MctDecision>> {
        let mut out = Vec::with_capacity(queries.len());
        self.evaluate_batch_into(queries, &mut out)?;
        Ok(out)
    }

    /// Batch-first entry point: evaluate into a caller-owned buffer
    /// (cleared first), so steady-state engine servers allocate nothing on
    /// the native path — encode and walk both run on reused scratch.
    pub fn evaluate_batch_into(
        &self,
        queries: &[MctQuery],
        out: &mut Vec<MctDecision>,
    ) -> Result<()> {
        out.clear();
        if queries.is_empty() {
            return Ok(());
        }
        match &self.xla {
            None => {
                self.evaluate_native_into(queries, out);
                Ok(())
            }
            Some(x) => {
                *out = self.evaluate_xla(x, queries)?;
                Ok(())
            }
        }
    }

    /// Evaluate and attach the hardware-model timing for the whole batch —
    /// the board holds the full NFA, so one logical invocation covers all
    /// queries regardless of how the stand-in partitions the work.
    pub fn evaluate_batch_timed(
        &self,
        queries: &[MctQuery],
    ) -> Result<(Vec<MctDecision>, BatchTiming)> {
        let out = self.evaluate_batch(queries)?;
        Ok((out, self.model.batch_timing(queries.len())))
    }

    fn evaluate_native_into(&self, queries: &[MctQuery], out: &mut Vec<MctDecision>) {
        let mut g = self.scratch.lock().unwrap();
        let NativeScratch { batch, scratch, lanes } = &mut *g;
        self.encoder.encode_batch_into(queries, batch);
        let n = queries.len();
        if self.lockstep && n >= LOCKSTEP_MIN_ROWS {
            // Query-parallel walk; sharded variant splits over lane groups.
            if NativeEvaluator::sharding_pays(n, self.shards) {
                self.native.evaluate_batch_lockstep_sharded(batch, self.shards, out);
            } else {
                self.native.evaluate_batch_lockstep(batch, lanes, out);
            }
        } else {
            // Scalar batch walk; the sharded call falls back to the
            // engine's warm scratch below the shard floor, so tiny batches
            // never allocate fresh bit-sets.
            self.native.evaluate_batch_sharded(batch, self.shards, scratch, out);
        }
    }

    fn evaluate_xla(&self, xla: &XlaState, queries: &[MctQuery]) -> Result<Vec<MctDecision>> {
        let mut out = vec![MctDecision::no_match(); queries.len()];
        // Group query indices by partition (station partitions + global).
        let mut by_partition: HashMap<usize, Vec<usize>> = HashMap::new();
        for (qi, q) in queries.iter().enumerate() {
            for pi in self.nfa.partitions_for(q.station) {
                by_partition.entry(pi).or_default().push(qi);
            }
        }
        let b = xla.exe.spec.batch;
        let mut enc_buf: Vec<i32> = Vec::new();
        let mut batch: Vec<MctQuery> = Vec::with_capacity(b);
        let mut idxs: Vec<usize> = Vec::with_capacity(b);
        let mut parts: Vec<usize> = by_partition.keys().copied().collect();
        parts.sort_unstable();
        for pi in parts {
            let dev = self.device_image(xla, pi)?;
            let qidx = &by_partition[&pi];
            for chunk in qidx.chunks(b) {
                batch.clear();
                idxs.clear();
                for &qi in chunk {
                    batch.push(queries[qi]);
                    idxs.push(qi);
                }
                // Small partition groups run on the smallest fitting
                // artifact variant — the dense kernel's cost is linear in
                // its static batch, so padding 7 queries to 1 024 rows
                // would dominate the whole call.
                let exe = match xla
                    .runtime
                    .pick_variant(chunk.len(), self.s_pad, self.l_pad)
                {
                    Some(spec) if spec.batch < b => xla.runtime.load(&spec.name)?,
                    _ => xla.exe.clone(),
                };
                let vb = exe.spec.batch;
                self.encoder.encode_batch(&batch, vb, &mut enc_buf);
                let res = exe.execute(&enc_buf, &dev)?;
                for (row, &qi) in idxs.iter().enumerate() {
                    if res.matched[row] <= 0.0 {
                        continue;
                    }
                    let state = res.best[row] as usize;
                    let rid = dev.rule_ids.get(state).copied().unwrap_or(u32::MAX);
                    if rid == u32::MAX {
                        continue;
                    }
                    let w = res.weight[row];
                    let cur = &mut out[qi];
                    let better = !cur.matched()
                        || w > cur.weight
                        || (w == cur.weight && rid < cur.rule_id);
                    if better {
                        *cur = MctDecision {
                            minutes: res.decision[row] as u16,
                            weight: w,
                            rule_id: rid,
                        };
                    }
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::constraint_gen::HardwareConfig;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{evaluate_ruleset, Schema, StandardVersion};
    use crate::workload::random_query;

    #[test]
    fn native_backend_agrees_with_oracle_via_engine() {
        let cfg = GeneratorConfig::small(91, 400);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let eng = ErbiumEngine::new(p, model, Backend::Native, 28, 64).unwrap();
        let mut rng = Rng::new(17);
        let queries: Vec<_> =
            (0..200)
            .map(|_| {
                let st = rng.index(cfg.n_airports) as u32;
                random_query(&mut rng, &w, st)
            })
            .collect();
        let got = eng.evaluate_batch(&queries).unwrap();
        for (q, g) in queries.iter().zip(&got) {
            let want = evaluate_ruleset(&schema, &rs, q);
            assert_eq!(g.rule_id, want.rule_id);
            assert_eq!(g.minutes, want.minutes);
        }
    }

    #[test]
    fn timed_evaluation_reports_model_clock() {
        let cfg = GeneratorConfig::small(93, 100);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v1_onprem(4), stats.depth);
        let eng = ErbiumEngine::new(p, model, Backend::Native, 28, 64).unwrap();
        let mut rng = Rng::new(3);
        let queries: Vec<_> = (0..64).map(|_| random_query(&mut rng, &w, 0)).collect();
        let (out, t) = eng.evaluate_batch_timed(&queries).unwrap();
        assert_eq!(out.len(), 64);
        assert!(t.total_us > 0.0);
    }

    #[test]
    fn sharded_engine_matches_single_core() {
        let cfg = GeneratorConfig::small(97, 300);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let single = ErbiumEngine::new(p.clone(), model, Backend::Native, 28, 64).unwrap();
        let sharded =
            ErbiumEngine::new(p, model, Backend::Native, 28, 64).unwrap().with_shards(4);
        assert_eq!(sharded.shards(), 4);
        let mut rng = Rng::new(29);
        // Large enough to clear the shard floor, with a ragged tail.
        let queries: Vec<_> = (0..301)
            .map(|_| {
                let st = rng.index(cfg.n_airports) as u32;
                random_query(&mut rng, &w, st)
            })
            .collect();
        let a = single.evaluate_batch(&queries).unwrap();
        let b = sharded.evaluate_batch(&queries).unwrap();
        assert_eq!(a.len(), b.len());
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert_eq!(x.rule_id, y.rule_id, "row {i}");
            assert_eq!(x.minutes, y.minutes, "row {i}");
        }
        // Reused engine scratch must not leak state between calls.
        let again = single.evaluate_batch(&queries).unwrap();
        assert_eq!(a.len(), again.len());
        assert!(a.iter().zip(&again).all(|(x, y)| x.rule_id == y.rule_id));
    }

    #[test]
    fn lockstep_engine_matches_scalar_engine() {
        let cfg = GeneratorConfig::small(101, 350);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v2_aws(4), stats.depth);
        let lockstep = ErbiumEngine::new(p.clone(), model, Backend::Native, 28, 64).unwrap();
        assert!(lockstep.lockstep(), "lockstep must be the default");
        let scalar = ErbiumEngine::new(p.clone(), model, Backend::Native, 28, 64)
            .unwrap()
            .with_lockstep(false);
        assert!(!scalar.lockstep());
        let sharded_lockstep =
            ErbiumEngine::new(p, model, Backend::Native, 28, 64).unwrap().with_shards(3);
        let mut rng = Rng::new(31);
        // Batch sizes straddling LOCKSTEP_MIN_ROWS, the shard floor and the
        // lane width, with the usual station mix.
        for n in [1usize, 8, 16, 64, 65, 300] {
            let queries: Vec<_> = (0..n)
                .map(|_| {
                    let st = rng.index(cfg.n_airports) as u32;
                    random_query(&mut rng, &w, st)
                })
                .collect();
            let a = scalar.evaluate_batch(&queries).unwrap();
            let b = lockstep.evaluate_batch(&queries).unwrap();
            let c = sharded_lockstep.evaluate_batch(&queries).unwrap();
            assert_eq!(a.len(), b.len());
            for (i, (x, y)) in a.iter().zip(&b).enumerate() {
                assert_eq!(x.rule_id, y.rule_id, "n={n} row {i}");
                assert_eq!(x.minutes, y.minutes, "n={n} row {i}");
            }
            assert!(a.iter().zip(&c).all(|(x, y)| x.rule_id == y.rule_id), "sharded n={n}");
        }
        // Warm lane scratch must not leak group state across calls.
        let queries: Vec<_> = (0..100)
            .map(|_| {
                let st = rng.index(cfg.n_airports) as u32;
                random_query(&mut rng, &w, st)
            })
            .collect();
        let first = lockstep.evaluate_batch(&queries).unwrap();
        let second = lockstep.evaluate_batch(&queries).unwrap();
        assert_eq!(first, second);
    }

    #[test]
    fn empty_batch_is_fine() {
        let cfg = GeneratorConfig::small(95, 50);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let model = FpgaModel::new(HardwareConfig::v1_onprem(1), stats.depth);
        let eng = ErbiumEngine::new(p, model, Backend::Native, 28, 64).unwrap();
        assert!(eng.evaluate_batch(&[]).unwrap().is_empty());
    }
}
