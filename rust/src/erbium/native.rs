//! Native (sparse) NFA evaluator: the functional simulator of the hardware
//! kernel.
//!
//! Semantically identical to the dense XLA path (`python/compile/model.py` /
//! [`crate::nfa::memory::NfaImage::evaluate_scalar`]) but works on the sparse
//! [`CompiledNfa`] with bit-set active states, which makes it fast enough to
//! replay the full production trace (Fig 12) and to serve as the oracle in
//! cross-layer tests.

use crate::nfa::model::{CompiledNfa, PartitionedNfa};
use crate::rules::types::MctDecision;

/// Dynamically-sized bit set over NFA states (width decided per
/// partition, so the CPU-side trie is not constrained by the hardware's
/// `S` bound).
#[derive(Clone)]
struct BitSet {
    w: Vec<u64>,
}

impl BitSet {
    #[inline]
    fn empty(width: usize) -> Self {
        BitSet { w: vec![0; width.div_ceil(64).max(1)] }
    }
    #[inline]
    fn clear(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0);
    }
    #[inline]
    fn set(&mut self, i: u32) {
        self.w[(i >> 6) as usize] |= 1u64 << (i & 63);
    }
    #[inline]
    #[cfg(test)]
    fn get(&self, i: u32) -> bool {
        self.w[(i >> 6) as usize] & (1u64 << (i & 63)) != 0
    }
    #[inline]
    #[cfg(test)]
    fn is_empty(&self) -> bool {
        self.w.iter().all(|&x| x == 0)
    }
    /// Iterate set bits.
    fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.w.iter().enumerate().flat_map(|(bi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros();
                    w &= w - 1;
                    Some((bi as u32) << 6 | b)
                }
            })
        })
    }
}

/// One state's outgoing edges, indexed for O(log E) matching: exact labels
/// sorted for binary search, ranges and wildcards scanned separately (both
/// are short lists in rule tries).
#[derive(Debug, Clone, Default)]
struct PreparedState {
    /// Sorted by value; per-(state, label) uniqueness of the trie builder
    /// guarantees at most one hit.
    exact: Vec<(u32, u32)>,
    ranges: Vec<(u32, u32, u32)>, // (lo, hi, to)
    anys: Vec<u32>,
}

/// A partition preprocessed for fast sparse walking.
#[derive(Debug, Clone)]
struct PreparedPartition {
    /// `[level][state]`.
    levels: Vec<Vec<PreparedState>>,
}

impl PreparedPartition {
    fn build(nfa: &CompiledNfa) -> PreparedPartition {
        let levels = nfa
            .states
            .iter()
            .map(|states| {
                states
                    .iter()
                    .map(|edges| {
                        let mut p = PreparedState::default();
                        for e in edges {
                            match e.label {
                                super::super::nfa::model::EdgeLabel::Exact(v) => {
                                    p.exact.push((v, e.to))
                                }
                                super::super::nfa::model::EdgeLabel::Range(lo, hi) => {
                                    p.ranges.push((lo, hi, e.to))
                                }
                                super::super::nfa::model::EdgeLabel::Any => p.anys.push(e.to),
                            }
                        }
                        p.exact.sort_unstable();
                        p
                    })
                    .collect()
            })
            .collect();
        PreparedPartition { levels }
    }
}

/// Sparse evaluator over a partitioned NFA.
#[derive(Debug, Clone)]
pub struct NativeEvaluator {
    nfa: PartitionedNfa,
    prepared: Vec<PreparedPartition>,
}

impl NativeEvaluator {
    pub fn new(nfa: PartitionedNfa) -> Self {
        let prepared = nfa.partitions.iter().map(PreparedPartition::build).collect();
        NativeEvaluator { nfa, prepared }
    }

    pub fn nfa(&self) -> &PartitionedNfa {
        &self.nfa
    }

    /// Evaluate one *encoded* query (level-ordered values, length ≥ depth)
    /// against one partition. Returns the best accept, if any.
    fn eval_partition(
        nfa: &CompiledNfa,
        prep: &PreparedPartition,
        q: &[i32],
    ) -> Option<(u32, f32, u16)> {
        let depth = nfa.depth();
        debug_assert!(q.len() >= depth);
        let width = nfa.max_width();
        let mut active = BitSet::empty(width);
        active.set(0);
        let mut next = BitSet::empty(width);
        for (lv, states) in prep.levels.iter().enumerate() {
            // qv comes from the encoder and is always a small non-negative
            // domain value, so the u32 cast below is lossless.
            let qv = q[lv] as u32;
            next.clear();
            let mut any_hit = false;
            for s in active.iter() {
                let ps = &states[s as usize];
                if let Ok(i) = ps.exact.binary_search_by_key(&qv, |&(v, _)| v) {
                    next.set(ps.exact[i].1);
                    any_hit = true;
                }
                for &(lo, hi, to) in &ps.ranges {
                    if qv >= lo && qv <= hi {
                        next.set(to);
                        any_hit = true;
                    }
                }
                for &to in &ps.anys {
                    next.set(to);
                    any_hit = true;
                }
            }
            if !any_hit {
                return None;
            }
            std::mem::swap(&mut active, &mut next);
        }
        // `active` now ranges over accepting states.
        let mut best: Option<(u32, f32, u16)> = None;
        for s in active.iter() {
            let a = &nfa.accepts[s as usize];
            let better = match best {
                None => true,
                // Strict > keeps the lowest accept index (= lowest rule id,
                // parser builds in id order) on ties — same rule as the
                // dense argmax.
                Some((_, w, _)) => a.weight > w,
            };
            if better {
                best = Some((a.rule_id, a.weight, a.decision_min));
            }
        }
        best
    }

    /// Evaluate one encoded query routed to `station`: consult the station's
    /// partitions plus the global ones and keep the most precise match.
    pub fn evaluate_encoded(&self, station: u32, q: &[i32]) -> MctDecision {
        let mut best = MctDecision::no_match();
        for pi in self.nfa.partitions_for(station) {
            if let Some((rid, w, min)) =
                Self::eval_partition(&self.nfa.partitions[pi], &self.prepared[pi], q)
            {
                let better = !best.matched()
                    || w > best.weight
                    || (w == best.weight && rid < best.rule_id);
                if better {
                    best = MctDecision { minutes: min, weight: w, rule_id: rid };
                }
            }
        }
        best
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::QueryEncoder;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{evaluate_ruleset, Schema, StandardVersion};
    use crate::workload::random_query;

    #[test]
    fn bitset_roundtrip() {
        let mut b = BitSet::empty(256);
        assert!(b.is_empty());
        for i in [0u32, 63, 64, 130, 255] {
            b.set(i);
        }
        assert!(b.get(64) && b.get(255) && !b.get(1));
        let got: Vec<u32> = b.iter().collect();
        assert_eq!(got, vec![0, 63, 64, 130, 255]);
    }

    /// The decisive correctness test: native NFA evaluation must agree with
    /// the semantic oracle (`evaluate_ruleset`) on random fleets of queries
    /// for both standard versions.
    #[test]
    fn native_agrees_with_semantic_oracle() {
        for (seed, version) in
            [(71u64, StandardVersion::V1), (73, StandardVersion::V2)]
        {
            let cfg = GeneratorConfig::small(seed, 600);
            let w = generate_world(&cfg);
            let schema = Schema::for_version(version);
            let rs = generate_rule_set(&cfg, &w, version);
            let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let enc = QueryEncoder::new(&p.plan, p.plan.len());
            let eval = NativeEvaluator::new(p);
            let mut rng = Rng::new(seed ^ 0xFF);
            let mut matched = 0;
            for _ in 0..400 {
                let station = rng.index(cfg.n_airports) as u32;
                let q = random_query(&mut rng, &w, station);
                let want = evaluate_ruleset(&schema, &rs, &q);
                let got = eval.evaluate_encoded(station, &enc.encode(&q));
                assert_eq!(got.rule_id, want.rule_id, "{version:?} q={q:?}");
                assert_eq!(got.minutes, want.minutes);
                if got.matched() {
                    matched += 1;
                }
            }
            assert!(matched > 50, "{version:?}: too few matches ({matched}) to be meaningful");
        }
    }

    #[test]
    fn unknown_station_falls_back_to_global_rules() {
        let cfg = GeneratorConfig::small(79, 300);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let eval = NativeEvaluator::new(p);
        // A station beyond the generated world: only wildcard-station rules
        // could match; the evaluator must not panic and must agree with the
        // oracle.
        let q = crate::workload::query_for_station(&w, 10_000, 1);
        let want = evaluate_ruleset(&schema, &rs, &q);
        let got = eval.evaluate_encoded(10_000, &enc.encode(&q));
        assert_eq!(got.rule_id, want.rule_id);
    }
}
