//! Native (sparse) NFA evaluator: the functional simulator of the hardware
//! kernel.
//!
//! Semantically identical to the dense XLA path (`python/compile/model.py` /
//! [`crate::nfa::memory::NfaImage::evaluate_scalar`]) but works on the sparse
//! compiled NFA with bit-set active states, which makes it fast enough to
//! replay the full production trace (Fig 12) and to serve as the oracle in
//! cross-layer tests.
//!
//! This module is the CPU *feeder* hot path of the §6.1 analysis: the
//! accelerator starves behind a slow software matcher, so every per-query
//! allocation here directly erodes the fleet-level numbers. The layout is
//! therefore batch-first and allocation-free (DESIGN.md §Hot path):
//!
//! * each partition is flattened into a contiguous CSR-style arena
//!   ([`CsrPartition`]) — per-level state offsets plus one packed edge
//!   array each for exact / range / wildcard edges, exact edges
//!   binary-searchable in place — replacing the pointer-chasing
//!   `Vec<Vec<PreparedState>>` of the original evaluator;
//! * scratch bit-sets live in a caller-owned [`EvalScratch`], reused
//!   across a whole [`EncodedBatch`] ([`NativeEvaluator::evaluate_batch`])
//!   instead of being allocated twice per query;
//! * large batches optionally split across cores
//!   ([`NativeEvaluator::evaluate_batch_sharded`]): the evaluator is
//!   immutable after construction, so shards share it without locks.

use crate::bits::BitSet;
use crate::encoder::EncodedBatch;
use crate::nfa::model::{CompiledNfa, EdgeLabel, PartitionedNfa};
use crate::rules::types::MctDecision;

/// A partition flattened into a contiguous CSR-style arena.
///
/// States of all levels are numbered consecutively (`level_base[lv] + s`),
/// and each packed edge array is indexed by a per-state offset table of
/// length `n_states + 1` — the classic CSR layout. A state's exact edges
/// are sorted by value so the walker binary-searches the packed slice in
/// place; ranges and wildcards are short lists in rule tries and are
/// scanned.
#[derive(Debug, Clone)]
struct CsrPartition {
    /// First flattened-state index of each level; `len = depth + 1`.
    level_base: Vec<u32>,
    /// Per flattened state: offsets into the packed arrays
    /// (`len = n_states + 1` each).
    exact_off: Vec<u32>,
    range_off: Vec<u32>,
    any_off: Vec<u32>,
    /// Packed exact edges, per state sorted by value (parallel arrays so
    /// the binary search touches only the value lane).
    exact_vals: Vec<u32>,
    exact_tos: Vec<u32>,
    /// Packed range edges `(lo, hi, to)`.
    ranges: Vec<(u32, u32, u32)>,
    /// Packed wildcard targets.
    any_tos: Vec<u32>,
    /// Bit-set words this partition's walk touches
    /// (`words_for(max_width)`), so the shared scratch clears only what
    /// this partition can dirty.
    words: usize,
}

impl CsrPartition {
    fn build(nfa: &CompiledNfa) -> CsrPartition {
        let n_states: usize = nfa.states.iter().map(Vec::len).sum();
        let mut c = CsrPartition {
            level_base: Vec::with_capacity(nfa.states.len() + 1),
            exact_off: Vec::with_capacity(n_states + 1),
            range_off: Vec::with_capacity(n_states + 1),
            any_off: Vec::with_capacity(n_states + 1),
            exact_vals: Vec::new(),
            exact_tos: Vec::new(),
            ranges: Vec::new(),
            any_tos: Vec::new(),
            words: BitSet::words_for(nfa.max_width()),
        };
        c.exact_off.push(0);
        c.range_off.push(0);
        c.any_off.push(0);
        let mut base = 0u32;
        // Per-state staging buffer for the sort; reused across states.
        let mut exact: Vec<(u32, u32)> = Vec::new();
        for states in &nfa.states {
            c.level_base.push(base);
            base += states.len() as u32;
            for edges in states {
                exact.clear();
                for e in edges {
                    match e.label {
                        EdgeLabel::Exact(v) => exact.push((v, e.to)),
                        EdgeLabel::Range(lo, hi) => c.ranges.push((lo, hi, e.to)),
                        EdgeLabel::Any => c.any_tos.push(e.to),
                    }
                }
                // Per-(state, label) uniqueness of the trie builder
                // guarantees at most one hit per sorted slice.
                exact.sort_unstable();
                for &(v, to) in &exact {
                    c.exact_vals.push(v);
                    c.exact_tos.push(to);
                }
                c.exact_off.push(c.exact_vals.len() as u32);
                c.range_off.push(c.ranges.len() as u32);
                c.any_off.push(c.any_tos.len() as u32);
            }
        }
        c.level_base.push(base);
        c
    }
}

/// Reusable per-thread scratch state of the sparse walk: the two
/// active-state bit-sets, sized once to the evaluator's widest level and
/// reused across every query of a batch (the whole point — the original
/// evaluator allocated both per query).
#[derive(Debug, Clone)]
pub struct EvalScratch {
    active: BitSet,
    next: BitSet,
    /// Words of the sets a previous walk may have dirtied: narrow
    /// partitions only pay to clear what was actually used, not the full
    /// max-width allocation.
    dirty_words: usize,
}

impl EvalScratch {
    /// Scratch able to walk partitions up to `width` states per level.
    pub fn with_width(width: usize) -> EvalScratch {
        EvalScratch {
            active: BitSet::empty(width),
            next: BitSet::empty(width),
            dirty_words: 0,
        }
    }
}

/// Sparse evaluator over a partitioned NFA.
#[derive(Debug, Clone)]
pub struct NativeEvaluator {
    nfa: PartitionedNfa,
    csr: Vec<CsrPartition>,
    /// Widest level across all partitions (scratch sizing).
    max_width: usize,
}

/// Below this many rows a sharded call falls back to the single-core walk:
/// thread spawn/join costs more than the evaluation itself.
pub const SHARD_MIN_ROWS: usize = 64;

impl NativeEvaluator {
    /// Whether a sharded walk pays for `rows` over `shards` cores — below
    /// the floor, thread spawn/join costs more than the evaluation.
    /// [`Self::evaluate_batch_sharded`] applies this internally; callers
    /// holding warm scratch (the engine) check it first so the fallback
    /// runs on their scratch instead of allocating fresh sets.
    pub fn sharding_pays(rows: usize, shards: usize) -> bool {
        shards > 1 && rows >= SHARD_MIN_ROWS.max(2 * shards)
    }

    pub fn new(nfa: PartitionedNfa) -> Self {
        let csr = nfa.partitions.iter().map(CsrPartition::build).collect();
        let max_width =
            nfa.partitions.iter().map(CompiledNfa::max_width).max().unwrap_or(0);
        NativeEvaluator { nfa, csr, max_width }
    }

    pub fn nfa(&self) -> &PartitionedNfa {
        &self.nfa
    }

    /// Fresh scratch sized for this evaluator. Callers keep one per thread
    /// and pass it to every batch (DESIGN.md §Hot path batch contract).
    pub fn scratch(&self) -> EvalScratch {
        EvalScratch::with_width(self.max_width)
    }

    /// Evaluate one *encoded* query (level-ordered values, length ≥ depth)
    /// against one partition. Returns the best accept, if any.
    fn eval_partition(
        nfa: &CompiledNfa,
        csr: &CsrPartition,
        q: &[i32],
        scratch: &mut EvalScratch,
    ) -> Option<(u32, f32, u16)> {
        let depth = nfa.depth();
        debug_assert!(q.len() >= depth);
        // Scrub whatever the previous walk dirtied, then only this
        // partition's span for the rest of the walk.
        let words = csr.words;
        let scrub = words.max(scratch.dirty_words);
        scratch.active.clear_first_words(scrub);
        scratch.next.clear_first_words(scrub);
        scratch.dirty_words = words;
        let EvalScratch { active, next, .. } = scratch;
        active.set(0);
        for lv in 0..depth {
            // qv comes from the encoder and is always a small non-negative
            // domain value, so the u32 cast below is lossless.
            let qv = q[lv] as u32;
            next.clear_first_words(words);
            let mut any_hit = false;
            let base = csr.level_base[lv];
            for s in active.iter() {
                let g = (base + s) as usize;
                let (lo, hi) = (csr.exact_off[g] as usize, csr.exact_off[g + 1] as usize);
                if let Ok(i) = csr.exact_vals[lo..hi].binary_search(&qv) {
                    next.set(csr.exact_tos[lo + i]);
                    any_hit = true;
                }
                for &(rlo, rhi, to) in
                    &csr.ranges[csr.range_off[g] as usize..csr.range_off[g + 1] as usize]
                {
                    if qv >= rlo && qv <= rhi {
                        next.set(to);
                        any_hit = true;
                    }
                }
                for &to in
                    &csr.any_tos[csr.any_off[g] as usize..csr.any_off[g + 1] as usize]
                {
                    next.set(to);
                    any_hit = true;
                }
            }
            if !any_hit {
                return None;
            }
            std::mem::swap(active, next);
        }
        // `active` now ranges over accepting states.
        let mut best: Option<(u32, f32, u16)> = None;
        for s in active.iter() {
            let a = &nfa.accepts[s as usize];
            let better = match best {
                None => true,
                // Strict > keeps the lowest accept index (= lowest rule id,
                // parser builds in id order) on ties — same rule as the
                // dense argmax.
                Some((_, w, _)) => a.weight > w,
            };
            if better {
                best = Some((a.rule_id, a.weight, a.decision_min));
            }
        }
        best
    }

    /// Evaluate one encoded query routed to `station` using caller-owned
    /// scratch: consult the station's partitions plus the global ones and
    /// keep the most precise match. Allocation-free.
    pub fn evaluate_encoded_with(
        &self,
        station: u32,
        q: &[i32],
        scratch: &mut EvalScratch,
    ) -> MctDecision {
        let mut best = MctDecision::no_match();
        for pi in self.nfa.partitions_for(station) {
            if let Some((rid, w, min)) =
                Self::eval_partition(&self.nfa.partitions[pi], &self.csr[pi], q, scratch)
            {
                let better = !best.matched()
                    || w > best.weight
                    || (w == best.weight && rid < best.rule_id);
                if better {
                    best = MctDecision { minutes: min, weight: w, rule_id: rid };
                }
            }
        }
        best
    }

    /// Scalar convenience path: allocates fresh scratch per call. Kept as
    /// the pre-batch baseline the perf harness measures against; hot
    /// callers use [`Self::evaluate_encoded_with`] or
    /// [`Self::evaluate_batch`].
    pub fn evaluate_encoded(&self, station: u32, q: &[i32]) -> MctDecision {
        let mut scratch = self.scratch();
        self.evaluate_encoded_with(station, q, &mut scratch)
    }

    /// Evaluate a whole encoded batch, reusing `scratch` across every row
    /// and appending one decision per row into `out` (cleared first). This
    /// is the feeder hot path: no allocation once `out`'s capacity is warm.
    pub fn evaluate_batch(
        &self,
        batch: &EncodedBatch,
        scratch: &mut EvalScratch,
        out: &mut Vec<MctDecision>,
    ) {
        out.clear();
        out.reserve(batch.len());
        for i in 0..batch.len() {
            out.push(self.evaluate_encoded_with(batch.station(i), batch.row(i), scratch));
        }
    }

    /// Split a large batch across `shards` cores (scoped threads; the
    /// evaluator is immutable so shards share it without locks), each shard
    /// walking with its own scratch. Falls back to the single-core walk for
    /// small batches or `shards <= 1`. Output order matches the batch.
    pub fn evaluate_batch_sharded(
        &self,
        batch: &EncodedBatch,
        shards: usize,
        out: &mut Vec<MctDecision>,
    ) {
        let n = batch.len();
        if !Self::sharding_pays(n, shards) {
            let mut scratch = self.scratch();
            self.evaluate_batch(batch, &mut scratch, out);
            return;
        }
        out.clear();
        out.resize(n, MctDecision::no_match());
        let rows_per_shard = n.div_ceil(shards);
        std::thread::scope(|scope| {
            for (si, chunk) in out.chunks_mut(rows_per_shard).enumerate() {
                let start = si * rows_per_shard;
                scope.spawn(move || {
                    let mut scratch = self.scratch();
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        let i = start + j;
                        *slot = self.evaluate_encoded_with(
                            batch.station(i),
                            batch.row(i),
                            &mut scratch,
                        );
                    }
                });
            }
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::QueryEncoder;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{evaluate_ruleset, Schema, StandardVersion};
    use crate::workload::random_query;

    /// The decisive correctness test: native NFA evaluation must agree with
    /// the semantic oracle (`evaluate_ruleset`) on random fleets of queries
    /// for both standard versions — through the scalar, the batch and the
    /// sharded entry points.
    #[test]
    fn native_agrees_with_semantic_oracle() {
        for (seed, version) in
            [(71u64, StandardVersion::V1), (73, StandardVersion::V2)]
        {
            let cfg = GeneratorConfig::small(seed, 600);
            let w = generate_world(&cfg);
            let schema = Schema::for_version(version);
            let rs = generate_rule_set(&cfg, &w, version);
            let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let enc = QueryEncoder::new(&p.plan, p.plan.len());
            let eval = NativeEvaluator::new(p);
            let mut rng = Rng::new(seed ^ 0xFF);
            let queries: Vec<_> = (0..400)
                .map(|_| {
                    let station = rng.index(cfg.n_airports) as u32;
                    random_query(&mut rng, &w, station)
                })
                .collect();
            let mut batch = EncodedBatch::default();
            enc.encode_batch_into(&queries, &mut batch);
            let mut scratch = eval.scratch();
            let mut got_batch = Vec::new();
            eval.evaluate_batch(&batch, &mut scratch, &mut got_batch);
            let mut got_sharded = Vec::new();
            eval.evaluate_batch_sharded(&batch, 3, &mut got_sharded);
            let mut matched = 0;
            for (i, q) in queries.iter().enumerate() {
                let want = evaluate_ruleset(&schema, &rs, q);
                let got = eval.evaluate_encoded(q.station, &enc.encode(q));
                assert_eq!(got.rule_id, want.rule_id, "{version:?} q={q:?}");
                assert_eq!(got.minutes, want.minutes);
                assert_eq!(got_batch[i], got, "batch row {i} diverges");
                assert_eq!(got_sharded[i], got, "sharded row {i} diverges");
                if got.matched() {
                    matched += 1;
                }
            }
            assert!(matched > 50, "{version:?}: too few matches ({matched}) to be meaningful");
        }
    }

    #[test]
    fn unknown_station_falls_back_to_global_rules() {
        let cfg = GeneratorConfig::small(79, 300);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let eval = NativeEvaluator::new(p);
        // A station beyond the generated world: only wildcard-station rules
        // could match; the evaluator must not panic and must agree with the
        // oracle.
        let q = crate::workload::query_for_station(&w, 10_000, 1);
        let want = evaluate_ruleset(&schema, &rs, &q);
        let got = eval.evaluate_encoded(10_000, &enc.encode(&q));
        assert_eq!(got.rule_id, want.rule_id);
    }

    #[test]
    fn csr_arena_matches_nested_edge_lists() {
        // The flattened arena must index exactly the edges of the compiled
        // NFA: per state, the packed slices reproduce the edge lists.
        let cfg = GeneratorConfig::small(83, 250);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        for nfa in &p.partitions {
            let csr = CsrPartition::build(nfa);
            assert_eq!(csr.level_base.len(), nfa.states.len() + 1);
            for (lv, states) in nfa.states.iter().enumerate() {
                for (s, edges) in states.iter().enumerate() {
                    let g = (csr.level_base[lv] as usize) + s;
                    let exact: Vec<(u32, u32)> = {
                        let (lo, hi) =
                            (csr.exact_off[g] as usize, csr.exact_off[g + 1] as usize);
                        csr.exact_vals[lo..hi]
                            .iter()
                            .copied()
                            .zip(csr.exact_tos[lo..hi].iter().copied())
                            .collect()
                    };
                    let mut want_exact: Vec<(u32, u32)> = edges
                        .iter()
                        .filter_map(|e| match e.label {
                            EdgeLabel::Exact(v) => Some((v, e.to)),
                            _ => None,
                        })
                        .collect();
                    want_exact.sort_unstable();
                    assert_eq!(exact, want_exact);
                    assert!(
                        exact.windows(2).all(|p| p[0].0 < p[1].0),
                        "exact values must be strictly sorted for binary search"
                    );
                    let n_ranges = (csr.range_off[g + 1] - csr.range_off[g]) as usize;
                    let n_any = (csr.any_off[g + 1] - csr.any_off[g]) as usize;
                    let want_ranges = edges
                        .iter()
                        .filter(|e| matches!(e.label, EdgeLabel::Range(..)))
                        .count();
                    let want_any = edges
                        .iter()
                        .filter(|e| matches!(e.label, EdgeLabel::Any))
                        .count();
                    assert_eq!(n_ranges, want_ranges);
                    assert_eq!(n_any, want_any);
                }
            }
        }
    }

    #[test]
    fn empty_batch_produces_empty_output() {
        let cfg = GeneratorConfig::small(89, 100);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let eval = NativeEvaluator::new(p);
        let batch = EncodedBatch::default();
        let mut out = vec![MctDecision::no_match(); 3]; // stale content must be cleared
        eval.evaluate_batch(&batch, &mut eval.scratch(), &mut out);
        assert!(out.is_empty());
        out.push(MctDecision::no_match());
        eval.evaluate_batch_sharded(&batch, 4, &mut out);
        assert!(out.is_empty());
    }
}
