//! Native (sparse) NFA evaluator: the functional simulator of the hardware
//! kernel.
//!
//! Semantically identical to the dense XLA path (`python/compile/model.py` /
//! [`crate::nfa::memory::NfaImage::evaluate_scalar`]) but works on the sparse
//! compiled NFA with bit-set active states, which makes it fast enough to
//! replay the full production trace (Fig 12) and to serve as the oracle in
//! cross-layer tests.
//!
//! This module is the CPU *feeder* hot path of the §6.1 analysis: the
//! accelerator starves behind a slow software matcher, so every per-query
//! allocation here directly erodes the fleet-level numbers. The layout is
//! therefore batch-first and allocation-free (DESIGN.md §Hot path):
//!
//! * each partition is flattened into a contiguous CSR-style arena
//!   ([`CsrPartition`]) — per-level state offsets plus one packed edge
//!   array each for exact / range / wildcard edges, exact edges
//!   binary-searchable in place — replacing the pointer-chasing
//!   `Vec<Vec<PreparedState>>` of the original evaluator;
//! * scratch bit-sets live in a caller-owned [`EvalScratch`], reused
//!   across a whole [`EncodedBatch`] ([`NativeEvaluator::evaluate_batch`])
//!   instead of being allocated twice per query;
//! * large batches optionally split across cores
//!   ([`NativeEvaluator::evaluate_batch_sharded`]): the evaluator is
//!   immutable after construction, so shards share it without locks;
//! * batches are evaluated **query-parallel in lockstep**
//!   ([`NativeEvaluator::evaluate_batch_lockstep`]): rows are bucketed by
//!   station into lane groups of up to [`LANE_WIDTH`] queries and the CSR
//!   arena is walked level-by-level with *transposed* state — one `u64`
//!   lane mask per NFA state ([`LaneScratch`]) instead of one bit-set per
//!   query — so a single AND/OR advances every matching query at once.
//!   Exact edges resolve against a per-level value → lane-mask prefix
//!   table built once per group; range edges take two probes into the
//!   same table (the prefix masks make the span mask one XOR); wildcard
//!   edges are a single word OR. Groups below [`LANE_MIN_OCCUPANCY`]
//!   lanes fall back to the scalar walk, and results are written back
//!   through the bucketing permutation so callers always see batch order.

use crate::bits::BitSet;
use crate::encoder::EncodedBatch;
use crate::nfa::model::{CompiledNfa, EdgeLabel, PartitionedNfa};
use crate::rules::types::MctDecision;

/// A partition flattened into a contiguous CSR-style arena.
///
/// States of all levels are numbered consecutively (`level_base[lv] + s`),
/// and each packed edge array is indexed by a per-state offset table of
/// length `n_states + 1` — the classic CSR layout. A state's exact edges
/// are sorted by value so the walker binary-searches the packed slice in
/// place; ranges and wildcards are short lists in rule tries and are
/// scanned.
#[derive(Debug, Clone)]
struct CsrPartition {
    /// First flattened-state index of each level; `len = depth + 1`.
    level_base: Vec<u32>,
    /// Per flattened state: offsets into the packed arrays
    /// (`len = n_states + 1` each).
    exact_off: Vec<u32>,
    range_off: Vec<u32>,
    any_off: Vec<u32>,
    /// Packed exact edges, per state sorted by value (parallel arrays so
    /// the binary search touches only the value lane).
    exact_vals: Vec<u32>,
    exact_tos: Vec<u32>,
    /// Packed range edges `(lo, hi, to)`.
    ranges: Vec<(u32, u32, u32)>,
    /// Packed wildcard targets.
    any_tos: Vec<u32>,
    /// Bit-set words this partition's walk touches
    /// (`words_for(max_width)`), so the shared scratch clears only what
    /// this partition can dirty.
    words: usize,
    /// Widest level of this partition in *states*. The transposed lockstep
    /// walk keeps one lane-mask word per state, so this is also the number
    /// of [`LaneScratch`] words the partition can dirty.
    width: usize,
}

impl CsrPartition {
    fn build(nfa: &CompiledNfa) -> CsrPartition {
        let n_states: usize = nfa.states.iter().map(Vec::len).sum();
        let mut c = CsrPartition {
            level_base: Vec::with_capacity(nfa.states.len() + 1),
            exact_off: Vec::with_capacity(n_states + 1),
            range_off: Vec::with_capacity(n_states + 1),
            any_off: Vec::with_capacity(n_states + 1),
            exact_vals: Vec::new(),
            exact_tos: Vec::new(),
            ranges: Vec::new(),
            any_tos: Vec::new(),
            words: BitSet::words_for(nfa.max_width()),
            width: nfa.max_width(),
        };
        c.exact_off.push(0);
        c.range_off.push(0);
        c.any_off.push(0);
        let mut base = 0u32;
        // Per-state staging buffer for the sort; reused across states.
        let mut exact: Vec<(u32, u32)> = Vec::new();
        for states in &nfa.states {
            c.level_base.push(base);
            base += states.len() as u32;
            for edges in states {
                exact.clear();
                for e in edges {
                    match e.label {
                        EdgeLabel::Exact(v) => exact.push((v, e.to)),
                        EdgeLabel::Range(lo, hi) => c.ranges.push((lo, hi, e.to)),
                        EdgeLabel::Any => c.any_tos.push(e.to),
                    }
                }
                // Per-(state, label) uniqueness of the trie builder
                // guarantees at most one hit per sorted slice.
                exact.sort_unstable();
                for &(v, to) in &exact {
                    c.exact_vals.push(v);
                    c.exact_tos.push(to);
                }
                c.exact_off.push(c.exact_vals.len() as u32);
                c.range_off.push(c.ranges.len() as u32);
                c.any_off.push(c.any_tos.len() as u32);
            }
        }
        c.level_base.push(base);
        c
    }
}

/// Reusable per-thread scratch state of the sparse walk: the two
/// active-state bit-sets, sized once to the evaluator's widest level and
/// reused across every query of a batch (the whole point — the original
/// evaluator allocated both per query).
#[derive(Debug, Clone)]
pub struct EvalScratch {
    active: BitSet,
    next: BitSet,
    /// Words of the sets a previous walk may have dirtied: narrow
    /// partitions only pay to clear what was actually used, not the full
    /// max-width allocation.
    dirty_words: usize,
}

impl EvalScratch {
    /// Scratch able to walk partitions up to `width` states per level.
    pub fn with_width(width: usize) -> EvalScratch {
        EvalScratch {
            active: BitSet::empty(width),
            next: BitSet::empty(width),
            dirty_words: 0,
        }
    }
}

/// Lanes per lockstep group: one query per bit of a `u64` lane mask.
pub const LANE_WIDTH: usize = 64;

/// Lane groups narrower than this walk the scalar path instead: building
/// the per-level value tables costs more than it saves when only a handful
/// of lanes share them.
pub const LANE_MIN_OCCUPANCY: usize = 8;

/// Below this many rows the engine does not try lockstep at all — the
/// station bucketing sort alone outweighs any lane sharing.
pub const LOCKSTEP_MIN_ROWS: usize = 16;

/// Hint the CPU to pull `p`'s cache line while the current level is still
/// being scanned. No-op off x86-64.
#[inline(always)]
fn prefetch<T>(p: *const T) {
    #[cfg(target_arch = "x86_64")]
    // SAFETY: _mm_prefetch is a pure hint — it never dereferences the
    // pointer and is architecturally valid for any address.
    unsafe {
        use std::arch::x86_64::{_mm_prefetch, _MM_HINT_T0};
        _mm_prefetch::<_MM_HINT_T0>(p as *const i8);
    }
    #[cfg(not(target_arch = "x86_64"))]
    let _ = p;
}

/// Per-level value → lane-mask table of one lockstep lane group.
///
/// `vals` holds the sorted distinct encoded values the group's lanes carry
/// at this level; `cum` holds *prefix* ORs of their lane masks
/// (`cum.len() == vals.len() + 1`, `cum[0] == 0`). Each lane contributes
/// exactly one value per level, so the per-value masks are disjoint and
/// `cum[j] ^ cum[i]` is the union of the masks of `vals[i..j]` — which
/// makes a range edge two binary probes plus one XOR, and an exact edge
/// one probe plus one XOR.
#[derive(Debug, Clone, Default)]
struct LevelTable {
    vals: Vec<u32>,
    cum: Vec<u64>,
}

impl LevelTable {
    /// Lane mask of one exact value, if any lane carries it.
    #[inline]
    fn mask_of(&self, v: u32) -> u64 {
        match self.vals.binary_search(&v) {
            Ok(i) => self.cum[i + 1] ^ self.cum[i],
            Err(_) => 0,
        }
    }

    /// Union of the lane masks of every value in `lo..=hi`.
    #[inline]
    fn mask_of_range(&self, lo: u32, hi: u32) -> u64 {
        let i = self.vals.partition_point(|&v| v < lo);
        let j = self.vals.partition_point(|&v| v <= hi);
        self.cum[j] ^ self.cum[i]
    }
}

/// Occupancy accounting of one lockstep batch evaluation: how many rows
/// actually ran transposed vs fell back to the scalar walk, and how full
/// the lane groups were. The perf harness reports these so a station skew
/// that defeats bucketing is visible rather than silent.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct LockstepStats {
    /// Lane groups walked transposed.
    pub groups: usize,
    /// Rows evaluated through those groups.
    pub lockstep_rows: usize,
    /// Rows that walked the scalar path (under-occupied trailing chunks).
    pub fallback_rows: usize,
    /// Distinct stations seen in the batch.
    pub stations: usize,
}

impl LockstepStats {
    /// Total rows accounted for.
    #[inline]
    pub fn rows(&self) -> usize {
        self.lockstep_rows + self.fallback_rows
    }

    /// Mean live lanes per transposed group (0 when nothing ran lockstep).
    pub fn mean_occupancy(&self) -> f64 {
        if self.groups == 0 {
            0.0
        } else {
            self.lockstep_rows as f64 / self.groups as f64
        }
    }

    /// Share of rows that fell back to the scalar walk.
    pub fn fallback_fraction(&self) -> f64 {
        let rows = self.rows();
        if rows == 0 {
            0.0
        } else {
            self.fallback_rows as f64 / rows as f64
        }
    }

    /// Fold another shard's accounting into this one.
    pub fn absorb(&mut self, other: LockstepStats) {
        self.groups += other.groups;
        self.lockstep_rows += other.lockstep_rows;
        self.fallback_rows += other.fallback_rows;
        self.stations += other.stations;
    }
}

/// Reusable scratch of the transposed lockstep walk
/// ([`NativeEvaluator::evaluate_batch_lockstep`]).
///
/// The two bit-sets are the *transposed* counterpart of
/// [`EvalScratch`]: instead of one bit per NFA state for one query, word
/// `s` holds the 64-lane mask of queries whose walk is live in state `s`.
/// Everything else is reusable buffer space — the per-level value tables,
/// the pair-staging buffer that builds them, the station-bucketing
/// permutation, and an embedded scalar [`EvalScratch`] for under-occupied
/// groups — so a warm caller evaluates whole batches allocation-free.
#[derive(Debug, Clone)]
pub struct LaneScratch {
    /// Transposed active set: word `s` = lane mask live in state `s`.
    active: BitSet,
    /// Transposed next-level set (swapped with `active` per level).
    next: BitSet,
    /// Lane-mask words a previous walk may have dirtied.
    dirty: usize,
    /// Per-level value → lane-mask tables of the current lane group.
    levels: Vec<LevelTable>,
    /// Staging buffer for table building: `(value, lane)` pairs.
    pairs: Vec<(u32, u32)>,
    /// Station-bucketing permutation (row indices sorted by station).
    order: Vec<u32>,
    /// Scalar scratch for the under-occupancy fallback path.
    scalar: EvalScratch,
}

impl LaneScratch {
    /// Scratch able to walk partitions up to `width` states per level.
    pub fn with_width(width: usize) -> LaneScratch {
        let w = width.max(1);
        LaneScratch {
            // One 64-bit lane-mask word per state, so `width` words.
            active: BitSet::empty(w * LANE_WIDTH),
            next: BitSet::empty(w * LANE_WIDTH),
            dirty: 0,
            levels: Vec::new(),
            pairs: Vec::new(),
            order: Vec::new(),
            scalar: EvalScratch::with_width(width),
        }
    }

    /// (Re)build the per-level value → lane-mask tables for one lane group
    /// (`rows` are indices into `batch`; lane `k` is `rows[k]`).
    fn build_tables(&mut self, batch: &EncodedBatch, rows: &[u32]) {
        debug_assert!(rows.len() <= LANE_WIDTH);
        let depth = batch.depth();
        if self.levels.len() < depth {
            self.levels.resize_with(depth, LevelTable::default);
        }
        for (lv, t) in self.levels.iter_mut().take(depth).enumerate() {
            self.pairs.clear();
            for (lane, &r) in rows.iter().enumerate() {
                // Encoded values are small non-negative domain values, so
                // the u32 cast is lossless (same cast as the scalar walk).
                self.pairs.push((batch.row(r as usize)[lv] as u32, lane as u32));
            }
            self.pairs.sort_unstable();
            t.vals.clear();
            t.cum.clear();
            t.cum.push(0);
            let mut acc = 0u64;
            for &(v, lane) in &self.pairs {
                if t.vals.last() != Some(&v) {
                    t.vals.push(v);
                    t.cum.push(acc);
                }
                acc |= 1u64 << lane;
                *t.cum.last_mut().unwrap() = acc;
            }
        }
    }

    /// Walk one partition with every lane of `group_mask` in lockstep,
    /// leaving the accept-level lane masks in `self.active`. Returns
    /// `false` if every lane died before the accept level.
    fn walk_partition(&mut self, nfa: &CompiledNfa, csr: &CsrPartition, group_mask: u64) -> bool {
        let depth = nfa.depth();
        debug_assert!(self.levels.len() >= depth);
        // Scrub whatever the previous walk dirtied, then only this
        // partition's span for the rest of the walk.
        let scrub = csr.width.max(self.dirty);
        self.active.clear_first_words(scrub);
        self.next.clear_first_words(scrub);
        self.dirty = csr.width;
        self.active.words_mut()[0] = group_mask;
        for lv in 0..depth {
            let base = csr.level_base[lv] as usize;
            let w_lv = csr.level_base[lv + 1] as usize - base;
            let last = lv + 1 == depth;
            let next_base = csr.level_base[lv + 1] as usize;
            let table = &self.levels[lv];
            let aw = &self.active.words()[..w_lv];
            let nw = self.next.words_mut();
            // OR of every lane mask written this level: the O(1) liveness
            // check that replaces scanning `next` for emptiness.
            let mut live = 0u64;
            for (s, &m) in aw.iter().enumerate() {
                if m == 0 {
                    continue;
                }
                let g = base + s;
                let (elo, ehi) = (csr.exact_off[g] as usize, csr.exact_off[g + 1] as usize);
                if ehi > elo {
                    if ehi - elo <= table.vals.len() {
                        // Few edges: probe the value table per edge.
                        for k in elo..ehi {
                            let hit = table.mask_of(csr.exact_vals[k]) & m;
                            if hit != 0 {
                                let to = csr.exact_tos[k] as usize;
                                nw[to] |= hit;
                                live |= hit;
                                if !last {
                                    prefetch(&csr.exact_off[next_base + to]);
                                }
                            }
                        }
                    } else {
                        // Few distinct values: probe the edges per value.
                        for (vi, &v) in table.vals.iter().enumerate() {
                            let hit = (table.cum[vi + 1] ^ table.cum[vi]) & m;
                            if hit == 0 {
                                continue;
                            }
                            if let Ok(k) = csr.exact_vals[elo..ehi].binary_search(&v) {
                                let to = csr.exact_tos[elo + k] as usize;
                                nw[to] |= hit;
                                live |= hit;
                                if !last {
                                    prefetch(&csr.exact_off[next_base + to]);
                                }
                            }
                        }
                    }
                }
                for &(rlo, rhi, to) in
                    &csr.ranges[csr.range_off[g] as usize..csr.range_off[g + 1] as usize]
                {
                    let hit = table.mask_of_range(rlo, rhi) & m;
                    if hit != 0 {
                        let to = to as usize;
                        nw[to] |= hit;
                        live |= hit;
                        if !last {
                            prefetch(&csr.exact_off[next_base + to]);
                        }
                    }
                }
                for &to in
                    &csr.any_tos[csr.any_off[g] as usize..csr.any_off[g + 1] as usize]
                {
                    let to = to as usize;
                    nw[to] |= m;
                    live |= m;
                    if !last {
                        prefetch(&csr.exact_off[next_base + to]);
                    }
                }
            }
            if live == 0 {
                return false;
            }
            std::mem::swap(&mut self.active, &mut self.next);
            // The swapped-out set (now `next`) was dirtied up to the level
            // width just scanned; scrub only that span for the next level.
            self.next.clear_first_words(w_lv);
        }
        true
    }
}

/// Sparse evaluator over a partitioned NFA.
#[derive(Debug, Clone)]
pub struct NativeEvaluator {
    nfa: PartitionedNfa,
    csr: Vec<CsrPartition>,
    /// Widest level across all partitions (scratch sizing).
    max_width: usize,
}

/// Below this many rows a sharded call falls back to the single-core walk:
/// thread spawn/join costs more than the evaluation itself.
pub const SHARD_MIN_ROWS: usize = 64;

impl NativeEvaluator {
    /// Whether a sharded walk pays for `rows` over `shards` cores — below
    /// the floor, thread spawn/join costs more than the evaluation.
    /// [`Self::evaluate_batch_sharded`] applies this internally; callers
    /// holding warm scratch (the engine) check it first so the fallback
    /// runs on their scratch instead of allocating fresh sets.
    pub fn sharding_pays(rows: usize, shards: usize) -> bool {
        shards > 1 && rows >= SHARD_MIN_ROWS.max(2 * shards)
    }

    pub fn new(nfa: PartitionedNfa) -> Self {
        let csr = nfa.partitions.iter().map(CsrPartition::build).collect();
        let max_width =
            nfa.partitions.iter().map(CompiledNfa::max_width).max().unwrap_or(0);
        NativeEvaluator { nfa, csr, max_width }
    }

    pub fn nfa(&self) -> &PartitionedNfa {
        &self.nfa
    }

    /// Fresh scratch sized for this evaluator. Callers keep one per thread
    /// and pass it to every batch (DESIGN.md §Hot path batch contract).
    pub fn scratch(&self) -> EvalScratch {
        EvalScratch::with_width(self.max_width)
    }

    /// Fresh lockstep scratch sized for this evaluator (one lane-mask word
    /// per state of the widest level). Same ownership contract as
    /// [`Self::scratch`]: one per thread, reused across batches.
    pub fn lane_scratch(&self) -> LaneScratch {
        LaneScratch::with_width(self.max_width)
    }

    /// Evaluate one *encoded* query (level-ordered values, length ≥ depth)
    /// against one partition. Returns the best accept, if any.
    fn eval_partition(
        nfa: &CompiledNfa,
        csr: &CsrPartition,
        q: &[i32],
        scratch: &mut EvalScratch,
    ) -> Option<(u32, f32, u16)> {
        let depth = nfa.depth();
        debug_assert!(q.len() >= depth);
        // Scrub whatever the previous walk dirtied, then only this
        // partition's span for the rest of the walk.
        let words = csr.words;
        let scrub = words.max(scratch.dirty_words);
        scratch.active.clear_first_words(scrub);
        scratch.next.clear_first_words(scrub);
        scratch.dirty_words = words;
        let EvalScratch { active, next, .. } = scratch;
        active.set(0);
        for lv in 0..depth {
            // qv comes from the encoder and is always a small non-negative
            // domain value, so the u32 cast below is lossless.
            let qv = q[lv] as u32;
            next.clear_first_words(words);
            let mut any_hit = false;
            let base = csr.level_base[lv];
            for s in active.iter() {
                let g = (base + s) as usize;
                let (lo, hi) = (csr.exact_off[g] as usize, csr.exact_off[g + 1] as usize);
                if let Ok(i) = csr.exact_vals[lo..hi].binary_search(&qv) {
                    next.set(csr.exact_tos[lo + i]);
                    any_hit = true;
                }
                for &(rlo, rhi, to) in
                    &csr.ranges[csr.range_off[g] as usize..csr.range_off[g + 1] as usize]
                {
                    if qv >= rlo && qv <= rhi {
                        next.set(to);
                        any_hit = true;
                    }
                }
                for &to in
                    &csr.any_tos[csr.any_off[g] as usize..csr.any_off[g + 1] as usize]
                {
                    next.set(to);
                    any_hit = true;
                }
            }
            if !any_hit {
                return None;
            }
            std::mem::swap(active, next);
        }
        // `active` now ranges over accepting states.
        let mut best: Option<(u32, f32, u16)> = None;
        for s in active.iter() {
            let a = &nfa.accepts[s as usize];
            let better = match best {
                None => true,
                // Strict > keeps the lowest accept index (= lowest rule id,
                // parser builds in id order) on ties — same rule as the
                // dense argmax.
                Some((_, w, _)) => a.weight > w,
            };
            if better {
                best = Some((a.rule_id, a.weight, a.decision_min));
            }
        }
        best
    }

    /// Evaluate one encoded query routed to `station` using caller-owned
    /// scratch: consult the station's partitions plus the global ones and
    /// keep the most precise match. Allocation-free.
    pub fn evaluate_encoded_with(
        &self,
        station: u32,
        q: &[i32],
        scratch: &mut EvalScratch,
    ) -> MctDecision {
        let mut best = MctDecision::no_match();
        for pi in self.nfa.partitions_for(station) {
            if let Some((rid, w, min)) =
                Self::eval_partition(&self.nfa.partitions[pi], &self.csr[pi], q, scratch)
            {
                let better = !best.matched()
                    || w > best.weight
                    || (w == best.weight && rid < best.rule_id);
                if better {
                    best = MctDecision { minutes: min, weight: w, rule_id: rid };
                }
            }
        }
        best
    }

    /// Scalar convenience path: allocates fresh scratch per call. Kept as
    /// the pre-batch baseline the perf harness measures against; hot
    /// callers use [`Self::evaluate_encoded_with`] or
    /// [`Self::evaluate_batch`].
    pub fn evaluate_encoded(&self, station: u32, q: &[i32]) -> MctDecision {
        let mut scratch = self.scratch();
        self.evaluate_encoded_with(station, q, &mut scratch)
    }

    /// Evaluate a whole encoded batch, reusing `scratch` across every row
    /// and appending one decision per row into `out` (cleared first). This
    /// is the feeder hot path: no allocation once `out`'s capacity is warm.
    pub fn evaluate_batch(
        &self,
        batch: &EncodedBatch,
        scratch: &mut EvalScratch,
        out: &mut Vec<MctDecision>,
    ) {
        out.clear();
        out.reserve(batch.len());
        for i in 0..batch.len() {
            out.push(self.evaluate_encoded_with(batch.station(i), batch.row(i), scratch));
        }
    }

    /// Split a large batch across `shards` cores (scoped threads; the
    /// evaluator is immutable so shards share it without locks), each shard
    /// walking with its own scratch. Falls back to the single-core walk on
    /// the *caller's* `scratch` for small batches or `shards <= 1`, so warm
    /// callers never pay a fresh allocation for the common small case.
    /// Output order matches the batch.
    pub fn evaluate_batch_sharded(
        &self,
        batch: &EncodedBatch,
        shards: usize,
        scratch: &mut EvalScratch,
        out: &mut Vec<MctDecision>,
    ) {
        let n = batch.len();
        if !Self::sharding_pays(n, shards) {
            self.evaluate_batch(batch, scratch, out);
            return;
        }
        out.clear();
        out.resize(n, MctDecision::no_match());
        let rows_per_shard = n.div_ceil(shards);
        std::thread::scope(|scope| {
            for (si, chunk) in out.chunks_mut(rows_per_shard).enumerate() {
                let start = si * rows_per_shard;
                scope.spawn(move || {
                    let mut scratch = self.scratch();
                    for (j, slot) in chunk.iter_mut().enumerate() {
                        let i = start + j;
                        *slot = self.evaluate_encoded_with(
                            batch.station(i),
                            batch.row(i),
                            &mut scratch,
                        );
                    }
                });
            }
        });
    }

    /// Walk one lane group (`rows`, all sharing `station`) through every
    /// relevant partition in lockstep, writing one decision per lane into
    /// `dest[..rows.len()]` (lane `k` answers row `rows[k]`).
    fn lockstep_group(
        &self,
        batch: &EncodedBatch,
        station: u32,
        rows: &[u32],
        lanes: &mut LaneScratch,
        dest: &mut [MctDecision],
    ) {
        debug_assert!(!rows.is_empty() && rows.len() <= LANE_WIDTH);
        lanes.build_tables(batch, rows);
        let group_mask = if rows.len() == LANE_WIDTH {
            u64::MAX
        } else {
            (1u64 << rows.len()) - 1
        };
        // Per-lane best across partitions (the scalar cross-partition
        // merge, vectorised over lanes).
        let mut matched = 0u64;
        let mut best_w = [0f32; LANE_WIDTH];
        let mut best_rid = [0u32; LANE_WIDTH];
        let mut best_min = [0u16; LANE_WIDTH];
        for pi in self.nfa.partitions_for(station) {
            let nfa = &self.nfa.partitions[pi];
            if !lanes.walk_partition(nfa, &self.csr[pi], group_mask) {
                continue;
            }
            // Per-partition accept scan: strict `>` with accepts visited
            // in ascending index keeps the lowest accept index on ties —
            // identical to the scalar walk's per-partition rule.
            let aw = lanes.active.words();
            let mut pm = 0u64;
            let mut pw = [0f32; LANE_WIDTH];
            let mut prid = [0u32; LANE_WIDTH];
            let mut pmin = [0u16; LANE_WIDTH];
            for (s, a) in nfa.accepts.iter().enumerate() {
                let mut m = aw[s];
                while m != 0 {
                    let lane = m.trailing_zeros() as usize;
                    m &= m - 1;
                    if (pm >> lane) & 1 == 0 || a.weight > pw[lane] {
                        pm |= 1u64 << lane;
                        pw[lane] = a.weight;
                        prid[lane] = a.rule_id;
                        pmin[lane] = a.decision_min;
                    }
                }
            }
            // Cross-partition merge, lane by lane (the scalar merge rule:
            // higher weight wins, lower rule id breaks weight ties).
            let mut lanes_hit = pm;
            while lanes_hit != 0 {
                let lane = lanes_hit.trailing_zeros() as usize;
                lanes_hit &= lanes_hit - 1;
                let better = (matched >> lane) & 1 == 0
                    || pw[lane] > best_w[lane]
                    || (pw[lane] == best_w[lane] && prid[lane] < best_rid[lane]);
                if better {
                    matched |= 1u64 << lane;
                    best_w[lane] = pw[lane];
                    best_rid[lane] = prid[lane];
                    best_min[lane] = pmin[lane];
                }
            }
        }
        for (lane, d) in dest.iter_mut().take(rows.len()).enumerate() {
            *d = if (matched >> lane) & 1 != 0 {
                MctDecision {
                    minutes: best_min[lane],
                    weight: best_w[lane],
                    rule_id: best_rid[lane],
                }
            } else {
                MctDecision::no_match()
            };
        }
    }

    /// Evaluate a whole batch query-parallel: bucket rows into same-station
    /// lane groups of up to [`LANE_WIDTH`], walk each group transposed
    /// (under-occupied trailing chunks fall back to the scalar walk on
    /// `lanes`' embedded scratch), and scatter results back through the
    /// bucketing permutation so `out` is in batch order. Allocation-free
    /// once `lanes` and `out` are warm. Returns occupancy accounting.
    pub fn evaluate_batch_lockstep(
        &self,
        batch: &EncodedBatch,
        lanes: &mut LaneScratch,
        out: &mut Vec<MctDecision>,
    ) -> LockstepStats {
        let n = batch.len();
        out.clear();
        out.resize(n, MctDecision::no_match());
        let mut stats = LockstepStats::default();
        if n == 0 {
            return stats;
        }
        // Bucket rows by station. Keys are unique (the row index breaks
        // station ties), so the unstable sort is deterministic and the
        // permutation stable with respect to batch order.
        let mut order = std::mem::take(&mut lanes.order);
        order.clear();
        order.extend(0..n as u32);
        let stations = batch.stations();
        order.sort_unstable_by_key(|&r| (stations[r as usize], r));
        let mut dest = [MctDecision::no_match(); LANE_WIDTH];
        let mut start = 0usize;
        while start < n {
            let station = stations[order[start] as usize];
            let mut end = start + 1;
            while end < n && stations[order[end] as usize] == station {
                end += 1;
            }
            stats.stations += 1;
            let mut gs = start;
            while gs < end {
                let ge = end.min(gs + LANE_WIDTH);
                let rows = &order[gs..ge];
                if rows.len() < LANE_MIN_OCCUPANCY {
                    // Under-occupied trailing chunk: the scalar walk is
                    // cheaper than building lane tables for a few rows.
                    stats.fallback_rows += rows.len();
                    for &r in rows {
                        out[r as usize] = self.evaluate_encoded_with(
                            station,
                            batch.row(r as usize),
                            &mut lanes.scalar,
                        );
                    }
                } else {
                    stats.groups += 1;
                    stats.lockstep_rows += rows.len();
                    self.lockstep_group(batch, station, rows, lanes, &mut dest);
                    for (k, &r) in rows.iter().enumerate() {
                        out[r as usize] = dest[k];
                    }
                }
                gs = ge;
            }
            start = end;
        }
        lanes.order = order;
        stats
    }

    /// Sharded lockstep: bucket once on the caller thread, cut the ordered
    /// rows into lane groups, deal contiguous spans of whole groups to
    /// scoped threads (each with its own [`LaneScratch`]), then scatter the
    /// per-group results back to batch order. Shards split *over lane
    /// groups*, never through one, so sharding cannot lower occupancy.
    /// Falls back to single-core lockstep when sharding does not pay.
    pub fn evaluate_batch_lockstep_sharded(
        &self,
        batch: &EncodedBatch,
        shards: usize,
        out: &mut Vec<MctDecision>,
    ) -> LockstepStats {
        let n = batch.len();
        if !Self::sharding_pays(n, shards) {
            let mut lanes = self.lane_scratch();
            return self.evaluate_batch_lockstep(batch, &mut lanes, out);
        }
        out.clear();
        out.resize(n, MctDecision::no_match());
        let stations = batch.stations();
        let mut order: Vec<u32> = (0..n as u32).collect();
        order.sort_unstable_by_key(|&r| (stations[r as usize], r));
        // Lane groups as (start, len) spans of `order`, plus the distinct
        // station count (counted here — a station's groups may straddle a
        // shard boundary, so shards cannot count stations themselves).
        let mut groups: Vec<(u32, u32)> = Vec::new();
        let mut n_stations = 0usize;
        let mut start = 0usize;
        while start < n {
            let station = stations[order[start] as usize];
            let mut end = start + 1;
            while end < n && stations[order[end] as usize] == station {
                end += 1;
            }
            n_stations += 1;
            let mut gs = start;
            while gs < end {
                let ge = end.min(gs + LANE_WIDTH);
                groups.push((gs as u32, (ge - gs) as u32));
                gs = ge;
            }
            start = end;
        }
        // Results land contiguously in group order first (`perm[k]` answers
        // row `order[k]`), so shards write disjoint slices without locking;
        // the scatter to batch order happens once at the end.
        let mut perm = vec![MctDecision::no_match(); n];
        let stats_acc = std::sync::Mutex::new(LockstepStats::default());
        let target = n.div_ceil(shards);
        std::thread::scope(|scope| {
            let mut rest: &[(u32, u32)] = &groups;
            let mut perm_rest: &mut [MctDecision] = &mut perm;
            while !rest.is_empty() {
                let mut take = 0usize;
                let mut rows_here = 0usize;
                while take < rest.len() && rows_here < target {
                    rows_here += rest[take].1 as usize;
                    take += 1;
                }
                let (span, r) = rest.split_at(take);
                rest = r;
                // `take` moves the `&mut` out so the halves keep the outer
                // lifetime (a plain reborrow would pin `perm_rest` and
                // forbid the reassignment below).
                let (chunk, pr) = std::mem::take(&mut perm_rest).split_at_mut(rows_here);
                perm_rest = pr;
                let order_ref = &order;
                let stats_ref = &stats_acc;
                scope.spawn(move || {
                    let mut lanes = self.lane_scratch();
                    let mut local = LockstepStats::default();
                    let mut dest = [MctDecision::no_match(); LANE_WIDTH];
                    let mut off = 0usize;
                    for &(gs, glen) in span {
                        let rows = &order_ref[gs as usize..(gs + glen) as usize];
                        let station = stations[rows[0] as usize];
                        if rows.len() < LANE_MIN_OCCUPANCY {
                            local.fallback_rows += rows.len();
                            for (k, &row) in rows.iter().enumerate() {
                                chunk[off + k] = self.evaluate_encoded_with(
                                    station,
                                    batch.row(row as usize),
                                    &mut lanes.scalar,
                                );
                            }
                        } else {
                            local.groups += 1;
                            local.lockstep_rows += rows.len();
                            self.lockstep_group(batch, station, rows, &mut lanes, &mut dest);
                            chunk[off..off + rows.len()]
                                .copy_from_slice(&dest[..rows.len()]);
                        }
                        off += rows.len();
                    }
                    stats_ref.lock().unwrap().absorb(local);
                });
            }
        });
        for (k, &r) in order.iter().enumerate() {
            out[r as usize] = perm[k];
        }
        let mut stats = stats_acc.into_inner().unwrap();
        stats.stations = n_stations;
        stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::encoder::QueryEncoder;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::prng::Rng;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::{evaluate_ruleset, Schema, StandardVersion};
    use crate::workload::random_query;

    /// The decisive correctness test: native NFA evaluation must agree with
    /// the semantic oracle (`evaluate_ruleset`) on random fleets of queries
    /// for both standard versions — through the scalar, the batch and the
    /// sharded entry points.
    #[test]
    fn native_agrees_with_semantic_oracle() {
        for (seed, version) in
            [(71u64, StandardVersion::V1), (73, StandardVersion::V2)]
        {
            let cfg = GeneratorConfig::small(seed, 600);
            let w = generate_world(&cfg);
            let schema = Schema::for_version(version);
            let rs = generate_rule_set(&cfg, &w, version);
            let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
            let enc = QueryEncoder::new(&p.plan, p.plan.len());
            let eval = NativeEvaluator::new(p);
            let mut rng = Rng::new(seed ^ 0xFF);
            let queries: Vec<_> = (0..400)
                .map(|_| {
                    let station = rng.index(cfg.n_airports) as u32;
                    random_query(&mut rng, &w, station)
                })
                .collect();
            let mut batch = EncodedBatch::default();
            enc.encode_batch_into(&queries, &mut batch);
            let mut scratch = eval.scratch();
            let mut got_batch = Vec::new();
            eval.evaluate_batch(&batch, &mut scratch, &mut got_batch);
            let mut got_sharded = Vec::new();
            eval.evaluate_batch_sharded(&batch, 3, &mut scratch, &mut got_sharded);
            let mut lanes = eval.lane_scratch();
            let mut got_lockstep = Vec::new();
            let stats = eval.evaluate_batch_lockstep(&batch, &mut lanes, &mut got_lockstep);
            assert_eq!(stats.rows(), queries.len());
            let mut got_ls_sharded = Vec::new();
            let sh_stats =
                eval.evaluate_batch_lockstep_sharded(&batch, 3, &mut got_ls_sharded);
            assert_eq!(sh_stats.rows(), queries.len());
            assert_eq!(sh_stats.stations, stats.stations);
            let mut matched = 0;
            for (i, q) in queries.iter().enumerate() {
                let want = evaluate_ruleset(&schema, &rs, q);
                let got = eval.evaluate_encoded(q.station, &enc.encode(q));
                assert_eq!(got.rule_id, want.rule_id, "{version:?} q={q:?}");
                assert_eq!(got.minutes, want.minutes);
                assert_eq!(got_batch[i], got, "batch row {i} diverges");
                assert_eq!(got_sharded[i], got, "sharded row {i} diverges");
                assert_eq!(got_lockstep[i], got, "lockstep row {i} diverges");
                assert_eq!(got_ls_sharded[i], got, "lockstep-sharded row {i} diverges");
                if got.matched() {
                    matched += 1;
                }
            }
            assert!(matched > 50, "{version:?}: too few matches ({matched}) to be meaningful");
        }
    }

    #[test]
    fn unknown_station_falls_back_to_global_rules() {
        let cfg = GeneratorConfig::small(79, 300);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let eval = NativeEvaluator::new(p);
        // A station beyond the generated world: only wildcard-station rules
        // could match; the evaluator must not panic and must agree with the
        // oracle.
        let q = crate::workload::query_for_station(&w, 10_000, 1);
        let want = evaluate_ruleset(&schema, &rs, &q);
        let got = eval.evaluate_encoded(10_000, &enc.encode(&q));
        assert_eq!(got.rule_id, want.rule_id);
    }

    #[test]
    fn csr_arena_matches_nested_edge_lists() {
        // The flattened arena must index exactly the edges of the compiled
        // NFA: per state, the packed slices reproduce the edge lists.
        let cfg = GeneratorConfig::small(83, 250);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        for nfa in &p.partitions {
            let csr = CsrPartition::build(nfa);
            assert_eq!(csr.level_base.len(), nfa.states.len() + 1);
            for (lv, states) in nfa.states.iter().enumerate() {
                for (s, edges) in states.iter().enumerate() {
                    let g = (csr.level_base[lv] as usize) + s;
                    let exact: Vec<(u32, u32)> = {
                        let (lo, hi) =
                            (csr.exact_off[g] as usize, csr.exact_off[g + 1] as usize);
                        csr.exact_vals[lo..hi]
                            .iter()
                            .copied()
                            .zip(csr.exact_tos[lo..hi].iter().copied())
                            .collect()
                    };
                    let mut want_exact: Vec<(u32, u32)> = edges
                        .iter()
                        .filter_map(|e| match e.label {
                            EdgeLabel::Exact(v) => Some((v, e.to)),
                            _ => None,
                        })
                        .collect();
                    want_exact.sort_unstable();
                    assert_eq!(exact, want_exact);
                    assert!(
                        exact.windows(2).all(|p| p[0].0 < p[1].0),
                        "exact values must be strictly sorted for binary search"
                    );
                    let n_ranges = (csr.range_off[g + 1] - csr.range_off[g]) as usize;
                    let n_any = (csr.any_off[g + 1] - csr.any_off[g]) as usize;
                    let want_ranges = edges
                        .iter()
                        .filter(|e| matches!(e.label, EdgeLabel::Range(..)))
                        .count();
                    let want_any = edges
                        .iter()
                        .filter(|e| matches!(e.label, EdgeLabel::Any))
                        .count();
                    assert_eq!(n_ranges, want_ranges);
                    assert_eq!(n_any, want_any);
                }
            }
        }
    }

    #[test]
    fn empty_batch_produces_empty_output() {
        let cfg = GeneratorConfig::small(89, 100);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let eval = NativeEvaluator::new(p);
        let batch = EncodedBatch::default();
        let mut out = vec![MctDecision::no_match(); 3]; // stale content must be cleared
        eval.evaluate_batch(&batch, &mut eval.scratch(), &mut out);
        assert!(out.is_empty());
        out.push(MctDecision::no_match());
        eval.evaluate_batch_sharded(&batch, 4, &mut eval.scratch(), &mut out);
        assert!(out.is_empty());
        out.push(MctDecision::no_match());
        let stats = eval.evaluate_batch_lockstep(&batch, &mut eval.lane_scratch(), &mut out);
        assert!(out.is_empty());
        assert_eq!(stats, LockstepStats::default());
    }

    /// Lane-group accounting: a single-station batch of 65 rows must split
    /// into one full 64-lane group plus a 1-row scalar fallback, and the
    /// stats must say so.
    #[test]
    fn lockstep_stats_count_groups_and_fallback() {
        let cfg = GeneratorConfig::small(97, 300);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let eval = NativeEvaluator::new(p);
        let mut rng = Rng::new(101);
        let station = 0u32;
        let mut lanes = eval.lane_scratch();
        let mut out = Vec::new();
        let mut batch = EncodedBatch::default();
        for (n, groups, ls_rows, fb_rows) in
            [(1usize, 0usize, 0usize, 1usize), (63, 1, 63, 0), (64, 1, 64, 0), (65, 1, 64, 1)]
        {
            let queries: Vec<_> =
                (0..n).map(|_| random_query(&mut rng, &w, station)).collect();
            enc.encode_batch_into(&queries, &mut batch);
            let stats = eval.evaluate_batch_lockstep(&batch, &mut lanes, &mut out);
            assert_eq!(stats.groups, groups, "n={n}");
            assert_eq!(stats.lockstep_rows, ls_rows, "n={n}");
            assert_eq!(stats.fallback_rows, fb_rows, "n={n}");
            assert_eq!(stats.stations, 1, "n={n}");
            // Every split agrees with the scalar walk regardless of which
            // side of the occupancy floor the rows landed on.
            for (i, q) in queries.iter().enumerate() {
                let want = eval.evaluate_encoded(q.station, &enc.encode(q));
                assert_eq!(out[i], want, "n={n} row {i}");
            }
        }
        assert_eq!(LockstepStats::default().mean_occupancy(), 0.0);
        assert_eq!(LockstepStats::default().fallback_fraction(), 0.0);
    }

    /// The prefix-OR level tables must map each distinct value to exactly
    /// the lanes that carry it, and range probes to the union in between.
    #[test]
    fn level_tables_partition_the_lanes() {
        let cfg = GeneratorConfig::small(103, 200);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V1);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V1);
        let (p, _) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        let enc = QueryEncoder::new(&p.plan, p.plan.len());
        let eval = NativeEvaluator::new(p);
        let mut rng = Rng::new(107);
        let queries: Vec<_> =
            (0..40).map(|_| random_query(&mut rng, &w, 1)).collect();
        let mut batch = EncodedBatch::default();
        enc.encode_batch_into(&queries, &mut batch);
        let rows: Vec<u32> = (0..queries.len() as u32).collect();
        let mut lanes = eval.lane_scratch();
        lanes.build_tables(&batch, &rows);
        for lv in 0..batch.depth() {
            let t = &lanes.levels[lv];
            assert_eq!(t.cum.len(), t.vals.len() + 1);
            assert!(t.vals.windows(2).all(|p| p[0] < p[1]), "values sorted+distinct");
            // Per-value masks are disjoint and cover exactly the group.
            let mut seen = 0u64;
            for (vi, &v) in t.vals.iter().enumerate() {
                let m = t.mask_of(v);
                assert_ne!(m, 0);
                assert_eq!(seen & m, 0, "lane masks must be disjoint");
                seen |= m;
                // Each lane in the mask really carries `v` at this level.
                let mut mm = m;
                while mm != 0 {
                    let lane = mm.trailing_zeros() as usize;
                    mm &= mm - 1;
                    assert_eq!(batch.row(lane)[lv] as u32, v);
                }
                assert_eq!(t.mask_of_range(v, v), m);
            }
            assert_eq!(seen, (1u64 << rows.len()) - 1, "masks cover all lanes");
            let (lo, hi) = (t.vals[0], *t.vals.last().unwrap());
            assert_eq!(t.mask_of_range(lo, hi), seen, "full-span range = all lanes");
            assert_eq!(t.mask_of_range(hi + 1, hi + 10), 0);
        }
    }
}
