//! Calibrated FPGA datapath cost model — the *hardware-model clock* of
//! DESIGN.md §Dual-clock.
//!
//! We have no Alveo U250 / AWS F1 board; answers are computed for real by
//! the XLA or native backend, while **time** on the accelerator side is
//! produced by this analytic model of the ERBIUM datapath, calibrated to
//! every anchor the paper publishes:
//!
//! * v1 (QDMA, 4 engines) saturates at **40 M q/s**, *PCIe-bandwidth-bound*
//!   (§3.2.2 "currently limited by the PCIe bandwidth", Fig 4);
//! * v2 (XDMA, 4 engines) saturates at **32 M q/s**, *frequency-bound* —
//!   "by virtue of a 11 % lower operating frequency" (§3.3);
//! * both curves respond similarly until the pipeline saturates around
//!   **100 k queries/batch** (Fig 4);
//! * the XDMA (blocking) shell dominates small-batch latency up to roughly
//!   **1 024 queries/batch** vs the streaming QDMA shell (§3.3);
//! * engine clock: §3.3 (−11 % v1→v2) and §4.3 (−30 % for 1→4 engines),
//!   see [`clock_frequency_mhz`];
//! * rule-update downtime ≈ **500 µs** ([15], §1).
//!
//! The model: queries stream over PCIe (2 B per consolidated criterion,
//! dictionary-encoded), each engine retires one query every
//! `II = κ·depth` cycles (κ = 0.85 — multiple active NFA states contend on
//! the transition memory ports), results return 8 B each. The blocking
//! XDMA shell serialises transfer-in → compute → transfer-out; the
//! streaming QDMA shell overlaps them.

use crate::nfa::constraint_gen::{clock_frequency_mhz, HardwareConfig, Shell};

/// Effective host↔board bandwidth (bytes/s). Calibrated so that
/// `bw / query_bytes(v1)` ≈ 40.9 M q/s — the paper's PCIe-bound v1 ceiling.
pub const PCIE_BW_BPS: f64 = 1.8e9;

/// Per-query initiation-interval factor (fraction of `depth` cycles).
pub const II_FACTOR: f64 = 0.85;

/// Fixed per-invocation shell overhead, µs.
pub const XDMA_SETUP_US: f64 = 55.0;
pub const QDMA_SETUP_US: f64 = 8.0;

/// Result payload per query (decision + weight + state id), bytes.
pub const RESULT_BYTES: f64 = 8.0;

/// Rule-update (NFA reload) downtime, µs — the [15] headline.
pub const NFA_UPDATE_DOWNTIME_US: f64 = 500.0;

/// DMA buffer granularity of the blocking XDMA shell, queries per kernel
/// invocation (≈ 0.4 MiB of encoded v2 queries).
pub const XDMA_CHUNK: usize = 8_192;

/// Decomposed timing of one kernel invocation over a batch.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BatchTiming {
    pub setup_us: f64,
    pub transfer_in_us: f64,
    pub compute_us: f64,
    pub transfer_out_us: f64,
    /// End-to-end time of the invocation (shell-dependent composition).
    pub total_us: f64,
}

/// The datapath model for one hardware configuration.
#[derive(Debug, Clone, Copy)]
pub struct FpgaModel {
    pub cfg: HardwareConfig,
    /// NFA depth = pipeline depth (22 for v1, 26 for v2).
    pub depth: usize,
    /// Engines synthesised on the whole board (≥ `cfg.engines` when several
    /// kernels share it). The clock penalty follows the *total* circuit
    /// complexity (§4.3, Fig 8), while the retire rate uses this kernel's
    /// own `cfg.engines`.
    pub total_engines: usize,
}

impl FpgaModel {
    pub fn new(cfg: HardwareConfig, depth: usize) -> FpgaModel {
        Self::with_total(cfg, depth, cfg.engines)
    }

    /// Model a kernel on a board carrying `total_engines` engines overall.
    pub fn with_total(cfg: HardwareConfig, depth: usize, total_engines: usize) -> FpgaModel {
        assert!(depth > 0 && cfg.engines > 0 && total_engines >= cfg.engines);
        FpgaModel { cfg, depth, total_engines }
    }

    /// Encoded query payload: 2 B per consolidated criterion.
    pub fn query_bytes(&self) -> f64 {
        2.0 * self.depth as f64
    }

    /// Engine clock, Hz (penalised by the board-wide engine count).
    pub fn clock_hz(&self) -> f64 {
        clock_frequency_mhz(self.cfg.version, self.total_engines) * 1e6
    }

    /// Aggregate compute retire rate, queries/s (pipeline saturated).
    pub fn compute_qps(&self) -> f64 {
        self.cfg.engines as f64 * self.clock_hz() / (II_FACTOR * self.depth as f64)
    }

    /// PCIe-bound ceiling, queries/s.
    pub fn pcie_qps(&self) -> f64 {
        PCIE_BW_BPS / self.query_bytes()
    }

    /// Saturation throughput of the kernel, queries/s.
    pub fn saturation_qps(&self) -> f64 {
        self.compute_qps().min(self.pcie_qps())
    }

    /// Timing of one invocation over `batch` queries.
    pub fn batch_timing(&self, batch: usize) -> BatchTiming {
        let b = batch as f64;
        let transfer_in_us = b * self.query_bytes() / PCIE_BW_BPS * 1e6;
        let transfer_out_us = b * RESULT_BYTES / PCIE_BW_BPS * 1e6;
        // Pipeline fill + steady-state retire.
        let fill_us = self.depth as f64 / self.clock_hz() * 1e6;
        let compute_us = fill_us + b / self.compute_qps() * 1e6;
        let (setup_us, total_us) = match self.cfg.shell {
            Shell::Xdma => {
                // Blocking shell: within one DMA chunk the phases are
                // strictly sequential. Large logical batches are split into
                // XDMA_CHUNK-query kernel invocations whose transfers XRT
                // overlaps with the previous chunk's compute (§4.1) — this
                // cross-chunk pipelining is how Fig 4's v2 curve still
                // saturates despite the blocking interface.
                let chunks = batch.div_ceil(XDMA_CHUNK).max(1);
                let cb = (b / chunks as f64).max(1.0);
                let in_c = cb * self.query_bytes() / PCIE_BW_BPS * 1e6;
                let out_c = cb * RESULT_BYTES / PCIE_BW_BPS * 1e6;
                let comp_c = fill_us + cb / self.compute_qps() * 1e6;
                let steady = in_c.max(comp_c).max(out_c);
                let total = XDMA_SETUP_US
                    + in_c
                    + comp_c
                    + out_c
                    + (chunks as f64 - 1.0) * steady;
                (XDMA_SETUP_US, total)
            }
            Shell::Qdma => {
                // Streaming: phases overlap; the slowest stream dominates,
                // with a small skew for the non-overlapped head/tail.
                let phases = [transfer_in_us, compute_us, transfer_out_us];
                let max = phases.iter().cloned().fold(0.0, f64::max);
                let sum: f64 = phases.iter().sum();
                (QDMA_SETUP_US, QDMA_SETUP_US + max + 0.08 * (sum - max))
            }
        };
        BatchTiming { setup_us, transfer_in_us, compute_us, transfer_out_us, total_us }
    }

    /// Sustained throughput when invoking back-to-back batches of `batch`.
    pub fn sustained_qps(&self, batch: usize) -> f64 {
        let t = self.batch_timing(batch);
        batch as f64 / (t.total_us * 1e-6)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v1() -> FpgaModel {
        FpgaModel::new(HardwareConfig::v1_onprem(4), 22)
    }
    fn v2(engines: usize) -> FpgaModel {
        FpgaModel::new(HardwareConfig::v2_aws(engines), 26)
    }

    #[test]
    fn saturation_anchors() {
        // Paper Fig 4: v1 ≈ 40 M q/s, v2 ≈ 32 M q/s.
        let s1 = v1().saturation_qps() / 1e6;
        let s2 = v2(4).saturation_qps() / 1e6;
        assert!((39.0..42.5).contains(&s1), "v1 saturation {s1} Mq/s");
        assert!((30.5..33.5).contains(&s2), "v2 saturation {s2} Mq/s");
    }

    #[test]
    fn bound_attribution_matches_paper() {
        // §3.2.2: v1 is PCIe-bound; §3.3: v2 is frequency(compute)-bound.
        let m1 = v1();
        assert!(m1.pcie_qps() < m1.compute_qps(), "v1 must be PCIe-bound");
        let m2 = v2(4);
        assert!(m2.compute_qps() < m2.pcie_qps(), "v2 must be compute-bound");
    }

    #[test]
    fn xdma_dominates_small_batches() {
        // §3.3: the shells differ strongly up to ~1 024 queries/batch.
        for b in [1usize, 16, 256, 1024] {
            let t1 = v1().batch_timing(b).total_us;
            let t2 = v2(4).batch_timing(b).total_us;
            assert!(t2 > 1.5 * t1, "batch {b}: XDMA {t2:.1}µs vs QDMA {t1:.1}µs");
        }
        // ...and converges within ~2× at very large batches (Fig 4).
        let t1 = v1().batch_timing(1 << 20).total_us;
        let t2 = v2(4).batch_timing(1 << 20).total_us;
        assert!(t2 / t1 < 2.0, "large batches must converge: {:.2}", t2 / t1);
    }

    #[test]
    fn sustained_throughput_saturates_near_100k_batch() {
        // Fig 4: pipeline not saturated below ~100 k queries/batch.
        let m = v2(4);
        let at_1k = m.sustained_qps(1_000);
        let at_100k = m.sustained_qps(100_000);
        let sat = m.saturation_qps();
        assert!(at_1k < 0.5 * sat, "1k batch must be far from saturation");
        assert!(at_100k > 0.8 * sat, "100k batch must approach saturation");
    }

    #[test]
    fn more_engines_more_throughput_lower_latency() {
        let t1 = v2(1).batch_timing(10_000);
        let t4 = v2(4).batch_timing(10_000);
        assert!(t4.compute_us < t1.compute_us);
        assert!(v2(4).saturation_qps() > v2(2).saturation_qps());
        assert!(v2(2).saturation_qps() > v2(1).saturation_qps());
        // ...but sub-linearly (30 % clock penalty, §4.3).
        let ratio = v2(4).saturation_qps() / v2(1).saturation_qps();
        assert!(ratio < 4.0 && ratio > 2.0, "engine scaling ratio {ratio}");
    }

    #[test]
    fn timing_decomposition_is_consistent() {
        let t = v2(4).batch_timing(4096);
        assert!(t.total_us >= t.transfer_in_us + t.compute_us + t.transfer_out_us);
        let q = v1().batch_timing(4096);
        // Streaming total is below the sum of phases (overlap).
        assert!(
            q.total_us
                < q.setup_us + q.transfer_in_us + q.compute_us + q.transfer_out_us
        );
    }
}
