//! Shared bit-set helper for the match hot path.
//!
//! One dynamically-sized bit set over NFA states, used by the sparse
//! evaluator ([`crate::erbium::NativeEvaluator`]) for active-state
//! propagation and by tests as a plain set. Lives in its own module so the
//! evaluator, the batch scratch ([`crate::erbium::EvalScratch`]) and the
//! test suite share one definition instead of `#[cfg(test)]`-gated
//! duplicates.

/// Dynamically-sized bit set (width decided by the caller, so the CPU-side
/// trie is not constrained by the hardware's `S` bound).
#[derive(Debug, Clone, Default)]
pub struct BitSet {
    w: Vec<u64>,
}

impl BitSet {
    /// An all-zero set able to hold bits `0..width`.
    #[inline]
    pub fn empty(width: usize) -> Self {
        BitSet { w: vec![0; Self::words_for(width)] }
    }

    /// Zero every bit, keeping the allocation.
    #[inline]
    pub fn clear(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0);
    }

    /// Zero only the first `n` words (bits `0..64n`). Hot-path variant for
    /// callers that track how much of an over-sized scratch set is dirty —
    /// clearing a shared max-width set in full per level would tax every
    /// small partition.
    #[inline]
    pub fn clear_first_words(&mut self, n: usize) {
        let n = n.min(self.w.len());
        self.w[..n].iter_mut().for_each(|x| *x = 0);
    }

    /// Words needed to hold bits `0..width`.
    #[inline]
    pub fn words_for(width: usize) -> usize {
        width.div_ceil(64).max(1)
    }

    #[inline]
    pub fn set(&mut self, i: u32) {
        self.w[(i >> 6) as usize] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn get(&self, i: u32) -> bool {
        self.w[(i >> 6) as usize] & (1u64 << (i & 63)) != 0
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.w.iter().all(|&x| x == 0)
    }

    /// Iterate set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.w.iter().enumerate().flat_map(|(bi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros();
                    w &= w - 1;
                    Some((bi as u32) << 6 | b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = BitSet::empty(256);
        assert!(b.is_empty());
        for i in [0u32, 63, 64, 130, 255] {
            b.set(i);
        }
        assert!(b.get(64) && b.get(255) && !b.get(1));
        let got: Vec<u32> = b.iter().collect();
        assert_eq!(got, vec![0, 63, 64, 130, 255]);
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    fn clear_first_words_is_partial() {
        let mut b = BitSet::empty(256);
        b.set(3);
        b.set(200);
        b.clear_first_words(1);
        assert!(!b.get(3) && b.get(200));
        // Out-of-range word counts are clamped.
        b.clear_first_words(1000);
        assert!(b.is_empty());
        assert_eq!(BitSet::words_for(0), 1);
        assert_eq!(BitSet::words_for(64), 1);
        assert_eq!(BitSet::words_for(65), 2);
    }

    #[test]
    fn zero_width_still_holds_one_word() {
        let b = BitSet::empty(0);
        assert!(b.is_empty());
        assert_eq!(b.iter().count(), 0);
    }
}
