//! Shared bit-set helper for the match hot path.
//!
//! One dynamically-sized bit set over NFA states, used by the sparse
//! evaluator ([`crate::erbium::NativeEvaluator`]) for active-state
//! propagation and by tests as a plain set. Lives in its own module so the
//! evaluator, the batch scratch ([`crate::erbium::EvalScratch`]) and the
//! test suite share one definition instead of `#[cfg(test)]`-gated
//! duplicates.

/// Dynamically-sized bit set (width decided by the caller, so the CPU-side
/// trie is not constrained by the hardware's `S` bound).
#[derive(Debug, Clone, Default)]
pub struct BitSet {
    w: Vec<u64>,
}

impl BitSet {
    /// An all-zero set able to hold bits `0..width`.
    #[inline]
    pub fn empty(width: usize) -> Self {
        BitSet { w: vec![0; Self::words_for(width)] }
    }

    /// Zero every bit, keeping the allocation.
    #[inline]
    pub fn clear(&mut self) {
        self.w.iter_mut().for_each(|x| *x = 0);
    }

    /// Zero only the first `n` words (bits `0..64n`). Hot-path variant for
    /// callers that track how much of an over-sized scratch set is dirty —
    /// clearing a shared max-width set in full per level would tax every
    /// small partition.
    #[inline]
    pub fn clear_first_words(&mut self, n: usize) {
        let n = n.min(self.w.len());
        self.w[..n].iter_mut().for_each(|x| *x = 0);
    }

    /// Words needed to hold bits `0..width`.
    #[inline]
    pub fn words_for(width: usize) -> usize {
        width.div_ceil(64).max(1)
    }

    /// The raw word lanes. The transposed lockstep walk
    /// ([`crate::erbium::native`]) treats one `BitSet` as a state-indexed
    /// array of 64-query lane masks — word `s` holds the mask of lanes whose
    /// NFA walk is live in state `s` — so it reads and writes whole words,
    /// not bits.
    #[inline]
    pub fn words(&self) -> &[u64] {
        &self.w
    }

    /// Mutable access to the raw word lanes (see [`Self::words`]).
    #[inline]
    pub fn words_mut(&mut self) -> &mut [u64] {
        &mut self.w
    }

    /// Total number of set bits. For a lane-mask set this is the number of
    /// live (state, query-lane) pairs — the occupancy quantity the perf
    /// harness reports.
    #[inline]
    pub fn count_ones(&self) -> usize {
        self.w.iter().map(|x| x.count_ones() as usize).sum()
    }

    /// OR every word of `self` into `dst` (word-level set union). `dst` must
    /// be at least as wide; extra words are left untouched.
    pub fn or_into(&self, dst: &mut BitSet) {
        assert!(dst.w.len() >= self.w.len(), "or_into target narrower than source");
        for (d, s) in dst.w.iter_mut().zip(&self.w) {
            *d |= s;
        }
    }

    #[inline]
    pub fn set(&mut self, i: u32) {
        self.w[(i >> 6) as usize] |= 1u64 << (i & 63);
    }

    #[inline]
    pub fn get(&self, i: u32) -> bool {
        self.w[(i >> 6) as usize] & (1u64 << (i & 63)) != 0
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.w.iter().all(|&x| x == 0)
    }

    /// Iterate set bits in ascending order.
    pub fn iter(&self) -> impl Iterator<Item = u32> + '_ {
        self.w.iter().enumerate().flat_map(|(bi, &word)| {
            let mut w = word;
            std::iter::from_fn(move || {
                if w == 0 {
                    None
                } else {
                    let b = w.trailing_zeros();
                    w &= w - 1;
                    Some((bi as u32) << 6 | b)
                }
            })
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let mut b = BitSet::empty(256);
        assert!(b.is_empty());
        for i in [0u32, 63, 64, 130, 255] {
            b.set(i);
        }
        assert!(b.get(64) && b.get(255) && !b.get(1));
        let got: Vec<u32> = b.iter().collect();
        assert_eq!(got, vec![0, 63, 64, 130, 255]);
        b.clear();
        assert!(b.is_empty());
    }

    #[test]
    fn clear_first_words_is_partial() {
        let mut b = BitSet::empty(256);
        b.set(3);
        b.set(200);
        b.clear_first_words(1);
        assert!(!b.get(3) && b.get(200));
        // Out-of-range word counts are clamped.
        b.clear_first_words(1000);
        assert!(b.is_empty());
        assert_eq!(BitSet::words_for(0), 1);
        assert_eq!(BitSet::words_for(64), 1);
        assert_eq!(BitSet::words_for(65), 2);
    }

    #[test]
    fn zero_width_still_holds_one_word() {
        let b = BitSet::empty(0);
        assert!(b.is_empty());
        assert_eq!(b.iter().count(), 0);
    }

    #[test]
    fn words_expose_lane_masks() {
        let mut b = BitSet::empty(192);
        assert_eq!(b.words().len(), 3);
        // Word-level write, bit-level read: the lockstep contract.
        b.words_mut()[1] = 0b1011;
        assert!(b.get(64) && b.get(65) && !b.get(66) && b.get(67));
        assert_eq!(b.words()[1], 0b1011);
    }

    #[test]
    fn count_ones_totals_across_words() {
        let mut b = BitSet::empty(256);
        assert_eq!(b.count_ones(), 0);
        for i in [0u32, 1, 63, 64, 128, 255] {
            b.set(i);
        }
        assert_eq!(b.count_ones(), 6);
        b.words_mut()[0] = u64::MAX;
        assert_eq!(b.count_ones(), 64 + 3);
    }

    #[test]
    fn or_into_unions_word_lanes() {
        let mut a = BitSet::empty(128);
        let mut b = BitSet::empty(256);
        a.set(3);
        a.set(100);
        b.set(4);
        b.set(200);
        a.or_into(&mut b);
        let got: Vec<u32> = b.iter().collect();
        assert_eq!(got, vec![3, 4, 100, 200]);
        // Source unchanged, words beyond the source width untouched.
        assert_eq!(a.iter().collect::<Vec<_>>(), vec![3, 100]);
        assert!(b.get(200));
    }

    #[test]
    #[should_panic(expected = "narrower")]
    fn or_into_rejects_narrow_target() {
        let a = BitSet::empty(256);
        let mut b = BitSet::empty(64);
        a.or_into(&mut b);
    }
}
