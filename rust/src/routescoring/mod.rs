//! The Route Scoring module ([17], §6.2): a gradient-boosted decision-tree
//! ensemble that scores candidate routes, previously accelerated on FPGAs
//! in its own right and — in the paper's Fig 14 proposal — co-located with
//! MCT on the same board to keep the FPGA busy.
//!
//! We implement (a) the functional scorer (a real GBT-ensemble inference
//! engine over route features), (b) its datapath occupancy model for the
//! combined-deployment scenario of Table 3, and (c) the "move scoring
//! earlier" capacity argument: inside the Domain Explorer the module must
//! score tens of thousands of routes per user query instead of the few
//! hundred the Route Selection stage sees (§6.2).

use crate::prng::Rng;
use crate::workload::TravelSolution;

/// Features extracted from a candidate route (a Travel Solution).
pub const N_FEATURES: usize = 12;

/// One internal node / leaf of a decision tree (array-encoded full binary
/// tree: children of `i` at `2i+1` / `2i+2`).
#[derive(Debug, Clone, Copy)]
struct Node {
    /// Feature index; `u8::MAX` marks a leaf.
    feature: u8,
    threshold: f32,
    /// Leaf payload (ignored for internal nodes).
    value: f32,
}

/// A fixed-depth decision tree.
#[derive(Debug, Clone)]
pub struct Tree {
    nodes: Vec<Node>,
    /// Depth the tree was built with (exposed for occupancy estimates).
    pub depth: usize,
}

impl Tree {
    /// Inference: root-to-leaf walk.
    #[inline]
    pub fn predict(&self, x: &[f32; N_FEATURES]) -> f32 {
        let mut i = 0usize;
        loop {
            let n = self.nodes[i];
            if n.feature == u8::MAX {
                return n.value;
            }
            i = if x[n.feature as usize] <= n.threshold { 2 * i + 1 } else { 2 * i + 2 };
        }
    }
}

/// The boosted ensemble.
#[derive(Debug, Clone)]
pub struct RouteScorer {
    pub trees: Vec<Tree>,
}

impl RouteScorer {
    /// Deterministic synthetic ensemble (the production model of [17] is
    /// proprietary; shape matters: ~100 trees of depth ~6).
    pub fn synthetic(seed: u64, n_trees: usize, depth: usize) -> RouteScorer {
        let mut rng = Rng::new(seed ^ 0x5C04E5);
        let trees = (0..n_trees)
            .map(|_| {
                let n_nodes = (1usize << (depth + 1)) - 1;
                let first_leaf = (1usize << depth) - 1;
                let nodes = (0..n_nodes)
                    .map(|i| {
                        if i >= first_leaf {
                            Node {
                                feature: u8::MAX,
                                threshold: 0.0,
                                value: (rng.f64() as f32 - 0.5) * 0.2,
                            }
                        } else {
                            Node {
                                feature: rng.index(N_FEATURES) as u8,
                                threshold: rng.f64() as f32,
                                value: 0.0,
                            }
                        }
                    })
                    .collect();
                Tree { nodes, depth }
            })
            .collect();
        RouteScorer { trees }
    }

    /// Score one route: sum of tree outputs, squashed to (0, 1).
    pub fn score(&self, x: &[f32; N_FEATURES]) -> f32 {
        let raw: f32 = self.trees.iter().map(|t| t.predict(x)).sum();
        1.0 / (1.0 + (-raw).exp())
    }

    /// Score a batch of routes.
    pub fn score_batch(&self, xs: &[[f32; N_FEATURES]]) -> Vec<f32> {
        xs.iter().map(|x| self.score(x)).collect()
    }
}

/// Route features from a Travel Solution (normalised to ~[0, 1]).
pub fn features_of(ts: &TravelSolution) -> [f32; N_FEATURES] {
    let mut f = [0f32; N_FEATURES];
    let n = ts.mct_queries.len() as f32;
    f[0] = n / 4.0; // number of connections
    if let Some(q0) = ts.mct_queries.first() {
        f[1] = q0.arr_time as f32 / 1440.0;
        f[2] = q0.dep_time as f32 / 1440.0;
        f[3] = q0.station as f32 / 512.0;
        f[4] = q0.arr_carrier_mkt as f32 / 128.0;
        f[5] = q0.conn_type as f32 / 4.0;
        f[6] = if q0.arr_codeshare { 1.0 } else { 0.0 };
        f[7] = q0.capacity as f32 / 600.0;
        f[8] = q0.day_of_week as f32 / 7.0;
    }
    if let Some(ql) = ts.mct_queries.last() {
        f[9] = ql.dep_time as f32 / 1440.0;
        f[10] = ql.next_station as f32 / 512.0;
    }
    f[11] = 1.0 - n / 5.0; // directness preference
    f
}

/// Datapath model of the FPGA Route Scoring kernel (from [17]: a tree
/// ensemble evaluated as a pipelined forest, one route per cycle once
/// full). Used by Table 3's combined-occupancy estimate.
#[derive(Debug, Clone, Copy)]
pub struct RsHwModel {
    pub clock_mhz: f64,
    /// Routes retired per cycle (forest replication factor).
    pub routes_per_cycle: f64,
}

impl Default for RsHwModel {
    fn default() -> Self {
        RsHwModel { clock_mhz: 220.0, routes_per_cycle: 1.0 }
    }
}

impl RsHwModel {
    pub fn routes_per_second(&self) -> f64 {
        self.clock_mhz * 1e6 * self.routes_per_cycle
    }

    /// §6.2: scoring moves inside the Domain Explorer, which must score all
    /// potential routes (tens of thousands) instead of Route Selection's
    /// few hundred. Time to score one user query's candidate set:
    pub fn time_to_score_us(&self, routes: usize) -> f64 {
        routes as f64 / self.routes_per_second() * 1e6
    }

    /// Fraction of board time consumed by scoring when co-located with MCT
    /// (Fig 14), given per-user-query route volume and query rate.
    pub fn occupancy(&self, routes_per_uq: usize, uq_per_second: f64) -> f64 {
        (routes_per_uq as f64 * uq_per_second / self.routes_per_second()).min(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_world, GeneratorConfig};
    use crate::workload::{generate_trace, TraceConfig};

    #[test]
    fn scorer_is_deterministic_and_bounded() {
        let s1 = RouteScorer::synthetic(1, 100, 6);
        let s2 = RouteScorer::synthetic(1, 100, 6);
        let x = [0.3f32; N_FEATURES];
        assert_eq!(s1.score(&x), s2.score(&x));
        for t in 0..50 {
            let x = [(t as f32) / 50.0; N_FEATURES];
            let y = s1.score(&x);
            assert!((0.0..1.0).contains(&y));
        }
    }

    #[test]
    fn different_routes_get_different_scores() {
        let s = RouteScorer::synthetic(2, 100, 6);
        let w = generate_world(&GeneratorConfig::small(5, 10));
        let trace = generate_trace(&TraceConfig::scaled(3, 5, 50.0), &w);
        let mut scores: Vec<f32> = trace.queries[0]
            .solutions
            .iter()
            .filter(|ts| !ts.is_direct())
            .take(20)
            .map(|ts| s.score(&features_of(ts)))
            .collect();
        scores.dedup();
        assert!(scores.len() > 5, "ensemble must discriminate: {scores:?}");
    }

    #[test]
    fn hw_model_scales_with_route_volume() {
        let m = RsHwModel::default();
        // §6.2: tens of thousands of routes inside the DE, still sub-ms.
        let t = m.time_to_score_us(50_000);
        assert!(t < 1_000.0, "50k routes must score in sub-ms: {t}µs");
        assert!(m.occupancy(50_000, 1000.0) < 0.5);
        assert_eq!(m.occupancy(1_000_000, 1e6), 1.0);
    }

    #[test]
    fn features_are_normalised() {
        let w = generate_world(&GeneratorConfig::small(7, 10));
        let trace = generate_trace(&TraceConfig::scaled(9, 3, 30.0), &w);
        for uq in &trace.queries {
            for ts in &uq.solutions {
                for f in features_of(ts) {
                    assert!((-0.1..=1.5).contains(&f), "feature {f}");
                }
            }
        }
    }
}
