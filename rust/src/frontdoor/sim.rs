//! The front door's DES twin: the same session plans, the same ladder
//! rules, the same router/admission policies as [`super::real`], run
//! against modeled single-FIFO replicas
//! ([`SimNodeSpec::request_service_us`]) on a virtual clock.
//!
//! Faults here are the *lossy* variant the real realisation's drain
//! semantics can't produce: a kill loses the request in service (its
//! window slot is freed) and reroutes the node's queue among the live
//! replicas — queries are lost only when no replica is live to take them.
//! Both realisations satisfy the same conservation law; they differ only
//! in which shed/lost bucket a fault lands in, which is exactly what the
//! conservation property test pins down.
//!
//! **Gray faults and the resilience ladder.** Gray windows
//! ([`FaultMode::Slowdown`](crate::controlplane::FaultMode) /
//! `ErrorRate` / `Hang`) never touch the up/down machinery: their
//! effects are sampled at *service start* from a seeded stream, exactly
//! like `cluster::sim`. Against them the
//! [`ResiliencePolicy`](crate::resilience::ResiliencePolicy) on the
//! front-door config runs deadlines on the accept clock, budgeted
//! retries with decorrelated-jitter backoff, tail-triggered hedges
//! (one logical request = one window slot, however many physical copies
//! fly; the first finisher wins and counts once), per-replica circuit
//! breakers consulted at routing time, and brown-out health weights
//! composed into the router — with the FPGA→CPU degradation ladder
//! rerouting a browning accelerator's traffic before shedding it.
//! Conservation extends to `offered = completed + shed_socket +
//! shed_queue + shed_deadline + lost`; a deadline-expired request is
//! cancelled work and is never counted completed.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashMap, HashSet, VecDeque};

use crate::cluster::{
    update_service_estimate, AdmissionPolicy, ClusterSimConfig, Router, SimEngine, SimNodeSpec,
};
use crate::controlplane::{FaultPlan, ScalingEvent};
use crate::coordinator::{DualClock, Overheads};
use crate::prng::Rng;
use crate::resilience::{
    CircuitBreaker, HealthScore, ResiliencePolicy, RetryBudget, BROWNOUT_DEGRADE_THRESHOLD,
};
use crate::telemetry::{
    AttemptKind, NullRecorder, Recorder, RingRecorder, ShedLane, StageEvent, CONTROL_ID,
};
use crate::workload::SessionPlan;

use super::{
    BackpressurePolicy, FrontdoorConfig, FrontdoorCounters, FrontdoorMode, FrontdoorReport,
    SessionGate,
};

/// Everything one simulated front-door run needs.
#[derive(Debug, Clone)]
pub struct FrontdoorSimConfig {
    pub cluster: ClusterSimConfig,
    pub frontdoor: FrontdoorConfig,
    pub faults: FaultPlan,
}

/// One DES occurrence. Ordering exists for the heap tuple; ties on the
/// nanosecond key are broken by push sequence, never by variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Accept { session: usize },
    Ready { session: usize, batch: usize },
    Done { node: usize, epoch: u64 },
    Kill { node: usize },
    Revive { node: usize },
    /// Retry of a failed logical request after its backoff.
    Resubmit { session: usize, batch: usize },
    /// Tail-latency hedge trigger; stale once the logical request moved
    /// past `attempt` (a retry invalidates the pending hedge).
    HedgeDue { session: usize, batch: usize, attempt: u32 },
}

/// One admitted request sitting in (or at the head of) a replica's FIFO.
#[derive(Debug, Clone, Copy)]
struct Req {
    session: usize,
    batch: usize,
    n_queries: usize,
    t_submit_us: f64,
    /// Cleared by a gray error draw at service start: the call still
    /// occupies the server, but completes as failed.
    ok: bool,
    /// A hedge copy (for first-winner attribution).
    is_hedge: bool,
    /// Kernel slice of the service span, fixed at service start
    /// (service × [`SimNodeSpec::kernel_share`]); carried to the
    /// `ExecEnd` trace event.
    kernel_us: f64,
}

/// Resilience state of one *logical* request — however many physical
/// copies (first attempt, retries, hedges) are in flight, the logical
/// request holds exactly one window slot and resolves exactly once.
#[derive(Debug, Clone, Copy)]
struct Logical {
    /// Physical copies currently in flight.
    copies: usize,
    /// Resolved (completed / deadline-shed / lost): later copies only do
    /// node-FIFO bookkeeping.
    resolved: bool,
    /// One hedge per logical request, ever.
    hedged: bool,
    /// Attempts used, first submission included.
    attempt: u32,
    /// Previous backoff (decorrelated jitter feeds on it).
    prev_backoff_us: f64,
    /// Node of the newest non-hedge copy — the hedge excludes it.
    first_node: usize,
}

/// A modeled replica: one FIFO server with drain-rate-matched service
/// times, a liveness flag, and an epoch that cancels the in-service
/// completion when a kill interrupts it.
#[derive(Debug, Clone, Default)]
struct SimNode {
    up: bool,
    epoch: u64,
    in_service: Option<Req>,
    queue: VecDeque<Req>,
    est_service_us: f64,
}

impl SimNode {
    fn depth(&self) -> usize {
        self.queue.len() + usize::from(self.in_service.is_some())
    }
}

struct Des<'a, R: Recorder> {
    plans: &'a [SessionPlan],
    policy: BackpressurePolicy,
    threads: usize,
    /// `ThreadPerSession` accept budget: the sessions that got a thread.
    accepted_set: Option<HashSet<usize>>,
    router: Router,
    admission: AdmissionPolicy,
    specs: &'a [SimNodeSpec],
    overheads: Overheads,
    nodes: Vec<SimNode>,
    gates: Vec<SessionGate>,
    thread_parked: Vec<usize>,
    counters: FrontdoorCounters,
    clock: DualClock,
    heap: BinaryHeap<Reverse<(u64, u64, Event)>>,
    seq: u64,
    fault_events: Vec<ScalingEvent>,
    // ---- resilience layer -------------------------------------------
    res: ResiliencePolicy,
    faults: &'a FaultPlan,
    /// Per-(session, batch) logical-request state.
    logical: HashMap<(usize, usize), Logical>,
    budget: RetryBudget,
    breakers: Vec<CircuitBreaker>,
    health: Vec<HealthScore>,
    /// Gray effects are drawn at service start, so the draw order is
    /// fixed by the (deterministic) event order.
    gray_rng: Rng,
    /// Backoff jitter draws.
    retry_rng: Rng,
    /// Half-open probe admission draws.
    breaker_rng: Rng,
    /// EWMA of winner latencies — the hedge trigger's expectation, like
    /// the real reactor's. Deliberately *fleet-wide*: a per-target
    /// estimate would learn the straggler's slowness as normal and stop
    /// hedging exactly where hedges matter. Zero until the first
    /// completion trains it (no hedges before that).
    lat_ewma: f64,
    /// Flight recorder. [`NullRecorder`] when tracing is off — the whole
    /// emission layer monomorphizes away. Recording is side-effect-only
    /// (no RNG draws, no event reordering), so a traced run replays the
    /// untraced run bit-for-bit.
    rec: R,
}

impl<R: Recorder> Des<'_, R> {
    fn push(&mut self, t_us: f64, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse(((t_us * 1_000.0).round() as u64, self.seq, ev)));
    }

    /// Stable request id shared with the real realisation — session in
    /// the high half, batch in the low — so deterministic sampling keeps
    /// the *same* requests in both worlds.
    fn rid(s: usize, b: usize) -> u64 {
        ((s as u64) << 32) | b as u64
    }

    fn n_up(&self) -> usize {
        self.nodes.iter().filter(|n| n.up).count()
    }

    /// Start service (node idle) or join the FIFO. `t_submit_us` is kept
    /// from admission time, so latency includes the queue wait — the same
    /// clock the real replica's tagged completion carries.
    fn enqueue(&mut self, node: usize, req: Req, t: f64) {
        if self.nodes[node].in_service.is_none() {
            self.start_service(node, req, t);
        } else {
            self.nodes[node].queue.push_back(req);
        }
    }

    /// Put `req` on the engine, sampling the node's gray effect *at
    /// service start* (the same instant the real decorator samples at
    /// call time): slowdowns stretch the service, error draws mark the
    /// call failed, hang draws add the stall.
    fn start_service(&mut self, node: usize, mut req: Req, t: f64) {
        let mut service_us = self.specs[node].request_service_us(&self.overheads, req.n_queries);
        let eff = self.faults.gray_at(node, t);
        if !eff.is_clean() {
            service_us *= eff.slow_factor;
            if eff.error_p > 0.0 && self.gray_rng.chance(eff.error_p) {
                req.ok = false;
            }
            if eff.hang_p > 0.0 && self.gray_rng.chance(eff.hang_p) {
                service_us += eff.stall_us;
            }
        }
        // Gray stretch is attributed proportionally: the kernel slice is
        // the clean share of however long the call actually takes.
        req.kernel_us = service_us * self.specs[node].kernel_share(&self.overheads, req.n_queries);
        self.rec.record(t, Self::rid(req.session, req.batch), StageEvent::ExecStart {
            replica: node,
        });
        self.nodes[node].in_service = Some(req);
        let epoch = self.nodes[node].epoch;
        self.push(t + service_us, Event::Done { node, epoch });
    }

    /// Mask breaker-open replicas out of `live`. Returns true when the
    /// breakers denied *every* otherwise-live replica — that, and only
    /// that, is counted a breaker rejection (partial masks are the
    /// breaker doing its routing job).
    fn apply_breaker_mask(&mut self, live: &mut [bool], t: f64) -> bool {
        if self.res.breaker.is_none() {
            return false;
        }
        let had_live = live.iter().any(|l| *l);
        let rng = &mut self.breaker_rng;
        for (l, br) in live.iter_mut().zip(self.breakers.iter_mut()) {
            if *l && !br.allows(t, rng) {
                *l = false;
            }
        }
        had_live && !live.iter().any(|l| *l)
    }

    /// Push the brown-out weights into the router (no-op unless the
    /// policy routes on health).
    fn apply_brownout(&mut self) {
        if self.res.brownout {
            let w: Vec<f64> = self.health.iter().map(HealthScore::weight).collect();
            self.router.set_health(w);
        }
    }

    /// Graceful-degradation ladder: a browning FPGA replica's traffic
    /// fails over to the least-loaded live CPU replica before shedding.
    fn degrade_target(&self, target: usize, live: &[bool], depths: &[usize]) -> Option<usize> {
        if !self.res.brownout
            || matches!(self.specs[target].engine, SimEngine::Cpu { .. })
            || self.health[target].weight() >= BROWNOUT_DEGRADE_THRESHOLD
        {
            return None;
        }
        (0..live.len())
            .filter(|&i| live[i] && matches!(self.specs[i].engine, SimEngine::Cpu { .. }))
            .min_by_key(|&i| depths[i])
    }

    /// Route a retry/hedge copy: already-admitted work, so no second
    /// admission pass — only liveness, breaker masks and (for hedges)
    /// exclusion of the node the first copy sits on.
    fn route_copy(&mut self, station: u32, t: f64, exclude: Option<usize>) -> Option<usize> {
        let depths: Vec<usize> = self.nodes.iter().map(SimNode::depth).collect();
        let mut live: Vec<bool> = self.nodes.iter().map(|n| n.up).collect();
        self.apply_breaker_mask(&mut live, t);
        if let Some(x) = exclude {
            if x < live.len() && live.iter().enumerate().any(|(i, l)| *l && i != x) {
                live[x] = false;
            }
        }
        self.apply_brownout();
        self.router.route_up(station, &depths, Some(&live))
    }

    /// Submit one more physical copy of a logical request already holding
    /// its window slot. Returns false when no replica could take it.
    fn submit_copy(&mut self, s: usize, b: usize, t: f64, is_hedge: bool) -> bool {
        let st = self.logical[&(s, b)];
        let exclude = if is_hedge { Some(st.first_node) } else { None };
        let Some(node) = self.route_copy(self.plans[s].station, t, exclude) else {
            return false;
        };
        let n_queries = self.plans[s].batches[b].n_queries;
        let entry = self.logical.get_mut(&(s, b)).expect("copy of a known logical");
        entry.copies += 1;
        if !is_hedge {
            entry.first_node = node;
        }
        self.counters.res.backend_requests += 1;
        let id = Self::rid(s, b);
        let kind = if is_hedge { AttemptKind::Hedge } else { AttemptKind::Retry };
        self.rec.record(t, id, StageEvent::AttemptStart { kind });
        self.rec.record(t, id, StageEvent::Routed { replica: node });
        self.rec.record(t, id, StageEvent::Enqueued { replica: node });
        let req = Req {
            session: s,
            batch: b,
            n_queries,
            t_submit_us: t,
            ok: true,
            is_hedge,
            kernel_us: 0.0,
        };
        self.enqueue(node, req, t);
        true
    }

    /// A physical copy died with its node (kill mid-service, or orphaned
    /// with nobody live). The logical request fails over to its surviving
    /// copies, then to the retry path.
    fn copy_died(&mut self, req: Req, t: f64) {
        let st = self.logical.get_mut(&(req.session, req.batch)).expect("copy state");
        st.copies -= 1;
        if st.resolved || st.copies > 0 {
            return;
        }
        self.fail_or_retry(req.session, req.batch, req.n_queries, t);
    }

    /// Last in-flight copy of an unresolved logical request failed:
    /// schedule a budgeted, deadline-aware retry — or resolve it lost.
    fn fail_or_retry(&mut self, s: usize, b: usize, n_queries: usize, t: f64) {
        let ready = self.plans[s].ready_us(b);
        let resolve_lost = |des: &mut Des<R>| {
            des.logical.get_mut(&(s, b)).expect("logical").resolved = true;
            des.counters.lost_queries += n_queries;
            des.gates[s].in_flight -= 1;
            des.rec.record(t, Self::rid(s, b), StageEvent::Lost { n_queries });
        };
        let Some(rp) = self.res.retry else {
            resolve_lost(self);
            return;
        };
        let attempt = self.logical[&(s, b)].attempt;
        if attempt >= rp.max_attempts {
            resolve_lost(self);
            return;
        }
        if !self.budget.try_spend() {
            self.counters.res.retry_budget_exhausted += 1;
            resolve_lost(self);
            return;
        }
        let prev = self.logical[&(s, b)].prev_backoff_us;
        let backoff = rp.backoff_us(prev, &mut self.retry_rng);
        let st = self.logical.get_mut(&(s, b)).expect("logical");
        st.prev_backoff_us = backoff;
        st.attempt += 1;
        self.counters.res.retries += 1;
        if self.res.expired(ready, t + backoff) {
            // The backoff alone would blow the deadline: cancel now.
            let st = self.logical.get_mut(&(s, b)).expect("logical");
            st.resolved = true;
            self.counters.shed_deadline_queries += n_queries;
            self.gates[s].in_flight -= 1;
            self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                lane: ShedLane::Deadline,
                n_queries,
            });
            return;
        }
        self.push(t + backoff, Event::Resubmit { session: s, batch: b });
    }

    /// `Resubmit` fired: issue the retry copy (unless the logical request
    /// resolved or expired while backing off).
    fn resubmit(&mut self, s: usize, b: usize, t: f64) {
        let Some(st) = self.logical.get(&(s, b)).copied() else { return };
        if st.resolved {
            return;
        }
        let n_queries = self.plans[s].batches[b].n_queries;
        if self.res.expired(self.plans[s].ready_us(b), t) {
            let st = self.logical.get_mut(&(s, b)).expect("logical");
            st.resolved = true;
            self.counters.shed_deadline_queries += n_queries;
            self.gates[s].in_flight -= 1;
            self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                lane: ShedLane::Deadline,
                n_queries,
            });
            return;
        }
        if !self.submit_copy(s, b, t, false) {
            // Nobody could take the retry (all dead, or breakers denied
            // everyone): consume the failure like any other attempt.
            self.fail_or_retry(s, b, n_queries, t);
        }
    }

    /// `HedgeDue` fired: duplicate the still-outstanding first attempt to
    /// a second replica, once per logical request.
    fn hedge_due(&mut self, s: usize, b: usize, attempt: u32, t: f64) {
        let Some(st) = self.logical.get(&(s, b)).copied() else { return };
        if st.resolved || st.hedged || st.attempt != attempt || st.copies == 0 {
            return;
        }
        if self.res.expired(self.plans[s].ready_us(b), t) {
            return; // pointless to duplicate work that can no longer count
        }
        if self.submit_copy(s, b, t, true) {
            self.logical.get_mut(&(s, b)).expect("logical").hedged = true;
            self.counters.res.hedges_issued += 1;
        }
    }

    /// The ladder's drain rule, identical to the real reactor: submit the
    /// session's parked batches while its window has room; an admission
    /// refusal bounces the batch (ladder policies) or drops it as
    /// shed-in-queue (`None`).
    fn drain_session(&mut self, s: usize, t: f64) {
        let window = self.policy.window();
        while self.gates[s].in_flight < window {
            let Some(&b) = self.gates[s].parked.front() else { break };
            let n_queries = self.plans[s].batches[b].n_queries;
            // A batch whose deadline passed while parked is cancelled
            // work: it never reaches a backend and never counts completed.
            if self.res.expired(self.plans[s].ready_us(b), t) {
                self.gates[s].parked.pop_front();
                self.thread_parked[s % self.threads] -= 1;
                self.counters.shed_deadline_queries += n_queries;
                self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                    lane: ShedLane::Deadline,
                    n_queries,
                });
                continue;
            }
            let depths: Vec<usize> = self.nodes.iter().map(SimNode::depth).collect();
            let mut live: Vec<bool> = self.nodes.iter().map(|n| n.up).collect();
            let all_denied = self.apply_breaker_mask(&mut live, t);
            self.apply_brownout();
            let routed = self.router.route_up(self.plans[s].station, &depths, Some(&live));
            let mut degraded = false;
            let target = routed.map(|n| match self.degrade_target(n, &live, &depths) {
                Some(cpu) => {
                    degraded = true;
                    cpu
                }
                None => n,
            });
            let admitted = target
                .map(|n| self.admission.admits(depths[n], self.nodes[n].est_service_us))
                .unwrap_or(false);
            let Some(node) = target.filter(|_| admitted) else {
                if all_denied {
                    self.counters.res.breaker_rejections += 1;
                }
                if self.policy.reparks_on_admission_shed() {
                    return; // stays parked; retried when a completion frees room
                }
                self.gates[s].parked.pop_front();
                self.thread_parked[s % self.threads] -= 1;
                self.counters.shed_queue_queries += n_queries;
                self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                    lane: ShedLane::Queue,
                    n_queries,
                });
                continue;
            };
            self.gates[s].parked.pop_front();
            self.thread_parked[s % self.threads] -= 1;
            self.gates[s].in_flight += 1;
            self.logical.insert(
                (s, b),
                Logical {
                    copies: 1,
                    resolved: false,
                    hedged: false,
                    attempt: 1,
                    prev_backoff_us: 0.0,
                    first_node: node,
                },
            );
            self.budget.deposit();
            self.counters.res.backend_requests += 1;
            if degraded {
                self.counters.res.degraded_requests += 1;
            }
            if let Some(h) = self.res.hedge {
                // Expectation is the fleet-wide winner EWMA (`lat_ewma`),
                // mirroring the real reactor — not the target node's own
                // estimate, which would learn a straggler's slowness as
                // normal and never hedge it. Untrained → no hedge yet.
                if self.lat_ewma > 0.0 {
                    if let Some(trig) = h.trigger_us(self.lat_ewma) {
                        self.push(t + trig, Event::HedgeDue { session: s, batch: b, attempt: 1 });
                    }
                }
            }
            let id = Self::rid(s, b);
            self.rec.record(t, id, StageEvent::Admitted);
            self.rec.record(t, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
            self.rec.record(t, id, StageEvent::Routed { replica: node });
            self.rec.record(t, id, StageEvent::Enqueued { replica: node });
            let req = Req {
                session: s,
                batch: b,
                n_queries,
                t_submit_us: t,
                ok: true,
                is_hedge: false,
                kernel_us: 0.0,
            };
            self.enqueue(node, req, t);
        }
    }

    fn drain_all(&mut self, t: f64) {
        for s in 0..self.plans.len() {
            if !self.gates[s].parked.is_empty() {
                self.drain_session(s, t);
            }
        }
    }

    fn accept(&mut self, s: usize, t: f64) {
        let refused = match &self.accepted_set {
            // Thread-per-session: no thread left ⇒ refused whole.
            Some(set) => !set.contains(&s),
            // Event mode: rung 3 of the ladder at the front edge.
            None => !self.policy.allows(self.thread_parked[s % self.threads]),
        };
        if refused {
            self.gates[s].refused = true;
            self.counters.sessions_shed += 1;
            self.counters.shed_socket_queries += self.plans[s].total_queries();
            // A session refused whole sheds every batch at the socket:
            // accept-less terminals, so lane totals still reconcile.
            for b in 0..self.plans[s].batches.len() {
                self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                    lane: ShedLane::Socket,
                    n_queries: self.plans[s].batches[b].n_queries,
                });
            }
        } else {
            self.counters.sessions_accepted += 1;
        }
    }

    fn ready(&mut self, s: usize, b: usize, t: f64) {
        if self.gates[s].refused {
            return;
        }
        let n_queries = self.plans[s].batches[b].n_queries;
        if self.policy.allows(self.thread_parked[s % self.threads]) {
            self.rec.record(t, Self::rid(s, b), StageEvent::Accepted { n_queries });
            self.gates[s].parked.push_back(b);
            self.thread_parked[s % self.threads] += 1;
            self.drain_session(s, t);
        } else {
            self.counters.shed_socket_queries += n_queries;
            self.rec.record(t, Self::rid(s, b), StageEvent::Shed {
                lane: ShedLane::Socket,
                n_queries,
            });
        }
    }

    fn complete(&mut self, node: usize, epoch: u64, t: f64) {
        if self.nodes[node].epoch != epoch {
            return; // cancelled by a kill
        }
        let req = self.nodes[node].in_service.take().expect("live Done ⇒ in service");
        self.rec.record(t, Self::rid(req.session, req.batch), StageEvent::ExecEnd {
            replica: node,
            kernel_us: req.kernel_us,
            ok: req.ok,
        });
        let latency_us = t - req.t_submit_us;
        let deadline_miss = self.resolve(req, latency_us, t);
        if let Some(next) = self.nodes[node].queue.pop_front() {
            self.start_service(node, next, t);
        }
        let prev = self.nodes[node].est_service_us;
        self.nodes[node].est_service_us =
            update_service_estimate(prev, latency_us, self.nodes[node].depth());
        // Per-replica signals the resilience policies feed on: the
        // breaker's depth-normalized latency/error EWMAs, and the brown-out
        // health score (a deadline miss is a partial strike — the replica
        // answered, too late to count).
        let norm = latency_us / (self.nodes[node].depth() as f64 + 1.0);
        if self.res.breaker.is_some() {
            self.breakers[node].on_outcome(t, req.ok, norm);
        }
        if self.res.brownout {
            if let Some(tr) = self.health[node].observe_at(t, req.ok, deadline_miss, norm) {
                self.rec.record(tr.t_us, CONTROL_ID, StageEvent::Health {
                    replica: node,
                    degraded: tr.degraded,
                });
            }
        }
        self.drain_all(t);
    }

    /// A physical copy finished: resolve its logical request exactly once.
    /// Returns whether the copy came back past its deadline (for the
    /// health signal). The winner — the first OK copy inside the deadline
    /// — records latency and counts completed; an expired response is
    /// cancelled work (`shed_deadline`, never completed); a failed copy
    /// defers to in-flight twins before the retry path.
    fn resolve(&mut self, req: Req, latency_us: f64, t: f64) -> bool {
        let key = (req.session, req.batch);
        let expired = self.res.expired(self.plans[req.session].ready_us(req.batch), t);
        let st = self.logical.get_mut(&key).expect("completion of a known logical");
        st.copies -= 1;
        if st.resolved {
            return expired; // a twin already settled this request
        }
        if req.ok && !expired {
            st.resolved = true;
            let accept_lat = (t - self.plans[req.session].ready_us(req.batch)).max(latency_us);
            self.clock.record(accept_lat, latency_us);
            self.lat_ewma = if self.lat_ewma > 0.0 {
                self.lat_ewma + 0.2 * (latency_us - self.lat_ewma)
            } else {
                latency_us
            };
            self.counters.completed_requests += 1;
            self.counters.completed_queries += req.n_queries;
            self.gates[req.session].in_flight -= 1;
            if req.is_hedge {
                self.counters.res.hedge_wins += 1;
            }
            self.rec.record(t, Self::rid(req.session, req.batch), StageEvent::Completed {
                n_queries: req.n_queries,
            });
            return false;
        }
        if expired {
            st.resolved = true;
            self.counters.shed_deadline_queries += req.n_queries;
            self.gates[req.session].in_flight -= 1;
            self.rec.record(t, Self::rid(req.session, req.batch), StageEvent::Shed {
                lane: ShedLane::Deadline,
                n_queries: req.n_queries,
            });
            return true;
        }
        // Failed copy, inside the deadline: an in-flight twin may still
        // win; only the last copy standing goes to the retry path.
        if st.copies == 0 {
            self.fail_or_retry(req.session, req.batch, req.n_queries, t);
        }
        false
    }

    fn kill(&mut self, node: usize, t: f64) {
        if !self.nodes[node].up {
            return;
        }
        self.nodes[node].up = false;
        self.nodes[node].epoch += 1;
        // The request on the engine dies with the node; with no retry
        // policy its window slot is freed as lost, with one it re-enters
        // through the backoff path like any other failed copy.
        if let Some(req) = self.nodes[node].in_service.take() {
            self.copy_died(req, t);
        }
        // Queued requests were already admitted once — reroute them among
        // the live replicas without a second admission pass; with nobody
        // live the copy dies and the retry path (if any) takes over.
        let orphans: Vec<Req> = self.nodes[node].queue.drain(..).collect();
        for req in orphans {
            let depths: Vec<usize> = self.nodes.iter().map(SimNode::depth).collect();
            let live: Vec<bool> = self.nodes.iter().map(|n| n.up).collect();
            let station = self.plans[req.session].station;
            match self.router.route_up(station, &depths, Some(&live)) {
                Some(target) => {
                    self.rec.record(t, Self::rid(req.session, req.batch), StageEvent::Enqueued {
                        replica: target,
                    });
                    self.enqueue(target, req, t);
                }
                None => self.copy_died(req, t),
            }
        }
        let up_after = self.n_up();
        self.fault_events.push(ScalingEvent::fail(
            t,
            self.specs[node].class_name,
            node,
            up_after,
        ));
    }

    fn revive(&mut self, node: usize, t: f64) {
        if self.nodes[node].up {
            return;
        }
        self.nodes[node].up = true;
        let up_after = self.n_up();
        self.fault_events.push(ScalingEvent::recover(
            t,
            self.specs[node].class_name,
            node,
            up_after,
        ));
        self.drain_all(t);
    }
}

/// Run the session plans through the simulated front door. Deterministic:
/// same config + plans ⇒ bit-identical report — with or without tracing,
/// because recording never draws RNG or reorders events.
pub fn sim_frontdoor(cfg: &FrontdoorSimConfig, plans: &[SessionPlan]) -> FrontdoorReport {
    match cfg.frontdoor.trace {
        None => sim_frontdoor_with(cfg, plans, NullRecorder),
        Some(spec) => sim_frontdoor_with(cfg, plans, RingRecorder::new(spec)),
    }
}

fn sim_frontdoor_with<R: Recorder>(
    cfg: &FrontdoorSimConfig,
    plans: &[SessionPlan],
    rec: R,
) -> FrontdoorReport {
    let threads = match cfg.frontdoor.mode {
        FrontdoorMode::Event => cfg.frontdoor.event_threads.max(1),
        FrontdoorMode::ThreadPerSession { .. } => 1,
    };
    let accepted_set = match cfg.frontdoor.mode {
        FrontdoorMode::ThreadPerSession { max_threads } => {
            let mut order: Vec<usize> = (0..plans.len()).collect();
            order.sort_by(|&a, &b| plans[a].accept_us.total_cmp(&plans[b].accept_us));
            Some(order.into_iter().take(max_threads).collect::<HashSet<usize>>())
        }
        FrontdoorMode::Event => None,
    };
    let n_nodes = cfg.cluster.specs.len();
    let res = cfg.frontdoor.resilience;
    let seed = cfg.cluster.route_seed;
    let mut des = Des {
        plans,
        policy: cfg.frontdoor.backpressure,
        threads,
        accepted_set,
        router: cfg.cluster.router(),
        admission: cfg.cluster.admission,
        specs: &cfg.cluster.specs,
        overheads: cfg.cluster.overheads.clone(),
        nodes: vec![SimNode { up: true, ..Default::default() }; n_nodes],
        gates: vec![SessionGate::default(); plans.len()],
        thread_parked: vec![0; threads],
        counters: FrontdoorCounters::default(),
        clock: DualClock::new(),
        heap: BinaryHeap::new(),
        seq: 0,
        fault_events: Vec::new(),
        res,
        faults: &cfg.faults,
        logical: HashMap::new(),
        budget: res.budget(),
        breakers: vec![
            CircuitBreaker::new(res.breaker.unwrap_or_default());
            n_nodes
        ],
        health: vec![HealthScore::new(); n_nodes],
        gray_rng: Rng::new(seed ^ 0x62AF_17),
        retry_rng: Rng::new(seed ^ 0x8E_774),
        breaker_rng: Rng::new(seed ^ 0xB4EA_C3),
        lat_ewma: 0.0,
        rec,
    };
    for (s, p) in plans.iter().enumerate() {
        des.push(p.accept_us, Event::Accept { session: s });
        for b in 0..p.batches.len() {
            des.push(p.ready_us(b), Event::Ready { session: s, batch: b });
        }
    }
    // Only fail-stop faults touch the up/down machinery; gray windows act
    // on the serving path via `gray_at` sampling at service start.
    for f in cfg.faults.kills() {
        des.push(f.at_us, Event::Kill { node: f.node });
        des.push(f.at_us + f.down_us, Event::Revive { node: f.node });
    }
    des.counters.res.gray_fault_windows = cfg.faults.grays().len();

    let mut t_end_us = 0.0f64;
    while let Some(Reverse((key, _, ev))) = des.heap.pop() {
        let t = key as f64 / 1_000.0;
        t_end_us = t_end_us.max(t);
        match ev {
            Event::Accept { session } => des.accept(session, t),
            Event::Ready { session, batch } => des.ready(session, batch, t),
            Event::Done { node, epoch } => des.complete(node, epoch, t),
            Event::Kill { node } => des.kill(node, t),
            Event::Revive { node } => des.revive(node, t),
            Event::Resubmit { session, batch } => des.resubmit(session, batch, t),
            Event::HedgeDue { session, batch, attempt } => {
                des.hedge_due(session, batch, attempt, t)
            }
        }
    }
    // Batches still parked when the heap runs dry can only mean the fleet
    // ended the run dead (no completion will ever drain them): count them
    // shed-in-queue so conservation stays structural, never silent.
    for s in 0..plans.len() {
        while let Some(b) = des.gates[s].parked.pop_front() {
            let n_queries = plans[s].batches[b].n_queries;
            des.counters.shed_queue_queries += n_queries;
            des.rec.record(t_end_us, Des::<R>::rid(s, b), StageEvent::Shed {
                lane: ShedLane::Queue,
                n_queries,
            });
        }
    }
    des.counters.res.breaker_trips = des.breakers.iter().map(CircuitBreaker::trips).sum();
    // Breaker state changes were logged inside the breakers on the same
    // virtual clock; drain them into the trace as control events.
    for (i, br) in des.breakers.iter_mut().enumerate() {
        for tr in br.take_transitions() {
            des.rec.record(tr.t_us, CONTROL_ID, StageEvent::Breaker {
                replica: i,
                from: tr.from.into(),
                to: tr.to.into(),
            });
        }
    }

    let label = format!("{} sessions | {}", plans.len(), cfg.cluster.label());
    let counters = des.counters;
    let fault_events = des.fault_events;
    let mut trace = des.rec.into_trace();
    trace.sort();
    let mut report = FrontdoorReport::assemble(
        label,
        &cfg.frontdoor,
        plans,
        counters,
        &mut des.clock,
        t_end_us / 1e6,
        fault_events,
    );
    report.trace = trace;
    debug_assert!(report.conserves_queries(), "{}", report.summary());
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RoutePolicy;
    use crate::resilience::{BreakerConfig, HedgePolicy, RetryPolicy};
    use crate::workload::{session_plans, RateSchedule};

    fn burst_plans(seed: u64, sessions: usize, batches: usize, batch_q: usize) -> Vec<SessionPlan> {
        session_plans(seed, &RateSchedule::constant(1e9), sessions, batches, batch_q, 0.0, 8)
    }

    fn event_cfg(nodes: usize, policy: BackpressurePolicy) -> FrontdoorSimConfig {
        FrontdoorSimConfig {
            cluster: ClusterSimConfig::v2_cloud(nodes, 2)
                .with_route(RoutePolicy::RoundRobin)
                .with_admission(AdmissionPolicy::QueueCap(24)),
            frontdoor: FrontdoorConfig::event(2, policy),
            faults: FaultPlan::none(),
        }
    }

    #[test]
    fn sim_frontdoor_is_deterministic() {
        let cfg = event_cfg(2, BackpressurePolicy::SocketShed { window: 2, pending_cap: 4 });
        let plans = burst_plans(11, 20, 8, 8);
        let a = sim_frontdoor(&cfg, &plans);
        let b = sim_frontdoor(&cfg, &plans);
        assert!(a.conserves_queries(), "{}", a.summary());
        assert_eq!(a.completed_queries, b.completed_queries);
        assert_eq!(a.shed_socket_queries, b.shed_socket_queries);
        assert_eq!(a.shed_queue_queries, b.shed_queue_queries);
        assert_eq!(a.accept_p99_us.to_bits(), b.accept_p99_us.to_bits());
        assert_eq!(a.goodput_qps.to_bits(), b.goodput_qps.to_bits());
    }

    #[test]
    fn kill_loses_exactly_the_request_in_service() {
        // Burst everything at t≈0, then kill node 0 mid-way through its
        // first service: the in-service request dies with the node (one
        // batch of 8 queries), the node's queue reroutes to node 1, and
        // the run still terminates and conserves.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(2, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        let svc_us = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::kill(0, 0.5 * svc_us, 50.0 * svc_us);
        let plans = burst_plans(3, 12, 6, 8);
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert_eq!(r.lost_queries, 8, "{}", r.summary());
        assert_eq!(r.fault_events.len(), 2);
        assert_eq!(r.completed_queries, r.offered_queries - 8);
        assert_eq!(r.sessions_accepted, 12);
        assert!(r.fault_events[0].line().contains("fail"));
    }

    #[test]
    fn revive_resumes_parked_sessions_and_the_accept_clock_shows_the_outage() {
        // A single replica killed mid-service for a full virtual second:
        // batches park behind the window through the outage, the revive
        // drains them, and the accept clock — unlike the submit clock —
        // carries the wait.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(1, BackpressurePolicy::Window { window: 1 });
        cfg.cluster = ClusterSimConfig::v2_cloud(1, 2)
            .with_route(RoutePolicy::RoundRobin)
            .with_admission(AdmissionPolicy::Open);
        let svc_us = spec.request_service_us(&cfg.cluster.overheads, 8);
        let down_us = 1e6;
        cfg.faults = FaultPlan::kill(0, 0.5 * svc_us, down_us);
        // Two window-1 sessions: at the kill, session 0's batch is in
        // service (lost with the node) and session 1's batch is queued
        // behind it (orphaned with no live replica to take it — lost too).
        let plans = burst_plans(5, 2, 4, 8);
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert_eq!(r.lost_queries, 16, "{}", r.summary());
        assert_eq!(r.completed_queries, r.offered_queries - 16);
        assert_eq!(r.fault_events.len(), 2);
        assert!(r.fault_events[1].line().contains("recover"));
        assert!(
            r.accept_p99_us > 0.5 * down_us,
            "the outage wait must surface on the accept clock: p99 {} µs",
            r.accept_p99_us
        );
        assert!(r.omission_gap_us() > 0.0, "{}", r.summary());
    }

    #[test]
    fn backpressure_policies_separate_in_the_sim() {
        // The engineered overload scenario the crossval ranks: 2× offered
        // load, bursty 16-batch sessions, queue-capped replicas. Window
        // completes the most (lossless parking), None loses admission
        // refusals, SocketShed turns sessions away whole — while on the
        // accept clock SocketShed is fastest (it only serves what fits)
        // and Window slowest (it queues the whole backlog client-side).
        let spec = SimNodeSpec::v2_cloud(2);
        let o = ClusterSimConfig::v2_cloud(2, 2).overheads;
        let node_rps = spec.capacity_qps(&o, 16) / 16.0;
        let rate = 2.0 * 2.0 * node_rps / 16.0; // 2× the 2-node fleet, 16 req/session
        let plans = session_plans(7, &RateSchedule::constant(rate), 40, 16, 16, 0.0, 8);
        let run = |policy| sim_frontdoor(&event_cfg(2, policy), &plans);
        let none = run(BackpressurePolicy::None);
        let window = run(BackpressurePolicy::Window { window: 2 });
        let socket = run(BackpressurePolicy::SocketShed { window: 2, pending_cap: 2 });

        for r in [&none, &window, &socket] {
            assert!(r.conserves_queries(), "{}", r.summary());
        }
        assert_eq!(window.completed_queries, window.offered_queries, "window is lossless");
        assert!(none.shed_queue_queries > 0, "{}", none.summary());
        assert!(socket.shed_socket_queries > 0, "{}", socket.summary());
        assert!(socket.sessions_shed > 0, "socket refuses sessions whole");
        // Goodput ranking: window > none > socket.
        assert!(
            window.completed_queries > none.completed_queries
                && none.completed_queries > socket.completed_queries,
            "completed: window {} none {} socket {}",
            window.completed_queries,
            none.completed_queries,
            socket.completed_queries
        );
        // Accept-clock tail ranking: socket < none < window.
        assert!(
            socket.accept_p99_us < none.accept_p99_us
                && none.accept_p99_us < window.accept_p99_us,
            "accept p99: socket {} none {} window {}",
            socket.accept_p99_us,
            none.accept_p99_us,
            window.accept_p99_us
        );
        // The omission gap is what the accept clock surfaces: under the
        // window policy batches wait parked far longer than they queue.
        assert!(window.omission_gap_us() > 0.0, "{}", window.summary());
    }

    #[test]
    fn resilient_sim_is_deterministic() {
        // The full mechanism stack (deadline + retry + hedge + breaker +
        // brownout) under a mixed gray-fault plan must stay bit-identical
        // across runs: every stochastic draw comes from a seeded stream.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(3, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        let svc = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::none()
            .and_slowdown(0, 0.0, 1e9, 8.0)
            .and_error_rate(1, 0.0, 1e9, 0.4);
        cfg.frontdoor = cfg.frontdoor.with_resilience(
            ResiliencePolicy::none()
                .with_deadline(60.0 * svc)
                .with_retry(RetryPolicy::new(3, 0.5 * svc, 8.0 * svc))
                .with_budget_ratio(0.5)
                .with_hedge(HedgePolicy::new(3.0))
                .with_breaker(BreakerConfig { open_us: 40.0 * svc, ..Default::default() })
                .with_brownout(),
        );
        let plans = burst_plans(17, 24, 6, 8);
        let a = sim_frontdoor(&cfg, &plans);
        let b = sim_frontdoor(&cfg, &plans);
        assert!(a.conserves_queries(), "{}", a.summary());
        assert_eq!(a.completed_queries, b.completed_queries);
        assert_eq!(a.shed_deadline_queries, b.shed_deadline_queries);
        assert_eq!(a.lost_queries, b.lost_queries);
        assert_eq!(a.res, b.res, "resilience counters must replay exactly");
        assert_eq!(a.accept_p99_us.to_bits(), b.accept_p99_us.to_bits());
        assert!(a.res.gray_fault_windows == 2, "{}", a.summary());
    }

    #[test]
    fn unsampled_trace_reconciles_with_the_report_exactly() {
        use crate::telemetry::TraceSpec;
        // Overload at the socket + gray errors + a deadline + a thin
        // retry budget: several shed/lost lanes fire at once. The flight
        // recorder's lane totals must re-derive the report's counters
        // *exactly*, every request must get exactly one terminal event,
        // and tracing must not perturb the run it observes.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(2, BackpressurePolicy::SocketShed { window: 2, pending_cap: 2 });
        let svc = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::none().and_error_rate(0, 0.0, 1e9, 0.5);
        cfg.frontdoor = cfg.frontdoor.with_resilience(
            ResiliencePolicy::none()
                .with_deadline(40.0 * svc)
                .with_retry(RetryPolicy::new(2, 0.5 * svc, 4.0 * svc))
                .with_budget_ratio(0.2),
        );
        let plans = burst_plans(31, 24, 6, 8);
        let plain = sim_frontdoor(&cfg, &plans);
        cfg.frontdoor = cfg.frontdoor.with_trace(TraceSpec::full());
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert!(r.trace.is_complete(), "a full-spec trace must not sample or drop");
        assert!(r.completed_queries > 0 && r.shed_socket_queries > 0, "{}", r.summary());

        let lanes = r.trace.lane_counts();
        assert_eq!(lanes.completed_queries, r.completed_queries);
        assert_eq!(lanes.completed_requests, r.completed_requests);
        assert_eq!(lanes.shed_socket_queries, r.shed_socket_queries);
        assert_eq!(lanes.shed_queue_queries, r.shed_queue_queries);
        assert_eq!(lanes.shed_deadline_queries, r.shed_deadline_queries);
        assert_eq!(lanes.lost_queries, r.lost_queries);
        assert_eq!(lanes.terminal_queries(), r.offered_queries, "trace-side conservation");
        for (id, n) in r.trace.terminals_per_request() {
            assert_eq!(n, 1, "request {id:#x} must resolve exactly once");
        }
        // The observer effect must be zero: bit-identical to the
        // untraced run.
        assert_eq!(plain.completed_queries, r.completed_queries);
        assert_eq!(plain.lost_queries, r.lost_queries);
        assert_eq!(plain.res, r.res);
        assert_eq!(plain.accept_p99_us.to_bits(), r.accept_p99_us.to_bits());
    }

    #[test]
    fn deadline_expired_work_is_shed_never_completed() {
        // One replica, deep client-side windows, a deadline a few services
        // wide: the backlog blows the deadline for most of the burst.
        // Expired work lands in shed_deadline — and because a winner is
        // only ever recorded inside its deadline, every recorded accept
        // latency (p99 included) stays under it.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(1, BackpressurePolicy::Window { window: 4 });
        cfg.cluster = ClusterSimConfig::v2_cloud(1, 2)
            .with_route(RoutePolicy::RoundRobin)
            .with_admission(AdmissionPolicy::Open);
        let svc = spec.request_service_us(&cfg.cluster.overheads, 8);
        let deadline = 3.0 * svc;
        cfg.frontdoor =
            cfg.frontdoor.with_resilience(ResiliencePolicy::none().with_deadline(deadline));
        let plans = burst_plans(9, 8, 6, 8);
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert!(r.shed_deadline_queries > 0, "{}", r.summary());
        assert!(r.completed_queries > 0, "{}", r.summary());
        assert!(
            r.completed_queries + r.shed_deadline_queries == r.offered_queries,
            "every query either completed in time or was cancelled: {}",
            r.summary()
        );
        assert!(
            r.accept_p99_us <= deadline + 1.0,
            "no completion past the deadline may be recorded: p99 {} vs deadline {}",
            r.accept_p99_us,
            deadline
        );
    }

    #[test]
    fn retries_recover_gray_errors() {
        // Node 0 fails 70% of its calls; node 1 is clean. Without a retry
        // policy those failures are lost queries; with budgeted backoff
        // retries nearly all of them land on a second attempt.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(2, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        let svc = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::none().and_error_rate(0, 0.0, 1e9, 0.7);
        let plans = burst_plans(13, 16, 6, 8);
        let plain = sim_frontdoor(&cfg, &plans);
        cfg.frontdoor = cfg.frontdoor.with_resilience(
            ResiliencePolicy::none()
                .with_retry(RetryPolicy::new(4, 0.5 * svc, 8.0 * svc))
                .with_budget_ratio(1.0),
        );
        let retried = sim_frontdoor(&cfg, &plans);
        assert!(plain.conserves_queries(), "{}", plain.summary());
        assert!(retried.conserves_queries(), "{}", retried.summary());
        assert!(plain.lost_queries > 0, "{}", plain.summary());
        assert!(
            retried.lost_queries * 4 < plain.lost_queries,
            "retries must recover most gray errors: {} vs {}",
            retried.lost_queries,
            plain.lost_queries
        );
        assert!(retried.res.retries > 0, "{}", retried.summary());
        assert!(
            retried.res.backend_requests > plain.res.backend_requests,
            "retries are extra physical load"
        );
    }

    #[test]
    fn hedging_rescues_hung_calls_and_cuts_the_tail() {
        // Node 0 stalls 20% of its calls for 40 services — the classic
        // gray straggler. A tail-triggered hedge reissues the stalled
        // request to a clean replica, which wins; accept p99 drops well
        // below the stall while the duplicate load stays bounded.
        let spec = SimNodeSpec::v2_cloud(2);
        let o = ClusterSimConfig::v2_cloud(4, 2).overheads;
        let svc = spec.request_service_us(&o, 8);
        let node_rps = spec.capacity_qps(&o, 8) / 8.0;
        let rate = 0.3 * 4.0 * node_rps / 8.0;
        let plans = session_plans(21, &RateSchedule::constant(rate), 60, 8, 8, 0.0, 8);
        let mut cfg = event_cfg(4, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        cfg.faults = FaultPlan::none().and_hang(0, 0.0, 1e9, 0.2, 40.0 * svc);
        let plain = sim_frontdoor(&cfg, &plans);
        cfg.frontdoor = cfg
            .frontdoor
            .with_resilience(ResiliencePolicy::none().with_hedge(HedgePolicy::new(3.0)));
        let hedged = sim_frontdoor(&cfg, &plans);
        assert!(plain.conserves_queries(), "{}", plain.summary());
        assert!(hedged.conserves_queries(), "{}", hedged.summary());
        assert!(hedged.res.hedges_issued > 0, "{}", hedged.summary());
        assert!(hedged.res.hedge_wins > 0, "{}", hedged.summary());
        assert!(
            hedged.accept_p99_us < 0.6 * plain.accept_p99_us,
            "hedging must cut the stall tail: {} vs {}",
            hedged.accept_p99_us,
            plain.accept_p99_us
        );
        assert!(
            hedged.backend_load_factor() < 1.5,
            "hedge amplification stays bounded: {}",
            hedged.backend_load_factor()
        );
        assert_eq!(hedged.completed_queries, hedged.offered_queries, "hedges lose nothing");
    }

    #[test]
    fn breaker_trips_on_a_high_error_replica() {
        // Node 0 fails 90% of its calls. With retry alone every second
        // request burns attempts against it; adding the breaker trips it
        // open after min_observations and steers traffic to the clean
        // replica, recovering more of the offered load.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(2, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        let svc = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::none().and_error_rate(0, 0.0, 1e9, 0.9);
        let retry = ResiliencePolicy::none()
            .with_retry(RetryPolicy::new(3, 0.5 * svc, 8.0 * svc))
            .with_budget_ratio(0.5);
        let plans = burst_plans(29, 24, 6, 8);
        cfg.frontdoor = cfg.frontdoor.with_resilience(retry);
        let retried = sim_frontdoor(&cfg, &plans);
        cfg.frontdoor = cfg.frontdoor.with_resilience(
            retry.with_breaker(BreakerConfig { open_us: 50.0 * svc, ..Default::default() }),
        );
        let broken = sim_frontdoor(&cfg, &plans);
        assert!(retried.conserves_queries(), "{}", retried.summary());
        assert!(broken.conserves_queries(), "{}", broken.summary());
        assert!(broken.res.breaker_trips > 0, "{}", broken.summary());
        assert!(
            broken.lost_queries <= retried.lost_queries,
            "tripping the bad replica cannot lose more: {} vs {}",
            broken.lost_queries,
            retried.lost_queries
        );
    }
}
