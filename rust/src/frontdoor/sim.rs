//! The front door's DES twin: the same session plans, the same ladder
//! rules, the same router/admission policies as [`super::real`], run
//! against modeled single-FIFO replicas
//! ([`SimNodeSpec::request_service_us`]) on a virtual clock.
//!
//! Faults here are the *lossy* variant the real realisation's drain
//! semantics can't produce: a kill loses the request in service (its
//! window slot is freed) and reroutes the node's queue among the live
//! replicas — queries are lost only when no replica is live to take them.
//! Both realisations satisfy the same conservation law; they differ only
//! in which shed/lost bucket a fault lands in, which is exactly what the
//! conservation property test pins down.

use std::cmp::Reverse;
use std::collections::{BinaryHeap, HashSet, VecDeque};

use crate::cluster::{
    update_service_estimate, AdmissionPolicy, ClusterSimConfig, Router, SimNodeSpec,
};
use crate::controlplane::{FaultPlan, ScalingEvent};
use crate::coordinator::{DualClock, Overheads};
use crate::workload::SessionPlan;

use super::{
    BackpressurePolicy, FrontdoorConfig, FrontdoorCounters, FrontdoorMode, FrontdoorReport,
    SessionGate,
};

/// Everything one simulated front-door run needs.
#[derive(Debug, Clone)]
pub struct FrontdoorSimConfig {
    pub cluster: ClusterSimConfig,
    pub frontdoor: FrontdoorConfig,
    pub faults: FaultPlan,
}

/// One DES occurrence. Ordering exists for the heap tuple; ties on the
/// nanosecond key are broken by push sequence, never by variant.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
enum Event {
    Accept { session: usize },
    Ready { session: usize, batch: usize },
    Done { node: usize, epoch: u64 },
    Kill { node: usize },
    Revive { node: usize },
}

/// One admitted request sitting in (or at the head of) a replica's FIFO.
#[derive(Debug, Clone, Copy)]
struct Req {
    session: usize,
    batch: usize,
    n_queries: usize,
    t_submit_us: f64,
}

/// A modeled replica: one FIFO server with drain-rate-matched service
/// times, a liveness flag, and an epoch that cancels the in-service
/// completion when a kill interrupts it.
#[derive(Debug, Clone, Default)]
struct SimNode {
    up: bool,
    epoch: u64,
    in_service: Option<Req>,
    queue: VecDeque<Req>,
    est_service_us: f64,
}

impl SimNode {
    fn depth(&self) -> usize {
        self.queue.len() + usize::from(self.in_service.is_some())
    }
}

struct Des<'a> {
    plans: &'a [SessionPlan],
    policy: BackpressurePolicy,
    threads: usize,
    /// `ThreadPerSession` accept budget: the sessions that got a thread.
    accepted_set: Option<HashSet<usize>>,
    router: Router,
    admission: AdmissionPolicy,
    specs: &'a [SimNodeSpec],
    overheads: Overheads,
    nodes: Vec<SimNode>,
    gates: Vec<SessionGate>,
    thread_parked: Vec<usize>,
    counters: FrontdoorCounters,
    clock: DualClock,
    heap: BinaryHeap<Reverse<(u64, u64, Event)>>,
    seq: u64,
    fault_events: Vec<ScalingEvent>,
}

impl Des<'_> {
    fn push(&mut self, t_us: f64, ev: Event) {
        self.seq += 1;
        self.heap.push(Reverse(((t_us * 1_000.0).round() as u64, self.seq, ev)));
    }

    fn n_up(&self) -> usize {
        self.nodes.iter().filter(|n| n.up).count()
    }

    /// Start service (node idle) or join the FIFO. `t_submit_us` is kept
    /// from admission time, so latency includes the queue wait — the same
    /// clock the real replica's tagged completion carries.
    fn enqueue(&mut self, node: usize, req: Req, t: f64) {
        if self.nodes[node].in_service.is_none() {
            let service_us = self.specs[node].request_service_us(&self.overheads, req.n_queries);
            self.nodes[node].in_service = Some(req);
            let epoch = self.nodes[node].epoch;
            self.push(t + service_us, Event::Done { node, epoch });
        } else {
            self.nodes[node].queue.push_back(req);
        }
    }

    /// The ladder's drain rule, identical to the real reactor: submit the
    /// session's parked batches while its window has room; an admission
    /// refusal bounces the batch (ladder policies) or drops it as
    /// shed-in-queue (`None`).
    fn drain_session(&mut self, s: usize, t: f64) {
        let window = self.policy.window();
        while self.gates[s].in_flight < window {
            let Some(&b) = self.gates[s].parked.front() else { break };
            let n_queries = self.plans[s].batches[b].n_queries;
            let depths: Vec<usize> = self.nodes.iter().map(SimNode::depth).collect();
            let live: Vec<bool> = self.nodes.iter().map(|n| n.up).collect();
            let target = self.router.route_up(self.plans[s].station, &depths, Some(&live));
            let admitted = target
                .map(|n| self.admission.admits(depths[n], self.nodes[n].est_service_us))
                .unwrap_or(false);
            let Some(node) = target.filter(|_| admitted) else {
                if self.policy.reparks_on_admission_shed() {
                    return; // stays parked; retried when a completion frees room
                }
                self.gates[s].parked.pop_front();
                self.thread_parked[s % self.threads] -= 1;
                self.counters.shed_queue_queries += n_queries;
                continue;
            };
            self.gates[s].parked.pop_front();
            self.thread_parked[s % self.threads] -= 1;
            self.gates[s].in_flight += 1;
            self.enqueue(node, Req { session: s, batch: b, n_queries, t_submit_us: t }, t);
        }
    }

    fn drain_all(&mut self, t: f64) {
        for s in 0..self.plans.len() {
            if !self.gates[s].parked.is_empty() {
                self.drain_session(s, t);
            }
        }
    }

    fn accept(&mut self, s: usize) {
        let refused = match &self.accepted_set {
            // Thread-per-session: no thread left ⇒ refused whole.
            Some(set) => !set.contains(&s),
            // Event mode: rung 3 of the ladder at the front edge.
            None => !self.policy.allows(self.thread_parked[s % self.threads]),
        };
        if refused {
            self.gates[s].refused = true;
            self.counters.sessions_shed += 1;
            self.counters.shed_socket_queries += self.plans[s].total_queries();
        } else {
            self.counters.sessions_accepted += 1;
        }
    }

    fn ready(&mut self, s: usize, b: usize, t: f64) {
        if self.gates[s].refused {
            return;
        }
        let n_queries = self.plans[s].batches[b].n_queries;
        if self.policy.allows(self.thread_parked[s % self.threads]) {
            self.gates[s].parked.push_back(b);
            self.thread_parked[s % self.threads] += 1;
            self.drain_session(s, t);
        } else {
            self.counters.shed_socket_queries += n_queries;
        }
    }

    fn complete(&mut self, node: usize, epoch: u64, t: f64) {
        if self.nodes[node].epoch != epoch {
            return; // cancelled by a kill
        }
        let req = self.nodes[node].in_service.take().expect("live Done ⇒ in service");
        let latency_us = t - req.t_submit_us;
        let accept_lat =
            (t - self.plans[req.session].ready_us(req.batch)).max(latency_us);
        self.clock.record(accept_lat, latency_us);
        self.counters.completed_requests += 1;
        self.counters.completed_queries += req.n_queries;
        self.gates[req.session].in_flight -= 1;
        if let Some(next) = self.nodes[node].queue.pop_front() {
            let service_us = self.specs[node].request_service_us(&self.overheads, next.n_queries);
            self.nodes[node].in_service = Some(next);
            let epoch = self.nodes[node].epoch;
            self.push(t + service_us, Event::Done { node, epoch });
        }
        let prev = self.nodes[node].est_service_us;
        self.nodes[node].est_service_us =
            update_service_estimate(prev, latency_us, self.nodes[node].depth());
        self.drain_all(t);
    }

    fn kill(&mut self, node: usize, t: f64) {
        if !self.nodes[node].up {
            return;
        }
        self.nodes[node].up = false;
        self.nodes[node].epoch += 1;
        // The request on the engine dies with the node; its window slot is
        // freed so the session keeps streaming.
        if let Some(req) = self.nodes[node].in_service.take() {
            self.counters.lost_queries += req.n_queries;
            self.gates[req.session].in_flight -= 1;
        }
        // Queued requests were already admitted once — reroute them among
        // the live replicas without a second admission pass; they are lost
        // only if nobody is live to take them.
        let orphans: Vec<Req> = self.nodes[node].queue.drain(..).collect();
        for req in orphans {
            let depths: Vec<usize> = self.nodes.iter().map(SimNode::depth).collect();
            let live: Vec<bool> = self.nodes.iter().map(|n| n.up).collect();
            let station = self.plans[req.session].station;
            match self.router.route_up(station, &depths, Some(&live)) {
                Some(target) => self.enqueue(target, req, t),
                None => {
                    self.counters.lost_queries += req.n_queries;
                    self.gates[req.session].in_flight -= 1;
                }
            }
        }
        let up_after = self.n_up();
        self.fault_events.push(ScalingEvent::fail(
            t,
            self.specs[node].class_name,
            node,
            up_after,
        ));
    }

    fn revive(&mut self, node: usize, t: f64) {
        if self.nodes[node].up {
            return;
        }
        self.nodes[node].up = true;
        let up_after = self.n_up();
        self.fault_events.push(ScalingEvent::recover(
            t,
            self.specs[node].class_name,
            node,
            up_after,
        ));
        self.drain_all(t);
    }
}

/// Run the session plans through the simulated front door. Deterministic:
/// same config + plans ⇒ bit-identical report.
pub fn sim_frontdoor(cfg: &FrontdoorSimConfig, plans: &[SessionPlan]) -> FrontdoorReport {
    let threads = match cfg.frontdoor.mode {
        FrontdoorMode::Event => cfg.frontdoor.event_threads.max(1),
        FrontdoorMode::ThreadPerSession { .. } => 1,
    };
    let accepted_set = match cfg.frontdoor.mode {
        FrontdoorMode::ThreadPerSession { max_threads } => {
            let mut order: Vec<usize> = (0..plans.len()).collect();
            order.sort_by(|&a, &b| {
                plans[a].accept_us.partial_cmp(&plans[b].accept_us).unwrap()
            });
            Some(order.into_iter().take(max_threads).collect::<HashSet<usize>>())
        }
        FrontdoorMode::Event => None,
    };
    let n_nodes = cfg.cluster.specs.len();
    let mut des = Des {
        plans,
        policy: cfg.frontdoor.backpressure,
        threads,
        accepted_set,
        router: cfg.cluster.router(),
        admission: cfg.cluster.admission,
        specs: &cfg.cluster.specs,
        overheads: cfg.cluster.overheads.clone(),
        nodes: vec![SimNode { up: true, ..Default::default() }; n_nodes],
        gates: vec![SessionGate::default(); plans.len()],
        thread_parked: vec![0; threads],
        counters: FrontdoorCounters::default(),
        clock: DualClock::new(),
        heap: BinaryHeap::new(),
        seq: 0,
        fault_events: Vec::new(),
    };
    for (s, p) in plans.iter().enumerate() {
        des.push(p.accept_us, Event::Accept { session: s });
        for b in 0..p.batches.len() {
            des.push(p.ready_us(b), Event::Ready { session: s, batch: b });
        }
    }
    for f in cfg.faults.faults() {
        des.push(f.at_us, Event::Kill { node: f.node });
        des.push(f.at_us + f.down_us, Event::Revive { node: f.node });
    }

    let mut t_end_us = 0.0f64;
    while let Some(Reverse((key, _, ev))) = des.heap.pop() {
        let t = key as f64 / 1_000.0;
        t_end_us = t_end_us.max(t);
        match ev {
            Event::Accept { session } => des.accept(session),
            Event::Ready { session, batch } => des.ready(session, batch, t),
            Event::Done { node, epoch } => des.complete(node, epoch, t),
            Event::Kill { node } => des.kill(node, t),
            Event::Revive { node } => des.revive(node, t),
        }
    }
    // Batches still parked when the heap runs dry can only mean the fleet
    // ended the run dead (no completion will ever drain them): count them
    // shed-in-queue so conservation stays structural, never silent.
    for s in 0..plans.len() {
        while let Some(b) = des.gates[s].parked.pop_front() {
            des.counters.shed_queue_queries += plans[s].batches[b].n_queries;
        }
    }

    let label = format!("{} sessions | {}", plans.len(), cfg.cluster.label());
    let counters = des.counters;
    let fault_events = des.fault_events;
    let report = FrontdoorReport::assemble(
        label,
        &cfg.frontdoor,
        plans,
        counters,
        &mut des.clock,
        t_end_us / 1e6,
        fault_events,
    );
    debug_assert!(report.conserves_queries(), "{}", report.summary());
    report
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::RoutePolicy;
    use crate::workload::{session_plans, RateSchedule};

    fn burst_plans(seed: u64, sessions: usize, batches: usize, batch_q: usize) -> Vec<SessionPlan> {
        session_plans(seed, &RateSchedule::constant(1e9), sessions, batches, batch_q, 0.0, 8)
    }

    fn event_cfg(nodes: usize, policy: BackpressurePolicy) -> FrontdoorSimConfig {
        FrontdoorSimConfig {
            cluster: ClusterSimConfig::v2_cloud(nodes, 2)
                .with_route(RoutePolicy::RoundRobin)
                .with_admission(AdmissionPolicy::QueueCap(24)),
            frontdoor: FrontdoorConfig::event(2, policy),
            faults: FaultPlan::none(),
        }
    }

    #[test]
    fn sim_frontdoor_is_deterministic() {
        let cfg = event_cfg(2, BackpressurePolicy::SocketShed { window: 2, pending_cap: 4 });
        let plans = burst_plans(11, 20, 8, 8);
        let a = sim_frontdoor(&cfg, &plans);
        let b = sim_frontdoor(&cfg, &plans);
        assert!(a.conserves_queries(), "{}", a.summary());
        assert_eq!(a.completed_queries, b.completed_queries);
        assert_eq!(a.shed_socket_queries, b.shed_socket_queries);
        assert_eq!(a.shed_queue_queries, b.shed_queue_queries);
        assert_eq!(a.accept_p99_us.to_bits(), b.accept_p99_us.to_bits());
        assert_eq!(a.goodput_qps.to_bits(), b.goodput_qps.to_bits());
    }

    #[test]
    fn kill_loses_exactly_the_request_in_service() {
        // Burst everything at t≈0, then kill node 0 mid-way through its
        // first service: the in-service request dies with the node (one
        // batch of 8 queries), the node's queue reroutes to node 1, and
        // the run still terminates and conserves.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(2, BackpressurePolicy::Window { window: 2 });
        cfg.cluster.admission = AdmissionPolicy::Open;
        let svc_us = spec.request_service_us(&cfg.cluster.overheads, 8);
        cfg.faults = FaultPlan::kill(0, 0.5 * svc_us, 50.0 * svc_us);
        let plans = burst_plans(3, 12, 6, 8);
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert_eq!(r.lost_queries, 8, "{}", r.summary());
        assert_eq!(r.fault_events.len(), 2);
        assert_eq!(r.completed_queries, r.offered_queries - 8);
        assert_eq!(r.sessions_accepted, 12);
        assert!(r.fault_events[0].line().contains("fail"));
    }

    #[test]
    fn revive_resumes_parked_sessions_and_the_accept_clock_shows_the_outage() {
        // A single replica killed mid-service for a full virtual second:
        // batches park behind the window through the outage, the revive
        // drains them, and the accept clock — unlike the submit clock —
        // carries the wait.
        let spec = SimNodeSpec::v2_cloud(2);
        let mut cfg = event_cfg(1, BackpressurePolicy::Window { window: 1 });
        cfg.cluster = ClusterSimConfig::v2_cloud(1, 2)
            .with_route(RoutePolicy::RoundRobin)
            .with_admission(AdmissionPolicy::Open);
        let svc_us = spec.request_service_us(&cfg.cluster.overheads, 8);
        let down_us = 1e6;
        cfg.faults = FaultPlan::kill(0, 0.5 * svc_us, down_us);
        // Two window-1 sessions: at the kill, session 0's batch is in
        // service (lost with the node) and session 1's batch is queued
        // behind it (orphaned with no live replica to take it — lost too).
        let plans = burst_plans(5, 2, 4, 8);
        let r = sim_frontdoor(&cfg, &plans);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert_eq!(r.lost_queries, 16, "{}", r.summary());
        assert_eq!(r.completed_queries, r.offered_queries - 16);
        assert_eq!(r.fault_events.len(), 2);
        assert!(r.fault_events[1].line().contains("recover"));
        assert!(
            r.accept_p99_us > 0.5 * down_us,
            "the outage wait must surface on the accept clock: p99 {} µs",
            r.accept_p99_us
        );
        assert!(r.omission_gap_us() > 0.0, "{}", r.summary());
    }

    #[test]
    fn backpressure_policies_separate_in_the_sim() {
        // The engineered overload scenario the crossval ranks: 2× offered
        // load, bursty 16-batch sessions, queue-capped replicas. Window
        // completes the most (lossless parking), None loses admission
        // refusals, SocketShed turns sessions away whole — while on the
        // accept clock SocketShed is fastest (it only serves what fits)
        // and Window slowest (it queues the whole backlog client-side).
        let spec = SimNodeSpec::v2_cloud(2);
        let o = ClusterSimConfig::v2_cloud(2, 2).overheads;
        let node_rps = spec.capacity_qps(&o, 16) / 16.0;
        let rate = 2.0 * 2.0 * node_rps / 16.0; // 2× the 2-node fleet, 16 req/session
        let plans = session_plans(7, &RateSchedule::constant(rate), 40, 16, 16, 0.0, 8);
        let run = |policy| sim_frontdoor(&event_cfg(2, policy), &plans);
        let none = run(BackpressurePolicy::None);
        let window = run(BackpressurePolicy::Window { window: 2 });
        let socket = run(BackpressurePolicy::SocketShed { window: 2, pending_cap: 2 });

        for r in [&none, &window, &socket] {
            assert!(r.conserves_queries(), "{}", r.summary());
        }
        assert_eq!(window.completed_queries, window.offered_queries, "window is lossless");
        assert!(none.shed_queue_queries > 0, "{}", none.summary());
        assert!(socket.shed_socket_queries > 0, "{}", socket.summary());
        assert!(socket.sessions_shed > 0, "socket refuses sessions whole");
        // Goodput ranking: window > none > socket.
        assert!(
            window.completed_queries > none.completed_queries
                && none.completed_queries > socket.completed_queries,
            "completed: window {} none {} socket {}",
            window.completed_queries,
            none.completed_queries,
            socket.completed_queries
        );
        // Accept-clock tail ranking: socket < none < window.
        assert!(
            socket.accept_p99_us < none.accept_p99_us
                && none.accept_p99_us < window.accept_p99_us,
            "accept p99: socket {} none {} window {}",
            socket.accept_p99_us,
            none.accept_p99_us,
            window.accept_p99_us
        );
        // The omission gap is what the accept clock surfaces: under the
        // window policy batches wait parked far longer than they queue.
        assert!(window.omission_gap_us() > 0.0, "{}", window.summary());
    }
}
