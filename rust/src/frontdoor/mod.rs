//! The **front door**: an event-driven session layer in front of the
//! cluster, multiplexing many client sessions onto few serving replicas —
//! the 1st-CLaaS lesson ("stream bits to the kernel, multiplex the web in
//! front of it") applied to the MCT fleet. PR 3–5 made the stack fast *per
//! batch*; this layer is what lets more than a few thousand concurrent
//! clients actually load it: before it, every in-flight request held a
//! blocking reply slot on a dedicated thread.
//!
//! Two realisations, as everywhere in this repo:
//!
//! * [`real::run_frontdoor`] — a poll-loop reactor on std threads: each
//!   event thread owns N sessions, reads their batch streams, submits
//!   through the cluster's tagged-completion surface
//!   ([`ClusterHandle`](crate::cluster::real::ClusterHandle)) and matches
//!   completions back to sessions — no per-request thread, no blocking
//!   slot. A thread-per-session baseline mode serves as the "what we had
//!   before" comparison the bench frontier measures.
//! * [`sim::sim_frontdoor`] — the deterministic DES twin over the same
//!   session plans, ladder rules and router/admission policies, with
//!   [`FaultPlan`](crate::controlplane::FaultPlan) kill/revive support.
//!
//! **The backpressure ladder** ([`BackpressurePolicy`]) has three rungs,
//! composing with the cluster's own
//! [`AdmissionPolicy`](crate::cluster::AdmissionPolicy):
//!
//! 1. *Per-session window* — at most W batches of one session in flight;
//!    excess waits parked (client-visible delay, no loss).
//! 2. *Per-connection pending cap* — at most P batches parked per event
//!    thread; the connection's read buffer is finite.
//! 3. *Socket-level shed* — when the cap is hit, new batches (and whole
//!    sessions, at accept) are refused at read/accept time, **before**
//!    they ever occupy queue space. Overload is turned away at the edge,
//!    not after queueing.
//!
//! Admission refusals below the ladder are counted `shed_queue` (the
//! "too late, already buffered" shed); ladder refusals are `shed_socket`.
//!
//! **The accept clock.** All front-door latency is measured from when the
//! client *had* the work — session accept plus the batch's stream offset —
//! to the response, not from cluster submission. The difference between
//! the two p99s ([`FrontdoorReport::omission_gap_us`]) is the
//! coordinated-omission error that submit-clock reports hide: a window-1
//! session's eighth batch waits seven round trips before the submit clock
//! even starts ticking.

pub mod real;
pub mod sim;

pub use real::run_frontdoor;
pub use sim::{sim_frontdoor, FrontdoorSimConfig};

use std::collections::VecDeque;

use crate::controlplane::ScalingEvent;
use crate::coordinator::DualClock;
use crate::resilience::{ResilienceCounters, ResiliencePolicy};
use crate::telemetry::{Trace, TraceSpec};
use crate::workload::SessionPlan;

/// The three-rung backpressure ladder of the front door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackpressurePolicy {
    /// No ladder: every batch is flung at the cluster the moment it is
    /// ready. Overload lands in the replicas' queues and is shed (or
    /// absorbed as queueing latency) there — the "shed in queue" world.
    None,
    /// Per-session window of `window` in-flight batches; excess parks
    /// without bound. Lossless, at the price of unbounded client-visible
    /// delay under sustained overload.
    Window { window: usize },
    /// Full ladder: per-session `window` plus a per-event-thread cap of
    /// `pending_cap` parked batches; beyond the cap, reads — and at
    /// accept time, whole sessions — are refused at the socket.
    SocketShed { window: usize, pending_cap: usize },
}

impl BackpressurePolicy {
    pub fn label(&self) -> String {
        match self {
            BackpressurePolicy::None => "none".to_string(),
            BackpressurePolicy::Window { window } => format!("window:{window}"),
            BackpressurePolicy::SocketShed { window, pending_cap } => {
                format!("socket:{window}:{pending_cap}")
            }
        }
    }

    /// Parse `none` | `window:W` | `socket:W:P` (the CLI/bench syntax).
    pub fn parse(s: &str) -> Option<BackpressurePolicy> {
        let mut parts = s.split(':');
        let kind = parts.next()?;
        let num = |p: Option<&str>| p.and_then(|x| x.parse::<usize>().ok()).filter(|&x| x > 0);
        let policy = match kind {
            "none" => BackpressurePolicy::None,
            "window" => BackpressurePolicy::Window { window: num(parts.next())? },
            "socket" => BackpressurePolicy::SocketShed {
                window: num(parts.next())?,
                pending_cap: num(parts.next())?,
            },
            _ => return None,
        };
        if parts.next().is_some() {
            return None;
        }
        Some(policy)
    }

    /// Per-session in-flight window (unbounded for `None`).
    pub fn window(&self) -> usize {
        match self {
            BackpressurePolicy::None => usize::MAX,
            BackpressurePolicy::Window { window }
            | BackpressurePolicy::SocketShed { window, .. } => (*window).max(1),
        }
    }

    /// Per-thread parked-batch cap, if this policy sheds at the socket.
    pub fn pending_cap(&self) -> Option<usize> {
        match self {
            BackpressurePolicy::SocketShed { pending_cap, .. } => Some((*pending_cap).max(1)),
            _ => None,
        }
    }

    /// Socket rung: may this thread buffer one more batch (or accept one
    /// more session) given `thread_parked` batches already parked?
    pub(crate) fn allows(&self, thread_parked: usize) -> bool {
        self.pending_cap().map(|cap| thread_parked < cap).unwrap_or(true)
    }

    /// What an admission refusal means under this policy: ladder policies
    /// hold the batch parked and retry (the refusal *is* backpressure);
    /// the no-ladder policy has nowhere to hold it — the batch is shed in
    /// queue.
    pub(crate) fn reparks_on_admission_shed(&self) -> bool {
        !matches!(self, BackpressurePolicy::None)
    }
}

/// How the front door schedules sessions onto threads.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrontdoorMode {
    /// The event-driven reactor: every event thread multiplexes its share
    /// of *all* sessions.
    Event,
    /// The pre-front-door architecture: one blocking thread per session,
    /// window 1, at most `max_threads` session threads ever — sessions
    /// beyond that are refused at accept (thread exhaustion *is* the
    /// socket shed of this mode).
    ThreadPerSession { max_threads: usize },
}

impl FrontdoorMode {
    pub fn label(&self) -> String {
        match self {
            FrontdoorMode::Event => "event".to_string(),
            FrontdoorMode::ThreadPerSession { max_threads } => {
                format!("thread-per-session(≤{max_threads})")
            }
        }
    }
}

/// Front-door configuration, identical across realisations.
#[derive(Debug, Clone, Copy)]
pub struct FrontdoorConfig {
    pub event_threads: usize,
    pub backpressure: BackpressurePolicy,
    pub mode: FrontdoorMode,
    /// Gray-failure resilience ladder (deadlines, retries, hedges,
    /// breakers, brown-out routing) — [`ResiliencePolicy::none`] keeps
    /// the pre-resilience behaviour bit-for-bit.
    pub resilience: ResiliencePolicy,
    /// Flight-recorder spec. `None` runs the zero-cost
    /// [`NullRecorder`](crate::telemetry::NullRecorder) path; `Some`
    /// gives every worker thread its own ring recorder, merged into
    /// [`FrontdoorReport::trace`] at join.
    pub trace: Option<TraceSpec>,
}

impl FrontdoorConfig {
    pub fn event(event_threads: usize, backpressure: BackpressurePolicy) -> FrontdoorConfig {
        FrontdoorConfig {
            event_threads: event_threads.max(1),
            backpressure,
            mode: FrontdoorMode::Event,
            resilience: ResiliencePolicy::none(),
            trace: None,
        }
    }

    pub fn thread_per_session(max_threads: usize) -> FrontdoorConfig {
        // Window 1 is structural to the baseline: one blocking slot per
        // session thread.
        FrontdoorConfig {
            event_threads: 1,
            backpressure: BackpressurePolicy::Window { window: 1 },
            mode: FrontdoorMode::ThreadPerSession { max_threads: max_threads.max(1) },
            resilience: ResiliencePolicy::none(),
            trace: None,
        }
    }

    pub fn with_resilience(mut self, resilience: ResiliencePolicy) -> FrontdoorConfig {
        self.resilience = resilience;
        self
    }

    pub fn with_trace(mut self, trace: TraceSpec) -> FrontdoorConfig {
        self.trace = Some(trace);
        self
    }

    pub fn label(&self) -> String {
        if self.resilience.is_none() {
            format!("{} bp={}", self.mode.label(), self.backpressure.label())
        } else {
            format!(
                "{} bp={} res={}",
                self.mode.label(),
                self.backpressure.label(),
                self.resilience.label()
            )
        }
    }
}

/// Per-session ladder state, shared by both realisations so the window
/// accounting exists exactly once: the FIFO of parked batch indices and
/// the in-flight count the window bounds.
#[derive(Debug, Clone, Default)]
pub(crate) struct SessionGate {
    pub(crate) parked: VecDeque<usize>,
    pub(crate) in_flight: usize,
    /// Session refused whole at accept (its batches never enter play).
    pub(crate) refused: bool,
}

/// Shed/served accounting, in queries — the conservation currency.
#[derive(Debug, Clone, Copy, Default)]
pub(crate) struct FrontdoorCounters {
    pub(crate) sessions_accepted: usize,
    pub(crate) sessions_shed: usize,
    pub(crate) completed_requests: usize,
    pub(crate) completed_queries: usize,
    pub(crate) shed_socket_queries: usize,
    pub(crate) shed_queue_queries: usize,
    /// Queries whose accept-clock deadline expired before completion —
    /// cancelled work, never counted completed.
    pub(crate) shed_deadline_queries: usize,
    pub(crate) lost_queries: usize,
    /// Resilience-mechanism accounting (retries, hedges, breakers, …).
    pub(crate) res: ResilienceCounters,
}

impl FrontdoorCounters {
    pub(crate) fn merge(&mut self, o: &FrontdoorCounters) {
        self.sessions_accepted += o.sessions_accepted;
        self.sessions_shed += o.sessions_shed;
        self.completed_requests += o.completed_requests;
        self.completed_queries += o.completed_queries;
        self.shed_socket_queries += o.shed_socket_queries;
        self.shed_queue_queries += o.shed_queue_queries;
        self.shed_deadline_queries += o.shed_deadline_queries;
        self.lost_queries += o.lost_queries;
        self.res.merge(&o.res);
    }
}

/// Outcome of one front-door run (either realisation).
#[derive(Debug, Clone)]
pub struct FrontdoorReport {
    /// Workload label ("S sessions × B batches × Q queries @ rate").
    pub label: String,
    /// `event` or `thread-per-session(≤N)`.
    pub mode: String,
    /// Backpressure-policy label.
    pub backpressure: String,
    pub event_threads: usize,

    pub sessions_offered: usize,
    pub sessions_accepted: usize,
    /// Sessions refused whole at accept time.
    pub sessions_shed: usize,

    /// Conservation: `offered = completed + shed_socket + shed_queue +
    /// shed_deadline + lost`, all in queries, measured from the accept
    /// clock.
    pub offered_queries: usize,
    pub completed_queries: usize,
    pub shed_socket_queries: usize,
    pub shed_queue_queries: usize,
    /// Deadline-expired queries — cancelled, never completed.
    pub shed_deadline_queries: usize,
    pub lost_queries: usize,
    pub completed_requests: usize,

    /// Resilience-policy label (`no-retry`, `retry+hedge`, …).
    pub resilience: String,
    /// Resilience-mechanism counters (hedge wins, breaker trips, physical
    /// backend submissions, …).
    pub res: ResilienceCounters,

    /// Offered queries over the client-clock span of the plans.
    pub offered_qps: f64,
    /// Completed queries over the run's wall (real) / virtual (sim) time.
    pub goodput_qps: f64,
    pub wall_s: f64,

    /// Accept-clock percentiles (the honest numbers).
    pub accept_p50_us: f64,
    pub accept_p90_us: f64,
    pub accept_p99_us: f64,
    /// Submit-clock p99 (the flattering number), kept to expose the gap.
    pub submit_p99_us: f64,

    /// Fault-plan kill/revive timeline, control-plane vocabulary.
    pub fault_events: Vec<ScalingEvent>,

    /// Flight-recorder stream (empty unless [`FrontdoorConfig::trace`]
    /// was set). Merged across worker threads and sorted by timestamp.
    pub trace: Trace,
}

impl FrontdoorReport {
    /// Build a report from the shared counters + dual-clock samples.
    pub(crate) fn assemble(
        label: String,
        config: &FrontdoorConfig,
        plans: &[SessionPlan],
        counters: FrontdoorCounters,
        clock: &mut DualClock,
        wall_s: f64,
        fault_events: Vec<ScalingEvent>,
    ) -> FrontdoorReport {
        let offered_queries: usize = plans.iter().map(SessionPlan::total_queries).sum();
        let span_s = plans
            .iter()
            .map(|p| (0..p.batches.len()).map(|i| p.ready_us(i)).fold(0.0, f64::max))
            .fold(0.0, f64::max)
            / 1e6;
        let empty = clock.is_empty();
        FrontdoorReport {
            label,
            mode: config.mode.label(),
            backpressure: config.backpressure.label(),
            event_threads: config.event_threads,
            sessions_offered: plans.len(),
            sessions_accepted: counters.sessions_accepted,
            sessions_shed: counters.sessions_shed,
            offered_queries,
            completed_queries: counters.completed_queries,
            shed_socket_queries: counters.shed_socket_queries,
            shed_queue_queries: counters.shed_queue_queries,
            shed_deadline_queries: counters.shed_deadline_queries,
            lost_queries: counters.lost_queries,
            completed_requests: counters.completed_requests,
            resilience: config.resilience.label(),
            res: counters.res,
            offered_qps: offered_queries as f64 / span_s.max(1e-9),
            goodput_qps: counters.completed_queries as f64 / wall_s.max(1e-9),
            wall_s,
            accept_p50_us: if empty { 0.0 } else { clock.accept.p50() },
            accept_p90_us: if empty { 0.0 } else { clock.accept.p90() },
            accept_p99_us: if empty { 0.0 } else { clock.accept.p99() },
            submit_p99_us: if empty { 0.0 } else { clock.submit.p99() },
            fault_events,
            trace: Trace::default(),
        }
    }

    /// The end-to-end conservation law, from the accept clock: every
    /// offered query is completed, refused at the socket, shed in queue,
    /// cancelled at its deadline, or lost to a fault — nothing vanishes,
    /// and a hedged request still counts exactly once.
    pub fn conserves_queries(&self) -> bool {
        self.offered_queries
            == self.completed_queries
                + self.shed_socket_queries
                + self.shed_queue_queries
                + self.shed_deadline_queries
                + self.lost_queries
    }

    /// Physical backend submissions per completed request — the hedge/
    /// retry amplification factor (1.0 when no mechanism fired).
    pub fn backend_load_factor(&self) -> f64 {
        if self.res.backend_requests == 0 || self.completed_requests == 0 {
            1.0
        } else {
            self.res.backend_requests as f64 / self.completed_requests as f64
        }
    }

    /// Completed fraction of offered queries (goodput as a ratio).
    pub fn delivered_fraction(&self) -> f64 {
        self.completed_queries as f64 / (self.offered_queries as f64).max(1.0)
    }

    /// Accept-clock p99 minus submit-clock p99: the latency the
    /// pre-front-door reports were hiding.
    pub fn omission_gap_us(&self) -> f64 {
        self.accept_p99_us - self.submit_p99_us
    }

    pub fn summary(&self) -> String {
        let mut s = format!(
            "{} [{}] {} | sessions {}/{} (+{} shed) | q: {} offered → {} done, {} shed@socket, \
             {} shed@queue, {} shed@deadline, {} lost ({:.0} % delivered) | goodput {:.0} q/s | \
             accept p50/p90/p99 {:.0}/{:.0}/{:.0} µs (submit p99 {:.0} µs, gap {:.0} µs)",
            self.mode,
            self.backpressure,
            self.label,
            self.sessions_accepted,
            self.sessions_offered,
            self.sessions_shed,
            self.offered_queries,
            self.completed_queries,
            self.shed_socket_queries,
            self.shed_queue_queries,
            self.shed_deadline_queries,
            self.lost_queries,
            self.delivered_fraction() * 100.0,
            self.goodput_qps,
            self.accept_p50_us,
            self.accept_p90_us,
            self.accept_p99_us,
            self.submit_p99_us,
            self.omission_gap_us(),
        );
        if self.res.any() {
            s.push_str(&format!(
                " | resilience[{}]: {} retries ({} budget-refused), {} hedges ({} wins), \
                 {} breaker-rejects/{} trips, {} degraded, {} backend reqs ({:.2}× load), \
                 {} gray windows",
                self.resilience,
                self.res.retries,
                self.res.retry_budget_exhausted,
                self.res.hedges_issued,
                self.res.hedge_wins,
                self.res.breaker_rejections,
                self.res.breaker_trips,
                self.res.degraded_requests,
                self.res.backend_requests,
                self.backend_load_factor(),
                self.res.gray_fault_windows,
            ));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backpressure_parse_roundtrips_labels() {
        for p in [
            BackpressurePolicy::None,
            BackpressurePolicy::Window { window: 4 },
            BackpressurePolicy::SocketShed { window: 2, pending_cap: 8 },
        ] {
            assert_eq!(BackpressurePolicy::parse(&p.label()), Some(p), "{}", p.label());
        }
        for bad in ["", "windows:2", "window", "window:0", "window:x", "socket:2", "none:1"] {
            assert_eq!(BackpressurePolicy::parse(bad), None, "{bad}");
        }
    }

    #[test]
    fn ladder_rungs() {
        let none = BackpressurePolicy::None;
        assert_eq!(none.window(), usize::MAX);
        assert!(none.allows(1_000_000), "no cap, always reads");
        assert!(!none.reparks_on_admission_shed(), "nowhere to park");

        let win = BackpressurePolicy::Window { window: 2 };
        assert_eq!(win.window(), 2);
        assert!(win.allows(1_000_000), "window parks without bound");
        assert!(win.reparks_on_admission_shed());

        let sock = BackpressurePolicy::SocketShed { window: 2, pending_cap: 3 };
        assert_eq!(sock.window(), 2);
        assert_eq!(sock.pending_cap(), Some(3));
        assert!(sock.allows(2));
        assert!(!sock.allows(3), "cap reached: refuse at the socket");
        assert!(sock.reparks_on_admission_shed());
    }

    #[test]
    fn report_conservation_and_gap() {
        let config = FrontdoorConfig::event(2, BackpressurePolicy::Window { window: 2 });
        let plans = crate::workload::session_plans(
            1,
            &crate::workload::RateSchedule::constant(1_000.0),
            10,
            4,
            8,
            0.0,
            4,
        );
        let mut clock = DualClock::new();
        for i in 0..30 {
            clock.record(100.0 + 10.0 * i as f64, 50.0);
        }
        let counters = FrontdoorCounters {
            sessions_accepted: 9,
            sessions_shed: 1,
            completed_requests: 30,
            completed_queries: 240,
            shed_socket_queries: 48,
            shed_queue_queries: 20,
            shed_deadline_queries: 4,
            lost_queries: 8,
            res: ResilienceCounters {
                retries: 3,
                hedges_issued: 2,
                hedge_wins: 1,
                backend_requests: 35,
                ..Default::default()
            },
            ..Default::default()
        };
        let r = FrontdoorReport::assemble(
            "test".into(),
            &config,
            &plans,
            counters,
            &mut clock,
            2.0,
            Vec::new(),
        );
        assert_eq!(r.offered_queries, 320);
        assert!(r.conserves_queries(), "{}", r.summary());
        assert_eq!(r.goodput_qps, 120.0);
        assert!((r.delivered_fraction() - 0.75).abs() < 1e-12);
        assert!(r.omission_gap_us() > 0.0);
        assert!(r.accept_p99_us >= r.accept_p90_us && r.accept_p90_us >= r.accept_p50_us);
        assert!(r.summary().contains("shed@socket"));
        assert!(r.summary().contains("resilience[no-retry]"), "{}", r.summary());
        assert!((r.backend_load_factor() - 35.0 / 30.0).abs() < 1e-12);

        // Conservation actually fails when a query vanishes.
        let mut broken = r.clone();
        broken.lost_queries = 0;
        assert!(!broken.conserves_queries());
        let mut broken = r.clone();
        broken.shed_deadline_queries = 0;
        assert!(!broken.conserves_queries(), "deadline sheds are part of the law");
    }
}
