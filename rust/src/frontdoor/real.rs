//! The real front door: a poll-loop reactor on std threads.
//!
//! Each event thread owns its share of the sessions (`s % threads`), walks
//! a time-ordered accept/ready event list against the shared wall clock,
//! and multiplexes every owned session's batches into the cluster through
//! the tagged-completion surface
//! ([`ClusterHandle`](crate::cluster::real::ClusterHandle)) — one channel
//! per event thread, no per-request thread, no blocking reply slot. The
//! [`BackpressurePolicy`](super::BackpressurePolicy) ladder runs at
//! accept/read time; admission refusals from the cluster bounce the batch
//! back to its parked slot (or drop it, under `None`), retried on the next
//! completion or on a ≤1 ms tick so a refusal can never deadlock a thread
//! with nothing in flight.
//!
//! The thread-per-session baseline
//! ([`FrontdoorMode::ThreadPerSession`](super::FrontdoorMode)) is the
//! pre-front-door architecture kept honest: one blocking thread per
//! accepted session, window 1, sessions beyond the thread budget refused
//! at accept. The bench frontier measures exactly this pair.

use std::collections::HashMap;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::{gray_fault_factory, BackendFactory};
use crate::cluster::real::{ClusterHandle, Submit, SubmitOpts};
use crate::cluster::ClusterConfig;
use crate::controlplane::{FaultPlan, ScalingEvent};
use crate::coordinator::pipeline::{pace_until, Completion};
use crate::coordinator::DualClock;
use crate::prng::Rng;
use crate::resilience::{CircuitBreaker, ResiliencePolicy, RetryBudget, RetryPolicy};
use crate::rules::types::{MctQuery, World};
use crate::telemetry::{
    AttemptKind, NullRecorder, Recorder, RingRecorder, ShedLane, StageEvent, Trace, CONTROL_ID,
};
use crate::workload::{QueryFactory, SessionPlan};

use super::{
    BackpressurePolicy, FrontdoorConfig, FrontdoorCounters, FrontdoorMode, FrontdoorReport,
    SessionGate,
};

/// Serve `plans` through the front door against a real cluster and report
/// on the accept clock. `factory` builds every replica's backend
/// (homogeneous fleet); `faults` is paced on the wall clock with the
/// real realisation's drain semantics (a downed replica finishes what it
/// holds, so nothing is ever lost here — the sim twin models the lossy
/// variant).
pub fn run_frontdoor(
    cluster: ClusterConfig,
    factory: BackendFactory,
    world: &World,
    seed: u64,
    plans: &[SessionPlan],
    fd: &FrontdoorConfig,
    faults: &FaultPlan,
) -> Result<FrontdoorReport> {
    let classes: Vec<String> =
        cluster.specs.iter().map(|s| s.class.name.to_string()).collect();
    let label = format!("{} sessions | {}", plans.len(), cluster.label());
    let payloads = materialise(world, seed, plans);
    // The gray decorators and the fault driver share one clock origin, so
    // a window scripted at `at_us` opens at the same instant for both.
    let t0 = Instant::now();
    let factories: Vec<BackendFactory> = (0..cluster.nodes())
        .map(|i| gray_fault_factory(factory.clone(), faults.clone(), i, t0, seed))
        .collect();
    let handle = ClusterHandle::spawn(&cluster, &factories);

    let (counters, mut clock, fault_events, mut trace) = std::thread::scope(|scope| {
        let h = &handle;
        let classes = &classes;
        let fault_driver = scope.spawn(move || drive_faults(h, t0, faults, classes));

        let mut shed = FrontdoorCounters::default();
        // Socket refusals decided before any worker exists land here, on
        // the same spec-filtered recording path as everything else.
        let mut door_rec = fd.trace.map(RingRecorder::new);
        let workers = match fd.mode {
            FrontdoorMode::Event => {
                // Partition sessions across event threads by index,
                // keeping the global session index for stable trace ids.
                let threads = fd.event_threads.min(plans.len().max(1));
                let mut parts: Vec<Vec<(usize, SessionPlan, Vec<Vec<MctQuery>>)>> =
                    (0..threads).map(|_| Vec::new()).collect();
                for (s, payload) in payloads.into_iter().enumerate() {
                    parts[s % threads].push((s, plans[s].clone(), payload));
                }
                let policy = fd.backpressure;
                let res = fd.resilience;
                let tspec = fd.trace;
                parts
                    .into_iter()
                    .enumerate()
                    .map(|(i, part)| {
                        let tseed = seed ^ ((i as u64 + 1) << 17);
                        scope.spawn(move || match tspec {
                            None => {
                                run_event_thread(h, t0, policy, res, tseed, part, NullRecorder)
                            }
                            Some(spec) => run_event_thread(
                                h,
                                t0,
                                policy,
                                res,
                                tseed,
                                part,
                                RingRecorder::new(spec),
                            ),
                        })
                    })
                    .collect::<Vec<_>>()
            }
            FrontdoorMode::ThreadPerSession { max_threads } => {
                // The old architecture: threads are the accept budget. The
                // first `max_threads` sessions by accept time get one
                // blocking thread each; everyone else is refused whole.
                let mut order: Vec<usize> = (0..plans.len()).collect();
                order.sort_by(|&a, &b| plans[a].accept_us.total_cmp(&plans[b].accept_us));
                let accepted: std::collections::HashSet<usize> =
                    order.iter().take(max_threads).copied().collect();
                let mut workers = Vec::new();
                let tspec = fd.trace;
                for (s, payload) in payloads.into_iter().enumerate() {
                    if accepted.contains(&s) {
                        let plan = plans[s].clone();
                        workers.push(scope.spawn(move || match tspec {
                            None => run_session_thread(h, t0, s, plan, payload, NullRecorder),
                            Some(spec) => {
                                run_session_thread(h, t0, s, plan, payload, RingRecorder::new(spec))
                            }
                        }));
                    } else {
                        shed.sessions_shed += 1;
                        shed.shed_socket_queries += plans[s].total_queries();
                        if let Some(rec) = door_rec.as_mut() {
                            for (b, batch) in plans[s].batches.iter().enumerate() {
                                rec.record(plans[s].accept_us, rid(s, b), StageEvent::Shed {
                                    lane: ShedLane::Socket,
                                    n_queries: batch.n_queries,
                                });
                            }
                        }
                    }
                }
                workers
            }
        };

        let mut counters = shed;
        let mut clock = DualClock::new();
        let mut trace = door_rec.map(RingRecorder::into_trace).unwrap_or_default();
        for w in workers {
            let (c, dc, tr) = w.join().expect("front-door worker panicked");
            counters.merge(&c);
            clock.merge(&dc);
            trace.merge(tr);
        }
        counters.res.gray_fault_windows = faults.grays().len();
        let fault_events = fault_driver.join().expect("fault driver panicked");
        (counters, clock, fault_events, trace)
    });

    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    handle.shutdown();

    trace.sort();
    let mut report = FrontdoorReport::assemble(
        label,
        fd,
        plans,
        counters,
        &mut clock,
        wall_s,
        fault_events,
    );
    report.trace = trace;
    anyhow::ensure!(report.conserves_queries(), "front door lost queries: {}", report.summary());
    Ok(report)
}

/// Stable trace id shared with the DES twin: session in the high half,
/// batch in the low, so deterministic sampling keeps the *same* requests
/// in both realisations.
fn rid(s: usize, b: usize) -> u64 {
    ((s as u64) << 32) | b as u64
}

/// Pre-materialise every batch's queries so generation cost never sits on
/// the serving path (the reactor measures the front door, not the RNG).
fn materialise(world: &World, seed: u64, plans: &[SessionPlan]) -> Vec<Vec<Vec<MctQuery>>> {
    let factory = QueryFactory::new(world, seed, 24);
    let mut rng = Rng::new(seed ^ 0xF207_D002);
    plans
        .iter()
        .map(|p| {
            p.batches
                .iter()
                .map(|b| {
                    (0..b.n_queries)
                        .map(|_| factory.query(&mut rng, world, p.station))
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn now_us(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// One accept/ready occurrence on a thread's timeline.
#[derive(Debug, Clone, Copy)]
enum Ev {
    Accept(usize),
    Ready(usize, usize),
}

impl Ev {
    fn rank(&self) -> u8 {
        // Accepts sort before same-instant readies (a gap-0 stream's first
        // batch is ready the moment its session is accepted).
        match self {
            Ev::Accept(_) => 0,
            Ev::Ready(..) => 1,
        }
    }
}

/// Resilience state of one in-flight *logical* request. All physical
/// copies (first attempt, retries, the hedge) share the request id; the
/// logical request holds one window slot and resolves exactly once.
#[derive(Debug, Clone, Copy)]
struct Flight {
    session: usize,
    batch: usize,
    n_queries: usize,
    /// Physical copies currently inside the cluster.
    copies: usize,
    /// Node of the newest non-hedge copy — hedges exclude it.
    first_node: usize,
    /// Attempts used, first submission included.
    attempt: u32,
    prev_backoff_us: f64,
    /// Set while waiting out a retry backoff (`copies == 0`).
    retry_at_us: Option<f64>,
    /// Hedge trigger instant; `None` when hedging is off or untrained.
    hedge_at_us: Option<f64>,
    hedged: bool,
}

/// Per-thread reactor state: the sessions it owns, their ladder gates,
/// this connection's parked-batch budget, and the resilience layer
/// (deadlines, budgeted retries, hedges, breakers — all per-connection,
/// like a client library's view of the fleet).
struct Reactor<'a, R: Recorder> {
    handle: &'a ClusterHandle,
    t0: Instant,
    policy: BackpressurePolicy,
    sessions: Vec<(SessionPlan, Vec<Vec<MctQuery>>)>,
    /// Global session index per local slot — trace/submit ids stay
    /// unique across event threads and aligned with the DES twin's.
    sids: Vec<usize>,
    gates: Vec<SessionGate>,
    thread_parked: usize,
    in_flight: usize,
    counters: FrontdoorCounters,
    clock: DualClock,
    ctx: mpsc::Sender<Completion>,
    res: ResiliencePolicy,
    flights: HashMap<u64, Flight>,
    budget: RetryBudget,
    breakers: Vec<CircuitBreaker>,
    retry_rng: Rng,
    breaker_rng: Rng,
    /// EWMA of winner latencies — the hedge trigger's expectation. Zero
    /// until the first completion trains it (no hedges before that).
    lat_ewma: f64,
    /// Flight recorder. [`NullRecorder`] when tracing is off — the whole
    /// emission layer monomorphizes away. This thread's ring is merged
    /// into the run's trace at join.
    rec: R,
}

impl<R: Recorder> Reactor<'_, R> {
    fn submit_opts<'d>(&self, deny: Option<&'d [bool]>, exclude: Option<usize>) -> SubmitOpts<'d> {
        SubmitOpts { exclude, deny, brownout: self.res.brownout, degrade: self.res.brownout }
    }

    /// Trace/submit id of a local session's batch (global session index
    /// in the high half).
    fn rid_of(&self, s: usize, b: usize) -> u64 {
        rid(self.sids[s], b)
    }

    /// The per-replica breaker mask for this routing decision, `None`
    /// when no breaker policy is set.
    fn breaker_deny(&mut self, now: f64) -> Option<Vec<bool>> {
        self.res.breaker?;
        let rng = &mut self.breaker_rng;
        Some(self.breakers.iter_mut().map(|b| !b.allows(now, rng)).collect())
    }

    /// Feed an outcome to the cluster's health plane; a brown-out
    /// threshold crossing becomes a control event in the trace.
    fn note_outcome(&mut self, c: &Completion, deadline_miss: bool, now: f64) {
        if let Some(tr) = self.handle.note_outcome_at(c, deadline_miss, now) {
            self.rec.record(tr.t_us, CONTROL_ID, StageEvent::Health {
                replica: c.node,
                degraded: tr.degraded,
            });
        }
    }

    /// Submit the session's parked batches while its window has room.
    /// An admission refusal either bounces the batch back to its parked
    /// slot (ladder policies — the refusal *is* backpressure) or drops it
    /// as shed-in-queue (`None` — nowhere to hold it). Batches whose
    /// deadline lapsed while parked are cancelled, never submitted.
    fn drain_session(&mut self, s: usize) {
        let window = self.policy.window();
        while self.gates[s].in_flight < window {
            let Some(&b) = self.gates[s].parked.front() else { break };
            let now = now_us(self.t0);
            let n_queries = self.sessions[s].1[b].len();
            if self.res.expired(self.sessions[s].0.ready_us(b), now) {
                self.gates[s].parked.pop_front();
                self.thread_parked -= 1;
                self.counters.shed_deadline_queries += n_queries;
                self.rec.record(now, self.rid_of(s, b), StageEvent::Shed {
                    lane: ShedLane::Deadline,
                    n_queries,
                });
                continue;
            }
            let station = self.sessions[s].0.station;
            let queries = self.sessions[s].1[b].clone();
            let id = self.rid_of(s, b);
            let deny = self.breaker_deny(now);
            let opts = self.submit_opts(deny.as_deref(), None);
            match self.handle.try_submit_ext(station, queries, id, &self.ctx, opts) {
                Submit::Submitted { node, degraded } => {
                    self.gates[s].parked.pop_front();
                    self.thread_parked -= 1;
                    self.gates[s].in_flight += 1;
                    self.in_flight += 1;
                    self.budget.deposit();
                    self.counters.res.backend_requests += 1;
                    if degraded {
                        self.counters.res.degraded_requests += 1;
                    }
                    self.rec.record(now, id, StageEvent::Admitted);
                    self.rec.record(now, id, StageEvent::AttemptStart {
                        kind: AttemptKind::Primary,
                    });
                    self.rec.record(now, id, StageEvent::Routed { replica: node });
                    self.rec.record(now, id, StageEvent::Enqueued { replica: node });
                    let hedge_at = self
                        .res
                        .hedge
                        .filter(|_| self.lat_ewma > 0.0)
                        .and_then(|h| h.trigger_us(self.lat_ewma))
                        .map(|trig| now + trig);
                    self.flights.insert(
                        id,
                        Flight {
                            session: s,
                            batch: b,
                            n_queries,
                            copies: 1,
                            first_node: node,
                            attempt: 1,
                            prev_backoff_us: 0.0,
                            retry_at_us: None,
                            hedge_at_us: hedge_at,
                            hedged: false,
                        },
                    );
                }
                Submit::Shed => {
                    if deny.as_ref().is_some_and(|d| d.iter().all(|&x| x)) {
                        self.counters.res.breaker_rejections += 1;
                    }
                    if self.policy.reparks_on_admission_shed() {
                        return; // stays parked; retried on completion/tick
                    }
                    self.gates[s].parked.pop_front();
                    self.thread_parked -= 1;
                    self.counters.shed_queue_queries += n_queries;
                    self.rec.record(now, id, StageEvent::Shed {
                        lane: ShedLane::Queue,
                        n_queries,
                    });
                }
            }
        }
    }

    fn drain_all(&mut self) {
        for s in 0..self.sessions.len() {
            if !self.gates[s].parked.is_empty() {
                self.drain_session(s);
            }
        }
    }

    fn complete(&mut self, c: Completion) {
        let now = now_us(self.t0);
        // Retroactive exec span: the worker measured dequeue→reply on its
        // own clock and shipped the span width; anchor it to end at
        // delivery so it nests inside the request's lifecycle.
        self.rec.record((now - c.exec_us).max(0.0), c.id, StageEvent::ExecStart {
            replica: c.node,
        });
        self.rec.record(now, c.id, StageEvent::ExecEnd {
            replica: c.node,
            kernel_us: c.kernel_us,
            ok: c.ok,
        });
        if self.res.breaker.is_some() {
            let norm = c.latency_us / (self.handle.outstanding(c.node) as f64 + 1.0);
            self.breakers[c.node].on_outcome(now, c.ok, norm);
            self.counters.res.breaker_trips = self.breakers.iter().map(|b| b.trips()).sum();
        }
        let Some(entry) = self.flights.get_mut(&c.id) else {
            // A copy of an already-resolved request (hedge loser, late
            // retry): pure signal, no counters.
            self.note_outcome(&c, false, now);
            return;
        };
        entry.copies -= 1;
        let fl = *entry;
        let s = fl.session;
        let ready = self.sessions[s].0.ready_us(fl.batch);
        let expired = self.res.expired(ready, now);
        self.note_outcome(&c, expired, now);
        if c.ok && !expired {
            // First OK copy inside the deadline wins and counts once.
            self.flights.remove(&c.id);
            let accept_lat = (now - ready).max(c.latency_us);
            self.clock.record(accept_lat, c.latency_us);
            self.gates[s].in_flight -= 1;
            self.in_flight -= 1;
            self.counters.completed_requests += 1;
            self.counters.completed_queries += c.n_queries;
            if fl.hedged && c.node != fl.first_node {
                self.counters.res.hedge_wins += 1;
            }
            self.lat_ewma = if self.lat_ewma > 0.0 {
                self.lat_ewma + 0.2 * (c.latency_us - self.lat_ewma)
            } else {
                c.latency_us
            };
            self.rec.record(now, c.id, StageEvent::Completed { n_queries: c.n_queries });
            return;
        }
        if expired {
            // Past its deadline: cancelled work, never completed.
            self.flights.remove(&c.id);
            self.counters.shed_deadline_queries += fl.n_queries;
            self.gates[s].in_flight -= 1;
            self.in_flight -= 1;
            self.rec.record(now, c.id, StageEvent::Shed {
                lane: ShedLane::Deadline,
                n_queries: fl.n_queries,
            });
            return;
        }
        // Failed copy inside the deadline: an in-flight twin may still
        // win; only the last copy standing goes to the retry path.
        if fl.copies == 0 {
            self.fail_or_retry(c.id, now);
        }
    }

    /// Resolve the flight as unrecoverable (`lost`) or schedule a
    /// budgeted, deadline-aware backoff retry.
    fn fail_or_retry(&mut self, id: u64, now: f64) {
        let fl = self.flights[&id];
        let ready = self.sessions[fl.session].0.ready_us(fl.batch);
        let give_up = |r: &mut Self| {
            r.flights.remove(&id);
            r.counters.lost_queries += fl.n_queries;
            r.gates[fl.session].in_flight -= 1;
            r.in_flight -= 1;
            r.rec.record(now, id, StageEvent::Lost { n_queries: fl.n_queries });
        };
        let Some(rp) = self.res.retry else {
            give_up(self);
            return;
        };
        if fl.attempt >= rp.max_attempts {
            give_up(self);
            return;
        }
        if !self.budget.try_spend() {
            self.counters.res.retry_budget_exhausted += 1;
            give_up(self);
            return;
        }
        let backoff = rp.backoff_us(fl.prev_backoff_us, &mut self.retry_rng);
        self.counters.res.retries += 1;
        if self.res.expired(ready, now + backoff) {
            // The backoff alone would blow the deadline: cancel now.
            self.flights.remove(&id);
            self.counters.shed_deadline_queries += fl.n_queries;
            self.gates[fl.session].in_flight -= 1;
            self.in_flight -= 1;
            self.rec.record(now, id, StageEvent::Shed {
                lane: ShedLane::Deadline,
                n_queries: fl.n_queries,
            });
            return;
        }
        let entry = self.flights.get_mut(&id).expect("retrying a live flight");
        entry.attempt += 1;
        entry.prev_backoff_us = backoff;
        entry.retry_at_us = Some(now + backoff);
    }

    /// Issue the retry copy whose backoff elapsed.
    fn resubmit(&mut self, id: u64, now: f64) {
        let fl = self.flights[&id];
        let station = self.sessions[fl.session].0.station;
        let queries = self.sessions[fl.session].1[fl.batch].clone();
        let deny = self.breaker_deny(now);
        let opts = self.submit_opts(deny.as_deref(), None);
        match self.handle.try_submit_ext(station, queries, id, &self.ctx, opts) {
            Submit::Submitted { node, degraded } => {
                self.counters.res.backend_requests += 1;
                if degraded {
                    self.counters.res.degraded_requests += 1;
                }
                self.rec.record(now, id, StageEvent::AttemptStart { kind: AttemptKind::Retry });
                self.rec.record(now, id, StageEvent::Routed { replica: node });
                self.rec.record(now, id, StageEvent::Enqueued { replica: node });
                let entry = self.flights.get_mut(&id).expect("resubmitting a live flight");
                entry.copies = 1;
                entry.first_node = node;
                entry.retry_at_us = None;
            }
            Submit::Shed => {
                // Refused (admission, or every replica breaker-denied):
                // the attempt is consumed like any other failure.
                if deny.as_ref().is_some_and(|d| d.iter().all(|&x| x)) {
                    self.counters.res.breaker_rejections += 1;
                }
                self.flights.get_mut(&id).expect("live flight").retry_at_us = None;
                self.fail_or_retry(id, now);
            }
        }
    }

    /// Issue the one hedge copy to a different replica (one-shot: a
    /// refusal forfeits the hedge rather than hammering the cluster).
    fn hedge(&mut self, id: u64, now: f64) {
        let fl = self.flights[&id];
        let station = self.sessions[fl.session].0.station;
        let queries = self.sessions[fl.session].1[fl.batch].clone();
        let deny = self.breaker_deny(now);
        let opts = self.submit_opts(deny.as_deref(), Some(fl.first_node));
        match self.handle.try_submit_ext(station, queries, id, &self.ctx, opts) {
            Submit::Submitted { node, .. } => {
                self.counters.res.backend_requests += 1;
                self.counters.res.hedges_issued += 1;
                self.rec.record(now, id, StageEvent::AttemptStart { kind: AttemptKind::Hedge });
                self.rec.record(now, id, StageEvent::Routed { replica: node });
                self.rec.record(now, id, StageEvent::Enqueued { replica: node });
                let entry = self.flights.get_mut(&id).expect("hedging a live flight");
                entry.copies += 1;
                entry.hedged = true;
            }
            Submit::Shed => {
                self.flights.get_mut(&id).expect("live flight").hedged = true;
            }
        }
    }

    /// The reactor's resilience tick: fire due retries and hedges, cancel
    /// backoff waits whose deadline lapsed. Runs on every loop iteration
    /// (completions and ≤1 ms timeouts alike).
    fn scan(&mut self) {
        if self.res.is_none() || self.flights.is_empty() {
            return;
        }
        let now = now_us(self.t0);
        let ids: Vec<u64> = self.flights.keys().copied().collect();
        for id in ids {
            let Some(&fl) = self.flights.get(&id) else { continue };
            let ready = self.sessions[fl.session].0.ready_us(fl.batch);
            if fl.copies == 0 {
                if self.res.expired(ready, now) {
                    self.flights.remove(&id);
                    self.counters.shed_deadline_queries += fl.n_queries;
                    self.gates[fl.session].in_flight -= 1;
                    self.in_flight -= 1;
                    self.rec.record(now, id, StageEvent::Shed {
                        lane: ShedLane::Deadline,
                        n_queries: fl.n_queries,
                    });
                } else if fl.retry_at_us.is_some_and(|due| due <= now) {
                    self.resubmit(id, now);
                }
                continue;
            }
            if !fl.hedged
                && fl.hedge_at_us.is_some_and(|due| due <= now)
                && !self.res.expired(ready, now)
            {
                self.hedge(id, now);
            }
        }
    }
}

/// The event loop: fire due accept/ready events, then wait on the
/// completion channel with a timeout bounded by the next event (≤1 ms, so
/// reparked batches retry even when this thread has nothing in flight).
fn run_event_thread<R: Recorder>(
    handle: &ClusterHandle,
    t0: Instant,
    policy: BackpressurePolicy,
    res: ResiliencePolicy,
    seed: u64,
    sessions: Vec<(usize, SessionPlan, Vec<Vec<MctQuery>>)>,
    rec: R,
) -> (FrontdoorCounters, DualClock, Trace) {
    let (ctx, crx) = mpsc::channel::<Completion>();
    // Split off the global session indices (trace ids must be unique
    // across threads; everything else runs on the local index).
    let sids: Vec<usize> = sessions.iter().map(|(s, ..)| *s).collect();
    let sessions: Vec<(SessionPlan, Vec<Vec<MctQuery>>)> =
        sessions.into_iter().map(|(_, plan, payload)| (plan, payload)).collect();
    let mut events: Vec<(f64, Ev)> = Vec::new();
    for (s, (plan, _)) in sessions.iter().enumerate() {
        events.push((plan.accept_us, Ev::Accept(s)));
        for b in 0..plan.batches.len() {
            events.push((plan.ready_us(b), Ev::Ready(s, b)));
        }
    }
    events.sort_by(|x, y| x.0.total_cmp(&y.0).then_with(|| x.1.rank().cmp(&y.1.rank())));

    let n = sessions.len();
    let n_nodes = handle.n_nodes();
    let mut r = Reactor {
        handle,
        t0,
        policy,
        sessions,
        sids,
        gates: vec![SessionGate::default(); n],
        thread_parked: 0,
        in_flight: 0,
        counters: FrontdoorCounters::default(),
        clock: DualClock::new(),
        ctx,
        res,
        flights: HashMap::new(),
        budget: res.budget(),
        breakers: vec![CircuitBreaker::new(res.breaker.unwrap_or_default()); n_nodes],
        retry_rng: Rng::new(seed ^ 0x8E_774),
        breaker_rng: Rng::new(seed ^ 0xB4EA_C3),
        lat_ewma: 0.0,
        rec,
    };

    let mut next_ev = 0usize;
    loop {
        while next_ev < events.len() && events[next_ev].0 <= now_us(t0) {
            let (_, ev) = events[next_ev];
            next_ev += 1;
            match ev {
                Ev::Accept(s) => {
                    if r.policy.allows(r.thread_parked) {
                        r.counters.sessions_accepted += 1;
                    } else {
                        // Rung 3 at the front edge: the connection buffer
                        // is full, so the whole session is refused before
                        // any of it is read — accept-less terminals for
                        // every batch so lane totals still reconcile.
                        r.gates[s].refused = true;
                        r.counters.sessions_shed += 1;
                        r.counters.shed_socket_queries += r.sessions[s].0.total_queries();
                        let now = now_us(t0);
                        for b in 0..r.sessions[s].0.batches.len() {
                            r.rec.record(now, r.rid_of(s, b), StageEvent::Shed {
                                lane: ShedLane::Socket,
                                n_queries: r.sessions[s].0.batches[b].n_queries,
                            });
                        }
                    }
                }
                Ev::Ready(s, b) => {
                    if r.gates[s].refused {
                        continue;
                    }
                    let n_queries = r.sessions[s].0.batches[b].n_queries;
                    if r.policy.allows(r.thread_parked) {
                        r.rec.record(now_us(t0), r.rid_of(s, b), StageEvent::Accepted {
                            n_queries,
                        });
                        r.gates[s].parked.push_back(b);
                        r.thread_parked += 1;
                        r.drain_session(s);
                    } else {
                        r.counters.shed_socket_queries += n_queries;
                        r.rec.record(now_us(t0), r.rid_of(s, b), StageEvent::Shed {
                            lane: ShedLane::Socket,
                            n_queries,
                        });
                    }
                }
            }
        }
        if next_ev == events.len() && r.in_flight == 0 && r.thread_parked == 0 {
            break;
        }

        let wait_us = if next_ev == events.len() {
            1_000.0
        } else {
            (events[next_ev].0 - now_us(t0)).clamp(50.0, 1_000.0)
        };
        match crx.recv_timeout(Duration::from_micros(wait_us as u64)) {
            Ok(c) => {
                r.complete(c);
                while let Ok(c) = crx.try_recv() {
                    r.complete(c);
                }
                r.scan();
                r.drain_all();
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                r.scan();
                if r.thread_parked > 0 {
                    r.drain_all();
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                unreachable!("reactor owns its completion sender")
            }
        }
    }
    // Breaker state changes were logged inside this thread's breakers on
    // the shared wall clock; drain them as control events.
    for (i, br) in r.breakers.iter_mut().enumerate() {
        for tr in br.take_transitions() {
            r.rec.record(tr.t_us, CONTROL_ID, StageEvent::Breaker {
                replica: i,
                from: tr.from.into(),
                to: tr.to.into(),
            });
        }
    }
    (r.counters, r.clock, r.rec.into_trace())
}

/// One blocking baseline thread: window-1 serial over its session's
/// batches, retrying admission refusals on a capped exponential backoff
/// with decorrelated jitter (a fixed-period poll synchronises refused
/// threads into thundering herds; jitter spreads them out).
fn run_session_thread<R: Recorder>(
    handle: &ClusterHandle,
    t0: Instant,
    s: usize,
    plan: SessionPlan,
    payloads: Vec<Vec<MctQuery>>,
    mut rec: R,
) -> (FrontdoorCounters, DualClock, Trace) {
    let (ctx, crx) = mpsc::channel::<Completion>();
    let mut counters = FrontdoorCounters { sessions_accepted: 1, ..Default::default() };
    let mut clock = DualClock::new();
    let repark = RetryPolicy::new(1, 100.0, 2_000.0);
    let mut rng = Rng::new(0x9A11_5EED ^ (u64::from(plan.station) << 32) ^ plan.accept_us as u64);
    for (b, queries) in payloads.into_iter().enumerate() {
        pace_until(t0, plan.ready_us(b));
        let id = rid(s, b);
        let n_queries = queries.len();
        rec.record(now_us(t0), id, StageEvent::Accepted { n_queries });
        let mut backoff_us = 0.0;
        loop {
            match handle.try_submit(plan.station, queries.clone(), id, &ctx) {
                Submit::Submitted { node, .. } => {
                    let now = now_us(t0);
                    rec.record(now, id, StageEvent::Admitted);
                    rec.record(now, id, StageEvent::AttemptStart { kind: AttemptKind::Primary });
                    rec.record(now, id, StageEvent::Routed { replica: node });
                    rec.record(now, id, StageEvent::Enqueued { replica: node });
                    let c = crx.recv().expect("tagged completion");
                    let done = now_us(t0);
                    rec.record((done - c.exec_us).max(0.0), id, StageEvent::ExecStart {
                        replica: c.node,
                    });
                    rec.record(done, id, StageEvent::ExecEnd {
                        replica: c.node,
                        kernel_us: c.kernel_us,
                        ok: c.ok,
                    });
                    rec.record(done, id, StageEvent::Completed { n_queries: c.n_queries });
                    let accept_lat =
                        (done - plan.ready_us(b)).max(c.latency_us);
                    clock.record(accept_lat, c.latency_us);
                    counters.completed_requests += 1;
                    counters.completed_queries += c.n_queries;
                    handle.note_completion(&c);
                    break;
                }
                Submit::Shed => {
                    backoff_us = repark.backoff_us(backoff_us, &mut rng);
                    std::thread::sleep(Duration::from_micros(backoff_us as u64));
                }
            }
        }
    }
    (counters, clock, rec.into_trace())
}

/// Pace the fault plan on the wall clock: kill/revive via the handle's
/// liveness mask (drain semantics — a downed replica finishes what it
/// holds) and return the control-plane-shaped timeline.
fn drive_faults(
    handle: &ClusterHandle,
    t0: Instant,
    faults: &FaultPlan,
    classes: &[String],
) -> Vec<ScalingEvent> {
    // Only fail-stop faults drive the liveness mask; gray windows are
    // executed inside the per-replica fault decorators.
    let mut timeline: Vec<(f64, usize, bool)> = Vec::new();
    for f in faults.kills() {
        timeline.push((f.at_us, f.node, false));
        timeline.push((f.at_us + f.down_us, f.node, true));
    }
    timeline.sort_by(|a, b| a.0.total_cmp(&b.0));
    let mut up = vec![true; handle.n_nodes()];
    let mut events = Vec::new();
    for (t, node, live) in timeline {
        pace_until(t0, t);
        handle.set_up(node, live);
        up[node] = live;
        let n_up = up.iter().filter(|u| **u).count();
        events.push(if live {
            ScalingEvent::recover(t, &classes[node], node, n_up)
        } else {
            ScalingEvent::fail(t, &classes[node], node, n_up)
        });
    }
    events
}
