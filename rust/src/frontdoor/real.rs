//! The real front door: a poll-loop reactor on std threads.
//!
//! Each event thread owns its share of the sessions (`s % threads`), walks
//! a time-ordered accept/ready event list against the shared wall clock,
//! and multiplexes every owned session's batches into the cluster through
//! the tagged-completion surface
//! ([`ClusterHandle`](crate::cluster::real::ClusterHandle)) — one channel
//! per event thread, no per-request thread, no blocking reply slot. The
//! [`BackpressurePolicy`](super::BackpressurePolicy) ladder runs at
//! accept/read time; admission refusals from the cluster bounce the batch
//! back to its parked slot (or drop it, under `None`), retried on the next
//! completion or on a ≤1 ms tick so a refusal can never deadlock a thread
//! with nothing in flight.
//!
//! The thread-per-session baseline
//! ([`FrontdoorMode::ThreadPerSession`](super::FrontdoorMode)) is the
//! pre-front-door architecture kept honest: one blocking thread per
//! accepted session, window 1, sessions beyond the thread budget refused
//! at accept. The bench frontier measures exactly this pair.

use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::Result;

use crate::backend::BackendFactory;
use crate::cluster::real::{ClusterHandle, Submit};
use crate::cluster::ClusterConfig;
use crate::controlplane::{FaultPlan, ScalingEvent};
use crate::coordinator::pipeline::{pace_until, Completion};
use crate::coordinator::DualClock;
use crate::prng::Rng;
use crate::rules::types::{MctQuery, World};
use crate::workload::{QueryFactory, SessionPlan};

use super::{
    BackpressurePolicy, FrontdoorConfig, FrontdoorCounters, FrontdoorMode, FrontdoorReport,
    SessionGate,
};

/// Serve `plans` through the front door against a real cluster and report
/// on the accept clock. `factory` builds every replica's backend
/// (homogeneous fleet); `faults` is paced on the wall clock with the
/// real realisation's drain semantics (a downed replica finishes what it
/// holds, so nothing is ever lost here — the sim twin models the lossy
/// variant).
pub fn run_frontdoor(
    cluster: ClusterConfig,
    factory: BackendFactory,
    world: &World,
    seed: u64,
    plans: &[SessionPlan],
    fd: &FrontdoorConfig,
    faults: &FaultPlan,
) -> Result<FrontdoorReport> {
    let factories = vec![factory; cluster.nodes()];
    let classes: Vec<String> =
        cluster.specs.iter().map(|s| s.class.name.to_string()).collect();
    let label = format!("{} sessions | {}", plans.len(), cluster.label());
    let payloads = materialise(world, seed, plans);
    let handle = ClusterHandle::spawn(&cluster, &factories);
    let t0 = Instant::now();

    let (counters, mut clock, fault_events) = std::thread::scope(|scope| {
        let h = &handle;
        let classes = &classes;
        let fault_driver = scope.spawn(move || drive_faults(h, t0, faults, classes));

        let mut shed = FrontdoorCounters::default();
        let workers = match fd.mode {
            FrontdoorMode::Event => {
                // Partition sessions across event threads by index.
                let threads = fd.event_threads.min(plans.len().max(1));
                let mut parts: Vec<Vec<(SessionPlan, Vec<Vec<MctQuery>>)>> =
                    (0..threads).map(|_| Vec::new()).collect();
                for (s, payload) in payloads.into_iter().enumerate() {
                    parts[s % threads].push((plans[s].clone(), payload));
                }
                let policy = fd.backpressure;
                parts
                    .into_iter()
                    .map(|part| scope.spawn(move || run_event_thread(h, t0, policy, part)))
                    .collect::<Vec<_>>()
            }
            FrontdoorMode::ThreadPerSession { max_threads } => {
                // The old architecture: threads are the accept budget. The
                // first `max_threads` sessions by accept time get one
                // blocking thread each; everyone else is refused whole.
                let mut order: Vec<usize> = (0..plans.len()).collect();
                order.sort_by(|&a, &b| {
                    plans[a].accept_us.partial_cmp(&plans[b].accept_us).unwrap()
                });
                let accepted: std::collections::HashSet<usize> =
                    order.iter().take(max_threads).copied().collect();
                let mut workers = Vec::new();
                for (s, payload) in payloads.into_iter().enumerate() {
                    if accepted.contains(&s) {
                        let plan = plans[s].clone();
                        workers.push(
                            scope.spawn(move || run_session_thread(h, t0, plan, payload)),
                        );
                    } else {
                        shed.sessions_shed += 1;
                        shed.shed_socket_queries += plans[s].total_queries();
                    }
                }
                workers
            }
        };

        let mut counters = shed;
        let mut clock = DualClock::new();
        for w in workers {
            let (c, dc) = w.join().expect("front-door worker panicked");
            counters.merge(&c);
            clock.merge(&dc);
        }
        let fault_events = fault_driver.join().expect("fault driver panicked");
        (counters, clock, fault_events)
    });

    let wall_s = t0.elapsed().as_secs_f64().max(1e-9);
    handle.shutdown();

    let report = FrontdoorReport::assemble(
        label,
        fd,
        plans,
        counters,
        &mut clock,
        wall_s,
        fault_events,
    );
    anyhow::ensure!(report.conserves_queries(), "front door lost queries: {}", report.summary());
    Ok(report)
}

/// Pre-materialise every batch's queries so generation cost never sits on
/// the serving path (the reactor measures the front door, not the RNG).
fn materialise(world: &World, seed: u64, plans: &[SessionPlan]) -> Vec<Vec<Vec<MctQuery>>> {
    let factory = QueryFactory::new(world, seed, 24);
    let mut rng = Rng::new(seed ^ 0xF207_D002);
    plans
        .iter()
        .map(|p| {
            p.batches
                .iter()
                .map(|b| {
                    (0..b.n_queries)
                        .map(|_| factory.query(&mut rng, world, p.station))
                        .collect()
                })
                .collect()
        })
        .collect()
}

fn now_us(t0: Instant) -> f64 {
    t0.elapsed().as_secs_f64() * 1e6
}

/// One accept/ready occurrence on a thread's timeline.
#[derive(Debug, Clone, Copy)]
enum Ev {
    Accept(usize),
    Ready(usize, usize),
}

impl Ev {
    fn rank(&self) -> u8 {
        // Accepts sort before same-instant readies (a gap-0 stream's first
        // batch is ready the moment its session is accepted).
        match self {
            Ev::Accept(_) => 0,
            Ev::Ready(..) => 1,
        }
    }
}

/// Per-thread reactor state: the sessions it owns, their ladder gates, and
/// this connection's parked-batch budget.
struct Reactor<'a> {
    handle: &'a ClusterHandle,
    t0: Instant,
    policy: BackpressurePolicy,
    sessions: Vec<(SessionPlan, Vec<Vec<MctQuery>>)>,
    gates: Vec<SessionGate>,
    thread_parked: usize,
    in_flight: usize,
    counters: FrontdoorCounters,
    clock: DualClock,
    ctx: mpsc::Sender<Completion>,
}

impl Reactor<'_> {
    /// Submit the session's parked batches while its window has room.
    /// An admission refusal either bounces the batch back to its parked
    /// slot (ladder policies — the refusal *is* backpressure) or drops it
    /// as shed-in-queue (`None` — nowhere to hold it).
    fn drain_session(&mut self, s: usize) {
        let window = self.policy.window();
        while self.gates[s].in_flight < window {
            let Some(&b) = self.gates[s].parked.front() else { break };
            let station = self.sessions[s].0.station;
            let queries = self.sessions[s].1[b].clone();
            let n_queries = queries.len();
            let id = ((s as u64) << 32) | b as u64;
            match self.handle.try_submit(station, queries, id, &self.ctx) {
                Submit::Submitted { .. } => {
                    self.gates[s].parked.pop_front();
                    self.thread_parked -= 1;
                    self.gates[s].in_flight += 1;
                    self.in_flight += 1;
                }
                Submit::Shed => {
                    if self.policy.reparks_on_admission_shed() {
                        return; // stays parked; retried on completion/tick
                    }
                    self.gates[s].parked.pop_front();
                    self.thread_parked -= 1;
                    self.counters.shed_queue_queries += n_queries;
                }
            }
        }
    }

    fn drain_all(&mut self) {
        for s in 0..self.sessions.len() {
            if !self.gates[s].parked.is_empty() {
                self.drain_session(s);
            }
        }
    }

    fn complete(&mut self, c: Completion) {
        let s = (c.id >> 32) as usize;
        let b = (c.id & 0xFFFF_FFFF) as usize;
        // Accept clock: from when the client had the batch, not from
        // submission. The max() absorbs sub-µs cross-clock jitter.
        let accept_lat = (now_us(self.t0) - self.sessions[s].0.ready_us(b)).max(c.latency_us);
        self.clock.record(accept_lat, c.latency_us);
        self.gates[s].in_flight -= 1;
        self.in_flight -= 1;
        self.counters.completed_requests += 1;
        self.counters.completed_queries += c.n_queries;
        self.handle.note_completion(&c);
    }
}

/// The event loop: fire due accept/ready events, then wait on the
/// completion channel with a timeout bounded by the next event (≤1 ms, so
/// reparked batches retry even when this thread has nothing in flight).
fn run_event_thread(
    handle: &ClusterHandle,
    t0: Instant,
    policy: BackpressurePolicy,
    sessions: Vec<(SessionPlan, Vec<Vec<MctQuery>>)>,
) -> (FrontdoorCounters, DualClock) {
    let (ctx, crx) = mpsc::channel::<Completion>();
    let mut events: Vec<(f64, Ev)> = Vec::new();
    for (s, (plan, _)) in sessions.iter().enumerate() {
        events.push((plan.accept_us, Ev::Accept(s)));
        for b in 0..plan.batches.len() {
            events.push((plan.ready_us(b), Ev::Ready(s, b)));
        }
    }
    events.sort_by(|x, y| {
        x.0.partial_cmp(&y.0).unwrap().then_with(|| x.1.rank().cmp(&y.1.rank()))
    });

    let n = sessions.len();
    let mut r = Reactor {
        handle,
        t0,
        policy,
        sessions,
        gates: vec![SessionGate::default(); n],
        thread_parked: 0,
        in_flight: 0,
        counters: FrontdoorCounters::default(),
        clock: DualClock::new(),
        ctx,
    };

    let mut next_ev = 0usize;
    loop {
        while next_ev < events.len() && events[next_ev].0 <= now_us(t0) {
            let (_, ev) = events[next_ev];
            next_ev += 1;
            match ev {
                Ev::Accept(s) => {
                    if r.policy.allows(r.thread_parked) {
                        r.counters.sessions_accepted += 1;
                    } else {
                        // Rung 3 at the front edge: the connection buffer
                        // is full, so the whole session is refused before
                        // any of it is read.
                        r.gates[s].refused = true;
                        r.counters.sessions_shed += 1;
                        r.counters.shed_socket_queries += r.sessions[s].0.total_queries();
                    }
                }
                Ev::Ready(s, b) => {
                    if r.gates[s].refused {
                        continue;
                    }
                    let n_queries = r.sessions[s].0.batches[b].n_queries;
                    if r.policy.allows(r.thread_parked) {
                        r.gates[s].parked.push_back(b);
                        r.thread_parked += 1;
                        r.drain_session(s);
                    } else {
                        r.counters.shed_socket_queries += n_queries;
                    }
                }
            }
        }
        if next_ev == events.len() && r.in_flight == 0 && r.thread_parked == 0 {
            break;
        }

        let wait_us = if next_ev == events.len() {
            1_000.0
        } else {
            (events[next_ev].0 - now_us(t0)).clamp(50.0, 1_000.0)
        };
        match crx.recv_timeout(Duration::from_micros(wait_us as u64)) {
            Ok(c) => {
                r.complete(c);
                while let Ok(c) = crx.try_recv() {
                    r.complete(c);
                }
                r.drain_all();
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                if r.thread_parked > 0 {
                    r.drain_all();
                }
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                unreachable!("reactor owns its completion sender")
            }
        }
    }
    (r.counters, r.clock)
}

/// One blocking baseline thread: window-1 serial over its session's
/// batches, retrying admission refusals on a 500 µs poll (a blocked
/// connection, in the old architecture's terms).
fn run_session_thread(
    handle: &ClusterHandle,
    t0: Instant,
    plan: SessionPlan,
    payloads: Vec<Vec<MctQuery>>,
) -> (FrontdoorCounters, DualClock) {
    let (ctx, crx) = mpsc::channel::<Completion>();
    let mut counters = FrontdoorCounters { sessions_accepted: 1, ..Default::default() };
    let mut clock = DualClock::new();
    for (b, queries) in payloads.into_iter().enumerate() {
        pace_until(t0, plan.ready_us(b));
        loop {
            match handle.try_submit(plan.station, queries.clone(), b as u64, &ctx) {
                Submit::Submitted { .. } => {
                    let c = crx.recv().expect("tagged completion");
                    let accept_lat =
                        (now_us(t0) - plan.ready_us(b)).max(c.latency_us);
                    clock.record(accept_lat, c.latency_us);
                    counters.completed_requests += 1;
                    counters.completed_queries += c.n_queries;
                    handle.note_completion(&c);
                    break;
                }
                Submit::Shed => std::thread::sleep(Duration::from_micros(500)),
            }
        }
    }
    (counters, clock)
}

/// Pace the fault plan on the wall clock: kill/revive via the handle's
/// liveness mask (drain semantics — a downed replica finishes what it
/// holds) and return the control-plane-shaped timeline.
fn drive_faults(
    handle: &ClusterHandle,
    t0: Instant,
    faults: &FaultPlan,
    classes: &[String],
) -> Vec<ScalingEvent> {
    let mut timeline: Vec<(f64, usize, bool)> = Vec::new();
    for f in faults.faults() {
        timeline.push((f.at_us, f.node, false));
        timeline.push((f.at_us + f.down_us, f.node, true));
    }
    timeline.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
    let mut up = vec![true; handle.n_nodes()];
    let mut events = Vec::new();
    for (t, node, live) in timeline {
        pace_until(t0, t);
        handle.set_up(node, live);
        up[node] = live;
        let n_up = up.iter().filter(|u| **u).count();
        events.push(if live {
            ScalingEvent::recover(t, &classes[node], node, n_up)
        } else {
            ScalingEvent::fail(t, &classes[node], node, n_up)
        });
    }
    events
}
