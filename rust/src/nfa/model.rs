//! NFA data model: levels, labelled transitions, accepting decisions.
//!
//! The NFA is a levelled DAG — one level per *consolidated criterion*
//! (§3.2.1) in the order chosen by the optimiser. Rules are paths from the
//! single root to per-rule accepting states; shared prefixes are merged
//! (that is what makes the structure compact, Fig 3a). Matching a query
//! means advancing an *active state set* level by level, following every
//! edge whose label matches the query's value for that level — wildcard
//! (`Any`) edges are what make the automaton non-deterministic.

use crate::rules::standard::Consolidated;

/// Edge label of one NFA transition.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum EdgeLabel {
    /// Matches any query value (wildcard criterion).
    Any,
    /// Matches one dictionary value exactly.
    Exact(u32),
    /// Matches `lo <= q <= hi` (v1 whole ranges; v2 expanded bounds use
    /// half-open sides: `(lo, u32::MAX)` / `(0, hi)`).
    Range(u32, u32),
}

impl EdgeLabel {
    #[inline]
    pub fn matches(&self, q: u32) -> bool {
        match *self {
            EdgeLabel::Any => true,
            EdgeLabel::Exact(v) => v == q,
            EdgeLabel::Range(lo, hi) => q >= lo && q <= hi,
        }
    }
}

/// One transition out of a state.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Edge {
    pub label: EdgeLabel,
    /// Target state index within the *next* level.
    pub to: u32,
}

/// Evaluation plan for one level: which consolidated criterion it tests.
/// The encoder uses this to lay a query out as a flat `[i32; L]` vector.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LevelPlan {
    pub criterion: Consolidated,
}

/// Accepting-state payload (one per rule surviving compilation).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Accept {
    pub rule_id: u32,
    pub weight: f32,
    pub decision_min: u16,
}

/// One compiled NFA partition.
///
/// `states[l]` holds the edge lists of the states at level `l` (edges point
/// into level `l+1`); level 0 has exactly one root state. `accepts[s]` is
/// the payload of final-level state `s`.
#[derive(Debug, Clone)]
pub struct CompiledNfa {
    /// Level order (identical across all partitions of a rule set).
    pub plan: Vec<LevelPlan>,
    /// `states[l][s]` = outgoing edges of state `s` at level `l`.
    /// `states.len() == plan.len()`; targets of the last entry index into
    /// `accepts`.
    pub states: Vec<Vec<Vec<Edge>>>,
    /// Accepting payloads, indexed by final-state id.
    pub accepts: Vec<Accept>,
    /// The station this partition serves, or `None` for the global
    /// (wildcard-station) partition.
    pub station: Option<u32>,
}

impl CompiledNfa {
    /// Number of levels (NFA depth = hardware pipeline depth, §3.3).
    pub fn depth(&self) -> usize {
        self.plan.len()
    }

    /// Widest level (states), the quantity bounded by the hardware `S`.
    pub fn max_width(&self) -> usize {
        self.states
            .iter()
            .map(|l| l.len())
            .max()
            .unwrap_or(0)
            .max(self.accepts.len())
    }

    /// Total transitions — the paper's memory driver ("the cardinality at
    /// each stage has a direct impact on the memory required to store the
    /// NFA transitions", §3.2.1).
    pub fn n_transitions(&self) -> usize {
        self.states.iter().map(|l| l.iter().map(Vec::len).sum::<usize>()).sum()
    }

    /// Per-level transition counts (used by the constraint generator to
    /// report the distribution the paper discusses in §3.3).
    pub fn transitions_per_level(&self) -> Vec<usize> {
        self.states.iter().map(|l| l.iter().map(Vec::len).sum()).collect()
    }
}

/// A full compiled rule set: station-keyed partitions plus the global
/// (wildcard-station) partitions every query must also consult.
///
/// Partitioning is the TPU adaptation of ERBIUM's single-BRAM NFA (see
/// DESIGN.md §Hardware-Adaptation): each partition's dense image fits one
/// VMEM-sized tile (`S` states/level).
#[derive(Debug, Clone)]
pub struct PartitionedNfa {
    pub partitions: Vec<CompiledNfa>,
    /// station id → indices into `partitions`.
    pub by_station: std::collections::HashMap<u32, Vec<usize>>,
    /// Indices of global partitions (consulted by every query).
    pub global: Vec<usize>,
    pub plan: Vec<LevelPlan>,
}

impl PartitionedNfa {
    /// Partition indices relevant to a query at `station`.
    pub fn partitions_for(&self, station: u32) -> impl Iterator<Item = usize> + '_ {
        self.by_station
            .get(&station)
            .map(|v| v.as_slice())
            .unwrap_or(&[])
            .iter()
            .chain(self.global.iter())
            .copied()
    }

    pub fn total_transitions(&self) -> usize {
        self.partitions.iter().map(|p| p.n_transitions()).sum()
    }

    pub fn total_accepts(&self) -> usize {
        self.partitions.iter().map(|p| p.accepts.len()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_label_matching() {
        assert!(EdgeLabel::Any.matches(123));
        assert!(EdgeLabel::Exact(5).matches(5));
        assert!(!EdgeLabel::Exact(5).matches(6));
        assert!(EdgeLabel::Range(10, 20).matches(10));
        assert!(EdgeLabel::Range(10, 20).matches(20));
        assert!(!EdgeLabel::Range(10, 20).matches(21));
        assert!(!EdgeLabel::Range(10, 20).matches(9));
    }

    #[test]
    fn depth_and_width_of_trivial_nfa() {
        let nfa = CompiledNfa {
            plan: vec![],
            states: vec![],
            accepts: vec![],
            station: None,
        };
        assert_eq!(nfa.depth(), 0);
        assert_eq!(nfa.max_width(), 0);
        assert_eq!(nfa.n_transitions(), 0);
    }
}
