//! NFA Parser (§3.1): builds the NFA memory structure from the rule set and
//! the optimiser's level order, absorbing all four MCT v2 standard changes
//! (§3.2) in software so the hardware kernel stays generic:
//!
//! 1. **Criteria merging / range expansion** (§3.2.1) — v2 numeric ranges
//!    become two half-open levels; handled by the level plan + labelling.
//! 2. **Precision weight for ranges** (§3.2.2) — overlapping flight-number
//!    ranges are split offline into disjoint sub-rules so the most precise
//!    range is unique as a match (Fig 3c); the dynamic range-size weight is
//!    frozen into the sub-rule's static weight.
//! 3. **Cross-matching criteria** (§3.2.3) — carrier duplication for
//!    non-code-share rules via [`effective_exact`].
//! 4. **Code-share flight numbers** (§3.2.4) — flight-range migration to
//!    the CsFlightRange criterion via [`effective_range`].

use std::collections::HashMap;

use crate::rules::standard::{
    effective_exact, effective_range, rule_weight, Consolidated, Schema,
};
use crate::rules::types::{RangeSlot, Rule, RuleSet, WILDCARD};

use super::model::{Accept, CompiledNfa, Edge, EdgeLabel, LevelPlan, PartitionedNfa};
use super::optimiser::{optimise_order, OrderStrategy};

/// Compilation knobs.
#[derive(Debug, Clone)]
pub struct CompileOptions {
    pub strategy: OrderStrategy,
    /// Hardware bound on states per level (`S` of the kernel image). One
    /// partition never exceeds this width; larger per-station rule
    /// populations are chunked across several partitions.
    pub max_states_per_level: usize,
    /// §3.2.2 offline range splitting (default on for v2; ablation toggle).
    pub split_overlaps: bool,
}

impl Default for CompileOptions {
    fn default() -> Self {
        CompileOptions {
            strategy: OrderStrategy::Optimised,
            max_states_per_level: 64,
            split_overlaps: true,
        }
    }
}

/// Compiler report — feeds the §3.3 resource/memory comparison.
#[derive(Debug, Clone)]
pub struct CompileStats {
    pub rules_in: usize,
    /// Additional rules produced by §3.2.2 splitting ("zero to a few
    /// hundred among an average of 160k rules").
    pub rules_added_by_split: usize,
    pub partitions: usize,
    pub depth: usize,
    pub max_width: usize,
    pub total_transitions: usize,
    pub total_accepts: usize,
}

/// A declared rule plus its (possibly overridden) frozen precision weight.
#[derive(Debug, Clone)]
struct WeightedRule {
    rule: Rule,
    weight: f32,
}

/// Compile a rule set into station-partitioned NFAs.
pub fn compile_rule_set(
    schema: &Schema,
    rs: &RuleSet,
    opts: &CompileOptions,
) -> (PartitionedNfa, CompileStats) {
    assert_eq!(schema.version, rs.version, "schema/rule-set version mismatch");
    let order = optimise_order(schema, rs, opts.strategy);
    let plan: Vec<LevelPlan> = order.iter().map(|c| LevelPlan { criterion: *c }).collect();

    // §3.2.2 offline splitting.
    let mut weighted: Vec<WeightedRule> = rs
        .rules
        .iter()
        .map(|r| WeightedRule { rule: r.clone(), weight: rule_weight(schema, r) })
        .collect();
    let rules_in = weighted.len();
    // §3.2.2 splitting realises the *v2* dynamic precision layer. v1 has no
    // range-size priority — overlapping equal-weight v1 rules tie-break by
    // id, which splitting-by-tightness would violate — so it must stay off.
    if opts.split_overlaps && schema.version == crate::rules::standard::StandardVersion::V2 {
        weighted = split_overlapping_ranges(schema, weighted);
    }
    let rules_after = weighted.len();
    // Deterministic build order: ascending rule id (ties by sub-rule range)
    // so that accepting-state order — and therefore argmax tie-breaking on
    // every backend — prefers the lowest rule id.
    weighted.sort_by(|a, b| {
        a.rule.id.cmp(&b.rule.id).then_with(|| a.rule.ranges.cmp(&b.rule.ranges))
    });

    // Label every rule per level, then bucket by the level-0 (station) label.
    let mut buckets: HashMap<Option<u32>, Vec<(Vec<EdgeLabel>, Accept)>> = HashMap::new();
    for wr in &weighted {
        let labels = label_rule(schema, &order, &wr.rule);
        let key = match labels[0] {
            EdgeLabel::Exact(st) => Some(st),
            EdgeLabel::Any => None,
            EdgeLabel::Range(..) => unreachable!("station level cannot be a range"),
        };
        let accept =
            Accept { rule_id: wr.rule.id, weight: wr.weight, decision_min: wr.rule.decision_min };
        buckets.entry(key).or_default().push((labels, accept));
    }

    // Chunk buckets to the hardware width and build tries.
    let mut partitions = Vec::new();
    let mut by_station: HashMap<u32, Vec<usize>> = HashMap::new();
    let mut global = Vec::new();
    let mut keys: Vec<Option<u32>> = buckets.keys().copied().collect();
    keys.sort();
    for key in keys {
        let rules = &buckets[&key];
        for chunk in rules.chunks(opts.max_states_per_level) {
            let nfa = build_trie(&plan, chunk, key);
            debug_assert!(nfa.max_width() <= opts.max_states_per_level);
            let idx = partitions.len();
            partitions.push(nfa);
            match key {
                Some(st) => by_station.entry(st).or_default().push(idx),
                None => global.push(idx),
            }
        }
    }

    let stats = CompileStats {
        rules_in,
        rules_added_by_split: rules_after - rules_in,
        partitions: partitions.len(),
        depth: plan.len(),
        max_width: partitions.iter().map(|p| p.max_width()).max().unwrap_or(0),
        total_transitions: partitions.iter().map(|p| p.n_transitions()).sum(),
        total_accepts: partitions.iter().map(|p| p.accepts.len()).sum(),
    };
    (PartitionedNfa { partitions, by_station, global, plan }, stats)
}

/// Produce the per-level edge labels of one rule under the chosen order,
/// applying the §3.2.3/§3.2.4 effective-value rewrites.
fn label_rule(schema: &Schema, order: &[Consolidated], rule: &Rule) -> Vec<EdgeLabel> {
    order
        .iter()
        .map(|c| match *c {
            Consolidated::Exact(slot) => {
                let idx = schema.exact_index(slot).expect("slot");
                match effective_exact(schema, rule, idx) {
                    WILDCARD => EdgeLabel::Any,
                    v => EdgeLabel::Exact(v),
                }
            }
            Consolidated::Range(slot) => {
                let idx = schema.range_index(slot).expect("slot");
                let (lo, hi) = effective_range(schema, rule, idx);
                if (lo, hi) == Schema::full_range(slot) {
                    EdgeLabel::Any
                } else {
                    EdgeLabel::Range(lo, hi)
                }
            }
            Consolidated::RangeMin(slot) => {
                let idx = schema.range_index(slot).expect("slot");
                let (lo, hi) = effective_range(schema, rule, idx);
                if (lo, hi) == Schema::full_range(slot) || lo == 0 {
                    EdgeLabel::Any
                } else {
                    EdgeLabel::Range(lo, u32::MAX)
                }
            }
            Consolidated::RangeMax(slot) => {
                let idx = schema.range_index(slot).expect("slot");
                let (lo, hi) = effective_range(schema, rule, idx);
                if (lo, hi) == Schema::full_range(slot) || hi >= Schema::domain_max(slot) {
                    EdgeLabel::Any
                } else {
                    EdgeLabel::Range(0, hi)
                }
            }
        })
        .collect()
}

/// §3.2.2: split overlapping flight-number ranges into disjoint sub-rules.
///
/// Rules are grouped by their *conflict signature* (every field except the
/// arrival flight range). Within a group, elementary intervals are assigned
/// to the tightest covering original range (ties → lowest rule id); each
/// original rule is re-emitted as one sub-rule per maximal owned run, with
/// the **original** rule's dynamic weight frozen in. Queries therefore match
/// exactly one sub-rule per group — "the most precise range is unique as a
/// match" (Fig 3c) — while reported winners and weights are unchanged.
fn split_overlapping_ranges(schema: &Schema, rules: Vec<WeightedRule>) -> Vec<WeightedRule> {
    let Some(fr) = schema.range_index(RangeSlot::ArrFlightRange) else {
        return rules;
    };
    let full = Schema::full_range(RangeSlot::ArrFlightRange);

    // Conflict signature: the whole rule minus the arrival flight range.
    let sig = |r: &Rule| -> String {
        let mut s = String::new();
        for v in &r.exact {
            s.push_str(&format!("{v},"));
        }
        for (i, rg) in r.ranges.iter().enumerate() {
            if i != fr {
                s.push_str(&format!("{}-{},", rg.0, rg.1));
            }
        }
        // NOTE: the decision is *not* part of the signature — two rules that
        // match the same traffic but prescribe different connection times
        // are precisely the conflicts §3.2.2 resolves by range precision.
        s.push_str(&format!("cs{:?}", r.cs_ind));
        s
    };

    let mut groups: HashMap<String, Vec<WeightedRule>> = HashMap::new();
    for wr in rules {
        groups.entry(sig(&wr.rule)).or_default().push(wr);
    }

    let mut out = Vec::new();
    for (_, group) in groups {
        let ranged: Vec<&WeightedRule> =
            group.iter().filter(|wr| wr.rule.ranges[fr] != full).collect();
        let has_overlap = ranged.len() >= 2 && {
            let mut iv: Vec<(u32, u32)> = ranged.iter().map(|wr| wr.rule.ranges[fr]).collect();
            iv.sort();
            iv.windows(2).any(|w| w[0].1 >= w[1].0)
        };
        if !has_overlap {
            out.extend(group);
            continue;
        }
        // Elementary-interval decomposition over the group's boundaries.
        let mut bounds: Vec<u32> = Vec::new();
        for wr in &ranged {
            let (lo, hi) = wr.rule.ranges[fr];
            bounds.push(lo);
            bounds.push(hi + 1);
        }
        bounds.sort_unstable();
        bounds.dedup();
        // For each elementary interval [bounds[i], bounds[i+1]-1], find the
        // owner: tightest covering original range, ties to lowest id.
        let mut owned_runs: HashMap<usize, Vec<(u32, u32)>> = HashMap::new(); // ranged idx → runs
        for win in bounds.windows(2) {
            let (ilo, ihi) = (win[0], win[1] - 1);
            let mut owner: Option<usize> = None;
            for (k, wr) in ranged.iter().enumerate() {
                let (lo, hi) = wr.rule.ranges[fr];
                if lo <= ilo && ihi <= hi {
                    let better = match owner {
                        None => true,
                        Some(o) => {
                            let (olo, ohi) = ranged[o].rule.ranges[fr];
                            let (sz, osz) = (hi - lo, ohi - olo);
                            sz < osz || (sz == osz && wr.rule.id < ranged[o].rule.id)
                        }
                    };
                    if better {
                        owner = Some(k);
                    }
                }
            }
            if let Some(o) = owner {
                let runs = owned_runs.entry(o).or_default();
                match runs.last_mut() {
                    Some(last) if last.1 + 1 == ilo => last.1 = ihi,
                    _ => runs.push((ilo, ihi)),
                }
            }
        }
        // Emit sub-rules; non-ranged rules of the group pass through.
        for wr in &group {
            if wr.rule.ranges[fr] == full {
                out.push(wr.clone());
            }
        }
        for (k, runs) in owned_runs {
            let original = ranged[k];
            for (lo, hi) in runs {
                let mut sub = original.rule.clone();
                sub.ranges[fr] = (lo, hi);
                out.push(WeightedRule { rule: sub, weight: original.weight });
            }
        }
    }
    out
}

/// Build one prefix-merged trie ("NFA") over a chunk of labelled rules.
fn build_trie(
    plan: &[LevelPlan],
    chunk: &[(Vec<EdgeLabel>, Accept)],
    station: Option<u32>,
) -> CompiledNfa {
    let depth = plan.len();
    let mut states: Vec<Vec<Vec<Edge>>> = vec![Vec::new(); depth];
    states[0].push(Vec::new()); // root
    let mut accepts: Vec<Accept> = Vec::new();
    // (level, from-state, label) → next-state id at level+1
    let mut node_index: Vec<HashMap<(u32, EdgeLabel), u32>> =
        vec![HashMap::new(); depth.saturating_sub(1)];

    for (labels, accept) in chunk {
        debug_assert_eq!(labels.len(), depth);
        let mut cur = 0u32;
        for l in 0..depth - 1 {
            let key = (cur, labels[l]);
            if let Some(&next) = node_index[l].get(&key) {
                cur = next;
            } else {
                let next = states[l + 1].len() as u32;
                states[l + 1].push(Vec::new());
                states[l][cur as usize].push(Edge { label: labels[l], to: next });
                node_index[l].insert(key, next);
                cur = next;
            }
        }
        // Final level: a fresh accepting state per (sub-)rule.
        let aid = accepts.len() as u32;
        accepts.push(*accept);
        states[depth - 1][cur as usize].push(Edge { label: labels[depth - 1], to: aid });
    }

    CompiledNfa { plan: plan.to_vec(), states, accepts, station }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::StandardVersion;

    fn compile_small(
        v: StandardVersion,
        n: usize,
        opts: &CompileOptions,
    ) -> (Schema, RuleSet, PartitionedNfa, CompileStats) {
        let cfg = GeneratorConfig::small(41, n);
        let w = generate_world(&cfg);
        let schema = Schema::for_version(v);
        let rs = generate_rule_set(&cfg, &w, v);
        let (p, s) = compile_rule_set(&schema, &rs, opts);
        (schema, rs, p, s)
    }

    #[test]
    fn depth_matches_consolidated_criteria() {
        let (_, _, p1, s1) = compile_small(StandardVersion::V1, 200, &CompileOptions::default());
        assert_eq!(s1.depth, 22);
        assert_eq!(p1.plan.len(), 22);
        let (_, _, _, s2) = compile_small(StandardVersion::V2, 200, &CompileOptions::default());
        assert_eq!(s2.depth, 26);
    }

    #[test]
    fn widths_respect_hardware_bound() {
        let opts = CompileOptions { max_states_per_level: 32, ..Default::default() };
        let (_, _, p, s) = compile_small(StandardVersion::V2, 500, &opts);
        assert!(s.max_width <= 32);
        for part in &p.partitions {
            assert!(part.max_width() <= 32);
        }
    }

    #[test]
    fn every_rule_reaches_an_accept() {
        // v1: no splitting — every rule id must survive verbatim.
        let (_, rs1, p1, _) = compile_small(StandardVersion::V1, 300, &CompileOptions::default());
        let mut seen = vec![false; rs1.rules.len() + 1000];
        for part in &p1.partitions {
            for a in &part.accepts {
                seen[a.rule_id as usize] = true;
            }
        }
        for r in &rs1.rules {
            assert!(seen[r.id as usize], "v1 rule {} lost in compilation", r.id);
        }
        // v2: §3.2.2 splitting may *legitimately* drop rules whose range is
        // fully shadowed by strictly tighter overlapping ranges (they can
        // never win), but that must stay rare.
        let (_, rs2, p2, _) = compile_small(StandardVersion::V2, 300, &CompileOptions::default());
        let mut seen = vec![false; rs2.rules.len() + 4000];
        for part in &p2.partitions {
            for a in &part.accepts {
                seen[a.rule_id as usize] = true;
            }
        }
        let lost = rs2.rules.iter().filter(|r| !seen[r.id as usize]).count();
        assert!(
            lost <= rs2.rules.len() / 100,
            "v2 lost {lost} of {} rules (only fully-shadowed ranges may drop)",
            rs2.rules.len()
        );
    }

    #[test]
    fn split_produces_disjoint_covers() {
        // Two identical rules with nested flight ranges must be split so no
        // flight number matches both.
        let schema = Schema::for_version(StandardVersion::V2);
        let fr = schema.range_index(RangeSlot::ArrFlightRange).unwrap();
        let mk = |id: u32, lo: u32, hi: u32| {
            let mut r = Rule {
                id,
                exact: vec![WILDCARD; schema.exact_slots.len()],
                ranges: schema.range_slots.iter().map(|s| Schema::full_range(*s)).collect(),
                cs_ind: Some(false),
                decision_min: 30,
            };
            r.exact[0] = 7; // station
            r.ranges[fr] = (lo, hi);
            r
        };
        // NOTE: decision_min equal so they share a conflict signature.
        let rules = vec![
            WeightedRule { rule: mk(0, 700, 1000), weight: 1.0 },
            WeightedRule { rule: mk(1, 700, 800), weight: 2.0 },
        ];
        let out = split_overlapping_ranges(&schema, rules);
        // Fig 3c: [700,800]→rule1, [801,1000]→rule0.
        assert_eq!(out.len(), 2);
        let mut ranges: Vec<(u32, u32, u32, f32)> =
            out.iter().map(|wr| {
                let (lo, hi) = wr.rule.ranges[fr];
                (wr.rule.id, lo, hi, wr.weight)
            }).collect();
        ranges.sort_by_key(|r| r.1);
        assert_eq!(ranges[0], (1, 700, 800, 2.0));
        assert_eq!(ranges[1], (0, 801, 1000, 1.0));
    }

    #[test]
    fn split_overlap_count_is_moderate() {
        // §3.2.2: "zero to a few hundred among an average of 160k rules".
        let mut cfg = GeneratorConfig::small(43, 2000);
        cfg.overlap_conflicts = 25;
        let w = generate_world(&cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let rs = generate_rule_set(&cfg, &w, StandardVersion::V2);
        let (_, stats) = compile_rule_set(&schema, &rs, &CompileOptions::default());
        assert!(stats.rules_added_by_split > 0, "injected overlaps must split");
        assert!(
            stats.rules_added_by_split < rs.rules.len() / 5,
            "splitting must stay moderate: {}",
            stats.rules_added_by_split
        );
    }

    #[test]
    fn prefix_merging_compresses() {
        // Many rules at one station share wildcard prefixes: the trie must
        // be much smaller than rules × depth states.
        let (_, rs, p, s) = compile_small(StandardVersion::V1, 400, &CompileOptions::default());
        let naive_states = rs.rules.len() * s.depth;
        let actual: usize = p.partitions.iter().map(|n| {
            n.states.iter().map(Vec::len).sum::<usize>()
        }).sum();
        assert!(
            actual < naive_states / 2,
            "prefix sharing too weak: {actual} vs naive {naive_states}"
        );
    }

    #[test]
    fn station_routing_covers_all_partitions() {
        let (_, _, p, _) = compile_small(StandardVersion::V2, 300, &CompileOptions::default());
        let routed: usize =
            p.by_station.values().map(Vec::len).sum::<usize>() + p.global.len();
        assert_eq!(routed, p.partitions.len());
    }
}
