//! Constraint Generator (§3.1): customises the hardware kernel according to
//! the rule structure and NFA shape, and estimates the synthesis outcome
//! (resources, memory, clock frequency).
//!
//! On the FPGA this module emitted HLS parameters and ran synthesis; here it
//! selects the AOT artifact variant `(B, S, L)` a compiled rule set needs
//! and evaluates the *synthesis model* — analytic formulas calibrated to the
//! paper's reported outcomes:
//!
//! * v2 is **56 % more resource-intensive** than v1 (§3.3);
//! * v2 clocks **11 % lower** than v1 (bigger NFA / deeper pipeline, §3.3);
//! * growing 1 → 4 engines costs **30 %** of the operating frequency
//!   (§4.3, Fig 7 discussion);
//! * v2 uses ~**4 % less FPGA memory** despite more rules, thanks to the
//!   more homogeneous per-level transition distribution (§3.3).

use crate::rules::standard::StandardVersion;

use super::model::PartitionedNfa;

/// FPGA shell / data-movement interface available to the deployment (§3.3):
/// on-premises Alveo boards expose the streaming QDMA shell; AWS F1 only has
/// the blocking XDMA shell, which dominates small-batch latency.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Shell {
    /// Streaming interface (on-prem Alveo U250 deployment of MCT v1).
    Qdma,
    /// Blocking memory-mapped interface (AWS F1), §3.3.
    Xdma,
}

impl Shell {
    pub fn name(self) -> &'static str {
        match self {
            Shell::Qdma => "QDMA",
            Shell::Xdma => "XDMA",
        }
    }
}

/// Hardware kernel configuration: what the Constraint Generator fixes before
/// "synthesis" and what the host must honour at runtime.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HardwareConfig {
    pub version: StandardVersion,
    pub shell: Shell,
    /// NFA Evaluation Engines inside one kernel (1, 2 or 4).
    pub engines: usize,
    /// Artifact depth (padded levels).
    pub l: usize,
    /// Artifact width (padded states per level).
    pub s: usize,
}

impl HardwareConfig {
    /// The deployments benchmarked in §3.3 / Fig 4.
    pub fn v1_onprem(engines: usize) -> Self {
        HardwareConfig { version: StandardVersion::V1, shell: Shell::Qdma, engines, l: 28, s: 64 }
    }
    pub fn v2_aws(engines: usize) -> Self {
        HardwareConfig { version: StandardVersion::V2, shell: Shell::Xdma, engines, l: 28, s: 64 }
    }

    /// Artifact variant name — must match `python/compile/aot.py` output.
    pub fn artifact_name(&self, batch: usize) -> String {
        format!("nfa_b{}_s{}_l{}", batch, self.s, self.l)
    }
}

/// Synthesis-model output for one (rule set, hardware config) pair.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct KernelEstimate {
    /// Abstract resource units (LUT/FF-equivalent); only ratios matter.
    pub resource_units: f64,
    /// Accelerator memory footprint, bytes.
    pub memory_bytes: usize,
    /// Achievable clock, MHz.
    pub frequency_mhz: f64,
    /// Pipeline depth = consolidated criteria (§3.3: 26 vs 22).
    pub pipeline_depth: usize,
    /// Number of compiled partitions (tiles streamed through the kernel).
    pub partitions: usize,
}

/// Base clock of the single-engine v1 design. ERBIUM [15] reports its Alveo
/// U250 kernels in the 250–300 MHz band; the absolute value only scales the
/// time axis — every figure depends on ratios and the PCIe bound.
pub const BASE_FREQ_MHZ: f64 = 285.0;

/// Clock model: the v1→v2 NFA growth costs 11 % (§3.3) and every doubling of
/// engines costs a fixed complexity factor such that 1→4 engines loses 30 %
/// (§4.3): per-doubling factor = sqrt(0.70) ≈ 0.8367.
pub fn clock_frequency_mhz(version: StandardVersion, engines: usize) -> f64 {
    let version_factor = match version {
        StandardVersion::V1 => 1.0,
        StandardVersion::V2 => 0.89,
    };
    let doublings = (engines as f64).log2();
    BASE_FREQ_MHZ * version_factor * 0.70f64.powf(doublings / 2.0)
}

/// Per-level BRAM bank granularity of the transition memory. The FPGA
/// allocates whole banks per pipeline stage; a skewed per-level transition
/// distribution (v1) strands capacity in hot levels, which is why v2 —
/// despite more rules — comes out slightly smaller (§3.3).
const BANK_TRANSITIONS: usize = 512;
const BYTES_PER_TRANSITION: usize = 16;

/// Evaluate the synthesis model for a compiled rule set.
pub fn estimate(cfg: &HardwareConfig, nfa: &PartitionedNfa) -> KernelEstimate {
    let depth = nfa.plan.len();
    // Resources: per engine, comparator+routing logic per level plus the
    // range comparators (two per range level), scaled by width.
    let range_levels = nfa
        .plan
        .iter()
        .filter(|p| {
            !matches!(p.criterion, crate::rules::standard::Consolidated::Exact(_))
        })
        .count();
    let per_engine = 150.0
        + 30.0 * depth as f64
        + 60.0 * range_levels as f64
        + 0.15 * cfg.s as f64 * depth as f64;
    // Routing/steering logic grows with the stored transition population
    // (wider per-level muxes and deeper priority encoders); this dominant
    // term is what makes the v2 deployment — larger rule set, deeper
    // pipeline — land near the paper's +56 % (§3.3).
    let routing = 3.0 * nfa.total_transitions() as f64;
    let resource_units = per_engine * cfg.engines as f64 + routing;

    // Memory: per partition, per level, transitions rounded up to banks.
    let mut memory_bytes = 0usize;
    for p in &nfa.partitions {
        for t in p.transitions_per_level() {
            let banks = t.div_ceil(BANK_TRANSITIONS).max(1);
            memory_bytes += banks * BANK_TRANSITIONS * BYTES_PER_TRANSITION;
        }
    }

    KernelEstimate {
        resource_units,
        memory_bytes,
        frequency_mhz: clock_frequency_mhz(cfg.version, cfg.engines),
        pipeline_depth: depth,
        partitions: nfa.partitions.len(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::parser::{compile_rule_set, CompileOptions};
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::Schema;

    #[test]
    fn frequency_anchors_match_paper() {
        // §3.3: v2 clocks 11 % below v1 at equal engine count.
        let f1 = clock_frequency_mhz(StandardVersion::V1, 4);
        let f2 = clock_frequency_mhz(StandardVersion::V2, 4);
        assert!((f2 / f1 - 0.89).abs() < 1e-9);
        // §4.3: 4 engines clock 30 % below 1 engine.
        let e1 = clock_frequency_mhz(StandardVersion::V2, 1);
        let e4 = clock_frequency_mhz(StandardVersion::V2, 4);
        assert!((e4 / e1 - 0.70).abs() < 1e-9);
        // 2 engines sit strictly in between.
        let e2 = clock_frequency_mhz(StandardVersion::V2, 2);
        assert!(e4 < e2 && e2 < e1);
    }

    #[test]
    fn v2_more_resource_intensive() {
        let cfg = GeneratorConfig::small(51, 800);
        let w = generate_world(&cfg);
        let opts = CompileOptions::default();
        let (n1, _) = compile_rule_set(
            &Schema::for_version(StandardVersion::V1),
            &generate_rule_set(&cfg, &w, StandardVersion::V1),
            &opts,
        );
        let (n2, _) = compile_rule_set(
            &Schema::for_version(StandardVersion::V2),
            &generate_rule_set(&cfg, &w, StandardVersion::V2),
            &opts,
        );
        let e1 = estimate(&HardwareConfig::v1_onprem(4), &n1);
        let e2 = estimate(&HardwareConfig::v2_aws(4), &n2);
        let ratio = e2.resource_units / e1.resource_units;
        // §3.3 reports +56 %; the synthesis model must land in that band.
        assert!((1.35..1.75).contains(&ratio), "resource ratio {ratio}");
        assert_eq!(e1.pipeline_depth, 22);
        assert_eq!(e2.pipeline_depth, 26);
    }

    #[test]
    fn artifact_name_is_stable() {
        let cfg = HardwareConfig::v2_aws(4);
        assert_eq!(cfg.artifact_name(1024), "nfa_b1024_s64_l28");
    }

    #[test]
    fn memory_scales_with_rules() {
        let opts = CompileOptions::default();
        let small_cfg = GeneratorConfig::small(53, 200);
        let big_cfg = GeneratorConfig::small(53, 2000);
        let w = generate_world(&big_cfg);
        let schema = Schema::for_version(StandardVersion::V2);
        let (ns, _) =
            compile_rule_set(&schema, &generate_rule_set(&small_cfg, &w, StandardVersion::V2), &opts);
        let (nb, _) =
            compile_rule_set(&schema, &generate_rule_set(&big_cfg, &w, StandardVersion::V2), &opts);
        let hw = HardwareConfig::v2_aws(1);
        assert!(estimate(&hw, &nb).memory_bytes > estimate(&hw, &ns).memory_bytes);
    }
}
