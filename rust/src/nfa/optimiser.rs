//! NFA Optimiser (§3.1): statistical heuristics on the rule set that choose
//! the NFA *shape* — the order of the criteria levels — "for both memory and
//! latency requirements".
//!
//! The driving observation (also §3.2.1): the cardinality at each stage
//! directly drives both the memory to store transitions and the traversal
//! latency. Putting low-branching, high-wildcard criteria *early* maximises
//! prefix sharing (few states near the root); high-cardinality
//! discriminating criteria go late, where their fan-out is paid only once
//! per surviving path.

use std::collections::HashSet;

use crate::rules::standard::{Consolidated, Schema};
use crate::rules::types::{RuleSet, WILDCARD};
use crate::rules::standard::{effective_exact, effective_range};

/// Level-ordering strategy. `Declared` exists as the ablation baseline for
/// the DESIGN.md ablation benches; `Optimised` is what production uses.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OrderStrategy {
    /// Standard-declared order, untouched.
    Declared,
    /// Statistical heuristic (cardinality × non-wildcard rate ascending).
    Optimised,
}

/// Per-criterion statistics collected over a rule set.
#[derive(Debug, Clone)]
pub struct CriterionStats {
    pub criterion: Consolidated,
    /// Distinct non-wildcard labels filed across rules.
    pub cardinality: usize,
    /// Fraction of rules with a non-wildcard value here.
    pub set_rate: f64,
}

impl CriterionStats {
    /// Expected branching contribution — the sort key. A criterion that is
    /// almost always a wildcard and has few distinct values keeps the trie
    /// narrow when placed early.
    pub fn branching_score(&self) -> f64 {
        (1.0 + self.cardinality as f64).ln() * (0.05 + self.set_rate)
    }
}

/// Collect statistics for every consolidated criterion of `schema` over the
/// (already §3.2-rewritten, i.e. *effective*) rule values.
pub fn collect_stats(schema: &Schema, rs: &RuleSet) -> Vec<CriterionStats> {
    schema
        .consolidated()
        .into_iter()
        .map(|c| {
            let mut values: HashSet<u64> = HashSet::new();
            let mut set_count = 0usize;
            for rule in &rs.rules {
                match c {
                    Consolidated::Exact(slot) => {
                        let idx = schema.exact_index(slot).expect("slot in schema");
                        let v = effective_exact(schema, rule, idx);
                        if v != WILDCARD {
                            set_count += 1;
                            values.insert(v as u64);
                        }
                    }
                    Consolidated::Range(slot)
                    | Consolidated::RangeMin(slot)
                    | Consolidated::RangeMax(slot) => {
                        let idx = schema.range_index(slot).expect("slot in schema");
                        let (lo, hi) = effective_range(schema, rule, idx);
                        if (lo, hi) != Schema::full_range(slot) {
                            set_count += 1;
                            values.insert(((lo as u64) << 32) | hi as u64);
                        }
                    }
                }
            }
            CriterionStats {
                criterion: c,
                cardinality: values.len(),
                set_rate: set_count as f64 / rs.rules.len().max(1) as f64,
            }
        })
        .collect()
}

/// Produce the level order for a rule set.
///
/// Invariants regardless of strategy:
/// * `Station` is always level 0 — it is the partition key (DESIGN.md
///   §Hardware-Adaptation) and the most selective criterion anyway;
/// * a `RangeMin`/`RangeMax` pair stays adjacent and ordered (the v2
///   expansion of §3.2.1 is a pure syntactic split of one declared range).
pub fn optimise_order(
    schema: &Schema,
    rs: &RuleSet,
    strategy: OrderStrategy,
) -> Vec<Consolidated> {
    let declared = schema.consolidated();
    match strategy {
        OrderStrategy::Declared => declared,
        OrderStrategy::Optimised => {
            let stats = collect_stats(schema, rs);
            // Group RangeMin/RangeMax pairs into single sortable units.
            #[derive(Debug)]
            struct Unit {
                levels: Vec<Consolidated>,
                score: f64,
                is_station: bool,
            }
            let mut units: Vec<Unit> = Vec::new();
            let mut i = 0;
            while i < declared.len() {
                let c = declared[i];
                let s = stats[i].branching_score();
                match c {
                    Consolidated::RangeMin(slot) => {
                        // Pair with the following RangeMax of the same slot.
                        debug_assert_eq!(declared[i + 1], Consolidated::RangeMax(slot));
                        let s2 = stats[i + 1].branching_score();
                        units.push(Unit {
                            levels: vec![c, declared[i + 1]],
                            score: s.max(s2),
                            is_station: false,
                        });
                        i += 2;
                    }
                    Consolidated::Exact(slot) => {
                        units.push(Unit {
                            levels: vec![c],
                            score: s,
                            is_station: slot == crate::rules::types::ExactSlot::Station,
                        });
                        i += 1;
                    }
                    _ => {
                        units.push(Unit { levels: vec![c], score: s, is_station: false });
                        i += 1;
                    }
                }
            }
            units.sort_by(|a, b| {
                b.is_station
                    .cmp(&a.is_station)
                    .then(a.score.partial_cmp(&b.score).unwrap_or(std::cmp::Ordering::Equal))
            });
            units.into_iter().flat_map(|u| u.levels).collect()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rules::generator::{generate_rule_set, generate_world, GeneratorConfig};
    use crate::rules::standard::StandardVersion;
    use crate::rules::types::ExactSlot;

    fn setup(v: StandardVersion) -> (Schema, RuleSet) {
        let cfg = GeneratorConfig::small(31, 400);
        let w = generate_world(&cfg);
        (Schema::for_version(v), generate_rule_set(&cfg, &w, v))
    }

    #[test]
    fn order_is_a_permutation_of_consolidated() {
        for v in [StandardVersion::V1, StandardVersion::V2] {
            let (schema, rs) = setup(v);
            for strat in [OrderStrategy::Declared, OrderStrategy::Optimised] {
                let order = optimise_order(&schema, &rs, strat);
                let mut a = order.clone();
                let mut b = schema.consolidated();
                let key = |c: &Consolidated| format!("{c:?}");
                a.sort_by_key(key);
                b.sort_by_key(key);
                assert_eq!(a, b, "{v:?} {strat:?}");
            }
        }
    }

    #[test]
    fn station_is_always_first() {
        let (schema, rs) = setup(StandardVersion::V2);
        let order = optimise_order(&schema, &rs, OrderStrategy::Optimised);
        assert_eq!(order[0], Consolidated::Exact(ExactSlot::Station));
    }

    #[test]
    fn range_pairs_stay_adjacent_in_v2() {
        let (schema, rs) = setup(StandardVersion::V2);
        let order = optimise_order(&schema, &rs, OrderStrategy::Optimised);
        for (i, c) in order.iter().enumerate() {
            if let Consolidated::RangeMin(slot) = c {
                assert_eq!(order[i + 1], Consolidated::RangeMax(*slot));
            }
        }
    }

    #[test]
    fn stats_cover_every_level() {
        let (schema, rs) = setup(StandardVersion::V1);
        let stats = collect_stats(&schema, &rs);
        assert_eq!(stats.len(), 22);
        // Station is always filed → set_rate 1.0, decent cardinality.
        let st = &stats[0];
        assert_eq!(st.criterion, Consolidated::Exact(ExactSlot::Station));
        assert!((st.set_rate - 1.0).abs() < 1e-9);
        assert!(st.cardinality > 1);
    }
}
