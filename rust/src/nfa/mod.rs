//! The ERBIUM offline toolchain (§3.1, Fig 2): NFA Optimiser, Constraint
//! Generator and NFA Parser, plus the NFA data model and the memory image
//! handed to the hardware engine.
//!
//! These modules run *offline* ("centralised machines of the cluster") every
//! time the rules change; the online engine only ever sees the compiled
//! [`memory::NfaImage`]s. This split is the paper's central maintainability
//! argument (§3.4): all four MCT v2 standard changes (§3.2) are absorbed
//! here, in software, while the hardware kernel stays untouched.

pub mod constraint_gen;
pub mod memory;
pub mod model;
pub mod optimiser;
pub mod parser;

pub use constraint_gen::{HardwareConfig, KernelEstimate, Shell};
pub use memory::NfaImage;
pub use model::{CompiledNfa, EdgeLabel, LevelPlan, PartitionedNfa};
pub use optimiser::{optimise_order, OrderStrategy};
pub use parser::{compile_rule_set, CompileOptions, CompileStats};
