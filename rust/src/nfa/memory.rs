//! NFA memory image: the dense tensor layout consumed by the hardware
//! engine (the AOT-compiled XLA kernel) and by the dense reference
//! evaluator.
//!
//! This is the TPU analogue of ERBIUM's BRAM memory file (§3.1 "the NFA
//! Parser builds the NFA memory file based on the current hardware settings
//! and on the rule set"): per level a dense `[S, S]` edge matrix with a
//! *kind* plane and `lo`/`hi` label planes. Levels are padded to the
//! artifact depth `L` with identity-`Any` diagonals, so one compiled
//! artifact (fixed `(B, S, L)`) serves every rule set whose partitions fit.
//!
//! Edge kinds (shared constant across Rust, `kernels/nfa_eval.py` and
//! `kernels/ref.py` — keep in sync):
//! `0` = no edge, `1` = exact (`q == lo`), `2` = any, `3` = range
//! (`lo <= q <= hi`).

use anyhow::{bail, Result};

use super::model::{CompiledNfa, EdgeLabel};

pub const KIND_NONE: i32 = 0;
pub const KIND_EXACT: i32 = 1;
pub const KIND_ANY: i32 = 2;
pub const KIND_RANGE: i32 = 3;

/// Score assigned to inactive final states before the argmax (must match
/// `model.py`).
pub const NEG_INF_SCORE: f32 = -1.0e9;

/// Dense NFA image for one partition.
#[derive(Debug, Clone)]
pub struct NfaImage {
    /// Padded depth (levels) — the artifact's `L`.
    pub l: usize,
    /// Padded width (states per level) — the artifact's `S`.
    pub s: usize,
    /// Levels actually used by the partition (≤ `l`).
    pub depth_used: usize,
    /// `[L*S*S]` row-major `[level][from][to]` edge kinds.
    pub kinds: Vec<i32>,
    /// `[L*S*S]` label low values (exact value for `KIND_EXACT`).
    pub lo: Vec<i32>,
    /// `[L*S*S]` label high values.
    pub hi: Vec<i32>,
    /// `[S]` accepting weights (final-level states; padding = 0).
    pub weights: Vec<f32>,
    /// `[S]` decisions in minutes (padding = 0).
    pub decisions: Vec<f32>,
    /// `[S]` original rule ids (padding = `u32::MAX`); not shipped to the
    /// accelerator, used host-side to resolve winners.
    pub rule_ids: Vec<u32>,
    /// Station this image serves (`None` = global partition).
    pub station: Option<u32>,
}

#[inline]
fn sat_i32(v: u32) -> i32 {
    v.min(i32::MAX as u32) as i32
}

impl NfaImage {
    /// Build the dense image of a compiled partition, padding to `(l, s)`.
    pub fn from_compiled(nfa: &CompiledNfa, l: usize, s: usize) -> Result<NfaImage> {
        let depth_used = nfa.depth();
        if depth_used == 0 {
            bail!("empty NFA");
        }
        if depth_used > l {
            bail!("NFA depth {depth_used} exceeds artifact depth {l}");
        }
        let width = nfa.max_width();
        if width > s {
            bail!("NFA width {width} exceeds artifact width {s}");
        }
        let mut kinds = vec![KIND_NONE; l * s * s];
        let mut lo = vec![0i32; l * s * s];
        let mut hi = vec![0i32; l * s * s];
        let idx = |lv: usize, f: usize, t: usize| (lv * s + f) * s + t;
        for (lv, level_states) in nfa.states.iter().enumerate() {
            for (from, edges) in level_states.iter().enumerate() {
                for e in edges {
                    let i = idx(lv, from, e.to as usize);
                    match e.label {
                        EdgeLabel::Any => kinds[i] = KIND_ANY,
                        EdgeLabel::Exact(v) => {
                            kinds[i] = KIND_EXACT;
                            lo[i] = sat_i32(v);
                        }
                        EdgeLabel::Range(a, b) => {
                            kinds[i] = KIND_RANGE;
                            lo[i] = sat_i32(a);
                            hi[i] = sat_i32(b);
                        }
                    }
                }
            }
        }
        // Padding levels: identity-Any diagonal keeps the active set fixed.
        for lv in depth_used..l {
            for st in 0..s {
                kinds[idx(lv, st, st)] = KIND_ANY;
            }
        }
        let mut weights = vec![0f32; s];
        let mut decisions = vec![0f32; s];
        let mut rule_ids = vec![u32::MAX; s];
        for (i, a) in nfa.accepts.iter().enumerate() {
            weights[i] = a.weight;
            decisions[i] = a.decision_min as f32;
            rule_ids[i] = a.rule_id;
        }
        Ok(NfaImage {
            l,
            s,
            depth_used,
            kinds,
            lo,
            hi,
            weights,
            decisions,
            rule_ids,
            station: nfa.station,
        })
    }

    /// On-accelerator memory footprint of this image in bytes (three `[L,S,S]`
    /// i32 planes + two `[S]` f32 vectors) — the quantity behind the paper's
    /// "requires 4 % less FPGA memory" comparison (§3.3).
    pub fn memory_bytes(&self) -> usize {
        3 * self.l * self.s * self.s * 4 + 2 * self.s * 4
    }

    /// Dense *scalar* reference evaluation of one encoded query — the
    /// semantics the XLA kernel implements, expressed in plain Rust. Used by
    /// tests to pin image construction and by no hot path.
    ///
    /// Returns `(best_state, weight, decision)`; `best_state == usize::MAX`
    /// when nothing matched.
    pub fn evaluate_scalar(&self, q: &[i32]) -> (usize, f32, f32) {
        assert_eq!(q.len(), self.l);
        let mut active = vec![false; self.s];
        active[0] = true;
        let mut next = vec![false; self.s];
        let idx = |lv: usize, f: usize, t: usize| (lv * self.s + f) * self.s + t;
        for lv in 0..self.l {
            next.iter_mut().for_each(|x| *x = false);
            for from in 0..self.s {
                if !active[from] {
                    continue;
                }
                for to in 0..self.s {
                    let i = idx(lv, from, to);
                    let hit = match self.kinds[i] {
                        KIND_NONE => false,
                        KIND_EXACT => self.lo[i] == q[lv],
                        KIND_ANY => true,
                        KIND_RANGE => self.lo[i] <= q[lv] && q[lv] <= self.hi[i],
                        k => unreachable!("bad kind {k}"),
                    };
                    if hit {
                        next[to] = true;
                    }
                }
            }
            std::mem::swap(&mut active, &mut next);
        }
        let mut best = usize::MAX;
        let mut best_w = NEG_INF_SCORE;
        for st in 0..self.s {
            if active[st] && self.rule_ids[st] != u32::MAX && self.weights[st] > best_w {
                best = st;
                best_w = self.weights[st];
            }
        }
        if best == usize::MAX {
            (usize::MAX, 0.0, 0.0)
        } else {
            (best, self.weights[best], self.decisions[best])
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nfa::model::{Accept, Edge, LevelPlan};
    use crate::rules::standard::Consolidated;
    use crate::rules::types::ExactSlot;

    /// Tiny hand-built 2-level NFA:
    ///   level 0 (station): root --Exact(7)--> s0 ; root --Any--> s1
    ///   level 1 (terminal): s0 --Exact(1)--> accept0(w=5, 25min)
    ///                       s1 --Any-->      accept1(w=1, 90min)
    fn tiny() -> CompiledNfa {
        let plan = vec![
            LevelPlan { criterion: Consolidated::Exact(ExactSlot::Station) },
            LevelPlan { criterion: Consolidated::Exact(ExactSlot::ArrTerminal) },
        ];
        CompiledNfa {
            plan,
            states: vec![
                vec![vec![
                    Edge { label: EdgeLabel::Exact(7), to: 0 },
                    Edge { label: EdgeLabel::Any, to: 1 },
                ]],
                vec![
                    vec![Edge { label: EdgeLabel::Exact(1), to: 0 }],
                    vec![Edge { label: EdgeLabel::Any, to: 1 }],
                ],
            ],
            accepts: vec![
                Accept { rule_id: 10, weight: 5.0, decision_min: 25 },
                Accept { rule_id: 11, weight: 1.0, decision_min: 90 },
            ],
            station: Some(7),
        }
    }

    #[test]
    fn image_shape_and_padding() {
        let img = NfaImage::from_compiled(&tiny(), 4, 8).unwrap();
        assert_eq!(img.kinds.len(), 4 * 8 * 8);
        // Padding level 2 has identity-Any.
        let idx = |lv: usize, f: usize, t: usize| (lv * 8 + f) * 8 + t;
        assert_eq!(img.kinds[idx(2, 3, 3)], KIND_ANY);
        assert_eq!(img.kinds[idx(2, 3, 4)], KIND_NONE);
    }

    #[test]
    fn scalar_eval_precise_beats_generic() {
        let img = NfaImage::from_compiled(&tiny(), 4, 8).unwrap();
        // station=7, terminal=1, padded zeros.
        let (st, w, d) = img.evaluate_scalar(&[7, 1, 0, 0]);
        assert_eq!(st, 0);
        assert_eq!(w, 5.0);
        assert_eq!(d, 25.0);
        // station=9 → only the Any path.
        let (st, _, d) = img.evaluate_scalar(&[9, 1, 0, 0]);
        assert_eq!(st, 1);
        assert_eq!(d, 90.0);
        // station=7, terminal=2 → specific path dies at level 1, Any path
        // (root --Any--> s1) still matches.
        let (st, _, d) = img.evaluate_scalar(&[7, 2, 0, 0]);
        assert_eq!(st, 1);
        assert_eq!(d, 90.0);
    }

    #[test]
    fn oversize_nfa_rejected() {
        assert!(NfaImage::from_compiled(&tiny(), 1, 8).is_err());
        assert!(NfaImage::from_compiled(&tiny(), 4, 1).is_err());
    }

    #[test]
    fn memory_accounting() {
        let img = NfaImage::from_compiled(&tiny(), 4, 8).unwrap();
        assert_eq!(img.memory_bytes(), 3 * 4 * 8 * 8 * 4 + 2 * 8 * 4);
    }
}
